//! Memory management: the paper's third technique, **peer memory pooling**
//! (PMEP, §4.4), plus the BMInf-style CPU-offload baseline it is compared
//! against in Fig. 13.
//!
//! The abstraction the worker executor sees is [`LayerProvider`]: "give me
//! layer k's weights, and here's a hint that layer k+lookahead is coming."
//! * [`ResidentProvider`] — everything in device memory (the common case).
//! * [`pool::PooledProvider`] — layers parked in peer-GPU (or host) memory,
//!   prefetched by a background copier thread over a modelled link, with
//!   eviction after use. Blocking on an unfinished copy is recorded as
//!   stall time — the number PMEP is designed to drive to zero.
//!
//! The **paged K/V cache** ([`kvcache`]) lives here too: per-session K/V
//! storage for incremental decode, carved from one worker-local slab in
//! fixed-size position blocks with free-list recycling — the memory-
//! pooling discipline of §4.4 applied to generation state, so thousands
//! of concurrent sessions share the slab without per-session allocation.
//! The cache is **two-tiered**: cold sessions spill whole-session block
//! images into a ledger-accounted host arena ([`kvcache::tier`]) and are
//! staged back before their next decode bucket dispatches, under an
//! engine-side LRU policy ([`kvcache::tier::TierPolicy`]) — so the live
//! session count is bounded by device + host capacity, not the slab.
//!
//! A further concern is the **activation arena** ([`arena`]),
//! the size-bucketed `Vec<f32>` recycler behind the zero-copy host hot
//! path (§Perf). Ownership rules in one line: *whoever checks a buffer out
//! returns it by dropping it* — drops shelve the buffer on the dropping
//! thread, so buffers that cross channels (collective chunks, activation
//! handoffs) migrate to the consumer's shelf, which is exactly where the
//! next symmetric send will check them out again. See the module docs of
//! [`arena`] for the full model.

pub mod arena;
pub mod kvcache;
pub mod ledger;
pub mod pool;

pub use arena::{ArenaBuf, ArenaPool, ArenaStats};
pub use kvcache::tier::{TierCmd, TierConfig, TierPolicy};
pub use kvcache::{KvCache, KvCacheConfig, KvStats};
pub use ledger::MemoryLedger;
pub use pool::{PoolConfig, PooledProvider};

use crate::model::weights::LayerWeights;
use crate::tensor::Value;

/// Statistics a provider accumulates (EXPERIMENTS.md §PMEP reads these).
#[derive(Clone, Copy, Debug, Default)]
pub struct ProviderStats {
    pub prefetches: u64,
    pub sync_fetches: u64,
    pub stall_us: u64,
    pub bytes_copied: u64,
    pub evictions: u64,
}

/// Source of per-layer weights for a worker executor.
pub trait LayerProvider: Send {
    fn n_layers(&self) -> usize;

    /// Hint: layer `layer` will be needed soon (async prefetch).
    fn prefetch(&mut self, _layer: usize) {}

    /// Blocking access to the layer's argument vectors.
    fn attn_args(&mut self, layer: usize) -> Vec<Value>;
    fn mlp_args(&mut self, layer: usize) -> Vec<Value>;
    fn all_args(&mut self, layer: usize) -> Vec<Value>;

    /// Hint: layer `layer` is done for this batch (eviction point).
    fn release(&mut self, _layer: usize) {}

    /// Monotonic counter bumped whenever the layer's weights may have
    /// changed identity (eviction + refetch). Lets the worker cache
    /// device-resident weight literals safely (§Perf).
    fn epoch(&self, _layer: usize) -> u64 {
        0
    }

    fn stats(&self) -> ProviderStats {
        ProviderStats::default()
    }
}

/// All layers resident in device memory.
pub struct ResidentProvider {
    layers: Vec<LayerWeights>,
}

impl ResidentProvider {
    pub fn new(layers: Vec<LayerWeights>) -> ResidentProvider {
        ResidentProvider { layers }
    }
}

impl LayerProvider for ResidentProvider {
    fn n_layers(&self) -> usize {
        self.layers.len()
    }

    fn attn_args(&mut self, layer: usize) -> Vec<Value> {
        self.layers[layer].attn_args()
    }

    fn mlp_args(&mut self, layer: usize) -> Vec<Value> {
        self.layers[layer].mlp_args()
    }

    fn all_args(&mut self, layer: usize) -> Vec<Value> {
        self.layers[layer].all_args()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::model::weights::ModelWeights;

    #[test]
    fn resident_provider_serves_all_layers() {
        let cfg = ModelConfig::preset("tiny").unwrap();
        let m = ModelWeights::random(&cfg, 1);
        let mut p = ResidentProvider::new(m.layers.clone());
        assert_eq!(p.n_layers(), 4);
        assert_eq!(p.attn_args(0).len(), 6);
        assert_eq!(p.mlp_args(3).len(), 6);
        assert_eq!(p.all_args(1).len(), 12);
        p.prefetch(2); // no-ops
        p.release(0);
        assert_eq!(p.stats().bytes_copied, 0);
    }
}
