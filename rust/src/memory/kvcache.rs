//! Paged per-session K/V cache: the storage half of incremental decode.
//!
//! Generation sessions keep the K/V rows of every processed position so a
//! decode step runs *one* position through the linears instead of
//! re-running the whole prefix (the paper's redundant-computation-
//! elimination idea, §4.2.2, applied along the time axis). Storage is
//! **paged** in the spirit of the paper's memory-pooling technique (§4.4):
//! one worker-local slab is carved into fixed-size *position blocks*; each
//! session holds a block table mapping logical position-block → physical
//! block, so thousands of concurrent sessions of wildly different lengths
//! share the slab with at most `block_positions - 1` wasted rows each and
//! zero copying on growth.
//!
//! Block layout (one block, `layers` local layers, K and V planes):
//!
//! ```text
//! [layer 0 | K rows][layer 0 | V rows][layer 1 | K rows]...
//!            each plane: block_positions × width f32
//! ```
//!
//! so the (layer, K/V) plane of a block is contiguous and `gather` into
//! the per-step staging tensor is one `copy_from_slice` per (block,
//! layer). Freed blocks go to a free list and are recycled before the
//! slab grows; alloc/recycle/peak counters are mirrored into process-wide
//! atomics surfaced through `metrics::Recorder` (like the activation
//! arena's, §Perf).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};

/// Process-wide counters, aggregated across every worker's cache.
/// `blocks_in_use` is a gauge; the rest are monotonic.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct KvStats {
    /// Blocks currently backing live sessions (all workers).
    pub blocks_in_use: u64,
    /// High-water mark of `blocks_in_use`.
    pub blocks_peak: u64,
    /// Block checkouts served from a free list instead of slab growth.
    pub blocks_recycled: u64,
    /// Blocks newly carved by growing a slab.
    pub blocks_grown: u64,
    /// Total slab bytes reserved across workers.
    pub slab_bytes: u64,
    /// Sessions currently holding cache entries.
    pub sessions: u64,
}

static G_IN_USE: AtomicU64 = AtomicU64::new(0);
static G_PEAK: AtomicU64 = AtomicU64::new(0);
static G_RECYCLED: AtomicU64 = AtomicU64::new(0);
static G_GROWN: AtomicU64 = AtomicU64::new(0);
static G_SLAB_BYTES: AtomicU64 = AtomicU64::new(0);
static G_SESSIONS: AtomicU64 = AtomicU64::new(0);

/// Process-wide snapshot (what `Engine::metrics_snapshot` folds into the
/// `Recorder`). Workers update the atomics as they allocate and free.
pub fn global_stats() -> KvStats {
    KvStats {
        blocks_in_use: G_IN_USE.load(Ordering::Relaxed),
        blocks_peak: G_PEAK.load(Ordering::Relaxed),
        blocks_recycled: G_RECYCLED.load(Ordering::Relaxed),
        blocks_grown: G_GROWN.load(Ordering::Relaxed),
        slab_bytes: G_SLAB_BYTES.load(Ordering::Relaxed),
        sessions: G_SESSIONS.load(Ordering::Relaxed),
    }
}

fn note_in_use_delta(delta: i64) {
    let now = if delta >= 0 {
        G_IN_USE.fetch_add(delta as u64, Ordering::Relaxed) + delta as u64
    } else {
        G_IN_USE.fetch_sub((-delta) as u64, Ordering::Relaxed) - (-delta) as u64
    };
    G_PEAK.fetch_max(now, Ordering::Relaxed);
}

/// Geometry of one worker's cache.
#[derive(Clone, Copy, Debug)]
pub struct KvCacheConfig {
    /// Positions per block (the paging granularity).
    pub block_positions: usize,
    /// Local transformer layers this worker executes.
    pub layers: usize,
    /// Width of one K (or V) row in f32 — `hidden / tp`.
    pub width: usize,
    /// Blocks added per slab growth (amortizes allocation).
    pub grow_blocks: usize,
}

impl KvCacheConfig {
    pub fn new(block_positions: usize, layers: usize, width: usize) -> KvCacheConfig {
        assert!(block_positions >= 1 && layers >= 1 && width >= 1);
        KvCacheConfig { block_positions, layers, width, grow_blocks: 64 }
    }

    /// f32 elements in one block: layers × {K,V} × positions × width.
    pub fn block_elems(&self) -> usize {
        self.layers * 2 * self.block_positions * self.width
    }
}

/// One session's cache state: its block table and filled length.
#[derive(Debug, Default)]
struct SessionKv {
    /// Logical position-block b lives in physical block `blocks[b]`.
    blocks: Vec<u32>,
    /// Positions 0..len hold valid K/V rows (all layers).
    len: usize,
}

/// Worker-local paged K/V store. Single-threaded by construction (it lives
/// inside a `Worker`); cross-worker visibility is via the global counters.
pub struct KvCache {
    cfg: KvCacheConfig,
    slab: Vec<f32>,
    free_list: Vec<u32>,
    sessions: HashMap<u64, SessionKv>,
    n_blocks: usize,
}

impl KvCache {
    pub fn new(cfg: KvCacheConfig) -> KvCache {
        KvCache {
            cfg,
            slab: Vec::new(),
            free_list: Vec::new(),
            sessions: HashMap::new(),
            n_blocks: 0,
        }
    }

    pub fn config(&self) -> &KvCacheConfig {
        &self.cfg
    }

    /// Blocks currently reserved by live sessions (this worker).
    pub fn blocks_in_use(&self) -> usize {
        self.n_blocks - self.free_list.len()
    }

    /// Total blocks ever carved into this worker's slab.
    pub fn capacity_blocks(&self) -> usize {
        self.n_blocks
    }

    pub fn session_count(&self) -> usize {
        self.sessions.len()
    }

    /// Positions filled for a session (`None` if it has no cache entry).
    pub fn len(&self, session: u64) -> Option<usize> {
        self.sessions.get(&session).map(|s| s.len)
    }

    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty()
    }

    fn checkout_block(&mut self) -> u32 {
        if let Some(b) = self.free_list.pop() {
            G_RECYCLED.fetch_add(1, Ordering::Relaxed);
            note_in_use_delta(1);
            return b;
        }
        // grow the slab by a chunk of blocks; existing indices stay valid
        let first = self.n_blocks as u32;
        let add = self.cfg.grow_blocks.max(1);
        self.slab.resize((self.n_blocks + add) * self.cfg.block_elems(), 0.0);
        self.n_blocks += add;
        G_GROWN.fetch_add(add as u64, Ordering::Relaxed);
        G_SLAB_BYTES.fetch_add((add * self.cfg.block_elems() * 4) as u64, Ordering::Relaxed);
        // newly carved blocks beyond the checked-out one go to the free list
        for b in (first + 1)..(self.n_blocks as u32) {
            self.free_list.push(b);
        }
        note_in_use_delta(1);
        first
    }

    /// Ensure `session` has blocks covering positions `0..=pos`.
    fn ensure(&mut self, session: u64, pos: usize) {
        if !self.sessions.contains_key(&session) {
            G_SESSIONS.fetch_add(1, Ordering::Relaxed);
            self.sessions.insert(session, SessionKv::default());
        }
        let need = pos / self.cfg.block_positions + 1;
        let have = self.sessions[&session].blocks.len();
        for _ in have..need {
            let b = self.checkout_block();
            self.sessions.get_mut(&session).unwrap().blocks.push(b);
        }
    }

    /// Offset of the (block-local) K plane of `(physical block, layer)`.
    fn plane(&self, block: u32, layer: usize, v_plane: bool) -> usize {
        let bp = self.cfg.block_positions;
        let w = self.cfg.width;
        block as usize * self.cfg.block_elems() + (layer * 2 + v_plane as usize) * bp * w
    }

    /// Write one position's K and V rows for one layer. Allocates blocks as
    /// needed; `advance` publishes the position once every layer wrote it.
    pub fn write_row(&mut self, session: u64, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        let w = self.cfg.width;
        assert_eq!(k.len(), w, "k row width mismatch");
        assert_eq!(v.len(), w, "v row width mismatch");
        assert!(layer < self.cfg.layers, "layer {layer} out of range");
        self.ensure(session, pos);
        let bp = self.cfg.block_positions;
        let block = self.sessions[&session].blocks[pos / bp];
        let slot = pos % bp;
        let k_off = self.plane(block, layer, false) + slot * w;
        self.slab[k_off..k_off + w].copy_from_slice(k);
        let v_off = self.plane(block, layer, true) + slot * w;
        self.slab[v_off..v_off + w].copy_from_slice(v);
    }

    /// Write positions `0..len` of one layer in bulk (prefill seeding):
    /// `k`/`v` hold `len` contiguous rows. The mirror of [`KvCache::gather`]
    /// — one `copy_from_slice` per (block, layer) plane instead of
    /// per-position lookups.
    pub fn write_prefix(&mut self, session: u64, layer: usize, len: usize, k: &[f32], v: &[f32]) {
        let w = self.cfg.width;
        assert!(k.len() >= len * w && v.len() >= len * w, "prefix rows too short");
        assert!(layer < self.cfg.layers, "layer {layer} out of range");
        if len == 0 {
            return;
        }
        self.ensure(session, len - 1);
        let bp = self.cfg.block_positions;
        let mut done = 0usize;
        for bi in 0..(len + bp - 1) / bp {
            let block = self.sessions[&session].blocks[bi];
            let take = (len - done).min(bp);
            let k_off = self.plane(block, layer, false);
            self.slab[k_off..k_off + take * w].copy_from_slice(&k[done * w..(done + take) * w]);
            let v_off = self.plane(block, layer, true);
            self.slab[v_off..v_off + take * w].copy_from_slice(&v[done * w..(done + take) * w]);
            done += take;
        }
    }

    /// Publish that positions `0..len` are now valid for `session` (called
    /// once per engine step, after every local layer wrote its rows).
    pub fn advance(&mut self, session: u64, len: usize) {
        let s = self.sessions.get_mut(&session).expect("advance on unknown session");
        debug_assert!(len >= s.len, "cache cannot shrink");
        s.len = len;
    }

    /// Copy a session's filled K and V rows for `layer` into the head of
    /// `dst_k`/`dst_v` (the per-step staging tensors, laid out as
    /// `capacity × width` rows per batch row). Returns the copied length.
    pub fn gather(&self, session: u64, layer: usize, dst_k: &mut [f32], dst_v: &mut [f32]) -> usize {
        let s = match self.sessions.get(&session) {
            Some(s) => s,
            None => return 0,
        };
        let bp = self.cfg.block_positions;
        let w = self.cfg.width;
        assert!(s.len * w <= dst_k.len() && s.len * w <= dst_v.len(), "staging too small");
        let mut done = 0usize;
        for &block in &s.blocks {
            let take = (s.len - done).min(bp);
            if take == 0 {
                break;
            }
            let k_off = self.plane(block, layer, false);
            dst_k[done * w..(done + take) * w]
                .copy_from_slice(&self.slab[k_off..k_off + take * w]);
            let v_off = self.plane(block, layer, true);
            dst_v[done * w..(done + take) * w]
                .copy_from_slice(&self.slab[v_off..v_off + take * w]);
            done += take;
        }
        done
    }

    /// Release a session's blocks back to the free list. Idempotent.
    pub fn free(&mut self, session: u64) {
        if let Some(s) = self.sessions.remove(&session) {
            let n = s.blocks.len();
            self.free_list.extend(s.blocks);
            if n > 0 {
                note_in_use_delta(-(n as i64));
            }
            G_SESSIONS.fetch_sub(1, Ordering::Relaxed);
        }
    }

    /// Drop every session (worker teardown).
    pub fn clear(&mut self) {
        let ids: Vec<u64> = self.sessions.keys().copied().collect();
        for id in ids {
            self.free(id);
        }
    }
}

impl Drop for KvCache {
    fn drop(&mut self) {
        self.clear();
        G_SLAB_BYTES.fetch_sub((self.n_blocks * self.cfg.block_elems() * 4) as u64, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cache(bp: usize, layers: usize, width: usize) -> KvCache {
        let mut cfg = KvCacheConfig::new(bp, layers, width);
        cfg.grow_blocks = 4; // small chunks so tests exercise growth
        KvCache::new(cfg)
    }

    fn row(tag: f32, w: usize) -> Vec<f32> {
        (0..w).map(|i| tag + i as f32 / 100.0).collect()
    }

    #[test]
    fn write_gather_roundtrip_across_blocks() {
        // 3 positions per block so position 7 spans 3 blocks
        let mut c = cache(3, 2, 4);
        let n = 8;
        for pos in 0..n {
            for layer in 0..2 {
                let tag = (layer * 100 + pos) as f32;
                c.write_row(9, layer, pos, &row(tag, 4), &row(tag + 0.5, 4));
            }
        }
        c.advance(9, n);
        assert_eq!(c.len(9), Some(n));
        for layer in 0..2 {
            let mut k = vec![-1.0; n * 4];
            let mut v = vec![-1.0; n * 4];
            assert_eq!(c.gather(9, layer, &mut k, &mut v), n);
            for pos in 0..n {
                let tag = (layer * 100 + pos) as f32;
                assert_eq!(&k[pos * 4..pos * 4 + 4], &row(tag, 4)[..], "k l{layer} p{pos}");
                assert_eq!(&v[pos * 4..pos * 4 + 4], &row(tag + 0.5, 4)[..], "v l{layer} p{pos}");
            }
        }
        assert_eq!(c.blocks_in_use(), 3); // ceil(8/3)
    }

    #[test]
    fn write_prefix_matches_per_row_writes() {
        let n = 7; // spans 3 blocks of 3
        let w = 4;
        let mut rows_k = Vec::new();
        let mut rows_v = Vec::new();
        for pos in 0..n {
            rows_k.extend(row(pos as f32, w));
            rows_v.extend(row(pos as f32 + 0.5, w));
        }
        let mut a = cache(3, 2, w);
        for pos in 0..n {
            for layer in 0..2 {
                let r = pos * w..(pos + 1) * w;
                a.write_row(1, layer, pos, &rows_k[r.clone()], &rows_v[r]);
            }
        }
        a.advance(1, n);
        let mut b = cache(3, 2, w);
        for layer in 0..2 {
            b.write_prefix(1, layer, n, &rows_k, &rows_v);
        }
        b.advance(1, n);
        for layer in 0..2 {
            let (mut ka, mut va) = (vec![0.0; n * w], vec![0.0; n * w]);
            let (mut kb, mut vb) = (vec![0.0; n * w], vec![0.0; n * w]);
            assert_eq!(a.gather(1, layer, &mut ka, &mut va), n);
            assert_eq!(b.gather(1, layer, &mut kb, &mut vb), n);
            assert_eq!(ka, kb, "layer {layer} k diverged");
            assert_eq!(va, vb, "layer {layer} v diverged");
            assert_eq!(kb, rows_k, "layer {layer} k roundtrip");
        }
        // zero-length prefix is a no-op that allocates nothing
        let mut c = cache(3, 1, w);
        c.write_prefix(9, 0, 0, &[], &[]);
        assert_eq!(c.blocks_in_use(), 0);
    }

    #[test]
    fn gather_copies_only_advanced_prefix() {
        let mut c = cache(4, 1, 2);
        for pos in 0..3 {
            c.write_row(1, 0, pos, &row(pos as f32, 2), &row(pos as f32, 2));
        }
        c.advance(1, 2); // third row written but not yet published
        let mut k = vec![0.0; 4 * 2];
        let mut v = vec![0.0; 4 * 2];
        assert_eq!(c.gather(1, 0, &mut k, &mut v), 2);
        assert_eq!(&k[0..2], &row(0.0, 2)[..]);
        assert_eq!(&k[2..4], &row(1.0, 2)[..]);
        // staging beyond len untouched
        assert_eq!(&k[4..], &[0.0; 4]);
    }

    #[test]
    fn free_recycles_blocks_and_sessions_share_the_slab() {
        let mut c = cache(2, 1, 2);
        // 100 sequential sessions of 6 positions (3 blocks each): the slab
        // must not grow past what one session needs (plus grow chunking)
        let mut peak_capacity = 0;
        for id in 0..100u64 {
            for pos in 0..6 {
                c.write_row(id, 0, pos, &row(pos as f32, 2), &row(pos as f32, 2));
            }
            c.advance(id, 6);
            peak_capacity = peak_capacity.max(c.capacity_blocks());
            c.free(id);
            assert_eq!(c.blocks_in_use(), 0, "session {id} leaked blocks");
        }
        assert_eq!(c.capacity_blocks(), peak_capacity, "slab grew after first session");
        assert!(peak_capacity <= 4, "one 3-block session grew {peak_capacity} blocks");
        assert_eq!(c.session_count(), 0);
    }

    #[test]
    fn free_is_idempotent_and_unknown_gather_is_empty() {
        let mut c = cache(2, 1, 2);
        c.write_row(5, 0, 0, &[1.0, 2.0], &[3.0, 4.0]);
        c.advance(5, 1);
        c.free(5);
        c.free(5);
        let mut k = vec![0.0; 2];
        let mut v = vec![0.0; 2];
        assert_eq!(c.gather(5, 0, &mut k, &mut v), 0);
        assert_eq!(c.blocks_in_use(), 0);
    }

    #[test]
    fn concurrent_sessions_do_not_alias() {
        let mut c = cache(2, 1, 2);
        for id in 0..8u64 {
            for pos in 0..5 {
                let tag = (id * 10 + pos as u64) as f32;
                c.write_row(id, 0, pos, &row(tag, 2), &row(tag, 2));
            }
            c.advance(id, 5);
        }
        for id in 0..8u64 {
            let mut k = vec![0.0; 5 * 2];
            let mut v = vec![0.0; 5 * 2];
            assert_eq!(c.gather(id, 0, &mut k, &mut v), 5);
            for pos in 0..5 {
                let tag = (id * 10 + pos as u64) as f32;
                assert_eq!(&k[pos * 2..pos * 2 + 2], &row(tag, 2)[..], "id {id} pos {pos}");
            }
        }
        assert_eq!(c.blocks_in_use(), 8 * 3); // ceil(5/2) per session
    }

    #[test]
    fn global_stats_track_use_and_recycling() {
        // other tests mutate the process-wide counters concurrently, so
        // assert only on monotonic counters' deltas
        let before = global_stats();
        let mut c = cache(2, 1, 2);
        c.write_row(1, 0, 0, &[1.0, 2.0], &[3.0, 4.0]);
        c.advance(1, 1);
        let mid = global_stats();
        assert!(mid.blocks_grown > before.blocks_grown, "growth not counted");
        assert!(mid.blocks_peak >= 1);
        c.free(1);
        c.write_row(2, 0, 0, &[1.0, 2.0], &[3.0, 4.0]);
        let after = global_stats();
        assert!(after.blocks_recycled > before.blocks_recycled, "free list unused");
        // instance-level invariants are deterministic
        assert_eq!(c.blocks_in_use(), 1);
        assert_eq!(c.session_count(), 1);
    }

    #[test]
    #[should_panic]
    fn wrong_width_panics() {
        let mut c = cache(2, 1, 4);
        c.write_row(0, 0, 0, &[1.0], &[1.0]);
    }
}
