//! Per-device memory accounting. The pool's placement policy (§4.4:
//! "through monitoring memory space on all GPUs, the memory pool decides
//! which device is available for offloading") reads these ledgers; Fig. 13
//! scenarios are expressed as capacity budgets.

use std::fmt;

/// Byte-accurate alloc/free ledger for one device.
#[derive(Clone, Debug)]
pub struct MemoryLedger {
    pub device: usize,
    pub capacity: u64,
    used: u64,
    peak: u64,
    /// Which tier this ledger accounts ("device", "host", "peer", ...):
    /// names the tier in the alloc-failure message so an operator knows
    /// *which* budget to resize.
    tier: &'static str,
    /// Free-path over-credits observed: a `dealloc` of more bytes than
    /// were allocated. Loud (counted here, debug-asserted) but tolerated
    /// in release builds — usage clamps to zero instead of wrapping.
    over_credits: u64,
}

impl MemoryLedger {
    pub fn new(device: usize, capacity: u64) -> MemoryLedger {
        MemoryLedger { device, capacity, used: 0, peak: 0, tier: "device", over_credits: 0 }
    }

    /// Label the tier this ledger accounts (shows up in OOM messages).
    pub fn with_tier(mut self, tier: &'static str) -> MemoryLedger {
        self.tier = tier;
        self
    }

    pub fn tier(&self) -> &'static str {
        self.tier
    }

    pub fn used(&self) -> u64 {
        self.used
    }

    /// Over-credits seen on the free path (each one is an accounting bug).
    pub fn over_credits(&self) -> u64 {
        self.over_credits
    }

    pub fn free(&self) -> u64 {
        self.capacity - self.used
    }

    pub fn peak(&self) -> u64 {
        self.peak
    }

    pub fn can_fit(&self, bytes: u64) -> bool {
        self.used + bytes <= self.capacity
    }

    /// Reserve bytes; errors if over capacity (the memory wall, literally).
    /// The message carries everything an operator needs to size the tier:
    /// the device id, the requested size, and how much is actually free.
    pub fn alloc(&mut self, bytes: u64) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.can_fit(bytes),
            "{} tier, device {} OOM: requested {} but only {} of {} free ({} used, peak {})",
            self.tier,
            self.device,
            crate::util::fmt_bytes(bytes),
            crate::util::fmt_bytes(self.free()),
            crate::util::fmt_bytes(self.capacity),
            crate::util::fmt_bytes(self.used),
            crate::util::fmt_bytes(self.peak)
        );
        self.used += bytes;
        self.peak = self.peak.max(self.used);
        Ok(())
    }

    /// Return bytes. Crediting more than is outstanding is an accounting
    /// bug somewhere on the free path; it is counted and debug-asserted
    /// (matching the kvcache anomaly style) rather than silently wrapping
    /// or hard-aborting a release build, and usage clamps to zero so the
    /// ledger stays sane for everything that follows.
    pub fn dealloc(&mut self, bytes: u64) {
        if bytes > self.used {
            self.over_credits += 1;
            eprintln!(
                "kvcache anomaly: over-credit of {} on {} tier, device {} (only {} used)",
                crate::util::fmt_bytes(bytes),
                self.tier,
                self.device,
                crate::util::fmt_bytes(self.used)
            );
            debug_assert!(
                false,
                "over-credit of {bytes} on {} tier, device {} (only {} used)",
                self.tier, self.device, self.used
            );
            self.used = 0;
            return;
        }
        self.used -= bytes;
    }
}

impl fmt::Display for MemoryLedger {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "dev{}: {}/{} used (peak {})",
            self.device,
            crate::util::fmt_bytes(self.used),
            crate::util::fmt_bytes(self.capacity),
            crate::util::fmt_bytes(self.peak)
        )
    }
}

/// Even-spread placement (§4.4: "layers to be offloaded are distributed
/// evenly among those to be held on device"): given `n_layers` and how many
/// fit locally, choose which layer indices live off-device.
///
/// Example from the paper: 24 layers, 20 local → offload {5, 11, 17, 23}.
pub fn even_offload_placement(n_layers: usize, n_local: usize) -> Vec<usize> {
    assert!(n_local <= n_layers);
    let n_off = n_layers - n_local;
    if n_off == 0 {
        return vec![];
    }
    // spread the offloaded layers evenly: layer i is offloaded when it is
    // the last of each of n_off equal groups
    let mut out = Vec::with_capacity(n_off);
    for k in 1..=n_off {
        let idx = (k * n_layers) / n_off - 1;
        out.push(idx);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_roundtrip() {
        let mut l = MemoryLedger::new(0, 100);
        l.alloc(60).unwrap();
        assert_eq!(l.used(), 60);
        assert_eq!(l.free(), 40);
        l.dealloc(20);
        assert_eq!(l.used(), 40);
        assert_eq!(l.peak(), 60);
    }

    #[test]
    fn oom_is_error_not_panic() {
        let mut l = MemoryLedger::new(1, 100);
        l.alloc(90).unwrap();
        assert!(l.alloc(20).is_err());
        assert_eq!(l.used(), 90); // failed alloc doesn't leak
    }

    #[test]
    fn oom_message_names_tier_device_and_free_bytes() {
        let mut l = MemoryLedger::new(3, 100);
        l.alloc(90).unwrap();
        let msg = l.alloc(20).unwrap_err().to_string();
        assert!(msg.contains("device 3"), "{msg}");
        assert!(msg.contains("device tier"), "{msg}");
        assert!(msg.contains("requested 20B"), "{msg}");
        assert!(msg.contains("10B of 100B free"), "{msg}");

        let mut h = MemoryLedger::new(3, 100).with_tier("host");
        h.alloc(90).unwrap();
        let msg = h.alloc(20).unwrap_err().to_string();
        assert!(msg.contains("host tier"), "{msg}");
    }

    #[test]
    fn exact_capacity_boundary() {
        let mut l = MemoryLedger::new(0, 100);
        // filling to exactly capacity is allowed...
        assert!(l.can_fit(100));
        l.alloc(100).unwrap();
        assert_eq!(l.free(), 0);
        assert_eq!(l.peak(), 100);
        // ...but one more byte is not, and the failed alloc moves nothing
        assert!(!l.can_fit(1));
        assert!(l.alloc(1).is_err());
        assert_eq!(l.used(), 100);
        assert_eq!(l.peak(), 100);
        // zero-byte allocs at the boundary are free
        assert!(l.can_fit(0));
        l.alloc(0).unwrap();
        // draining and refilling keeps the peak at the high-water mark
        l.dealloc(100);
        l.alloc(40).unwrap();
        assert_eq!(l.peak(), 100);
    }

    #[test]
    fn over_credit_is_loud_but_tolerated() {
        use std::panic::{catch_unwind, AssertUnwindSafe};
        let mut l = MemoryLedger::new(0, 100);
        l.alloc(10).unwrap();
        // crediting more than is outstanding trips the debug_assert in
        // debug builds; in release it is counted and the ledger clamps
        let got = catch_unwind(AssertUnwindSafe(|| l.dealloc(11)));
        match got {
            Ok(()) => assert!(!cfg!(debug_assertions)),
            Err(_) => assert!(cfg!(debug_assertions)),
        }
        if !cfg!(debug_assertions) {
            assert_eq!(l.over_credits(), 1);
            assert_eq!(l.used(), 0);
            // the ledger still works after the anomaly
            l.alloc(30).unwrap();
            assert_eq!(l.used(), 30);
            l.dealloc(30);
            assert_eq!(l.over_credits(), 1);
        }
    }

    #[test]
    fn paper_placement_24_layers_20_local() {
        // §5.6: "Taking the 24-layer GPT-3 for example, layers No.5, 11,
        // 17, and 23 are offloaded."
        assert_eq!(even_offload_placement(24, 20), vec![5, 11, 17, 23]);
    }

    #[test]
    fn placement_edge_cases() {
        assert_eq!(even_offload_placement(10, 10), Vec::<usize>::new());
        assert_eq!(even_offload_placement(4, 0), vec![0, 1, 2, 3]);
        // 40 layers, 20 local -> every other layer offloaded
        let p = even_offload_placement(40, 20);
        assert_eq!(p.len(), 20);
        assert_eq!(p[0], 1);
        assert_eq!(p[19], 39);
    }

    #[test]
    fn placement_is_sorted_unique() {
        for (n, local) in [(24, 20), (30, 20), (40, 20), (13, 7)] {
            let p = even_offload_placement(n, local);
            assert_eq!(p.len(), n - local);
            let mut q = p.clone();
            q.sort();
            q.dedup();
            assert_eq!(p, q);
            assert!(p.iter().all(|&i| i < n));
        }
    }
}
