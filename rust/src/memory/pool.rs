//! Peer memory pooling (PMEP, §4.4) and the BMInf-style CPU-offload
//! baseline, behind one implementation with two configs.
//!
//! Off-device layers live in a *peer store* (peer-GPU memory in the paper;
//! host memory for the BMInf baseline). A background copier thread plays
//! the role of the dedicated CUDA copy stream (Fig. 8's multi-stream
//! pattern): the executor calls `prefetch(k + lookahead)` before running
//! layer k, and by the time it needs layer k+lookahead the copy has
//! usually landed. Every microsecond the executor *does* have to wait is
//! recorded as stall — PMEP's success criterion is stall ≈ 0 while BMInf's
//! synchronous host copies put the whole transfer on the critical path.
//!
//! Copy timing: real `memcpy` plus a modelled link delay
//! (`bytes / link.bandwidth × time_scale`). At paper scale the delay is
//! exercised through the DES (`sim::pmep`); in real execution `time_scale`
//! lets tests make overlap effects visible on fast host memory.

use super::{LayerProvider, ProviderStats};
use crate::comm::topology::Link;
use crate::model::weights::LayerWeights;
use crate::tensor::Value;
use std::collections::HashMap;
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Pool behaviour knobs.
#[derive(Clone, Copy, Debug)]
pub struct PoolConfig {
    /// Prefetch distance in layers (0 disables prefetch → every off-device
    /// layer is a synchronous fetch; this is the BMInf mode when combined
    /// with the host link).
    pub lookahead: usize,
    /// Link the copies traverse (NVLink for peer GPUs, HOST for BMInf).
    pub link: Link,
    /// Multiplier on the modelled copy delay (1.0 = faithful; tests use
    /// larger values to surface overlap behaviour on tiny models).
    pub time_scale: f64,
    /// Evict off-device layers after use (keeps local footprint at
    /// `resident + in-flight`, §4.4's offload-after-compute).
    pub evict_after_use: bool,
}

impl PoolConfig {
    pub fn pmep() -> PoolConfig {
        PoolConfig { lookahead: 1, link: Link::NVLINK, time_scale: 1.0, evict_after_use: true }
    }

    pub fn bminf() -> PoolConfig {
        PoolConfig { lookahead: 0, link: Link::HOST, time_scale: 1.0, evict_after_use: true }
    }
}

enum CopyReq {
    Fetch(usize),
    Stop,
}

struct Shared {
    /// Landed off-device layers (layer idx → weights).
    landed: Mutex<HashMap<usize, Arc<LayerWeights>>>,
    cv: Condvar,
}

/// A worker's pooled layer provider.
pub struct PooledProvider {
    n_layers: usize,
    /// Layers resident in local device memory.
    resident: HashMap<usize, Arc<LayerWeights>>,
    /// Which layers are off-device.
    off_device: Vec<usize>,
    cfg: PoolConfig,
    shared: Arc<Shared>,
    tx: Sender<CopyReq>,
    copier: Option<JoinHandle<()>>,
    in_flight: std::collections::HashSet<usize>,
    epochs: Vec<u64>,
    stats: ProviderStats,
}

impl PooledProvider {
    /// `layers`: the full (already sharded) stack; `off_device`: indices
    /// parked in the peer store (see `ledger::even_offload_placement`).
    pub fn new(layers: Vec<LayerWeights>, off_device: Vec<usize>, cfg: PoolConfig) -> PooledProvider {
        let n_layers = layers.len();
        let mut resident = HashMap::new();
        let mut peer_store: HashMap<usize, Arc<LayerWeights>> = HashMap::new();
        for (i, lw) in layers.into_iter().enumerate() {
            if off_device.contains(&i) {
                peer_store.insert(i, Arc::new(lw));
            } else {
                resident.insert(i, Arc::new(lw));
            }
        }
        let shared = Arc::new(Shared { landed: Mutex::new(HashMap::new()), cv: Condvar::new() });
        let (tx, rx): (Sender<CopyReq>, Receiver<CopyReq>) = std::sync::mpsc::channel();
        let copier = {
            let shared = shared.clone();
            let link = cfg.link;
            let scale = cfg.time_scale;
            std::thread::spawn(move || copier_loop(rx, peer_store, shared, link, scale))
        };
        PooledProvider {
            n_layers,
            resident,
            off_device,
            cfg,
            shared,
            tx,
            copier: Some(copier),
            in_flight: Default::default(),
            epochs: vec![0; n_layers],
            stats: ProviderStats::default(),
        }
    }

    fn is_off_device(&self, layer: usize) -> bool {
        self.off_device.contains(&layer)
    }

    /// Block until an off-device layer has landed.
    fn wait_landed(&mut self, layer: usize) -> Arc<LayerWeights> {
        // issue the fetch if nobody prefetched it (sync path / BMInf)
        if !self.in_flight.contains(&layer) {
            self.stats.sync_fetches += 1;
            self.tx.send(CopyReq::Fetch(layer)).expect("copier alive");
            self.in_flight.insert(layer);
        }
        let t0 = Instant::now();
        let mut landed = self.shared.landed.lock().unwrap();
        loop {
            if let Some(w) = landed.get(&layer) {
                let w = w.clone();
                let stall = t0.elapsed();
                self.stats.stall_us += stall.as_micros() as u64;
                self.stats.bytes_copied += w.bytes();
                return w;
            }
            landed = self.shared.cv.wait(landed).unwrap();
        }
    }

    fn get(&mut self, layer: usize) -> Arc<LayerWeights> {
        assert!(layer < self.n_layers, "layer {layer} out of range");
        if let Some(w) = self.resident.get(&layer) {
            return w.clone();
        }
        self.wait_landed(layer)
    }

    /// Stall time accumulated waiting on copies (µs).
    pub fn stall_us(&self) -> u64 {
        self.stats.stall_us
    }
}

fn copier_loop(
    rx: Receiver<CopyReq>,
    peer_store: HashMap<usize, Arc<LayerWeights>>,
    shared: Arc<Shared>,
    link: Link,
    scale: f64,
) {
    while let Ok(req) = rx.recv() {
        match req {
            CopyReq::Stop => break,
            CopyReq::Fetch(layer) => {
                let src = peer_store
                    .get(&layer)
                    .unwrap_or_else(|| panic!("layer {layer} not in peer store"));
                // modelled link delay (the cudaMemcpyAsync duration)
                let secs = link.transfer_time(src.bytes()) * scale;
                if secs > 0.0 {
                    std::thread::sleep(Duration::from_secs_f64(secs));
                }
                // the "copy": clone the weights into local memory
                let copy = Arc::new((**src).clone());
                let mut landed = shared.landed.lock().unwrap();
                landed.insert(layer, copy);
                shared.cv.notify_all();
            }
        }
    }
}

impl LayerProvider for PooledProvider {
    fn n_layers(&self) -> usize {
        self.n_layers
    }

    fn prefetch(&mut self, layer: usize) {
        if layer >= self.n_layers || self.cfg.lookahead == 0 {
            return;
        }
        if self.is_off_device(layer) && !self.in_flight.contains(&layer) {
            let already_landed = self.shared.landed.lock().unwrap().contains_key(&layer);
            if !already_landed {
                self.stats.prefetches += 1;
                self.tx.send(CopyReq::Fetch(layer)).expect("copier alive");
                self.in_flight.insert(layer);
            }
        }
    }

    fn attn_args(&mut self, layer: usize) -> Vec<Value> {
        self.get(layer).attn_args()
    }

    fn mlp_args(&mut self, layer: usize) -> Vec<Value> {
        self.get(layer).mlp_args()
    }

    fn all_args(&mut self, layer: usize) -> Vec<Value> {
        self.get(layer).all_args()
    }

    fn release(&mut self, layer: usize) {
        if self.cfg.evict_after_use && self.is_off_device(layer) {
            let mut landed = self.shared.landed.lock().unwrap();
            if landed.remove(&layer).is_some() {
                self.stats.evictions += 1;
            }
            self.in_flight.remove(&layer);
            // weights evicted: any cached device literals are stale
            self.epochs[layer] += 1;
        }
    }

    fn epoch(&self, layer: usize) -> u64 {
        self.epochs[layer]
    }

    fn stats(&self) -> ProviderStats {
        self.stats
    }
}

impl Drop for PooledProvider {
    fn drop(&mut self) {
        let _ = self.tx.send(CopyReq::Stop);
        if let Some(h) = self.copier.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::memory::ledger::even_offload_placement;
    use crate::model::weights::ModelWeights;

    fn layers() -> Vec<LayerWeights> {
        let cfg = ModelConfig::preset("tiny").unwrap();
        ModelWeights::random(&cfg, 5).layers
    }

    #[test]
    fn serves_resident_and_pooled_layers() {
        let ls = layers();
        let expect: Vec<_> = ls.iter().map(|l| l.wqkv.clone()).collect();
        let mut p = PooledProvider::new(ls, vec![1, 3], PoolConfig::pmep());
        for i in 0..4 {
            let args = p.attn_args(i);
            let got = args[2].as_f32().unwrap();
            assert_eq!(got, &expect[i], "layer {i} weights wrong");
            p.release(i);
        }
        let st = p.stats();
        assert_eq!(st.sync_fetches, 2); // no prefetch hints issued
        assert_eq!(st.evictions, 2);
    }

    #[test]
    fn prefetch_overlaps_and_avoids_stall() {
        let ls = layers();
        // scale the modelled link delay so a tiny-layer copy takes ~27ms
        // (5.33µs NVLink cost × 5000); compute sleep 60ms hides it fully
        let mut cfg = PoolConfig::pmep();
        cfg.time_scale = 5_000.0;
        let mut p = PooledProvider::new(ls, vec![2], cfg);
        p.prefetch(2);
        // emulate running layers 0,1 (compute time to overlap with)
        std::thread::sleep(Duration::from_millis(60));
        let t0 = Instant::now();
        let _ = p.all_args(2);
        let waited = t0.elapsed();
        assert!(waited < Duration::from_millis(20), "stalled {waited:?}");
        assert_eq!(p.stats().prefetches, 1);
        assert_eq!(p.stats().sync_fetches, 0);
    }

    #[test]
    fn sync_fetch_stalls_without_prefetch() {
        let ls = layers();
        let mut cfg = PoolConfig::bminf();
        cfg.time_scale = 5_000.0; // ~115ms per copy over the host link
        let mut p = PooledProvider::new(ls, vec![2], cfg);
        let t0 = Instant::now();
        let _ = p.all_args(2);
        assert!(t0.elapsed() >= Duration::from_millis(20));
        assert!(p.stall_us() > 10_000);
    }

    #[test]
    fn eviction_forces_refetch() {
        let ls = layers();
        let mut p = PooledProvider::new(ls, vec![1], PoolConfig::pmep());
        let _ = p.all_args(1);
        p.release(1);
        let _ = p.all_args(1);
        assert_eq!(p.stats().sync_fetches, 2);
        assert_eq!(p.stats().evictions, 1);
    }

    #[test]
    fn placement_integrates_with_provider() {
        let ls = layers();
        let off = even_offload_placement(4, 3);
        assert_eq!(off, vec![3]);
        let mut p = PooledProvider::new(ls, off, PoolConfig::pmep());
        let _ = p.all_args(3);
        assert!(p.stats().sync_fetches + p.stats().prefetches > 0);
    }
}
