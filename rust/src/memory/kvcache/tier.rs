//! The placement engine behind the three-tier K/V cache (device → peer →
//! host).
//!
//! Two halves, mirroring the split between the centralized engine and the
//! SPMD workers (§4.1.2):
//!
//! * [`HostTier`] — the **worker-side** spill arena: a
//!   [`MemoryLedger`]-accounted store of whole-session block images. A
//!   spill copies every device block a session holds into one arena
//!   buffer (checked out of the PR-1 activation arena, so buffers cycle
//!   between spills instead of hitting the allocator); a prefetch copies
//!   it back into freshly checked-out device blocks and returns the
//!   buffer to the arena shelf. This is the paper's §4.4 heterogeneous
//!   memory space applied to generation state instead of weights.
//!
//! * [`TierPolicy`] — the **engine-side** model of every worker's tier
//!   occupancy. Block counts per session are sharding-independent
//!   (`ceil(len / block_positions)` on every worker, whatever its tp/pp
//!   slice), so one model tracks them all. The policy decides *which*
//!   sessions leave the device (LRU by last decode step, cold and
//!   unpinned only), *where* they go — a peer worker's spare memory
//!   first (§4.4 PMEP, when `peer_blocks > 0`), demoting the coldest
//!   parked sessions peer → host under peer pressure, host directly
//!   otherwise — and *when* sessions stage back (sync at decode-bucket
//!   admission, or one bucket ahead as a prefetch hint, mirroring
//!   `PoolConfig.lookahead`), and emits [`TierCmd`]s the engine publishes
//!   as ticketed commands through the consistency queue. Ticket order is
//!   the correctness story: a `Prefetch`/`Fetch` issued at
//!   bucket-formation time always carries a smaller ticket than the
//!   bucket's `Forward`, so by the time any worker pops the decode step,
//!   its sessions are resident — without any worker-to-engine
//!   backchannel. (For the peer ring, ticket order is also what makes
//!   the park/fetch exchange deadlock-free; see `kvcache::peer`.)
//!
//! The policy also implements **admission control**: a prefill batch
//! whose sessions cannot fit the device tier even after spilling every
//! cold session is deferred (left in the batcher queue) until running
//! sessions finish, instead of overflowing the slab.

use crate::memory::arena::ArenaBuf;
use crate::memory::ledger::MemoryLedger;
use std::collections::HashMap;

/// Worker-side host tier: spilled sessions' block images, byte-accounted
/// by a [`MemoryLedger`] so "host tier full" is an explicit, observable
/// condition rather than silent growth.
pub struct HostTier {
    pub(super) ledger: MemoryLedger,
    pub(super) bufs: HashMap<u64, ArenaBuf>,
}

impl HostTier {
    /// `capacity_bytes` of 0 means unlimited.
    pub fn new(device: usize, capacity_bytes: u64) -> HostTier {
        let cap = if capacity_bytes == 0 { u64::MAX } else { capacity_bytes };
        HostTier { ledger: MemoryLedger::new(device, cap).with_tier("host"), bufs: HashMap::new() }
    }

    pub fn bytes_used(&self) -> u64 {
        self.ledger.used()
    }

    pub fn sessions(&self) -> usize {
        self.bufs.len()
    }
}

/// Tiering knobs (engine-side policy and worker-side caches share these
/// numbers; the engine derives both from `EngineConfig`).
#[derive(Clone, Copy, Debug)]
pub struct TierConfig {
    /// Device-tier capacity in blocks (per worker).
    pub device_blocks: usize,
    /// Host-tier capacity in blocks (0 = unlimited).
    pub host_blocks: usize,
    /// Peer-tier capacity in blocks — how much of the ring peer's spare
    /// memory each worker may occupy (0 = tier disabled; placement then
    /// degenerates to the two-tier device/host policy).
    pub peer_blocks: usize,
    /// Spill trigger: fraction of `device_blocks` in use.
    pub high_water: f64,
    /// Spill target: evict cold sessions until use falls to this fraction.
    pub low_water: f64,
    /// How many decode buckets ahead prefetch hints are issued
    /// (mirrors `PoolConfig.lookahead`; 0 disables hints).
    pub lookahead: usize,
}

impl TierConfig {
    pub fn new(device_blocks: usize, host_blocks: usize) -> TierConfig {
        assert!(device_blocks >= 1, "device tier needs at least one block");
        TierConfig {
            device_blocks,
            host_blocks,
            peer_blocks: 0,
            high_water: 0.90,
            low_water: 0.70,
            lookahead: 1,
        }
    }

    /// Enable the peer tier with room for `blocks` parked blocks.
    pub fn with_peer(mut self, blocks: usize) -> TierConfig {
        self.peer_blocks = blocks;
        self
    }
}

/// One spill/prefetch decision, published by the engine as a ticketed
/// command so every worker applies it at the same point in its execution
/// order.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum TierCmd {
    /// Write these sessions' blocks out to the host tier. A session
    /// currently *parked* in the peer tier demotes peer → host instead
    /// (the worker's `spill` dispatches on the session's location).
    Spill(Vec<u64>),
    /// Stage these sessions' blocks back from the host tier. `hint`
    /// distinguishes lookahead prefetches (overlappable) from sync
    /// prefetches at bucket admission (decode-stall path).
    Prefetch { ids: Vec<u64>, hint: bool },
    /// Park these sessions' blocks in the ring peer's spare memory.
    Park(Vec<u64>),
    /// Bring these sessions' images home from the peer tier. Same `hint`
    /// split as `Prefetch`.
    Fetch { ids: Vec<u64>, hint: bool },
}

/// Counters the policy accumulates (engine-side intent; the worker-side
/// truth lives in `kvcache::global_stats`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TierPolicyStats {
    /// Sessions selected for spill.
    pub spills: u64,
    /// Sessions staged back one bucket ahead (the overlap win).
    pub prefetch_hints: u64,
    /// Sessions staged back synchronously at bucket admission (each one
    /// is a decode stall the lookahead failed to hide).
    pub prefetch_syncs: u64,
    /// Prefill batches deferred by admission control.
    pub prefill_deferrals: u64,
    /// Spill candidates skipped because the host tier was full.
    pub spill_denied: u64,
    /// Sessions parked in the peer tier.
    pub parks: u64,
    /// Sessions staged back from the peer tier (sync and hint alike; the
    /// stall-class split lives in `prefetch_syncs`/`prefetch_hints`).
    pub fetches: u64,
    /// Parked sessions demoted peer → host under peer pressure.
    pub demotes: u64,
    /// Park candidates that found no peer room even after demotion (they
    /// fall through to a plain host spill).
    pub park_denied: u64,
    /// Lookahead hints skipped because the same session already has a
    /// staging command in flight (e.g. the same `form` pass just
    /// sync-prefetched it) — each one would have been a duplicate copy.
    pub hint_duplicate: u64,
}

/// Where the policy believes a session's blocks live.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Loc {
    Device,
    Peer,
    Host,
}

#[derive(Debug)]
struct TierSession {
    /// Total positions the session's cache holds (tracked at decode-gate
    /// time, so it matches what the worker writes during that step).
    len: usize,
    loc: Loc,
    /// In a formed-but-uncompleted batch: never a spill victim.
    pinned: bool,
    /// Holds (or adopted) shared-prefix blocks: never a spill victim —
    /// spilling would strand another holder's reads on recycled blocks
    /// ("no block both shared and spilled"). Sticky for the session's
    /// lifetime; the worker-side refcount guard is the backstop.
    shared: bool,
    /// Decode-bucket step of last use (the LRU axis).
    last_step: u64,
}

fn blocks_for(block_positions: usize, len: usize) -> usize {
    ((len + block_positions - 1) / block_positions).max(1)
}

/// Engine-side residency model + eviction/prefetch policy.
pub struct TierPolicy {
    cfg: TierConfig,
    block_positions: usize,
    sessions: HashMap<u64, TierSession>,
    device_used: usize,
    host_used: usize,
    /// Peer-tier blocks the model believes are parked.
    peer_used: usize,
    /// Sessions with a staging command (sync or hint `Prefetch`/`Fetch`)
    /// already in flight — consulted so a lookahead hint never duplicates
    /// a copy the same (or an earlier) `form` pass already ordered.
    /// Cleared when the session is next seen resident at its gate, spills
    /// again, or finishes.
    staging: std::collections::HashSet<u64>,
    /// Blocks held by pinned (in-flight) sessions — maintained
    /// incrementally so decode admission is O(bucket), not O(sessions).
    pinned_used: usize,
    /// A prefill batch is currently parked by admission control (dedups
    /// the deferral counter across the former's retries).
    deferral_streak: bool,
    step: u64,
    pub stats: TierPolicyStats,
}

impl TierPolicy {
    pub fn new(cfg: TierConfig, block_positions: usize) -> TierPolicy {
        assert!(block_positions >= 1);
        assert!(
            cfg.low_water <= cfg.high_water && cfg.high_water <= 1.0 && cfg.low_water >= 0.0,
            "water marks must satisfy 0 <= low <= high <= 1"
        );
        TierPolicy {
            cfg,
            block_positions,
            sessions: HashMap::new(),
            device_used: 0,
            host_used: 0,
            peer_used: 0,
            staging: std::collections::HashSet::new(),
            pinned_used: 0,
            deferral_streak: false,
            step: 0,
            stats: TierPolicyStats::default(),
        }
    }

    pub fn config(&self) -> &TierConfig {
        &self.cfg
    }

    /// Device-tier blocks the model believes are in use.
    pub fn device_used(&self) -> usize {
        self.device_used
    }

    /// Host-tier blocks the model believes are in use.
    pub fn host_used(&self) -> usize {
        self.host_used
    }

    /// Peer-tier blocks the model believes are parked.
    pub fn peer_used(&self) -> usize {
        self.peer_used
    }

    /// Blocks pinned by in-flight batches (subset of `device_used`).
    pub fn pinned_used(&self) -> usize {
        self.pinned_used
    }

    pub fn session_count(&self) -> usize {
        self.sessions.len()
    }

    /// `None` if the session is unknown to the policy; `Some(false)` for
    /// any off-device placement (peer *or* host).
    pub fn is_resident(&self, id: u64) -> Option<bool> {
        self.sessions.get(&id).map(|s| s.loc == Loc::Device)
    }

    /// Is the session parked in the peer tier specifically?
    pub fn is_parked(&self, id: u64) -> Option<bool> {
        self.sessions.get(&id).map(|s| s.loc == Loc::Peer)
    }

    fn blocks_of(&self, len: usize) -> usize {
        blocks_for(self.block_positions, len)
    }

    fn high_mark(&self) -> usize {
        ((self.cfg.device_blocks as f64) * self.cfg.high_water).floor() as usize
    }

    fn low_mark(&self) -> usize {
        ((self.cfg.device_blocks as f64) * self.cfg.low_water).floor() as usize
    }

    fn host_cap(&self) -> usize {
        if self.cfg.host_blocks == 0 {
            usize::MAX
        } else {
            self.cfg.host_blocks
        }
    }

    /// Demote the coldest parked sessions peer → host until `need` more
    /// blocks fit the peer tier (or the host fills up / candidates run
    /// out). Demote ids ride in the `Spill` command — the worker's
    /// `spill` dispatches a parked session to its demotion path — and
    /// must be published *before* any new `Park`, so the peer ledger is
    /// credited before the new parks charge it.
    fn demote_for(&mut self, need: usize, spills: &mut Vec<u64>, count_denials: bool) {
        if self.peer_used + need <= self.cfg.peer_blocks {
            return;
        }
        let mut parked: Vec<(u64, u64, usize)> = self
            .sessions
            .iter()
            .filter(|(_, s)| s.loc == Loc::Peer)
            .map(|(&id, s)| (s.last_step, id, self.blocks_of(s.len)))
            .collect();
        parked.sort_unstable();
        for (_, id, blocks) in parked {
            if self.peer_used + need <= self.cfg.peer_blocks {
                break;
            }
            if self.host_used + blocks > self.host_cap() {
                if count_denials {
                    self.stats.spill_denied += 1;
                }
                continue;
            }
            self.sessions.get_mut(&id).unwrap().loc = Loc::Host;
            self.peer_used -= blocks;
            self.host_used += blocks;
            self.stats.demotes += 1;
            spills.push(id);
        }
    }

    /// Evict cold sessions (LRU by last decode step; never pinned or
    /// shared ones) until device use falls to `target` blocks or
    /// candidates run out. Victims go to the peer tier first (when
    /// enabled), demoting the coldest parked sessions to host under peer
    /// pressure, and to the host tier otherwise. Updates the model and
    /// returns the commands to publish (`Spill` — demotions first, then
    /// direct spills — before `Park`, so peer-ledger credits land before
    /// new charges). `count_denials` suppresses the denial stats on
    /// retries of an already-parked prefill, so the counters reflect
    /// distinct events rather than the former's ~ms retry cadence.
    fn relieve(&mut self, target: usize, count_denials: bool) -> Vec<TierCmd> {
        if self.device_used <= target {
            return Vec::new();
        }
        let mut candidates: Vec<(u64, u64, usize)> = self
            .sessions
            .iter()
            .filter(|(_, s)| s.loc == Loc::Device && !s.pinned && !s.shared)
            .map(|(&id, s)| (s.last_step, id, self.blocks_of(s.len)))
            .collect();
        candidates.sort_unstable();
        let mut spills = Vec::new();
        let mut parks = Vec::new();
        for (_, id, blocks) in candidates {
            if self.device_used <= target {
                break;
            }
            if self.cfg.peer_blocks > 0 && blocks <= self.cfg.peer_blocks {
                self.demote_for(blocks, &mut spills, count_denials);
                if self.peer_used + blocks <= self.cfg.peer_blocks {
                    self.sessions.get_mut(&id).unwrap().loc = Loc::Peer;
                    self.staging.remove(&id);
                    self.device_used -= blocks;
                    self.peer_used += blocks;
                    self.stats.parks += 1;
                    parks.push(id);
                    continue;
                }
                // demotion couldn't clear room (host full): fall through
                // to a plain host spill
                if count_denials {
                    self.stats.park_denied += 1;
                }
            }
            if self.host_used + blocks > self.host_cap() {
                if count_denials {
                    self.stats.spill_denied += 1;
                }
                continue; // a smaller session may still fit
            }
            self.sessions.get_mut(&id).unwrap().loc = Loc::Host;
            self.staging.remove(&id);
            self.device_used -= blocks;
            self.host_used += blocks;
            self.stats.spills += 1;
            spills.push(id);
        }
        let mut cmds = Vec::new();
        if !spills.is_empty() {
            cmds.push(TierCmd::Spill(spills));
        }
        if !parks.is_empty() {
            cmds.push(TierCmd::Park(parks));
        }
        cmds
    }

    /// Admission control for a prefill batch: `rows` are `(session id,
    /// prompt length)`. Returns the tier commands to publish (pressure
    /// spills happen even on deferral — relief is never wrong) and
    /// whether the batch may be formed. On `false` the caller must leave
    /// the requests queued and retry once running sessions finish.
    pub fn admit_prefill(&mut self, rows: &[(u64, usize)]) -> (Vec<TierCmd>, bool) {
        let need: usize = rows.iter().map(|&(_, len)| self.blocks_of(len)).sum();
        let mut cmds = Vec::new();
        if self.device_used + need > self.cfg.device_blocks {
            let target = self.cfg.device_blocks.saturating_sub(need).min(self.low_mark());
            // a parked prefill is retried every former tick: count its
            // tier-full denials once per park, not once per retry
            cmds.extend(self.relieve(target, !self.deferral_streak));
        }
        // a batch bigger than the whole device tier can never be admitted
        // by waiting; let it through and rely on the slab's soft cap
        let oversized = need > self.cfg.device_blocks;
        if self.device_used + need > self.cfg.device_blocks && !oversized {
            // count distinct parked batches, not the former's retries
            if !self.deferral_streak {
                self.stats.prefill_deferrals += 1;
                self.deferral_streak = true;
            }
            return (cmds, false);
        }
        self.deferral_streak = false;
        self.step += 1;
        for &(id, len) in rows {
            let blocks = self.blocks_of(len);
            self.device_used += blocks;
            self.pinned_used += blocks;
            self.sessions.insert(
                id,
                TierSession { len, loc: Loc::Device, pinned: true, shared: false, last_step: self.step },
            );
        }
        (cmds, true)
    }

    /// Decode-side admission: the largest prefix of `rows` a decode
    /// bucket may contain without the *pinned* working set (in-flight
    /// buckets + this one) exceeding the device tier — cold resident
    /// sessions don't count, since `gate_decode` can spill them. Returns
    /// 0 when in-flight buckets already pin everything (the caller must
    /// defer until one completes); a lone session bigger than the whole
    /// device tier is let through (soft cap) rather than livelocked.
    pub fn max_decode_rows(&self, rows: &[(u64, usize)]) -> usize {
        let pinned = self.pinned_used;
        debug_assert_eq!(
            pinned,
            self.sessions
                .values()
                .filter(|s| s.pinned)
                .map(|s| self.blocks_of(s.len))
                .sum::<usize>(),
            "pinned-block accounting drifted"
        );
        let mut used = pinned;
        let mut n = 0;
        for &(_, len) in rows {
            let b = self.blocks_of(len);
            if used + b > self.cfg.device_blocks {
                break;
            }
            used += b;
            n += 1;
        }
        if n == 0 && pinned == 0 {
            1 // oversized lone session: soft-cap tolerance
        } else {
            n
        }
    }

    /// Prefill-side bucket cap: the largest prefix of `rows` whose
    /// prompts alone fit the device tier (so a wide prompt wave splits
    /// into admissible buckets instead of tripping the oversized-batch
    /// overflow path). Always at least 1 — a lone oversized prompt still
    /// goes through the soft cap.
    pub fn max_prefill_rows(&self, rows: &[(u64, usize)]) -> usize {
        let mut used = 0;
        let mut n = 0;
        for &(_, len) in rows {
            let b = self.blocks_of(len);
            if used + b > self.cfg.device_blocks {
                break;
            }
            used += b;
            n += 1;
        }
        n.max(1)
    }

    /// Gate a decode bucket: `rows` are `(session id, total length
    /// including the token being decoded)`. Pins every row, charges block
    /// growth, stages off-device rows back (sync fetch/prefetch — the
    /// decode-stall path the lookahead hints exist to avoid), and
    /// relieves pressure past the high-water mark. Returned commands must
    /// be published before the bucket's `Forward`.
    pub fn gate_decode(&mut self, rows: &[(u64, usize)]) -> Vec<TierCmd> {
        self.step += 1;
        let step = self.step;
        let bp = self.block_positions;
        let mut prefetch_ids = Vec::new();
        let mut fetch_ids = Vec::new();
        for &(id, len) in rows {
            if !self.sessions.contains_key(&id) {
                // unknown to the policy (e.g. policy attached after the
                // session started): adopt it as resident
                let blocks = blocks_for(bp, len);
                self.device_used += blocks;
                self.pinned_used += blocks;
                self.sessions.insert(
                    id,
                    TierSession { len, loc: Loc::Device, pinned: true, shared: false, last_step: step },
                );
                continue;
            }
            let s = self.sessions.get_mut(&id).unwrap();
            let old = blocks_for(bp, s.len);
            let new = blocks_for(bp, len);
            let was = s.loc;
            let was_pinned = s.pinned;
            s.loc = Loc::Device;
            s.len = len;
            s.pinned = true;
            s.last_step = step;
            match was {
                // an earlier staging (sync or hint) has settled by this
                // bucket's forward: the id is fair game for hints again
                Loc::Device => {
                    self.staging.remove(&id);
                }
                // its blocks move host -> device at the old size; growth
                // (if any) lands on the device side
                Loc::Host => {
                    prefetch_ids.push(id);
                    self.host_used -= old;
                    self.device_used += old;
                }
                Loc::Peer => {
                    fetch_ids.push(id);
                    self.peer_used -= old;
                    self.device_used += old;
                }
            }
            // the length can shrink as well as grow: a speculative verify
            // step charges its whole drafted window, and the worker
            // truncates rejected rows before the session's next step
            self.device_used = self.device_used.saturating_sub(old) + new;
            self.pinned_used =
                self.pinned_used.saturating_sub(if was_pinned { old } else { 0 }) + new;
        }
        let mut cmds = Vec::new();
        if self.device_used > self.high_mark() {
            cmds.extend(self.relieve(self.low_mark(), true));
        }
        if !fetch_ids.is_empty() {
            self.stats.prefetch_syncs += fetch_ids.len() as u64;
            self.stats.fetches += fetch_ids.len() as u64;
            for &id in &fetch_ids {
                self.staging.insert(id);
            }
            cmds.push(TierCmd::Fetch { ids: fetch_ids, hint: false });
        }
        if !prefetch_ids.is_empty() {
            self.stats.prefetch_syncs += prefetch_ids.len() as u64;
            for &id in &prefetch_ids {
                self.staging.insert(id);
            }
            cmds.push(TierCmd::Prefetch { ids: prefetch_ids, hint: false });
        }
        cmds
    }

    /// Lookahead: `upcoming` are the `(id, len)` pairs expected in the
    /// *next* decode bucket. Off-device ones are staged back now (hint
    /// fetch/prefetch) so their bucket admits without a sync stall — but
    /// only while staying under the high-water mark; hints never cause
    /// eviction (that would thrash). A session whose staging is already
    /// in flight (the same `form` pass just sync-prefetched it, or an
    /// earlier hint did) is skipped and counted in `hint_duplicate`
    /// instead of ordering a second copy of the same image.
    pub fn prefetch_hint(&mut self, upcoming: &[(u64, usize)]) -> Vec<TierCmd> {
        if self.cfg.lookahead == 0 {
            return Vec::new();
        }
        let bp = self.block_positions;
        let mut prefetch_ids = Vec::new();
        let mut fetch_ids = Vec::new();
        for &(id, _len) in upcoming {
            if self.staging.contains(&id) {
                self.stats.hint_duplicate += 1;
                continue;
            }
            let s = match self.sessions.get(&id) {
                Some(s) => s,
                None => continue,
            };
            if s.loc == Loc::Device {
                continue;
            }
            let blocks = blocks_for(bp, s.len);
            if self.device_used + blocks > self.high_mark() {
                continue; // no headroom for this one — a smaller session
                          // later in the bucket may still fit
            }
            let step = self.step;
            let s = self.sessions.get_mut(&id).unwrap();
            let from = s.loc;
            s.loc = Loc::Device;
            s.last_step = step;
            match from {
                Loc::Host => {
                    self.host_used -= blocks;
                    prefetch_ids.push(id);
                }
                Loc::Peer => {
                    self.peer_used -= blocks;
                    self.stats.fetches += 1;
                    fetch_ids.push(id);
                }
                Loc::Device => unreachable!(),
            }
            self.device_used += blocks;
            self.staging.insert(id);
            self.stats.prefetch_hints += 1;
        }
        let mut cmds = Vec::new();
        if !fetch_ids.is_empty() {
            cmds.push(TierCmd::Fetch { ids: fetch_ids, hint: true });
        }
        if !prefetch_ids.is_empty() {
            cmds.push(TierCmd::Prefetch { ids: prefetch_ids, hint: true });
        }
        cmds
    }

    /// Flag a session as holding shared-prefix blocks (a registrant whose
    /// blocks the registry retained, or an adopter referencing cached
    /// blocks). Shared sessions are excluded from spill candidacy for
    /// their whole lifetime. Unknown ids are tolerated (the session may
    /// already have finished).
    pub fn mark_shared(&mut self, id: u64) {
        if let Some(s) = self.sessions.get_mut(&id) {
            s.shared = true;
        }
    }

    /// The shared-prefix registry retained `blocks` device blocks. The
    /// registry is its own holder, independent of the registrant session's
    /// lifetime, so the policy charges it separately — a deliberate
    /// over-estimate while the registrant is still alive (the physical
    /// blocks are shared), which keeps admission conservative.
    pub fn note_retained(&mut self, blocks: usize) {
        self.device_used += blocks;
    }

    /// A registry entry was evicted: credit its device blocks.
    pub fn note_released(&mut self, blocks: usize) {
        self.device_used = self.device_used.saturating_sub(blocks);
    }

    /// A session's batch completed and it re-entered the queue: unpin and
    /// stamp recency (it is now the *warmest* cold session).
    pub fn on_requeue(&mut self, id: u64) {
        let step = self.step;
        if let Some(s) = self.sessions.get_mut(&id) {
            let was_pinned = s.pinned;
            s.pinned = false;
            s.last_step = step;
            if was_pinned {
                self.pinned_used -= blocks_for(self.block_positions, s.len);
            }
        }
    }

    /// Finished sessions: credit whichever tier held their blocks.
    pub fn on_free(&mut self, ids: &[u64]) {
        for id in ids {
            self.staging.remove(id);
            if let Some(s) = self.sessions.remove(id) {
                let blocks = self.blocks_of(s.len);
                match s.loc {
                    Loc::Device => self.device_used -= blocks,
                    Loc::Peer => self.peer_used -= blocks,
                    Loc::Host => self.host_used -= blocks,
                }
                if s.pinned {
                    self.pinned_used -= blocks;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy(device_blocks: usize, host_blocks: usize) -> TierPolicy {
        // bp=2: a len-4 session is 2 blocks
        TierPolicy::new(TierConfig::new(device_blocks, host_blocks), 2)
    }

    fn spilled_ids(cmds: &[TierCmd]) -> Vec<u64> {
        cmds.iter()
            .flat_map(|c| match c {
                TierCmd::Spill(ids) => ids.clone(),
                _ => vec![],
            })
            .collect()
    }

    #[test]
    fn resident_sessions_need_no_commands() {
        let mut p = policy(16, 16);
        let (cmds, ok) = p.admit_prefill(&[(1, 4), (2, 4)]);
        assert!(ok && cmds.is_empty());
        assert_eq!(p.device_used(), 4);
        p.on_requeue(1);
        p.on_requeue(2);
        let cmds = p.gate_decode(&[(1, 5), (2, 5)]);
        assert!(cmds.is_empty(), "{cmds:?}");
        // len 5 crosses into a 3rd block per session
        assert_eq!(p.device_used(), 6);
        assert_eq!(p.pinned_used(), 6, "gated rows are pinned");
        p.on_requeue(1);
        assert_eq!(p.pinned_used(), 3, "requeue unpins");
        p.on_free(&[1, 2]);
        assert_eq!(p.device_used(), 0);
        assert_eq!(p.pinned_used(), 0, "free credits pinned blocks");
        assert_eq!(p.session_count(), 0);
    }

    #[test]
    fn eviction_is_lru_by_last_decode_step() {
        // 8 device blocks, sessions of 2 blocks each
        let mut p = policy(8, 64);
        for id in 0..3u64 {
            let (_, ok) = p.admit_prefill(&[(id, 4)]);
            assert!(ok);
            p.on_requeue(id);
        }
        // touch 0 most recently: decode order 1, 2, 0
        for id in [1u64, 2, 0] {
            p.gate_decode(&[(id, 4)]);
            p.on_requeue(id);
        }
        // admitting three more 2-block sessions (6 + 6 > 8) must evict
        // the least recently *decoded* sessions: 1 then 2 — never 0
        let (cmds, ok) = p.admit_prefill(&[(10, 4), (11, 4), (12, 4)]);
        assert!(ok);
        assert_eq!(spilled_ids(&cmds), vec![1, 2]);
        assert_eq!(p.is_resident(0), Some(true));
        assert_eq!(p.is_resident(1), Some(false));
        assert_eq!(p.host_used(), 4);
    }

    #[test]
    fn pinned_sessions_are_never_victims() {
        let mut p = policy(2, 64);
        let (_, ok) = p.admit_prefill(&[(1, 4)]);
        assert!(ok);
        // 1 is still pinned (in flight); admitting 2 can't evict it and
        // can't fit beside it -> deferred
        let (cmds, ok) = p.admit_prefill(&[(2, 4)]);
        assert!(!ok && cmds.is_empty());
        assert_eq!(p.stats.prefill_deferrals, 1);
        // once 1 completes and cools, 2 admits by evicting it
        p.on_requeue(1);
        let (cmds, ok) = p.admit_prefill(&[(2, 4)]);
        assert!(ok);
        assert_eq!(spilled_ids(&cmds), vec![1]);
    }

    #[test]
    fn spilled_bucket_rows_sync_prefetch() {
        let mut p = policy(2, 64);
        let (_, ok) = p.admit_prefill(&[(1, 4)]);
        assert!(ok);
        p.on_requeue(1);
        let (_, ok) = p.admit_prefill(&[(2, 4)]); // evicts 1
        assert!(ok);
        assert_eq!(p.is_resident(1), Some(false));
        p.on_requeue(2);
        // 1's next decode step must bring it back before the forward;
        // 2 (cold, LRU) is evicted to relieve pressure
        let cmds = p.gate_decode(&[(1, 5)]);
        assert_eq!(spilled_ids(&cmds), vec![2]);
        assert!(cmds
            .iter()
            .any(|c| matches!(c, TierCmd::Prefetch { ids, hint: false } if ids == &vec![1])));
        // spills are published before prefetches
        assert!(matches!(cmds[0], TierCmd::Spill(_)));
        assert_eq!(p.is_resident(1), Some(true));
        assert_eq!(p.stats.prefetch_syncs, 1);
    }

    #[test]
    fn lookahead_hint_stages_back_without_pinning() {
        let mut p = policy(6, 64);
        let (_, ok) = p.admit_prefill(&[(1, 4)]);
        assert!(ok);
        p.on_requeue(1);
        // 4 + 2 = 6 new blocks force 1 (2 blocks) out
        let (cmds, ok) = p.admit_prefill(&[(2, 8), (3, 4)]);
        assert!(ok);
        assert_eq!(spilled_ids(&cmds), vec![1]);
        p.on_free(&[2, 3]);
        let cmds = p.prefetch_hint(&[(1, 5)]);
        assert_eq!(cmds, vec![TierCmd::Prefetch { ids: vec![1], hint: true }]);
        assert_eq!(p.is_resident(1), Some(true));
        assert_eq!(p.stats.prefetch_hints, 1);
        // the following gate sees it resident: no sync prefetch
        let cmds = p.gate_decode(&[(1, 5)]);
        assert!(cmds.is_empty(), "{cmds:?}");
        assert_eq!(p.stats.prefetch_syncs, 0);
    }

    #[test]
    fn hints_never_push_past_the_high_water_mark() {
        let mut p = policy(8, 64); // high mark = 7 blocks
        let (_, ok) = p.admit_prefill(&[(1, 4)]); // 2 blocks
        assert!(ok);
        p.on_requeue(1);
        let (_, ok) = p.admit_prefill(&[(2, 8)]); // 4 blocks
        assert!(ok);
        p.on_requeue(2);
        let (cmds, ok) = p.admit_prefill(&[(3, 8)]); // forces 1 out
        assert!(ok);
        assert_eq!(spilled_ids(&cmds), vec![1]);
        p.on_requeue(3);
        let (cmds, ok) = p.admit_prefill(&[(4, 8)]); // forces 2 out
        assert!(ok);
        assert_eq!(spilled_ids(&cmds), vec![2]);
        p.on_free(&[3]); // 4 (4 blocks) stays resident
        assert_eq!(p.device_used(), 4);
        // hinting both 1 (2 blocks: 4 + 2 = 6 <= 7, fits) and 2
        // (4 blocks: 6 + 4 = 10 > 7, skipped)
        let cmds = p.prefetch_hint(&[(1, 5), (2, 9)]);
        assert_eq!(cmds, vec![TierCmd::Prefetch { ids: vec![1], hint: true }]);
        assert_eq!(p.is_resident(2), Some(false));
    }

    #[test]
    fn host_capacity_denies_spills() {
        let mut p = policy(2, 2); // host tier: 2 blocks only
        let (_, ok) = p.admit_prefill(&[(1, 4)]); // 2 blocks
        assert!(ok);
        p.on_requeue(1);
        let (_, ok) = p.admit_prefill(&[(2, 4)]); // evicts 1 -> host full
        assert!(ok);
        p.on_requeue(2);
        assert_eq!(p.host_used(), 2);
        // a third session: no spill possible (host full) -> deferred
        let (cmds, ok) = p.admit_prefill(&[(3, 4)]);
        assert!(!ok && spilled_ids(&cmds).is_empty());
        assert!(p.stats.spill_denied > 0);
        // freeing the spilled session makes host room again
        p.on_free(&[1]);
        assert_eq!(p.host_used(), 0);
        let (_, ok) = p.admit_prefill(&[(3, 4)]);
        assert!(ok);
    }

    #[test]
    fn oversized_batch_is_admitted_not_livelocked() {
        let mut p = policy(2, 8);
        // 4 blocks of prompts can never fit a 2-block device tier; the
        // policy lets it through (soft cap) instead of deferring forever
        let (_, ok) = p.admit_prefill(&[(1, 4), (2, 4)]);
        assert!(ok);
        assert_eq!(p.device_used(), 4);
    }

    #[test]
    fn free_of_spilled_session_credits_the_host_tier() {
        let mut p = policy(2, 8);
        let (_, ok) = p.admit_prefill(&[(1, 4)]);
        assert!(ok);
        p.on_requeue(1);
        let (_, ok) = p.admit_prefill(&[(2, 4)]);
        assert!(ok);
        assert_eq!((p.device_used(), p.host_used()), (2, 2));
        p.on_free(&[1, 2]);
        assert_eq!((p.device_used(), p.host_used()), (0, 0));
    }

    #[test]
    fn decode_admission_caps_the_bucket_by_pinned_blocks() {
        let mut p = policy(4, 64); // bp=2
        // nothing pinned: a full device tier of rows fits
        assert_eq!(p.max_decode_rows(&[(1, 4), (2, 4), (3, 4)]), 2); // 2+2 fit, 3rd doesn't
        // a lone oversized session passes (soft cap) instead of livelocking
        assert_eq!(p.max_decode_rows(&[(9, 100)]), 1);
        // pin 2 blocks via an in-flight prefill: one 2-block row still fits
        let (_, ok) = p.admit_prefill(&[(1, 4)]);
        assert!(ok);
        assert_eq!(p.max_decode_rows(&[(2, 4), (3, 4)]), 1);
        // pin everything: nothing fits -> caller must defer
        let (_, ok) = p.admit_prefill(&[(2, 4)]);
        assert!(ok);
        assert_eq!(p.max_decode_rows(&[(3, 4)]), 0);
        // completion unpins and decode admission resumes
        p.on_requeue(1);
        p.on_requeue(2);
        assert_eq!(p.max_decode_rows(&[(3, 4)]), 1);
    }

    #[test]
    fn prefill_rows_cap_splits_wide_waves() {
        let p = policy(4, 64); // bp=2
        // 4 two-block prompts: only 2 fit the 4-block device tier at once
        let rows: Vec<(u64, usize)> = (0..4).map(|id| (id, 4)).collect();
        assert_eq!(p.max_prefill_rows(&rows), 2);
        // a lone oversized prompt still passes (soft cap)
        assert_eq!(p.max_prefill_rows(&[(9, 100)]), 1);
    }

    #[test]
    fn shared_sessions_are_never_spill_victims() {
        let mut p = policy(2, 64);
        let (_, ok) = p.admit_prefill(&[(1, 4)]); // fills the device tier
        assert!(ok);
        p.on_requeue(1);
        p.mark_shared(1);
        // 1 is cold and unpinned but shared: admission finds no victim
        // and defers rather than spilling a shared block
        let (cmds, ok) = p.admit_prefill(&[(2, 4)]);
        assert!(!ok && spilled_ids(&cmds).is_empty());
        assert_eq!(p.is_resident(1), Some(true));
        // decode pressure relief skips it too
        let cmds = p.gate_decode(&[(1, 4)]);
        assert!(spilled_ids(&cmds).is_empty());
        // unknown ids are tolerated
        p.mark_shared(99);
        p.on_free(&[1]);
        assert_eq!(p.device_used(), 0);
    }

    #[test]
    fn retained_registry_blocks_are_charged_and_credited() {
        let mut p = policy(8, 64);
        let (_, ok) = p.admit_prefill(&[(1, 4)]); // 2 blocks
        assert!(ok);
        p.note_retained(2); // registry takes its own hold
        assert_eq!(p.device_used(), 4);
        p.on_requeue(1);
        p.on_free(&[1]); // session dies; the registry hold survives
        assert_eq!(p.device_used(), 2);
        p.note_released(2); // trie eviction credits it
        assert_eq!(p.device_used(), 0);
        p.note_released(5); // over-credit saturates, never underflows
        assert_eq!(p.device_used(), 0);
    }

    fn peered_policy(device_blocks: usize, host_blocks: usize, peer_blocks: usize) -> TierPolicy {
        TierPolicy::new(TierConfig::new(device_blocks, host_blocks).with_peer(peer_blocks), 2)
    }

    fn parked_ids(cmds: &[TierCmd]) -> Vec<u64> {
        cmds.iter()
            .flat_map(|c| match c {
                TierCmd::Park(ids) => ids.clone(),
                _ => vec![],
            })
            .collect()
    }

    #[test]
    fn hint_duplicate_is_counted_not_reemitted() {
        // sits alongside lookahead_hint_stages_back_without_pinning: the
        // same form pass that just sync-prefetched a session must not
        // also emit a lookahead hint for it (two copies of one image)
        let mut p = policy(6, 64);
        let (_, ok) = p.admit_prefill(&[(1, 4)]);
        assert!(ok);
        p.on_requeue(1);
        let (_, ok) = p.admit_prefill(&[(2, 8), (3, 4)]); // evicts 1
        assert!(ok);
        p.on_free(&[2, 3]);
        // the gate sync-prefetches 1; its staging is now in flight
        let cmds = p.gate_decode(&[(1, 5)]);
        assert!(cmds
            .iter()
            .any(|c| matches!(c, TierCmd::Prefetch { ids, hint: false } if ids == &vec![1])));
        // the same form pass hints the upcoming bucket, which holds 1 too
        let cmds = p.prefetch_hint(&[(1, 5)]);
        assert!(cmds.is_empty(), "duplicate staging emitted: {cmds:?}");
        assert_eq!(p.stats.hint_duplicate, 1);
        assert_eq!(p.stats.prefetch_hints, 0);
        // once its bucket gates (the staging settled), 1 is resident and
        // later hints skip it silently — not as a duplicate
        p.on_requeue(1);
        let cmds = p.gate_decode(&[(1, 6)]);
        assert!(cmds.is_empty(), "{cmds:?}");
        p.on_requeue(1);
        assert!(p.prefetch_hint(&[(1, 6)]).is_empty());
        assert_eq!(p.stats.hint_duplicate, 1, "resident skip misread as duplicate");
        // a hint's own staging also dedupes a second hint in flight
        let (_, ok) = p.admit_prefill(&[(4, 8)]); // evicts 1 again
        assert!(ok);
        p.on_free(&[4]);
        assert_eq!(p.prefetch_hint(&[(1, 6)]).len(), 1);
        assert_eq!(p.stats.prefetch_hints, 1);
        assert!(p.prefetch_hint(&[(1, 6)]).is_empty());
        assert_eq!(p.stats.hint_duplicate, 2);
    }

    #[test]
    fn victims_park_to_peer_before_host() {
        let mut p = peered_policy(4, 64, 8);
        let (_, ok) = p.admit_prefill(&[(1, 4), (2, 4)]); // fills the device
        assert!(ok);
        p.on_requeue(1);
        p.on_requeue(2);
        // the next wave evicts both — into the peer tier, not the host
        let (cmds, ok) = p.admit_prefill(&[(3, 4), (4, 4)]);
        assert!(ok);
        assert_eq!(parked_ids(&cmds), vec![1, 2]);
        assert!(spilled_ids(&cmds).is_empty(), "host spill with peer room free");
        assert_eq!(p.peer_used(), 4);
        assert_eq!(p.host_used(), 0);
        assert_eq!(p.is_resident(1), Some(false));
        assert_eq!(p.is_parked(1), Some(true));
        assert_eq!(p.stats.parks, 2);
        // freeing a parked session credits the peer tier
        p.on_free(&[1]);
        assert_eq!(p.peer_used(), 2);
    }

    #[test]
    fn peer_pressure_demotes_coldest_to_host() {
        let mut p = peered_policy(2, 64, 2); // peer holds one 2-block session
        let (_, ok) = p.admit_prefill(&[(1, 4)]);
        assert!(ok);
        p.on_requeue(1);
        let (cmds, ok) = p.admit_prefill(&[(2, 4)]); // parks 1
        assert!(ok);
        assert_eq!(parked_ids(&cmds), vec![1]);
        p.on_requeue(2);
        // parking 2 exceeds the peer tier: 1 (coldest parked) demotes to
        // host first, and the Spill command precedes the Park command so
        // the worker credits the peer ledger before the new park charges
        let (cmds, ok) = p.admit_prefill(&[(3, 4)]);
        assert!(ok);
        assert_eq!(spilled_ids(&cmds), vec![1], "demote must ride the Spill command");
        assert_eq!(parked_ids(&cmds), vec![2]);
        let spill_pos = cmds.iter().position(|c| matches!(c, TierCmd::Spill(_))).unwrap();
        let park_pos = cmds.iter().position(|c| matches!(c, TierCmd::Park(_))).unwrap();
        assert!(spill_pos < park_pos, "Spill (demote) must precede Park");
        assert_eq!(p.stats.demotes, 1);
        assert_eq!(p.is_parked(1), Some(false));
        assert_eq!(p.is_resident(1), Some(false));
        assert_eq!(p.is_parked(2), Some(true));
        assert_eq!((p.peer_used(), p.host_used()), (2, 2));
    }

    #[test]
    fn parked_bucket_rows_sync_fetch() {
        let mut p = peered_policy(8, 64, 8); // high mark = 7 blocks
        let (_, ok) = p.admit_prefill(&[(1, 12)]); // 6 blocks
        assert!(ok);
        p.on_requeue(1);
        let (_, ok) = p.admit_prefill(&[(2, 12)]); // parks 1
        assert!(ok);
        assert_eq!(p.is_parked(1), Some(true));
        p.on_requeue(2);
        // 1's next decode step fetches it home before the forward; 2
        // (cold, LRU) parks to relieve pressure
        let cmds = p.gate_decode(&[(1, 13)]);
        assert_eq!(parked_ids(&cmds), vec![2]);
        assert!(cmds
            .iter()
            .any(|c| matches!(c, TierCmd::Fetch { ids, hint: false } if ids == &vec![1])));
        assert_eq!(p.is_resident(1), Some(true));
        assert_eq!(p.stats.prefetch_syncs, 1);
        assert_eq!(p.stats.fetches, 1);
        // a parked session in the lookahead gets a hint Fetch
        p.on_free(&[1]);
        let cmds = p.prefetch_hint(&[(2, 13)]);
        assert_eq!(cmds, vec![TierCmd::Fetch { ids: vec![2], hint: true }]);
        assert_eq!(p.stats.prefetch_hints, 1);
        assert_eq!(p.stats.fetches, 2);
        assert_eq!(p.peer_used(), 0);
    }

    #[test]
    fn full_host_blocks_demotion_and_park_falls_back() {
        // peer: one 2-block slot; host: full after one demotion
        let mut p = peered_policy(2, 2, 2);
        let (_, ok) = p.admit_prefill(&[(1, 4)]);
        assert!(ok);
        p.on_requeue(1);
        let (_, ok) = p.admit_prefill(&[(2, 4)]); // parks 1
        assert!(ok);
        p.on_requeue(2);
        let (_, ok) = p.admit_prefill(&[(3, 4)]); // demotes 1, parks 2
        assert!(ok);
        p.on_requeue(3);
        assert_eq!((p.peer_used(), p.host_used()), (2, 2));
        // now everything is full: 3 can't park (no demotion room) and
        // can't spill (host full) -> the next prefill defers
        let (cmds, ok) = p.admit_prefill(&[(4, 4)]);
        assert!(!ok);
        assert!(spilled_ids(&cmds).is_empty() && parked_ids(&cmds).is_empty());
        assert!(p.stats.park_denied > 0, "failed park went uncounted");
        assert!(p.stats.spill_denied > 0, "failed fallback spill went uncounted");
    }

    #[test]
    fn blocks_for_rounds_up() {
        assert_eq!(blocks_for(8, 1), 1);
        assert_eq!(blocks_for(8, 8), 1);
        assert_eq!(blocks_for(8, 9), 2);
        // a zero-length session still accounts for one block
        assert_eq!(blocks_for(8, 0), 1);
    }
}
