//! Token-id-keyed prefix trie: the admission half of shared-prefix K/V
//! reuse.
//!
//! The trie lives engine-side (owned by the `Batcher`, consulted under its
//! lock) and maps *block-granular chunks* of prompt token ids to cached
//! prefixes held in the worker registries ([`super::KvCache`]'s
//! `retain_prefix`/`adopt_prefix`). Granularity is one K/V block
//! (`KV_BLOCK_POSITIONS` tokens per chunk): an entry at depth `d` means
//! "the first `d × chunk` prompt positions of some past prompt are
//! retained on every worker under the registrant's session id", so a new
//! prompt that walks `d` chunks deep can adopt those blocks wholesale and
//! compute only its suffix.
//!
//! Two pieces of state close the lifecycle races:
//!
//! - **`ready`**: an entry is registered when its prefill is *formed* but
//!   only becomes matchable once that forward completed (the registrant's
//!   rows are durably in every worker's registry). Commands flow through
//!   ticketed per-worker queues, so any adoption formed after readiness is
//!   ordered after the registrant's prefill on every worker.
//! - **`leases`**: each in-flight adoption holds a lease on its entry;
//!   eviction (capacity or registrant spill) only touches entries that are
//!   ready with zero leases, so a registry entry can never be dropped on
//!   the workers while a formed-but-unexecuted batch still adopts from it.
//!
//! Evicted ids accumulate in a pending list the batcher drains and
//! publishes as ticketed `EvictPrefix` commands.

use std::collections::HashMap;

#[derive(Debug, Default)]
struct Node {
    /// Child per distinct next chunk of token ids.
    children: HashMap<Vec<i32>, Node>,
    /// The registrant whose cached prefix ends exactly here.
    entry: Option<u64>,
}

#[derive(Debug)]
struct EntryMeta {
    /// The full chunk-aligned token path (for removal).
    path: Vec<i32>,
    /// Registrant's prefill has completed; the entry is matchable.
    ready: bool,
    /// In-flight adoptions formed against this entry.
    leases: usize,
    /// Logical-clock stamp of the entry's last match (or its
    /// registration, before any hit) — the recency half of eviction.
    last_hit: u64,
    /// Whole blocks this entry pins on every worker (`path.len / chunk`)
    /// — the footprint weight: a stale 8-block template costs the device
    /// tier more than a stale 1-block one.
    blocks: usize,
}

/// Engine-side prefix trie with capacity-bounded eviction: the victim is
/// the ready, lease-free entry with the highest `staleness × blocks
/// pinned` score — LRU by last hit, weighted by how much device memory
/// the entry actually holds. Entries that have never been matched age
/// from their registration stamp, so with equal footprints the policy
/// degrades to FIFO.
#[derive(Debug)]
pub struct PrefixIndex {
    chunk: usize,
    max_entries: usize,
    root: Node,
    entries: HashMap<u64, EntryMeta>,
    /// Logical clock: bumped on every registration and every hit.
    seq: u64,
    pending_evict: Vec<u64>,
    hits: u64,
    misses: u64,
}

impl PrefixIndex {
    /// `chunk` is the K/V block size in positions; `max_entries` caps the
    /// number of retained prefixes (0 = unbounded).
    pub fn new(chunk: usize, max_entries: usize) -> PrefixIndex {
        assert!(chunk >= 1);
        PrefixIndex {
            chunk,
            max_entries,
            root: Node::default(),
            entries: HashMap::new(),
            seq: 0,
            pending_evict: Vec::new(),
            hits: 0,
            misses: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn contains(&self, id: u64) -> bool {
        self.entries.contains_key(&id)
    }

    /// (matches, misses) observed by `match_longest` so far.
    pub fn hit_counts(&self) -> (u64, u64) {
        (self.hits, self.misses)
    }

    /// Register `id`'s prompt as a cached prefix covering its whole
    /// blocks (`floor(len/chunk)` chunks). The entry starts not-ready.
    /// Returns `false` (nothing registered) when the prompt is shorter
    /// than one chunk, the id is already registered, or an entry with the
    /// identical chunk path already exists (no point caching it twice).
    pub fn register(&mut self, id: u64, tokens: &[i32]) -> bool {
        let chunks = tokens.len() / self.chunk;
        if chunks == 0 || self.entries.contains_key(&id) {
            return false;
        }
        let path = &tokens[..chunks * self.chunk];
        let mut node = &mut self.root;
        for ch in path.chunks_exact(self.chunk) {
            node = node.children.entry(ch.to_vec()).or_default();
        }
        if node.entry.is_some() {
            return false;
        }
        node.entry = Some(id);
        self.seq += 1;
        self.entries.insert(
            id,
            EntryMeta {
                path: path.to_vec(),
                ready: false,
                leases: 0,
                last_hit: self.seq,
                blocks: chunks,
            },
        );
        self.enforce_cap();
        true
    }

    /// The registrant's prefill completed: its rows are in every worker's
    /// registry, so the entry becomes matchable.
    pub fn mark_ready(&mut self, id: u64) {
        if let Some(e) = self.entries.get_mut(&id) {
            e.ready = true;
        }
    }

    /// Longest *ready* cached prefix of `tokens`, as `(registrant id,
    /// matched positions)`; positions are always a multiple of the chunk.
    /// An entry deeper than the query still matches for the blocks they
    /// share: every entry in the subtree below a walked node starts with
    /// the query's walked chunks, and the worker registries adopt partial
    /// prefixes of an entry. Counts a hit or miss for the stats line.
    pub fn match_longest(&mut self, tokens: &[i32]) -> Option<(u64, usize)> {
        let mut path_nodes: Vec<&Node> = Vec::new();
        let mut node = &self.root;
        for ch in tokens.chunks_exact(self.chunk) {
            match node.children.get(ch) {
                Some(n) => {
                    node = n;
                    path_nodes.push(n);
                }
                None => break,
            }
        }
        let mut best = None;
        for (depth0, n) in path_nodes.iter().enumerate().rev() {
            if let Some(id) = find_ready_entry(n, &self.entries) {
                best = Some((id, (depth0 + 1) * self.chunk));
                break;
            }
        }
        if let Some((id, _)) = best {
            self.hits += 1;
            // refresh recency: a matched entry is hot, keep it resident
            self.seq += 1;
            let stamp = self.seq;
            if let Some(e) = self.entries.get_mut(&id) {
                e.last_hit = stamp;
            }
        } else {
            self.misses += 1;
        }
        best
    }

    /// An adoption was formed against `id`: pin the entry until the
    /// adopter's forward completes. Returns `false` for unknown entries.
    pub fn lease(&mut self, id: u64) -> bool {
        match self.entries.get_mut(&id) {
            Some(e) => {
                e.leases += 1;
                true
            }
            None => false,
        }
    }

    /// The adopter's forward completed (or its batch failed): release the
    /// pin taken by [`PrefixIndex::lease`].
    pub fn unlease(&mut self, id: u64) {
        if let Some(e) = self.entries.get_mut(&id) {
            e.leases = e.leases.saturating_sub(1);
        }
        self.enforce_cap();
    }

    /// Force-remove entries (the registrant's blocks are leaving the
    /// device tier — spill — or the feature is shutting down). Leased
    /// entries are removed too: the caller publishes the eviction through
    /// the same ticketed stream as the spill, and adoption commands formed
    /// earlier hold earlier tickets. Removed ids join the pending-evict
    /// list for the caller to drain.
    pub fn remove(&mut self, ids: &[u64]) {
        for &id in ids {
            if let Some(meta) = self.entries.remove(&id) {
                remove_path(&mut self.root, &meta.path, self.chunk);
                self.pending_evict.push(id);
            }
        }
    }

    /// Drain the ids whose registry entries must be dropped on the
    /// workers (publish as ticketed `EvictPrefix`).
    pub fn take_evictions(&mut self) -> Vec<u64> {
        std::mem::take(&mut self.pending_evict)
    }

    /// Evict ready, lease-free entries down to the cap. The victim
    /// maximizes `staleness × blocks pinned` (staleness measured on the
    /// shared logical clock), so a long-stale multi-block template is
    /// reclaimed before a recently-hit or cheap one; ties fall to the
    /// entry with the oldest last hit, then the smallest id, which keeps
    /// the policy deterministic and FIFO-compatible for never-hit,
    /// equal-footprint entries.
    fn enforce_cap(&mut self) {
        if self.max_entries == 0 {
            return;
        }
        while self.entries.len() > self.max_entries {
            let clock = self.seq;
            let victim = self
                .entries
                .iter()
                .filter(|(_, e)| e.ready && e.leases == 0)
                .max_by_key(|(&id, e)| {
                    let staleness = clock - e.last_hit;
                    (
                        staleness * e.blocks as u64,
                        std::cmp::Reverse(e.last_hit),
                        std::cmp::Reverse(id),
                    )
                })
                .map(|(&id, _)| id);
            match victim {
                Some(id) => self.remove(&[id]),
                None => break, // everything pinned; retry on the next unlease
            }
        }
    }
}

/// Any *ready* entry at `node` or in its subtree — smallest id wins so
/// repeated queries resolve deterministically. All candidates share the
/// walked chunks with the query, so any of them yields the same adopted
/// token positions.
fn find_ready_entry(node: &Node, entries: &HashMap<u64, EntryMeta>) -> Option<u64> {
    let mut best: Option<u64> = None;
    if let Some(id) = node.entry {
        if entries[&id].ready {
            best = Some(id);
        }
    }
    for child in node.children.values() {
        if let Some(id) = find_ready_entry(child, entries) {
            best = Some(best.map_or(id, |b| b.min(id)));
        }
    }
    best
}

/// Clear the entry at the end of `path` and prune now-empty nodes.
fn remove_path(node: &mut Node, path: &[i32], chunk: usize) {
    if path.is_empty() {
        node.entry = None;
        return;
    }
    let (head, rest) = path.split_at(chunk);
    if let Some(child) = node.children.get_mut(head) {
        remove_path(child, rest, chunk);
        if child.children.is_empty() && child.entry.is_none() {
            node.children.remove(head);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(n: usize) -> Vec<i32> {
        (0..n as i32).collect()
    }

    #[test]
    fn register_match_roundtrip_block_granular() {
        let mut t = PrefixIndex::new(4, 0);
        // 10 tokens -> 2 chunks registered; the trailing 2 are dropped
        assert!(t.register(1, &toks(10)));
        assert_eq!(t.match_longest(&toks(10)), None, "not ready yet");
        t.mark_ready(1);
        assert_eq!(t.match_longest(&toks(10)), Some((1, 8)));
        // a shorter query only matches whole chunks it covers
        assert_eq!(t.match_longest(&toks(7)), Some((1, 4)));
        assert_eq!(t.match_longest(&toks(3)), None);
        // divergence inside the first chunk: no match
        let mut other = toks(10);
        other[2] = 99;
        assert_eq!(t.match_longest(&other), None);
        // divergence in the second chunk: first chunk still matches
        let mut other = toks(10);
        other[5] = 99;
        assert_eq!(t.match_longest(&other), Some((1, 4)));
        let (hits, misses) = t.hit_counts();
        assert_eq!((hits, misses), (3, 3));
    }

    #[test]
    fn deepest_ready_entry_wins() {
        let mut t = PrefixIndex::new(2, 0);
        assert!(t.register(1, &toks(2)));
        assert!(t.register(2, &toks(6)));
        t.mark_ready(1);
        // the deep entry is not ready: the shallow one matches
        assert_eq!(t.match_longest(&toks(6)), Some((1, 2)));
        t.mark_ready(2);
        assert_eq!(t.match_longest(&toks(6)), Some((2, 6)));
        // duplicate path or id is refused
        assert!(!t.register(3, &toks(6)));
        assert!(!t.register(2, &toks(4)));
        // sub-chunk prompt registers nothing
        assert!(!t.register(4, &toks(1)));
        assert_eq!(t.len(), 2);
    }

    #[test]
    fn removal_prunes_and_stops_matching() {
        let mut t = PrefixIndex::new(2, 0);
        assert!(t.register(1, &toks(4)));
        assert!(t.register(2, &toks(8)));
        t.mark_ready(1);
        t.mark_ready(2);
        t.remove(&[2]);
        assert_eq!(t.match_longest(&toks(8)), Some((1, 4)));
        assert_eq!(t.take_evictions(), vec![2]);
        assert!(t.take_evictions().is_empty());
        t.remove(&[1]);
        assert_eq!(t.match_longest(&toks(8)), None);
        assert!(t.is_empty());
        // unknown removal is a tolerated no-op
        t.remove(&[7]);
        assert!(t.take_evictions() == vec![1]);
    }

    #[test]
    fn capacity_evicts_fifo_but_never_leased_or_pending_entries() {
        let mut t = PrefixIndex::new(2, 2);
        assert!(t.register(1, &[1, 1]));
        assert!(t.register(2, &[2, 2]));
        t.mark_ready(1);
        t.mark_ready(2);
        assert!(t.lease(1));
        // over cap: id 2 (oldest evictable) goes, leased id 1 survives
        assert!(t.register(3, &[3, 3]));
        assert_eq!(t.take_evictions(), vec![2]);
        assert!(t.contains(1) && t.contains(3));
        // id 3 is not ready and id 1 is leased: nothing can go yet
        assert!(t.register(4, &[4, 4]));
        assert!(t.take_evictions().is_empty());
        assert_eq!(t.len(), 3);
        // releasing the lease resumes eviction (oldest first)
        t.unlease(1);
        assert_eq!(t.take_evictions(), vec![1]);
        assert_eq!(t.len(), 2);
        // lease of an evicted entry reports failure
        assert!(!t.lease(1));
        t.unlease(99); // unknown: tolerated
    }

    #[test]
    fn a_match_refreshes_recency_and_deflects_eviction() {
        let mut t = PrefixIndex::new(2, 2);
        assert!(t.register(1, &[1, 1]));
        assert!(t.register(2, &[2, 2]));
        t.mark_ready(1);
        t.mark_ready(2);
        // hit the *older* entry: it becomes the most recently used
        assert_eq!(t.match_longest(&[1, 1]), Some((1, 2)));
        // over cap: id 2 is now the stalest despite registering later
        assert!(t.register(3, &[3, 3]));
        assert_eq!(t.take_evictions(), vec![2]);
        assert!(t.contains(1) && t.contains(3));
    }

    #[test]
    fn eviction_weighs_staleness_by_blocks_pinned() {
        let mut t = PrefixIndex::new(2, 2);
        // id 1 pins 3 blocks (6 tokens / chunk 2), id 2 pins 1 block
        assert!(t.register(1, &toks(6)));
        assert!(t.register(2, &[9, 9]));
        t.mark_ready(1);
        t.mark_ready(2);
        // refresh the big entry so it is *fresher* than the small one...
        assert_eq!(t.match_longest(&toks(6)), Some((1, 6)));
        // ...yet its staleness × 3-block footprint still outweighs the
        // small entry's: clock 4 at eviction, id 1 scores (4-3)*3 = 3,
        // id 2 scores (4-2)*1 = 2, so the expensive entry goes first
        assert!(t.register(3, &[8, 8]));
        assert_eq!(t.take_evictions(), vec![1]);
        assert!(t.contains(2) && t.contains(3));
    }
}
