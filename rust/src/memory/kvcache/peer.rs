//! Peer-memory tier and overlapped copier: §4.4's PMEP, promoted from the
//! simulator (`sim::pmep`) into the live cache.
//!
//! Workers form a **parking ring**: worker `r` parks cold session block
//! images in the spare device memory of its *peer* `(r+1) % world`, and in
//! turn holds images for its *client* `(r-1+world) % world`. Everything is
//! shipped over the ordinary [`crate::comm::channel`] endpoints, and both
//! ends account bytes in a [`MemoryLedger`]: the owner against a capped
//! "peer" ledger (this is what decides park eligibility, in whole blocks,
//! so every worker reaches the same verdict regardless of shard size), the
//! holder against an uncapped "peer-guest" ledger (pure bookkeeping — a
//! holder never refuses what its client's capped ledger admitted).
//!
//! The exchange protocol is driven purely by consistency-queue ticket
//! order — there is no extra handshake:
//!
//! * **Park** ticket: every worker copies its own shard image out, sends
//!   it to its peer, and opportunistically drains ([`PeerTier::pump`])
//!   whatever its client has shipped so far. Sends are buffered, so
//!   nobody waits for a slow neighbour here.
//! * **Fetch/demote** ticket: every worker first ships the client's image
//!   home ([blocking][PeerTier::retrieve] until the client's park from the
//!   earlier ticket has arrived — the client is strictly behind in the
//!   same ticket stream, so this always terminates), then receives its own
//!   image from its peer. Send-before-receive keeps the ring deadlock-free.
//!
//! A world of one degenerates to a self-loop over a buffered self-channel
//! ([`crate::comm::channel::CommWorld::new_looped`]): the worker is its own
//! peer, and the park/fetch paths are byte-identical to the mesh case.
//!
//! [`KvCopier`] is the overlap half (modeled on `memory::pool`'s copier
//! thread): staging an off-tier image back toward the device hands the
//! landing memcpy to a dedicated thread so it overlaps the current
//! forward; the worker only waits — [`KvCopier::wait_landed`], counted as
//! prefetch stall — if the copy has not finished by the time the rows are
//! actually needed.

use crate::comm::channel::{CommWorld, Endpoint, Mode};
use crate::memory::arena::{ArenaBuf, ArenaPool};
use crate::memory::ledger::MemoryLedger;
use std::collections::{HashMap, HashSet};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Block images on the wire between ring neighbours.
pub enum PeerMsg {
    /// Owner → holder: park this session's image in your spare memory.
    Park { session: u64, image: ArenaBuf },
    /// Holder → owner: a parked image coming home (fetch or demote).
    Image { session: u64, image: ArenaBuf },
}

/// One worker's two-sided view of the parking ring (owner of its parked
/// sessions, holder of its client's). Lives inside [`super::KvCache`];
/// single-threaded like the rest of the cache.
pub(super) struct PeerTier {
    /// Owner side: capped ledger of bytes parked in the peer's memory.
    pub(super) ledger: MemoryLedger,
    /// Holder side: uncapped ledger of bytes held for the client.
    guest_ledger: MemoryLedger,
    /// Owner side: bytes parked per session.
    parked: HashMap<u64, u64>,
    /// Holder side: the client's images.
    guests: HashMap<u64, ArenaBuf>,
    /// Holder side: sessions freed before their park image arrived — the
    /// late image is dropped on arrival instead of leaking.
    dead_guests: HashSet<u64>,
    /// Holder side: truncations that outran the park image (blocks to
    /// keep), applied on arrival.
    pending_trunc: HashMap<u64, usize>,
    /// Images that came home ahead of the call that wants them.
    homebound: HashMap<u64, ArenaBuf>,
    ep: Endpoint<PeerMsg>,
    /// Ring neighbour we park into.
    peer: usize,
    /// Ring neighbour whose images we hold.
    client: usize,
}

impl PeerTier {
    pub(super) fn new(
        device: usize,
        capacity_bytes: u64,
        ep: Endpoint<PeerMsg>,
        peer: usize,
        client: usize,
    ) -> PeerTier {
        PeerTier {
            ledger: MemoryLedger::new(device, capacity_bytes).with_tier("peer"),
            guest_ledger: MemoryLedger::new(device, u64::MAX).with_tier("peer-guest"),
            parked: HashMap::new(),
            guests: HashMap::new(),
            dead_guests: HashSet::new(),
            pending_trunc: HashMap::new(),
            homebound: HashMap::new(),
            ep,
            peer,
            client,
        }
    }

    /// Self-loop tier for a world of one (and unit tests): the worker is
    /// its own ring neighbour over a buffered self-channel.
    pub(super) fn looped(device: usize, capacity_bytes: u64) -> PeerTier {
        let ep = CommWorld::new_looped::<PeerMsg>(1, Mode::NonBlocking).pop().unwrap();
        PeerTier::new(device, capacity_bytes, ep, 0, 0)
    }

    pub(super) fn bytes_used(&self) -> u64 {
        self.ledger.used()
    }

    pub(super) fn sessions(&self) -> usize {
        self.parked.len()
    }

    pub(super) fn guest_bytes(&self) -> u64 {
        self.guest_ledger.used()
    }

    pub(super) fn guest_count(&self) -> usize {
        self.guests.len()
    }

    pub(super) fn parked_bytes(&self, session: u64) -> Option<u64> {
        self.parked.get(&session).copied()
    }

    /// Owner side: reserve room for a park (whole-block bytes, so every
    /// shard size reaches the same verdict).
    pub(super) fn charge(&mut self, session: u64, bytes: u64) -> anyhow::Result<()> {
        self.ledger.alloc(bytes)?;
        self.parked.insert(session, bytes);
        Ok(())
    }

    /// Owner side: return a parked session's bytes to the ledger.
    pub(super) fn credit(&mut self, session: u64) -> u64 {
        let bytes = self.parked.remove(&session).unwrap_or(0);
        self.ledger.dealloc(bytes);
        bytes
    }

    /// Owner side: shrink a parked session's reservation to `new_bytes`,
    /// returning the bytes freed.
    pub(super) fn shrink_parked(&mut self, session: u64, new_bytes: u64) -> u64 {
        match self.parked.get_mut(&session) {
            Some(b) if *b > new_bytes => {
                let freed = *b - new_bytes;
                *b = new_bytes;
                self.ledger.dealloc(freed);
                freed
            }
            _ => 0,
        }
    }

    /// Absorb one wire message into the holder-side maps.
    fn absorb(&mut self, msg: PeerMsg, be: usize) {
        match msg {
            PeerMsg::Park { session, image } => self.admit_guest(session, image, be),
            PeerMsg::Image { session, image } => {
                self.homebound.insert(session, image);
            }
        }
    }

    fn admit_guest(&mut self, session: u64, mut image: ArenaBuf, be: usize) {
        if self.dead_guests.remove(&session) {
            return; // freed before arrival: drop the late image
        }
        if let Some(keep) = self.pending_trunc.remove(&session) {
            if image.len() > keep * be {
                image.vec_mut().truncate(keep * be);
            }
        }
        self.guest_ledger.alloc((image.len() * 4) as u64).expect("guest ledger is uncapped");
        self.guests.insert(session, image);
    }

    /// Holder side: drain whatever the client has shipped so far (never
    /// blocks).
    pub(super) fn pump(&mut self, be: usize) {
        while let Some(msg) = self.ep.try_recv(self.client) {
            self.absorb(msg, be);
        }
    }

    /// Owner side: ship our shard image to the peer (buffered — returns
    /// immediately), then drain the client's traffic.
    pub(super) fn send_park(&mut self, session: u64, image: ArenaBuf, be: usize) {
        self.ep.send(self.peer, PeerMsg::Park { session, image });
        self.pump(be);
    }

    /// Holder side: take the client's image of `session`, blocking until
    /// its park (from an earlier ticket) has arrived if need be.
    fn guest_take(&mut self, session: u64, be: usize) -> ArenaBuf {
        self.pump(be);
        loop {
            if let Some(img) = self.guests.remove(&session) {
                self.guest_ledger.dealloc((img.len() * 4) as u64);
                return img;
            }
            // the client is strictly behind in the same ticket stream;
            // its park for this session is on the wire or still queued
            let msg = self.ep.recv(self.client);
            self.absorb(msg, be);
        }
    }

    /// The fetch/demote exchange for `session`, symmetric on every worker:
    /// ship the client's copy home first, then receive our own from the
    /// peer. Send-before-receive keeps the ring deadlock-free; ticket
    /// order guarantees both images exist.
    pub(super) fn retrieve(&mut self, session: u64, be: usize) -> ArenaBuf {
        let home = self.guest_take(session, be);
        if self.peer == self.ep.rank {
            // world of one: the client's copy *is* our own image
            return home;
        }
        self.ep.send(self.client, PeerMsg::Image { session, image: home });
        loop {
            if let Some(img) = self.homebound.remove(&session) {
                return img;
            }
            let msg = self.ep.recv(self.peer);
            self.absorb(msg, be);
        }
    }

    /// Holder side of a free: drop the client's image, or mark the session
    /// dead so a still-in-flight park image is dropped on arrival.
    pub(super) fn drop_guest(&mut self, session: u64, be: usize) {
        self.pump(be);
        self.pending_trunc.remove(&session);
        if let Some(img) = self.guests.remove(&session) {
            self.guest_ledger.dealloc((img.len() * 4) as u64);
        } else {
            self.dead_guests.insert(session);
        }
    }

    /// Holder side of a tail truncation: shorten the client's image in
    /// place (every worker truncates the same session at the same ticket,
    /// so owner and holder arithmetic agree), or record it for arrival.
    pub(super) fn truncate_guest(&mut self, session: u64, keep_blocks: usize, be: usize) {
        self.pump(be);
        if let Some(img) = self.guests.get_mut(&session) {
            let keep = keep_blocks * be;
            if img.len() > keep {
                let freed = ((img.len() - keep) * 4) as u64;
                img.vec_mut().truncate(keep);
                self.guest_ledger.dealloc(freed);
            }
        } else {
            let e = self.pending_trunc.entry(session).or_insert(keep_blocks);
            *e = (*e).min(keep_blocks);
        }
    }
}

/// What the copier thread does with its life.
enum CopyReq {
    /// Land this off-tier image so it is ready to install.
    Stage { session: u64, image: ArenaBuf },
    Stop,
}

struct CopierShared {
    landed: Mutex<HashMap<u64, ArenaBuf>>,
    cv: Condvar,
}

/// Per-worker copier thread (modeled on `memory::pool`'s): landing
/// memcpys run here so they overlap the worker's current forward. All
/// ledger and gauge accounting stays on the worker thread at stage time —
/// only the data movement is asynchronous, so accounting is deterministic
/// regardless of copier timing.
pub(super) struct KvCopier {
    tx: Sender<CopyReq>,
    shared: Arc<CopierShared>,
    handle: Option<JoinHandle<()>>,
}

impl KvCopier {
    pub(super) fn spawn() -> KvCopier {
        let (tx, rx) = std::sync::mpsc::channel();
        let shared =
            Arc::new(CopierShared { landed: Mutex::new(HashMap::new()), cv: Condvar::new() });
        let sh = Arc::clone(&shared);
        let handle = std::thread::Builder::new()
            .name("kv-copier".into())
            .spawn(move || copier_loop(rx, sh))
            .expect("spawn kv copier");
        KvCopier { tx, shared, handle: Some(handle) }
    }

    /// Hand an off-tier image to the copier; the landing copy overlaps
    /// whatever the worker does next.
    pub(super) fn stage(&self, session: u64, image: ArenaBuf) {
        self.tx.send(CopyReq::Stage { session, image }).expect("kv copier died");
    }

    /// Block until the staged image for `session` has landed. The caller
    /// measures this wait — it is the residual (un-overlapped) stall.
    pub(super) fn wait_landed(&self, session: u64) -> ArenaBuf {
        let mut landed = self.shared.landed.lock().unwrap();
        loop {
            if let Some(img) = landed.remove(&session) {
                return img;
            }
            landed = self.shared.cv.wait(landed).unwrap();
        }
    }
}

impl Drop for KvCopier {
    fn drop(&mut self) {
        let _ = self.tx.send(CopyReq::Stop);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn copier_loop(rx: Receiver<CopyReq>, shared: Arc<CopierShared>) {
    while let Ok(CopyReq::Stage { session, image }) = rx.recv() {
        // the "DMA": land the image into a fresh arena buffer off the
        // worker thread so the memcpy overlaps the current forward
        let mut dst = ArenaPool::checkout(image.len());
        dst.as_mut_slice().copy_from_slice(image.as_slice());
        drop(image);
        let mut landed = shared.landed.lock().unwrap();
        landed.insert(session, dst);
        shared.cv.notify_all();
    }
    ArenaPool::drain_thread();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn looped_tier_parks_and_retrieves_through_the_self_channel() {
        let be = 4;
        let mut t = PeerTier::looped(0, 1024);
        t.charge(7, 32).unwrap();
        assert_eq!(t.bytes_used(), 32);
        t.send_park(7, ArenaBuf::owned(vec![1.0, 2.0, 3.0, 4.0]), be);
        // the self-channel delivered our own image into the guest map
        assert_eq!(t.guest_count(), 1);
        assert_eq!(t.guest_bytes(), 16);
        let img = t.retrieve(7, be);
        assert_eq!(img.as_slice(), &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(t.guest_bytes(), 0);
        assert_eq!(t.credit(7), 32);
        assert_eq!(t.bytes_used(), 0);
    }

    #[test]
    fn dead_guest_and_pending_truncation_apply_on_arrival() {
        let be = 2;
        let mut t = PeerTier::looped(0, 1024);
        // free outruns the park image: the late arrival is dropped
        t.drop_guest(5, be);
        t.send_park(5, ArenaBuf::owned(vec![0.0; 4]), be);
        t.pump(be);
        assert_eq!(t.guest_count(), 0, "dead guest image must be dropped");
        assert_eq!(t.guest_bytes(), 0);
        // truncation outruns the park image: applied when it lands
        t.truncate_guest(6, 1, be);
        t.send_park(6, ArenaBuf::owned(vec![9.0; 6]), be); // 3 blocks of 2
        t.pump(be);
        assert_eq!(t.guest_bytes(), (be * 4) as u64, "pending truncation skipped");
        // in-place truncation of an arrived image
        t.truncate_guest(6, 0, be);
        assert_eq!(t.guest_bytes(), 0);
        t.drop_guest(6, be);
        assert_eq!(t.guest_count(), 0);
    }

    #[test]
    fn copier_lands_images_for_settle() {
        let c = KvCopier::spawn();
        c.stage(3, ArenaBuf::owned(vec![1.5; 8]));
        let img = c.wait_landed(3);
        assert_eq!(img.as_slice(), &[1.5; 8]);
        // staging more after a wait still works; Drop joins the thread
        c.stage(4, ArenaBuf::owned(vec![2.5; 2]));
        assert_eq!(c.wait_landed(4).as_slice(), &[2.5; 2]);
    }
}
