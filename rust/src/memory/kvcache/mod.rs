//! Paged per-session K/V cache: the storage half of incremental decode —
//! now a **three-tier** store (device → peer → host).
//!
//! Generation sessions keep the K/V rows of every processed position so a
//! decode step runs *one* position through the linears instead of
//! re-running the whole prefix (the paper's redundant-computation-
//! elimination idea, §4.2.2, applied along the time axis). Storage is
//! **paged** in the spirit of the paper's memory-pooling technique (§4.4):
//! one worker-local slab is carved into fixed-size *position blocks*; each
//! session holds a block table mapping logical position-block → physical
//! block, so thousands of concurrent sessions of wildly different lengths
//! share the slab with at most `block_positions - 1` wasted rows each and
//! zero copying on growth.
//!
//! The **device tier** is that slab. The **host tier** ([`tier::HostTier`])
//! is a [`crate::memory::MemoryLedger`]-accounted spill arena: a cold
//! session's whole block set can be written out ([`KvCache::spill`]) and
//! staged back ([`KvCache::prefetch`]) — §4.4's larger heterogeneous
//! memory space applied to generation state, so the number of *live*
//! sessions is no longer capped by the device slab. Between the two sits
//! the **peer tier** ([`peer::PeerTier`]): §4.4's PMEP — cold images park
//! in a *peer worker's* spare device memory first ([`KvCache::park`] /
//! [`KvCache::fetch`]), and demote to host only under peer pressure, with
//! an optional copier thread ([`peer::KvCopier`]) overlapping the landing
//! copies with the current forward. Which sessions move, and when, is
//! decided engine-side by [`tier::TierPolicy`] and arrives here as
//! ticketed commands; this module only executes the copies.
//!
//! Block layout (one block, `layers` local layers, K and V planes):
//!
//! ```text
//! [layer 0 | K rows][layer 0 | V rows][layer 1 | K rows]...
//!            each plane: block_positions × width f32
//! ```
//!
//! so the (layer, K/V) plane of a block is contiguous and `gather` into
//! the per-step staging tensor is one `copy_from_slice` per (block,
//! layer). Freed blocks go to a free list and are recycled before the
//! slab grows; alloc/recycle/peak/spill counters are mirrored into
//! process-wide atomics surfaced through `metrics::Recorder` (like the
//! activation arena's, §Perf).

pub mod peer;
pub mod prefix;
pub mod tier;

use crate::comm::channel::Endpoint;
use crate::memory::arena::{ArenaBuf, ArenaPool};
use peer::{KvCopier, PeerTier};
pub use peer::PeerMsg;
use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use tier::HostTier;

/// How many recently-freed session ids each cache remembers to tell a
/// true double release (a cancellation/watchdog race: freed again after
/// being freed) apart from a benign unknown free (an error-path release
/// for a batch this worker never executed).
const FREED_RING: usize = 1024;

/// Process-wide counters, aggregated across every worker's cache.
/// `blocks_in_use`, `host_bytes` and `sessions*` are gauges; the rest are
/// monotonic.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct KvStats {
    /// Blocks currently backing live sessions (all workers).
    pub blocks_in_use: u64,
    /// High-water mark of `blocks_in_use`.
    pub blocks_peak: u64,
    /// Block checkouts served from a free list instead of slab growth.
    pub blocks_recycled: u64,
    /// Blocks newly carved by growing a slab.
    pub blocks_grown: u64,
    /// Total slab bytes reserved across workers.
    pub slab_bytes: u64,
    /// Sessions currently holding cache entries.
    pub sessions: u64,
    /// Whole-session writes to the host tier.
    pub spills: u64,
    /// Whole-session stagings back to the device tier.
    pub prefetches: u64,
    /// Bytes moved device → host by spills.
    pub spill_bytes: u64,
    /// Bytes moved host → device by prefetches.
    pub prefetch_bytes: u64,
    /// Host-tier bytes currently held (all workers).
    pub host_bytes: u64,
    /// Sessions currently parked in the host tier.
    pub sessions_spilled: u64,
    /// Time spent copying sessions back synchronously because a decode
    /// bucket needed them *now* (the lookahead failed to hide it) — the
    /// decode-stall-on-prefetch number.
    pub prefetch_stall_us: u64,
    /// `gather`/`write_row`/`write_prefix` calls that hit a spilled
    /// session (admission-gate bug: loud, never silent).
    pub gather_spilled: u64,
    /// `free` calls for sessions this cache never held (error-path
    /// releases are legitimate but must be visible).
    pub free_unknown: u64,
    /// `free`/`truncate_tail` calls for sessions this cache *recently
    /// released* — a true double release (cancel racing the watchdog or
    /// the collector), never legitimate. Counted in release builds,
    /// debug-asserted in debug builds, and surfaced by the Recorder as a
    /// `KVFREE-ANOMALY` marker CI greps for.
    pub double_free: u64,
    /// Spills refused because the host tier ledger was full.
    pub spill_denied: u64,
    /// `truncate_tail` calls that actually shortened a session
    /// (speculative decode: rejected draft rows cut back).
    pub truncates: u64,
    /// Block references released by tail truncation (shared blocks are
    /// decremented, not recycled; spilled images count their host bytes'
    /// worth of blocks).
    pub truncated_blocks: u64,
    /// Device blocks carved past the configured soft capacity (the
    /// engine-side policy failed to keep pressure down).
    pub overflow_blocks: u64,
    /// Cached prefixes currently retained in worker registries (gauge;
    /// shared-prefix reuse).
    pub cached_prefixes: u64,
    /// Sessions that adopted a cached prefix instead of prefilling it.
    pub prefix_adopts: u64,
    /// Device blocks adopted by refcount instead of being written fresh
    /// (each one is a whole block of prefill K/V that was never stored
    /// twice).
    pub adopted_blocks: u64,
    /// Copy-on-write block copies: a session wrote into a block another
    /// holder still references, so the block was privatized first.
    pub cow_copies: u64,
    /// Spills refused because one of the session's blocks is shared — a
    /// block another resident holder still reads must never leave the
    /// device tier ("no block both shared and spilled").
    pub spill_denied_shared: u64,
    /// Whole-session parks into a peer worker's spare memory (§4.4 PMEP).
    pub parks: u64,
    /// Whole-session retrievals from the peer tier back to the device.
    pub fetches: u64,
    /// Bytes shipped device → peer by parks.
    pub park_bytes: u64,
    /// Bytes shipped peer → device by fetches.
    pub fetch_bytes: u64,
    /// Peer-tier bytes currently parked (all workers, owner side).
    pub peer_bytes: u64,
    /// Sessions currently parked in the peer tier.
    pub sessions_parked: u64,
    /// Parks refused (no peer tier, or the peer ledger was full).
    pub park_denied: u64,
    /// Parked sessions demoted peer → host under peer pressure.
    pub demotes: u64,
}

static G_IN_USE: AtomicU64 = AtomicU64::new(0);
static G_PEAK: AtomicU64 = AtomicU64::new(0);
static G_RECYCLED: AtomicU64 = AtomicU64::new(0);
static G_GROWN: AtomicU64 = AtomicU64::new(0);
static G_SLAB_BYTES: AtomicU64 = AtomicU64::new(0);
static G_SESSIONS: AtomicU64 = AtomicU64::new(0);
static G_SPILLS: AtomicU64 = AtomicU64::new(0);
static G_PREFETCHES: AtomicU64 = AtomicU64::new(0);
static G_SPILL_BYTES: AtomicU64 = AtomicU64::new(0);
static G_PREFETCH_BYTES: AtomicU64 = AtomicU64::new(0);
static G_HOST_BYTES: AtomicU64 = AtomicU64::new(0);
static G_SESSIONS_SPILLED: AtomicU64 = AtomicU64::new(0);
static G_PREFETCH_STALL_US: AtomicU64 = AtomicU64::new(0);
static G_GATHER_SPILLED: AtomicU64 = AtomicU64::new(0);
static G_FREE_UNKNOWN: AtomicU64 = AtomicU64::new(0);
static G_DOUBLE_FREE: AtomicU64 = AtomicU64::new(0);
static G_SPILL_DENIED: AtomicU64 = AtomicU64::new(0);
static G_OVERFLOW: AtomicU64 = AtomicU64::new(0);
static G_TRUNCATES: AtomicU64 = AtomicU64::new(0);
static G_TRUNCATED_BLOCKS: AtomicU64 = AtomicU64::new(0);
static G_CACHED_PREFIXES: AtomicU64 = AtomicU64::new(0);
static G_PREFIX_ADOPTS: AtomicU64 = AtomicU64::new(0);
static G_ADOPTED_BLOCKS: AtomicU64 = AtomicU64::new(0);
static G_COW_COPIES: AtomicU64 = AtomicU64::new(0);
static G_SPILL_DENIED_SHARED: AtomicU64 = AtomicU64::new(0);
static G_PARKS: AtomicU64 = AtomicU64::new(0);
static G_FETCHES: AtomicU64 = AtomicU64::new(0);
static G_PARK_BYTES: AtomicU64 = AtomicU64::new(0);
static G_FETCH_BYTES: AtomicU64 = AtomicU64::new(0);
static G_PEER_BYTES: AtomicU64 = AtomicU64::new(0);
static G_SESSIONS_PARKED: AtomicU64 = AtomicU64::new(0);
static G_PARK_DENIED: AtomicU64 = AtomicU64::new(0);
static G_DEMOTES: AtomicU64 = AtomicU64::new(0);

/// Process-wide snapshot (what `Engine::metrics_snapshot` folds into the
/// `Recorder`). Workers update the atomics as they allocate and free.
pub fn global_stats() -> KvStats {
    KvStats {
        blocks_in_use: G_IN_USE.load(Ordering::Relaxed),
        blocks_peak: G_PEAK.load(Ordering::Relaxed),
        blocks_recycled: G_RECYCLED.load(Ordering::Relaxed),
        blocks_grown: G_GROWN.load(Ordering::Relaxed),
        slab_bytes: G_SLAB_BYTES.load(Ordering::Relaxed),
        sessions: G_SESSIONS.load(Ordering::Relaxed),
        spills: G_SPILLS.load(Ordering::Relaxed),
        prefetches: G_PREFETCHES.load(Ordering::Relaxed),
        spill_bytes: G_SPILL_BYTES.load(Ordering::Relaxed),
        prefetch_bytes: G_PREFETCH_BYTES.load(Ordering::Relaxed),
        host_bytes: G_HOST_BYTES.load(Ordering::Relaxed),
        sessions_spilled: G_SESSIONS_SPILLED.load(Ordering::Relaxed),
        prefetch_stall_us: G_PREFETCH_STALL_US.load(Ordering::Relaxed),
        gather_spilled: G_GATHER_SPILLED.load(Ordering::Relaxed),
        free_unknown: G_FREE_UNKNOWN.load(Ordering::Relaxed),
        double_free: G_DOUBLE_FREE.load(Ordering::Relaxed),
        spill_denied: G_SPILL_DENIED.load(Ordering::Relaxed),
        overflow_blocks: G_OVERFLOW.load(Ordering::Relaxed),
        truncates: G_TRUNCATES.load(Ordering::Relaxed),
        truncated_blocks: G_TRUNCATED_BLOCKS.load(Ordering::Relaxed),
        cached_prefixes: G_CACHED_PREFIXES.load(Ordering::Relaxed),
        prefix_adopts: G_PREFIX_ADOPTS.load(Ordering::Relaxed),
        adopted_blocks: G_ADOPTED_BLOCKS.load(Ordering::Relaxed),
        cow_copies: G_COW_COPIES.load(Ordering::Relaxed),
        spill_denied_shared: G_SPILL_DENIED_SHARED.load(Ordering::Relaxed),
        parks: G_PARKS.load(Ordering::Relaxed),
        fetches: G_FETCHES.load(Ordering::Relaxed),
        park_bytes: G_PARK_BYTES.load(Ordering::Relaxed),
        fetch_bytes: G_FETCH_BYTES.load(Ordering::Relaxed),
        peer_bytes: G_PEER_BYTES.load(Ordering::Relaxed),
        sessions_parked: G_SESSIONS_PARKED.load(Ordering::Relaxed),
        park_denied: G_PARK_DENIED.load(Ordering::Relaxed),
        demotes: G_DEMOTES.load(Ordering::Relaxed),
    }
}

/// Attribute synchronous (non-hint) prefetch copy time — the worker calls
/// this with the measured duration of each sync staging.
pub fn note_prefetch_stall_us(us: u64) {
    G_PREFETCH_STALL_US.fetch_add(us, Ordering::Relaxed);
}

fn note_in_use_delta(delta: i64) {
    let now = if delta >= 0 {
        G_IN_USE.fetch_add(delta as u64, Ordering::Relaxed) + delta as u64
    } else {
        G_IN_USE.fetch_sub((-delta) as u64, Ordering::Relaxed) - (-delta) as u64
    };
    G_PEAK.fetch_max(now, Ordering::Relaxed);
}

/// Geometry of one worker's cache.
#[derive(Clone, Copy, Debug)]
pub struct KvCacheConfig {
    /// Positions per block (the paging granularity).
    pub block_positions: usize,
    /// Local transformer layers this worker executes.
    pub layers: usize,
    /// Width of one K (or V) row in f32 — `hidden / tp`.
    pub width: usize,
    /// Blocks added per slab growth (amortizes allocation).
    pub grow_blocks: usize,
    /// Soft cap on device-tier blocks (0 = unbounded, the resident-only
    /// configuration). Growth past the cap is tolerated — correctness
    /// never hinges on the engine-side policy — but counted loudly in
    /// `overflow_blocks`, and growth switches to single blocks so the
    /// gauge is exact.
    pub capacity_blocks: usize,
    /// Host (spill) tier capacity in blocks (0 = tier disabled).
    pub host_blocks: usize,
    /// Peer (park) tier capacity in blocks — how much of a peer worker's
    /// spare memory this worker may occupy (0 = tier disabled; the
    /// two-tier path is then byte-identical to before the tier existed).
    pub peer_blocks: usize,
    /// Run a copier thread so staged prefetch/fetch landing copies
    /// overlap the current forward instead of running inline.
    pub copier: bool,
    /// Ledger device id (observability only).
    pub device: usize,
}

impl KvCacheConfig {
    pub fn new(block_positions: usize, layers: usize, width: usize) -> KvCacheConfig {
        assert!(block_positions >= 1 && layers >= 1 && width >= 1);
        KvCacheConfig {
            block_positions,
            layers,
            width,
            grow_blocks: 64,
            capacity_blocks: 0,
            host_blocks: 0,
            peer_blocks: 0,
            copier: false,
            device: 0,
        }
    }

    /// Cap the device tier at `blocks` (soft; see `capacity_blocks`).
    pub fn with_device_capacity(mut self, blocks: usize) -> KvCacheConfig {
        self.capacity_blocks = blocks;
        self
    }

    /// Enable the host spill tier with room for `blocks` blocks
    /// (0 keeps it disabled).
    pub fn with_host_tier(mut self, blocks: usize) -> KvCacheConfig {
        self.host_blocks = blocks;
        self
    }

    /// Enable the peer (park) tier with room for `blocks` blocks in the
    /// peer worker's memory (0 keeps it disabled). Takes effect once a
    /// mesh or self-loop is attached ([`KvCache::attach_peer_mesh`] /
    /// [`KvCache::attach_self_peer`]).
    pub fn with_peer_tier(mut self, blocks: usize) -> KvCacheConfig {
        self.peer_blocks = blocks;
        self
    }

    /// Toggle the overlapped copier thread.
    pub fn with_copier(mut self, on: bool) -> KvCacheConfig {
        self.copier = on;
        self
    }

    pub fn with_device_id(mut self, device: usize) -> KvCacheConfig {
        self.device = device;
        self
    }

    /// f32 elements in one block: layers × {K,V} × positions × width.
    pub fn block_elems(&self) -> usize {
        self.layers * 2 * self.block_positions * self.width
    }

    /// Bytes in one block.
    pub fn block_bytes(&self) -> u64 {
        (self.block_elems() * 4) as u64
    }
}

/// Which tier currently holds a session's block images.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
enum KvLoc {
    /// Resident in the device slab (the only gatherable state).
    #[default]
    Device,
    /// Parked in the peer worker's spare device memory (§4.4 PMEP).
    Peer,
    /// Spilled to the host arena.
    Host,
}

/// One session's cache state: its block table and filled length. An
/// off-device session keeps its length but its blocks live as a single
/// image in the peer or host tier.
#[derive(Debug, Default)]
struct SessionKv {
    /// Logical position-block b lives in physical block `blocks[b]`
    /// (empty while off-device).
    blocks: Vec<u32>,
    /// Positions 0..len hold valid K/V rows (all layers).
    len: usize,
    /// Which tier holds the blocks.
    loc: KvLoc,
}

/// A cached shared prefix: the first blocks of some past session's prompt,
/// retained in the registry beyond that session's lifetime so later
/// prompts with the same token prefix can adopt them by refcount instead
/// of prefilling their own copy. `len` is in positions and is always
/// covered by `blocks`.
#[derive(Debug, Default)]
struct CachedPrefix {
    blocks: Vec<u32>,
    len: usize,
}

/// Worker-local paged K/V store. Single-threaded by construction (it lives
/// inside a `Worker`); cross-worker visibility is via the global counters.
pub struct KvCache {
    cfg: KvCacheConfig,
    slab: Vec<f32>,
    free_list: Vec<u32>,
    sessions: HashMap<u64, SessionKv>,
    n_blocks: usize,
    /// Per-physical-block reference count (0 = on the free list). A block
    /// is *shared* when more than one holder — session block tables plus
    /// the prefix registry — references it; shared blocks are freed by
    /// decrement and privatized copy-on-write before any in-place write.
    refcounts: Vec<u32>,
    /// Shared-prefix registry: cached prompt prefixes keyed by the
    /// registrant's session id (ids are never reused, so the key stays
    /// unambiguous after the session itself is released). Entries hold
    /// their own refcount on every block and are dropped only by an
    /// explicit ticketed eviction ([`KvCache::evict_prefix`]).
    cached: HashMap<u64, CachedPrefix>,
    /// Host spill tier (`None` when `cfg.host_blocks == 0`).
    host: Option<HostTier>,
    /// Peer park tier (`None` until a mesh/self-loop is attached).
    peer: Option<PeerTier>,
    /// Copier thread for overlapped staging (`None` = inline copies).
    copier: Option<KvCopier>,
    /// Sessions whose images are staged at the copier but not yet
    /// installed into device blocks ([`KvCache::settle`] completes them).
    pending_install: HashSet<u64>,
    /// Bounded FIFO of recently-released session ids (+ membership set),
    /// consulted on unknown frees to call out true double releases.
    freed_ring: VecDeque<u64>,
    freed_set: HashSet<u64>,
}

impl KvCache {
    pub fn new(cfg: KvCacheConfig) -> KvCache {
        // usize::MAX host blocks means "unlimited": saturate the byte cap
        let host = (cfg.host_blocks > 0).then(|| {
            HostTier::new(cfg.device, (cfg.host_blocks as u64).saturating_mul(cfg.block_bytes()))
        });
        KvCache {
            cfg,
            slab: Vec::new(),
            free_list: Vec::new(),
            sessions: HashMap::new(),
            n_blocks: 0,
            refcounts: Vec::new(),
            cached: HashMap::new(),
            host,
            peer: None,
            copier: cfg.copier.then(KvCopier::spawn),
            pending_install: HashSet::new(),
            freed_ring: VecDeque::new(),
            freed_set: HashSet::new(),
        }
    }

    /// Join the parking ring: park into worker `peer`, hold images for
    /// worker `client`. No-op when `cfg.peer_blocks == 0`.
    pub fn attach_peer_mesh(&mut self, ep: Endpoint<PeerMsg>, peer: usize, client: usize) {
        if self.cfg.peer_blocks > 0 {
            let cap = (self.cfg.peer_blocks as u64).saturating_mul(self.cfg.block_bytes());
            self.peer = Some(PeerTier::new(self.cfg.device, cap, ep, peer, client));
        }
    }

    /// Degenerate one-worker ring: the worker is its own peer over a
    /// buffered self-channel. No-op when `cfg.peer_blocks == 0`.
    pub fn attach_self_peer(&mut self) {
        if self.cfg.peer_blocks > 0 {
            let cap = (self.cfg.peer_blocks as u64).saturating_mul(self.cfg.block_bytes());
            self.peer = Some(PeerTier::looped(self.cfg.device, cap));
        }
    }

    /// Remember `session` as recently released (bounded ring).
    fn note_freed(&mut self, session: u64) {
        if self.freed_set.insert(session) {
            if self.freed_ring.len() == FREED_RING {
                let old = self.freed_ring.pop_front().unwrap();
                self.freed_set.remove(&old);
            }
            self.freed_ring.push_back(session);
        }
    }

    /// An unknown session was freed/truncated: classify it as a benign
    /// error-path release or a true double release, count accordingly,
    /// and fail fast in debug builds on the latter.
    fn note_unknown_release(&mut self, session: u64, op: &str) {
        if self.freed_set.contains(&session) {
            G_DOUBLE_FREE.fetch_add(1, Ordering::Relaxed);
            eprintln!(
                "kvcache device {}: double {op} of session {session} (already released)",
                self.cfg.device,
            );
            debug_assert!(false, "double {op} of session {session}");
        } else {
            G_FREE_UNKNOWN.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn config(&self) -> &KvCacheConfig {
        &self.cfg
    }

    /// Blocks currently reserved by live sessions (this worker).
    pub fn blocks_in_use(&self) -> usize {
        self.n_blocks - self.free_list.len()
    }

    /// Total blocks ever carved into this worker's slab.
    pub fn capacity_blocks(&self) -> usize {
        self.n_blocks
    }

    pub fn session_count(&self) -> usize {
        self.sessions.len()
    }

    /// Sessions currently parked in the host tier (this worker).
    pub fn spilled_count(&self) -> usize {
        self.host.as_ref().map_or(0, HostTier::sessions)
    }

    /// Host-tier bytes in use (this worker).
    pub fn host_bytes_used(&self) -> u64 {
        self.host.as_ref().map_or(0, HostTier::bytes_used)
    }

    /// Sessions currently parked in the peer tier (this worker, owner
    /// side).
    pub fn parked_count(&self) -> usize {
        self.peer.as_ref().map_or(0, PeerTier::sessions)
    }

    /// Peer-tier bytes this worker has parked (owner-side ledger).
    pub fn peer_bytes_used(&self) -> u64 {
        self.peer.as_ref().map_or(0, PeerTier::bytes_used)
    }

    /// Bytes this worker holds on behalf of its ring client (holder-side
    /// ledger).
    pub fn guest_bytes_used(&self) -> u64 {
        self.peer.as_ref().map_or(0, PeerTier::guest_bytes)
    }

    /// Is this session's cache off-device (host *or* peer tier)?
    pub fn is_spilled(&self, session: u64) -> bool {
        self.sessions.get(&session).map_or(false, |s| s.loc != KvLoc::Device)
    }

    /// Is this session's cache parked in the peer tier specifically?
    pub fn is_parked(&self, session: u64) -> bool {
        self.sessions.get(&session).map_or(false, |s| s.loc == KvLoc::Peer)
    }

    /// Positions filled for a session (`None` if it has no cache entry).
    pub fn len(&self, session: u64) -> Option<usize> {
        self.sessions.get(&session).map(|s| s.len)
    }

    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty()
    }

    fn checkout_block(&mut self) -> u32 {
        if let Some(b) = self.free_list.pop() {
            G_RECYCLED.fetch_add(1, Ordering::Relaxed);
            note_in_use_delta(1);
            self.refcounts[b as usize] = 1;
            return b;
        }
        // grow the slab by a chunk of blocks; existing indices stay valid.
        // Near or past the soft cap the chunk shrinks so the overflow
        // gauge counts policy failures block-exactly.
        let first = self.n_blocks as u32;
        let cap = self.cfg.capacity_blocks;
        let add = if cap == 0 {
            self.cfg.grow_blocks.max(1)
        } else if self.n_blocks < cap {
            self.cfg.grow_blocks.max(1).min(cap - self.n_blocks)
        } else {
            G_OVERFLOW.fetch_add(1, Ordering::Relaxed);
            1
        };
        self.slab.resize((self.n_blocks + add) * self.cfg.block_elems(), 0.0);
        self.n_blocks += add;
        self.refcounts.resize(self.n_blocks, 0);
        G_GROWN.fetch_add(add as u64, Ordering::Relaxed);
        G_SLAB_BYTES.fetch_add(add as u64 * self.cfg.block_bytes(), Ordering::Relaxed);
        // newly carved blocks beyond the checked-out one go to the free list
        for b in (first + 1)..(self.n_blocks as u32) {
            self.free_list.push(b);
        }
        note_in_use_delta(1);
        self.refcounts[first as usize] = 1;
        first
    }

    /// Drop one holder's reference to a physical block; the block is
    /// recycled only when the last holder lets go. Returns `true` when the
    /// block actually went back to the free list.
    fn release_block(&mut self, block: u32) -> bool {
        let rc = &mut self.refcounts[block as usize];
        debug_assert!(*rc > 0, "release of a free block");
        *rc = rc.saturating_sub(1);
        if *rc == 0 {
            self.free_list.push(block);
            note_in_use_delta(-1);
            true
        } else {
            false
        }
    }

    /// Copy-on-write: if the session's block covering `pos` is shared,
    /// copy its contents into a private block and swap it into the block
    /// table before writing. New blocks from `ensure` start private, so
    /// this only ever fires on adopted/retained blocks.
    fn make_private(&mut self, session: u64, pos: usize) {
        let bi = pos / self.cfg.block_positions;
        let old = self.sessions[&session].blocks[bi];
        if self.refcounts[old as usize] <= 1 {
            return;
        }
        let fresh = self.checkout_block();
        let be = self.cfg.block_elems();
        let (src, dst) = (old as usize * be, fresh as usize * be);
        // split_at_mut: the two block images never overlap
        if src < dst {
            let (a, b) = self.slab.split_at_mut(dst);
            b[..be].copy_from_slice(&a[src..src + be]);
        } else {
            let (a, b) = self.slab.split_at_mut(src);
            b[..be].copy_from_slice(&a[dst..dst + be]);
        }
        self.sessions.get_mut(&session).unwrap().blocks[bi] = fresh;
        self.release_block(old);
        G_COW_COPIES.fetch_add(1, Ordering::Relaxed);
    }

    /// Ensure `session` has blocks covering positions `0..=pos`.
    fn ensure(&mut self, session: u64, pos: usize) {
        if !self.sessions.contains_key(&session) {
            G_SESSIONS.fetch_add(1, Ordering::Relaxed);
            self.sessions.insert(session, SessionKv::default());
            // a freed id legitimately coming back to life (tests reuse
            // ids) must not trip the double-release guard later
            if self.freed_set.remove(&session) {
                self.freed_ring.retain(|&id| id != session);
            }
        }
        let need = pos / self.cfg.block_positions + 1;
        let have = self.sessions[&session].blocks.len();
        for _ in have..need {
            let b = self.checkout_block();
            self.sessions.get_mut(&session).unwrap().blocks.push(b);
        }
    }

    /// Offset of the (block-local) K plane of `(physical block, layer)`.
    fn plane(&self, block: u32, layer: usize, v_plane: bool) -> usize {
        let bp = self.cfg.block_positions;
        let w = self.cfg.width;
        block as usize * self.cfg.block_elems() + (layer * 2 + v_plane as usize) * bp * w
    }

    /// Write one position's K and V rows for one layer. Allocates blocks as
    /// needed; `advance` publishes the position once every layer wrote it.
    pub fn write_row(&mut self, session: u64, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        let w = self.cfg.width;
        assert_eq!(k.len(), w, "k row width mismatch");
        assert_eq!(v.len(), w, "v row width mismatch");
        assert!(layer < self.cfg.layers, "layer {layer} out of range");
        self.settle(session);
        if self.is_spilled(session) {
            // same loudness contract as gather: counter + debug assert;
            // release builds drop the write instead of allocating fresh
            // zeroed blocks beside the spilled image (which would corrupt
            // the cache and leak the new blocks on the next prefetch)
            G_GATHER_SPILLED.fetch_add(1, Ordering::Relaxed);
            debug_assert!(false, "write_row on spilled session {session} (prefetch it first)");
            return;
        }
        self.ensure(session, pos);
        self.make_private(session, pos);
        let bp = self.cfg.block_positions;
        let block = self.sessions[&session].blocks[pos / bp];
        let slot = pos % bp;
        let k_off = self.plane(block, layer, false) + slot * w;
        self.slab[k_off..k_off + w].copy_from_slice(k);
        let v_off = self.plane(block, layer, true) + slot * w;
        self.slab[v_off..v_off + w].copy_from_slice(v);
    }

    /// Write positions `0..len` of one layer in bulk (prefill seeding):
    /// `k`/`v` hold `len` contiguous rows. The mirror of [`KvCache::gather`]
    /// — one `copy_from_slice` per (block, layer) plane instead of
    /// per-position lookups.
    pub fn write_prefix(&mut self, session: u64, layer: usize, len: usize, k: &[f32], v: &[f32]) {
        let w = self.cfg.width;
        assert!(k.len() >= len * w && v.len() >= len * w, "prefix rows too short");
        assert!(layer < self.cfg.layers, "layer {layer} out of range");
        if len == 0 {
            return;
        }
        self.settle(session);
        if self.is_spilled(session) {
            // see write_row: loud, and never write beside a spilled image
            G_GATHER_SPILLED.fetch_add(1, Ordering::Relaxed);
            debug_assert!(false, "write_prefix on spilled session {session} (prefetch it first)");
            return;
        }
        self.ensure(session, len - 1);
        let bp = self.cfg.block_positions;
        let mut done = 0usize;
        for bi in 0..(len + bp - 1) / bp {
            self.make_private(session, bi * bp);
            let block = self.sessions[&session].blocks[bi];
            let take = (len - done).min(bp);
            let k_off = self.plane(block, layer, false);
            self.slab[k_off..k_off + take * w].copy_from_slice(&k[done * w..(done + take) * w]);
            let v_off = self.plane(block, layer, true);
            self.slab[v_off..v_off + take * w].copy_from_slice(&v[done * w..(done + take) * w]);
            done += take;
        }
    }

    /// Publish that positions `0..len` are now valid for `session` (called
    /// once per engine step, after every local layer wrote its rows).
    pub fn advance(&mut self, session: u64, len: usize) {
        let s = self.sessions.get_mut(&session).expect("advance on unknown session");
        debug_assert!(len >= s.len, "cache cannot shrink");
        s.len = len;
    }

    /// Copy a session's filled K and V rows for `layer` into the head of
    /// `dst_k`/`dst_v` (the per-step staging tensors, laid out as
    /// `capacity × width` rows per batch row). Returns the copied length.
    ///
    /// A spilled session is an admission-gate failure and is **loud**:
    /// the `gather_spilled` counter trips, debug builds assert, and
    /// release builds return 0 so the caller's length check fails the
    /// batch instead of decoding against garbage.
    pub fn gather(&self, session: u64, layer: usize, dst_k: &mut [f32], dst_v: &mut [f32]) -> usize {
        let s = match self.sessions.get(&session) {
            Some(s) => s,
            None => return 0,
        };
        if s.loc != KvLoc::Device || self.pending_install.contains(&session) {
            G_GATHER_SPILLED.fetch_add(1, Ordering::Relaxed);
            debug_assert!(
                false,
                "gather on off-device session {session}: the admission gate must stage (and settle) before dispatch"
            );
            return 0;
        }
        let bp = self.cfg.block_positions;
        let w = self.cfg.width;
        assert!(s.len * w <= dst_k.len() && s.len * w <= dst_v.len(), "staging too small");
        let mut done = 0usize;
        for &block in &s.blocks {
            let take = (s.len - done).min(bp);
            if take == 0 {
                break;
            }
            let k_off = self.plane(block, layer, false);
            dst_k[done * w..(done + take) * w]
                .copy_from_slice(&self.slab[k_off..k_off + take * w]);
            let v_off = self.plane(block, layer, true);
            dst_v[done * w..(done + take) * w]
                .copy_from_slice(&self.slab[v_off..v_off + take * w]);
            done += take;
        }
        done
    }

    /// Copy a resident session's whole block set into one arena image and
    /// return its device blocks to the free list. The caller has already
    /// reserved room for the image in the destination tier's ledger.
    fn image_out(&mut self, session: u64) -> ArenaBuf {
        let be = self.cfg.block_elems();
        let s = self.sessions.get_mut(&session).unwrap();
        // block images go into one arena buffer; spill/prefetch cycles
        // recycle these through the arena shelves (§Perf)
        let mut buf = ArenaPool::checkout(s.blocks.len() * be);
        for (i, &b) in s.blocks.iter().enumerate() {
            let src = b as usize * be;
            buf[i * be..(i + 1) * be].copy_from_slice(&self.slab[src..src + be]);
        }
        let blocks: Vec<u32> = s.blocks.drain(..).collect();
        for b in blocks {
            self.release_block(b);
        }
        buf
    }

    /// Install an off-tier image into freshly checked-out device blocks.
    fn install(&mut self, session: u64, buf: ArenaBuf) {
        let be = self.cfg.block_elems();
        let n_blocks = buf.len() / be;
        let mut blocks = Vec::with_capacity(n_blocks);
        for _ in 0..n_blocks {
            blocks.push(self.checkout_block());
        }
        for (i, &b) in blocks.iter().enumerate() {
            let dst = b as usize * be;
            self.slab[dst..dst + be].copy_from_slice(&buf[i * be..(i + 1) * be]);
        }
        drop(buf); // back to the arena shelf for the next spill
        self.sessions.get_mut(&session).unwrap().blocks = blocks;
    }

    /// Does any of the session's blocks have another holder? A shared
    /// block must never leave the device tier: spilling or parking it
    /// would strand the other holder's reads on a recycled block.
    fn refuses_shared(&self, session: u64) -> bool {
        match self.sessions.get(&session) {
            Some(s) if s.loc == KvLoc::Device => {
                if s.blocks.iter().any(|&b| self.refcounts[b as usize] > 1) {
                    G_SPILL_DENIED_SHARED.fetch_add(1, Ordering::Relaxed);
                    return true;
                }
                false
            }
            _ => false,
        }
    }

    /// Write a session's whole block set out to the host tier and return
    /// its device blocks to the free list. Returns the bytes moved, or 0
    /// when nothing happened (unknown/already-spilled session — benign:
    /// a release may have raced the command — or host tier disabled/full,
    /// which trips `spill_denied`). A *peer-parked* session spilled here
    /// is the three-tier **demotion** path: its image moves peer → host.
    pub fn spill(&mut self, session: u64) -> u64 {
        self.settle(session);
        if self.host.is_none() {
            G_SPILL_DENIED.fetch_add(1, Ordering::Relaxed);
            return 0;
        }
        if self.sessions.get(&session).map_or(false, |s| s.loc == KvLoc::Peer) {
            return self.demote(session);
        }
        if self.refuses_shared(session) {
            return 0;
        }
        let block_bytes = self.cfg.block_bytes();
        let bytes = match self.sessions.get(&session) {
            Some(s) if s.loc == KvLoc::Device && !s.blocks.is_empty() => {
                s.blocks.len() as u64 * block_bytes
            }
            _ => return 0,
        };
        if self.host.as_mut().unwrap().ledger.alloc(bytes).is_err() {
            G_SPILL_DENIED.fetch_add(1, Ordering::Relaxed);
            return 0;
        }
        let buf = self.image_out(session);
        self.host.as_mut().unwrap().bufs.insert(session, buf);
        self.sessions.get_mut(&session).unwrap().loc = KvLoc::Host;
        G_SPILLS.fetch_add(1, Ordering::Relaxed);
        G_SPILL_BYTES.fetch_add(bytes, Ordering::Relaxed);
        G_HOST_BYTES.fetch_add(bytes, Ordering::Relaxed);
        G_SESSIONS_SPILLED.fetch_add(1, Ordering::Relaxed);
        bytes
    }

    /// Demote a peer-parked session's image to this worker's host tier
    /// (peer pressure: the policy wants the peer blocks back). On a full
    /// host ledger the image stays parked — whole-block arithmetic means
    /// every worker reaches the same verdict.
    fn demote(&mut self, session: u64) -> u64 {
        let be = self.cfg.block_elems();
        let bytes = self
            .peer
            .as_ref()
            .and_then(|p| p.parked_bytes(session))
            .expect("parked session has a peer reservation");
        if self.host.as_mut().unwrap().ledger.alloc(bytes).is_err() {
            G_SPILL_DENIED.fetch_add(1, Ordering::Relaxed);
            return 0;
        }
        let peer = self.peer.as_mut().unwrap();
        let img = peer.retrieve(session, be);
        peer.credit(session);
        debug_assert_eq!((img.len() * 4) as u64, bytes, "parked image drifted from its ledger");
        self.host.as_mut().unwrap().bufs.insert(session, img);
        self.sessions.get_mut(&session).unwrap().loc = KvLoc::Host;
        G_DEMOTES.fetch_add(1, Ordering::Relaxed);
        G_PEER_BYTES.fetch_sub(bytes, Ordering::Relaxed);
        G_HOST_BYTES.fetch_add(bytes, Ordering::Relaxed);
        G_SESSIONS_PARKED.fetch_sub(1, Ordering::Relaxed);
        G_SESSIONS_SPILLED.fetch_add(1, Ordering::Relaxed);
        bytes
    }

    /// Park a resident session's whole block set in the peer worker's
    /// spare device memory (§4.4 PMEP) and return its device blocks to
    /// the free list. Mirrors [`KvCache::spill`]'s contract: returns the
    /// bytes shipped, or 0 when nothing happened (unknown/off-device
    /// session — benign release races — or no peer tier / peer ledger
    /// full, which trips `park_denied`; shared blocks refuse with
    /// `spill_denied_shared`).
    pub fn park(&mut self, session: u64) -> u64 {
        self.settle(session);
        if self.peer.is_none() {
            G_PARK_DENIED.fetch_add(1, Ordering::Relaxed);
            return 0;
        }
        if self.refuses_shared(session) {
            return 0;
        }
        let be = self.cfg.block_elems();
        let block_bytes = self.cfg.block_bytes();
        let bytes = match self.sessions.get(&session) {
            Some(s) if s.loc == KvLoc::Device && !s.blocks.is_empty() => {
                s.blocks.len() as u64 * block_bytes
            }
            _ => return 0,
        };
        if self.peer.as_mut().unwrap().charge(session, bytes).is_err() {
            G_PARK_DENIED.fetch_add(1, Ordering::Relaxed);
            return 0;
        }
        let img = self.image_out(session);
        self.sessions.get_mut(&session).unwrap().loc = KvLoc::Peer;
        self.peer.as_mut().unwrap().send_park(session, img, be);
        G_PARKS.fetch_add(1, Ordering::Relaxed);
        G_PARK_BYTES.fetch_add(bytes, Ordering::Relaxed);
        G_PEER_BYTES.fetch_add(bytes, Ordering::Relaxed);
        G_SESSIONS_PARKED.fetch_add(1, Ordering::Relaxed);
        bytes
    }

    /// Bring a peer-parked session's image home and stage it back into
    /// the device tier. Returns the bytes moved (0 for unknown or
    /// non-parked sessions — benign, e.g. a hint racing a sync fetch).
    /// The ring wait and — without a copier — the install copy run on the
    /// worker thread and are counted as prefetch stall; with a copier
    /// only the residual [`KvCache::settle`] wait is.
    pub fn fetch(&mut self, session: u64) -> u64 {
        match self.sessions.get(&session) {
            Some(s) if s.loc == KvLoc::Peer => {}
            _ => return 0,
        }
        let t0 = std::time::Instant::now();
        let be = self.cfg.block_elems();
        let peer = self.peer.as_mut().expect("parked session without a peer tier");
        let img = peer.retrieve(session, be);
        let bytes = peer.credit(session);
        debug_assert_eq!((img.len() * 4) as u64, bytes, "parked image drifted from its ledger");
        self.sessions.get_mut(&session).unwrap().loc = KvLoc::Device;
        G_FETCHES.fetch_add(1, Ordering::Relaxed);
        G_FETCH_BYTES.fetch_add(bytes, Ordering::Relaxed);
        G_PEER_BYTES.fetch_sub(bytes, Ordering::Relaxed);
        G_SESSIONS_PARKED.fetch_sub(1, Ordering::Relaxed);
        if let Some(cp) = &self.copier {
            cp.stage(session, img);
            self.pending_install.insert(session);
        } else {
            self.install(session, img);
        }
        note_prefetch_stall_us(t0.elapsed().as_micros() as u64);
        bytes
    }

    /// Stage a spilled session's blocks back into the device tier.
    /// Returns the bytes moved (0 for unknown or already-resident
    /// sessions — benign, e.g. a hint that arrived after a sync fetch).
    /// With a copier the landing copy overlaps the current forward and
    /// [`KvCache::settle`] installs it when the rows are needed.
    pub fn prefetch(&mut self, session: u64) -> u64 {
        match self.sessions.get(&session) {
            Some(s) if s.loc == KvLoc::Host => {}
            _ => return 0,
        }
        let buf = self
            .host
            .as_mut()
            .expect("spilled session without a host tier")
            .bufs
            .remove(&session)
            .expect("spilled session has a host buffer");
        let bytes = (buf.len() * 4) as u64;
        self.host.as_mut().unwrap().ledger.dealloc(bytes);
        self.sessions.get_mut(&session).unwrap().loc = KvLoc::Device;
        G_PREFETCHES.fetch_add(1, Ordering::Relaxed);
        G_PREFETCH_BYTES.fetch_add(bytes, Ordering::Relaxed);
        G_HOST_BYTES.fetch_sub(bytes, Ordering::Relaxed);
        G_SESSIONS_SPILLED.fetch_sub(1, Ordering::Relaxed);
        if let Some(cp) = &self.copier {
            cp.stage(session, buf);
            self.pending_install.insert(session);
        } else {
            self.install(session, buf);
        }
        bytes
    }

    /// Complete an in-flight staging for `session`: wait for the copier's
    /// landing copy and install it into device blocks. The wait is the
    /// residual stall the copier could not hide — usually zero, because
    /// the landing memcpy overlapped the previous forward.
    pub fn settle(&mut self, session: u64) {
        if !self.pending_install.remove(&session) {
            return;
        }
        let t0 = std::time::Instant::now();
        let img =
            self.copier.as_ref().expect("pending install without a copier").wait_landed(session);
        note_prefetch_stall_us(t0.elapsed().as_micros() as u64);
        if self.sessions.contains_key(&session) {
            self.install(session, img);
        }
    }

    /// Complete every in-flight staging (the worker calls this right
    /// before a forward so `gather` only ever sees resident sessions).
    pub fn settle_all(&mut self) {
        let mut pending: Vec<u64> = self.pending_install.iter().copied().collect();
        pending.sort_unstable();
        for id in pending {
            self.settle(id);
        }
    }

    /// Drain any park images the ring client has already shipped (without
    /// blocking). Workers call this at ticketed park points so the
    /// buffered channel never fills even when the client parks long before
    /// this worker's next fetch-side wait absorbs the message.
    pub fn pump_peer(&mut self) {
        let be = self.cfg.block_elems();
        if let Some(peer) = self.peer.as_mut() {
            peer.pump(be);
        }
    }

    // ---- shared-prefix registry ---------------------------------------

    /// Retain the first `positions` positions of a *resident* session's
    /// cache in the shared-prefix registry, keyed by the session's own id.
    /// The registry takes its own reference on every covered block, so the
    /// cached prefix outlives the session and later prompts can adopt it
    /// ([`KvCache::adopt_prefix`]) instead of prefilling their own copy.
    /// `positions` must be block-aligned (the engine only registers whole
    /// blocks). Returns the number of blocks retained; 0 means nothing was
    /// retained (unknown/spilled/too-short session, zero positions, or the
    /// key is already registered).
    pub fn retain_prefix(&mut self, session: u64, positions: usize) -> usize {
        let bp = self.cfg.block_positions;
        if positions == 0 || self.cached.contains_key(&session) {
            return 0;
        }
        debug_assert!(positions % bp == 0, "retained prefixes are block-aligned");
        let n = (positions + bp - 1) / bp;
        let blocks: Vec<u32> = match self.sessions.get(&session) {
            Some(s) if s.loc == KvLoc::Device && s.len >= positions && s.blocks.len() >= n => {
                s.blocks[..n].to_vec()
            }
            _ => return 0,
        };
        for &b in &blocks {
            self.refcounts[b as usize] += 1;
        }
        self.cached.insert(session, CachedPrefix { blocks, len: positions });
        G_CACHED_PREFIXES.fetch_add(1, Ordering::Relaxed);
        n
    }

    /// Seed a brand-new session from a registry entry: the session's block
    /// table references the cached blocks (refcount, no copy) and starts
    /// with `positions` positions already valid — the whole point of the
    /// feature: those positions' K/V are never computed or stored again.
    /// `positions` may be shorter than the entry (an unaligned tail block
    /// stays shared until copy-on-write privatizes it). Returns `false`
    /// and does nothing when the entry is missing/too short or the
    /// session already exists.
    pub fn adopt_prefix(&mut self, session: u64, donor: u64, positions: usize) -> bool {
        if positions == 0 || self.sessions.contains_key(&session) {
            return false;
        }
        let bp = self.cfg.block_positions;
        let n = (positions + bp - 1) / bp;
        let blocks: Vec<u32> = match self.cached.get(&donor) {
            Some(e) if e.len >= positions && e.blocks.len() >= n => e.blocks[..n].to_vec(),
            _ => return false,
        };
        for &b in &blocks {
            self.refcounts[b as usize] += 1;
        }
        // an id coming back to life must not trip the double-release guard
        if self.freed_set.remove(&session) {
            self.freed_ring.retain(|&id| id != session);
        }
        self.sessions.insert(session, SessionKv { blocks, len: positions, loc: KvLoc::Device });
        G_SESSIONS.fetch_add(1, Ordering::Relaxed);
        G_PREFIX_ADOPTS.fetch_add(1, Ordering::Relaxed);
        G_ADOPTED_BLOCKS.fetch_add(n as u64, Ordering::Relaxed);
        true
    }

    /// Drop registry entries (ticketed eviction from the engine-side trie,
    /// or spill of the registrant). Unknown keys are tolerated — eviction
    /// may race a registration that never happened on this worker.
    pub fn evict_prefix(&mut self, ids: &[u64]) {
        for &id in ids {
            if let Some(e) = self.cached.remove(&id) {
                for b in e.blocks {
                    self.release_block(b);
                }
                G_CACHED_PREFIXES.fetch_sub(1, Ordering::Relaxed);
            }
        }
    }

    /// Prefixes currently retained in this worker's registry.
    pub fn cached_prefix_count(&self) -> usize {
        self.cached.len()
    }

    #[cfg(test)]
    fn refcount_total(&self) -> u64 {
        self.refcounts.iter().map(|&r| r as u64).sum()
    }

    #[cfg(test)]
    fn referenced_blocks(&self) -> usize {
        self.refcounts.iter().filter(|&&r| r > 0).count()
    }

    /// Σ block-table lengths over every holder (resident sessions + the
    /// registry) — the shadow side of the refcount invariant.
    #[cfg(test)]
    fn holder_table_blocks(&self) -> u64 {
        let s: usize = self.sessions.values().map(|s| s.blocks.len()).sum();
        let c: usize = self.cached.values().map(|e| e.blocks.len()).sum();
        (s + c) as u64
    }

    /// Shrink a session's cache to its first `new_len` positions,
    /// returning now-unreferenced whole blocks to the free list — the
    /// speculative-decode cleanup: a verify step appends K/V rows for its
    /// whole drafted window, and the rejected tail must come back out
    /// before the session's next step reads the cache. Growing is not
    /// possible through this call (`new_len >= len` is a no-op on the
    /// length), and unknown sessions are tolerated loudly (`free_unknown`
    /// counter) like [`KvCache::free`].
    ///
    /// An *off-device* session can be truncated too: a host image is
    /// shortened in place and its ledger bytes credited; a peer-parked
    /// image is shrunk on the owner's ledger and a truncation shipped to
    /// the holder (applied in place, or deferred until the park lands).
    /// Block accounting stays exact across any interleaving of
    /// append/truncate/spill/park/fetch/prefetch/free (pinned by the
    /// property test below).
    pub fn truncate_tail(&mut self, session: u64, new_len: usize) -> bool {
        self.settle(session);
        let bp = self.cfg.block_positions;
        let be = self.cfg.block_elems();
        if !self.sessions.contains_key(&session) {
            self.note_unknown_release(session, "truncate");
            return false;
        }
        let s = self.sessions.get_mut(&session).unwrap();
        let shortened = new_len < s.len;
        s.len = s.len.min(new_len);
        let need = if new_len == 0 { 0 } else { (new_len + bp - 1) / bp };
        match s.loc {
            KvLoc::Host => {
                let host = self.host.as_mut().expect("spilled session without a host tier");
                let buf = host.bufs.get_mut(&session).expect("spilled session has a host buffer");
                let have = buf.len() / be;
                if have > need {
                    let freed = have - need;
                    buf.vec_mut().truncate(need * be);
                    let bytes = (freed * be * 4) as u64;
                    host.ledger.dealloc(bytes);
                    G_HOST_BYTES.fetch_sub(bytes, Ordering::Relaxed);
                    G_TRUNCATED_BLOCKS.fetch_add(freed as u64, Ordering::Relaxed);
                }
            }
            KvLoc::Peer => {
                let block_bytes = self.cfg.block_bytes();
                let peer = self.peer.as_mut().expect("parked session without a peer tier");
                let have =
                    (peer.parked_bytes(session).expect("parked session has a peer reservation")
                        / block_bytes) as usize;
                if have > need {
                    let freed = have - need;
                    peer.shrink_parked(session, need as u64 * block_bytes);
                    peer.truncate_guest(session, need, be);
                    let bytes = freed as u64 * block_bytes;
                    G_PEER_BYTES.fetch_sub(bytes, Ordering::Relaxed);
                    G_TRUNCATED_BLOCKS.fetch_add(freed as u64, Ordering::Relaxed);
                }
            }
            KvLoc::Device => {
                if s.blocks.len() > need {
                    let drained: Vec<u32> = s.blocks.drain(need..).collect();
                    G_TRUNCATED_BLOCKS.fetch_add(drained.len() as u64, Ordering::Relaxed);
                    for b in drained {
                        // shared tail blocks (the registry or another table
                        // still holds them) are decremented, not recycled
                        self.release_block(b);
                    }
                }
            }
        }
        if shortened {
            G_TRUNCATES.fetch_add(1, Ordering::Relaxed);
        }
        true
    }

    /// Release a session's blocks — on whichever tier they live — and
    /// forget it. Returns `false` (and trips the `free_unknown` counter:
    /// loud, never silent) when this cache holds nothing for the session,
    /// which legitimately happens on error-path releases for batches this
    /// worker never executed. A session this cache *recently released*
    /// is different: freeing it again is a double release (a
    /// cancellation/watchdog race), counted in `double_free` and fatal
    /// in debug builds — the recently-freed ring covers device, host,
    /// *and* peer frees alike, so each anomaly is counted exactly once
    /// regardless of where the session's bytes sat when it died.
    pub fn free(&mut self, session: u64) -> bool {
        self.settle(session);
        match self.sessions.remove(&session) {
            None => {
                self.note_unknown_release(session, "free");
                false
            }
            Some(s) => {
                self.note_freed(session);
                match s.loc {
                    KvLoc::Host => {
                        let host =
                            self.host.as_mut().expect("spilled session without a host tier");
                        let buf =
                            host.bufs.remove(&session).expect("spilled session has a host buffer");
                        let bytes = (buf.len() * 4) as u64;
                        host.ledger.dealloc(bytes);
                        G_HOST_BYTES.fetch_sub(bytes, Ordering::Relaxed);
                        G_SESSIONS_SPILLED.fetch_sub(1, Ordering::Relaxed);
                    }
                    KvLoc::Peer => {
                        let be = self.cfg.block_elems();
                        let peer =
                            self.peer.as_mut().expect("parked session without a peer tier");
                        let bytes = peer.credit(session);
                        peer.drop_guest(session, be);
                        G_PEER_BYTES.fetch_sub(bytes, Ordering::Relaxed);
                        G_SESSIONS_PARKED.fetch_sub(1, Ordering::Relaxed);
                    }
                    KvLoc::Device => {
                        for b in s.blocks {
                            // a shared block survives its session: the prefix
                            // registry (or an adopter) still reads it
                            self.release_block(b);
                        }
                    }
                }
                G_SESSIONS.fetch_sub(1, Ordering::Relaxed);
                true
            }
        }
    }

    /// Drop every session and every retained prefix (worker teardown).
    pub fn clear(&mut self) {
        let ids: Vec<u64> = self.sessions.keys().copied().collect();
        for id in ids {
            self.free(id);
        }
        let cached: Vec<u64> = self.cached.keys().copied().collect();
        self.evict_prefix(&cached);
    }
}

impl Drop for KvCache {
    fn drop(&mut self) {
        self.clear();
        G_SLAB_BYTES.fetch_sub(self.n_blocks as u64 * self.cfg.block_bytes(), Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cache(bp: usize, layers: usize, width: usize) -> KvCache {
        let mut cfg = KvCacheConfig::new(bp, layers, width);
        cfg.grow_blocks = 4; // small chunks so tests exercise growth
        KvCache::new(cfg)
    }

    fn tiered(bp: usize, layers: usize, width: usize, device: usize, host: usize) -> KvCache {
        let mut cfg = KvCacheConfig::new(bp, layers, width)
            .with_device_capacity(device)
            .with_host_tier(host);
        cfg.grow_blocks = 4;
        KvCache::new(cfg)
    }

    /// Three-tier cache whose peer ring is a buffered self-loop (world 1).
    fn peered(
        bp: usize,
        layers: usize,
        width: usize,
        device: usize,
        host: usize,
        peer: usize,
    ) -> KvCache {
        let mut cfg = KvCacheConfig::new(bp, layers, width)
            .with_device_capacity(device)
            .with_host_tier(host)
            .with_peer_tier(peer);
        cfg.grow_blocks = 4;
        let mut c = KvCache::new(cfg);
        c.attach_self_peer();
        c
    }

    fn row(tag: f32, w: usize) -> Vec<f32> {
        (0..w).map(|i| tag + i as f32 / 100.0).collect()
    }

    /// Fill `n` positions over `layers` layers with deterministic rows.
    fn fill(c: &mut KvCache, id: u64, layers: usize, n: usize, w: usize) {
        for pos in 0..n {
            for layer in 0..layers {
                let tag = (id * 1000 + layer as u64 * 100 + pos as u64) as f32;
                c.write_row(id, layer, pos, &row(tag, w), &row(tag + 0.5, w));
            }
        }
        c.advance(id, n);
    }

    fn check(c: &KvCache, id: u64, layers: usize, n: usize, w: usize) {
        for layer in 0..layers {
            let mut k = vec![-1.0; n * w];
            let mut v = vec![-1.0; n * w];
            assert_eq!(c.gather(id, layer, &mut k, &mut v), n, "id {id} layer {layer}");
            for pos in 0..n {
                let tag = (id * 1000 + layer as u64 * 100 + pos as u64) as f32;
                assert_eq!(&k[pos * w..(pos + 1) * w], &row(tag, w)[..], "k {id}/{layer}/{pos}");
                assert_eq!(
                    &v[pos * w..(pos + 1) * w],
                    &row(tag + 0.5, w)[..],
                    "v {id}/{layer}/{pos}"
                );
            }
        }
    }

    #[test]
    fn write_gather_roundtrip_across_blocks() {
        // 3 positions per block so position 7 spans 3 blocks
        let mut c = cache(3, 2, 4);
        fill(&mut c, 9, 2, 8, 4);
        assert_eq!(c.len(9), Some(8));
        check(&c, 9, 2, 8, 4);
        assert_eq!(c.blocks_in_use(), 3); // ceil(8/3)
    }

    #[test]
    fn write_prefix_matches_per_row_writes() {
        let n = 7; // spans 3 blocks of 3
        let w = 4;
        let mut rows_k = Vec::new();
        let mut rows_v = Vec::new();
        for pos in 0..n {
            rows_k.extend(row(pos as f32, w));
            rows_v.extend(row(pos as f32 + 0.5, w));
        }
        let mut a = cache(3, 2, w);
        for pos in 0..n {
            for layer in 0..2 {
                let r = pos * w..(pos + 1) * w;
                a.write_row(1, layer, pos, &rows_k[r.clone()], &rows_v[r]);
            }
        }
        a.advance(1, n);
        let mut b = cache(3, 2, w);
        for layer in 0..2 {
            b.write_prefix(1, layer, n, &rows_k, &rows_v);
        }
        b.advance(1, n);
        for layer in 0..2 {
            let (mut ka, mut va) = (vec![0.0; n * w], vec![0.0; n * w]);
            let (mut kb, mut vb) = (vec![0.0; n * w], vec![0.0; n * w]);
            assert_eq!(a.gather(1, layer, &mut ka, &mut va), n);
            assert_eq!(b.gather(1, layer, &mut kb, &mut vb), n);
            assert_eq!(ka, kb, "layer {layer} k diverged");
            assert_eq!(va, vb, "layer {layer} v diverged");
            assert_eq!(kb, rows_k, "layer {layer} k roundtrip");
        }
        // zero-length prefix is a no-op that allocates nothing
        let mut c = cache(3, 1, w);
        c.write_prefix(9, 0, 0, &[], &[]);
        assert_eq!(c.blocks_in_use(), 0);
    }

    #[test]
    fn gather_copies_only_advanced_prefix() {
        let mut c = cache(4, 1, 2);
        for pos in 0..3 {
            c.write_row(1, 0, pos, &row(pos as f32, 2), &row(pos as f32, 2));
        }
        c.advance(1, 2); // third row written but not yet published
        let mut k = vec![0.0; 4 * 2];
        let mut v = vec![0.0; 4 * 2];
        assert_eq!(c.gather(1, 0, &mut k, &mut v), 2);
        assert_eq!(&k[0..2], &row(0.0, 2)[..]);
        assert_eq!(&k[2..4], &row(1.0, 2)[..]);
        // staging beyond len untouched
        assert_eq!(&k[4..], &[0.0; 4]);
    }

    #[test]
    fn free_recycles_blocks_and_sessions_share_the_slab() {
        let mut c = cache(2, 1, 2);
        // 100 sequential sessions of 6 positions (3 blocks each): the slab
        // must not grow past what one session needs (plus grow chunking)
        let mut peak_capacity = 0;
        for id in 0..100u64 {
            for pos in 0..6 {
                c.write_row(id, 0, pos, &row(pos as f32, 2), &row(pos as f32, 2));
            }
            c.advance(id, 6);
            peak_capacity = peak_capacity.max(c.capacity_blocks());
            assert!(c.free(id), "session {id} was live");
            assert_eq!(c.blocks_in_use(), 0, "session {id} leaked blocks");
        }
        assert_eq!(c.capacity_blocks(), peak_capacity, "slab grew after first session");
        assert!(peak_capacity <= 4, "one 3-block session grew {peak_capacity} blocks");
        assert_eq!(c.session_count(), 0);
    }

    #[test]
    fn free_unknown_is_counted_not_silent() {
        let mut c = cache(2, 1, 2);
        // a session this cache never held: benign error-path release,
        // tolerated but visible in the counter — and never a panic
        let before = global_stats().free_unknown;
        assert!(!c.free(41));
        assert!(global_stats().free_unknown > before, "unknown free went uncounted");
        // a *recently released* session freed again is a true double
        // release: its own counter, and fatal in debug builds
        c.write_row(5, 0, 0, &[1.0, 2.0], &[3.0, 4.0]);
        c.advance(5, 1);
        assert!(c.free(5));
        let dbl = global_stats().double_free;
        let got = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| c.free(5)));
        match got {
            Ok(ret) => {
                assert!(!cfg!(debug_assertions), "debug build must assert on a double free");
                assert!(!ret);
            }
            Err(_) => assert!(cfg!(debug_assertions), "release build must tolerate loudly"),
        }
        assert!(global_stats().double_free > dbl, "double free went uncounted");
        let mut k = vec![0.0; 2];
        let mut v = vec![0.0; 2];
        assert_eq!(c.gather(5, 0, &mut k, &mut v), 0);
        assert_eq!(c.blocks_in_use(), 0);
    }

    #[test]
    fn revived_session_id_is_not_a_double_free() {
        let mut c = cache(2, 1, 2);
        fill(&mut c, 7, 1, 3, 2);
        assert!(c.free(7));
        // the same id coming back to life (restarts and tests reuse ids)
        // makes its next release first-class again
        fill(&mut c, 7, 1, 2, 2);
        let dbl = global_stats().double_free;
        assert!(c.free(7));
        assert_eq!(global_stats().double_free, dbl, "revived id misread as double free");
    }

    #[test]
    fn truncate_of_released_session_is_loud() {
        let mut c = cache(2, 1, 2);
        fill(&mut c, 9, 1, 3, 2);
        assert!(c.free(9));
        let dbl = global_stats().double_free;
        let got = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| c.truncate_tail(9, 1)));
        match got {
            Ok(ret) => {
                assert!(!cfg!(debug_assertions));
                assert!(!ret);
            }
            Err(_) => assert!(cfg!(debug_assertions)),
        }
        assert!(global_stats().double_free > dbl, "double truncate went uncounted");
    }

    #[test]
    fn concurrent_sessions_do_not_alias() {
        let mut c = cache(2, 1, 2);
        for id in 0..8u64 {
            fill(&mut c, id, 1, 5, 2);
        }
        for id in 0..8u64 {
            check(&c, id, 1, 5, 2);
        }
        assert_eq!(c.blocks_in_use(), 8 * 3); // ceil(5/2) per session
    }

    #[test]
    fn global_stats_track_use_and_recycling() {
        // other tests mutate the process-wide counters concurrently, so
        // assert only on monotonic counters' deltas
        let before = global_stats();
        let mut c = cache(2, 1, 2);
        c.write_row(1, 0, 0, &[1.0, 2.0], &[3.0, 4.0]);
        c.advance(1, 1);
        let mid = global_stats();
        assert!(mid.blocks_grown > before.blocks_grown, "growth not counted");
        assert!(mid.blocks_peak >= 1);
        c.free(1);
        c.write_row(2, 0, 0, &[1.0, 2.0], &[3.0, 4.0]);
        let after = global_stats();
        assert!(after.blocks_recycled > before.blocks_recycled, "free list unused");
        // instance-level invariants are deterministic
        assert_eq!(c.blocks_in_use(), 1);
        assert_eq!(c.session_count(), 1);
    }

    #[test]
    #[should_panic]
    fn wrong_width_panics() {
        let mut c = cache(2, 1, 4);
        c.write_row(0, 0, 0, &[1.0], &[1.0]);
    }

    // ---- two-tier behaviour -------------------------------------------

    #[test]
    fn spill_prefetch_roundtrip_preserves_rows() {
        let mut c = tiered(3, 2, 4, 8, 64);
        fill(&mut c, 7, 2, 8, 4); // 3 blocks
        let before_use = c.blocks_in_use();
        let bytes = c.spill(7);
        assert_eq!(bytes, 3 * c.config().block_bytes());
        assert!(c.is_spilled(7));
        assert_eq!(c.blocks_in_use(), before_use - 3);
        assert_eq!(c.host_bytes_used(), bytes);
        assert_eq!(c.spilled_count(), 1);
        // a second session can reuse the freed blocks meanwhile
        fill(&mut c, 8, 2, 5, 4);
        assert_eq!(c.prefetch(7), bytes);
        assert!(!c.is_spilled(7));
        assert_eq!(c.host_bytes_used(), 0);
        // both sessions read back exactly what was written
        check(&c, 7, 2, 8, 4);
        check(&c, 8, 2, 5, 4);
        // growth continues cleanly after staging back
        for layer in 0..2u64 {
            let tag = (7 * 1000 + layer * 100 + 8) as f32;
            c.write_row(7, layer as usize, 8, &row(tag, 4), &row(tag + 0.5, 4));
        }
        c.advance(7, 9);
        check(&c, 7, 2, 9, 4);
    }

    #[test]
    fn spill_noops_are_benign_and_denials_counted() {
        let mut c = tiered(2, 1, 2, 4, 1); // host tier: one block only
        fill(&mut c, 1, 1, 2, 2); // 1 block
        fill(&mut c, 2, 1, 4, 2); // 2 blocks: won't fit the host tier
        let denied_before = global_stats().spill_denied;
        assert_eq!(c.spill(2), 0, "host tier must refuse an oversized spill");
        assert!(global_stats().spill_denied > denied_before);
        assert!(!c.is_spilled(2));
        // unknown session / double spill / prefetch of resident: no-ops
        assert_eq!(c.spill(99), 0);
        assert!(c.spill(1) > 0);
        assert_eq!(c.spill(1), 0);
        assert_eq!(c.prefetch(99), 0);
        assert_eq!(c.prefetch(2), 0);
        // no-host-tier cache refuses loudly too
        let mut flat = cache(2, 1, 2);
        fill(&mut flat, 1, 1, 2, 2);
        let denied_before = global_stats().spill_denied;
        assert_eq!(flat.spill(1), 0);
        assert!(global_stats().spill_denied > denied_before);
    }

    #[test]
    fn gather_on_spilled_session_is_loud() {
        let mut c = tiered(2, 1, 2, 4, 8);
        fill(&mut c, 3, 1, 2, 2);
        assert!(c.spill(3) > 0);
        let before = global_stats().gather_spilled;
        let mut k = vec![0.0; 4];
        let mut v = vec![0.0; 4];
        // debug builds assert; release builds return 0 so the caller's
        // row-count check fails the batch. Either way the counter trips.
        let got = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            c.gather(3, 0, &mut k, &mut v)
        }));
        match got {
            Ok(n) => {
                assert!(!cfg!(debug_assertions), "debug builds must assert");
                assert_eq!(n, 0, "spilled gather must not fabricate rows");
            }
            Err(_) => assert!(cfg!(debug_assertions), "release builds must not panic"),
        }
        assert!(global_stats().gather_spilled > before, "spilled gather went uncounted");
    }

    #[test]
    fn write_on_spilled_session_is_loud_and_does_not_leak() {
        let mut c = tiered(2, 1, 2, 4, 8);
        fill(&mut c, 3, 1, 2, 2);
        assert!(c.spill(3) > 0);
        let before = global_stats().gather_spilled;
        let in_use = c.blocks_in_use();
        let got = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            c.write_row(3, 0, 2, &[9.0, 9.0], &[9.0, 9.0]);
        }));
        if got.is_ok() {
            assert!(!cfg!(debug_assertions), "debug builds must assert");
        }
        // no fresh blocks were carved beside the spilled image
        assert_eq!(c.blocks_in_use(), in_use, "spilled write allocated device blocks");
        assert!(c.is_spilled(3));
        assert!(global_stats().gather_spilled > before, "spilled write went uncounted");
        // the image itself is intact
        assert!(c.prefetch(3) > 0);
        check(&c, 3, 1, 2, 2);
    }

    #[test]
    fn free_drops_host_tier_entries() {
        let mut c = tiered(2, 1, 2, 4, 8);
        fill(&mut c, 1, 1, 4, 2); // 2 blocks
        assert!(c.spill(1) > 0);
        assert!(c.host_bytes_used() > 0);
        assert!(c.free(1));
        assert_eq!(c.host_bytes_used(), 0);
        assert_eq!(c.spilled_count(), 0);
        assert_eq!(c.session_count(), 0);
        assert_eq!(c.blocks_in_use(), 0);
    }

    #[test]
    fn device_soft_cap_counts_overflow_exactly() {
        let mut c = tiered(2, 1, 2, 2, 8); // cap: 2 device blocks
        let before = global_stats().overflow_blocks;
        fill(&mut c, 1, 1, 4, 2); // exactly 2 blocks: at cap, no overflow
        assert_eq!(global_stats().overflow_blocks, before);
        assert_eq!(c.capacity_blocks(), 2, "growth must clamp to the cap");
        fill(&mut c, 2, 1, 3, 2); // 2 more blocks: both carved past cap
        assert_eq!(global_stats().overflow_blocks, before + 2);
        assert_eq!(c.capacity_blocks(), 4);
    }

    // ---- tail truncation (speculative decode) --------------------------

    #[test]
    fn truncate_tail_frees_whole_blocks_and_keeps_prefix() {
        let mut c = cache(3, 2, 4);
        fill(&mut c, 1, 2, 8, 4); // 3 blocks (ceil 8/3)
        assert_eq!(c.blocks_in_use(), 3);
        // cut back to 4 positions: ceil(4/3) = 2 blocks stay
        assert!(c.truncate_tail(1, 4));
        assert_eq!(c.len(1), Some(4));
        assert_eq!(c.blocks_in_use(), 2);
        check(&c, 1, 2, 4, 4);
        // re-growing over the truncated region recycles the freed block
        // (instance-level capacity must not grow — other tests run
        // concurrently, so the process-wide counters can't be compared)
        let cap_before = c.capacity_blocks();
        for pos in 4..8 {
            for layer in 0..2 {
                let tag = (1000 + layer * 100 + pos) as f32;
                c.write_row(1, layer, pos, &row(tag, 4), &row(tag + 0.5, 4));
            }
        }
        c.advance(1, 8);
        assert_eq!(c.capacity_blocks(), cap_before, "truncate leaked to growth");
        check(&c, 1, 2, 8, 4);
    }

    #[test]
    fn truncate_tail_edge_cases() {
        let mut c = cache(2, 1, 2);
        fill(&mut c, 1, 1, 5, 2); // 3 blocks
        // growing via truncate is a length no-op
        assert!(c.truncate_tail(1, 9));
        assert_eq!(c.len(1), Some(5));
        assert_eq!(c.blocks_in_use(), 3);
        // same length: no blocks move, nothing changes
        let t_before = global_stats().truncates;
        assert!(c.truncate_tail(1, 5));
        assert_eq!(c.len(1), Some(5));
        assert_eq!(c.blocks_in_use(), 3);
        // to zero: every block comes back, session stays known
        assert!(c.truncate_tail(1, 0));
        assert_eq!(c.len(1), Some(0));
        assert_eq!(c.blocks_in_use(), 0);
        assert_eq!(c.session_count(), 1);
        assert!(global_stats().truncates > t_before);
        // unknown session: loud, not silent
        let u_before = global_stats().free_unknown;
        assert!(!c.truncate_tail(99, 1));
        assert!(global_stats().free_unknown > u_before);
        // mid-block cut: the partial block stays, rows above len are
        // simply never gathered again
        let mut c = cache(4, 1, 2);
        fill(&mut c, 2, 1, 6, 2); // 2 blocks
        assert!(c.truncate_tail(2, 3));
        assert_eq!(c.blocks_in_use(), 1);
        check(&c, 2, 1, 3, 2);
    }

    #[test]
    fn truncate_tail_shrinks_spilled_images() {
        let mut c = tiered(2, 1, 2, 8, 16);
        fill(&mut c, 5, 1, 8, 2); // 4 blocks
        let bytes_full = c.spill(5);
        assert_eq!(bytes_full, 4 * c.config().block_bytes());
        // truncate while parked: the host image shortens in place
        assert!(c.truncate_tail(5, 3)); // ceil(3/2) = 2 blocks stay
        assert_eq!(c.host_bytes_used(), 2 * c.config().block_bytes());
        assert!(c.is_spilled(5));
        // staging back restores exactly the surviving prefix
        assert_eq!(c.prefetch(5), 2 * c.config().block_bytes());
        assert_eq!(c.len(5), Some(3));
        assert_eq!(c.blocks_in_use(), 2);
        check(&c, 5, 1, 3, 2);
        assert!(c.free(5));
        assert_eq!(c.blocks_in_use(), 0);
        assert_eq!(c.host_bytes_used(), 0);
    }

    // ---- three-tier (peer) behaviour -----------------------------------

    #[test]
    fn park_fetch_roundtrip_preserves_rows() {
        let mut c = peered(3, 2, 4, 8, 64, 8);
        fill(&mut c, 7, 2, 8, 4); // 3 blocks
        let before_use = c.blocks_in_use();
        let bytes = c.park(7);
        assert_eq!(bytes, 3 * c.config().block_bytes());
        assert!(c.is_parked(7));
        assert!(c.is_spilled(7), "parked is off-device");
        assert_eq!(c.blocks_in_use(), before_use - 3);
        assert_eq!(c.peer_bytes_used(), bytes);
        assert_eq!(c.parked_count(), 1);
        assert_eq!(c.host_bytes_used(), 0, "park must not touch the host tier");
        // a second session can reuse the freed blocks meanwhile
        fill(&mut c, 8, 2, 5, 4);
        assert_eq!(c.fetch(7), bytes);
        assert!(!c.is_parked(7));
        assert_eq!(c.peer_bytes_used(), 0);
        assert_eq!(c.guest_bytes_used(), 0, "holder side must credit on fetch");
        // both sessions read back exactly what was written
        check(&c, 7, 2, 8, 4);
        check(&c, 8, 2, 5, 4);
        // growth continues cleanly after coming home
        for layer in 0..2u64 {
            let tag = (7 * 1000 + layer * 100 + 8) as f32;
            c.write_row(7, layer as usize, 8, &row(tag, 4), &row(tag + 0.5, 4));
        }
        c.advance(7, 9);
        check(&c, 7, 2, 9, 4);
    }

    #[test]
    fn park_noops_are_benign_and_denials_counted() {
        let mut c = peered(2, 1, 2, 8, 16, 1); // peer tier: one block only
        fill(&mut c, 1, 1, 2, 2); // 1 block
        fill(&mut c, 2, 1, 4, 2); // 2 blocks: won't fit the peer tier
        let denied_before = global_stats().park_denied;
        assert_eq!(c.park(2), 0, "peer tier must refuse an oversized park");
        assert!(global_stats().park_denied > denied_before);
        assert!(!c.is_parked(2));
        // unknown session / double park / fetch of resident: no-ops
        assert_eq!(c.park(99), 0);
        assert!(c.park(1) > 0);
        assert_eq!(c.park(1), 0);
        assert_eq!(c.fetch(99), 0);
        assert_eq!(c.fetch(2), 0);
        assert!(c.fetch(1) > 0);
        // a cache without a peer tier refuses loudly too
        let mut flat = tiered(2, 1, 2, 4, 8);
        fill(&mut flat, 1, 1, 2, 2);
        let denied_before = global_stats().park_denied;
        assert_eq!(flat.park(1), 0);
        assert!(global_stats().park_denied > denied_before);
    }

    #[test]
    fn park_refuses_shared_blocks() {
        let mut c = peered(2, 1, 2, 8, 16, 8);
        fill(&mut c, 1, 1, 4, 2); // 2 blocks
        assert_eq!(c.retain_prefix(1, 4), 2);
        let denied = global_stats().spill_denied_shared;
        assert_eq!(c.park(1), 0, "a shared session must never park");
        assert!(global_stats().spill_denied_shared > denied);
        assert!(!c.is_parked(1));
        c.evict_prefix(&[1]);
        assert!(c.park(1) > 0);
        assert!(c.fetch(1) > 0);
        check(&c, 1, 1, 4, 2);
    }

    #[test]
    fn spill_of_parked_session_demotes_to_host() {
        let mut c = peered(2, 1, 2, 8, 16, 8);
        fill(&mut c, 5, 1, 6, 2); // 3 blocks
        let bytes = c.park(5);
        assert!(bytes > 0);
        let demotes = global_stats().demotes;
        // peer pressure: the policy spills the parked session, which
        // moves its image peer -> host with both ledgers settled
        assert_eq!(c.spill(5), bytes);
        assert!(global_stats().demotes > demotes);
        assert!(c.is_spilled(5));
        assert!(!c.is_parked(5));
        assert_eq!(c.peer_bytes_used(), 0);
        assert_eq!(c.guest_bytes_used(), 0);
        assert_eq!(c.host_bytes_used(), bytes);
        // and comes back bit-exact from the host tier
        assert_eq!(c.prefetch(5), bytes);
        check(&c, 5, 1, 6, 2);
        // a demotion the host tier cannot absorb leaves the image parked
        let mut small = peered(2, 1, 2, 8, 1, 8); // host: one block
        fill(&mut small, 6, 1, 4, 2); // 2 blocks
        assert!(small.park(6) > 0);
        let denied = global_stats().spill_denied;
        assert_eq!(small.spill(6), 0);
        assert!(global_stats().spill_denied > denied);
        assert!(small.is_parked(6), "failed demotion must keep the image parked");
        assert!(small.fetch(6) > 0);
        check(&small, 6, 1, 4, 2);
    }

    #[test]
    fn truncate_tail_shrinks_parked_images() {
        let mut c = peered(2, 1, 2, 8, 16, 8);
        fill(&mut c, 5, 1, 8, 2); // 4 blocks
        let bytes_full = c.park(5);
        assert_eq!(bytes_full, 4 * c.config().block_bytes());
        // truncate while parked: the owner ledger shrinks and the holder
        // image shortens in place
        assert!(c.truncate_tail(5, 3)); // ceil(3/2) = 2 blocks stay
        assert_eq!(c.peer_bytes_used(), 2 * c.config().block_bytes());
        assert_eq!(c.guest_bytes_used(), 2 * c.config().block_bytes());
        assert!(c.is_parked(5));
        // fetching back restores exactly the surviving prefix
        assert_eq!(c.fetch(5), 2 * c.config().block_bytes());
        assert_eq!(c.len(5), Some(3));
        assert_eq!(c.blocks_in_use(), 2);
        check(&c, 5, 1, 3, 2);
        assert!(c.free(5));
        assert_eq!(c.blocks_in_use(), 0);
        assert_eq!(c.peer_bytes_used(), 0);
        assert_eq!(c.guest_bytes_used(), 0);
    }

    #[test]
    fn free_drops_peer_tier_entries() {
        let mut c = peered(2, 1, 2, 8, 16, 8);
        fill(&mut c, 1, 1, 4, 2); // 2 blocks
        assert!(c.park(1) > 0);
        assert!(c.peer_bytes_used() > 0);
        assert!(c.free(1));
        assert_eq!(c.peer_bytes_used(), 0);
        assert_eq!(c.guest_bytes_used(), 0, "holder must drop the dead guest image");
        assert_eq!(c.parked_count(), 0);
        assert_eq!(c.session_count(), 0);
        assert_eq!(c.blocks_in_use(), 0);
    }

    /// Satellite regression for the cancel×spill race: the recently-freed
    /// guard ring must cover frees on *every* tier, so a racing second
    /// release counts `double_free` exactly once — and stale tier commands
    /// (spill/prefetch/park/fetch of the dead id) stay silent no-ops, not
    /// `free_unknown` noise.
    #[test]
    fn cancel_race_frees_are_ring_guarded_on_every_tier() {
        use std::panic::{catch_unwind, AssertUnwindSafe};
        let mut c = peered(2, 1, 2, 8, 16, 8);
        for (id, offload) in [(1u64, "host"), (2u64, "peer")] {
            fill(&mut c, id, 1, 4, 2);
            match offload {
                "host" => assert!(c.spill(id) > 0),
                _ => assert!(c.park(id) > 0),
            }
            assert!(c.free(id), "off-device free must succeed");
            // stale tier commands racing the free are benign no-ops
            let unk = global_stats().free_unknown;
            let dbl = global_stats().double_free;
            assert_eq!(c.spill(id), 0);
            assert_eq!(c.prefetch(id), 0);
            assert_eq!(c.park(id), 0);
            assert_eq!(c.fetch(id), 0);
            assert_eq!(global_stats().free_unknown, unk, "stale {offload} ops miscounted");
            assert_eq!(global_stats().double_free, dbl, "stale {offload} ops double-counted");
            // the racing second free is the anomaly, counted exactly once
            let got = catch_unwind(AssertUnwindSafe(|| c.free(id)));
            match got {
                Ok(ret) => {
                    assert!(!cfg!(debug_assertions), "debug build must assert");
                    assert!(!ret);
                }
                Err(_) => assert!(cfg!(debug_assertions)),
            }
            assert!(global_stats().double_free > dbl, "{offload} double free uncounted");
        }
        assert_eq!(c.blocks_in_use(), 0);
        assert_eq!(c.host_bytes_used(), 0);
        assert_eq!(c.peer_bytes_used(), 0);
        assert_eq!(c.guest_bytes_used(), 0);
    }

    // ---- overlapped copier ---------------------------------------------

    /// Three-tier cache with the staging copier on.
    fn copiered(device: usize, host: usize, peer: usize) -> KvCache {
        let mut cfg = KvCacheConfig::new(2, 1, 2)
            .with_device_capacity(device)
            .with_host_tier(host)
            .with_peer_tier(peer)
            .with_copier(true);
        cfg.grow_blocks = 4;
        let mut c = KvCache::new(cfg);
        c.attach_self_peer();
        c
    }

    #[test]
    fn copier_stages_host_and_peer_images_for_settle() {
        let mut c = copiered(8, 16, 8);
        fill(&mut c, 1, 1, 4, 2); // 2 blocks
        fill(&mut c, 2, 1, 2, 2); // 1 block
        assert!(c.spill(1) > 0);
        assert!(c.park(2) > 0);
        let in_use = c.blocks_in_use();
        // staging returns immediately; the landing copy runs on the
        // copier thread and install waits for settle
        assert!(c.prefetch(1) > 0);
        assert!(c.fetch(2) > 0);
        assert!(!c.is_spilled(1) && !c.is_parked(2), "staged sessions read as device");
        assert_eq!(c.host_bytes_used(), 0, "ledgers settle at stage time");
        assert_eq!(c.peer_bytes_used(), 0);
        c.settle_all();
        assert_eq!(c.blocks_in_use(), in_use + 3);
        check(&c, 1, 1, 4, 2);
        check(&c, 2, 1, 2, 2);
        // a second settle is a no-op
        c.settle_all();
        assert_eq!(c.blocks_in_use(), in_use + 3);
    }

    #[test]
    fn writes_settle_pending_installs_implicitly() {
        let mut c = copiered(8, 16, 8);
        fill(&mut c, 3, 1, 3, 2); // 2 blocks
        assert!(c.park(3) > 0);
        assert!(c.fetch(3) > 0);
        // no explicit settle: the next write must install first, not
        // scribble into a stale block table
        let tag = (3 * 1000 + 3) as f32;
        c.write_row(3, 0, 3, &row(tag, 2), &row(tag + 0.5, 2));
        c.advance(3, 4);
        check(&c, 3, 1, 4, 2);
        assert!(c.free(3));
        assert_eq!(c.blocks_in_use(), 0);
    }

    #[test]
    fn free_of_a_staged_session_does_not_leak_any_tier() {
        let mut c = copiered(8, 16, 8);
        fill(&mut c, 4, 1, 4, 2);
        assert!(c.spill(4) > 0);
        assert!(c.prefetch(4) > 0);
        // the cancel lands while the image is still in flight
        assert!(c.free(4));
        assert_eq!(c.blocks_in_use(), 0, "staged free leaked device blocks");
        assert_eq!(c.host_bytes_used(), 0);
        assert_eq!(c.peer_bytes_used(), 0);
        assert_eq!(c.guest_bytes_used(), 0);
        assert_eq!(c.session_count(), 0);
        // truncate-while-staged settles first too
        fill(&mut c, 5, 1, 4, 2);
        assert!(c.park(5) > 0);
        assert!(c.fetch(5) > 0);
        assert!(c.truncate_tail(5, 1));
        assert_eq!(c.blocks_in_use(), 1);
        check(&c, 5, 1, 1, 2);
        assert!(c.free(5));
        assert_eq!(c.blocks_in_use(), 0);
    }

    /// Property-style: random interleavings of append / truncate / spill /
    /// park / fetch / prefetch / free preserve block accounting and
    /// gathered-row contents across all three tiers. A deterministic LCG
    /// drives the schedule; a shadow model (per-session expected length)
    /// checks every gather against the rows `fill`-style writes produced.
    #[test]
    fn random_interleavings_preserve_accounting_and_contents() {
        const BP: usize = 3;
        const LAYERS: usize = 2;
        const W: usize = 4;
        const N_SESSIONS: u64 = 6;
        let mut c = peered(BP, LAYERS, W, 16, 64, 8);
        let mut rng: u64 = 0x9E37_79B9_7F4A_7C15;
        let mut next = |m: u64| {
            rng = rng.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            (rng >> 33) % m
        };
        // shadow model: session -> Some(len) while alive
        let mut model: Vec<Option<usize>> = vec![None; N_SESSIONS as usize];

        let blocks_of = |len: usize| if len == 0 { 0 } else { (len + BP - 1) / BP };
        for step in 0..400 {
            let id = next(N_SESSIONS);
            let idx = id as usize;
            match next(7) {
                // append 1..=3 positions (bring it home first if off-device
                // — the production write path never touches one)
                0 => {
                    if c.is_parked(id) {
                        c.fetch(id);
                    }
                    if c.is_spilled(id) {
                        c.prefetch(id);
                    }
                    let cur = model[idx].unwrap_or(0);
                    let n = 1 + next(3) as usize;
                    let new = (cur + n).min(24);
                    for pos in cur..new {
                        for layer in 0..LAYERS {
                            let tag = (id * 1000 + layer as u64 * 100 + pos as u64) as f32;
                            c.write_row(id, layer, pos, &row(tag, W), &row(tag + 0.5, W));
                        }
                    }
                    if new > 0 {
                        c.advance(id, new);
                    }
                    model[idx] = Some(new);
                }
                // truncate to a random shorter length
                1 => {
                    if let Some(len) = model[idx] {
                        let keep = next(len as u64 + 1) as usize;
                        assert!(c.truncate_tail(id, keep), "live session refused truncate");
                        model[idx] = Some(keep.min(len));
                    }
                }
                2 => {
                    c.spill(id);
                }
                3 => {
                    c.prefetch(id);
                }
                4 => {
                    c.park(id);
                }
                5 => {
                    c.fetch(id);
                }
                _ => {
                    if model[idx].is_some() {
                        assert!(c.free(id), "live session refused free (step {step})");
                        model[idx] = None;
                    }
                }
            }
            // invariant: device blocks in use == Σ ceil(len/bp) over
            // resident sessions — append grows to exactly that, and
            // truncate frees back down to exactly that
            let expect_device: usize = model
                .iter()
                .enumerate()
                .filter(|(i, l)| l.is_some() && !c.is_spilled(*i as u64))
                .map(|(_, l)| blocks_of(l.unwrap()))
                .sum();
            assert_eq!(
                c.blocks_in_use(),
                expect_device,
                "step {step}: block accounting drifted from the model"
            );
        }
        // contents: every surviving session gathers exactly its prefix
        for id in 0..N_SESSIONS {
            if let Some(len) = model[id as usize] {
                if c.is_parked(id) {
                    c.fetch(id);
                }
                if c.is_spilled(id) {
                    c.prefetch(id);
                }
                check(&c, id, LAYERS, len, W);
            }
        }
        // teardown: everything comes back, on every tier
        for id in 0..N_SESSIONS {
            if model[id as usize].is_some() {
                c.free(id);
            }
        }
        assert_eq!(c.blocks_in_use(), 0, "interleaving leaked device blocks");
        assert_eq!(c.host_bytes_used(), 0, "interleaving leaked host bytes");
        assert_eq!(c.peer_bytes_used(), 0, "interleaving leaked peer bytes");
        assert_eq!(c.guest_bytes_used(), 0, "interleaving leaked guest bytes");
        assert_eq!(c.session_count(), 0);
    }

    // ---- shared-prefix registry / copy-on-write ------------------------

    #[test]
    fn retained_prefix_outlives_donor_and_adopts_by_refcount() {
        let mut c = cache(3, 2, 4);
        fill(&mut c, 1, 2, 6, 4); // exactly 2 blocks
        assert_eq!(c.retain_prefix(1, 6), 2);
        assert_eq!(c.cached_prefix_count(), 1);
        // double registration under the same key is refused
        assert_eq!(c.retain_prefix(1, 6), 0);
        // the donor session dies; the registry keeps its blocks alive
        assert!(c.free(1));
        assert_eq!(c.blocks_in_use(), 2, "registry must hold the blocks");
        // a new session adopts the whole prefix: no copy, no new blocks
        let adopts = global_stats().prefix_adopts;
        assert!(c.adopt_prefix(2, 1, 6));
        assert!(global_stats().prefix_adopts > adopts);
        assert_eq!(c.blocks_in_use(), 2);
        assert_eq!(c.len(2), Some(6));
        // the adopter reads the donor's rows bit-exact
        check(&c, 1, 2, 6, 4); // tags were written under id 1
        // growth past the shared prefix allocates a private block
        for layer in 0..2u64 {
            let tag = (1 * 1000 + layer * 100 + 6) as f32;
            c.write_row(2, layer as usize, 6, &row(tag, 4), &row(tag + 0.5, 4));
        }
        c.advance(2, 7);
        assert_eq!(c.blocks_in_use(), 3);
        check(&c, 1, 2, 7, 4); // rows still follow the donor tag scheme
        // adopter frees: shared blocks survive, the private one recycles
        assert!(c.free(2));
        assert_eq!(c.blocks_in_use(), 2);
        // eviction releases the last references
        c.evict_prefix(&[1]);
        assert_eq!(c.cached_prefix_count(), 0);
        assert_eq!(c.blocks_in_use(), 0, "evicted prefix leaked blocks");
        // bogus adopt/evict are no-ops
        assert!(!c.adopt_prefix(3, 1, 6));
        c.evict_prefix(&[1]);
    }

    #[test]
    fn unaligned_adopt_copies_on_write_before_the_append() {
        let mut c = cache(4, 1, 2);
        fill(&mut c, 1, 1, 8, 2); // 2 blocks
        assert_eq!(c.retain_prefix(1, 8), 2);
        // adopt only 6 of the 8 positions: the tail block stays shared
        // while holding donor rows the adopter must not clobber
        assert!(c.adopt_prefix(2, 1, 6));
        assert_eq!(c.blocks_in_use(), 2);
        let cow = global_stats().cow_copies;
        // the adopter's first append lands inside the shared tail block
        c.write_row(2, 0, 6, &[9.0, 9.5], &[19.0, 19.5]);
        c.advance(2, 7);
        assert!(global_stats().cow_copies > cow, "shared-tail write skipped CoW");
        assert_eq!(c.blocks_in_use(), 3, "CoW must privatize into a fresh block");
        // donor and registry images are untouched: a full-length adopter
        // still sees the original rows at positions 6 and 7
        check(&c, 1, 1, 8, 2);
        assert!(c.adopt_prefix(3, 1, 8));
        check(&c, 1, 1, 8, 2);
        // and the diverged adopter sees its own row at 6
        let (mut k, mut v) = (vec![0.0; 7 * 2], vec![0.0; 7 * 2]);
        assert_eq!(c.gather(2, 0, &mut k, &mut v), 7);
        assert_eq!(&k[12..14], &[9.0, 9.5]);
        assert_eq!(&v[12..14], &[19.0, 19.5]);
        // a second write to the now-private block does not CoW again
        let cow = global_stats().cow_copies;
        c.write_row(2, 0, 7, &[1.0, 1.0], &[1.0, 1.0]);
        assert_eq!(global_stats().cow_copies, cow);
    }

    #[test]
    fn spill_refuses_shared_blocks() {
        let mut c = tiered(2, 1, 2, 8, 16);
        fill(&mut c, 1, 1, 4, 2); // 2 blocks
        assert_eq!(c.retain_prefix(1, 4), 2);
        let denied = global_stats().spill_denied_shared;
        assert_eq!(c.spill(1), 0, "a shared session must never spill");
        assert!(global_stats().spill_denied_shared > denied);
        assert!(!c.is_spilled(1));
        // same refusal for an adopter holding shared blocks
        assert!(c.adopt_prefix(2, 1, 4));
        assert_eq!(c.spill(2), 0);
        assert!(c.free(2));
        // once the registry lets go (and no adopter holds the blocks),
        // the session is private again and spills normally
        c.evict_prefix(&[1]);
        assert!(c.spill(1) > 0);
        assert!(c.is_spilled(1));
        // a spilled session cannot register a prefix
        assert_eq!(c.retain_prefix(1, 4), 0);
        assert!(c.prefetch(1) > 0);
        check(&c, 1, 1, 4, 2);
        assert!(c.free(1));
        assert_eq!(c.blocks_in_use(), 0);
    }

    #[test]
    fn truncate_below_shared_prefix_decrements_not_frees() {
        let mut c = cache(2, 1, 2);
        fill(&mut c, 1, 1, 6, 2); // 3 blocks
        assert_eq!(c.retain_prefix(1, 6), 3);
        // the registrant is cut back below its own retained prefix (the
        // engine never does this; the cache must still stay consistent)
        assert!(c.truncate_tail(1, 2));
        assert_eq!(c.blocks_in_use(), 3, "registry still holds all 3 blocks");
        assert!(c.free(1));
        assert_eq!(c.blocks_in_use(), 3);
        // adopters of the full prefix still read the original rows
        assert!(c.adopt_prefix(2, 1, 6));
        check(&c, 1, 1, 6, 2);
        assert!(c.free(2));
        c.evict_prefix(&[1]);
        assert_eq!(c.blocks_in_use(), 0);
    }

    #[test]
    fn clear_drops_registry_entries_too() {
        let mut c = cache(2, 1, 2);
        fill(&mut c, 1, 1, 4, 2);
        assert_eq!(c.retain_prefix(1, 4), 2);
        c.clear();
        assert_eq!(c.session_count(), 0);
        assert_eq!(c.cached_prefix_count(), 0);
        assert_eq!(c.blocks_in_use(), 0);
    }

    /// Property-style: random interleavings of append / truncate / spill /
    /// prefetch / free / retain / adopt / evict keep the refcount invariant
    /// — Σ refcounts == Σ holder-table lengths, physical blocks-in-use ==
    /// blocks with refcount > 0, and no block is ever both shared and
    /// spilled (shared sessions refuse to spill). A per-position writer-id
    /// shadow model checks every surviving session's rows, so a missed
    /// copy-on-write (cross-session clobber) is caught by content, not
    /// just accounting.
    #[test]
    fn random_sharing_interleavings_preserve_refcounts_and_contents() {
        const BP: usize = 3;
        const LAYERS: usize = 2;
        const W: usize = 4;
        let mut c = tiered(BP, LAYERS, W, 24, 64);
        let mut rng: u64 = 0x2209_0234_1CAF_E42D;
        let mut next = |m: u64| {
            rng = rng.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            (rng >> 33) % m
        };
        // shadow model: per live session, the writer id of every position
        // (adopted positions carry the *donor's* writer id — their rows
        // were written by the donor and must never change underneath it);
        // per registry entry, the frozen writer-id vector at retain time.
        let mut live: HashMap<u64, Vec<u64>> = HashMap::new();
        let mut reg: HashMap<u64, Vec<u64>> = HashMap::new();
        let mut next_id: u64 = 1;

        let tag = |writer: u64, layer: usize, pos: usize| {
            (writer * 1000 + layer as u64 * 100 + pos as u64) as f32
        };
        for step in 0..600 {
            let pick = |m: &HashMap<u64, Vec<u64>>, r: u64| -> Option<u64> {
                let mut ids: Vec<u64> = m.keys().copied().collect();
                ids.sort_unstable();
                if ids.is_empty() { None } else { Some(ids[r as usize % ids.len()]) }
            };
            match next(9) {
                // spawn or append: writes tagged with the session's own id
                0 | 1 => {
                    let id = if live.is_empty() || next(3) == 0 {
                        next_id += 1;
                        live.insert(next_id, Vec::new());
                        next_id
                    } else {
                        pick(&live, next(1 << 30)).unwrap()
                    };
                    if c.is_spilled(id) {
                        c.prefetch(id);
                    }
                    let tags = live.get_mut(&id).unwrap();
                    let cur = tags.len();
                    let new = (cur + 1 + next(3) as usize).min(24);
                    for pos in cur..new {
                        for layer in 0..LAYERS {
                            let t = tag(id, layer, pos);
                            c.write_row(id, layer, pos, &row(t, W), &row(t + 0.5, W));
                        }
                        tags.push(id);
                    }
                    if new > 0 {
                        c.advance(id, new);
                    }
                }
                // truncate to a random shorter length
                2 => {
                    if let Some(id) = pick(&live, next(1 << 30)) {
                        let tags = live.get_mut(&id).unwrap();
                        let keep = next(tags.len() as u64 + 1) as usize;
                        assert!(c.truncate_tail(id, keep), "live session refused truncate");
                        tags.truncate(keep);
                    }
                }
                3 => {
                    if let Some(id) = pick(&live, next(1 << 30)) {
                        c.spill(id); // refused for shared sessions; either way no leak
                    }
                }
                4 => {
                    if let Some(id) = pick(&live, next(1 << 30)) {
                        c.prefetch(id);
                    }
                }
                5 => {
                    if let Some(id) = pick(&live, next(1 << 30)) {
                        assert!(c.free(id), "live session refused free (step {step})");
                        live.remove(&id);
                    }
                }
                // retain: register a block-aligned prefix of a live session
                6 => {
                    if let Some(id) = pick(&live, next(1 << 30)) {
                        let len = live[&id].len();
                        let aligned = (len / BP) * BP;
                        let got = c.retain_prefix(id, aligned);
                        if got > 0 {
                            reg.insert(id, live[&id][..aligned].to_vec());
                        }
                    }
                }
                // adopt: a brand-new session takes a (possibly unaligned)
                // cut of a cached prefix
                7 => {
                    if let Some(donor) = pick(&reg, next(1 << 30)) {
                        let max = reg[&donor].len() as u64;
                        let positions = 1 + next(max) as usize;
                        next_id += 1;
                        assert!(
                            c.adopt_prefix(next_id, donor, positions),
                            "step {step}: adopt of a live registry entry failed"
                        );
                        live.insert(next_id, reg[&donor][..positions].to_vec());
                    }
                }
                _ => {
                    if let Some(id) = pick(&reg, next(1 << 30)) {
                        c.evict_prefix(&[id]);
                        reg.remove(&id);
                    }
                }
            }
            assert_eq!(
                c.refcount_total(),
                c.holder_table_blocks(),
                "step {step}: Σrefcounts drifted from the holder tables"
            );
            assert_eq!(
                c.blocks_in_use(),
                c.referenced_blocks(),
                "step {step}: physical accounting drifted from refcounts"
            );
        }
        // contents: every surviving session reads exactly the rows its
        // shadow writers produced — adopted prefixes included
        for (&id, tags) in &live {
            if c.is_spilled(id) {
                c.prefetch(id);
            }
            let n = tags.len();
            for layer in 0..LAYERS {
                let (mut k, mut v) = (vec![-1.0; 24 * W], vec![-1.0; 24 * W]);
                assert_eq!(c.gather(id, layer, &mut k, &mut v), n, "session {id}");
                for (pos, &writer) in tags.iter().enumerate() {
                    let t = tag(writer, layer, pos);
                    assert_eq!(
                        &k[pos * W..(pos + 1) * W],
                        &row(t, W)[..],
                        "session {id} layer {layer} pos {pos} (writer {writer})"
                    );
                    assert_eq!(&v[pos * W..(pos + 1) * W], &row(t + 0.5, W)[..]);
                }
            }
        }
        // teardown: every holder lets go and every block comes back
        let ids: Vec<u64> = live.keys().copied().collect();
        for id in ids {
            c.free(id);
        }
        let keys: Vec<u64> = reg.keys().copied().collect();
        c.evict_prefix(&keys);
        assert_eq!(c.blocks_in_use(), 0, "sharing interleaving leaked device blocks");
        assert_eq!(c.host_bytes_used(), 0, "sharing interleaving leaked host bytes");
        assert_eq!(c.refcount_total(), 0);
        assert_eq!(c.cached_prefix_count(), 0);
        assert_eq!(c.session_count(), 0);
    }

    #[test]
    fn spilled_sessions_survive_device_churn() {
        // many sessions cycling through a tiny device tier while one
        // session sits spilled: its image must come back bit-exact
        let mut c = tiered(2, 2, 3, 4, 16);
        fill(&mut c, 42, 2, 6, 3); // 3 blocks
        assert!(c.spill(42) > 0);
        for id in 0..20u64 {
            fill(&mut c, id, 2, 4, 3);
            c.free(id);
        }
        assert!(c.prefetch(42) > 0);
        check(&c, 42, 2, 6, 3);
    }
}
