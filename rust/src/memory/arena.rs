//! The activation arena: size-bucketed recycling of `Vec<f32>` buffers so
//! the host hot path (collective chunks, DRCE pack/unpack scratch, residual
//! adds, activation handoff) is allocation-free at steady state.
//!
//! # Ownership model — who checks out, who returns
//!
//! * **Checkout** — [`ArenaPool::checkout`] hands out an [`ArenaBuf`] of the
//!   requested length, recycling a shelved buffer when one of the right size
//!   class exists, allocating a fresh one otherwise. Contents of a recycled
//!   buffer are *unspecified* (initialized but stale); callers that don't
//!   overwrite every element must use [`ArenaPool::checkout_zeroed`].
//! * **Return** — nobody calls a free function. Dropping an `ArenaBuf`
//!   returns its backing `Vec` to the shelf of the *dropping* thread. A
//!   buffer sent across a channel (e.g. a collective chunk inside
//!   `comm::collective::ChunkMsg`) therefore lands on the receiver's shelf;
//!   since ring collectives send and receive symmetrically, every endpoint's
//!   shelf stays balanced and steady-state checkouts always hit.
//! * **Escape** — [`ArenaBuf::take`] extracts the raw `Vec` and detaches it
//!   from the pool (used when a buffer must outlive the arena discipline).
//!
//! Shelves are **thread-local** (no mutex on the hot path, and per-thread
//! [`ArenaPool::thread_stats`] make allocation-freedom assertable in tests
//! without cross-test interference). Process-wide aggregates for the
//! `metrics::Recorder` are kept in relaxed atomics
//! ([`ArenaPool::global_stats`]).

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};

/// Smallest checkout worth recycling, in f32 elements. Tiny vectors are
/// cheaper to allocate than to shelve.
const MIN_BUCKET: usize = 64;
/// Buffers kept per size class per thread before overflow is really freed.
const SHELF_DEPTH: usize = 32;
/// Size classes are powers of two: 2^6 .. 2^35 elements (256 B – 128 GiB).
const N_CLASSES: usize = 36;
/// Cap on the bytes a single thread's shelves may pin. Returns beyond the
/// cap are freed instead of shelved, so the per-thread footprint cannot
/// ratchet up to the all-time high-water mark of every size class.
const MAX_SHELF_BYTES: u64 = 256 * 1024 * 1024;

/// Counters the arena accumulates; snapshot via [`ArenaPool::thread_stats`]
/// (this thread) or [`ArenaPool::global_stats`] (process-wide).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ArenaStats {
    /// Checkouts that had to allocate a fresh `Vec`.
    pub fresh_allocs: u64,
    /// Checkouts served from a shelf (no heap allocation).
    pub reuses: u64,
    /// Buffers returned to a shelf on drop.
    pub returns: u64,
    /// Returns dropped on the floor (shelf full or class out of range).
    pub shed: u64,
    /// Bytes newly allocated by fresh checkouts.
    pub bytes_allocated: u64,
    /// Bytes served from shelves instead of the allocator.
    pub bytes_recycled: u64,
}

struct Shelves {
    classes: Vec<Vec<Vec<f32>>>,
    /// Bytes currently pinned by this thread's shelves (capacity, not len).
    shelved_bytes: u64,
    stats: ArenaStats,
}

thread_local! {
    static SHELVES: RefCell<Shelves> = RefCell::new(Shelves {
        classes: (0..N_CLASSES).map(|_| Vec::new()).collect(),
        shelved_bytes: 0,
        stats: ArenaStats::default(),
    });
}

static G_FRESH: AtomicU64 = AtomicU64::new(0);
static G_REUSES: AtomicU64 = AtomicU64::new(0);
static G_RETURNS: AtomicU64 = AtomicU64::new(0);
static G_SHED: AtomicU64 = AtomicU64::new(0);
static G_BYTES_ALLOCATED: AtomicU64 = AtomicU64::new(0);
static G_BYTES_RECYCLED: AtomicU64 = AtomicU64::new(0);

/// Size class a checkout of `len` elements draws from (ceil log2).
fn class_of_len(len: usize) -> usize {
    (len.max(MIN_BUCKET)).next_power_of_two().trailing_zeros() as usize
}

/// Size class a returned buffer of `cap` capacity shelves under (floor
/// log2, so every buffer under class k has capacity >= 2^k).
fn class_of_cap(cap: usize) -> usize {
    (usize::BITS - 1 - cap.leading_zeros()) as usize
}

/// The size-bucketed buffer pool. All state is thread-local or atomic, so
/// the type itself is a namespace: `ArenaPool::checkout(n)`.
pub struct ArenaPool;

impl ArenaPool {
    /// Checkout a buffer of exactly `len` elements. Contents are
    /// unspecified (stale on reuse, zero on a fresh allocation) — the
    /// caller must overwrite every element it reads.
    pub fn checkout(len: usize) -> ArenaBuf {
        Self::checkout_inner(len, false)
    }

    /// Checkout a buffer of `len` elements, all zero.
    pub fn checkout_zeroed(len: usize) -> ArenaBuf {
        Self::checkout_inner(len, true)
    }

    /// Checkout an *empty* buffer (`len == 0`) with capacity for at least
    /// `cap` elements — for single-pass `extend_from_slice` fills. Unlike
    /// [`ArenaPool::checkout`] this never initializes elements, so a fresh
    /// allocation costs only the allocation.
    pub fn checkout_empty(cap: usize) -> ArenaBuf {
        let k = class_of_len(cap);
        if k >= N_CLASSES {
            Self::note_fresh((cap * 4) as u64);
            return ArenaBuf::owned(Vec::with_capacity(cap));
        }
        match Self::pop_shelf(k) {
            Some(mut v) => {
                Self::note_reuse((v.capacity() * 4) as u64);
                v.clear();
                ArenaBuf { vec: v, pooled: true }
            }
            None => {
                let c = 1usize << k;
                Self::note_fresh((c * 4) as u64);
                ArenaBuf { vec: Vec::with_capacity(c), pooled: true }
            }
        }
    }

    fn checkout_inner(len: usize, zero: bool) -> ArenaBuf {
        let k = class_of_len(len);
        if k >= N_CLASSES {
            // beyond the largest tracked class: plain unpooled allocation
            // (graceful fallback, mirrors give_back's bound check)
            Self::note_fresh((len * 4) as u64);
            return ArenaBuf::owned(vec![0.0; len]);
        }
        match Self::pop_shelf(k) {
            Some(mut v) => {
                // count the full capacity, symmetric with the fresh path,
                // so the recycle ratio compares like with like
                Self::note_reuse((v.capacity() * 4) as u64);
                if zero {
                    v.clear();
                    v.resize(len, 0.0);
                } else if v.len() > len {
                    v.truncate(len);
                } else {
                    v.resize(len, 0.0); // only the tail is (re)initialized
                }
                ArenaBuf { vec: v, pooled: true }
            }
            None => {
                let cap = 1usize << k;
                Self::note_fresh((cap * 4) as u64);
                let mut v = Vec::with_capacity(cap);
                v.resize(len, 0.0);
                ArenaBuf { vec: v, pooled: true }
            }
        }
    }

    fn pop_shelf(k: usize) -> Option<Vec<f32>> {
        SHELVES
            .try_with(|s| {
                let mut s = s.borrow_mut();
                let v = s.classes[k].pop();
                if let Some(v) = &v {
                    s.shelved_bytes -= (v.capacity() * 4) as u64;
                }
                v
            })
            .ok()
            .flatten()
    }

    fn note_reuse(bytes: u64) {
        let _ = SHELVES.try_with(|s| {
            let mut s = s.borrow_mut();
            s.stats.reuses += 1;
            s.stats.bytes_recycled += bytes;
        });
        G_REUSES.fetch_add(1, Ordering::Relaxed);
        G_BYTES_RECYCLED.fetch_add(bytes, Ordering::Relaxed);
    }

    fn note_fresh(bytes: u64) {
        let _ = SHELVES.try_with(|s| {
            let mut s = s.borrow_mut();
            s.stats.fresh_allocs += 1;
            s.stats.bytes_allocated += bytes;
        });
        G_FRESH.fetch_add(1, Ordering::Relaxed);
        G_BYTES_ALLOCATED.fetch_add(bytes, Ordering::Relaxed);
    }

    /// Return path (called from `ArenaBuf::drop`). Shelves on the current
    /// thread; silently frees when the shelf or the thread's byte budget is
    /// full, or the thread's TLS is already torn down.
    fn give_back(v: Vec<f32>) {
        let cap = v.capacity();
        if cap < MIN_BUCKET {
            return;
        }
        let k = class_of_cap(cap);
        if k >= N_CLASSES {
            G_SHED.fetch_add(1, Ordering::Relaxed);
            return;
        }
        let cap_bytes = (cap * 4) as u64;
        let kept = SHELVES
            .try_with(|s| {
                let mut s = s.borrow_mut();
                if s.classes[k].len() < SHELF_DEPTH && s.shelved_bytes + cap_bytes <= MAX_SHELF_BYTES {
                    s.classes[k].push(v);
                    s.shelved_bytes += cap_bytes;
                    s.stats.returns += 1;
                    true
                } else {
                    s.stats.shed += 1;
                    false
                }
            })
            .unwrap_or(false);
        if kept {
            G_RETURNS.fetch_add(1, Ordering::Relaxed);
        } else {
            G_SHED.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// This thread's counters (deterministic in tests — unaffected by other
    /// test threads).
    pub fn thread_stats() -> ArenaStats {
        SHELVES.try_with(|s| s.borrow().stats).unwrap_or_default()
    }

    /// Process-wide counters (what `Engine::metrics_snapshot` folds into
    /// the `Recorder`).
    pub fn global_stats() -> ArenaStats {
        ArenaStats {
            fresh_allocs: G_FRESH.load(Ordering::Relaxed),
            reuses: G_REUSES.load(Ordering::Relaxed),
            returns: G_RETURNS.load(Ordering::Relaxed),
            shed: G_SHED.load(Ordering::Relaxed),
            bytes_allocated: G_BYTES_ALLOCATED.load(Ordering::Relaxed),
            bytes_recycled: G_BYTES_RECYCLED.load(Ordering::Relaxed),
        }
    }

    /// Drop every buffer shelved by this thread (tests that want a cold
    /// pool).
    pub fn drain_thread() {
        let _ = SHELVES.try_with(|s| {
            let mut s = s.borrow_mut();
            for c in s.classes.iter_mut() {
                c.clear();
            }
            s.shelved_bytes = 0;
        });
    }
}

/// A checked-out buffer. Dereferences to `Vec<f32>` content; returns its
/// storage to the dropping thread's shelf when it goes out of scope. Also
/// doubles as the crate's universal f32 buffer: [`ArenaBuf::owned`] wraps a
/// plain `Vec` that will be freed normally instead of shelved.
#[derive(Debug)]
pub struct ArenaBuf {
    vec: Vec<f32>,
    pooled: bool,
}

impl ArenaBuf {
    /// Wrap an ordinary `Vec` — freed on drop, never shelved.
    pub fn owned(vec: Vec<f32>) -> ArenaBuf {
        ArenaBuf { vec, pooled: false }
    }

    /// Zero-length detached buffer (placeholder for `mem::replace`).
    pub fn empty() -> ArenaBuf {
        ArenaBuf { vec: Vec::new(), pooled: false }
    }

    /// Pool-checked-out copy of `src`.
    pub fn copy_of(src: &[f32]) -> ArenaBuf {
        let mut b = ArenaPool::checkout(src.len());
        b.vec.copy_from_slice(src);
        b
    }

    pub fn len(&self) -> usize {
        self.vec.len()
    }

    pub fn is_empty(&self) -> bool {
        self.vec.is_empty()
    }

    pub fn is_pooled(&self) -> bool {
        self.pooled
    }

    pub fn as_slice(&self) -> &[f32] {
        &self.vec
    }

    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.vec
    }

    /// Mutable access to the backing `Vec` (for `extend_from_slice` fills
    /// into a [`ArenaPool::checkout_empty`] buffer). Growing beyond the
    /// checked-out capacity works but defeats the recycling discipline.
    pub fn vec_mut(&mut self) -> &mut Vec<f32> {
        &mut self.vec
    }

    /// Detach the raw `Vec` from the pool (it will be freed, not shelved).
    pub fn take(mut self) -> Vec<f32> {
        std::mem::take(&mut self.vec)
    }
}

impl Drop for ArenaBuf {
    fn drop(&mut self) {
        if self.pooled {
            ArenaPool::give_back(std::mem::take(&mut self.vec));
        }
    }
}

impl std::ops::Deref for ArenaBuf {
    type Target = [f32];
    fn deref(&self) -> &[f32] {
        &self.vec
    }
}

impl std::ops::DerefMut for ArenaBuf {
    fn deref_mut(&mut self) -> &mut [f32] {
        &mut self.vec
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checkout_reuses_returned_buffer() {
        // run in a dedicated thread so other tests' shelves don't interfere
        std::thread::spawn(|| {
            ArenaPool::drain_thread();
            let before = ArenaPool::thread_stats();
            let b = ArenaPool::checkout(1000);
            assert_eq!(b.len(), 1000);
            drop(b);
            let b2 = ArenaPool::checkout(900); // same 1024-class
            let mid = ArenaPool::thread_stats();
            assert_eq!(mid.fresh_allocs - before.fresh_allocs, 1);
            assert_eq!(mid.reuses - before.reuses, 1);
            assert_eq!(b2.len(), 900);
        })
        .join()
        .unwrap();
    }

    #[test]
    fn zeroed_checkout_really_zeroes() {
        std::thread::spawn(|| {
            let mut b = ArenaPool::checkout(128);
            b.as_mut_slice().fill(7.0);
            drop(b);
            let z = ArenaPool::checkout_zeroed(128);
            assert!(z.iter().all(|&v| v == 0.0));
        })
        .join()
        .unwrap();
    }

    #[test]
    fn owned_buffers_bypass_the_pool() {
        std::thread::spawn(|| {
            ArenaPool::drain_thread();
            let before = ArenaPool::thread_stats();
            let b = ArenaBuf::owned(vec![1.0; 4096]);
            drop(b);
            let after = ArenaPool::thread_stats();
            assert_eq!(before, after, "owned buffer touched the pool");
        })
        .join()
        .unwrap();
    }

    #[test]
    fn cross_thread_return_lands_on_dropping_thread() {
        let (tx, rx) = std::sync::mpsc::channel::<ArenaBuf>();
        let sender = std::thread::spawn(move || {
            tx.send(ArenaPool::checkout(512)).unwrap();
        });
        let receiver = std::thread::spawn(move || {
            ArenaPool::drain_thread();
            let base = ArenaPool::thread_stats();
            let b = rx.recv().unwrap();
            drop(b); // returns to THIS thread's shelf
            let got = ArenaPool::thread_stats();
            assert_eq!(got.returns - base.returns, 1);
            // and is now reusable here without a fresh allocation
            let _b2 = ArenaPool::checkout(512);
            let got2 = ArenaPool::thread_stats();
            assert_eq!(got2.fresh_allocs, got.fresh_allocs);
            assert_eq!(got2.reuses - got.reuses, 1);
        });
        sender.join().unwrap();
        receiver.join().unwrap();
    }

    #[test]
    fn extend_fill_stays_within_capacity() {
        let mut b = ArenaPool::checkout_empty(300);
        assert_eq!(b.len(), 0);
        let cap = b.vec_mut().capacity();
        assert!(cap >= 300);
        for _ in 0..3 {
            b.vec_mut().extend_from_slice(&[1.0; 100]);
        }
        assert_eq!(b.len(), 300);
        assert_eq!(b.vec_mut().capacity(), cap, "extend reallocated");
    }

    #[test]
    fn take_detaches_from_pool() {
        std::thread::spawn(|| {
            ArenaPool::drain_thread();
            let b = ArenaPool::checkout(256);
            let base = ArenaPool::thread_stats();
            let v = b.take();
            assert_eq!(v.len(), 256);
            drop(v);
            let after = ArenaPool::thread_stats();
            assert_eq!(after.returns, base.returns, "taken Vec was shelved");
        })
        .join()
        .unwrap();
    }

    #[test]
    fn size_classes_round_up() {
        assert_eq!(class_of_len(1), class_of_len(64));
        assert_eq!(class_of_len(65), class_of_len(128));
        assert!(class_of_cap(1 << class_of_len(100)) >= class_of_len(100));
    }
}
