//! Serving metrics: latency distribution + throughput, the two axes every
//! figure in the paper's evaluation reports — plus the generation-level
//! axes the iteration scheduler adds (TTFT, per-token decode latency,
//! tokens/sec, mean batch occupancy) and the activation-arena allocation
//! counters the §Perf pass watches (fresh allocations vs bytes recycled on
//! the host hot path).

use crate::memory::arena::ArenaStats;
use crate::memory::kvcache::KvStats;
use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// Rolling SLO window length: the last N first/continuation tokens vote
/// on whether the engine is meeting its latency targets.
const SLO_WINDOW: usize = 64;
/// Minimum window fill before the pressure signal may fire — a handful of
/// cold-start tokens must not flip the engine into shedding.
const SLO_MIN_SAMPLES: usize = 16;

/// Accumulates batch completions.
#[derive(Clone, Debug)]
pub struct Recorder {
    started: Instant,
    first_completion: Option<Instant>,
    last_completion: Option<Instant>,
    /// Token-emission window, tracked separately from batch completions so
    /// tokens/sec is not diluted by unrelated (non-generation) batches.
    first_token: Option<Instant>,
    last_token: Option<Instant>,
    latencies_us: Vec<u64>,
    /// Time-to-first-token per generation session (submit → first sampled
    /// token, including batch-formation queueing).
    ttft_us: Vec<u64>,
    /// Per-token decode latency (gap between consecutive engine steps of
    /// one session), first token excluded.
    tok_lat_us: Vec<u64>,
    /// TTFT split by shared-prefix cache outcome (both empty with the
    /// prefix cache off — `ttft_us` stays the aggregate either way).
    ttft_hit_us: Vec<u64>,
    ttft_miss_us: Vec<u64>,
    /// Prompt positions actually computed (whole prompts for fresh
    /// prefills, one per prompt-stepping decode row of a prefix hit) —
    /// the work shared-prefix reuse exists to cut.
    prefill_toks: u64,
    /// Admission-time prefix-trie outcomes (folded from the batcher on
    /// every `metrics_snapshot`).
    prefix_hits: u64,
    prefix_misses: u64,
    prefix_entries: usize,
    tokens_done: u64,
    requests_done: u64,
    batches_done: u64,
    /// Speculative decode: verify passes completed (one per session row
    /// per verify batch).
    spec_passes: u64,
    /// Drafted tokens scored by verify passes.
    spec_drafted: u64,
    /// Drafted tokens accepted (matched the true greedy token).
    spec_accepted: u64,
    /// Tokens actually committed to session streams by verify passes
    /// (accepted + the bonus token, minus any cut off by stop/budget).
    spec_emitted: u64,
    /// Requests rejected by the admission gate (`busy` replies).
    shed: u64,
    /// Sessions cancelled mid-generation (client disconnect / explicit
    /// `GenRef::cancel`).
    cancelled: u64,
    /// Sessions admitted with a clamped token budget (graceful
    /// degradation under SLO pressure instead of a `busy` reply).
    degraded: u64,
    /// Cumulative µs decode/verify batches spent waiting behind
    /// in-flight prompt work (prefills or prefill chunks) at dispatch —
    /// the head-of-line blocking chunked prefill exists to bound.
    /// Folded from the engine on every `metrics_snapshot`.
    decode_stall_us: u64,
    /// TTFT SLO target in µs (0 = untracked).
    slo_ttft_us: u64,
    /// Per-token (TPOT) SLO target in µs (0 = untracked).
    slo_tpot_us: u64,
    /// Rolling pass/fail votes of the last [`SLO_WINDOW`] tokens.
    slo_window: VecDeque<bool>,
    /// Monotonic count of SLO-violating tokens (never decays — the
    /// rolling window is what feeds the shed decision).
    slo_violations: u64,
    arena: ArenaStats,
    kvcache: KvStats,
}

impl Default for Recorder {
    fn default() -> Self {
        Self::new()
    }
}

impl Recorder {
    pub fn new() -> Recorder {
        Recorder {
            started: Instant::now(),
            first_completion: None,
            last_completion: None,
            first_token: None,
            last_token: None,
            latencies_us: Vec::new(),
            ttft_us: Vec::new(),
            tok_lat_us: Vec::new(),
            ttft_hit_us: Vec::new(),
            ttft_miss_us: Vec::new(),
            prefill_toks: 0,
            prefix_hits: 0,
            prefix_misses: 0,
            prefix_entries: 0,
            tokens_done: 0,
            requests_done: 0,
            batches_done: 0,
            spec_passes: 0,
            spec_drafted: 0,
            spec_accepted: 0,
            spec_emitted: 0,
            shed: 0,
            cancelled: 0,
            degraded: 0,
            decode_stall_us: 0,
            slo_ttft_us: 0,
            slo_tpot_us: 0,
            slo_window: VecDeque::new(),
            slo_violations: 0,
            arena: ArenaStats::default(),
            kvcache: KvStats::default(),
        }
    }

    /// Set latency SLO targets (zero disables an axis). Every recorded
    /// first/continuation token then votes in the rolling window that
    /// [`Recorder::under_pressure`] reads.
    pub fn set_slo(&mut self, ttft: Duration, tpot: Duration) {
        self.slo_ttft_us = ttft.as_micros() as u64;
        self.slo_tpot_us = tpot.as_micros() as u64;
    }

    /// The admission gate rejected a request with a `busy` reply.
    pub fn record_shed(&mut self) {
        self.shed += 1;
    }

    /// `n` sessions were cancelled mid-generation.
    pub fn record_cancelled(&mut self, n: u64) {
        self.cancelled += n;
    }

    /// A session was admitted with its `max_new_tokens` clamped to the
    /// pressure floor instead of being shed.
    pub fn record_degraded(&mut self) {
        self.degraded += 1;
    }

    pub fn shed(&self) -> u64 {
        self.shed
    }

    pub fn cancelled(&self) -> u64 {
        self.cancelled
    }

    pub fn degraded(&self) -> u64 {
        self.degraded
    }

    /// Total SLO-violating tokens observed (monotonic).
    pub fn slo_violations(&self) -> u64 {
        self.slo_violations
    }

    fn note_slo(&mut self, violated: bool) {
        if self.slo_window.len() == SLO_WINDOW {
            self.slo_window.pop_front();
        }
        self.slo_window.push_back(violated);
        if violated {
            self.slo_violations += 1;
        }
    }

    /// True when a majority of the rolling window violates the SLO — the
    /// signal that tightens the batcher's admission cap. Requires targets
    /// to be set and at least [`SLO_MIN_SAMPLES`] recent tokens.
    pub fn under_pressure(&self) -> bool {
        if self.slo_window.len() < SLO_MIN_SAMPLES {
            return false;
        }
        let violated = self.slo_window.iter().filter(|v| **v).count();
        2 * violated > self.slo_window.len()
    }

    /// Fold an arena snapshot into the recorder (the engine does this with
    /// [`crate::memory::arena::ArenaPool::global_stats`] on every
    /// `metrics_snapshot`; tests use per-thread snapshots to assert
    /// allocation-freedom deterministically).
    pub fn record_arena(&mut self, stats: ArenaStats) {
        self.arena = stats;
    }

    /// The last recorded arena allocation counters.
    pub fn arena_stats(&self) -> ArenaStats {
        self.arena
    }

    /// Fold a paged-KV-cache snapshot into the recorder (the engine uses
    /// [`crate::memory::kvcache::global_stats`] on every
    /// `metrics_snapshot`, so operators can watch cache pressure).
    pub fn record_kvcache(&mut self, stats: KvStats) {
        self.kvcache = stats;
    }

    /// The last recorded KV-cache counters.
    pub fn kvcache_stats(&self) -> KvStats {
        self.kvcache
    }

    /// Record a completed batch of unknown size (counts as 1 request).
    pub fn record(&mut self, latency: Duration) {
        self.record_batch(latency, 1);
    }

    pub fn record_batch(&mut self, latency: Duration, n_requests: usize) {
        let now = Instant::now();
        self.first_completion.get_or_insert(now);
        self.last_completion = Some(now);
        self.latencies_us.push(latency.as_micros() as u64);
        self.requests_done += n_requests.max(1) as u64;
        self.batches_done += 1;
    }

    /// A generation session's first token completed `ttft` after submit.
    pub fn record_first_token(&mut self, ttft: Duration) {
        self.ttft_us.push(ttft.as_micros() as u64);
        if self.slo_ttft_us > 0 {
            self.note_slo(ttft.as_micros() as u64 > self.slo_ttft_us);
        }
        self.count_token();
    }

    /// [`Recorder::record_first_token`] plus the shared-prefix outcome
    /// tag, so TTFT percentiles can be split by cache hit vs miss (the
    /// aggregate `ttft_us` series records the token either way).
    pub fn record_first_token_prefix(&mut self, ttft: Duration, prefix_hit: bool) {
        if prefix_hit {
            self.ttft_hit_us.push(ttft.as_micros() as u64);
        } else {
            self.ttft_miss_us.push(ttft.as_micros() as u64);
        }
        self.record_first_token(ttft);
    }

    /// `n` prompt positions were computed by completed engine steps.
    pub fn record_prefill_tokens(&mut self, n: u64) {
        self.prefill_toks += n;
    }

    /// Prompt positions actually computed so far (fresh prefills count
    /// their whole prompt; a prefix hit counts only its unmatched
    /// suffix, one position per stepping decode).
    pub fn prefill_tokens(&self) -> u64 {
        self.prefill_toks
    }

    /// Fold the admission-time prefix-trie counters in (the engine does
    /// this from the batcher on every `metrics_snapshot`).
    pub fn record_prefix_index(&mut self, hits: u64, misses: u64, entries: usize) {
        self.prefix_hits = hits;
        self.prefix_misses = misses;
        self.prefix_entries = entries;
    }

    /// Admission-time (hits, misses) of the shared-prefix trie.
    pub fn prefix_hit_counts(&self) -> (u64, u64) {
        (self.prefix_hits, self.prefix_misses)
    }

    /// Fraction of admitted prompts that matched a cached prefix.
    pub fn prefix_hit_rate(&self) -> Option<f64> {
        let total = self.prefix_hits + self.prefix_misses;
        (total > 0).then(|| self.prefix_hits as f64 / total as f64)
    }

    /// TTFT percentile over sessions that adopted a cached prefix.
    pub fn ttft_hit_percentile(&self, p: f64) -> Option<Duration> {
        Self::pct_of(&self.ttft_hit_us, p)
    }

    /// TTFT percentile over sessions that ran a full prefill.
    pub fn ttft_miss_percentile(&self, p: f64) -> Option<Duration> {
        Self::pct_of(&self.ttft_miss_us, p)
    }

    /// Back-off hint stamped into `busy` rejections: roughly how long a
    /// queue slot takes to open, read off the median observed TTFT (the
    /// submit→first-token time already includes queueing). Doubled while
    /// the rolling SLO window says the engine is shedding. Falls back to
    /// a 50 ms guess before any session has finished its first token.
    pub fn retry_after_hint_ms(&self) -> u64 {
        let base = Self::pct_of(&self.ttft_us, 0.50)
            .map(|d| d.as_millis() as u64)
            .unwrap_or(50)
            .clamp(10, 5_000);
        if self.under_pressure() {
            base * 2
        } else {
            base
        }
    }

    /// A generation session produced a continuation token `gap` after its
    /// previous one.
    pub fn record_decode_token(&mut self, gap: Duration) {
        self.tok_lat_us.push(gap.as_micros() as u64);
        if self.slo_tpot_us > 0 {
            self.note_slo(gap.as_micros() as u64 > self.slo_tpot_us);
        }
        self.count_token();
    }

    fn count_token(&mut self) {
        let now = Instant::now();
        self.first_token.get_or_insert(now);
        self.last_token = Some(now);
        self.tokens_done += 1;
    }

    /// One verify pass of a session row completed: it scored `drafted`
    /// proposed tokens, `accepted` of them matched the true greedy
    /// continuation, and `emitted` tokens were committed to the stream
    /// (`accepted + 1` unless the stop token / budget cut it short).
    pub fn record_spec(&mut self, drafted: u64, accepted: u64, emitted: u64) {
        self.spec_passes += 1;
        self.spec_drafted += drafted;
        self.spec_accepted += accepted;
        self.spec_emitted += emitted;
    }

    pub fn spec_passes(&self) -> u64 {
        self.spec_passes
    }

    /// Fraction of drafted tokens accepted by verify passes.
    pub fn spec_accept_rate(&self) -> Option<f64> {
        (self.spec_drafted > 0).then(|| self.spec_accepted as f64 / self.spec_drafted as f64)
    }

    /// Mean tokens committed per verify pass (> 1 is the speculative win;
    /// 1.0 is the plain-decode degenerate case).
    pub fn spec_tokens_per_pass(&self) -> Option<f64> {
        (self.spec_passes > 0).then(|| self.spec_emitted as f64 / self.spec_passes as f64)
    }

    pub fn batches(&self) -> u64 {
        self.batches_done
    }

    pub fn requests(&self) -> u64 {
        self.requests_done
    }

    /// Generated tokens streamed through the session lifecycle.
    pub fn tokens(&self) -> u64 {
        self.tokens_done
    }

    /// Mean requests per dispatched batch — >1 means the scheduler is
    /// coalescing concurrent work into shared buckets.
    pub fn mean_occupancy(&self) -> f64 {
        if self.batches_done == 0 {
            0.0
        } else {
            self.requests_done as f64 / self.batches_done as f64
        }
    }

    fn pct_of(xs: &[u64], p: f64) -> Option<Duration> {
        if xs.is_empty() {
            return None;
        }
        let mut xs = xs.to_vec();
        xs.sort_unstable();
        let idx = ((xs.len() as f64 - 1.0) * p).round() as usize;
        Some(Duration::from_micros(xs[idx]))
    }

    fn percentile(&self, p: f64) -> Option<Duration> {
        Self::pct_of(&self.latencies_us, p)
    }

    /// Time-to-first-token percentile across finished/streaming sessions.
    pub fn ttft_percentile(&self, p: f64) -> Option<Duration> {
        Self::pct_of(&self.ttft_us, p)
    }

    /// Per-token decode latency percentile.
    pub fn token_percentile(&self, p: f64) -> Option<Duration> {
        Self::pct_of(&self.tok_lat_us, p)
    }

    /// Worst observed per-token decode latency — the TPOT spike bounded
    /// by chunked prefill.
    pub fn token_max(&self) -> Option<Duration> {
        self.tok_lat_us.iter().max().map(|&us| Duration::from_micros(us))
    }

    /// Fold the engine's cumulative decode-stall counter in (the engine
    /// does this on every `metrics_snapshot` from its dispatcher-side
    /// atomic).
    pub fn record_decode_stall(&mut self, us: u64) {
        self.decode_stall_us = us;
    }

    /// Cumulative time decode/verify batches waited behind in-flight
    /// prompt work at dispatch.
    pub fn decode_stall(&self) -> Duration {
        Duration::from_micros(self.decode_stall_us)
    }

    pub fn p50(&self) -> Option<Duration> {
        self.percentile(0.50)
    }

    pub fn p95(&self) -> Option<Duration> {
        self.percentile(0.95)
    }

    pub fn p99(&self) -> Option<Duration> {
        self.percentile(0.99)
    }

    pub fn mean(&self) -> Option<Duration> {
        if self.latencies_us.is_empty() {
            return None;
        }
        let sum: u64 = self.latencies_us.iter().sum();
        Some(Duration::from_micros(sum / self.latencies_us.len() as u64))
    }

    /// Requests per second over the completion window.
    pub fn throughput_rps(&self) -> f64 {
        match (self.first_completion, self.last_completion) {
            (Some(a), Some(b)) if b > a => {
                (self.requests_done as f64 - 1.0).max(1.0) / (b - a).as_secs_f64()
            }
            _ => 0.0,
        }
    }

    /// Generated tokens per second over the token-emission window (not the
    /// batch-completion window, which may include non-generation batches).
    pub fn tokens_per_sec(&self) -> f64 {
        match (self.first_token, self.last_token) {
            (Some(a), Some(b)) if b > a && self.tokens_done > 0 => {
                (self.tokens_done as f64 - 1.0).max(1.0) / (b - a).as_secs_f64()
            }
            _ => 0.0,
        }
    }

    pub fn elapsed(&self) -> Duration {
        self.started.elapsed()
    }

    pub fn summary(&self) -> String {
        let mut s = format!(
            "{} batches / {} requests; mean {} p50 {} p99 {}; {:.1} req/s",
            self.batches_done,
            self.requests_done,
            fmt_opt(self.mean()),
            fmt_opt(self.p50()),
            fmt_opt(self.p99()),
            self.throughput_rps(),
        );
        if self.tokens_done > 0 {
            s.push_str(&format!(
                "; gen {} toks {:.1} tok/s occupancy {:.2}; ttft p50 {} p99 {}; \
                 tok p50 {} p99 {} p99.9 {} max {}",
                self.tokens_done,
                self.tokens_per_sec(),
                self.mean_occupancy(),
                fmt_opt(self.ttft_percentile(0.50)),
                fmt_opt(self.ttft_percentile(0.99)),
                fmt_opt(self.token_percentile(0.50)),
                fmt_opt(self.token_percentile(0.99)),
                fmt_opt(self.token_percentile(0.999)),
                fmt_opt(self.token_max()),
            ));
        }
        if self.decode_stall_us > 0 {
            s.push_str(&format!(
                "; decode stall {}ms behind prompt work",
                self.decode_stall_us / 1000,
            ));
        }
        if self.spec_passes > 0 {
            s.push_str(&format!(
                "; spec {} passes {:.2} tok/pass accept {:.0}% ({}/{} drafts)",
                self.spec_passes,
                self.spec_tokens_per_pass().unwrap_or(0.0),
                self.spec_accept_rate().unwrap_or(0.0) * 100.0,
                self.spec_accepted,
                self.spec_drafted,
            ));
        }
        if self.arena != ArenaStats::default() {
            s.push_str(&format!(
                "; arena {} fresh / {} reused ({} recycled)",
                self.arena.fresh_allocs,
                self.arena.reuses,
                crate::util::fmt_bytes(self.arena.bytes_recycled),
            ));
        }
        if self.kvcache != KvStats::default() {
            s.push_str(&format!(
                "; kvcache {} blocks in use (peak {}, {} recycled, {} slab)",
                self.kvcache.blocks_in_use,
                self.kvcache.blocks_peak,
                self.kvcache.blocks_recycled,
                crate::util::fmt_bytes(self.kvcache.slab_bytes),
            ));
        }
        if self.kvcache.spills + self.kvcache.prefetches > 0 {
            s.push_str(&format!(
                "; kvspill {} out / {} in ({} spilled, {} held, stall {}ms)",
                self.kvcache.spills,
                self.kvcache.prefetches,
                crate::util::fmt_bytes(self.kvcache.spill_bytes),
                crate::util::fmt_bytes(self.kvcache.host_bytes),
                self.kvcache.prefetch_stall_us / 1000,
            ));
        }
        if self.kvcache.parks + self.kvcache.fetches + self.kvcache.demotes > 0 {
            s.push_str(&format!(
                "; kvpeer {} parked / {} fetched ({} held, {} demoted)",
                self.kvcache.parks,
                self.kvcache.fetches,
                crate::util::fmt_bytes(self.kvcache.peer_bytes),
                self.kvcache.demotes,
            ));
        }
        if self.prefix_hits + self.prefix_misses > 0 || self.kvcache.prefix_adopts > 0 {
            s.push_str(&format!(
                "; prefix {} hits / {} misses ({} cached, {} blocks adopted, {} cow)",
                self.prefix_hits,
                self.prefix_misses,
                self.prefix_entries,
                self.kvcache.adopted_blocks,
                self.kvcache.cow_copies,
            ));
            if self.kvcache.spill_denied_shared > 0 {
                // the engine-side exemption should keep shared sessions
                // off every spill list; the worker refusing one is the
                // backstop firing — loud, CI greps for this marker
                s.push_str(&format!(
                    "; PREFIX-ANOMALY {} shared-block spills denied",
                    self.kvcache.spill_denied_shared,
                ));
            }
            if !self.ttft_hit_us.is_empty() {
                s.push_str(&format!(
                    "; ttft hit p50 {} / miss p50 {}",
                    fmt_opt(self.ttft_hit_percentile(0.50)),
                    fmt_opt(self.ttft_miss_percentile(0.50)),
                ));
            }
        }
        if self.prefill_toks > 0 {
            s.push_str(&format!("; prefill {} toks", self.prefill_toks));
        }
        if self.kvcache.gather_spilled + self.kvcache.overflow_blocks > 0 {
            s.push_str(&format!(
                "; KVSPILL-ANOMALY {} spilled gathers, {} overflow blocks",
                self.kvcache.gather_spilled, self.kvcache.overflow_blocks,
            ));
        }
        if self.kvcache.double_free > 0 {
            // cancellation/watchdog release races: always loud, CI greps
            // for this marker
            s.push_str(&format!(
                "; KVFREE-ANOMALY {} double frees",
                self.kvcache.double_free,
            ));
        }
        if self.shed + self.cancelled + self.degraded > 0 {
            s.push_str(&format!("; shed {} cancelled {}", self.shed, self.cancelled));
            if self.degraded > 0 {
                s.push_str(&format!(" degraded {}", self.degraded));
            }
        }
        if self.slo_ttft_us > 0 || self.slo_tpot_us > 0 {
            let hot = self.slo_window.iter().filter(|v| **v).count();
            s.push_str(&format!(
                "; slo {} violations (window {}/{}{})",
                self.slo_violations,
                hot,
                self.slo_window.len(),
                if self.under_pressure() { ", shedding" } else { "" },
            ));
        }
        s
    }
}

fn fmt_opt(d: Option<Duration>) -> String {
    d.map(crate::util::fmt_duration).unwrap_or_else(|| "-".into())
}

/// One replica's health and load as seen by the fleet router's probe
/// loop — a point-in-time snapshot, not an accumulator.
#[derive(Clone, Debug)]
pub struct ReplicaSnapshot {
    pub id: usize,
    /// `"healthy"`, `"draining"`, or `"dead"`.
    pub state: &'static str,
    /// Live sessions held by the replica's engine.
    pub sessions: usize,
    /// Prefill requests waiting in the replica's admission queue.
    pub queued_prefills: usize,
    /// The replica's rolling SLO window votes "shedding".
    pub under_pressure: bool,
    /// Collector liveness ticks (worker replies processed so far); a
    /// stalled counter with work pending marks a wedged pipeline.
    pub collector_ticks: u64,
    /// Sessions the router has placed here over the fleet's lifetime.
    pub placed: u64,
    /// (device, host) K/V blocks in use in the replica's tier model
    /// (zeros without the spill tier).
    pub device_blocks: usize,
    pub host_blocks: usize,
    /// The replica Recorder's one-line summary (empty once dead).
    pub summary: String,
}

/// Fleet-wide rollup assembled by `coordinator::fleet::Fleet::stats`:
/// per-replica snapshots plus the router's own failure-verb counters.
#[derive(Clone, Debug, Default)]
pub struct FleetRollup {
    pub replicas: Vec<ReplicaSnapshot>,
    /// Sessions placed across all replicas.
    pub placed: u64,
    /// Sessions transparently replayed on a survivor.
    pub failovers: u64,
    /// Per-failover latency samples (error detected → replacement
    /// stream admitted), in µs.
    pub failover_us: Vec<u64>,
    pub kills: u64,
    pub drains: u64,
}

impl FleetRollup {
    pub fn healthy(&self) -> usize {
        self.replicas.iter().filter(|r| r.state == "healthy").count()
    }

    /// Nearest-rank percentile over the failover latency samples.
    pub fn failover_percentile(&self, p: f64) -> Option<Duration> {
        if self.failover_us.is_empty() {
            return None;
        }
        let mut xs = self.failover_us.clone();
        xs.sort_unstable();
        let rank = (p * xs.len() as f64).ceil() as usize;
        Some(Duration::from_micros(xs[rank.clamp(1, xs.len()) - 1]))
    }

    /// One aggregated line for the TCP `stats`/`fleet` verbs.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "fleet {} replicas ({} healthy); placed {}",
            self.replicas.len(),
            self.healthy(),
            self.placed,
        );
        if self.failovers > 0 {
            s.push_str(&format!(
                "; failovers {} (p50 {} p99 {})",
                self.failovers,
                fmt_opt(self.failover_percentile(0.50)),
                fmt_opt(self.failover_percentile(0.99)),
            ));
        }
        if self.kills + self.drains > 0 {
            s.push_str(&format!("; kills {} drains {}", self.kills, self.drains));
        }
        s
    }

    /// One line with a per-replica segment each — the `fleet` verb's
    /// detailed form (still newline-free: the TCP protocol is
    /// line-oriented).
    pub fn detail(&self) -> String {
        let mut s = self.summary();
        for r in &self.replicas {
            s.push_str(&format!(
                " | r{} {}: {} sessions, {} queued, {} placed, ticks {}{}",
                r.id,
                r.state,
                r.sessions,
                r.queued_prefills,
                r.placed,
                r.collector_ticks,
                if r.under_pressure { ", pressure" } else { "" },
            ));
            if r.device_blocks + r.host_blocks > 0 {
                s.push_str(&format!(
                    ", tiers {}d/{}h",
                    r.device_blocks, r.host_blocks
                ));
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let mut r = Recorder::new();
        for ms in [1u64, 2, 3, 4, 5, 6, 7, 8, 9, 100] {
            r.record(Duration::from_millis(ms));
        }
        assert!(r.p50().unwrap() <= r.p95().unwrap());
        assert!(r.p95().unwrap() <= r.p99().unwrap());
        assert_eq!(r.p99().unwrap(), Duration::from_millis(100));
        assert_eq!(r.batches(), 10);
    }

    #[test]
    fn empty_recorder_is_safe() {
        let r = Recorder::new();
        assert!(r.p50().is_none());
        assert_eq!(r.throughput_rps(), 0.0);
        assert!(r.summary().contains("0 batches"));
    }

    #[test]
    fn batch_sizes_counted() {
        let mut r = Recorder::new();
        r.record_batch(Duration::from_millis(5), 8);
        r.record_batch(Duration::from_millis(5), 8);
        assert_eq!(r.requests(), 16);
        assert_eq!(r.batches(), 2);
    }

    #[test]
    fn generation_axes_recorded() {
        let mut r = Recorder::new();
        assert_eq!(r.tokens(), 0);
        assert!(r.ttft_percentile(0.5).is_none());
        assert!(r.token_percentile(0.5).is_none());
        assert!(!r.summary().contains("ttft"));
        r.record_first_token(Duration::from_millis(8));
        for ms in [2u64, 3, 4] {
            r.record_decode_token(Duration::from_millis(ms));
        }
        assert_eq!(r.tokens(), 4);
        assert_eq!(r.ttft_percentile(0.5).unwrap(), Duration::from_millis(8));
        assert_eq!(r.token_percentile(0.5).unwrap(), Duration::from_millis(3));
        assert!(r.token_percentile(0.5).unwrap() <= r.token_percentile(0.99).unwrap());
        assert!(r.token_percentile(0.99).unwrap() <= r.token_percentile(0.999).unwrap());
        assert_eq!(r.token_max().unwrap(), Duration::from_millis(4));
        let s = r.summary();
        assert!(s.contains("ttft p50"), "{s}");
        assert!(s.contains("tok p50"), "{s}");
        assert!(s.contains("p99.9"), "{s}");
        assert!(s.contains("max 4ms"), "{s}");
    }

    #[test]
    fn tpot_tail_and_decode_stall_surface() {
        let mut r = Recorder::new();
        assert!(r.token_max().is_none());
        assert_eq!(r.decode_stall(), Duration::ZERO);
        assert!(!r.summary().contains("decode stall"), "{}", r.summary());
        // a tail spike dominates max and p99.9 but not the median
        for _ in 0..99 {
            r.record_decode_token(Duration::from_millis(2));
        }
        r.record_decode_token(Duration::from_millis(80));
        assert_eq!(r.token_percentile(0.50).unwrap(), Duration::from_millis(2));
        assert_eq!(r.token_percentile(0.999).unwrap(), Duration::from_millis(80));
        assert_eq!(r.token_max().unwrap(), Duration::from_millis(80));
        // the stall fold is set-style: the engine hands over its
        // cumulative atomic, a re-fold overwrites rather than adds
        r.record_decode_stall(4_200);
        r.record_decode_stall(5_000);
        assert_eq!(r.decode_stall(), Duration::from_micros(5_000));
        let s = r.summary();
        assert!(s.contains("max 80ms"), "{s}");
        assert!(s.contains("decode stall 5ms behind prompt work"), "{s}");
    }

    #[test]
    fn spec_axes_recorded() {
        let mut r = Recorder::new();
        assert_eq!(r.spec_passes(), 0);
        assert!(r.spec_accept_rate().is_none());
        assert!(r.spec_tokens_per_pass().is_none());
        assert!(!r.summary().contains("spec"), "{}", r.summary());
        // 3 drafts, 2 accepted, 3 emitted; then a worst-case pass
        r.record_spec(3, 2, 3);
        r.record_spec(3, 0, 1);
        assert_eq!(r.spec_passes(), 2);
        assert!((r.spec_accept_rate().unwrap() - 2.0 / 6.0).abs() < 1e-9);
        assert!((r.spec_tokens_per_pass().unwrap() - 2.0).abs() < 1e-9);
        let s = r.summary();
        assert!(s.contains("spec 2 passes"), "{s}");
        assert!(s.contains("2.00 tok/pass"), "{s}");
    }

    #[test]
    fn occupancy_is_requests_over_batches() {
        let mut r = Recorder::new();
        assert_eq!(r.mean_occupancy(), 0.0);
        r.record_batch(Duration::from_millis(1), 4);
        r.record_batch(Duration::from_millis(1), 2);
        assert!((r.mean_occupancy() - 3.0).abs() < 1e-9);
    }

    #[test]
    fn kvcache_counters_surface_in_summary() {
        let mut r = Recorder::new();
        assert!(!r.summary().contains("kvcache"));
        r.record_kvcache(KvStats {
            blocks_in_use: 12,
            blocks_peak: 40,
            blocks_recycled: 99,
            blocks_grown: 41,
            slab_bytes: 64 * 1024,
            sessions: 3,
            ..Default::default()
        });
        assert_eq!(r.kvcache_stats().blocks_peak, 40);
        let s = r.summary();
        assert!(s.contains("kvcache 12 blocks in use (peak 40"), "{s}");
        assert!(!s.contains("kvspill"), "no tier traffic -> no spill line: {s}");
    }

    #[test]
    fn kvspill_counters_surface_in_summary() {
        let mut r = Recorder::new();
        r.record_kvcache(KvStats {
            blocks_in_use: 4,
            spills: 7,
            prefetches: 6,
            spill_bytes: 7 * 16 * 1024,
            prefetch_bytes: 6 * 16 * 1024,
            host_bytes: 16 * 1024,
            sessions_spilled: 1,
            prefetch_stall_us: 2500,
            ..Default::default()
        });
        let s = r.summary();
        assert!(s.contains("kvspill 7 out / 6 in"), "{s}");
        assert!(s.contains("stall 2ms"), "{s}");
        assert!(!s.contains("ANOMALY"), "{s}");
        // loud-path counters surface as an anomaly marker
        r.record_kvcache(KvStats { gather_spilled: 1, ..Default::default() });
        assert!(r.summary().contains("KVSPILL-ANOMALY 1 spilled gathers"), "{}", r.summary());
    }

    #[test]
    fn kvpeer_counters_surface_in_summary() {
        let mut r = Recorder::new();
        assert!(!r.summary().contains("kvpeer"), "{}", r.summary());
        r.record_kvcache(KvStats {
            parks: 5,
            fetches: 4,
            park_bytes: 5 * 16 * 1024,
            fetch_bytes: 4 * 16 * 1024,
            peer_bytes: 16 * 1024,
            sessions_parked: 1,
            demotes: 2,
            ..Default::default()
        });
        let s = r.summary();
        assert!(s.contains("kvpeer 5 parked / 4 fetched"), "{s}");
        assert!(s.contains("2 demoted"), "{s}");
    }

    #[test]
    fn shed_and_cancel_counters_surface_in_summary() {
        let mut r = Recorder::new();
        assert!(!r.summary().contains("shed"), "{}", r.summary());
        r.record_shed();
        r.record_shed();
        r.record_cancelled(3);
        assert_eq!((r.shed(), r.cancelled()), (2, 3));
        assert!(r.summary().contains("shed 2 cancelled 3"), "{}", r.summary());
    }

    #[test]
    fn slo_window_feeds_pressure_signal() {
        let mut r = Recorder::new();
        // no targets -> no votes, never under pressure
        r.record_first_token(Duration::from_millis(500));
        assert!(!r.under_pressure());
        assert_eq!(r.slo_violations(), 0);
        assert!(!r.summary().contains("slo"), "{}", r.summary());
        r.set_slo(Duration::from_millis(10), Duration::from_millis(5));
        // below-target tokens never trip the signal
        for _ in 0..SLO_MIN_SAMPLES {
            r.record_decode_token(Duration::from_millis(1));
        }
        assert!(!r.under_pressure());
        // a majority of violating tokens does — and the counter sticks
        for _ in 0..SLO_WINDOW {
            r.record_first_token(Duration::from_millis(50));
        }
        assert!(r.under_pressure());
        assert_eq!(r.slo_violations(), SLO_WINDOW as u64);
        let s = r.summary();
        assert!(s.contains("slo 64 violations"), "{s}");
        assert!(s.contains(", shedding"), "{s}");
        // recovery: a window full of fast tokens clears the pressure bit
        // but not the monotonic total
        for _ in 0..SLO_WINDOW {
            r.record_decode_token(Duration::from_millis(1));
        }
        assert!(!r.under_pressure());
        assert_eq!(r.slo_violations(), SLO_WINDOW as u64);
        assert!(!r.summary().contains(", shedding"), "{}", r.summary());
    }

    #[test]
    fn pressure_needs_minimum_samples() {
        let mut r = Recorder::new();
        r.set_slo(Duration::from_millis(10), Duration::ZERO);
        for _ in 0..SLO_MIN_SAMPLES - 1 {
            r.record_first_token(Duration::from_millis(50));
        }
        assert!(!r.under_pressure(), "too few samples to judge");
        r.record_first_token(Duration::from_millis(50));
        assert!(r.under_pressure());
        // tpot target is off (ZERO): decode tokens do not vote
        for _ in 0..SLO_WINDOW {
            r.record_decode_token(Duration::from_millis(500));
        }
        assert_eq!(r.slo_violations(), SLO_MIN_SAMPLES as u64);
    }

    #[test]
    fn double_free_surfaces_as_anomaly() {
        let mut r = Recorder::new();
        assert!(!r.summary().contains("KVFREE"), "{}", r.summary());
        r.record_kvcache(KvStats { double_free: 2, ..Default::default() });
        assert!(r.summary().contains("KVFREE-ANOMALY 2 double frees"), "{}", r.summary());
    }

    #[test]
    fn prefix_axes_recorded_and_surface_in_summary() {
        let mut r = Recorder::new();
        assert!(!r.summary().contains("prefix"), "{}", r.summary());
        assert!(r.prefix_hit_rate().is_none());
        r.record_prefix_index(3, 1, 2);
        assert_eq!(r.prefix_hit_counts(), (3, 1));
        assert!((r.prefix_hit_rate().unwrap() - 0.75).abs() < 1e-9);
        r.record_kvcache(KvStats { prefix_adopts: 3, adopted_blocks: 9, cow_copies: 1, ..Default::default() });
        r.record_first_token_prefix(Duration::from_millis(2), true);
        r.record_first_token_prefix(Duration::from_millis(20), false);
        r.record_prefill_tokens(17);
        assert_eq!(r.prefill_tokens(), 17);
        // the aggregate series sees both first tokens; the split keeps
        // them apart
        assert_eq!(r.ttft_percentile(0.99).unwrap(), Duration::from_millis(20));
        assert_eq!(r.ttft_hit_percentile(0.50).unwrap(), Duration::from_millis(2));
        assert_eq!(r.ttft_miss_percentile(0.50).unwrap(), Duration::from_millis(20));
        let s = r.summary();
        assert!(s.contains("prefix 3 hits / 1 misses (2 cached, 9 blocks adopted, 1 cow)"), "{s}");
        assert!(s.contains("ttft hit p50"), "{s}");
        assert!(s.contains("prefill 17 toks"), "{s}");
        assert!(!s.contains("PREFIX-ANOMALY"), "{s}");
        // a worker-side spill refusal of a shared block is loud
        r.record_kvcache(KvStats { prefix_adopts: 3, spill_denied_shared: 2, ..Default::default() });
        assert!(r.summary().contains("PREFIX-ANOMALY 2 shared-block spills denied"), "{}", r.summary());
    }

    #[test]
    fn retry_hint_tracks_observed_ttft_and_pressure() {
        let mut r = Recorder::new();
        // no data yet: a default guess, inside the clamp
        assert_eq!(r.retry_after_hint_ms(), 50);
        r.record_first_token(Duration::from_millis(120));
        assert_eq!(r.retry_after_hint_ms(), 120);
        // sub-clamp medians round up to the floor
        let mut fast = Recorder::new();
        for _ in 0..4 {
            fast.record_first_token(Duration::from_millis(1));
        }
        assert_eq!(fast.retry_after_hint_ms(), 10);
        // sustained SLO violation doubles the hint
        let mut hot = Recorder::new();
        hot.set_slo(Duration::from_millis(10), Duration::ZERO);
        for _ in 0..SLO_WINDOW {
            hot.record_first_token(Duration::from_millis(40));
        }
        assert!(hot.under_pressure());
        assert_eq!(hot.retry_after_hint_ms(), 80);
    }

    #[test]
    fn arena_counters_surface_in_summary() {
        let mut r = Recorder::new();
        assert!(!r.summary().contains("arena"));
        r.record_arena(ArenaStats {
            fresh_allocs: 2,
            reuses: 98,
            returns: 100,
            shed: 0,
            bytes_allocated: 8192,
            bytes_recycled: 401_408,
        });
        assert_eq!(r.arena_stats().reuses, 98);
        let s = r.summary();
        assert!(s.contains("arena 2 fresh / 98 reused"), "{s}");
    }

    #[test]
    fn degraded_counter_surfaces_in_summary() {
        let mut r = Recorder::new();
        assert!(!r.summary().contains("degraded"), "{}", r.summary());
        r.record_degraded();
        r.record_degraded();
        assert_eq!(r.degraded(), 2);
        // degraded admissions surface even with zero sheds/cancels
        assert!(r.summary().contains("shed 0 cancelled 0 degraded 2"), "{}", r.summary());
    }

    #[test]
    fn fleet_rollup_summary_and_detail() {
        let snap = |id: usize, state: &'static str, sessions: usize| ReplicaSnapshot {
            id,
            state,
            sessions,
            queued_prefills: id,
            under_pressure: false,
            collector_ticks: 10 * id as u64,
            placed: 5,
            device_blocks: if id == 1 { 3 } else { 0 },
            host_blocks: 0,
            summary: String::new(),
        };
        let mut roll = FleetRollup {
            replicas: vec![snap(0, "healthy", 2), snap(1, "healthy", 1), snap(2, "dead", 0)],
            placed: 15,
            failovers: 2,
            failover_us: vec![900, 1_100],
            kills: 1,
            drains: 0,
        };
        assert_eq!(roll.healthy(), 2);
        assert_eq!(roll.failover_percentile(0.50), Some(Duration::from_micros(900)));
        assert_eq!(roll.failover_percentile(0.99), Some(Duration::from_micros(1_100)));
        let s = roll.summary();
        assert!(s.contains("fleet 3 replicas (2 healthy)"), "{s}");
        assert!(s.contains("placed 15"), "{s}");
        assert!(s.contains("failovers 2"), "{s}");
        assert!(s.contains("kills 1 drains 0"), "{s}");
        let d = roll.detail();
        assert!(d.contains("| r0 healthy: 2 sessions"), "{d}");
        assert!(d.contains("| r2 dead: 0 sessions"), "{d}");
        assert!(d.contains("tiers 3d/0h"), "{d}");
        assert!(!d.contains('\n'), "line protocol: {d}");
        // quiet fleet: no failure segments at all
        roll.failovers = 0;
        roll.kills = 0;
        let s = roll.summary();
        assert!(!s.contains("failovers") && !s.contains("kills"), "{s}");
    }
}
