//! Model state on the Rust side: synthetic weights, Megatron 1-D sharding,
//! and argument assembly for the AOT executables.
//!
//! The paper's engine "delegates sub-models to workers [and] loads
//! parameters into memory" during runtime initialization (§4.1.2); this
//! module is that parameter store. Weights are synthetic (seeded,
//! reproducible) since no public checkpoint matches the customized
//! 12/24/48-layer GPT-3 variants the paper benchmarks.

pub mod shard;
pub mod weights;

pub use shard::shard_layer;
pub use weights::{LayerWeights, ModelWeights};

use crate::runtime::VariantMeta;
use crate::tensor::Value;

/// Assemble the argument vector for a variant from (activations, weights).
/// Order must match `python/compile/model.py::variant` exactly — the
/// manifest's input names are cross-checked in debug builds.
pub fn assemble_args(
    variant: &VariantMeta,
    activations: Vec<Value>,
    weights: &[Value],
) -> Vec<Value> {
    let mut args = activations;
    args.extend(weights.iter().cloned());
    debug_assert_eq!(
        args.len(),
        variant.inputs.len(),
        "arg count mismatch for {}",
        variant.name
    );
    args
}
