//! Synthetic model weights: seeded, reproducible, shaped per the config.
//!
//! Weight naming and ordering mirrors `python/compile/model.py`:
//! ATTN_PARAMS = [ln1_g, ln1_b, wqkv, bqkv, wo, bo]
//! MLP_PARAMS  = [ln2_g, ln2_b, w1, b1, w2, b2]

use crate::config::ModelConfig;
use crate::tensor::{Tensor, Value};
use crate::util::rng::Rng;

/// Canonical per-layer parameter names, in executable argument order.
pub const ATTN_PARAMS: [&str; 6] = ["ln1_g", "ln1_b", "wqkv", "bqkv", "wo", "bo"];
pub const MLP_PARAMS: [&str; 6] = ["ln2_g", "ln2_b", "w1", "b1", "w2", "b2"];

/// One transformer layer's full (unsharded) parameters.
#[derive(Clone, Debug)]
pub struct LayerWeights {
    pub ln1_g: Tensor,
    pub ln1_b: Tensor,
    pub wqkv: Tensor, // (H, 3H)
    pub bqkv: Tensor, // (3H,)
    pub wo: Tensor,   // (H, H)
    pub bo: Tensor,   // (H,)
    pub ln2_g: Tensor,
    pub ln2_b: Tensor,
    pub w1: Tensor, // (H, F)
    pub b1: Tensor, // (F,)
    pub w2: Tensor, // (F, H)
    pub b2: Tensor, // (H,)
}

impl LayerWeights {
    /// GPT-2-style init scaled for inference stability on synthetic data.
    pub fn random(cfg: &ModelConfig, rng: &mut Rng) -> LayerWeights {
        let h = cfg.hidden;
        let f = cfg.ffn();
        let std_h = 1.0 / (h as f32).sqrt();
        let std_f = 1.0 / (f as f32).sqrt();
        let near_one = |rng: &mut Rng, n: usize| {
            let mut t = Tensor::randn(&[n], 0.02, rng);
            for v in &mut t.data {
                *v += 1.0;
            }
            t
        };
        LayerWeights {
            ln1_g: near_one(rng, h),
            ln1_b: Tensor::randn(&[h], 0.02, rng),
            wqkv: Tensor::randn(&[h, 3 * h], std_h, rng),
            bqkv: Tensor::randn(&[3 * h], 0.02, rng),
            wo: Tensor::randn(&[h, h], std_h, rng),
            bo: Tensor::randn(&[h], 0.02, rng),
            ln2_g: near_one(rng, h),
            ln2_b: Tensor::randn(&[h], 0.02, rng),
            w1: Tensor::randn(&[h, f], std_h, rng),
            b1: Tensor::randn(&[f], 0.02, rng),
            w2: Tensor::randn(&[f, h], std_f, rng),
            b2: Tensor::randn(&[h], 0.02, rng),
        }
    }

    pub fn by_name(&self, name: &str) -> &Tensor {
        match name {
            "ln1_g" => &self.ln1_g,
            "ln1_b" => &self.ln1_b,
            "wqkv" => &self.wqkv,
            "bqkv" => &self.bqkv,
            "wo" => &self.wo,
            "bo" => &self.bo,
            "ln2_g" => &self.ln2_g,
            "ln2_b" => &self.ln2_b,
            "w1" => &self.w1,
            "b1" => &self.b1,
            "w2" => &self.w2,
            "b2" => &self.b2,
            other => panic!("unknown layer param {other:?}"),
        }
    }

    /// Args in ATTN order (layer_full prepends these before MLP ones).
    pub fn attn_args(&self) -> Vec<Value> {
        ATTN_PARAMS.iter().map(|n| Value::F32(self.by_name(n).clone())).collect()
    }

    pub fn mlp_args(&self) -> Vec<Value> {
        MLP_PARAMS.iter().map(|n| Value::F32(self.by_name(n).clone())).collect()
    }

    pub fn all_args(&self) -> Vec<Value> {
        let mut v = self.attn_args();
        v.extend(self.mlp_args());
        v
    }

    /// Total bytes (f32 host storage).
    pub fn bytes(&self) -> u64 {
        ATTN_PARAMS
            .iter()
            .chain(MLP_PARAMS.iter())
            .map(|n| self.by_name(n).bytes())
            .sum()
    }
}

/// Full model: embeddings + layers + final layernorm (tied LM head).
#[derive(Clone, Debug)]
pub struct ModelWeights {
    pub cfg: ModelConfig,
    pub wte: Tensor, // (V, H)
    pub wpe: Tensor, // (max_seq, H)
    pub lnf_g: Tensor,
    pub lnf_b: Tensor,
    pub layers: Vec<LayerWeights>,
}

impl ModelWeights {
    pub fn random(cfg: &ModelConfig, seed: u64) -> ModelWeights {
        let mut rng = Rng::new(seed);
        let h = cfg.hidden;
        let layers = (0..cfg.n_layers)
            .map(|i| LayerWeights::random(cfg, &mut rng.fork(i as u64)))
            .collect();
        ModelWeights {
            cfg: cfg.clone(),
            wte: Tensor::randn(&[cfg.vocab, h], 0.02, &mut rng),
            wpe: Tensor::randn(&[cfg.max_seq, h], 0.01, &mut rng),
            lnf_g: Tensor::full(&[h], 1.0),
            lnf_b: Tensor::zeros(&[h]),
            layers,
        }
    }

    pub fn embed_args(&self) -> Vec<Value> {
        vec![Value::F32(self.wte.clone()), Value::F32(self.wpe.clone())]
    }

    pub fn logits_args(&self) -> Vec<Value> {
        vec![
            Value::F32(self.lnf_g.clone()),
            Value::F32(self.lnf_b.clone()),
            Value::F32(self.wte.clone()),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ModelConfig {
        ModelConfig::preset("tiny").unwrap()
    }

    #[test]
    fn shapes_match_config() {
        let mut rng = Rng::new(1);
        let lw = LayerWeights::random(&tiny(), &mut rng);
        assert_eq!(lw.wqkv.shape, vec![64, 192]);
        assert_eq!(lw.w1.shape, vec![64, 256]);
        assert_eq!(lw.w2.shape, vec![256, 64]);
        assert_eq!(lw.all_args().len(), 12);
    }

    #[test]
    fn reproducible_by_seed() {
        let a = ModelWeights::random(&tiny(), 7);
        let b = ModelWeights::random(&tiny(), 7);
        assert_eq!(a.layers[0].wqkv, b.layers[0].wqkv);
        assert_eq!(a.wte, b.wte);
        let c = ModelWeights::random(&tiny(), 8);
        assert_ne!(a.layers[0].wqkv, c.layers[0].wqkv);
    }

    #[test]
    fn layers_differ_from_each_other() {
        let m = ModelWeights::random(&tiny(), 7);
        assert_ne!(m.layers[0].wqkv, m.layers[1].wqkv);
    }

    #[test]
    fn bytes_accounting() {
        let m = ModelWeights::random(&tiny(), 1);
        let per_layer = m.layers[0].bytes();
        // tiny: params_per_layer * 4 bytes
        assert_eq!(per_layer, tiny().params_per_layer() * 4);
    }
}
