//! Megatron 1-D tensor-parallel sharding (§4.1.3), mirroring
//! `python/compile/model.py::shard_layer_params` exactly (the pytest suite
//! checks the python side; `rust/tests/tp_parity.rs` checks that sharded
//! execution reassembles to the full layer through real artifacts).
//!
//! Rules:
//! * `wqkv` (H, 3H) is column-split **by head groups** within each of the
//!   q|k|v blocks so every shard computes whole heads.
//! * `wo` (H, H) and `w2` (F, H) are row-split.
//! * Biases of row-split linears (`bo`, `b2`) are pre-divided by tp so the
//!   all-reduce reconstructs them exactly once.
//! * Layernorm params are replicated.

use super::weights::LayerWeights;
use crate::config::ModelConfig;

/// Shard one layer's weights for (tp, rank).
pub fn shard_layer(cfg: &ModelConfig, full: &LayerWeights, tp: usize, rank: usize) -> LayerWeights {
    assert!(rank < tp, "rank {rank} out of range for tp {tp}");
    assert_eq!(cfg.n_heads % tp, 0, "heads {} not divisible by tp {tp}", cfg.n_heads);
    if tp == 1 {
        return full.clone();
    }
    let h = cfg.hidden;
    let f = cfg.ffn();
    let hd = cfg.head_dim();
    let heads_local = cfg.n_heads / tp;
    let hsl = (rank * heads_local * hd, (rank + 1) * heads_local * hd);

    // wqkv: columns [q | k | v], each (H, H); take our head block of each.
    let wq = full.wqkv.slice_cols(hsl.0, hsl.1);
    let wk = full.wqkv.slice_cols(h + hsl.0, h + hsl.1);
    let wv = full.wqkv.slice_cols(2 * h + hsl.0, 2 * h + hsl.1);
    let local = h / tp;
    let mut wqkv = crate::tensor::Tensor::zeros(&[h, 3 * local]);
    for r in 0..h {
        wqkv.row_mut(r)[0..local].copy_from_slice(wq.row(r));
        wqkv.row_mut(r)[local..2 * local].copy_from_slice(wk.row(r));
        wqkv.row_mut(r)[2 * local..3 * local].copy_from_slice(wv.row(r));
    }
    let mut bqkv = Vec::with_capacity(3 * local);
    bqkv.extend_from_slice(&full.bqkv.data[hsl.0..hsl.1]);
    bqkv.extend_from_slice(&full.bqkv.data[h + hsl.0..h + hsl.1]);
    bqkv.extend_from_slice(&full.bqkv.data[2 * h + hsl.0..2 * h + hsl.1]);

    let fsl = (rank * f / tp, (rank + 1) * f / tp);
    LayerWeights {
        ln1_g: full.ln1_g.clone(),
        ln1_b: full.ln1_b.clone(),
        wqkv,
        bqkv: crate::tensor::Tensor::new(&[3 * local], bqkv),
        wo: full.wo.slice_rows(hsl.0, hsl.1),
        bo: full.bo.scale(1.0 / tp as f32),
        ln2_g: full.ln2_g.clone(),
        ln2_b: full.ln2_b.clone(),
        w1: full.w1.slice_cols(fsl.0, fsl.1),
        b1: full.b1.slice_rows_1d(fsl.0, fsl.1),
        w2: full.w2.slice_rows(fsl.0, fsl.1),
        b2: full.b2.scale(1.0 / tp as f32),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::weights::ModelWeights;
    use crate::tensor::Tensor;

    fn setup() -> (ModelConfig, LayerWeights) {
        let cfg = ModelConfig::preset("tiny").unwrap();
        let m = ModelWeights::random(&cfg, 3);
        (cfg, m.layers[0].clone())
    }

    #[test]
    fn tp1_is_identity() {
        let (cfg, lw) = setup();
        let s = shard_layer(&cfg, &lw, 1, 0);
        assert_eq!(s.wqkv, lw.wqkv);
    }

    #[test]
    fn shapes_shrink_by_tp() {
        let (cfg, lw) = setup();
        let s = shard_layer(&cfg, &lw, 2, 0);
        assert_eq!(s.wqkv.shape, vec![64, 96]);
        assert_eq!(s.wo.shape, vec![32, 64]);
        assert_eq!(s.w1.shape, vec![64, 128]);
        assert_eq!(s.w2.shape, vec![128, 64]);
        assert_eq!(s.b1.shape, vec![128]);
        // replicated params keep full size
        assert_eq!(s.ln1_g.shape, vec![64]);
        assert_eq!(s.bo.shape, vec![64]);
    }

    #[test]
    fn row_biases_sum_to_full() {
        let (cfg, lw) = setup();
        let s0 = shard_layer(&cfg, &lw, 2, 0);
        let s1 = shard_layer(&cfg, &lw, 2, 1);
        let bo_sum = s0.bo.add(&s1.bo);
        assert!(bo_sum.max_abs_diff(&lw.bo) < 1e-6);
        let b2_sum = s0.b2.add(&s1.b2);
        assert!(b2_sum.max_abs_diff(&lw.b2) < 1e-6);
    }

    #[test]
    fn qkv_split_is_by_head_groups() {
        let (cfg, lw) = setup();
        // tiny: 2 heads, head_dim 32; tp=2 -> each shard gets 1 head
        let s0 = shard_layer(&cfg, &lw, 2, 0);
        let s1 = shard_layer(&cfg, &lw, 2, 1);
        // shard0's q block = full q columns 0..32
        let full_q = lw.wqkv.slice_cols(0, 32);
        let s0_q = s0.wqkv.slice_cols(0, 32);
        assert_eq!(s0_q, full_q);
        // shard1's k block = full k columns (h + 32..h + 64) = (96..128)
        let full_k1 = lw.wqkv.slice_cols(96, 128);
        let s1_k = s1.wqkv.slice_cols(32, 64);
        assert_eq!(s1_k, full_k1);
    }

    #[test]
    fn column_shards_tile_w1() {
        let (cfg, lw) = setup();
        let s0 = shard_layer(&cfg, &lw, 2, 0);
        let s1 = shard_layer(&cfg, &lw, 2, 1);
        // re-concatenate w1 columns and compare
        let mut rebuilt = Tensor::zeros(&[64, 256]);
        for r in 0..64 {
            rebuilt.row_mut(r)[0..128].copy_from_slice(s0.w1.row(r));
            rebuilt.row_mut(r)[128..256].copy_from_slice(s1.w1.row(r));
        }
        assert_eq!(rebuilt, lw.w1);
    }

    #[test]
    #[should_panic]
    fn rank_out_of_range_panics() {
        let (cfg, lw) = setup();
        shard_layer(&cfg, &lw, 2, 2);
    }
}
