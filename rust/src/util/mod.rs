//! Small self-contained utilities (the build is fully offline, so the crate
//! hand-rolls what would normally come from `rand`, `serde_json` and `clap`).

pub mod bench;
pub mod cli;
pub mod json;
pub mod rng;
pub mod toml;

use std::time::{Duration, Instant};

/// Measure the wall-clock duration of a closure.
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed())
}

/// Median of a slice of durations (destructive sort on a copy).
pub fn median(mut xs: Vec<Duration>) -> Duration {
    assert!(!xs.is_empty());
    xs.sort();
    xs[xs.len() / 2]
}

/// Format a duration as adaptive human units.
pub fn fmt_duration(d: Duration) -> String {
    let us = d.as_secs_f64() * 1e6;
    if us < 1e3 {
        format!("{us:.1}µs")
    } else if us < 1e6 {
        format!("{:.2}ms", us / 1e3)
    } else {
        format!("{:.3}s", us / 1e6)
    }
}

/// Format a byte count as adaptive human units.
pub fn fmt_bytes(b: u64) -> String {
    const K: f64 = 1024.0;
    let b = b as f64;
    if b < K {
        format!("{b:.0}B")
    } else if b < K * K {
        format!("{:.1}KiB", b / K)
    } else if b < K * K * K {
        format!("{:.1}MiB", b / K / K)
    } else {
        format!("{:.2}GiB", b / K / K / K)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_odd() {
        let xs = vec![
            Duration::from_millis(3),
            Duration::from_millis(1),
            Duration::from_millis(2),
        ];
        assert_eq!(median(xs), Duration::from_millis(2));
    }

    #[test]
    fn fmt_units() {
        assert_eq!(fmt_duration(Duration::from_micros(500)), "500.0µs");
        assert_eq!(fmt_duration(Duration::from_millis(12)), "12.00ms");
        assert_eq!(fmt_bytes(512), "512B");
        assert_eq!(fmt_bytes(2048), "2.0KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.0MiB");
    }
}
