//! Minimal JSON parser — just enough to read `artifacts/manifest.json`
//! (written by python/compile/aot.py) and simple config files. Supports the
//! full JSON grammar except `\u` surrogate pairs beyond the BMP.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors -----------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// `obj.str("k")` with a readable error for manifest parsing.
    pub fn str_field(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("missing/str field {key:?}"))
    }

    pub fn usize_field(&self, key: &str) -> anyhow::Result<usize> {
        self.get(key)
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("missing/num field {key:?}"))
    }

    pub fn arr_field(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.get(key)
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("missing/arr field {key:?}"))
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {s}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a run of plain utf-8 bytes
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i]).map_err(|_| self.err("bad utf8"))?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(r#""hi\n""#).unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        let a = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[2].get("b").unwrap().as_str(), Some("c"));
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""é""#).unwrap(), Json::Str("é".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{,}").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse(r#""open"#).is_err());
    }

    #[test]
    fn manifest_shape() {
        let j = Json::parse(
            r#"{"format_version": 1, "variants": [{"name": "x", "inputs": [{"shape": [2, 16], "dtype": "int32"}]}]}"#,
        )
        .unwrap();
        assert_eq!(j.usize_field("format_version").unwrap(), 1);
        let v = &j.arr_field("variants").unwrap()[0];
        assert_eq!(v.str_field("name").unwrap(), "x");
        let shape: Vec<usize> = v.arr_field("inputs").unwrap()[0]
            .arr_field("shape")
            .unwrap()
            .iter()
            .map(|s| s.as_usize().unwrap())
            .collect();
        assert_eq!(shape, vec![2, 16]);
    }
}
