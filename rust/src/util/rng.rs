//! Deterministic PRNG (splitmix64 + xoshiro256**) for synthetic weights,
//! workload generation and property tests. Seeded runs are exactly
//! reproducible across machines, which EXPERIMENTS.md relies on.

/// xoshiro256** with splitmix64 seeding.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent stream (for per-worker / per-layer RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn next_below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        // multiply-shift; bias is negligible for n << 2^64
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(hi >= lo);
        lo + self.next_below(hi - lo + 1)
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// N(0, std^2) as f32 — synthetic weight init.
    pub fn normal_f32(&mut self, std: f32) -> f32 {
        (self.normal() as f32) * std
    }

    /// Exponential with the given rate (Poisson inter-arrivals).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        -self.next_f64().max(1e-12).ln() / rate
    }

    /// Zipf-like heavy-tailed integer in [1, n] (s = skew).
    pub fn zipf(&mut self, n: u64, s: f64) -> u64 {
        // inverse-CDF on the fly; n is small (sequence lengths)
        let norm: f64 = (1..=n).map(|k| 1.0 / (k as f64).powf(s)).sum();
        let mut u = self.next_f64() * norm;
        for k in 1..=n {
            u -= 1.0 / (k as f64).powf(s);
            if u <= 0.0 {
                return k;
            }
        }
        n
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn forks_are_independent() {
        let mut root = Rng::new(7);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(42);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.08, "var={var}");
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let v = r.range(3, 9);
            assert!((3..=9).contains(&v));
        }
    }

    #[test]
    fn zipf_is_heavy_headed() {
        let mut r = Rng::new(11);
        let n = 5000;
        let ones = (0..n).filter(|_| r.zipf(16, 1.2) == 1).count();
        // rank 1 should dominate
        assert!(ones > n / 4, "ones={ones}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>());
    }
}
