//! Micro-benchmark harness (criterion is unavailable in this offline
//! build): warmup + N timed iterations, reporting min/median/mean.

use std::time::{Duration, Instant};

/// Timing summary of one benchmark.
#[derive(Clone, Copy, Debug)]
pub struct Stats {
    pub iters: usize,
    pub min: Duration,
    pub median: Duration,
    pub mean: Duration,
    pub max: Duration,
}

impl Stats {
    pub fn line(&self, name: &str) -> String {
        format!(
            "{name:<44} min {:>10}  med {:>10}  mean {:>10}  (n={})",
            crate::util::fmt_duration(self.min),
            crate::util::fmt_duration(self.median),
            crate::util::fmt_duration(self.mean),
            self.iters
        )
    }
}

/// Run `f` `iters` times (after `warmup` unmeasured runs).
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Stats {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    samples.sort();
    let sum: Duration = samples.iter().sum();
    Stats {
        iters,
        min: samples[0],
        median: samples[samples.len() / 2],
        mean: sum / iters as u32,
        max: *samples.last().unwrap(),
    }
}

/// Convenience: run, print, return.
pub fn run_print<F: FnMut()>(name: &str, warmup: usize, iters: usize, f: F) -> Stats {
    let s = bench(warmup, iters, f);
    println!("{}", s.line(name));
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_ordering() {
        let s = bench(1, 20, || std::thread::sleep(Duration::from_micros(100)));
        assert!(s.min <= s.median && s.median <= s.max);
        assert!(s.min >= Duration::from_micros(100));
        assert_eq!(s.iters, 20);
    }

    #[test]
    fn line_formats() {
        let s = bench(0, 3, || {});
        assert!(s.line("noop").contains("noop"));
    }
}
