//! Tiny argv parser for the launcher (`--key value` / `--flag` / positional
//! subcommands), standing in for `clap` in this offline build.

use std::collections::BTreeMap;

/// Parsed command line: a subcommand, positional args and `--key value` opts.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    out.opts.insert(key.to_string(), it.next().unwrap());
                } else {
                    out.flags.push(key.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} must be an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} must be a number, got {v:?}")))
            .unwrap_or(default)
    }

    /// Comma-separated list of integers, e.g. `--batches 1,4,16,32`.
    pub fn usize_list(&self, name: &str, default: &[usize]) -> Vec<usize> {
        match self.get(name) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .map(|s| s.trim().parse().unwrap_or_else(|_| panic!("--{name}: bad int {s:?}")))
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_opts() {
        let a = args("bench fig10 --tp 4 --verbose --seq=64");
        assert_eq!(a.subcommand.as_deref(), Some("bench"));
        assert_eq!(a.positional, vec!["fig10"]);
        assert_eq!(a.usize("tp", 1), 4);
        assert_eq!(a.usize("seq", 0), 64);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn lists_and_defaults() {
        let a = args("serve --batches 1,4,16");
        assert_eq!(a.usize_list("batches", &[2]), vec![1, 4, 16]);
        assert_eq!(a.usize_list("seqs", &[64, 128]), vec![64, 128]);
        assert_eq!(a.get_or("preset", "tiny"), "tiny");
        assert_eq!(a.f64("rate", 1.5), 1.5);
    }

    #[test]
    fn flag_before_subcommand_value_ambiguity() {
        // `--flag sub` consumes `sub` as a value; callers put flags last.
        let a = args("run --dry");
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert!(a.flag("dry"));
    }
}
