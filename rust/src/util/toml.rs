//! Minimal TOML-subset parser for launcher config files (the offline
//! build has no `toml` crate). Supports what `energonai --config` needs:
//! `[section]` / `[section.sub]` headers, `key = value` with strings,
//! integers, floats, booleans and flat arrays, `#` comments.

use std::collections::BTreeMap;

/// A parsed TOML value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_int().and_then(|i| usize::try_from(i).ok())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Flat document: dotted section path + key → value.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TomlDoc {
    entries: BTreeMap<String, TomlValue>,
}

impl TomlDoc {
    /// Fetch `section.key` (or just `key` for the root table).
    pub fn get(&self, path: &str) -> Option<&TomlValue> {
        self.entries.get(path)
    }

    pub fn str_or<'a>(&'a self, path: &str, default: &'a str) -> &'a str {
        self.get(path).and_then(TomlValue::as_str).unwrap_or(default)
    }

    pub fn usize_or(&self, path: &str, default: usize) -> usize {
        self.get(path).and_then(TomlValue::as_usize).unwrap_or(default)
    }

    pub fn f64_or(&self, path: &str, default: f64) -> f64 {
        self.get(path).and_then(TomlValue::as_f64).unwrap_or(default)
    }

    pub fn bool_or(&self, path: &str, default: bool) -> bool {
        self.get(path).and_then(TomlValue::as_bool).unwrap_or(default)
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(String::as_str)
    }

    pub fn parse(text: &str) -> anyhow::Result<TomlDoc> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| anyhow::anyhow!("line {}: unterminated section", lineno + 1))?
                    .trim();
                anyhow::ensure!(
                    !name.is_empty() && name.chars().all(|c| c.is_alphanumeric() || c == '_' || c == '.' || c == '-'),
                    "line {}: bad section name {name:?}",
                    lineno + 1
                );
                section = name.to_string();
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("line {}: expected key = value", lineno + 1))?;
            let key = key.trim();
            anyhow::ensure!(!key.is_empty(), "line {}: empty key", lineno + 1);
            let path = if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
            let parsed = parse_value(value.trim())
                .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))?;
            doc.entries.insert(path, parsed);
        }
        Ok(doc)
    }

    pub fn load(path: impl AsRef<std::path::Path>) -> anyhow::Result<TomlDoc> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| anyhow::anyhow!("read {:?}: {e}", path.as_ref()))?;
        TomlDoc::parse(&text)
    }
}

fn strip_comment(line: &str) -> &str {
    // naive but sufficient: ignore '#' inside quoted strings
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> anyhow::Result<TomlValue> {
    anyhow::ensure!(!s.is_empty(), "empty value");
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest.strip_suffix('"').ok_or_else(|| anyhow::anyhow!("unterminated string"))?;
        return Ok(TomlValue::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest.strip_suffix(']').ok_or_else(|| anyhow::anyhow!("unterminated array"))?;
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(TomlValue::Array(vec![]));
        }
        let items = inner
            .split(',')
            .map(|p| parse_value(p.trim()))
            .collect::<anyhow::Result<Vec<_>>>()?;
        return Ok(TomlValue::Array(items));
    }
    if let Ok(i) = s.replace('_', "").parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    anyhow::bail!("cannot parse value {s:?}")
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# launcher config
preset = "small"
seed = 7

[parallel]
tp = 2
pp = 2

[engine]
drce = true
batch_timeout_us = 1_500
pool_threads = 8

[memory]
mode = "pmep"
n_local = 10
lookahead = 2
time_scale = 1.5

[workload]
batches = [1, 4, 16, 32]
"#;

    #[test]
    fn parse_sample() {
        let d = TomlDoc::parse(SAMPLE).unwrap();
        assert_eq!(d.str_or("preset", "tiny"), "small");
        assert_eq!(d.usize_or("seed", 0), 7);
        assert_eq!(d.usize_or("parallel.tp", 1), 2);
        assert!(d.bool_or("engine.drce", false));
        assert_eq!(d.usize_or("engine.batch_timeout_us", 0), 1500);
        assert_eq!(d.f64_or("memory.time_scale", 0.0), 1.5);
        let arr = d.get("workload.batches").unwrap();
        match arr {
            TomlValue::Array(a) => assert_eq!(a.len(), 4),
            _ => panic!("expected array"),
        }
    }

    #[test]
    fn defaults_for_missing_keys() {
        let d = TomlDoc::parse("").unwrap();
        assert_eq!(d.usize_or("parallel.tp", 1), 1);
        assert_eq!(d.str_or("preset", "tiny"), "tiny");
    }

    #[test]
    fn comments_and_strings() {
        let d = TomlDoc::parse("a = \"x # not a comment\" # real comment\n").unwrap();
        assert_eq!(d.str_or("a", ""), "x # not a comment");
    }

    #[test]
    fn rejects_garbage() {
        assert!(TomlDoc::parse("[unterminated\n").is_err());
        assert!(TomlDoc::parse("novalue\n").is_err());
        assert!(TomlDoc::parse("k = \n").is_err());
        assert!(TomlDoc::parse("k = [1, 2\n").is_err());
        assert!(TomlDoc::parse("k = \"open\n").is_err());
    }

    #[test]
    fn floats_and_negatives() {
        let d = TomlDoc::parse("x = -3\ny = 2.5\n").unwrap();
        assert_eq!(d.get("x").unwrap().as_int(), Some(-3));
        assert_eq!(d.f64_or("y", 0.0), 2.5);
        // ints coerce to f64 when asked
        assert_eq!(d.f64_or("x", 0.0), -3.0);
    }
}
