//! Fig. 2 reproduction: normalized kernel-execution-time distribution of
//! GPT models (125M → 175B) in a single transformer layer at batch 32,
//! seq 64, FP16 — the measurement that motivates EnergonAI's "kernel
//! fusion stops mattering at scale" design argument (§3.1).

use super::{layer_kernels, DeviceModel, KernelClass, LayerShape};
use crate::config::ModelConfig;
use std::collections::BTreeMap;

/// Normalized time share per kernel bucket for one model.
#[derive(Clone, Debug)]
pub struct Distribution {
    pub model: String,
    pub total_seconds: f64,
    /// (bucket name, fraction of layer time), fractions sum to 1.
    pub shares: Vec<(String, f64)>,
}

impl Distribution {
    pub fn share(&self, bucket: &str) -> f64 {
        self.shares.iter().find(|(n, _)| n == bucket).map(|(_, s)| *s).unwrap_or(0.0)
    }
}

/// Bucket a kernel name the way the paper's figure legend does.
fn bucket(name: &str, class: KernelClass) -> &'static str {
    if class == KernelClass::Gemm {
        return "gemm";
    }
    match name {
        "softmax" => "softmax",
        "layernorm1" | "layernorm2" => "layernorm",
        n if n.starts_with("transpose") => "transpose",
        n if n.starts_with("bias") => "bias_act",
        n if n.starts_with("residual") => "residual",
        _ => "other",
    }
}

/// Kernel-time distribution for one model config at (batch, seq).
pub fn distribution(dev: &DeviceModel, cfg: &ModelConfig, batch: usize, seq: usize) -> Distribution {
    let ks = layer_kernels(dev, cfg, LayerShape::padded(batch, seq, 1), false);
    let total: f64 = ks.iter().map(|k| k.seconds).sum();
    let mut by_bucket: BTreeMap<&'static str, f64> = BTreeMap::new();
    for k in &ks {
        *by_bucket.entry(bucket(k.name, k.class)).or_default() += k.seconds;
    }
    let mut shares: Vec<(String, f64)> = by_bucket
        .into_iter()
        .map(|(n, s)| (n.to_string(), s / total))
        .collect();
    shares.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    Distribution { model: cfg.name.clone(), total_seconds: total, shares }
}

/// The full Fig. 2 sweep over the GPT family (paper settings: bs=32 s=64).
pub fn fig2(dev: &DeviceModel) -> Vec<Distribution> {
    ModelConfig::gpt_family()
        .iter()
        .map(|cfg| distribution(dev, cfg, 32, 64))
        .collect()
}

/// Render the figure as an ASCII table (one row per model).
pub fn render(dists: &[Distribution]) -> String {
    let mut buckets: Vec<String> = Vec::new();
    for d in dists {
        for (n, _) in &d.shares {
            if !buckets.contains(n) {
                buckets.push(n.clone());
            }
        }
    }
    let mut out = format!("{:<12}", "model");
    for b in &buckets {
        out += &format!("{b:>11}");
    }
    out += &format!("{:>12}\n", "layer_ms");
    for d in dists {
        out += &format!("{:<12}", d.model);
        for b in &buckets {
            out += &format!("{:>10.1}%", d.share(b) * 100.0);
        }
        out += &format!("{:>12.3}\n", d.total_seconds * 1e3);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_gemm_share_grows_62_to_96() {
        // the paper's headline numbers: ~62% at 125M, ~96% at 175B
        let dists = fig2(&DeviceModel::default());
        let small = dists.iter().find(|d| d.model == "gpt-125M").unwrap();
        let big = dists.iter().find(|d| d.model == "gpt-175B").unwrap();
        let s = small.share("gemm");
        let b = big.share("gemm");
        assert!((0.52..0.72).contains(&s), "125M gemm share {s}");
        assert!((0.90..0.99).contains(&b), "175B gemm share {b}");
        assert!(b > s);
    }

    #[test]
    fn gemm_share_is_monotonic_in_model_size() {
        let dists = fig2(&DeviceModel::default());
        let shares: Vec<f64> = dists.iter().map(|d| d.share("gemm")).collect();
        for w in shares.windows(2) {
            assert!(w[1] >= w[0] - 0.02, "share dropped: {shares:?}");
        }
    }

    #[test]
    fn shares_sum_to_one() {
        for d in fig2(&DeviceModel::default()) {
            let sum: f64 = d.shares.iter().map(|(_, s)| s).sum();
            assert!((sum - 1.0).abs() < 1e-9, "{}: {sum}", d.model);
        }
    }

    #[test]
    fn render_contains_all_models() {
        let dists = fig2(&DeviceModel::default());
        let table = render(&dists);
        assert!(table.contains("gpt-125M"));
        assert!(table.contains("gpt-175B"));
        assert!(table.contains("gemm"));
    }
}
