//! Analytic device performance model (A100 roofline).
//!
//! Real multi-GPU hardware is the one thing this testbed cannot provide
//! (DESIGN.md substitution table), so paper-scale figures are regenerated
//! by costing each kernel with a roofline model: compute-bound kernels run
//! at `peak_tflops × efficiency`, memory-bound kernels at HBM bandwidth,
//! and every kernel pays a fixed launch overhead. §3.1/Fig. 2's
//! observation — GEMM share grows 62%→96% from GPT-125M to GPT-175B —
//! falls out of this model without per-figure tuning, which is the
//! calibration check in `breakdown::tests`.

pub mod breakdown;

use crate::config::ModelConfig;

/// Accelerator envelope. Defaults model an NVIDIA A100-80GB (§5.1).
#[derive(Clone, Copy, Debug)]
pub struct DeviceModel {
    /// Peak dense FP16 tensor-core throughput.
    pub peak_tflops: f64,
    /// HBM bandwidth (paper quotes 1555 GB/s, §4.4).
    pub hbm_gbps: f64,
    /// Fixed kernel-launch + scheduling overhead per kernel.
    pub launch_us: f64,
    /// Best-case fraction of peak a large well-shaped GEMM achieves.
    pub gemm_peak_eff: f64,
    /// Device memory capacity in bytes (A100-80GB).
    pub mem_bytes: u64,
}

impl Default for DeviceModel {
    fn default() -> Self {
        DeviceModel {
            peak_tflops: 312.0,
            hbm_gbps: 1555.0,
            launch_us: 4.5,
            gemm_peak_eff: 0.72,
            mem_bytes: 80 * 1024 * 1024 * 1024,
        }
    }
}

/// Kernel classes for Fig. 2's distribution.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum KernelClass {
    Gemm,
    /// Softmax, layernorm, bias/residual adds, transposes — memory-bound.
    MemoryBound,
}

/// One costed kernel invocation.
#[derive(Clone, Debug)]
pub struct KernelCost {
    pub name: &'static str,
    pub class: KernelClass,
    pub seconds: f64,
}

impl DeviceModel {
    /// GEMM efficiency: large well-shaped GEMMs approach `gemm_peak_eff`;
    /// small outputs starve the SMs. Utilization is modelled as tile
    /// occupancy — the number of 128×128 output tiles relative to the
    /// A100's 108 SMs — which captures §5.3's observation that "splitting
    /// the workload into pieces can further exacerbate" under-utilization:
    /// tensor-parallel shards shrink N, cutting the tile count.
    pub fn gemm_eff(&self, m: usize, n: usize, _k: usize) -> f64 {
        const TILE: f64 = 128.0;
        const SMS: f64 = 108.0;
        let tiles = (m as f64 / TILE).ceil() * (n as f64 / TILE).ceil();
        // below one full wave, idle SMs are pure waste: occupancy is
        // simply tiles/SMs, saturating at 1 (A100: 108 SMs)
        let occ = (tiles / SMS).min(1.0);
        self.gemm_peak_eff * occ
    }

    /// Time for one m×n×k GEMM (fp16 in, fp32 accumulate).
    pub fn gemm_time(&self, m: usize, n: usize, k: usize) -> f64 {
        let flops = 2.0 * m as f64 * n as f64 * k as f64;
        let compute = flops / (self.peak_tflops * 1e12 * self.gemm_eff(m, n, k));
        let bytes = 2.0 * (m * k + k * n + m * n) as f64; // fp16
        let memory = bytes / (self.hbm_gbps * 1e9);
        compute.max(memory) + self.launch_us * 1e-6
    }

    /// A batched GEMM launched as one kernel (attention score/context).
    pub fn batched_gemm_time(&self, batches: usize, m: usize, n: usize, k: usize) -> f64 {
        let flops = 2.0 * batches as f64 * (m * n * k) as f64;
        // batching restores utilization: effective rows = batches * m
        let eff = self.gemm_eff(batches * m, n, k);
        let compute = flops / (self.peak_tflops * 1e12 * eff);
        let bytes = 2.0 * batches as f64 * (m * k + k * n + m * n) as f64;
        let memory = bytes / (self.hbm_gbps * 1e9);
        compute.max(memory) + self.launch_us * 1e-6
    }

    /// Memory-bound elementwise/reduction kernel moving `bytes`.
    pub fn mem_time(&self, bytes: u64) -> f64 {
        bytes as f64 / (self.hbm_gbps * 1e9) + self.launch_us * 1e-6
    }
}

/// Workload point for one layer execution.
#[derive(Clone, Copy, Debug)]
pub struct LayerShape {
    pub batch: usize,
    pub seq: usize,
    /// Rows the *linear* kernels see: `batch*seq` padded, fewer with DRCE.
    pub linear_rows: usize,
    /// Tensor-parallel degree (shards heads and ffn).
    pub tp: usize,
}

impl LayerShape {
    pub fn padded(batch: usize, seq: usize, tp: usize) -> LayerShape {
        LayerShape { batch, seq, linear_rows: batch * seq, tp }
    }

    pub fn drce(batch: usize, seq: usize, valid_rows: usize, tp: usize) -> LayerShape {
        LayerShape { batch, seq, linear_rows: valid_rows, tp }
    }
}

/// Cost every kernel in one transformer layer (per TP worker).
///
/// Kernel list mirrors the L1/L2 decomposition: 4 projection GEMMs + 2 MLP
/// GEMMs + 2 attention batched GEMMs, with layernorms, softmax, bias adds,
/// residuals and (without fused attention) transposes as memory-bound
/// kernels. `fused_attention` folds softmax+transposes into the GEMMs the
/// way FasterTransformer's fused MHA does (§5.5).
pub fn layer_kernels(
    dev: &DeviceModel,
    cfg: &ModelConfig,
    shape: LayerShape,
    fused_attention: bool,
) -> Vec<KernelCost> {
    let h = cfg.hidden;
    let f = cfg.ffn();
    let hd = cfg.head_dim();
    let nh = cfg.n_heads / shape.tp;
    let rows = shape.linear_rows; // rows into linear kernels
    let act_bytes = |r: usize, c: usize| (r * c * 2) as u64; // fp16

    let mut ks = Vec::new();
    let gemm = |name, m: usize, n: usize, k: usize| KernelCost {
        name,
        class: KernelClass::Gemm,
        seconds: dev.gemm_time(m, n, k),
    };
    let mem = |name, bytes: u64| KernelCost {
        name,
        class: KernelClass::MemoryBound,
        seconds: dev.mem_time(bytes),
    };

    // attention half
    ks.push(mem("layernorm1", 2 * act_bytes(rows, h)));
    ks.push(gemm("qkv_proj", rows, 3 * h / shape.tp, h));
    if !fused_attention {
        ks.push(mem("bias_qkv", 2 * act_bytes(rows, 3 * h / shape.tp)));
        ks.push(mem("transpose_qkv", 2 * act_bytes(shape.batch * shape.seq, 3 * h / shape.tp)));
    }
    ks.push(KernelCost {
        name: "attn_scores",
        class: KernelClass::Gemm,
        seconds: dev.batched_gemm_time(shape.batch * nh, shape.seq, shape.seq, hd),
    });
    if !fused_attention {
        ks.push(mem(
            "softmax",
            3 * (shape.batch * nh * shape.seq * shape.seq * 2) as u64,
        ));
    }
    ks.push(KernelCost {
        name: "attn_context",
        class: KernelClass::Gemm,
        seconds: dev.batched_gemm_time(shape.batch * nh, shape.seq, hd, shape.seq),
    });
    if !fused_attention {
        ks.push(mem("transpose_ctx", 2 * act_bytes(shape.batch * shape.seq, h / shape.tp)));
    }
    ks.push(gemm("out_proj", rows, h, h / shape.tp));
    ks.push(mem("residual1", 3 * act_bytes(rows, h)));

    // mlp half
    ks.push(mem("layernorm2", 2 * act_bytes(rows, h)));
    ks.push(gemm("fc1", rows, f / shape.tp, h));
    ks.push(mem("bias_gelu", 2 * act_bytes(rows, f / shape.tp)));
    ks.push(gemm("fc2", rows, h, f / shape.tp));
    ks.push(mem("residual2", 3 * act_bytes(rows, h)));
    ks
}

/// Total single-device time for one layer.
pub fn layer_time(dev: &DeviceModel, cfg: &ModelConfig, shape: LayerShape, fused: bool) -> f64 {
    layer_kernels(dev, cfg, shape, fused).iter().map(|k| k.seconds).sum()
}

/// Embedding lookup (memory-bound gather) — the stage-0 extra the paper
/// blames for slight pipeline imbalance (§5.4).
pub fn embed_time(dev: &DeviceModel, cfg: &ModelConfig, batch: usize, seq: usize) -> f64 {
    dev.mem_time((batch * seq * cfg.hidden * 2 * 2) as u64)
}

/// LM head: final layernorm + (rows × vocab × hidden) GEMM.
pub fn logits_time(dev: &DeviceModel, cfg: &ModelConfig, batch: usize, seq: usize) -> f64 {
    dev.mem_time((batch * seq * cfg.hidden * 2 * 2) as u64)
        + dev.gemm_time(batch * seq, cfg.vocab, cfg.hidden)
}

/// FLOPs of one layer forward at the given shape (for TFLOPS reporting in
/// Fig. 13; matches the model the paper computes "with the parameters").
pub fn layer_flops(cfg: &ModelConfig, batch: usize, seq: usize) -> f64 {
    let h = cfg.hidden as f64;
    let f = cfg.ffn() as f64;
    let rows = (batch * seq) as f64;
    let attn_gemms = 4.0 * (batch * cfg.n_heads) as f64 * (seq * seq) as f64 * cfg.head_dim() as f64;
    2.0 * rows * (3.0 * h * h + h * h + h * f + f * h) + attn_gemms
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gpt(name: &str) -> ModelConfig {
        ModelConfig::gpt_family().into_iter().find(|c| c.name == name).unwrap()
    }

    #[test]
    fn gemm_eff_grows_with_rows() {
        let d = DeviceModel::default();
        assert!(d.gemm_eff(2048, 768, 768) > d.gemm_eff(128, 768, 768));
        assert!(d.gemm_eff(4096, 4096, 4096) <= d.gemm_peak_eff);
    }

    #[test]
    fn gemm_time_monotonic() {
        let d = DeviceModel::default();
        assert!(d.gemm_time(2048, 3072, 768) < d.gemm_time(2048, 3072, 12288));
    }

    #[test]
    fn layer_time_scales_superlinearly_with_hidden() {
        let d = DeviceModel::default();
        let small = gpt("gpt-125M");
        let big = gpt("gpt-175B");
        let s = layer_time(&d, &small, LayerShape::padded(32, 64, 1), false);
        let b = layer_time(&d, &big, LayerShape::padded(32, 64, 1), false);
        // hidden grows 16x, gemm work 256x; total should grow >100x
        assert!(b / s > 100.0, "ratio {}", b / s);
    }

    #[test]
    fn tp_divides_gemm_work() {
        let d = DeviceModel::default();
        let cfg = gpt("gpt-175B");
        let t1 = layer_time(&d, &cfg, LayerShape::padded(32, 128, 1), false);
        let t8 = layer_time(&d, &cfg, LayerShape::padded(32, 128, 8), false);
        let speedup = t1 / t8;
        assert!(speedup > 4.0 && speedup < 8.0, "speedup {speedup}");
    }

    #[test]
    fn drce_halves_linear_time() {
        let d = DeviceModel::default();
        let cfg = gpt("gpt-175B");
        let full = layer_time(&d, &cfg, LayerShape::padded(32, 64, 1), false);
        let drce = layer_time(&d, &cfg, LayerShape::drce(32, 64, 32 * 32, 1), false);
        let ratio = drce / full;
        // linears dominate at 175B and see half the rows -> ~0.5-0.65
        assert!(ratio > 0.45 && ratio < 0.7, "ratio {ratio}");
    }

    #[test]
    fn fused_attention_reduces_time() {
        let d = DeviceModel::default();
        let cfg = gpt("gpt-125M");
        let shape = LayerShape::padded(1, 64, 1);
        let unfused = layer_time(&d, &cfg, shape, false);
        let fused = layer_time(&d, &cfg, shape, true);
        assert!(fused < unfused);
        // at tiny batch the gap is material (>5%) — §5.5's bs=1 observation
        assert!((unfused - fused) / unfused > 0.05);
    }

    #[test]
    fn layer_flops_match_formula() {
        let cfg = gpt("gpt-175B");
        let fl = layer_flops(&cfg, 32, 64);
        // 12*rows*h^2-ish: sanity window
        let rows = 2048.0;
        let h = 12288.0f64;
        let approx = 2.0 * rows * 12.0 * h * h;
        assert!((fl / approx - 1.0).abs() < 0.1, "{fl} vs {approx}");
    }
}
