//! Baseline systems the paper compares against, as launchable configs.
//!
//! Both baselines are *modes* of the same machinery rather than forks:
//!
//! * **FasterTransformer** (§5.4, §5.5): blocking `nccl_send/recv`
//!   pipeline hand-offs — [`crate::comm::channel::Mode::Blocking`] on the
//!   real engine, [`crate::sim::System::FasterTransformer`] in the
//!   paper-scale simulators (which also model FT's fused-MHA kernel and
//!   warm-up GEMM algorithm selection as a device-efficiency edge).
//! * **BMInf** (§5.6): parameters offloaded to host memory and fetched
//!   *synchronously* on the compute path —
//!   [`crate::memory::pool::PoolConfig::bminf`].

use crate::coordinator::engine::{LaunchConfig, MemoryMode};

/// FasterTransformer-style launch: blocking stage-to-stage communication.
/// (The kernel-level fusion edge only exists on real GPUs; on this testbed
/// the sims carry it — see `sim::System::device`.)
pub fn fastertransformer(preset: &str, tp: usize, pp: usize) -> LaunchConfig {
    LaunchConfig::preset(preset)
        .with_parallel(tp, pp)
        .with_blocking_comms(true)
}

/// BMInf-style launch: `n_local` layers resident, the rest offloaded to
/// host memory with synchronous fetches.
pub fn bminf(preset: &str, n_local: usize) -> LaunchConfig {
    LaunchConfig::preset(preset).with_memory(MemoryMode::Bminf { n_local })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ft_config_blocks() {
        let c = fastertransformer("tiny", 1, 2);
        assert!(c.engine.blocking_comms);
        assert_eq!(c.parallel.pp, 2);
    }

    #[test]
    fn bminf_config_offloads() {
        let c = bminf("tiny", 2);
        match c.memory {
            MemoryMode::Bminf { n_local } => assert_eq!(n_local, 2),
            _ => panic!("expected Bminf memory mode"),
        }
    }
}
