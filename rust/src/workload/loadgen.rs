//! Saturation load generation: a seeded client pool that drives the
//! engine the way hostile traffic does — Poisson bursts, heavy-tailed
//! prompt and output lengths, multi-turn re-entry with grown context,
//! and mid-stream disconnects — and reports what the engine did about
//! it (sustained tok/s, TTFT/TPOT percentiles, shed rate, survivor
//! streams for differential parity).
//!
//! Everything is derived from one seed through forked RNG streams, with
//! the *chaos* decisions (who disconnects, when) on their own stream:
//! two scenarios that differ only in `disconnect_pct` produce byte-
//! identical prompts, arrival gaps and token budgets, so a faulted run
//! can be compared stream-for-stream against an unfaulted control
//! ([`parity_mismatches`]) — greedy decode is deterministic per prompt,
//! and chaos must never change a survivor's bytes.
//!
//! The same discipline covers the newer knobs: busy-retry backoff
//! jitter rides stream 5 and the replica-[`kill_schedule`]
//! (SaturationScenario::kill_schedule) rides stream 6, both forked
//! *after* the original content/arrival/chaos/template streams — so
//! backing off or scheduling kills perturbs no other draw. Clients
//! honor `Busy::retry_after_ms` (jittered, bounded retries) and report
//! shed-then-succeeded turns as `recovered`; [`run_fleet_saturation`]
//! drives a replica fleet instead of a bare engine and executes the
//! kill schedule mid-run.

use crate::coordinator::engine::{Engine, GenRef, GenRequest};
use crate::coordinator::fleet::Fleet;
use crate::coordinator::Busy;
use crate::util::rng::Rng;
use crate::workload::LengthDist;
use std::time::{Duration, Instant};

/// How many times a client re-submits a `Busy` turn before giving up
/// and recording it as shed.
const MAX_BUSY_RETRIES: usize = 3;
/// Ceiling on one backoff sleep, ms — the engine's hint is honored up
/// to here (an engine under heavy pressure can hint seconds; a loadgen
/// client should not stall a whole scenario on one turn).
const MAX_BACKOFF_MS: f64 = 200.0;

/// One seeded hostile-traffic scenario.
#[derive(Clone, Debug)]
pub struct SaturationScenario {
    pub seed: u64,
    /// Concurrent clients (one thread each in [`run_saturation`]).
    pub clients: usize,
    /// Conversation turns per client (turn > 0 re-enters with the grown
    /// context of the previous completed turn).
    pub turns: usize,
    pub prompt_dist: LengthDist,
    /// Continuation-token budget per turn (heavy-tailed outputs).
    pub output_dist: LengthDist,
    pub vocab: usize,
    /// Per-client Poisson arrival rate (turns/second of *scenario* time;
    /// the runner sleeps the sampled gaps directly, so pick rates that
    /// keep the whole run in the hundreds of milliseconds).
    pub arrival_rate: f64,
    /// Probability that a turn's client disconnects mid-stream.
    pub disconnect_pct: f64,
    /// Fresh tokens a re-entering turn appends to its grown context.
    pub followup_tokens: usize,
    /// Templated traffic: number of shared prompt templates (0 = off —
    /// plans are then byte-identical to a scenario without the knob).
    pub templates: usize,
    /// Fraction of fresh prompts that start with one of the templates.
    pub template_pct: f64,
    /// Tokens per template. Multiples of the engine's K/V block size make
    /// whole-block prefix reuse likely; any length is legal.
    pub template_tokens: usize,
    /// Heavy-tail mix: fraction of fresh prompts stretched into long
    /// prompts (0 = off — plans are then byte-identical to a scenario
    /// without the knob).
    pub long_prompt_pct: f64,
    /// Extra tail tokens appended to a stretched prompt.
    pub long_prompt_tokens: usize,
}

impl SaturationScenario {
    /// The acceptance-scenario shape: heavy-tailed prompts and outputs,
    /// bursty arrivals, no chaos (turn it on with
    /// [`SaturationScenario::with_disconnects`]).
    pub fn new(seed: u64, clients: usize, turns: usize) -> SaturationScenario {
        SaturationScenario {
            seed,
            clients,
            turns,
            prompt_dist: LengthDist::HeavyTail(12, 1.1),
            output_dist: LengthDist::HeavyTail(6, 1.1),
            vocab: 100,
            arrival_rate: 200.0,
            disconnect_pct: 0.0,
            followup_tokens: 2,
            templates: 0,
            template_pct: 0.0,
            template_tokens: 0,
            long_prompt_pct: 0.0,
            long_prompt_tokens: 0,
        }
    }

    /// Same plans, plus mid-stream disconnects on `pct` of turns.
    pub fn with_disconnects(mut self, pct: f64) -> Self {
        self.disconnect_pct = pct;
        self
    }

    /// Templated traffic: `pct` of fresh prompts start with one of `n`
    /// shared `tokens`-long templates (the shape that makes a shared-
    /// prefix cache pay). Template bytes and the per-turn choice come
    /// from their own forked RNG stream, so every prompt suffix, gap,
    /// budget and chaos flag stays byte-identical to the untemplated
    /// scenario — the differential lever for the prefix bench.
    pub fn with_templates(mut self, n: usize, pct: f64, tokens: usize) -> Self {
        self.templates = n;
        self.template_pct = pct;
        self.template_tokens = tokens;
        self
    }

    /// Mixed traffic: `pct` of fresh prompts grow a `tokens`-long tail —
    /// the heavy-tail shape whose monolithic prefills starve concurrent
    /// decodes (the chunked-prefill differential lever). Tail bytes ride
    /// their own forked RNG stream (7, after every earlier stream) and
    /// both draws happen unconditionally, so `pct` flips *which* turns
    /// are long without moving any suffix, gap, budget, chaos flag,
    /// template choice, backoff seed, or kill offset.
    pub fn with_long_prompts(mut self, pct: f64, tokens: usize) -> Self {
        self.long_prompt_pct = pct;
        self.long_prompt_tokens = tokens;
        self
    }

    /// Materialize the per-client plans. Deterministic in `seed`; the
    /// chaos stream is forked separately and *always drawn*, so changing
    /// `disconnect_pct` flips disconnect flags without perturbing any
    /// prompt, gap or budget.
    pub fn plan(&self) -> Vec<ClientPlan> {
        let mut root = Rng::new(self.seed);
        let mut content = root.fork(1);
        let mut arrivals = root.fork(2);
        let mut chaos = root.fork(3);
        // the template stream is only ever drawn when templates exist, so
        // `templates == 0` plans are byte-identical to pre-template builds
        let mut tmpl = root.fork(4);
        // busy-retry jitter rides its own stream so backing off never
        // perturbs prompts, gaps, budgets, or chaos flags
        let mut backoff = root.fork(5);
        // long-prompt tails ride stream 7 (6 is the kill schedule's,
        // drawn off its own root replay) — forked last, so the knob's
        // existence perturbs nothing older
        let mut longp = root.fork(7);
        let templates: Vec<Vec<i32>> = (0..self.templates)
            .map(|_| {
                (0..self.template_tokens)
                    .map(|_| (tmpl.next_below(self.vocab as u64 - 1) + 1) as i32)
                    .collect()
            })
            .collect();
        (0..self.clients)
            .map(|client| {
                let mut content = content.fork(client as u64);
                let mut arrivals = arrivals.fork(client as u64);
                let mut chaos = chaos.fork(client as u64);
                let mut tmpl = tmpl.fork(client as u64);
                let mut longp = longp.fork(client as u64);
                let turns = (0..self.turns)
                    .map(|_| {
                        let plen = self.prompt_dist.sample(&mut content);
                        let mut fresh_prompt: Vec<i32> = (0..plen)
                            .map(|_| (content.next_below(self.vocab as u64 - 1) + 1) as i32)
                            .collect();
                        // both template draws happen unconditionally (like
                        // the chaos draws) so `template_pct` flips which
                        // turns are templated without moving any suffix
                        let template = if self.templates > 0 {
                            let roll = tmpl.next_f64();
                            let idx = tmpl.next_below(self.templates as u64) as usize;
                            (roll < self.template_pct).then_some(idx)
                        } else {
                            None
                        };
                        if let Some(idx) = template {
                            fresh_prompt.splice(0..0, templates[idx].iter().copied());
                        }
                        // both long-prompt draws happen unconditionally
                        // (like chaos and templates) so the pct knob flips
                        // which turns are long without moving anything
                        let long = if self.long_prompt_tokens > 0 {
                            let roll = longp.next_f64();
                            let tail: Vec<i32> = (0..self.long_prompt_tokens)
                                .map(|_| (longp.next_below(self.vocab as u64 - 1) + 1) as i32)
                                .collect();
                            let long = roll < self.long_prompt_pct;
                            if long {
                                fresh_prompt.extend_from_slice(&tail);
                            }
                            long
                        } else {
                            false
                        };
                        let followup = (0..self.followup_tokens)
                            .map(|_| (content.next_below(self.vocab as u64 - 1) + 1) as i32)
                            .collect();
                        let new_tokens = self.output_dist.sample(&mut content).max(1);
                        let delay =
                            Duration::from_secs_f64(arrivals.exponential(self.arrival_rate));
                        // both chaos draws happen unconditionally — see plan()
                        let roll = chaos.next_f64();
                        let after = 1 + chaos.next_below(new_tokens as u64) as usize;
                        let disconnect_after =
                            (roll < self.disconnect_pct).then_some(after.min(new_tokens));
                        TurnPlan {
                            fresh_prompt,
                            followup,
                            new_tokens,
                            delay,
                            disconnect_after,
                            template,
                            long,
                        }
                    })
                    .collect();
                ClientPlan { client, turns, backoff_seed: backoff.fork(client as u64).next_u64() }
            })
            .collect()
    }

    /// Deterministic replica-kill schedule on its own forked stream (6):
    /// up to `kills` *distinct* replicas — capped at `replicas - 1`, the
    /// last survivor is never scheduled — each at a uniform offset
    /// inside `window`, sorted by time. Forked after every client
    /// stream, so adding kills to a scenario perturbs no prompt, gap,
    /// budget, chaos flag, or backoff draw — the differential lever for
    /// the failover suites.
    pub fn kill_schedule(
        &self,
        replicas: usize,
        kills: usize,
        window: Duration,
    ) -> Vec<ReplicaKill> {
        let mut root = Rng::new(self.seed);
        for tag in 1..=5 {
            let _ = root.fork(tag);
        }
        let mut kr = root.fork(6);
        let mut ids: Vec<usize> = (0..replicas).collect();
        kr.shuffle(&mut ids);
        let mut schedule: Vec<ReplicaKill> = ids
            .into_iter()
            .take(kills.min(replicas.saturating_sub(1)))
            .map(|replica| ReplicaKill {
                after: Duration::from_secs_f64(kr.next_f64() * window.as_secs_f64()),
                replica,
            })
            .collect();
        schedule.sort_by_key(|k| k.after);
        schedule
    }
}

/// One scheduled deliberate replica kill (see
/// [`SaturationScenario::kill_schedule`] / [`run_fleet_saturation`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ReplicaKill {
    /// Offset from the run's start.
    pub after: Duration,
    pub replica: usize,
}

/// One client's scripted conversation.
#[derive(Clone, Debug)]
pub struct ClientPlan {
    pub client: usize,
    pub turns: Vec<TurnPlan>,
    /// Seeds the client's busy-retry jitter stream.
    pub backoff_seed: u64,
}

/// One scripted turn.
#[derive(Clone, Debug)]
pub struct TurnPlan {
    /// Prompt when this turn starts a fresh conversation (turn 0, or the
    /// previous turn did not complete).
    pub fresh_prompt: Vec<i32>,
    /// Appended to the previous turn's full sequence on re-entry, so the
    /// context grows turn over turn.
    pub followup: Vec<i32>,
    /// Continuation-token budget.
    pub new_tokens: usize,
    /// Poisson gap slept before submitting.
    pub delay: Duration,
    /// Disconnect (cancel) after streaming this many tokens.
    pub disconnect_after: Option<usize>,
    /// Which shared template (if any) this turn's fresh prompt starts
    /// with — `fresh_prompt` already includes it.
    pub template: Option<usize>,
    /// Whether the heavy-tail knob stretched this turn's fresh prompt —
    /// `fresh_prompt` already includes the tail.
    pub long: bool,
}

/// How one turn ended.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Outcome {
    Completed,
    /// Client hung up mid-stream after the recorded tokens.
    Disconnected,
    /// Admission control shed the turn (structured busy).
    Shed,
    Error(String),
}

/// One turn's observed stream.
#[derive(Clone, Debug)]
pub struct StreamOutcome {
    pub client: usize,
    pub turn: usize,
    pub prompt: Vec<i32>,
    pub tokens: Vec<i32>,
    pub outcome: Outcome,
}

/// Aggregated result of one [`run_saturation`] pass.
#[derive(Clone, Debug, Default)]
pub struct LoadReport {
    pub completed: usize,
    pub disconnected: usize,
    pub shed: usize,
    /// Turns that were admitted after at least one `Busy` rejection —
    /// shed-then-succeeded, the payoff of honoring `retry_after_ms`.
    pub recovered: usize,
    /// Total `Busy` replies observed by clients, *including* retries
    /// that later succeeded (so this equals the engine's shed counter,
    /// whereas `shed` counts only turns that gave up).
    pub busy_rejections: usize,
    pub errors: usize,
    pub tokens_streamed: usize,
    pub wall: Duration,
    /// First-token latency per completed-or-disconnected stream, µs.
    pub ttft_us: Vec<u64>,
    /// Inter-token gap for every subsequent streamed token, µs.
    pub tpot_us: Vec<u64>,
    pub streams: Vec<StreamOutcome>,
}

impl LoadReport {
    pub fn turns(&self) -> usize {
        self.streams.len()
    }

    /// Fraction of turns shed by admission control.
    pub fn shed_rate(&self) -> f64 {
        if self.streams.is_empty() {
            0.0
        } else {
            self.shed as f64 / self.streams.len() as f64
        }
    }

    /// Sustained decode throughput over the whole run.
    pub fn tokens_per_sec(&self) -> f64 {
        let s = self.wall.as_secs_f64();
        if s > 0.0 {
            self.tokens_streamed as f64 / s
        } else {
            0.0
        }
    }
}

/// Nearest-rank percentile (p in [0, 100]) of a latency sample, µs.
/// Returns 0 on an empty sample.
pub fn pctl_us(xs: &[u64], p: f64) -> u64 {
    if xs.is_empty() {
        return 0;
    }
    let mut v = xs.to_vec();
    v.sort_unstable();
    let rank = ((p / 100.0) * v.len() as f64).ceil() as usize;
    v[rank.clamp(1, v.len()) - 1]
}

/// Keys completed streams by (client, turn) and checks that every pair
/// completed in *both* reports with the same prompt produced the same
/// bytes — the survivor-parity invariant: chaos may change *which*
/// streams finish, never *what* a finished stream says. Returns one
/// human-readable line per violation (empty == parity holds).
pub fn parity_mismatches(a: &LoadReport, b: &LoadReport) -> Vec<String> {
    let key = |r: &LoadReport| -> std::collections::HashMap<(usize, usize), (Vec<i32>, Vec<i32>)> {
        r.streams
            .iter()
            .filter(|s| s.outcome == Outcome::Completed)
            .map(|s| ((s.client, s.turn), (s.prompt.clone(), s.tokens.clone())))
            .collect()
    };
    let (ka, kb) = (key(a), key(b));
    let mut diffs = Vec::new();
    for (k, (pa, ta)) in &ka {
        if let Some((pb, tb)) = kb.get(k) {
            if pa == pb && ta != tb {
                diffs.push(format!(
                    "client {} turn {}: same prompt, tokens {:?} vs {:?}",
                    k.0, k.1, ta, tb
                ));
            }
        }
    }
    diffs.sort();
    diffs
}

/// What the client pool is driving: a bare engine or a replica fleet.
/// Fleet placement is session-affine, so the fleet variant forwards the
/// client id as the affinity key.
#[derive(Clone, Copy)]
enum Target<'a> {
    Engine(&'a Engine),
    Fleet(&'a Fleet),
}

impl Target<'_> {
    fn generate_stream(&self, client: u64, req: GenRequest) -> anyhow::Result<GenRef> {
        match *self {
            Target::Engine(e) => e.generate_stream(req),
            Target::Fleet(f) => f.generate_stream_for(client, req),
        }
    }
}

/// Drive `engine` with the scenario's client pool: one thread per
/// client, each playing its turns in order — sleep the Poisson gap,
/// submit (re-entering with grown context when the previous turn
/// completed and the result still fits `max_context`, backing off with
/// jitter on `Busy` up to [`MAX_BUSY_RETRIES`] times), stream, and
/// disconnect mid-stream where the plan says so. Returns the merged
/// report; leak accounting is the caller's (workers own the block
/// gauges — see `memory::kvcache::global_stats`).
pub fn run_saturation(
    engine: &Engine,
    scenario: &SaturationScenario,
    max_context: usize,
) -> LoadReport {
    run_target(Target::Engine(engine), scenario, max_context, &[])
}

/// [`run_saturation`] against a replica fleet, with a deliberate
/// [`kill_schedule`](SaturationScenario::kill_schedule) executed on its
/// own thread while the clients play: each kill fires at its offset
/// from the run's start, and the fleet is expected to fail victims over
/// so that survivor parity against a no-kill control still holds.
pub fn run_fleet_saturation(
    fleet: &Fleet,
    scenario: &SaturationScenario,
    max_context: usize,
    kills: &[ReplicaKill],
) -> LoadReport {
    run_target(Target::Fleet(fleet), scenario, max_context, kills)
}

fn run_target(
    target: Target<'_>,
    scenario: &SaturationScenario,
    max_context: usize,
    kills: &[ReplicaKill],
) -> LoadReport {
    let plans = scenario.plan();
    let t0 = Instant::now();
    let mut per_client: Vec<ClientResult> = Vec::new();
    std::thread::scope(|scope| {
        if !kills.is_empty() {
            if let Target::Fleet(fleet) = target {
                // the assassin: sleeps to each scheduled offset, then
                // kills — already-dead / out-of-range ids are ignored so
                // a schedule can outlive a short run
                scope.spawn(move || {
                    for k in kills {
                        let elapsed = t0.elapsed();
                        if k.after > elapsed {
                            std::thread::sleep(k.after - elapsed);
                        }
                        let _ = fleet.kill(k.replica);
                    }
                });
            }
        }
        let handles: Vec<_> = plans
            .iter()
            .map(|plan| scope.spawn(move || run_client(target, plan, max_context)))
            .collect();
        for h in handles {
            per_client.push(h.join().expect("loadgen client panicked"));
        }
    });
    let mut report = LoadReport { wall: t0.elapsed(), ..LoadReport::default() };
    for r in per_client {
        for s in r.streams {
            match &s.outcome {
                Outcome::Completed => report.completed += 1,
                Outcome::Disconnected => report.disconnected += 1,
                Outcome::Shed => report.shed += 1,
                Outcome::Error(_) => report.errors += 1,
            }
            report.tokens_streamed += s.tokens.len();
            report.streams.push(s);
        }
        report.ttft_us.extend(r.ttft_us);
        report.tpot_us.extend(r.tpot_us);
        report.recovered += r.recovered;
        report.busy_rejections += r.busy_rejections;
    }
    report.streams.sort_by_key(|s| (s.client, s.turn));
    report
}

/// One client thread's contribution to the merged [`LoadReport`].
#[derive(Default)]
struct ClientResult {
    streams: Vec<StreamOutcome>,
    ttft_us: Vec<u64>,
    tpot_us: Vec<u64>,
    recovered: usize,
    busy_rejections: usize,
}

fn run_client(target: Target<'_>, plan: &ClientPlan, max_context: usize) -> ClientResult {
    let mut res = ClientResult::default();
    // the busy-backoff jitter stream — forked in plan() after every
    // other stream, so its existence perturbs nothing
    let mut backoff = Rng::new(plan.backoff_seed);
    // the grown context of the previous turn, when it completed
    let mut context: Option<Vec<i32>> = None;
    for (turn, t) in plan.turns.iter().enumerate() {
        std::thread::sleep(t.delay);
        // multi-turn re-entry: continue the conversation if the previous
        // turn completed and the grown context still fits; otherwise
        // start fresh (a disconnected client reconnects as a new session)
        let prompt = match context.take() {
            Some(mut c)
                if c.len() + t.followup.len() + t.new_tokens <= max_context =>
            {
                c.extend_from_slice(&t.followup);
                c
            }
            _ => t.fresh_prompt.clone(),
        };
        // TTFT is measured from the *first* submit — backoff sleeps are
        // part of the latency the client observed
        let submitted = Instant::now();
        let mut rejections = 0usize;
        let admitted = loop {
            match target.generate_stream(
                plan.client as u64,
                GenRequest::new(prompt.clone(), t.new_tokens),
            ) {
                Ok(g) => break Ok(g),
                Err(e) => match e.downcast_ref::<Busy>() {
                    Some(b) if rejections < MAX_BUSY_RETRIES => {
                        rejections += 1;
                        // honor the engine's hint, jittered to ±50% so a
                        // shed wave does not resubmit in lockstep
                        let ms = (b.retry_after_ms.max(1) as f64
                            * (0.5 + backoff.next_f64()))
                        .min(MAX_BACKOFF_MS);
                        std::thread::sleep(Duration::from_secs_f64(ms / 1000.0));
                    }
                    _ => break Err(e),
                },
            }
        };
        res.busy_rejections += rejections;
        let gref = match admitted {
            Ok(g) => {
                if rejections > 0 {
                    res.recovered += 1;
                }
                g
            }
            Err(e) => {
                let outcome = if e.downcast_ref::<Busy>().is_some() {
                    res.busy_rejections += 1; // the final, fatal rejection
                    Outcome::Shed
                } else {
                    Outcome::Error(format!("{e:#}"))
                };
                res.streams.push(StreamOutcome {
                    client: plan.client,
                    turn,
                    prompt,
                    tokens: Vec::new(),
                    outcome,
                });
                continue;
            }
        };
        let mut tokens = Vec::new();
        let mut last = submitted;
        let outcome = loop {
            match gref.next() {
                Ok(Some(tok)) => {
                    let now = Instant::now();
                    if tokens.is_empty() {
                        res.ttft_us.push(now.duration_since(submitted).as_micros() as u64);
                    } else {
                        res.tpot_us.push(now.duration_since(last).as_micros() as u64);
                    }
                    last = now;
                    tokens.push(tok);
                    if t.disconnect_after == Some(tokens.len()) {
                        // the hostile part: hang up mid-stream and never
                        // read another byte
                        gref.cancel();
                        break Outcome::Disconnected;
                    }
                }
                Ok(None) => break Outcome::Completed,
                Err(e) => {
                    let msg = format!("{e:#}");
                    break if msg.contains("cancelled") {
                        Outcome::Disconnected
                    } else {
                        Outcome::Error(msg)
                    };
                }
            }
        };
        if outcome == Outcome::Completed {
            let mut full = prompt.clone();
            full.extend_from_slice(&tokens);
            context = Some(full);
        }
        res.streams
            .push(StreamOutcome { client: plan.client, turn, prompt, tokens, outcome });
    }
    res
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scenario(pct: f64) -> SaturationScenario {
        SaturationScenario::new(99, 6, 3).with_disconnects(pct)
    }

    #[test]
    fn plans_are_seed_deterministic() {
        let a = scenario(0.25).plan();
        let b = scenario(0.25).plan();
        assert_eq!(a.len(), 6);
        for (pa, pb) in a.iter().zip(&b) {
            assert_eq!(pa.turns.len(), 3);
            assert_eq!(pa.backoff_seed, pb.backoff_seed);
            for (ta, tb) in pa.turns.iter().zip(&pb.turns) {
                assert_eq!(ta.fresh_prompt, tb.fresh_prompt);
                assert_eq!(ta.followup, tb.followup);
                assert_eq!(ta.new_tokens, tb.new_tokens);
                assert_eq!(ta.delay, tb.delay);
                assert_eq!(ta.disconnect_after, tb.disconnect_after);
            }
        }
    }

    /// The differential-run invariant: chaos knobs flip disconnect flags
    /// only — prompts, budgets and gaps stay byte-identical.
    #[test]
    fn disconnect_pct_changes_only_the_chaos_flags() {
        let clean = scenario(0.0).plan();
        let chaotic = scenario(0.25).plan();
        let mut disconnects = 0;
        for (pc, ph) in clean.iter().zip(&chaotic) {
            for (tc, th) in pc.turns.iter().zip(&ph.turns) {
                assert_eq!(tc.fresh_prompt, th.fresh_prompt);
                assert_eq!(tc.followup, th.followup);
                assert_eq!(tc.new_tokens, th.new_tokens);
                assert_eq!(tc.delay, th.delay);
                assert_eq!(tc.disconnect_after, None);
                if let Some(k) = th.disconnect_after {
                    disconnects += 1;
                    assert!((1..=th.new_tokens).contains(&k));
                }
            }
        }
        assert!(disconnects > 0, "25% over 18 turns should fire at least once");
    }

    #[test]
    fn full_disconnect_pct_marks_every_turn() {
        let plans = scenario(1.0).plan();
        assert!(plans
            .iter()
            .flat_map(|p| &p.turns)
            .all(|t| t.disconnect_after.is_some()));
    }

    /// The prefix-bench differential lever: templated plans must share
    /// their prefixes *and* keep every suffix, gap, budget and chaos flag
    /// byte-identical to the untemplated scenario.
    #[test]
    fn templates_prepend_shared_prefixes_without_moving_anything_else() {
        let base = scenario(0.25).plan();
        let templated = scenario(0.25).with_templates(2, 1.0, 8).plan();
        let mut seen = std::collections::HashMap::new();
        for (pb, pt) in base.iter().zip(&templated) {
            for (tb, tt) in pb.turns.iter().zip(&pt.turns) {
                let idx = tt.template.expect("pct 1.0 templates every turn");
                assert!(idx < 2);
                assert_eq!(tt.fresh_prompt.len(), tb.fresh_prompt.len() + 8);
                assert_eq!(&tt.fresh_prompt[8..], &tb.fresh_prompt[..], "suffix moved");
                // every turn with the same index carries the same 8 tokens
                let prefix = tt.fresh_prompt[..8].to_vec();
                assert_eq!(seen.entry(idx).or_insert_with(|| prefix.clone()), &prefix);
                assert_eq!(tb.followup, tt.followup);
                assert_eq!(tb.new_tokens, tt.new_tokens);
                assert_eq!(tb.delay, tt.delay);
                assert_eq!(tb.disconnect_after, tt.disconnect_after);
            }
        }
        assert_eq!(seen.len(), 2, "both templates should appear over 18 turns");
        // distinct templates are distinct token strings
        assert_ne!(seen[&0], seen[&1]);
    }

    #[test]
    fn template_share_knob_flips_only_the_template_flags() {
        let none = scenario(0.0).with_templates(3, 0.0, 8).plan();
        let half = scenario(0.0).with_templates(3, 0.5, 8).plan();
        let base = scenario(0.0).plan();
        let mut templated = 0;
        for ((pn, ph), pb) in none.iter().zip(&half).zip(&base) {
            for ((tn, th), tb) in pn.turns.iter().zip(&ph.turns).zip(&pb.turns) {
                // pct 0.0 with templates configured is the untemplated plan
                assert_eq!(tn.template, None);
                assert_eq!(tn.fresh_prompt, tb.fresh_prompt);
                match th.template {
                    Some(_) => {
                        templated += 1;
                        assert_eq!(&th.fresh_prompt[8..], &tn.fresh_prompt[..]);
                    }
                    None => assert_eq!(th.fresh_prompt, tn.fresh_prompt),
                }
            }
        }
        assert!(templated > 0, "50% over 18 turns should template at least one");
    }

    /// The chunked-prefill differential lever: the heavy-tail knob must
    /// stretch only the flagged prompts and leave every other draw —
    /// suffixes, gaps, budgets, chaos flags, template choices — exactly
    /// where the un-stretched scenario put it.
    #[test]
    fn long_prompts_stretch_only_flagged_turns() {
        let base = scenario(0.25).plan();
        // pct 0 with the knob configured: the stream exists and draws,
        // but no prompt moves — byte-identical to the base plan
        let off = scenario(0.25).with_long_prompts(0.0, 32).plan();
        for (pb, po) in base.iter().zip(&off) {
            for (tb, to) in pb.turns.iter().zip(&po.turns) {
                assert!(!to.long);
                assert_eq!(tb.fresh_prompt, to.fresh_prompt);
            }
        }
        // pct 1.0: every fresh prompt grows the same-length tail; all
        // other fields stay put
        let all = scenario(0.25).with_long_prompts(1.0, 32).plan();
        for (pb, pa) in base.iter().zip(&all) {
            for (tb, ta) in pb.turns.iter().zip(&pa.turns) {
                assert!(ta.long);
                assert_eq!(ta.fresh_prompt.len(), tb.fresh_prompt.len() + 32);
                assert_eq!(&ta.fresh_prompt[..tb.fresh_prompt.len()], &tb.fresh_prompt[..]);
                assert_eq!(tb.followup, ta.followup);
                assert_eq!(tb.new_tokens, ta.new_tokens);
                assert_eq!(tb.delay, ta.delay);
                assert_eq!(tb.disconnect_after, ta.disconnect_after);
            }
        }
        // a partial mix: flagged turns match the pct-1.0 stretch, the
        // rest match the base — the pct only flips which turns are long
        let half = scenario(0.25).with_long_prompts(0.5, 32).plan();
        let mut long_turns = 0;
        for ((pb, pa), ph) in base.iter().zip(&all).zip(&half) {
            for ((tb, ta), th) in pb.turns.iter().zip(&pa.turns).zip(&ph.turns) {
                if th.long {
                    long_turns += 1;
                    assert_eq!(th.fresh_prompt, ta.fresh_prompt);
                } else {
                    assert_eq!(th.fresh_prompt, tb.fresh_prompt);
                }
            }
        }
        assert!(long_turns > 0, "50% over 18 turns should stretch at least one");
        // composes with templates: the shared prefix stays at the front,
        // the tail goes on the end
        let both = scenario(0.25).with_templates(2, 1.0, 8).with_long_prompts(1.0, 32).plan();
        let tmpl_only = scenario(0.25).with_templates(2, 1.0, 8).plan();
        for (pt, pb) in tmpl_only.iter().zip(&both) {
            for (tt, tb) in pt.turns.iter().zip(&pb.turns) {
                assert_eq!(tt.template, tb.template);
                assert_eq!(&tb.fresh_prompt[..tt.fresh_prompt.len()], &tt.fresh_prompt[..]);
                assert_eq!(tb.fresh_prompt.len(), tt.fresh_prompt.len() + 32);
            }
        }
    }

    /// Backoff seeds ride stream 5 — they exist, differ per client, and
    /// never perturb the content/arrival/chaos/template streams that
    /// older builds drew from forks 1–4.
    #[test]
    fn backoff_seeds_are_per_client_and_perturb_nothing() {
        let plans = scenario(0.25).plan();
        let seeds: std::collections::HashSet<u64> =
            plans.iter().map(|p| p.backoff_seed).collect();
        assert_eq!(seeds.len(), plans.len(), "per-client seeds must differ");
        // replaying forks 1..=4 by hand reproduces client 0's first
        // prompt: stream 5 was appended after them, not spliced between
        let sc = scenario(0.25);
        let mut root = Rng::new(sc.seed);
        let mut content = root.fork(1);
        let mut c0 = content.fork(0);
        let plen = sc.prompt_dist.sample(&mut c0);
        let first: Vec<i32> = (0..plen)
            .map(|_| (c0.next_below(sc.vocab as u64 - 1) + 1) as i32)
            .collect();
        assert_eq!(plans[0].turns[0].fresh_prompt, first);
    }

    #[test]
    fn kill_schedule_is_deterministic_capped_and_distinct() {
        let sc = scenario(0.0);
        let w = Duration::from_millis(80);
        let a = sc.kill_schedule(3, 2, w);
        let b = sc.kill_schedule(3, 2, w);
        assert_eq!(a, b, "same seed, same schedule");
        assert_eq!(a.len(), 2);
        let ids: std::collections::HashSet<usize> = a.iter().map(|k| k.replica).collect();
        assert_eq!(ids.len(), 2, "kills hit distinct replicas");
        assert!(a.iter().all(|k| k.replica < 3 && k.after <= w));
        assert!(a.windows(2).all(|p| p[0].after <= p[1].after), "sorted by time");
        // never schedule the last survivor: asking for >= replicas kills
        // still leaves one standing, and a 1-replica fleet loses nobody
        assert_eq!(sc.kill_schedule(3, 9, w).len(), 2);
        assert!(sc.kill_schedule(1, 1, w).is_empty());
        // the schedule does not perturb the plans (its stream is forked
        // after every plan stream)
        assert_eq!(
            scenario(0.0).plan()[0].turns[0].fresh_prompt,
            sc.plan()[0].turns[0].fresh_prompt
        );
    }

    #[test]
    fn pctl_us_nearest_rank() {
        assert_eq!(pctl_us(&[], 99.0), 0);
        assert_eq!(pctl_us(&[5], 50.0), 5);
        let xs: Vec<u64> = (1..=100).collect();
        assert_eq!(pctl_us(&xs, 50.0), 50);
        assert_eq!(pctl_us(&xs, 99.0), 99);
        assert_eq!(pctl_us(&xs, 100.0), 100);
        // order-independent
        let mut rev: Vec<u64> = xs.iter().rev().copied().collect();
        rev.push(1000);
        assert_eq!(pctl_us(&rev, 99.0), 100);
    }

    #[test]
    fn parity_compares_completed_streams_with_equal_prompts() {
        let s = |client, turn, prompt: Vec<i32>, tokens: Vec<i32>, outcome| StreamOutcome {
            client,
            turn,
            prompt,
            tokens,
            outcome,
        };
        let mut a = LoadReport::default();
        let mut b = LoadReport::default();
        // same prompt, same tokens: fine
        a.streams.push(s(0, 0, vec![1, 2], vec![9], Outcome::Completed));
        b.streams.push(s(0, 0, vec![1, 2], vec![9], Outcome::Completed));
        // completed only on one side: not comparable
        a.streams.push(s(1, 0, vec![3], vec![7], Outcome::Completed));
        b.streams.push(s(1, 0, vec![3], vec![7], Outcome::Disconnected));
        // different prompts (divergent multi-turn context): not comparable
        a.streams.push(s(2, 1, vec![4, 5], vec![1], Outcome::Completed));
        b.streams.push(s(2, 1, vec![4, 6], vec![2], Outcome::Completed));
        assert!(parity_mismatches(&a, &b).is_empty());
        // same prompt, different tokens: the violation
        a.streams.push(s(3, 0, vec![8], vec![1, 1], Outcome::Completed));
        b.streams.push(s(3, 0, vec![8], vec![1, 2], Outcome::Completed));
        let diffs = parity_mismatches(&a, &b);
        assert_eq!(diffs.len(), 1);
        assert!(diffs[0].contains("client 3"));
    }

    #[test]
    fn report_rates() {
        let mut r = LoadReport::default();
        assert_eq!(r.shed_rate(), 0.0);
        r.streams.push(StreamOutcome {
            client: 0,
            turn: 0,
            prompt: vec![1],
            tokens: vec![],
            outcome: Outcome::Shed,
        });
        r.streams.push(StreamOutcome {
            client: 0,
            turn: 1,
            prompt: vec![1],
            tokens: vec![2, 3],
            outcome: Outcome::Completed,
        });
        r.shed = 1;
        r.tokens_streamed = 2;
        r.wall = Duration::from_secs(2);
        assert!((r.shed_rate() - 0.5).abs() < 1e-9);
        assert!((r.tokens_per_sec() - 1.0).abs() < 1e-9);
        assert_eq!(r.turns(), 2);
    }
}
