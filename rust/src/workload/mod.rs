//! Workload generation: the request streams the evaluation section runs.
//!
//! Three length distributions matter to the paper:
//! * **fixed**: every request exactly `len` tokens (Fig. 10/11 padding
//!   experiments),
//! * **half-padding**: valid length = padding/2 (the DRCE setup, §5.5),
//! * **heavy-tailed**: Zipf-like lengths — the variable-length reality
//!   DRCE exists for (the paper cites Du et al. [21] on GLUE corpora
//!   being *more* padded than half).
//!
//! Arrivals are either closed-loop (back-to-back batches) or open-loop
//! Poisson at a target rate.

pub mod loadgen;

use crate::coordinator::batcher::Request;
use crate::util::rng::Rng;
use std::time::Duration;

/// Sequence-length distribution.
#[derive(Clone, Copy, Debug)]
pub enum LengthDist {
    /// All requests exactly this long.
    Fixed(usize),
    /// Uniform in [lo, hi].
    Uniform(usize, usize),
    /// Valid = padding/2 (paper's DRCE experiments).
    HalfOf(usize),
    /// Zipf-ish heavy tail over [1, max] with skew s (~1.1 for GLUE-like).
    HeavyTail(usize, f64),
}

impl LengthDist {
    pub fn sample(&self, rng: &mut Rng) -> usize {
        match *self {
            LengthDist::Fixed(n) => n,
            LengthDist::Uniform(lo, hi) => rng.range(lo as u64, hi as u64) as usize,
            LengthDist::HalfOf(pad) => (pad / 2).max(1),
            LengthDist::HeavyTail(max, s) => {
                // zipf rank 1 is the most frequent; rank = length, so
                // short sequences dominate (heavy-tailed corpora, [21])
                (rng.zipf(max as u64, s) as usize).clamp(1, max)
            }
        }
    }
}

/// A reproducible request stream.
pub struct Generator {
    rng: Rng,
    dist: LengthDist,
    vocab: usize,
    next_id: u64,
}

impl Generator {
    pub fn new(seed: u64, dist: LengthDist, vocab: usize) -> Generator {
        Generator { rng: Rng::new(seed), dist, vocab, next_id: 0 }
    }

    pub fn request(&mut self) -> Request {
        let len = self.dist.sample(&mut self.rng);
        let tokens = (0..len)
            .map(|_| (self.rng.next_below(self.vocab as u64 - 1) + 1) as i32)
            .collect();
        let id = self.next_id;
        self.next_id += 1;
        Request::new(id, tokens)
    }

    pub fn batch(&mut self, n: usize) -> Vec<Request> {
        (0..n).map(|_| self.request()).collect()
    }

    /// Poisson inter-arrival gap for an open-loop client at `rate` req/s.
    pub fn next_gap(&mut self, rate: f64) -> Duration {
        Duration::from_secs_f64(self.rng.exponential(rate))
    }
}

/// Padding waste of a request set at a given padded length — the quantity
/// DRCE eliminates (1 - valid/padded).
pub fn padding_waste(requests: &[Request], pad: usize) -> f64 {
    let valid: usize = requests.iter().map(|r| r.len().min(pad)).sum();
    1.0 - valid as f64 / (requests.len() * pad) as f64
}

/// Multi-client generation scenario for the iteration-level scheduler
/// benches and tests: `clients` concurrent sessions, each with a prompt
/// drawn from `prompt_dist` and asking for `new_tokens` continuation
/// tokens. Decode steps of concurrent sessions should coalesce into
/// shared buckets, which shows up as mean batch occupancy > 1.
#[derive(Clone, Copy, Debug)]
pub struct GenScenario {
    pub clients: usize,
    pub new_tokens: usize,
    pub prompt_dist: LengthDist,
    pub vocab: usize,
    pub seed: u64,
}

impl GenScenario {
    /// The paper-flavoured default: N clients, short heavy-tailed prompts.
    pub fn concurrent(clients: usize, new_tokens: usize, max_prompt: usize, vocab: usize) -> Self {
        GenScenario {
            clients,
            new_tokens,
            prompt_dist: LengthDist::HeavyTail(max_prompt, 1.1),
            vocab,
            seed: 2209,
        }
    }

    /// One reproducible prompt per client.
    pub fn prompts(&self) -> Vec<Vec<i32>> {
        let mut gen = Generator::new(self.seed, self.prompt_dist, self.vocab);
        (0..self.clients).map(|_| gen.request().tokens).collect()
    }

    /// Upper bound on generated tokens (sessions may stop early at the
    /// longest compiled bucket).
    pub fn max_total_tokens(&self) -> usize {
        self.clients * self.new_tokens
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_dist_is_fixed() {
        let mut g = Generator::new(1, LengthDist::Fixed(12), 100);
        for _ in 0..10 {
            assert_eq!(g.request().len(), 12);
        }
    }

    #[test]
    fn half_padding_matches_paper_setup() {
        let mut g = Generator::new(1, LengthDist::HalfOf(64), 100);
        let reqs = g.batch(8);
        assert!(reqs.iter().all(|r| r.len() == 32));
        assert!((padding_waste(&reqs, 64) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn heavy_tail_mostly_short() {
        let mut g = Generator::new(7, LengthDist::HeavyTail(64, 1.2), 100);
        let lens: Vec<usize> = (0..500).map(|_| g.request().len()).collect();
        let short = lens.iter().filter(|&&l| l <= 16).count();
        let long = lens.iter().filter(|&&l| l > 48).count();
        assert!(short > long, "short {short} vs long {long}");
        assert!(lens.iter().all(|&l| (1..=64).contains(&l)));
    }

    #[test]
    fn ids_unique_and_tokens_in_vocab() {
        let mut g = Generator::new(3, LengthDist::Uniform(1, 8), 50);
        let reqs = g.batch(20);
        let mut ids: Vec<u64> = reqs.iter().map(|r| r.id).collect();
        ids.dedup();
        assert_eq!(ids.len(), 20);
        assert!(reqs.iter().all(|r| r.tokens.iter().all(|&t| (1..50).contains(&t))));
    }

    #[test]
    fn reproducible_by_seed() {
        let mut a = Generator::new(9, LengthDist::Uniform(1, 30), 100);
        let mut b = Generator::new(9, LengthDist::Uniform(1, 30), 100);
        for _ in 0..10 {
            let (ra, rb) = (a.request(), b.request());
            assert_eq!(ra.tokens, rb.tokens);
        }
    }

    #[test]
    fn gen_scenario_is_reproducible_and_sized() {
        let sc = GenScenario::concurrent(8, 16, 12, 100);
        let a = sc.prompts();
        let b = sc.prompts();
        assert_eq!(a, b, "same seed must give same prompts");
        assert_eq!(a.len(), 8);
        assert!(a.iter().all(|p| (1..=12).contains(&p.len())));
        assert_eq!(sc.max_total_tokens(), 128);
    }

    #[test]
    fn poisson_gaps_average_to_rate() {
        let mut g = Generator::new(5, LengthDist::Fixed(4), 100);
        let n = 2000;
        let total: f64 = (0..n).map(|_| g.next_gap(50.0).as_secs_f64()).sum();
        let mean = total / n as f64;
        assert!((mean - 0.02).abs() < 0.004, "mean gap {mean}");
    }
}
