//! Runtime: artifact manifest + PJRT execution.
//!
//! This is the boundary between L3 (Rust coordination) and L2/L1 (the AOT
//! compiled JAX/Pallas compute). Everything below this module is
//! numerics-free; everything above never touches Python.

pub mod artifact;
pub mod pjrt;

pub use artifact::{ArgMeta, DType, Manifest, VariantMeta};
pub use pjrt::{valid_len_arg, Device, DeviceStats};

/// Default artifacts directory (relative to the repo root / CWD).
pub const ARTIFACTS_DIR: &str = "artifacts";

/// Locate the artifacts directory: `$ENERGONAI_ARTIFACTS`, else walk up
/// from CWD looking for `artifacts/manifest.json` (so examples and tests
/// work from any subdirectory of the repo).
pub fn find_artifacts() -> anyhow::Result<std::path::PathBuf> {
    if let Ok(dir) = std::env::var("ENERGONAI_ARTIFACTS") {
        return Ok(dir.into());
    }
    let mut cur = std::env::current_dir()?;
    loop {
        let cand = cur.join(ARTIFACTS_DIR);
        if cand.join("manifest.json").exists() {
            return Ok(cand);
        }
        if !cur.pop() {
            anyhow::bail!(
                "artifacts/manifest.json not found above the current directory; run `make artifacts`"
            );
        }
    }
}
