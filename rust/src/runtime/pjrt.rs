//! PJRT execution: load HLO-text artifacts, compile once, execute many.
//!
//! One [`Device`] per worker thread — PJRT wrapper types hold raw pointers
//! and are not `Send`, which conveniently enforces the paper's one-device-
//! one-worker discipline. Compilation is cached per variant name; the
//! request path is `Literal`-in/`Literal`-out with shape/dtype validation
//! against the manifest.

use super::artifact::{ArgMeta, DType, Manifest, VariantMeta};
use crate::tensor::{IntTensor, Tensor, Value};
use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

/// A simulated accelerator: its own PJRT client + executable cache.
pub struct Device {
    pub id: usize,
    client: xla::PjRtClient,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    /// compile + execute counters (perf accounting / tests)
    pub stats: RefCell<DeviceStats>,
}

#[derive(Clone, Copy, Debug, Default)]
pub struct DeviceStats {
    pub compiles: u64,
    pub executions: u64,
}

impl Device {
    pub fn new(id: usize) -> anyhow::Result<Device> {
        Ok(Device {
            id,
            client: xla::PjRtClient::cpu()?,
            cache: RefCell::new(HashMap::new()),
            stats: RefCell::new(DeviceStats::default()),
        })
    }

    /// Compile (or fetch from cache) a variant's executable.
    pub fn load(&self, manifest: &Manifest, variant: &VariantMeta) -> anyhow::Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(&variant.name) {
            return Ok(exe.clone());
        }
        let path = manifest.hlo_path(variant);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("load {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compile {}: {e}", variant.name))?,
        );
        self.stats.borrow_mut().compiles += 1;
        self.cache.borrow_mut().insert(variant.name.clone(), exe.clone());
        Ok(exe)
    }

    /// Pre-compile a set of variants (worker init, §4.1.2's runtime
    /// initialization stage).
    pub fn warmup<'a>(
        &self,
        manifest: &Manifest,
        variants: impl IntoIterator<Item = &'a VariantMeta>,
    ) -> anyhow::Result<()> {
        for v in variants {
            self.load(manifest, v)?;
        }
        Ok(())
    }

    /// Execute a variant. Validates every argument against the manifest.
    pub fn execute(
        &self,
        manifest: &Manifest,
        variant: &VariantMeta,
        args: &[Value],
    ) -> anyhow::Result<Vec<Tensor>> {
        validate_args(variant, args)?;
        let exe = self.load(manifest, variant)?;
        let literals: Vec<xla::Literal> = args.iter().map(to_literal).collect::<anyhow::Result<_>>()?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("execute {}: {e}", variant.name))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch result of {}: {e}", variant.name))?;
        self.stats.borrow_mut().executions += 1;
        // aot.py lowers with return_tuple=True: unpack the tuple
        let parts = result
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untuple result of {}: {e}", variant.name))?;
        anyhow::ensure!(
            parts.len() == variant.outputs.len(),
            "{}: expected {} outputs, got {}",
            variant.name,
            variant.outputs.len(),
            parts.len()
        );
        parts
            .into_iter()
            .zip(&variant.outputs)
            .map(|(lit, meta)| from_literal(lit, meta))
            .collect()
    }

    /// Execute with a pre-converted weight tail ([`prepare`]): only the
    /// activations are converted per call. This is the hot-path variant —
    /// weights stay "resident on device" across batches (§Perf).
    pub fn execute_prepared(
        &self,
        manifest: &Manifest,
        variant: &VariantMeta,
        activations: &[Value],
        weights: &[xla::Literal],
    ) -> anyhow::Result<Vec<Tensor>> {
        anyhow::ensure!(
            activations.len() + weights.len() == variant.inputs.len(),
            "{}: {} activations + {} prepared weights != {} inputs",
            variant.name,
            activations.len(),
            weights.len(),
            variant.inputs.len()
        );
        for (i, (arg, meta)) in activations.iter().zip(&variant.inputs).enumerate() {
            anyhow::ensure!(
                shape_matches(meta, arg.shape()),
                "{}: activation {i} ({}) shape {:?}, expected {:?}",
                variant.name,
                meta.name,
                arg.shape(),
                meta.shape
            );
        }
        let exe = self.load(manifest, variant)?;
        let act_lits: Vec<xla::Literal> =
            activations.iter().map(to_literal).collect::<anyhow::Result<_>>()?;
        let all: Vec<&xla::Literal> = act_lits.iter().chain(weights.iter()).collect();
        let result = exe
            .execute::<&xla::Literal>(&all)
            .map_err(|e| anyhow::anyhow!("execute {}: {e}", variant.name))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch result of {}: {e}", variant.name))?;
        self.stats.borrow_mut().executions += 1;
        let parts = result
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untuple result of {}: {e}", variant.name))?;
        parts
            .into_iter()
            .zip(&variant.outputs)
            .map(|(lit, meta)| from_literal(lit, meta))
            .collect()
    }
}

fn shape_matches(meta: &ArgMeta, got: &[usize]) -> bool {
    meta.shape == got
}

fn validate_args(variant: &VariantMeta, args: &[Value]) -> anyhow::Result<()> {
    anyhow::ensure!(
        args.len() == variant.inputs.len(),
        "{}: expected {} args, got {}",
        variant.name,
        variant.inputs.len(),
        args.len()
    );
    for (i, (arg, meta)) in args.iter().zip(&variant.inputs).enumerate() {
        let ok = match (arg, meta.dtype) {
            (Value::F32(_), DType::F32) | (Value::I32(_), DType::I32) => true,
            _ => false,
        };
        anyhow::ensure!(ok, "{}: arg {i} ({}) dtype mismatch", variant.name, meta.name);
        anyhow::ensure!(
            shape_matches(meta, arg.shape()),
            "{}: arg {i} ({}) shape {:?}, expected {:?}",
            variant.name,
            meta.name,
            arg.shape(),
            meta.shape
        );
    }
    Ok(())
}

/// Host tensor -> device literal, one copy (no vec1+reshape round trip).
pub fn to_literal(v: &Value) -> anyhow::Result<xla::Literal> {
    let (ty, dims, bytes): (xla::ElementType, &[usize], &[u8]) = match v {
        Value::F32(t) => (
            xla::ElementType::F32,
            &t.shape,
            unsafe { std::slice::from_raw_parts(t.data.as_ptr() as *const u8, t.data.len() * 4) },
        ),
        Value::I32(t) => (
            xla::ElementType::S32,
            &t.shape,
            unsafe { std::slice::from_raw_parts(t.data.as_ptr() as *const u8, t.data.len() * 4) },
        ),
    };
    xla::Literal::create_from_shape_and_untyped_data(ty, dims, bytes)
        .map_err(|e| anyhow::anyhow!("create literal: {e}"))
}

/// Pre-convert a weight tail to device literals once ("weights resident on
/// device") — the §Perf optimization that removes per-batch re-upload.
pub fn prepare(values: &[Value]) -> anyhow::Result<Vec<xla::Literal>> {
    values.iter().map(to_literal).collect()
}

fn from_literal(lit: xla::Literal, meta: &ArgMeta) -> anyhow::Result<Tensor> {
    anyhow::ensure!(meta.dtype == DType::F32, "only f32 outputs supported");
    let data = lit
        .to_vec::<f32>()
        .map_err(|e| anyhow::anyhow!("output to_vec: {e}"))?;
    Ok(Tensor::new(&meta.shape, data))
}

/// Convenience: wrap valid lengths as the i32 arg every layer takes.
pub fn valid_len_arg(valid_lens: &[usize]) -> Value {
    Value::I32(IntTensor::from_vec(valid_lens.iter().map(|&v| v as i32).collect()))
}

#[cfg(test)]
mod tests {
    use super::*;

    // Execution against real artifacts lives in rust/tests/ (integration);
    // here we unit-test validation logic only.

    fn variant() -> VariantMeta {
        VariantMeta {
            name: "v".into(),
            kind: "layer_full".into(),
            preset: "tiny".into(),
            file: "v.hlo.txt".into(),
            batch: 2,
            seq: 16,
            tp: 1,
            t_bucket: 0,
            inputs: vec![
                ArgMeta { name: "x".into(), shape: vec![2, 16, 64], dtype: DType::F32 },
                ArgMeta { name: "valid_len".into(), shape: vec![2], dtype: DType::I32 },
            ],
            outputs: vec![ArgMeta { name: String::new(), shape: vec![2, 16, 64], dtype: DType::F32 }],
        }
    }

    #[test]
    fn validate_catches_wrong_count() {
        let v = variant();
        let args = vec![Value::F32(Tensor::zeros(&[2, 16, 64]))];
        assert!(validate_args(&v, &args).is_err());
    }

    #[test]
    fn validate_catches_wrong_shape() {
        let v = variant();
        let args = vec![
            Value::F32(Tensor::zeros(&[2, 16, 32])),
            valid_len_arg(&[16, 16]),
        ];
        let err = validate_args(&v, &args).unwrap_err().to_string();
        assert!(err.contains("shape"), "{err}");
    }

    #[test]
    fn validate_catches_wrong_dtype() {
        let v = variant();
        let args = vec![
            valid_len_arg(&[0; 2 * 16 * 64]).to_owned(),
            valid_len_arg(&[16, 16]),
        ];
        // first arg is i32 but must be f32 — shape check would also fail,
        // dtype fires first
        let err = validate_args(&v, &args).unwrap_err().to_string();
        assert!(err.contains("dtype"), "{err}");
    }

    #[test]
    fn validate_accepts_good_args() {
        let v = variant();
        let args = vec![
            Value::F32(Tensor::zeros(&[2, 16, 64])),
            valid_len_arg(&[16, 9]),
        ];
        assert!(validate_args(&v, &args).is_ok());
    }
}
