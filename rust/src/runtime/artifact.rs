//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime. The manifest records, for every AOT-lowered variant, its
//! HLO file, shape point (batch, seq, tp, t_bucket) and the exact argument
//! order/shapes/dtypes — the loader refuses to execute on any mismatch.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, OnceLock};

/// Supported artifact dtypes (all our variants use these two).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> anyhow::Result<DType> {
        match s {
            "float32" => Ok(DType::F32),
            "int32" => Ok(DType::I32),
            other => anyhow::bail!("unsupported dtype {other:?} in manifest"),
        }
    }
}

/// One executable argument or output.
#[derive(Clone, Debug)]
pub struct ArgMeta {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

/// One AOT-lowered executable.
#[derive(Clone, Debug)]
pub struct VariantMeta {
    pub name: String,
    pub kind: String,
    pub preset: String,
    pub file: String,
    pub batch: usize,
    pub seq: usize,
    pub tp: usize,
    pub t_bucket: usize,
    pub inputs: Vec<ArgMeta>,
    pub outputs: Vec<ArgMeta>,
}

impl VariantMeta {
    /// Rows the variant's row-shaped input expects (mlp_shard / DRCE).
    pub fn rows(&self) -> usize {
        if self.t_bucket > 0 {
            self.t_bucket
        } else {
            self.batch * self.seq
        }
    }
}

/// Model geometry recorded in the manifest (mirrors config::ModelConfig).
#[derive(Clone, Debug)]
pub struct ManifestConfig {
    pub name: String,
    pub hidden: usize,
    pub n_heads: usize,
    pub ffn: usize,
    pub vocab: usize,
    pub max_seq: usize,
    pub n_layers: usize,
}

/// Parsed `artifacts/manifest.json` + the directory it lives in.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub configs: BTreeMap<String, ManifestConfig>,
    pub variants: BTreeMap<String, VariantMeta>,
}

fn parse_arg(j: &Json, with_name: bool) -> anyhow::Result<ArgMeta> {
    let shape = j
        .arr_field("shape")?
        .iter()
        .map(|s| s.as_usize().ok_or_else(|| anyhow::anyhow!("bad shape entry")))
        .collect::<anyhow::Result<Vec<_>>>()?;
    Ok(ArgMeta {
        name: if with_name { j.str_field("name")?.to_string() } else { String::new() },
        shape,
        dtype: DType::parse(j.str_field("dtype")?)?,
    })
}

/// Process-wide memoized manifests, keyed by canonical artifacts dir.
static MANIFEST_CACHE: OnceLock<Mutex<BTreeMap<PathBuf, Arc<Manifest>>>> = OnceLock::new();

impl Manifest {
    /// Memoized [`Manifest::load`], keyed by the (canonicalized) artifacts
    /// path. Parsing the full-plan manifest costs ~2 ms
    /// (`BENCH_hotpath.json: manifest_parse_us`), and every engine, bench
    /// and test construction used to pay it again; the registry parses
    /// once per path per process. Artifacts are written by `make
    /// artifacts` and immutable while a process runs.
    pub fn cached(dir: impl AsRef<Path>) -> anyhow::Result<Arc<Manifest>> {
        let key = std::fs::canonicalize(dir.as_ref()).unwrap_or_else(|_| dir.as_ref().to_path_buf());
        let cache = MANIFEST_CACHE.get_or_init(|| Mutex::new(BTreeMap::new()));
        if let Some(m) = cache.lock().unwrap().get(&key) {
            return Ok(m.clone());
        }
        // parse outside the lock; a racing double-parse is harmless
        let m = Arc::new(Manifest::load(dir)?);
        cache.lock().unwrap().insert(key, m.clone());
        Ok(m)
    }

    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("read {path:?}: {e}. Run `make artifacts` first."))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("parse {path:?}: {e}"))?;
        anyhow::ensure!(
            j.usize_field("format_version")? == 1,
            "unsupported manifest format"
        );

        let mut configs = BTreeMap::new();
        for c in j.arr_field("configs")? {
            let mc = ManifestConfig {
                name: c.str_field("name")?.to_string(),
                hidden: c.usize_field("hidden")?,
                n_heads: c.usize_field("n_heads")?,
                ffn: c.usize_field("ffn")?,
                vocab: c.usize_field("vocab")?,
                max_seq: c.usize_field("max_seq")?,
                n_layers: c.usize_field("n_layers")?,
            };
            configs.insert(mc.name.clone(), mc);
        }

        let mut variants = BTreeMap::new();
        for v in j.arr_field("variants")? {
            let vm = VariantMeta {
                name: v.str_field("name")?.to_string(),
                kind: v.str_field("kind")?.to_string(),
                preset: v.str_field("preset")?.to_string(),
                file: v.str_field("file")?.to_string(),
                batch: v.usize_field("batch").unwrap_or(0),
                seq: v.usize_field("seq").unwrap_or(0),
                tp: v.usize_field("tp").unwrap_or(1),
                t_bucket: v.usize_field("t_bucket").unwrap_or(0),
                inputs: v
                    .arr_field("inputs")?
                    .iter()
                    .map(|a| parse_arg(a, true))
                    .collect::<anyhow::Result<_>>()?,
                outputs: v
                    .arr_field("outputs")?
                    .iter()
                    .map(|a| parse_arg(a, false))
                    .collect::<anyhow::Result<_>>()?,
            };
            variants.insert(vm.name.clone(), vm);
        }
        Ok(Manifest { dir, configs, variants })
    }

    pub fn get(&self, name: &str) -> anyhow::Result<&VariantMeta> {
        self.variants
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("variant {name:?} not in manifest (re-run `make artifacts`)"))
    }

    pub fn hlo_path(&self, v: &VariantMeta) -> PathBuf {
        self.dir.join(&v.file)
    }

    /// All variants of a kind for a preset.
    pub fn by_kind<'a>(&'a self, preset: &'a str, kind: &'a str) -> impl Iterator<Item = &'a VariantMeta> {
        self.variants
            .values()
            .filter(move |v| v.preset == preset && v.kind == kind)
    }

    /// Canonical variant names (must mirror python/compile/model.py).
    pub fn name_of(preset: &str, kind: &str, batch: usize, seq: usize, tp: usize, t_bucket: usize) -> String {
        match kind {
            "embed" => format!("{preset}_embed_b{batch}_s{seq}"),
            "layer_full" => format!("{preset}_layer_full_b{batch}_s{seq}"),
            "logits" => format!("{preset}_logits_b{batch}_s{seq}"),
            "attn_shard" => format!("{preset}_attn_shard_tp{tp}_b{batch}_s{seq}"),
            "mlp_shard" => {
                let rows = if t_bucket > 0 { t_bucket } else { batch * seq };
                format!("{preset}_mlp_shard_tp{tp}_r{rows}")
            }
            "drce_attn_shard" => {
                format!("{preset}_drce_attn_shard_tp{tp}_b{batch}_s{seq}_t{t_bucket}")
            }
            // incremental decode: cache capacity is implied (max_seq), so
            // decode names carry only the bucket width
            "embed_decode" => format!("{preset}_embed_decode_b{batch}"),
            "layer_full_decode" => format!("{preset}_layer_full_decode_b{batch}"),
            "attn_shard_decode" => format!("{preset}_attn_shard_decode_tp{tp}_b{batch}"),
            // speculative decode: the verify window size k rides in `seq`
            "embed_verify" => format!("{preset}_embed_verify_b{batch}_k{seq}"),
            "layer_full_verify" => format!("{preset}_layer_full_verify_b{batch}_k{seq}"),
            "attn_shard_verify" => {
                format!("{preset}_attn_shard_verify_tp{tp}_b{batch}_k{seq}")
            }
            "layer_full_kv" => format!("{preset}_layer_full_kv_b{batch}_s{seq}"),
            "attn_shard_kv" => format!("{preset}_attn_shard_kv_tp{tp}_b{batch}_s{seq}"),
            other => panic!("unknown variant kind {other:?}"),
        }
    }

    /// Shape points (batch, seq) available for a preset's `layer_full`.
    pub fn shape_points(&self, preset: &str) -> Vec<(usize, usize)> {
        let mut pts: Vec<(usize, usize)> = self
            .by_kind(preset, "layer_full")
            .map(|v| (v.batch, v.seq))
            .collect();
        pts.sort();
        pts.dedup();
        pts
    }

    /// Compiled decode bucket widths for `(preset, tp)`: every width for
    /// which the *whole* decode family exists (`embed_decode`, the layer
    /// decode variant, a seq=1 `logits`, and — under TP — the rows=width
    /// `mlp_shard`). The engine enables incremental decode only for these.
    pub fn decode_widths(&self, preset: &str, tp: usize) -> Vec<usize> {
        let kind = if tp == 1 { "layer_full_decode" } else { "attn_shard_decode" };
        let mut ws: Vec<usize> = self
            .by_kind(preset, kind)
            .filter(|v| tp == 1 || v.tp == tp)
            .map(|v| v.batch)
            .filter(|&w| {
                let mut need = vec![
                    Self::name_of(preset, "embed_decode", w, 0, 1, 0),
                    Self::name_of(preset, "logits", w, 1, 1, 0),
                ];
                if tp > 1 {
                    need.push(Self::name_of(preset, "mlp_shard", w, 1, tp, 0));
                }
                need.iter().all(|n| self.variants.contains_key(n))
            })
            .collect();
        ws.sort_unstable();
        ws.dedup();
        ws
    }

    /// Compiled speculative-verify buckets `(width, k)` for `(preset,
    /// tp)`: every pair for which the *whole* verify family exists
    /// (`embed_verify`, the layer verify variant, a seq=k `logits`
    /// scoring all window rows, and — under TP — the rows=width*k
    /// `mlp_shard`). The engine enables draft-and-verify decoding only
    /// for these.
    pub fn verify_points(&self, preset: &str, tp: usize) -> Vec<(usize, usize)> {
        let kind = if tp == 1 { "layer_full_verify" } else { "attn_shard_verify" };
        let mut pts: Vec<(usize, usize)> = self
            .by_kind(preset, kind)
            .filter(|v| tp == 1 || v.tp == tp)
            .map(|v| (v.batch, v.seq))
            .filter(|&(w, k)| {
                let mut need = vec![
                    Self::name_of(preset, "embed_verify", w, k, 1, 0),
                    Self::name_of(preset, "logits", w, k, 1, 0),
                ];
                if tp > 1 {
                    need.push(Self::name_of(preset, "mlp_shard", w, k, tp, 0));
                }
                need.iter().all(|n| self.variants.contains_key(n))
            })
            .collect();
        pts.sort_unstable();
        pts.dedup();
        pts
    }

    /// Do the cache-seeding `*_kv` prefill twins exist for every shape
    /// point of `(preset, tp)`? Required before the engine can route
    /// generation prefills through the KV path.
    pub fn has_kv_prefill(&self, preset: &str, tp: usize) -> bool {
        let points = self.shape_points(preset);
        !points.is_empty()
            && points.iter().all(|&(b, s)| {
                let name = if tp == 1 {
                    Self::name_of(preset, "layer_full_kv", b, s, 1, 0)
                } else {
                    Self::name_of(preset, "attn_shard_kv", b, s, tp, 0)
                };
                self.variants.contains_key(&name)
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format_version": 1,
      "configs": [{"name": "tiny", "hidden": 64, "n_heads": 2, "head_dim": 32,
                   "ffn": 256, "vocab": 128, "max_seq": 32, "n_layers": 4}],
      "variants": [
        {"name": "tiny_layer_full_b2_s16", "kind": "layer_full", "preset": "tiny",
         "file": "tiny_layer_full_b2_s16.hlo.txt", "batch": 2, "seq": 16, "tp": 1,
         "t_bucket": 0,
         "inputs": [{"name": "x", "shape": [2, 16, 64], "dtype": "float32"},
                    {"name": "valid_len", "shape": [2], "dtype": "int32"}],
         "outputs": [{"shape": [2, 16, 64], "dtype": "float32"}]}
      ]
    }"#;

    fn write_sample(dir: &std::path::Path) {
        std::fs::write(dir.join("manifest.json"), SAMPLE).unwrap();
    }

    #[test]
    fn parse_sample() {
        let dir = std::env::temp_dir().join(format!("eai-man-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        write_sample(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.configs["tiny"].hidden, 64);
        let v = m.get("tiny_layer_full_b2_s16").unwrap();
        assert_eq!(v.inputs.len(), 2);
        assert_eq!(v.inputs[1].dtype, DType::I32);
        assert_eq!(v.outputs[0].shape, vec![2, 16, 64]);
        assert_eq!(m.shape_points("tiny"), vec![(2, 16)]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn names_mirror_python() {
        assert_eq!(
            Manifest::name_of("tiny", "layer_full", 2, 16, 1, 0),
            "tiny_layer_full_b2_s16"
        );
        assert_eq!(
            Manifest::name_of("small", "drce_attn_shard", 4, 64, 2, 128),
            "small_drce_attn_shard_tp2_b4_s64_t128"
        );
        assert_eq!(Manifest::name_of("tiny", "mlp_shard", 2, 16, 2, 0), "tiny_mlp_shard_tp2_r32");
        assert_eq!(Manifest::name_of("tiny", "mlp_shard", 0, 0, 1, 16), "tiny_mlp_shard_tp1_r16");
        // the incremental-decode family
        assert_eq!(Manifest::name_of("tiny", "embed_decode", 2, 0, 1, 0), "tiny_embed_decode_b2");
        assert_eq!(
            Manifest::name_of("tiny", "layer_full_decode", 4, 0, 1, 0),
            "tiny_layer_full_decode_b4"
        );
        assert_eq!(
            Manifest::name_of("tiny", "attn_shard_decode", 2, 0, 2, 0),
            "tiny_attn_shard_decode_tp2_b2"
        );
        assert_eq!(
            Manifest::name_of("tiny", "layer_full_kv", 2, 16, 1, 0),
            "tiny_layer_full_kv_b2_s16"
        );
        assert_eq!(
            Manifest::name_of("small", "attn_shard_kv", 4, 64, 2, 0),
            "small_attn_shard_kv_tp2_b4_s64"
        );
        // the speculative-verify family (window size k rides in seq)
        assert_eq!(Manifest::name_of("tiny", "embed_verify", 2, 4, 1, 0), "tiny_embed_verify_b2_k4");
        assert_eq!(
            Manifest::name_of("tiny", "layer_full_verify", 2, 4, 1, 0),
            "tiny_layer_full_verify_b2_k4"
        );
        assert_eq!(
            Manifest::name_of("tiny", "attn_shard_verify", 2, 2, 2, 0),
            "tiny_attn_shard_verify_tp2_b2_k2"
        );
    }

    #[test]
    fn cached_load_is_memoized_per_path() {
        let dir = std::env::temp_dir().join(format!("eai-man-cache-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        write_sample(&dir);
        let a = Manifest::cached(&dir).unwrap();
        let b = Manifest::cached(&dir).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "second load re-parsed the manifest");
        assert_eq!(a.configs["tiny"].hidden, 64);
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Minimal manifest carrying a complete decode family for width 2.
    const DECODE_SAMPLE: &str = r#"{
      "format_version": 1,
      "configs": [{"name": "tiny", "hidden": 64, "n_heads": 2, "head_dim": 32,
                   "ffn": 256, "vocab": 128, "max_seq": 32, "n_layers": 4}],
      "variants": [
        {"name": "tiny_layer_full_b2_s16", "kind": "layer_full", "preset": "tiny",
         "file": "f.hlo.txt", "batch": 2, "seq": 16, "tp": 1, "t_bucket": 0,
         "inputs": [], "outputs": []},
        {"name": "tiny_layer_full_kv_b2_s16", "kind": "layer_full_kv", "preset": "tiny",
         "file": "f.hlo.txt", "batch": 2, "seq": 16, "tp": 1, "t_bucket": 0,
         "inputs": [], "outputs": []},
        {"name": "tiny_layer_full_decode_b2", "kind": "layer_full_decode", "preset": "tiny",
         "file": "f.hlo.txt", "batch": 2, "seq": 0, "tp": 1, "t_bucket": 0,
         "inputs": [], "outputs": []},
        {"name": "tiny_layer_full_decode_b4", "kind": "layer_full_decode", "preset": "tiny",
         "file": "f.hlo.txt", "batch": 4, "seq": 0, "tp": 1, "t_bucket": 0,
         "inputs": [], "outputs": []},
        {"name": "tiny_embed_decode_b2", "kind": "embed_decode", "preset": "tiny",
         "file": "f.hlo.txt", "batch": 2, "seq": 0, "tp": 1, "t_bucket": 0,
         "inputs": [], "outputs": []},
        {"name": "tiny_logits_b2_s1", "kind": "logits", "preset": "tiny",
         "file": "f.hlo.txt", "batch": 2, "seq": 1, "tp": 1, "t_bucket": 0,
         "inputs": [], "outputs": []}
      ]
    }"#;

    #[test]
    fn decode_widths_require_the_whole_family() {
        let dir = std::env::temp_dir().join(format!("eai-man-dec-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), DECODE_SAMPLE).unwrap();
        let m = Manifest::load(&dir).unwrap();
        // width 2 has embed_decode + logits_s1; width 4 is missing both
        assert_eq!(m.decode_widths("tiny", 1), vec![2]);
        // no attn_shard_decode at all => no tp=2 widths
        assert!(m.decode_widths("tiny", 2).is_empty());
        assert!(m.has_kv_prefill("tiny", 1));
        assert!(!m.has_kv_prefill("tiny", 2));
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Minimal manifest carrying a complete verify family for (2, 2) and
    /// incomplete ones for (2, 4) (no logits) and (4, 2) (no embed).
    const VERIFY_SAMPLE: &str = r#"{
      "format_version": 1,
      "configs": [{"name": "tiny", "hidden": 64, "n_heads": 2, "head_dim": 32,
                   "ffn": 256, "vocab": 128, "max_seq": 32, "n_layers": 4}],
      "variants": [
        {"name": "tiny_layer_full_verify_b2_k2", "kind": "layer_full_verify", "preset": "tiny",
         "file": "f.hlo.txt", "batch": 2, "seq": 2, "tp": 1, "t_bucket": 0,
         "inputs": [], "outputs": []},
        {"name": "tiny_layer_full_verify_b2_k4", "kind": "layer_full_verify", "preset": "tiny",
         "file": "f.hlo.txt", "batch": 2, "seq": 4, "tp": 1, "t_bucket": 0,
         "inputs": [], "outputs": []},
        {"name": "tiny_layer_full_verify_b4_k2", "kind": "layer_full_verify", "preset": "tiny",
         "file": "f.hlo.txt", "batch": 4, "seq": 2, "tp": 1, "t_bucket": 0,
         "inputs": [], "outputs": []},
        {"name": "tiny_attn_shard_verify_tp2_b2_k2", "kind": "attn_shard_verify", "preset": "tiny",
         "file": "f.hlo.txt", "batch": 2, "seq": 2, "tp": 2, "t_bucket": 0,
         "inputs": [], "outputs": []},
        {"name": "tiny_embed_verify_b2_k2", "kind": "embed_verify", "preset": "tiny",
         "file": "f.hlo.txt", "batch": 2, "seq": 2, "tp": 1, "t_bucket": 0,
         "inputs": [], "outputs": []},
        {"name": "tiny_embed_verify_b2_k4", "kind": "embed_verify", "preset": "tiny",
         "file": "f.hlo.txt", "batch": 2, "seq": 4, "tp": 1, "t_bucket": 0,
         "inputs": [], "outputs": []},
        {"name": "tiny_logits_b2_s2", "kind": "logits", "preset": "tiny",
         "file": "f.hlo.txt", "batch": 2, "seq": 2, "tp": 1, "t_bucket": 0,
         "inputs": [], "outputs": []},
        {"name": "tiny_mlp_shard_tp2_r4", "kind": "mlp_shard", "preset": "tiny",
         "file": "f.hlo.txt", "batch": 2, "seq": 2, "tp": 2, "t_bucket": 0,
         "inputs": [], "outputs": []}
      ]
    }"#;

    #[test]
    fn verify_points_require_the_whole_family() {
        let dir = std::env::temp_dir().join(format!("eai-man-ver-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), VERIFY_SAMPLE).unwrap();
        let m = Manifest::load(&dir).unwrap();
        // (2,2) is complete; (2,4) lacks its logits head; (4,2) lacks embed
        assert_eq!(m.verify_points("tiny", 1), vec![(2, 2)]);
        // tp=2 needs attn_shard_verify AND the rows=w*k mlp_shard
        assert_eq!(m.verify_points("tiny", 2), vec![(2, 2)]);
        // no tp=4 shards at all
        assert!(m.verify_points("tiny", 4).is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_variant_is_friendly_error() {
        let dir = std::env::temp_dir().join(format!("eai-man2-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        write_sample(&dir);
        let m = Manifest::load(&dir).unwrap();
        let err = m.get("nope").unwrap_err().to_string();
        assert!(err.contains("make artifacts"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
