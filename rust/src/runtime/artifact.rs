//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime. The manifest records, for every AOT-lowered variant, its
//! HLO file, shape point (batch, seq, tp, t_bucket) and the exact argument
//! order/shapes/dtypes — the loader refuses to execute on any mismatch.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Supported artifact dtypes (all our variants use these two).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> anyhow::Result<DType> {
        match s {
            "float32" => Ok(DType::F32),
            "int32" => Ok(DType::I32),
            other => anyhow::bail!("unsupported dtype {other:?} in manifest"),
        }
    }
}

/// One executable argument or output.
#[derive(Clone, Debug)]
pub struct ArgMeta {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

/// One AOT-lowered executable.
#[derive(Clone, Debug)]
pub struct VariantMeta {
    pub name: String,
    pub kind: String,
    pub preset: String,
    pub file: String,
    pub batch: usize,
    pub seq: usize,
    pub tp: usize,
    pub t_bucket: usize,
    pub inputs: Vec<ArgMeta>,
    pub outputs: Vec<ArgMeta>,
}

impl VariantMeta {
    /// Rows the variant's row-shaped input expects (mlp_shard / DRCE).
    pub fn rows(&self) -> usize {
        if self.t_bucket > 0 {
            self.t_bucket
        } else {
            self.batch * self.seq
        }
    }
}

/// Model geometry recorded in the manifest (mirrors config::ModelConfig).
#[derive(Clone, Debug)]
pub struct ManifestConfig {
    pub name: String,
    pub hidden: usize,
    pub n_heads: usize,
    pub ffn: usize,
    pub vocab: usize,
    pub max_seq: usize,
    pub n_layers: usize,
}

/// Parsed `artifacts/manifest.json` + the directory it lives in.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub configs: BTreeMap<String, ManifestConfig>,
    pub variants: BTreeMap<String, VariantMeta>,
}

fn parse_arg(j: &Json, with_name: bool) -> anyhow::Result<ArgMeta> {
    let shape = j
        .arr_field("shape")?
        .iter()
        .map(|s| s.as_usize().ok_or_else(|| anyhow::anyhow!("bad shape entry")))
        .collect::<anyhow::Result<Vec<_>>>()?;
    Ok(ArgMeta {
        name: if with_name { j.str_field("name")?.to_string() } else { String::new() },
        shape,
        dtype: DType::parse(j.str_field("dtype")?)?,
    })
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("read {path:?}: {e}. Run `make artifacts` first."))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("parse {path:?}: {e}"))?;
        anyhow::ensure!(
            j.usize_field("format_version")? == 1,
            "unsupported manifest format"
        );

        let mut configs = BTreeMap::new();
        for c in j.arr_field("configs")? {
            let mc = ManifestConfig {
                name: c.str_field("name")?.to_string(),
                hidden: c.usize_field("hidden")?,
                n_heads: c.usize_field("n_heads")?,
                ffn: c.usize_field("ffn")?,
                vocab: c.usize_field("vocab")?,
                max_seq: c.usize_field("max_seq")?,
                n_layers: c.usize_field("n_layers")?,
            };
            configs.insert(mc.name.clone(), mc);
        }

        let mut variants = BTreeMap::new();
        for v in j.arr_field("variants")? {
            let vm = VariantMeta {
                name: v.str_field("name")?.to_string(),
                kind: v.str_field("kind")?.to_string(),
                preset: v.str_field("preset")?.to_string(),
                file: v.str_field("file")?.to_string(),
                batch: v.usize_field("batch").unwrap_or(0),
                seq: v.usize_field("seq").unwrap_or(0),
                tp: v.usize_field("tp").unwrap_or(1),
                t_bucket: v.usize_field("t_bucket").unwrap_or(0),
                inputs: v
                    .arr_field("inputs")?
                    .iter()
                    .map(|a| parse_arg(a, true))
                    .collect::<anyhow::Result<_>>()?,
                outputs: v
                    .arr_field("outputs")?
                    .iter()
                    .map(|a| parse_arg(a, false))
                    .collect::<anyhow::Result<_>>()?,
            };
            variants.insert(vm.name.clone(), vm);
        }
        Ok(Manifest { dir, configs, variants })
    }

    pub fn get(&self, name: &str) -> anyhow::Result<&VariantMeta> {
        self.variants
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("variant {name:?} not in manifest (re-run `make artifacts`)"))
    }

    pub fn hlo_path(&self, v: &VariantMeta) -> PathBuf {
        self.dir.join(&v.file)
    }

    /// All variants of a kind for a preset.
    pub fn by_kind<'a>(&'a self, preset: &'a str, kind: &'a str) -> impl Iterator<Item = &'a VariantMeta> {
        self.variants
            .values()
            .filter(move |v| v.preset == preset && v.kind == kind)
    }

    /// Canonical variant names (must mirror python/compile/model.py).
    pub fn name_of(preset: &str, kind: &str, batch: usize, seq: usize, tp: usize, t_bucket: usize) -> String {
        match kind {
            "embed" => format!("{preset}_embed_b{batch}_s{seq}"),
            "layer_full" => format!("{preset}_layer_full_b{batch}_s{seq}"),
            "logits" => format!("{preset}_logits_b{batch}_s{seq}"),
            "attn_shard" => format!("{preset}_attn_shard_tp{tp}_b{batch}_s{seq}"),
            "mlp_shard" => {
                let rows = if t_bucket > 0 { t_bucket } else { batch * seq };
                format!("{preset}_mlp_shard_tp{tp}_r{rows}")
            }
            "drce_attn_shard" => {
                format!("{preset}_drce_attn_shard_tp{tp}_b{batch}_s{seq}_t{t_bucket}")
            }
            other => panic!("unknown variant kind {other:?}"),
        }
    }

    /// Shape points (batch, seq) available for a preset's `layer_full`.
    pub fn shape_points(&self, preset: &str) -> Vec<(usize, usize)> {
        let mut pts: Vec<(usize, usize)> = self
            .by_kind(preset, "layer_full")
            .map(|v| (v.batch, v.seq))
            .collect();
        pts.sort();
        pts.dedup();
        pts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format_version": 1,
      "configs": [{"name": "tiny", "hidden": 64, "n_heads": 2, "head_dim": 32,
                   "ffn": 256, "vocab": 128, "max_seq": 32, "n_layers": 4}],
      "variants": [
        {"name": "tiny_layer_full_b2_s16", "kind": "layer_full", "preset": "tiny",
         "file": "tiny_layer_full_b2_s16.hlo.txt", "batch": 2, "seq": 16, "tp": 1,
         "t_bucket": 0,
         "inputs": [{"name": "x", "shape": [2, 16, 64], "dtype": "float32"},
                    {"name": "valid_len", "shape": [2], "dtype": "int32"}],
         "outputs": [{"shape": [2, 16, 64], "dtype": "float32"}]}
      ]
    }"#;

    fn write_sample(dir: &std::path::Path) {
        std::fs::write(dir.join("manifest.json"), SAMPLE).unwrap();
    }

    #[test]
    fn parse_sample() {
        let dir = std::env::temp_dir().join(format!("eai-man-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        write_sample(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.configs["tiny"].hidden, 64);
        let v = m.get("tiny_layer_full_b2_s16").unwrap();
        assert_eq!(v.inputs.len(), 2);
        assert_eq!(v.inputs[1].dtype, DType::I32);
        assert_eq!(v.outputs[0].shape, vec![2, 16, 64]);
        assert_eq!(m.shape_points("tiny"), vec![(2, 16)]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn names_mirror_python() {
        assert_eq!(
            Manifest::name_of("tiny", "layer_full", 2, 16, 1, 0),
            "tiny_layer_full_b2_s16"
        );
        assert_eq!(
            Manifest::name_of("small", "drce_attn_shard", 4, 64, 2, 128),
            "small_drce_attn_shard_tp2_b4_s64_t128"
        );
        assert_eq!(Manifest::name_of("tiny", "mlp_shard", 2, 16, 2, 0), "tiny_mlp_shard_tp2_r32");
        assert_eq!(Manifest::name_of("tiny", "mlp_shard", 0, 0, 1, 16), "tiny_mlp_shard_tp1_r16");
    }

    #[test]
    fn missing_variant_is_friendly_error() {
        let dir = std::env::temp_dir().join(format!("eai-man2-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        write_sample(&dir);
        let m = Manifest::load(&dir).unwrap();
        let err = m.get("nope").unwrap_err().to_string();
        assert!(err.contains("make artifacts"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
