//! Host-side tensors: the activations and weights the coordinator moves
//! between executables, all-reduces across TP workers, and streams through
//! the pipeline. Deliberately minimal — heavy math happens inside the AOT
//! executables (L2/L1); the host only does residual adds, all-reduce sums
//! and DRCE pack/unpack.
//!
//! # Storage model (§Perf: the zero-copy hot path)
//!
//! A [`Tensor`] is a shape plus a [`Storage`]:
//!
//! * `Storage::Exclusive` — a uniquely-owned buffer, either a plain `Vec`
//!   (weights, test fixtures) or an arena-checked-out [`ArenaBuf`] that
//!   recycles itself on drop. All hot-path producers (`add`, `sum_of`,
//!   `scale`, `slice_cols`, DRCE pack/unpack) write into arena scratch, so
//!   at steady state they perform no heap allocation.
//! * `Storage::Shared` — an `Arc`-shared view (offset + length) of a
//!   buffer. [`Tensor::make_shared`] converts in place; afterwards `clone`
//!   and `slice_rows` are O(1) pointer bumps instead of copies. Mutating a
//!   shared tensor copies-on-write into arena scratch.
//!
//! `Storage` dereferences to `[f32]`, so `t.data[i]`, `t.data.iter()` and
//! friends read exactly as before.

pub mod drce;

use crate::memory::arena::{ArenaBuf, ArenaPool};
use crate::util::rng::Rng;
use std::fmt;
use std::sync::Arc;

/// Backing buffer of a [`Tensor`]: uniquely owned, or an `Arc`-shared view.
pub enum Storage {
    /// Uniquely-owned buffer (plain `Vec` or pooled arena scratch).
    Exclusive(ArenaBuf),
    /// Zero-copy view of `buf[off .. off + len]`. When the last view drops,
    /// a pooled underlying buffer returns to the arena.
    Shared { buf: Arc<ArenaBuf>, off: usize, len: usize },
}

impl Storage {
    pub fn as_slice(&self) -> &[f32] {
        match self {
            Storage::Exclusive(b) => b.as_slice(),
            Storage::Shared { buf, off, len } => &buf.as_slice()[*off..*off + *len],
        }
    }

    /// Is this an `Arc`-shared view (clones are O(1))?
    pub fn is_shared(&self) -> bool {
        matches!(self, Storage::Shared { .. })
    }

    /// Ensure exclusive ownership: unwrap a uniquely-held full-range `Arc`
    /// for free, otherwise copy-on-write into arena scratch.
    pub fn make_exclusive(&mut self) {
        let (off, len) = match self {
            Storage::Exclusive(_) => return,
            Storage::Shared { off, len, .. } => (*off, *len),
        };
        let prev = std::mem::replace(self, Storage::Exclusive(ArenaBuf::empty()));
        let arc = match prev {
            Storage::Shared { buf, .. } => buf,
            Storage::Exclusive(_) => unreachable!(),
        };
        *self = if off == 0 && len == arc.len() {
            match Arc::try_unwrap(arc) {
                Ok(b) => Storage::Exclusive(b),
                Err(arc) => Storage::Exclusive(ArenaBuf::copy_of(arc.as_slice())),
            }
        } else {
            Storage::Exclusive(ArenaBuf::copy_of(&arc.as_slice()[off..off + len]))
        };
    }

    /// Convert to a full-range shared buffer (no copy for exclusive
    /// storage; a view first materializes via [`Storage::make_exclusive`]).
    pub fn make_shared(&mut self) {
        match self {
            Storage::Shared { buf, off, len } if *off == 0 && *len == buf.len() => {}
            Storage::Shared { .. } => {
                self.make_exclusive();
                self.make_shared();
            }
            Storage::Exclusive(_) => {
                let prev = std::mem::replace(self, Storage::Exclusive(ArenaBuf::empty()));
                let b = match prev {
                    Storage::Exclusive(b) => b,
                    Storage::Shared { .. } => unreachable!(),
                };
                let len = b.len();
                *self = Storage::Shared { buf: Arc::new(b), off: 0, len };
            }
        }
    }
}

impl std::ops::Deref for Storage {
    type Target = [f32];
    fn deref(&self) -> &[f32] {
        self.as_slice()
    }
}

impl std::ops::DerefMut for Storage {
    fn deref_mut(&mut self) -> &mut [f32] {
        self.make_exclusive();
        match self {
            Storage::Exclusive(b) => b.as_mut_slice(),
            Storage::Shared { .. } => unreachable!("make_exclusive left a shared storage"),
        }
    }
}

impl Clone for Storage {
    fn clone(&self) -> Storage {
        match self {
            // shared views clone by reference — the zero-copy fast path
            Storage::Shared { buf, off, len } => {
                Storage::Shared { buf: buf.clone(), off: *off, len: *len }
            }
            Storage::Exclusive(b) if b.is_pooled() => {
                Storage::Exclusive(ArenaBuf::copy_of(b.as_slice()))
            }
            Storage::Exclusive(b) => Storage::Exclusive(ArenaBuf::owned(b.as_slice().to_vec())),
        }
    }
}

impl fmt::Debug for Storage {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(self.as_slice(), f)
    }
}

impl PartialEq for Storage {
    fn eq(&self, other: &Storage) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl PartialEq<Vec<f32>> for Storage {
    fn eq(&self, other: &Vec<f32>) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl PartialEq<[f32]> for Storage {
    fn eq(&self, other: &[f32]) -> bool {
        self.as_slice() == other
    }
}

impl From<Vec<f32>> for Storage {
    fn from(v: Vec<f32>) -> Storage {
        Storage::Exclusive(ArenaBuf::owned(v))
    }
}

impl From<ArenaBuf> for Storage {
    fn from(b: ArenaBuf) -> Storage {
        Storage::Exclusive(b)
    }
}

impl<'a> IntoIterator for &'a Storage {
    type Item = &'a f32;
    type IntoIter = std::slice::Iter<'a, f32>;
    fn into_iter(self) -> Self::IntoIter {
        self.as_slice().iter()
    }
}

impl<'a> IntoIterator for &'a mut Storage {
    type Item = &'a mut f32;
    type IntoIter = std::slice::IterMut<'a, f32>;
    fn into_iter(self) -> Self::IntoIter {
        self.iter_mut()
    }
}

/// Dense row-major f32 tensor.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Storage,
}

impl Tensor {
    pub fn new(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape: shape.to_vec(), data: Storage::from(data) }
    }

    pub fn from_storage(shape: &[usize], data: Storage) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor::new(shape, vec![0.0; shape.iter().product()])
    }

    pub fn full(shape: &[usize], v: f32) -> Tensor {
        Tensor::new(shape, vec![v; shape.iter().product()])
    }

    /// Arena-backed scratch tensor with **unspecified contents** — the
    /// caller must overwrite every element it exposes (DRCE pack, etc.).
    pub fn pooled_uninit(shape: &[usize]) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: Storage::Exclusive(ArenaPool::checkout(n)) }
    }

    /// Arena-backed zeroed tensor.
    pub fn pooled_zeros(shape: &[usize]) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: Storage::Exclusive(ArenaPool::checkout_zeroed(n)) }
    }

    /// N(0, std²) init — synthetic weights (seeded, reproducible).
    pub fn randn(shape: &[usize], std: f32, rng: &mut Rng) -> Tensor {
        let n = shape.iter().product();
        let mut data = Vec::with_capacity(n);
        for _ in 0..n {
            data.push(rng.normal_f32(std));
        }
        Tensor::new(shape, data)
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Bytes this tensor occupies (f32 host representation).
    pub fn bytes(&self) -> u64 {
        (self.len() * 4) as u64
    }

    /// Reinterpret the shape (same element count). Zero-copy — the storage
    /// moves unchanged.
    pub fn reshape(mut self, shape: &[usize]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    /// Convert the storage to an `Arc`-shared buffer in place: afterwards
    /// `clone()` and `slice_rows` are O(1). Call once where an activation
    /// fans out (executable arg + residual, pipeline handoff).
    pub fn make_shared(&mut self) {
        self.data.make_shared();
    }

    /// By-value variant of [`Tensor::make_shared`].
    pub fn into_shared(mut self) -> Tensor {
        self.data.make_shared();
        self
    }

    /// The full-range shared buffer behind this tensor, if it is one
    /// (what `comm::collective::broadcast` puts on the wire).
    pub fn shared_full_arc(&self) -> Option<Arc<ArenaBuf>> {
        match &self.data {
            Storage::Shared { buf, off: 0, len } if *len == buf.len() => Some(buf.clone()),
            _ => None,
        }
    }

    /// Last-axis length; tensors are treated as (rows, cols) row-major.
    pub fn cols(&self) -> usize {
        *self.shape.last().expect("scalar tensor has no cols")
    }

    pub fn rows(&self) -> usize {
        self.len() / self.cols()
    }

    pub fn row(&self, r: usize) -> &[f32] {
        let c = self.cols();
        &self.data[r * c..(r + 1) * c]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        let c = self.cols();
        &mut self.data[r * c..(r + 1) * c]
    }

    /// Elementwise `self += other` (residual adds, all-reduce accumulation).
    pub fn add_assign(&mut self, other: &Tensor) {
        assert_eq!(self.shape, other.shape, "add_assign shape mismatch");
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += b;
        }
    }

    /// `self + other`, written into arena scratch (no fresh allocation at
    /// steady state).
    pub fn add(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape, "add shape mismatch");
        let mut buf = ArenaBuf::copy_of(&self.data);
        for (a, b) in buf.as_mut_slice().iter_mut().zip(other.data.iter()) {
            *a += b;
        }
        Tensor::from_storage(&self.shape, Storage::Exclusive(buf))
    }

    /// Sum a set of same-shape tensors into arena scratch (host all-reduce
    /// epilogue).
    pub fn sum_of(parts: &[Tensor]) -> Tensor {
        assert!(!parts.is_empty());
        let mut buf = ArenaBuf::copy_of(&parts[0].data);
        for p in &parts[1..] {
            assert_eq!(parts[0].shape, p.shape, "sum_of shape mismatch");
            for (a, b) in buf.as_mut_slice().iter_mut().zip(p.data.iter()) {
                *a += b;
            }
        }
        Tensor::from_storage(&parts[0].shape, Storage::Exclusive(buf))
    }

    /// Shared bounds check for the `slice_*` family: `[a, b)` must sit
    /// inside `0..n`.
    #[inline]
    fn check_slice_range(a: usize, b: usize, n: usize, what: &str) {
        assert!(a <= b && b <= n, "bad {what} slice [{a}, {b}) of {n}");
    }

    /// Column slice [c0, c1) of a 2-D tensor — weight sharding. Single pass
    /// of `extend_from_slice` over precomputed row ranges into arena
    /// scratch; the contiguous full-width case is one memcpy (or a shared
    /// O(1) view when the storage already is one).
    #[inline]
    pub fn slice_cols(&self, c0: usize, c1: usize) -> Tensor {
        assert_eq!(self.rank(), 2);
        let (rows, cols) = (self.shape[0], self.shape[1]);
        Self::check_slice_range(c0, c1, cols, "col");
        let w = c1 - c0;
        if w == cols {
            // contiguous full-width fast path: the slice IS the buffer
            if self.data.is_shared() {
                return Tensor { shape: vec![rows, w], data: self.data.clone() };
            }
            return Tensor::from_storage(
                &[rows, w],
                Storage::Exclusive(ArenaBuf::copy_of(&self.data)),
            );
        }
        let src: &[f32] = &self.data;
        let mut buf = ArenaPool::checkout_empty(rows * w);
        {
            let v = buf.vec_mut();
            let mut start = c0;
            for _ in 0..rows {
                v.extend_from_slice(&src[start..start + w]);
                start += cols;
            }
        }
        Tensor::from_storage(&[rows, w], Storage::Exclusive(buf))
    }

    /// Row slice [r0, r1) of a 2-D tensor. On shared storage this is a
    /// zero-copy view; otherwise it copies into arena scratch.
    pub fn slice_rows(&self, r0: usize, r1: usize) -> Tensor {
        let cols = self.cols();
        Self::check_slice_range(r0, r1, self.rows(), "row");
        let shape = vec![r1 - r0, cols];
        match &self.data {
            Storage::Shared { buf, off, .. } => Tensor {
                shape,
                data: Storage::Shared {
                    buf: buf.clone(),
                    off: off + r0 * cols,
                    len: (r1 - r0) * cols,
                },
            },
            _ => Tensor {
                shape,
                data: Storage::Exclusive(ArenaBuf::copy_of(&self.data[r0 * cols..r1 * cols])),
            },
        }
    }

    /// 1-D slice [a, b) — bias sharding helper (the rank-1 sibling of
    /// [`Tensor::slice_rows`]), copied into arena scratch.
    pub fn slice_rows_1d(&self, a: usize, b: usize) -> Tensor {
        assert_eq!(self.rank(), 1);
        Self::check_slice_range(a, b, self.len(), "1-d");
        Tensor::from_storage(
            &[b - a],
            Storage::Exclusive(ArenaBuf::copy_of(&self.data[a..b])),
        )
    }

    /// Scale every element (bias pre-division for row-sharded linears),
    /// into arena scratch.
    pub fn scale(&self, s: f32) -> Tensor {
        let mut buf = ArenaPool::checkout(self.len());
        for (d, v) in buf.as_mut_slice().iter_mut().zip(self.data.iter()) {
            *d = v * s;
        }
        Tensor::from_storage(&self.shape, Storage::Exclusive(buf))
    }

    /// Max |a - b| — test helper.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}", self.shape)?;
        if self.len() <= 8 {
            write!(f, " {:?}", self.data)?;
        }
        Ok(())
    }
}

/// Dense row-major i32 tensor (token ids, valid lengths, DRCE index maps).
#[derive(Clone, Debug, PartialEq)]
pub struct IntTensor {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

impl IntTensor {
    pub fn new(shape: &[usize], data: Vec<i32>) -> IntTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        IntTensor { shape: shape.to_vec(), data }
    }

    pub fn from_vec(data: Vec<i32>) -> IntTensor {
        IntTensor { shape: vec![data.len()], data }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

/// An argument to an executable: the two dtypes our artifacts use.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    F32(Tensor),
    I32(IntTensor),
}

impl Value {
    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F32(t) => &t.shape,
            Value::I32(t) => &t.shape,
        }
    }

    pub fn as_f32(&self) -> Option<&Tensor> {
        match self {
            Value::F32(t) => Some(t),
            _ => None,
        }
    }

    pub fn into_f32(self) -> Tensor {
        match self {
            Value::F32(t) => t,
            Value::I32(t) => panic!("expected f32 tensor, got i32 {:?}", t.shape),
        }
    }
}

impl From<Tensor> for Value {
    fn from(t: Tensor) -> Value {
        Value::F32(t)
    }
}

impl From<IntTensor> for Value {
    fn from(t: IntTensor) -> Value {
        Value::I32(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_checks() {
        let t = Tensor::new(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.rows(), 2);
        assert_eq!(t.cols(), 3);
        assert_eq!(t.row(1), &[4., 5., 6.]);
        assert_eq!(t.bytes(), 24);
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        Tensor::new(&[2, 2], vec![1.0]);
    }

    #[test]
    fn add_and_sum() {
        let a = Tensor::new(&[2, 2], vec![1., 2., 3., 4.]);
        let b = Tensor::full(&[2, 2], 1.0);
        assert_eq!(a.add(&b).data, vec![2., 3., 4., 5.]);
        let s = Tensor::sum_of(&[a.clone(), a.clone(), a]);
        assert_eq!(s.data, vec![3., 6., 9., 12.]);
    }

    #[test]
    fn slicing() {
        let t = Tensor::new(&[2, 4], (0..8).map(|v| v as f32).collect());
        assert_eq!(t.slice_cols(1, 3).data, vec![1., 2., 5., 6.]);
        assert_eq!(t.slice_rows(1, 2).data, vec![4., 5., 6., 7.]);
        assert_eq!(t.slice_cols(1, 3).shape, vec![2, 2]);
        let b = Tensor::new(&[4], vec![1., 2., 3., 4.]);
        assert_eq!(b.slice_rows_1d(1, 3).data, vec![2., 3.]);
        assert_eq!(b.slice_rows_1d(1, 3).shape, vec![2]);
    }

    #[test]
    #[should_panic]
    fn slice_rows_1d_rejects_rank2() {
        Tensor::zeros(&[2, 2]).slice_rows_1d(0, 1);
    }

    #[test]
    #[should_panic]
    fn slice_out_of_range_panics() {
        Tensor::new(&[4], vec![0.; 4]).slice_rows_1d(3, 5);
    }

    #[test]
    fn slice_cols_full_width_fast_path() {
        let t = Tensor::new(&[2, 4], (0..8).map(|v| v as f32).collect());
        let full = t.slice_cols(0, 4);
        assert_eq!(full, t);
        // on shared storage, the fast path is a zero-copy view
        let shared = t.into_shared();
        let view = shared.slice_cols(0, 4);
        assert!(view.data.is_shared());
        assert_eq!(view.data.as_ptr(), shared.data.as_ptr());
    }

    #[test]
    fn shared_views_are_zero_copy() {
        let t = Tensor::new(&[4, 3], (0..12).map(|v| v as f32).collect());
        let base = t.into_shared();
        let v = base.slice_rows(1, 3);
        assert_eq!(v.shape, vec![2, 3]);
        assert_eq!(v.data, vec![3., 4., 5., 6., 7., 8.]);
        // the view aliases the parent buffer: same address, offset by a row
        assert_eq!(v.data.as_ptr(), unsafe { base.data.as_ptr().add(3) });
        // clones of shared tensors are O(1) and alias too
        let c = base.clone();
        assert_eq!(c.data.as_ptr(), base.data.as_ptr());
    }

    #[test]
    fn copy_on_write_detaches_shared_views() {
        let t = Tensor::new(&[2, 2], vec![1., 2., 3., 4.]);
        let base = t.into_shared();
        let mut c = base.clone();
        c.row_mut(0)[0] = 9.0; // triggers CoW — base must be untouched
        assert_eq!(c.data, vec![9., 2., 3., 4.]);
        assert_eq!(base.data, vec![1., 2., 3., 4.]);
    }

    #[test]
    fn randn_reproducible() {
        let mut r1 = Rng::new(1);
        let mut r2 = Rng::new(1);
        let a = Tensor::randn(&[4, 4], 0.5, &mut r1);
        let b = Tensor::randn(&[4, 4], 0.5, &mut r2);
        assert_eq!(a, b);
        assert!(a.data.iter().any(|v| *v != 0.0));
    }

    #[test]
    fn scale_for_bias_division() {
        let b = Tensor::full(&[4], 2.0);
        let half = b.scale(0.5);
        assert_eq!(half.data, vec![1.0; 4]);
    }
}
