//! Host-side tensors: the activations and weights the coordinator moves
//! between executables, all-reduces across TP workers, and streams through
//! the pipeline. Deliberately minimal — heavy math happens inside the AOT
//! executables (L2/L1); the host only does residual adds, all-reduce sums
//! and DRCE pack/unpack.

pub mod drce;

use crate::util::rng::Rng;
use std::fmt;

/// Dense row-major f32 tensor.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn full(shape: &[usize], v: f32) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![v; shape.iter().product()] }
    }

    /// N(0, std²) init — synthetic weights (seeded, reproducible).
    pub fn randn(shape: &[usize], std: f32, rng: &mut Rng) -> Tensor {
        let n = shape.iter().product();
        let mut data = Vec::with_capacity(n);
        for _ in 0..n {
            data.push(rng.normal_f32(std));
        }
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Bytes this tensor occupies (f32 host representation).
    pub fn bytes(&self) -> u64 {
        (self.len() * 4) as u64
    }

    /// Reinterpret the shape (same element count).
    pub fn reshape(mut self, shape: &[usize]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    /// Last-axis length; tensors are treated as (rows, cols) row-major.
    pub fn cols(&self) -> usize {
        *self.shape.last().expect("scalar tensor has no cols")
    }

    pub fn rows(&self) -> usize {
        self.len() / self.cols()
    }

    pub fn row(&self, r: usize) -> &[f32] {
        let c = self.cols();
        &self.data[r * c..(r + 1) * c]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        let c = self.cols();
        &mut self.data[r * c..(r + 1) * c]
    }

    /// Elementwise `self += other` (residual adds, all-reduce accumulation).
    pub fn add_assign(&mut self, other: &Tensor) {
        assert_eq!(self.shape, other.shape, "add_assign shape mismatch");
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    /// `self + other` (allocating).
    pub fn add(&self, other: &Tensor) -> Tensor {
        let mut out = self.clone();
        out.add_assign(other);
        out
    }

    /// Sum a set of same-shape tensors (host all-reduce epilogue).
    pub fn sum_of(parts: &[Tensor]) -> Tensor {
        assert!(!parts.is_empty());
        let mut out = parts[0].clone();
        for p in &parts[1..] {
            out.add_assign(p);
        }
        out
    }

    /// Column slice [c0, c1) of a 2-D tensor — weight sharding.
    pub fn slice_cols(&self, c0: usize, c1: usize) -> Tensor {
        assert_eq!(self.rank(), 2);
        let (rows, cols) = (self.shape[0], self.shape[1]);
        assert!(c0 <= c1 && c1 <= cols);
        let w = c1 - c0;
        let mut data = Vec::with_capacity(rows * w);
        for r in 0..rows {
            data.extend_from_slice(&self.data[r * cols + c0..r * cols + c1]);
        }
        Tensor { shape: vec![rows, w], data }
    }

    /// Row slice [r0, r1) of a 2-D tensor.
    pub fn slice_rows(&self, r0: usize, r1: usize) -> Tensor {
        let cols = self.cols();
        assert!(r0 <= r1 && r1 <= self.rows());
        Tensor {
            shape: vec![r1 - r0, cols],
            data: self.data[r0 * cols..r1 * cols].to_vec(),
        }
    }

    /// Scale every element (bias pre-division for row-sharded linears).
    pub fn scale(&self, s: f32) -> Tensor {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|v| v * s).collect(),
        }
    }

    /// Max |a - b| — test helper.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}", self.shape)?;
        if self.len() <= 8 {
            write!(f, " {:?}", self.data)?;
        }
        Ok(())
    }
}

/// Dense row-major i32 tensor (token ids, valid lengths, DRCE index maps).
#[derive(Clone, Debug, PartialEq)]
pub struct IntTensor {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

impl IntTensor {
    pub fn new(shape: &[usize], data: Vec<i32>) -> IntTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        IntTensor { shape: shape.to_vec(), data }
    }

    pub fn from_vec(data: Vec<i32>) -> IntTensor {
        IntTensor { shape: vec![data.len()], data }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

/// An argument to an executable: the two dtypes our artifacts use.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    F32(Tensor),
    I32(IntTensor),
}

impl Value {
    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F32(t) => &t.shape,
            Value::I32(t) => &t.shape,
        }
    }

    pub fn as_f32(&self) -> Option<&Tensor> {
        match self {
            Value::F32(t) => Some(t),
            _ => None,
        }
    }

    pub fn into_f32(self) -> Tensor {
        match self {
            Value::F32(t) => t,
            Value::I32(t) => panic!("expected f32 tensor, got i32 {:?}", t.shape),
        }
    }
}

impl From<Tensor> for Value {
    fn from(t: Tensor) -> Value {
        Value::F32(t)
    }
}

impl From<IntTensor> for Value {
    fn from(t: IntTensor) -> Value {
        Value::I32(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_checks() {
        let t = Tensor::new(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.rows(), 2);
        assert_eq!(t.cols(), 3);
        assert_eq!(t.row(1), &[4., 5., 6.]);
        assert_eq!(t.bytes(), 24);
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        Tensor::new(&[2, 2], vec![1.0]);
    }

    #[test]
    fn add_and_sum() {
        let a = Tensor::new(&[2, 2], vec![1., 2., 3., 4.]);
        let b = Tensor::full(&[2, 2], 1.0);
        assert_eq!(a.add(&b).data, vec![2., 3., 4., 5.]);
        let s = Tensor::sum_of(&[a.clone(), a.clone(), a]);
        assert_eq!(s.data, vec![3., 6., 9., 12.]);
    }

    #[test]
    fn slicing() {
        let t = Tensor::new(&[2, 4], (0..8).map(|v| v as f32).collect());
        assert_eq!(t.slice_cols(1, 3).data, vec![1., 2., 5., 6.]);
        assert_eq!(t.slice_rows(1, 2).data, vec![4., 5., 6., 7.]);
        assert_eq!(t.slice_cols(1, 3).shape, vec![2, 2]);
    }

    #[test]
    fn randn_reproducible() {
        let mut r1 = Rng::new(1);
        let mut r2 = Rng::new(1);
        let a = Tensor::randn(&[4, 4], 0.5, &mut r1);
        let b = Tensor::randn(&[4, 4], 0.5, &mut r2);
        assert_eq!(a, b);
        assert!(a.data.iter().any(|v| *v != 0.0));
    }

    #[test]
    fn scale_for_bias_division() {
        let b = Tensor::full(&[4], 2.0);
        let half = b.scale(0.5);
        assert_eq!(half.data, vec![1.0; 4]);
    }
}
