//! DRCE host support (§4.3): sequence-length metadata → index maps, packed
//! layout bookkeeping, and the host pack/unpack used at pipeline/domain
//! boundaries. Mirrors `python/compile/kernels/pack.py::make_maps` — the
//! pytest suite and `rust/tests/drce_parity.rs` keep the two in lockstep.

use super::{IntTensor, Tensor};

/// Index maps for one batch: the engine binds these to the command it
/// broadcasts to all workers, so every worker packs identically.
#[derive(Clone, Debug, PartialEq)]
pub struct DrceMaps {
    /// (t_bucket,) — for each packed row, the flat padded position it came
    /// from; slack rows replicate row 0 (harmless compute, never read back).
    pub unpad_map: IntTensor,
    /// (batch*seq,) — for each padded position, its packed row, or
    /// `t_bucket` (sentinel selecting the appended zero row) for padding.
    pub pad_map: IntTensor,
    /// Valid token count (≤ t_bucket).
    pub n_valid: usize,
    pub t_bucket: usize,
    pub batch: usize,
    pub seq: usize,
}

/// Build DRCE maps for per-sequence valid lengths, packing into a
/// `t_bucket`-row matrix. Errors if the valid tokens overflow the bucket.
pub fn make_maps(valid_lens: &[usize], seq: usize, t_bucket: usize) -> anyhow::Result<DrceMaps> {
    let batch = valid_lens.len();
    let total: usize = valid_lens.iter().sum();
    anyhow::ensure!(
        total <= t_bucket,
        "{total} valid tokens exceed DRCE bucket {t_bucket}"
    );
    anyhow::ensure!(
        valid_lens.iter().all(|&v| v <= seq),
        "valid length exceeds padded seq {seq}"
    );
    let mut unpad = vec![0i32; t_bucket];
    let mut pad = vec![t_bucket as i32; batch * seq];
    let mut j = 0usize;
    for (b, &vl) in valid_lens.iter().enumerate() {
        for s in 0..vl {
            let flat = b * seq + s;
            unpad[j] = flat as i32;
            pad[flat] = j as i32;
            j += 1;
        }
    }
    Ok(DrceMaps {
        unpad_map: IntTensor::from_vec(unpad),
        pad_map: IntTensor::from_vec(pad),
        n_valid: total,
        t_bucket,
        batch,
        seq,
    })
}

/// Smallest bucket from `buckets` that fits `total` valid tokens.
pub fn pick_bucket(total: usize, buckets: &[usize]) -> Option<usize> {
    buckets.iter().copied().filter(|&b| b >= total).min()
}

/// Host pack: padded (batch*seq, h) → packed (t_bucket, h). The result is
/// arena scratch (recycled on drop) — every output row is written (slack
/// rows replicate row 0), so no zero-fill pass is needed.
pub fn pack(x: &Tensor, maps: &DrceMaps) -> Tensor {
    let h = x.cols();
    let mut out = Tensor::pooled_uninit(&[maps.t_bucket, h]);
    pack_into(x, maps, &mut out);
    out
}

/// Pack into caller-provided scratch of shape (t_bucket, h). Overwrites
/// every row — safe to reuse the same scratch across batches.
pub fn pack_into(x: &Tensor, maps: &DrceMaps, out: &mut Tensor) {
    let h = x.cols();
    assert_eq!(x.rows(), maps.batch * maps.seq, "padded rows mismatch");
    assert_eq!(out.shape, vec![maps.t_bucket, h], "pack scratch shape mismatch");
    for (j, &src) in maps.unpad_map.data.iter().enumerate() {
        out.row_mut(j).copy_from_slice(x.row(src as usize));
    }
}

/// Host unpack: packed (t_bucket, h) → padded (batch*seq, h), zeros in
/// pads. The result is arena scratch (recycled on drop).
pub fn unpack(packed: &Tensor, maps: &DrceMaps) -> Tensor {
    let h = packed.cols();
    let mut out = Tensor::pooled_uninit(&[maps.batch * maps.seq, h]);
    unpack_into(packed, maps, &mut out);
    out
}

/// Unpack into caller-provided scratch of shape (batch*seq, h). Every row
/// is either copied from `packed` or zero-filled in the same single pass —
/// no upfront zero-fill of the whole tensor, and safe to reuse scratch.
pub fn unpack_into(packed: &Tensor, maps: &DrceMaps, out: &mut Tensor) {
    let h = packed.cols();
    assert_eq!(packed.rows(), maps.t_bucket, "packed rows mismatch");
    assert_eq!(out.shape, vec![maps.batch * maps.seq, h], "unpack scratch shape mismatch");
    let cut = maps.t_bucket.min(maps.n_valid);
    for (i, &src) in maps.pad_map.data.iter().enumerate() {
        let row = out.row_mut(i);
        if (src as usize) < cut {
            row.copy_from_slice(packed.row(src as usize));
        } else {
            row.fill(0.0);
        }
    }
}

/// Allocating reference implementations of [`pack`]/[`unpack`] — the
/// pre-arena code path, kept verbatim for differential tests and the
/// before/after hot-path bench (`benches/hotpath.rs`).
pub mod reference {
    use super::{DrceMaps, Tensor};

    pub fn pack(x: &Tensor, maps: &DrceMaps) -> Tensor {
        let h = x.cols();
        assert_eq!(x.rows(), maps.batch * maps.seq, "padded rows mismatch");
        let mut out = Tensor::zeros(&[maps.t_bucket, h]);
        for (j, &src) in maps.unpad_map.data.iter().enumerate() {
            out.row_mut(j).copy_from_slice(x.row(src as usize));
        }
        out
    }

    pub fn unpack(packed: &Tensor, maps: &DrceMaps) -> Tensor {
        let h = packed.cols();
        assert_eq!(packed.rows(), maps.t_bucket, "packed rows mismatch");
        let mut out = Tensor::zeros(&[maps.batch * maps.seq, h]);
        for (i, &src) in maps.pad_map.data.iter().enumerate() {
            if (src as usize) < maps.t_bucket.min(maps.n_valid) {
                out.row_mut(i).copy_from_slice(packed.row(src as usize));
            }
        }
        out
    }
}

/// FLOP-savings ratio DRCE buys on the linear layers: valid / padded rows.
/// The paper's experiments set valid = pad/2 → ratio 0.5 (§5.5).
pub fn linear_row_ratio(valid_lens: &[usize], seq: usize) -> f64 {
    let total: usize = valid_lens.iter().sum();
    total as f64 / (valid_lens.len() * seq) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn maps_match_python_semantics() {
        let m = make_maps(&[5, 8, 2], 8, 16).unwrap();
        assert_eq!(m.n_valid, 15);
        // first sequence occupies packed rows 0..5 from flat 0..5
        assert_eq!(&m.unpad_map.data[0..5], &[0, 1, 2, 3, 4]);
        // second sequence starts at flat 8
        assert_eq!(m.unpad_map.data[5], 8);
        // pad positions map to the sentinel
        assert_eq!(m.pad_map.data[5], 16);
        assert_eq!(m.pad_map.data[7], 16);
        // slack rows replicate row 0
        assert_eq!(m.unpad_map.data[15], 0);
    }

    #[test]
    fn overflow_is_error() {
        assert!(make_maps(&[8, 8], 8, 15).is_err());
        assert!(make_maps(&[9], 8, 16).is_err());
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let mut rng = Rng::new(1);
        let seq = 8;
        let lens = [5usize, 8, 2];
        let m = make_maps(&lens, seq, 16).unwrap();
        let x = Tensor::randn(&[3 * seq, 4], 1.0, &mut rng);
        // zero pad rows like the batcher does
        let mut xz = x.clone();
        for (b, &vl) in lens.iter().enumerate() {
            for s in vl..seq {
                xz.row_mut(b * seq + s).fill(0.0);
            }
        }
        let packed = pack(&xz, &m);
        let back = unpack(&packed, &m);
        assert_eq!(back, xz);
    }

    #[test]
    fn pack_slack_rows_replicate_row0() {
        let m = make_maps(&[2], 4, 8).unwrap();
        let x = Tensor::new(&[4, 2], vec![1., 2., 3., 4., 0., 0., 0., 0.]);
        let packed = pack(&x, &m);
        assert_eq!(packed.row(0), &[1., 2.]);
        assert_eq!(packed.row(1), &[3., 4.]);
        // slack rows replicate row 0
        for j in 2..8 {
            assert_eq!(packed.row(j), &[1., 2.]);
        }
    }

    // Differential coverage of pack/pack_into/unpack/unpack_into against
    // the reference implementations (incl. scratch reuse) lives in
    // rust/tests/zero_copy.rs.

    #[test]
    fn bucket_picking() {
        assert_eq!(pick_bucket(10, &[8, 16, 32]), Some(16));
        assert_eq!(pick_bucket(33, &[8, 16, 32]), None);
        assert_eq!(pick_bucket(8, &[8, 16]), Some(8));
    }

    #[test]
    fn paper_half_padding_ratio() {
        assert!((linear_row_ratio(&[32; 4], 64) - 0.5).abs() < 1e-9);
    }
}
