//! Minimal TCP front-end: a line protocol over the engine, so the serving
//! stack can be driven by external clients (`energonai serve`).
//!
//! Protocol (one line per message, UTF-8):
//!   client:  `infer 12,7,42\n`   — comma-separated token ids
//!   server:  `ok 99\n`           — greedy next token
//!            `err <message>\n`
//!   client:  `gen 8 12,7,42\n`   — generate up to 8 continuation tokens
//!   server:  `tok 99\n`          — streamed as each engine step completes
//!            `...`
//!            `done 12,7,42,99,...\n` — the full sequence on completion
//!   client:  `stats\n`           — server: `ok <metrics summary>\n`
//!   client:  `quit\n`            — closes the connection.
//!
//! Requests flow through the engine's continuation batcher, so concurrent
//! clients — including every decode step of their generations — get
//! batched together exactly like the paper's engine.

use crate::coordinator::engine::{Engine, GenRef, GenRequest};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// A running server (listener thread + per-connection threads).
pub struct Server {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind `addr` (e.g. "127.0.0.1:0") and serve the engine.
    pub fn start(engine: Arc<Engine>, addr: &str) -> anyhow::Result<Server> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::spawn(move || {
            let mut conns = Vec::new();
            while !stop2.load(Ordering::SeqCst) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let engine = engine.clone();
                        conns.push(std::thread::spawn(move || handle_conn(stream, engine)));
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
            for c in conns {
                let _ = c.join();
            }
        });
        Ok(Server { addr: local, stop, handle: Some(handle) })
    }

    pub fn stop(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn handle_conn(stream: TcpStream, engine: Arc<Engine>) {
    let peer = stream.peer_addr().ok();
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break,
        };
        match dispatch(line.trim(), &engine) {
            Action::Close => break,
            Action::Reply(r) => {
                if writer.write_all(r.as_bytes()).is_err() {
                    break;
                }
            }
            Action::Stream(gref) => {
                // write each token line as the scheduler streams it —
                // TcpStream is unbuffered, so the client sees tokens as
                // engine steps complete
                if stream_tokens(&gref, |s| writer.write_all(s.as_bytes())).is_err() {
                    break;
                }
            }
        }
    }
    let _ = peer;
}

/// What one protocol line asks the connection loop to do.
pub enum Action {
    /// Write a single reply line.
    Reply(String),
    /// Stream a generation session (`tok …` lines, then `done …`).
    Stream(GenRef),
    /// Close the connection.
    Close,
}

/// Parse one request line into an [`Action`]. `gen` is non-blocking — the
/// session enters the continuation batcher and the returned `GenRef`
/// streams from the connection loop.
pub fn dispatch(line: &str, engine: &Engine) -> Action {
    if line == "quit" {
        return Action::Close;
    }
    if line == "stats" {
        return Action::Reply(format!("ok {}\n", engine.metrics_snapshot().summary()));
    }
    if let Some(rest) = line.strip_prefix("infer ") {
        return match parse_tokens(rest) {
            Some(tokens) => match engine.submit(tokens).and_then(|fut| fut.to_here()) {
                Ok(tok) => Action::Reply(format!("ok {tok}\n")),
                Err(e) => Action::Reply(format!("err {e}\n")),
            },
            None => Action::Reply("err malformed token list\n".to_string()),
        };
    }
    if let Some(rest) = line.strip_prefix("gen ") {
        let mut parts = rest.splitn(2, ' ');
        let n = parts.next().and_then(|n| n.trim().parse::<usize>().ok());
        let tokens = parts.next().and_then(parse_tokens);
        return match (n, tokens) {
            (Some(n), Some(tokens)) if n >= 1 => {
                match engine.generate_stream(GenRequest::new(tokens, n)) {
                    Ok(gref) => Action::Stream(gref),
                    Err(e) => Action::Reply(format!("err {e}\n")),
                }
            }
            _ => Action::Reply("err usage: gen <n> <t0,t1,...>\n".to_string()),
        };
    }
    Action::Reply("err unknown command (infer/gen/stats/quit)\n".to_string())
}

fn parse_tokens(csv: &str) -> Option<Vec<i32>> {
    let tokens: Result<Vec<i32>, _> = csv.split(',').map(|t| t.trim().parse::<i32>()).collect();
    match tokens {
        Ok(t) if !t.is_empty() => Some(t),
        _ => None,
    }
}

/// Drive one generation stream through `write`: a `tok <t>` line per
/// sampled token, then `done <full csv>` (or `err <msg>` on failure).
/// The outer Result is the transport's; protocol errors go to the client.
fn stream_tokens<W: FnMut(&str) -> std::io::Result<()>>(
    gref: &GenRef,
    mut write: W,
) -> std::io::Result<()> {
    loop {
        match gref.next() {
            Ok(Some(t)) => write(&format!("tok {t}\n"))?,
            Ok(None) => {
                let full = match gref.to_here() {
                    Ok(seq) => seq,
                    Err(e) => return write(&format!("err {e}\n")),
                };
                let csv: Vec<String> = full.iter().map(i32::to_string).collect();
                return write(&format!("done {}\n", csv.join(",")));
            }
            Err(e) => return write(&format!("err {e}\n")),
        }
    }
}

/// One request line → the full reply as a single string (None = close).
/// Streaming replies are drained to completion — handy for tests and
/// non-incremental callers; live connections use [`dispatch`] directly.
pub fn handle_line(line: &str, engine: &Engine) -> Option<String> {
    match dispatch(line, engine) {
        Action::Close => None,
        Action::Reply(r) => Some(r),
        Action::Stream(gref) => {
            let mut out = String::new();
            let _ = stream_tokens(&gref, |s| {
                out.push_str(s);
                Ok(())
            });
            Some(out)
        }
    }
}

#[cfg(test)]
mod tests {
    // Protocol behaviour is tested through dispatch/handle_line in the
    // integration suite (rust/tests/server_loop.rs) where a real engine
    // exists — an Engine is not constructible without AOT artifacts, so
    // grammar-only cases live there too.
}
