//! Minimal TCP front-end: a line protocol over the engine, so the serving
//! stack can be driven by external clients (`energonai serve`).
//!
//! Protocol (one line per message, UTF-8):
//!   client:  `infer 12,7,42\n`   — comma-separated token ids
//!   server:  `ok 99\n`           — greedy next token
//!            `err <message>\n`
//!   client:  `stats\n`           — server: `ok <metrics summary>\n`
//!   client:  `quit\n`            — closes the connection.
//!
//! Requests flow through the engine's dynamic batcher, so concurrent
//! clients get batched together exactly like the paper's engine.

use crate::coordinator::engine::Engine;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// A running server (listener thread + per-connection threads).
pub struct Server {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind `addr` (e.g. "127.0.0.1:0") and serve the engine.
    pub fn start(engine: Arc<Engine>, addr: &str) -> anyhow::Result<Server> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::spawn(move || {
            let mut conns = Vec::new();
            while !stop2.load(Ordering::SeqCst) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let engine = engine.clone();
                        conns.push(std::thread::spawn(move || handle_conn(stream, engine)));
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
            for c in conns {
                let _ = c.join();
            }
        });
        Ok(Server { addr: local, stop, handle: Some(handle) })
    }

    pub fn stop(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn handle_conn(stream: TcpStream, engine: Arc<Engine>) {
    let peer = stream.peer_addr().ok();
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break,
        };
        let reply = handle_line(line.trim(), &engine);
        match reply {
            Some(r) => {
                if writer.write_all(r.as_bytes()).is_err() {
                    break;
                }
            }
            None => break, // quit
        }
    }
    let _ = peer;
}

/// One request line → one reply line (None = close).
pub fn handle_line(line: &str, engine: &Engine) -> Option<String> {
    if line == "quit" {
        return None;
    }
    if line == "stats" {
        return Some(format!("ok {}\n", engine.metrics_snapshot().summary()));
    }
    if let Some(rest) = line.strip_prefix("infer ") {
        let tokens: Result<Vec<i32>, _> = rest.split(',').map(|t| t.trim().parse::<i32>()).collect();
        return Some(match tokens {
            Ok(tokens) if !tokens.is_empty() => match engine.submit(tokens) {
                Ok(fut) => match fut.to_here() {
                    Ok(tok) => format!("ok {tok}\n"),
                    Err(e) => format!("err {e}\n"),
                },
                Err(e) => format!("err {e}\n"),
            },
            _ => "err malformed token list\n".to_string(),
        });
    }
    Some("err unknown command (infer/stats/quit)\n".to_string())
}

#[cfg(test)]
mod tests {
    // Protocol parsing is tested through handle_line in the integration
    // suite (rust/tests/server_loop.rs) where a real engine exists; here we
    // only check the command grammar against a never-used engine is not
    // constructible without artifacts, so grammar-only cases live there too.
}
