//! Minimal TCP front-end: a line protocol over the engine, so the serving
//! stack can be driven by external clients (`energonai serve`).
//!
//! Protocol (one line per message, UTF-8):
//!   client:  `infer 12,7,42\n`   — comma-separated token ids
//!   server:  `ok 99\n`           — greedy next token
//!            `err <message>\n`
//!   client:  `gen 8 12,7,42\n`   — generate up to 8 continuation tokens
//!   server:  `tok 99\n`          — streamed as each engine step completes
//!            `...`
//!            `done 12,7,42,99,...\n` — the full sequence on completion
//!   client:  `stats\n`           — server: `ok <metrics summary>\n`
//!   client:  `fleet\n`           — server: `ok <per-replica rollup>\n`
//!                                  (fleet-backed servers only)
//!   client:  `quit\n`            — closes the connection.
//!
//! Two more reply forms matter under hostile traffic: malformed lines get
//! a structured `err <reason>\n` (the connection stays up — a garbled
//! client doesn't tear down its own stream), and when admission control
//! sheds a request the reply is
//! `busy <reason>: <n> prefills queued, retry after <ms> ms\n`,
//! distinguishable from a hard error so clients can back off and retry —
//! the hint is the engine's median observed time-to-first-token, so the
//! back-off tracks actual service time rather than a guess.
//!
//! Disconnect propagation: if a client drops mid-stream, the failed write
//! cancels the session ([`GenRef::cancel`]) — the engine purges it from
//! the batch queue (or evicts it at the next collector boundary) and
//! frees its K/V blocks on every worker, so a dead client costs no
//! further decode work and leaks nothing.
//!
//! Requests flow through the engine's continuation batcher, so concurrent
//! clients — including every decode step of their generations — get
//! batched together exactly like the paper's engine.
//!
//! The connection loop is dispatcher-agnostic: [`Server::start`] serves a
//! single [`Engine`], [`Server::start_fleet`] serves a replica [`Fleet`]
//! (requests route through session-affine placement, and the `fleet`
//! verb exposes the per-replica health rollup). The wire protocol is
//! identical either way — a client cannot tell how many replicas answer.

use crate::coordinator::engine::{Engine, GenRef, GenRequest};
use crate::coordinator::fleet::Fleet;
use crate::coordinator::Busy;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// A running server (listener thread + per-connection threads).
pub struct Server {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

/// The per-line dispatcher a connection loop runs — `dispatch` with its
/// engine (or fleet) captured.
type Dispatcher = dyn Fn(&str) -> Action + Send + Sync;

impl Server {
    /// Bind `addr` (e.g. "127.0.0.1:0") and serve the engine.
    pub fn start(engine: Arc<Engine>, addr: &str) -> anyhow::Result<Server> {
        Server::start_with(addr, move |line| dispatch(line, &engine))
    }

    /// Bind `addr` and serve a replica fleet: same wire protocol, with
    /// requests placed session-affinely and the extra `fleet` verb.
    pub fn start_fleet(fleet: Arc<Fleet>, addr: &str) -> anyhow::Result<Server> {
        Server::start_with(addr, move |line| dispatch_fleet(line, &fleet))
    }

    fn start_with<D>(addr: &str, dispatcher: D) -> anyhow::Result<Server>
    where
        D: Fn(&str) -> Action + Send + Sync + 'static,
    {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let dispatcher: Arc<Dispatcher> = Arc::new(dispatcher);
        let handle = std::thread::spawn(move || {
            let mut conns = Vec::new();
            while !stop2.load(Ordering::SeqCst) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let dispatcher = dispatcher.clone();
                        conns.push(std::thread::spawn(move || handle_conn(stream, dispatcher)));
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
            for c in conns {
                let _ = c.join();
            }
        });
        Ok(Server { addr: local, stop, handle: Some(handle) })
    }

    pub fn stop(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn handle_conn(stream: TcpStream, dispatcher: Arc<Dispatcher>) {
    let peer = stream.peer_addr().ok();
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break,
        };
        match dispatcher(line.trim()) {
            Action::Close => break,
            Action::Reply(r) => {
                if writer.write_all(r.as_bytes()).is_err() {
                    break;
                }
            }
            Action::Stream(gref) => {
                // write each token line as the scheduler streams it —
                // TcpStream is unbuffered, so the client sees tokens as
                // engine steps complete
                if stream_tokens(&gref, |s| writer.write_all(s.as_bytes())).is_err() {
                    // the client hung up mid-generation: cancel so the
                    // engine stops decoding for a dead socket and frees
                    // the session's K/V blocks on every worker
                    gref.cancel();
                    break;
                }
            }
        }
    }
    let _ = peer;
}

/// What one protocol line asks the connection loop to do.
pub enum Action {
    /// Write a single reply line.
    Reply(String),
    /// Stream a generation session (`tok …` lines, then `done …`).
    Stream(GenRef),
    /// Close the connection.
    Close,
}

/// One parsed protocol line, dispatcher-agnostic — [`dispatch`] and
/// [`dispatch_fleet`] map it onto their backend.
enum Cmd {
    Quit,
    Stats,
    /// The per-replica rollup (only meaningful on a fleet server).
    FleetStats,
    Infer(Vec<i32>),
    Gen(usize, Vec<i32>),
    /// Malformed / unknown: the full structured reply line.
    Bad(String),
}

fn parse_line(line: &str) -> Cmd {
    if line == "quit" {
        return Cmd::Quit;
    }
    if line == "stats" {
        return Cmd::Stats;
    }
    if line == "fleet" {
        return Cmd::FleetStats;
    }
    if let Some(rest) = line.strip_prefix("infer ") {
        return match parse_tokens(rest) {
            Some(tokens) => Cmd::Infer(tokens),
            None => Cmd::Bad("err infer: malformed token list\n".to_string()),
        };
    }
    if let Some(rest) = line.strip_prefix("gen ") {
        // parse each field separately so a garbled line gets a *specific*
        // structured reason, not a catch-all usage string
        let mut parts = rest.splitn(2, ' ');
        let count = parts.next().unwrap_or("");
        let n = match count.trim().parse::<usize>() {
            Ok(n) => n,
            Err(_) => {
                return Cmd::Bad(format!(
                    "err gen: malformed count {count:?} (usage: gen <n> <t0,t1,...>)\n"
                ))
            }
        };
        if n == 0 {
            return Cmd::Bad("err gen: count must be >= 1\n".to_string());
        }
        return match parts.next() {
            None => Cmd::Bad("err gen: missing token list\n".to_string()),
            Some(csv) => match parse_tokens(csv) {
                Some(t) => Cmd::Gen(n, t),
                None => Cmd::Bad("err gen: malformed token list\n".to_string()),
            },
        };
    }
    Cmd::Bad("err unknown command (infer/gen/stats/fleet/quit)\n".to_string())
}

/// Parse one request line into an [`Action`]. `gen` is non-blocking — the
/// session enters the continuation batcher and the returned `GenRef`
/// streams from the connection loop.
pub fn dispatch(line: &str, engine: &Engine) -> Action {
    match parse_line(line) {
        Cmd::Quit => Action::Close,
        Cmd::Stats => Action::Reply(format!("ok {}\n", engine.metrics_snapshot().summary())),
        Cmd::FleetStats => {
            Action::Reply("err fleet: not a fleet server (single engine)\n".to_string())
        }
        Cmd::Infer(tokens) => match engine.submit(tokens).and_then(|fut| fut.to_here()) {
            Ok(tok) => Action::Reply(format!("ok {tok}\n")),
            Err(e) => reject(&e),
        },
        Cmd::Gen(n, tokens) => match engine.generate_stream(GenRequest::new(tokens, n)) {
            Ok(gref) => Action::Stream(gref),
            Err(e) => reject(&e),
        },
        Cmd::Bad(reply) => Action::Reply(reply),
    }
}

/// [`dispatch`] against a replica fleet: identical wire protocol (the
/// streamed `GenRef` is the fleet's failover-transparent outer handle),
/// `stats` rolls up the whole fleet, and `fleet` adds the per-replica
/// health detail.
pub fn dispatch_fleet(line: &str, fleet: &Fleet) -> Action {
    match parse_line(line) {
        Cmd::Quit => Action::Close,
        Cmd::Stats => Action::Reply(format!("ok {}\n", fleet.stats().summary())),
        Cmd::FleetStats => Action::Reply(format!("ok {}\n", fleet.stats().detail())),
        Cmd::Infer(tokens) => match fleet.submit(tokens).and_then(|fut| fut.to_here()) {
            Ok(tok) => Action::Reply(format!("ok {tok}\n")),
            Err(e) => reject(&e),
        },
        Cmd::Gen(n, tokens) => match fleet.generate_stream(GenRequest::new(tokens, n)) {
            Ok(gref) => Action::Stream(gref),
            Err(e) => reject(&e),
        },
        Cmd::Bad(reply) => Action::Reply(reply),
    }
}

/// Map a submission failure to its reply line: a shed ([`Busy`]) request
/// gets the structured back-off form, anything else a hard `err`.
fn reject(e: &anyhow::Error) -> Action {
    match e.downcast_ref::<Busy>() {
        Some(b) => Action::Reply(format!(
            "busy {}: {} prefills queued, retry after {} ms\n",
            b.reason, b.queued, b.retry_after_ms
        )),
        None => Action::Reply(format!("err {e}\n")),
    }
}

fn parse_tokens(csv: &str) -> Option<Vec<i32>> {
    let tokens: Result<Vec<i32>, _> = csv.split(',').map(|t| t.trim().parse::<i32>()).collect();
    match tokens {
        Ok(t) if !t.is_empty() => Some(t),
        _ => None,
    }
}

/// Drive one generation stream through `write`: a `tok <t>` line per
/// sampled token, then `done <full csv>` (or `err <msg>` on failure).
/// The outer Result is the transport's; protocol errors go to the client.
fn stream_tokens<W: FnMut(&str) -> std::io::Result<()>>(
    gref: &GenRef,
    mut write: W,
) -> std::io::Result<()> {
    loop {
        match gref.next() {
            Ok(Some(t)) => write(&format!("tok {t}\n"))?,
            Ok(None) => {
                let full = match gref.to_here() {
                    Ok(seq) => seq,
                    Err(e) => return write(&format!("err {e}\n")),
                };
                let csv: Vec<String> = full.iter().map(i32::to_string).collect();
                return write(&format!("done {}\n", csv.join(",")));
            }
            Err(e) => return write(&format!("err {e}\n")),
        }
    }
}

/// One request line → the full reply as a single string (None = close).
/// Streaming replies are drained to completion — handy for tests and
/// non-incremental callers; live connections use [`dispatch`] directly.
pub fn handle_line(line: &str, engine: &Engine) -> Option<String> {
    drain_action(dispatch(line, engine))
}

/// [`handle_line`] for a fleet-backed server.
pub fn handle_line_fleet(line: &str, fleet: &Fleet) -> Option<String> {
    drain_action(dispatch_fleet(line, fleet))
}

fn drain_action(action: Action) -> Option<String> {
    match action {
        Action::Close => None,
        Action::Reply(r) => Some(r),
        Action::Stream(gref) => {
            let mut out = String::new();
            let _ = stream_tokens(&gref, |s| {
                out.push_str(s);
                Ok(())
            });
            Some(out)
        }
    }
}

#[cfg(test)]
mod tests {
    // Engine-backed protocol behaviour is tested through
    // dispatch/handle_line in the integration suite
    // (rust/tests/server_loop.rs) where a real engine exists — an Engine
    // is not constructible without AOT artifacts. The pure parsing layer
    // is fuzzed here.
    use super::*;

    #[test]
    fn parse_tokens_accepts_well_formed_lists() {
        assert_eq!(parse_tokens("1,2,3"), Some(vec![1, 2, 3]));
        assert_eq!(parse_tokens("7"), Some(vec![7]));
        assert_eq!(parse_tokens(" 4 , 8 , 15 "), Some(vec![4, 8, 15]));
        assert_eq!(parse_tokens("-1,0,2147483647"), Some(vec![-1, 0, i32::MAX]));
        assert_eq!(parse_tokens("-2147483648"), Some(vec![i32::MIN]));
    }

    #[test]
    fn parse_tokens_rejects_malformed_lists() {
        for bad in [
            "",
            " ",
            ",",
            "1,",
            ",1",
            "1,,2",
            "a",
            "1,b",
            "1;2",
            "1 2",
            "0x10",
            "1.5",
            "+",
            "-",
            "2147483648",           // i32 overflow
            "-2147483649",          // i32 underflow
            "99999999999999999999", // way past u64 too
            "1,2,\n",
            "\u{1F600}",
            "1,\u{0}",
        ] {
            assert_eq!(parse_tokens(bad), None, "{bad:?} must be rejected");
        }
    }

    #[test]
    fn fleet_verb_parses_and_unknown_commands_mention_it() {
        assert!(matches!(parse_line("fleet"), Cmd::FleetStats));
        assert!(matches!(parse_line("quit"), Cmd::Quit));
        assert!(matches!(parse_line("gen 3 1,2"), Cmd::Gen(3, _)));
        match parse_line("nonsense") {
            Cmd::Bad(r) => assert!(r.contains("fleet"), "{r:?}"),
            _ => panic!("unknown command must be Bad"),
        }
    }

    /// Every rejection path a hostile line can hit must keep the
    /// connection protocol well-formed: a single line, a known verb
    /// (`err`/`busy`), trailing newline.
    #[test]
    fn reject_distinguishes_busy_from_hard_errors() {
        let busy =
            anyhow::Error::new(Busy { reason: "queue-full", queued: 7, retry_after_ms: 40 });
        match reject(&busy) {
            Action::Reply(r) => {
                assert_eq!(r, "busy queue-full: 7 prefills queued, retry after 40 ms\n");
            }
            _ => panic!("busy must reply"),
        }
        let hard = anyhow::anyhow!("no compiled bucket fits");
        match reject(&hard) {
            Action::Reply(r) => {
                assert!(r.starts_with("err "), "{r:?}");
                assert!(r.ends_with('\n'));
                assert_eq!(r.lines().count(), 1);
            }
            _ => panic!("errors must reply"),
        }
    }
}
