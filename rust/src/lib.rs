//! # EnergonAI (reproduction)
//!
//! A faithful reproduction of **"EnergonAI: An Inference System for 10-100
//! Billion Parameter Transformer Models"** (Du et al., 2022) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the paper's contribution: a *hierarchy-controller*
//!   architecture. A centralized [`coordinator::Engine`] publishes tasks over
//!   an RPC-style command bus to SPMD workers ([`runtime::Worker`]) that run
//!   tensor-parallel shards and pipeline stages, plus the paper's three
//!   techniques: non-blocking pipeline parallelism
//!   ([`coordinator::pipeline`]), distributed redundant computation
//!   elimination ([`tensor::drce`] + the `drce_attn_shard` artifacts), and
//!   peer memory pooling ([`memory`]) — plus incremental decode through a
//!   paged per-session K/V cache ([`memory::kvcache`] + the `*_decode`
//!   artifacts), which removes per-token prefill recompute from the
//!   generation hot path, and speculative draft-and-verify decoding
//!   ([`coordinator::drafter`] + the `*_verify` artifacts), which commits
//!   up to k greedy tokens per engine pass losslessly.
//! * **L2 (python/compile/model.py)** — the transformer compute graph in
//!   JAX, AOT-lowered to HLO text artifacts loaded by [`runtime`].
//! * **L1 (python/compile/kernels/)** — Pallas kernels (fused attention,
//!   tiled MLP matmul, layernorm, DRCE pack/unpack) called from L2.
//!
//! Python never runs on the request path: `make artifacts` emits HLO text
//! once; the Rust binary is self-contained afterwards.
//!
//! Paper-scale experiments (8×A100, NVLink) are regenerated through a
//! discrete-event simulator ([`sim`]) driven by the same scheduling policies
//! and an analytic A100 roofline model ([`perf`]); real end-to-end execution
//! uses scaled-down model presets on the PJRT CPU client. See DESIGN.md for
//! the substitution table.

pub mod baselines;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod memory;
pub mod metrics;
pub mod model;
pub mod perf;
pub mod runtime;
pub mod server;
pub mod sim;
pub mod tensor;
pub mod util;
pub mod workload;

pub use config::{EngineConfig, ModelConfig, ParallelConfig};
pub use coordinator::Engine;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
