//! Configuration: model geometry, parallel layout, engine tuning.
//!
//! Model presets mirror `python/compile/model.py::PRESETS` exactly — the
//! manifest is cross-checked against these at load time. The GPT size table
//! used by Fig. 2 and the paper-scale simulations lives here too.

pub mod file;

use std::fmt;

/// GPT-style model geometry (mirrors the python `ModelConfig`).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub hidden: usize,
    pub n_heads: usize,
    pub vocab: usize,
    pub max_seq: usize,
    pub n_layers: usize,
    pub ffn_mult: usize,
}

impl ModelConfig {
    pub fn new(name: &str, hidden: usize, n_heads: usize, vocab: usize, max_seq: usize, n_layers: usize) -> Self {
        ModelConfig {
            name: name.to_string(),
            hidden,
            n_heads,
            vocab,
            max_seq,
            n_layers,
            ffn_mult: 4,
        }
    }

    pub fn head_dim(&self) -> usize {
        self.hidden / self.n_heads
    }

    pub fn ffn(&self) -> usize {
        self.hidden * self.ffn_mult
    }

    /// Parameters in one transformer layer (ln1+ln2, qkv, out-proj, fc1, fc2).
    pub fn params_per_layer(&self) -> u64 {
        let h = self.hidden as u64;
        let f = self.ffn() as u64;
        4 * h + (h * 3 * h + 3 * h) + (h * h + h) + (h * f + f) + (f * h + h)
    }

    /// Total parameters including embeddings and final layernorm.
    pub fn total_params(&self) -> u64 {
        let h = self.hidden as u64;
        self.params_per_layer() * self.n_layers as u64
            + (self.vocab as u64) * h        // wte (tied with the head)
            + (self.max_seq as u64) * h      // wpe
            + 2 * h                          // final layernorm
    }

    /// Bytes per layer at the given element width (paper uses FP16 => 2).
    pub fn layer_bytes(&self, elem: u64) -> u64 {
        self.params_per_layer() * elem
    }

    /// With n layers overridden — the paper customizes 12/20/24/30/40/48
    /// layer GPT-3 variants for its experiments.
    pub fn with_layers(&self, n_layers: usize) -> ModelConfig {
        let mut c = self.clone();
        c.n_layers = n_layers;
        c.name = format!("{}-{}l", self.name, n_layers);
        c
    }

    /// Scaled-down presets (real PJRT execution) — must match python.
    pub fn preset(name: &str) -> Option<ModelConfig> {
        Some(match name {
            "tiny" => ModelConfig::new("tiny", 64, 2, 128, 32, 4),
            "small" => ModelConfig::new("small", 256, 4, 512, 64, 8),
            "base" => ModelConfig::new("base", 512, 8, 2048, 128, 12),
            // Paper-scale: GPT-3 head config (96 heads × 128 dim), §5.1
            "gpt3" => ModelConfig::new("gpt3", 12288, 96, 51200, 2048, 96),
            _ => return None,
        })
    }

    /// The GPT family used by Fig. 2 (sizes from the GPT-3 paper, Table 2.1).
    pub fn gpt_family() -> Vec<ModelConfig> {
        vec![
            ModelConfig::new("gpt-125M", 768, 12, 51200, 2048, 12),
            ModelConfig::new("gpt-350M", 1024, 16, 51200, 2048, 24),
            ModelConfig::new("gpt-760M", 1536, 16, 51200, 2048, 24),
            ModelConfig::new("gpt-1.3B", 2048, 24, 51200, 2048, 24),
            ModelConfig::new("gpt-2.7B", 2560, 32, 51200, 2048, 32),
            ModelConfig::new("gpt-6.7B", 4096, 32, 51200, 2048, 32),
            ModelConfig::new("gpt-13B", 5120, 40, 51200, 2048, 40),
            ModelConfig::new("gpt-66B", 9216, 72, 51200, 2048, 64),
            ModelConfig::new("gpt-175B", 12288, 96, 51200, 2048, 96),
        ]
    }
}

impl fmt::Display for ModelConfig {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{} (h={}, heads={}, layers={}, {:.2}B params)",
            self.name,
            self.hidden,
            self.n_heads,
            self.n_layers,
            self.total_params() as f64 / 1e9
        )
    }
}

/// How the model is spread over devices: `tp` workers per stage × `pp`
/// stages (§4.1.3, §4.2). `tp * pp` devices total.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ParallelConfig {
    pub tp: usize,
    pub pp: usize,
}

impl ParallelConfig {
    pub fn new(tp: usize, pp: usize) -> Self {
        assert!(tp >= 1 && pp >= 1);
        ParallelConfig { tp, pp }
    }

    pub fn serial() -> Self {
        ParallelConfig { tp: 1, pp: 1 }
    }

    pub fn world_size(&self) -> usize {
        self.tp * self.pp
    }

    /// Device id for (stage, tp_rank): stage-major like the paper's Fig. 5.
    pub fn device_of(&self, stage: usize, tp_rank: usize) -> usize {
        assert!(stage < self.pp && tp_rank < self.tp);
        stage * self.tp + tp_rank
    }

    /// Contiguous layer range for a pipeline stage (embedding lives with
    /// stage 0, logits with the last stage — the paper notes the resulting
    /// slight imbalance in §5.4).
    pub fn stage_layers(&self, stage: usize, n_layers: usize) -> std::ops::Range<usize> {
        let base = n_layers / self.pp;
        let rem = n_layers % self.pp;
        let start = stage * base + stage.min(rem);
        let len = base + usize::from(stage < rem);
        start..start + len
    }
}

/// Engine tuning knobs (§4.2): thread pool width, queueing, batching.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Engine-side thread pool size (concurrent in-flight batches). For
    /// NBPP this bounds how many microbatches occupy pipeline stages.
    pub pool_threads: usize,
    /// Max requests the dynamic batcher packs into one batch.
    pub max_batch: usize,
    /// How long the batcher waits to fill a batch before dispatching.
    pub batch_timeout_us: u64,
    /// Collector watchdog deadline: an in-flight batch older than this is
    /// declared poisoned (a non-replier worker dropped the activation) and
    /// its pending `RRef`/sessions are failed instead of hanging forever.
    pub batch_deadline_ms: u64,
    /// Use the distributed consistency queue (§4.2). Disabling it is the
    /// ablation showing out-of-order hazards.
    pub consistency_queue: bool,
    /// Use DRCE packed execution (§4.3).
    pub drce: bool,
    /// Blocking collectives (FasterTransformer style) instead of NBPP.
    pub blocking_comms: bool,
    /// Incremental decode through the paged K/V cache: continuation steps
    /// run a single position against cached K/V instead of re-running the
    /// whole prefix. Requires the decode artifacts (`embed_decode`,
    /// `layer_full_decode`/`attn_shard_decode`); the engine silently falls
    /// back to re-prefill decode when they are missing from the manifest.
    /// Disabling this is also the baseline half of the decode bench.
    pub kv_cache: bool,
    /// Tiered K/V cache (§4.4 applied to generation state): cap every
    /// worker's device slab and spill cold sessions' blocks to a pooled
    /// host tier, staging them back before their next decode bucket.
    /// Off by default — the resident-only fast path is byte-identical.
    pub kv_spill: bool,
    /// Device-tier capacity in blocks per worker (required > 0 for
    /// `kv_spill`; 0 leaves the slab unbounded).
    pub kv_device_blocks: usize,
    /// Host-tier capacity in blocks (0 = unlimited).
    pub kv_host_blocks: usize,
    /// Spill trigger: fraction of `kv_device_blocks` in use.
    pub kv_spill_high_water: f64,
    /// Spill target: evict cold sessions down to this fraction.
    pub kv_spill_low_water: f64,
    /// Peer tier (§4.4 PMEP applied to generation state): how many blocks
    /// each worker may park in its ring peer's spare device memory. Cold
    /// victims park to the peer before spilling to host, and the coldest
    /// parked sessions demote peer → host under peer pressure. Requires
    /// `kv_spill`; 0 (the default) disables the tier and keeps the
    /// two-tier device/host path byte-identical.
    pub kv_peer_blocks: usize,
    /// Overlapped tier copier: give each worker a copier thread that runs
    /// host/peer staging memcpys behind the current forward, so sync
    /// prefetch stalls collapse to the residual settle wait. Builder-only
    /// knob (no TOML key); off by default — staging copies run inline on
    /// the worker thread exactly as before.
    pub kv_copier: bool,
    /// Shared-prefix K/V reuse: retain whole-block prompt prefixes in a
    /// refcounted registry and match new prompts against a trie at
    /// admission — a hit adopts the cached blocks copy-on-write and
    /// replays only the unmatched suffix, so templated traffic skips most
    /// of its prefill work. Requires the KV cache; off by default, and
    /// off is byte-identical to a build without the feature (no trie, no
    /// registry, no extra batch metadata).
    pub prefix_cache: bool,
    /// Speculative decode (draft-and-verify): a cheap drafter proposes
    /// tokens and one `*_verify` pass scores the whole window, committing
    /// the longest accepted prefix — tokens-per-pass > 1 at unchanged
    /// greedy streams (pinned empirically by the differential suite; the
    /// verify and decode kernels agree to float tolerance, so a
    /// near-argmax-tie is the theoretical exception). Requires the verify
    /// artifact family and the KV
    /// cache, and runs only under pp == 1 (acceptance is computed on the
    /// last stage, which must own every layer's cache); the engine falls
    /// back to plain decode whenever any of that is missing. Off by
    /// default: with it off, token streams are byte-identical to the
    /// non-speculative engine by construction (the verify path is never
    /// entered).
    pub speculative: bool,
    /// Largest verify window (committed token + drafted tokens) a
    /// speculative step may use; the engine picks the largest compiled
    /// k ≤ this that fits the session's remaining budget and context.
    pub spec_k: usize,
    /// Chunked prefill: prompts longer than this split into fixed-size
    /// windows that seed the paged KV cache incrementally, interleaving
    /// with decode buckets so a long prompt can no longer freeze every
    /// in-flight generation (the engine picks the largest compiled
    /// verify-family window k ≤ this as the chunk size). Requires the KV
    /// cache; 0 (the default) keeps the monolithic prefill path
    /// byte-identical to a build without the feature.
    pub prefill_chunk: usize,
    /// Decode-interleave ratio for chunked prefill: after this many
    /// consecutive chunk waves, waiting decode/verify continuations are
    /// scheduled ahead of the next chunk (minimum 1 — a long prompt
    /// yields after every `ratio` windows).
    pub chunk_decode_ratio: usize,
    /// Load shedding: max queued prefill requests before new submissions
    /// get a structured `busy` rejection (0 = unlimited queueing). Under
    /// SLO pressure the effective cap halves (an unlimited cap degrades
    /// to `2 * max_batch`).
    pub max_queue_depth: usize,
    /// Token-budget admission gate: new prefill buckets defer while the
    /// KV positions held by unfinished sessions exceed this (0 = off).
    pub admission_token_budget: usize,
    /// TTFT SLO target in ms (0 = untracked). Violations feed the
    /// Recorder's rolling pressure window, which tightens admission.
    pub slo_ttft_ms: u64,
    /// Per-token (TPOT) SLO target in ms (0 = untracked).
    pub slo_tpot_ms: u64,
    /// Graceful degradation before shedding: while the SLO pressure
    /// window votes "shedding", clamp each admitted session's
    /// `max_new_tokens` to this floor instead of replying `Busy`
    /// outright (0 = off; shed as before). Shorter answers drain the
    /// queue faster without turning load spikes into hard errors.
    pub pressure_max_new_tokens: usize,
    /// Chaos fault schedule, e.g. `"delay5ms@t3,drop@every16+7@w0"`
    /// (empty = no faults). Parsed by `coordinator::FaultPlan`; applied
    /// at the worker reply boundary so collectives never desynchronize.
    pub fault_plan: String,
    /// Seed for probabilistic fault selectors (`p<frac>`).
    pub fault_seed: u64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            pool_threads: 4,
            max_batch: 32,
            batch_timeout_us: 2_000,
            batch_deadline_ms: 30_000,
            consistency_queue: true,
            drce: false,
            blocking_comms: false,
            kv_cache: true,
            kv_spill: false,
            kv_device_blocks: 0,
            kv_host_blocks: 0,
            kv_spill_high_water: 0.90,
            kv_spill_low_water: 0.70,
            kv_peer_blocks: 0,
            kv_copier: false,
            prefix_cache: false,
            speculative: false,
            spec_k: 4,
            prefill_chunk: 0,
            chunk_decode_ratio: 1,
            max_queue_depth: 0,
            admission_token_budget: 0,
            slo_ttft_ms: 0,
            slo_tpot_ms: 0,
            pressure_max_new_tokens: 0,
            fault_plan: String::new(),
            fault_seed: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_python() {
        let t = ModelConfig::preset("tiny").unwrap();
        assert_eq!((t.hidden, t.n_heads, t.vocab, t.max_seq, t.n_layers), (64, 2, 128, 32, 4));
        let s = ModelConfig::preset("small").unwrap();
        assert_eq!((s.hidden, s.n_heads), (256, 4));
        assert!(ModelConfig::preset("nope").is_none());
    }

    #[test]
    fn gpt3_layer_params_match_paper() {
        // §4.4: one GPT3-175B layer has ~1.812e9 params, 3.375 GB in fp16
        let g = ModelConfig::preset("gpt3").unwrap();
        let p = g.params_per_layer();
        assert!((1.7e9..1.9e9).contains(&(p as f64)), "{p}");
        let gb = g.layer_bytes(2) as f64 / (1024.0 * 1024.0 * 1024.0);
        assert!((3.2..3.5).contains(&gb), "{gb}");
    }

    #[test]
    fn gpt_family_sizes() {
        let fam = ModelConfig::gpt_family();
        let small = fam.iter().find(|c| c.name == "gpt-125M").unwrap();
        let total = small.total_params() as f64;
        assert!((1.0e8..2.0e8).contains(&total), "{total}");
        let big = fam.iter().find(|c| c.name == "gpt-175B").unwrap();
        let total = big.total_params() as f64;
        assert!((1.6e11..1.85e11).contains(&total), "{total}");
    }

    #[test]
    fn stage_layers_partition() {
        let p = ParallelConfig::new(1, 4);
        let ranges: Vec<_> = (0..4).map(|s| p.stage_layers(s, 12)).collect();
        assert_eq!(ranges[0], 0..3);
        assert_eq!(ranges[3], 9..12);
        // uneven split: 10 layers on 4 stages -> 3,3,2,2
        let lens: Vec<_> = (0..4).map(|s| p.stage_layers(s, 10).len()).collect();
        assert_eq!(lens, vec![3, 3, 2, 2]);
        // covers every layer exactly once
        let mut covered = vec![false; 10];
        for s in 0..4 {
            for l in p.stage_layers(s, 10) {
                assert!(!covered[l]);
                covered[l] = true;
            }
        }
        assert!(covered.iter().all(|&c| c));
    }

    #[test]
    fn device_mapping_stage_major() {
        let p = ParallelConfig::new(2, 2);
        assert_eq!(p.world_size(), 4);
        assert_eq!(p.device_of(0, 0), 0);
        assert_eq!(p.device_of(0, 1), 1);
        assert_eq!(p.device_of(1, 0), 2);
    }
}
