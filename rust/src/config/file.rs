//! Launcher config files: `energonai serve --config cluster.toml` — the
//! "real config system" a deployable framework needs. CLI flags override
//! file values; the file covers every launch knob:
//!
//! ```toml
//! preset = "small"
//! seed = 42
//! warmup = true
//!
//! [parallel]
//! tp = 2
//! pp = 2
//!
//! [engine]
//! drce = true
//! blocking_comms = false
//! consistency_queue = true
//! kv_cache = true        # incremental decode via the paged K/V cache
//! kv_spill = true        # tiered cache: spill cold sessions to host
//! kv_device_blocks = 256 # device-tier cap per worker (blocks)
//! kv_host_blocks = 1024  # host-tier capacity (0 = unlimited)
//! kv_peer_blocks = 128   # peer tier: blocks parked in the ring peer (0 = off)
//! prefix_cache = true    # shared-prefix K/V reuse at admission
//! speculative = true     # draft-and-verify decode over the cache
//! spec_k = 4             # largest verify window (1 committed + k-1 drafts)
//! prefill_chunk = 64     # chunked prefill: split prompts longer than this (0 = off)
//! chunk_decode_ratio = 1 # decode buckets interleave after this many chunk waves
//! pool_threads = 4
//! max_batch = 32
//! batch_timeout_us = 2000
//! max_queue_depth = 64   # load shedding: busy-reject past this (0 = off)
//! admission_token_budget = 4096 # defer prefills past this KV load (0 = off)
//! slo_ttft_ms = 200      # TTFT SLO target feeding the pressure window
//! slo_tpot_ms = 50       # per-token SLO target
//! pressure_max_new_tokens = 8 # degrade: clamp budgets under pressure (0 = shed)
//! fault_plan = ""        # chaos schedule, e.g. "delay5ms@t3,drop@every16+7@w0"
//! fault_seed = 0         # seed for probabilistic fault selectors
//!
//! [model]
//! n_layers = 24          # customized layer count (paper §5.5)
//!
//! [memory]
//! mode = "pmep"          # resident | pmep | bminf
//! n_local = 10
//! lookahead = 2
//! time_scale = 1.0
//! ```

use crate::comm::topology::Link;
use crate::coordinator::engine::{LaunchConfig, MemoryMode};
use crate::memory::pool::PoolConfig;
use crate::util::toml::TomlDoc;

/// Build a [`LaunchConfig`] from a TOML document.
pub fn launch_from_doc(doc: &TomlDoc) -> anyhow::Result<LaunchConfig> {
    let preset = doc.str_or("preset", "tiny").to_string();
    let mut launch = LaunchConfig::preset(&preset);
    launch.seed = doc.usize_or("seed", 42) as u64;
    launch.warmup = doc.bool_or("warmup", true);
    launch = launch.with_parallel(doc.usize_or("parallel.tp", 1), doc.usize_or("parallel.pp", 1));

    launch.engine.drce = doc.bool_or("engine.drce", false);
    launch.engine.blocking_comms = doc.bool_or("engine.blocking_comms", false);
    launch.engine.consistency_queue = doc.bool_or("engine.consistency_queue", true);
    launch.engine.pool_threads = doc.usize_or("engine.pool_threads", 4);
    launch.engine.max_batch = doc.usize_or("engine.max_batch", 32);
    launch.engine.batch_timeout_us = doc.usize_or("engine.batch_timeout_us", 2000) as u64;
    launch.engine.batch_deadline_ms = doc.usize_or("engine.batch_deadline_ms", 30_000) as u64;
    launch.engine.kv_cache = doc.bool_or("engine.kv_cache", true);
    launch.engine.kv_spill = doc.bool_or("engine.kv_spill", false);
    launch.engine.kv_device_blocks = doc.usize_or("engine.kv_device_blocks", 0);
    launch.engine.kv_host_blocks = doc.usize_or("engine.kv_host_blocks", 0);
    launch.engine.kv_peer_blocks = doc.usize_or("engine.kv_peer_blocks", 0);
    launch.engine.kv_spill_high_water =
        doc.f64_or("engine.kv_spill_high_water", launch.engine.kv_spill_high_water);
    launch.engine.kv_spill_low_water =
        doc.f64_or("engine.kv_spill_low_water", launch.engine.kv_spill_low_water);
    launch.engine.prefix_cache = doc.bool_or("engine.prefix_cache", false);
    launch.engine.speculative = doc.bool_or("engine.speculative", false);
    launch.engine.spec_k = doc.usize_or("engine.spec_k", launch.engine.spec_k);
    launch.engine.prefill_chunk = doc.usize_or("engine.prefill_chunk", 0);
    launch.engine.chunk_decode_ratio =
        doc.usize_or("engine.chunk_decode_ratio", launch.engine.chunk_decode_ratio);
    launch.engine.max_queue_depth = doc.usize_or("engine.max_queue_depth", 0);
    launch.engine.admission_token_budget = doc.usize_or("engine.admission_token_budget", 0);
    launch.engine.slo_ttft_ms = doc.usize_or("engine.slo_ttft_ms", 0) as u64;
    launch.engine.slo_tpot_ms = doc.usize_or("engine.slo_tpot_ms", 0) as u64;
    launch.engine.pressure_max_new_tokens = doc.usize_or("engine.pressure_max_new_tokens", 0);
    launch.engine.fault_plan = doc.str_or("engine.fault_plan", "").to_string();
    launch.engine.fault_seed = doc.usize_or("engine.fault_seed", 0) as u64;
    // fail at load time, not at worker spawn, on an unparsable schedule
    crate::coordinator::FaultPlan::parse(&launch.engine.fault_plan, launch.engine.fault_seed)?;
    anyhow::ensure!(
        !launch.engine.speculative || launch.engine.spec_k >= 2,
        "engine.speculative requires engine.spec_k >= 2 (one committed token + >= 1 draft)"
    );
    anyhow::ensure!(
        !launch.engine.speculative || launch.engine.kv_cache,
        "engine.speculative requires engine.kv_cache (the verify pass scores against it)"
    );
    anyhow::ensure!(
        !launch.engine.kv_spill || launch.engine.kv_device_blocks > 0,
        "engine.kv_spill requires engine.kv_device_blocks > 0"
    );
    anyhow::ensure!(
        launch.engine.kv_peer_blocks == 0 || launch.engine.kv_spill,
        "engine.kv_peer_blocks requires engine.kv_spill (the peer tier sits between device and host)"
    );
    anyhow::ensure!(
        !launch.engine.prefix_cache || launch.engine.kv_cache,
        "engine.prefix_cache requires engine.kv_cache (adoption replays through the paged cache)"
    );
    anyhow::ensure!(
        launch.engine.prefill_chunk == 0 || launch.engine.kv_cache,
        "engine.prefill_chunk requires engine.kv_cache (chunks seed the paged cache)"
    );
    anyhow::ensure!(
        launch.engine.chunk_decode_ratio >= 1,
        "engine.chunk_decode_ratio must be >= 1 (a ratio of 0 would never run a chunk)"
    );
    anyhow::ensure!(
        launch.engine.kv_spill_low_water <= launch.engine.kv_spill_high_water
            && launch.engine.kv_spill_high_water <= 1.0
            && launch.engine.kv_spill_low_water >= 0.0,
        "kv spill water marks must satisfy 0 <= low <= high <= 1"
    );

    if let Some(n) = doc.get("model.n_layers").and_then(|v| v.as_usize()) {
        launch = launch.with_layers(n);
    }

    let mode = doc.str_or("memory.mode", "resident");
    launch.memory = match mode {
        "resident" => MemoryMode::Resident,
        "pmep" => {
            let mut pool = PoolConfig::pmep();
            pool.lookahead = doc.usize_or("memory.lookahead", pool.lookahead);
            pool.time_scale = doc.f64_or("memory.time_scale", pool.time_scale);
            if doc.str_or("memory.link", "nvlink") == "host" {
                pool.link = Link::HOST;
            }
            MemoryMode::Pmep { n_local: doc.usize_or("memory.n_local", usize::MAX), pool }
        }
        "bminf" => MemoryMode::Bminf { n_local: doc.usize_or("memory.n_local", usize::MAX) },
        other => anyhow::bail!("memory.mode must be resident|pmep|bminf, got {other:?}"),
    };

    // catch typos: warn on unknown sections/keys
    for key in doc.keys() {
        let known = [
            "preset", "seed", "warmup",
            "parallel.tp", "parallel.pp",
            "engine.drce", "engine.blocking_comms", "engine.consistency_queue",
            "engine.pool_threads", "engine.max_batch", "engine.batch_timeout_us",
            "engine.batch_deadline_ms", "engine.kv_cache",
            "engine.kv_spill", "engine.kv_device_blocks", "engine.kv_host_blocks",
            "engine.kv_peer_blocks",
            "engine.kv_spill_high_water", "engine.kv_spill_low_water",
            "engine.prefix_cache",
            "engine.speculative", "engine.spec_k",
            "engine.prefill_chunk", "engine.chunk_decode_ratio",
            "engine.max_queue_depth", "engine.admission_token_budget",
            "engine.slo_ttft_ms", "engine.slo_tpot_ms",
            "engine.pressure_max_new_tokens",
            "engine.fault_plan", "engine.fault_seed",
            "model.n_layers",
            "memory.mode", "memory.n_local", "memory.lookahead", "memory.time_scale", "memory.link",
        ];
        anyhow::ensure!(known.contains(&key), "unknown config key {key:?}");
    }
    Ok(launch)
}

/// Load a launch config from a TOML file.
pub fn launch_from_file(path: impl AsRef<std::path::Path>) -> anyhow::Result<LaunchConfig> {
    launch_from_doc(&TomlDoc::load(path)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_config_round_trip() {
        let doc = TomlDoc::parse(
            r#"
preset = "small"
seed = 9
warmup = false

[parallel]
tp = 2
pp = 2

[engine]
drce = true
pool_threads = 8

[model]
n_layers = 24

[memory]
mode = "pmep"
n_local = 10
lookahead = 2
"#,
        )
        .unwrap();
        let l = launch_from_doc(&doc).unwrap();
        assert_eq!(l.preset, "small");
        assert_eq!(l.seed, 9);
        assert!(!l.warmup);
        assert_eq!((l.parallel.tp, l.parallel.pp), (2, 2));
        assert!(l.engine.drce);
        assert_eq!(l.engine.pool_threads, 8);
        assert_eq!(l.n_layers, Some(24));
        match l.memory {
            MemoryMode::Pmep { n_local, pool } => {
                assert_eq!(n_local, 10);
                assert_eq!(pool.lookahead, 2);
            }
            _ => panic!("expected pmep"),
        }
    }

    #[test]
    fn defaults_when_empty() {
        let l = launch_from_doc(&TomlDoc::parse("").unwrap()).unwrap();
        assert_eq!(l.preset, "tiny");
        assert_eq!(l.parallel.world_size(), 1);
        assert!(matches!(l.memory, MemoryMode::Resident));
        assert!(l.engine.consistency_queue);
    }

    #[test]
    fn kv_spill_round_trip_and_validation() {
        let doc = TomlDoc::parse(
            r#"
[engine]
kv_spill = true
kv_device_blocks = 64
kv_host_blocks = 256
kv_peer_blocks = 32
kv_spill_high_water = 0.8
kv_spill_low_water = 0.5
"#,
        )
        .unwrap();
        let l = launch_from_doc(&doc).unwrap();
        assert!(l.engine.kv_spill);
        assert_eq!(l.engine.kv_device_blocks, 64);
        assert_eq!(l.engine.kv_host_blocks, 256);
        assert_eq!(l.engine.kv_peer_blocks, 32);
        assert!((l.engine.kv_spill_high_water - 0.8).abs() < 1e-9);
        assert!((l.engine.kv_spill_low_water - 0.5).abs() < 1e-9);
        // the default leaves the peer tier off (two-tier path untouched)
        let l = launch_from_doc(&TomlDoc::parse("").unwrap()).unwrap();
        assert_eq!(l.engine.kv_peer_blocks, 0);
        assert!(!l.engine.kv_copier);
        // a peer tier without the spill tier has nowhere to demote to
        let doc = TomlDoc::parse("[engine]\nkv_peer_blocks = 8\n").unwrap();
        let err = launch_from_doc(&doc).unwrap_err().to_string();
        assert!(err.contains("kv_peer_blocks requires engine.kv_spill"), "{err}");
        // spill without a device cap is a config error, not a silent no-op
        let doc = TomlDoc::parse("[engine]\nkv_spill = true\n").unwrap();
        let err = launch_from_doc(&doc).unwrap_err().to_string();
        assert!(err.contains("kv_device_blocks"), "{err}");
        // inverted water marks are rejected
        let doc = TomlDoc::parse(
            "[engine]\nkv_spill = true\nkv_device_blocks = 8\nkv_spill_low_water = 0.95\n",
        )
        .unwrap();
        assert!(launch_from_doc(&doc).is_err());
    }

    #[test]
    fn speculative_round_trip_and_validation() {
        let doc = TomlDoc::parse("[engine]\nspeculative = true\nspec_k = 2\n").unwrap();
        let l = launch_from_doc(&doc).unwrap();
        assert!(l.engine.speculative);
        assert_eq!(l.engine.spec_k, 2);
        // defaults: off, window 4
        let l = launch_from_doc(&TomlDoc::parse("").unwrap()).unwrap();
        assert!(!l.engine.speculative);
        assert_eq!(l.engine.spec_k, 4);
        // a window of 1 has no draft to verify — config error, not a no-op
        let doc = TomlDoc::parse("[engine]\nspeculative = true\nspec_k = 1\n").unwrap();
        let err = launch_from_doc(&doc).unwrap_err().to_string();
        assert!(err.contains("spec_k"), "{err}");
        // speculation without the cache cannot verify anything
        let doc = TomlDoc::parse("[engine]\nspeculative = true\nkv_cache = false\n").unwrap();
        let err = launch_from_doc(&doc).unwrap_err().to_string();
        assert!(err.contains("kv_cache"), "{err}");
    }

    #[test]
    fn prefix_cache_round_trip_and_validation() {
        let doc = TomlDoc::parse("[engine]\nprefix_cache = true\n").unwrap();
        let l = launch_from_doc(&doc).unwrap();
        assert!(l.engine.prefix_cache);
        // default: off (byte-identical fast path)
        let l = launch_from_doc(&TomlDoc::parse("").unwrap()).unwrap();
        assert!(!l.engine.prefix_cache);
        // prefix reuse without the paged cache has nothing to adopt from
        let doc = TomlDoc::parse("[engine]\nprefix_cache = true\nkv_cache = false\n").unwrap();
        let err = launch_from_doc(&doc).unwrap_err().to_string();
        assert!(err.contains("kv_cache"), "{err}");
    }

    #[test]
    fn chunked_prefill_round_trip_and_validation() {
        let doc =
            TomlDoc::parse("[engine]\nprefill_chunk = 64\nchunk_decode_ratio = 2\n").unwrap();
        let l = launch_from_doc(&doc).unwrap();
        assert_eq!(l.engine.prefill_chunk, 64);
        assert_eq!(l.engine.chunk_decode_ratio, 2);
        // defaults: off, ratio 1 (monolithic path byte-identical)
        let l = launch_from_doc(&TomlDoc::parse("").unwrap()).unwrap();
        assert_eq!(l.engine.prefill_chunk, 0);
        assert_eq!(l.engine.chunk_decode_ratio, 1);
        // chunks seed the paged cache; without it the feature is meaningless
        let doc = TomlDoc::parse("[engine]\nprefill_chunk = 32\nkv_cache = false\n").unwrap();
        let err = launch_from_doc(&doc).unwrap_err().to_string();
        assert!(err.contains("kv_cache"), "{err}");
        // a zero interleave ratio would starve chunks entirely
        let doc = TomlDoc::parse("[engine]\nchunk_decode_ratio = 0\n").unwrap();
        let err = launch_from_doc(&doc).unwrap_err().to_string();
        assert!(err.contains("chunk_decode_ratio"), "{err}");
    }

    #[test]
    fn robustness_knobs_round_trip_and_validation() {
        let doc = TomlDoc::parse(
            r#"
[engine]
max_queue_depth = 64
admission_token_budget = 4096
slo_ttft_ms = 200
slo_tpot_ms = 50
pressure_max_new_tokens = 8
fault_plan = "delay5ms@t3,drop@every16+7@w0"
fault_seed = 7
"#,
        )
        .unwrap();
        let l = launch_from_doc(&doc).unwrap();
        assert_eq!(l.engine.max_queue_depth, 64);
        assert_eq!(l.engine.admission_token_budget, 4096);
        assert_eq!((l.engine.slo_ttft_ms, l.engine.slo_tpot_ms), (200, 50));
        assert_eq!(l.engine.pressure_max_new_tokens, 8);
        assert_eq!(l.engine.fault_plan, "delay5ms@t3,drop@every16+7@w0");
        assert_eq!(l.engine.fault_seed, 7);
        // defaults: everything off
        let l = launch_from_doc(&TomlDoc::parse("").unwrap()).unwrap();
        assert_eq!(l.engine.max_queue_depth, 0);
        assert_eq!(l.engine.admission_token_budget, 0);
        assert_eq!(l.engine.pressure_max_new_tokens, 0);
        assert!(l.engine.fault_plan.is_empty());
        // an unparsable fault schedule fails at load time
        let doc = TomlDoc::parse("[engine]\nfault_plan = \"explode@sometimes\"\n").unwrap();
        assert!(launch_from_doc(&doc).is_err());
    }

    #[test]
    fn unknown_key_is_error() {
        let doc = TomlDoc::parse("[engine]\ndrc = true\n").unwrap();
        let err = launch_from_doc(&doc).unwrap_err().to_string();
        assert!(err.contains("engine.drc"), "{err}");
    }

    #[test]
    fn bad_memory_mode_is_error() {
        let doc = TomlDoc::parse("[memory]\nmode = \"cloud\"\n").unwrap();
        assert!(launch_from_doc(&doc).is_err());
    }
}
