//! The distributed consistency queue (§4.2): the mechanism that lets the
//! whole system go non-blocking without mispairing batches.
//!
//! Problem: with a multi-threaded engine, commands for batches A and B can
//! arrive at worker 1 as (A, B) but at worker 2 as (B, A). If each worker
//! executes in arrival order, the TP all-reduce (or the pipeline hand-off)
//! mixes tensors from different batches — numerically garbage, and with
//! variable shapes a deadlock.
//!
//! Fix: the engine and every worker share a *loop data structure that
//! increments unidirectionally*. The engine stamps each command with the
//! next ticket; each worker executes strictly in local ticket order,
//! buffering early arrivals. Everyone processes batch k as their k-th
//! execution, so all workers stay consistent.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};

/// Engine side: the monotonic ticket source ("loop data structure").
#[derive(Debug, Default)]
pub struct TicketCounter {
    next: AtomicU64,
}

impl TicketCounter {
    pub fn new() -> TicketCounter {
        TicketCounter { next: AtomicU64::new(0) }
    }

    /// Take the next unique, gap-free ticket.
    pub fn issue(&self) -> u64 {
        self.next.fetch_add(1, Ordering::SeqCst)
    }

    pub fn issued(&self) -> u64 {
        self.next.load(Ordering::SeqCst)
    }
}

/// Worker side: reorder buffer keyed by ticket.
///
/// `push` accepts commands in any arrival order; `pop_ready` yields them
/// in strict ticket order, or `None` if the next ticket hasn't arrived.
#[derive(Debug)]
pub struct ConsistencyQueue<T> {
    next: u64,
    pending: BTreeMap<u64, T>,
    /// When disabled (ablation), `pop_ready` returns arrivals FIFO.
    enabled: bool,
    fifo: std::collections::VecDeque<T>,
}

impl<T> ConsistencyQueue<T> {
    pub fn new(enabled: bool) -> ConsistencyQueue<T> {
        ConsistencyQueue {
            next: 0,
            pending: BTreeMap::new(),
            enabled,
            fifo: std::collections::VecDeque::new(),
        }
    }

    pub fn push(&mut self, ticket: u64, item: T) {
        if self.enabled {
            let prev = self.pending.insert(ticket, item);
            assert!(prev.is_none(), "duplicate ticket {ticket}");
        } else {
            self.fifo.push_back(item);
        }
    }

    /// Next in-order item, if it has arrived.
    pub fn pop_ready(&mut self) -> Option<T> {
        if self.enabled {
            if let Some(item) = self.pending.remove(&self.next) {
                self.next += 1;
                Some(item)
            } else {
                None
            }
        } else {
            self.fifo.pop_front()
        }
    }

    /// Buffered-but-not-yet-executable count (observability).
    pub fn buffered(&self) -> usize {
        if self.enabled {
            self.pending.len()
        } else {
            self.fifo.len()
        }
    }

    pub fn expected_next(&self) -> u64 {
        self.next
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tickets_are_gap_free() {
        let c = TicketCounter::new();
        assert_eq!(c.issue(), 0);
        assert_eq!(c.issue(), 1);
        assert_eq!(c.issue(), 2);
        assert_eq!(c.issued(), 3);
    }

    #[test]
    fn tickets_unique_across_threads() {
        let c = std::sync::Arc::new(TicketCounter::new());
        let mut handles = vec![];
        for _ in 0..4 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                (0..250).map(|_| c.issue()).collect::<Vec<_>>()
            }));
        }
        let mut all: Vec<u64> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        all.sort();
        all.dedup();
        assert_eq!(all.len(), 1000);
    }

    #[test]
    fn reorders_out_of_order_arrivals() {
        let mut q = ConsistencyQueue::new(true);
        q.push(2, "c");
        q.push(0, "a");
        assert_eq!(q.pop_ready(), Some("a"));
        assert_eq!(q.pop_ready(), None); // 1 hasn't arrived
        assert_eq!(q.buffered(), 1);
        q.push(1, "b");
        assert_eq!(q.pop_ready(), Some("b"));
        assert_eq!(q.pop_ready(), Some("c"));
        assert_eq!(q.pop_ready(), None);
    }

    #[test]
    fn disabled_queue_is_fifo_by_arrival() {
        let mut q = ConsistencyQueue::new(false);
        q.push(2, "c");
        q.push(0, "a");
        // hazard: executes c before a — the ablation's wrong pairing
        assert_eq!(q.pop_ready(), Some("c"));
        assert_eq!(q.pop_ready(), Some("a"));
    }

    #[test]
    #[should_panic]
    fn duplicate_ticket_panics() {
        let mut q = ConsistencyQueue::new(true);
        q.push(0, "a");
        q.push(0, "b");
    }

    #[test]
    fn interleaved_push_pop() {
        let mut q = ConsistencyQueue::new(true);
        for round in 0..50u64 {
            // arrive in pairs, reversed
            q.push(round * 2 + 1, round * 2 + 1);
            q.push(round * 2, round * 2);
            assert_eq!(q.pop_ready(), Some(round * 2));
            assert_eq!(q.pop_ready(), Some(round * 2 + 1));
        }
        assert_eq!(q.expected_next(), 100);
    }
}
