//! Fault-tolerant replica fleet: N self-contained engine replicas in one
//! process behind a session-affine router.
//!
//! One coordinator is both a throughput ceiling and a single point of
//! failure — a wedged or killed engine takes every session it holds down
//! with it. The fleet makes replicas fungible (the DeepSpeed-Inference
//! serving model) without giving up streaming sessions:
//!
//! - **Placement** is session-affine with spill-aware headroom scoring:
//!   a returning client lands on its previous replica while it stays
//!   healthy (its K/V context and hot prefixes live there), new sessions
//!   go to the healthy replica with the most admission headroom (live
//!   sessions, queued prefills, SLO pressure, device-tier occupancy). A
//!   `Busy` from the preferred replica falls through to the next-best
//!   one before the caller ever sees it.
//! - **Health probes** run in a supervisor loop: collector liveness
//!   ticks (worker replies processed), queue depth, and the `Recorder`
//!   SLO pressure bit per replica, surfaced through
//!   [`crate::metrics::FleetRollup`].
//! - **Failure verbs**: [`Fleet::drain`] stops placement and lets
//!   sessions finish, then proves zero blocks in use on both tiers at
//!   teardown; [`Fleet::kill`] marks a replica dead and fails its work
//!   fast; failover is implicit — any session whose replica is dead or
//!   draining when its stream errors is transparently **replayed on a
//!   survivor**.
//!
//! Failover = replay-from-committed-tokens: the client holds an *outer*
//! [`GenRef`] owned by the fleet; a relay thread copies tokens into it
//! from whichever replica currently runs the session, so the committed
//! tokens live in the outer stream state regardless of replica health.
//! On failure the relay re-prefills `prompt ⊕ committed` with the
//! remaining budget on a survivor. Greedy decode is deterministic in the
//! token sequence, so the survivor's continuation is byte-identical to
//! the one the dead replica would have produced — the client sees one
//! uninterrupted stream, never a mid-stream error.

use super::batcher::Busy;
use super::engine::{Engine, GenRef, GenRequest, LaunchConfig, TokenRef};
use super::fault::FaultPlan;
use crate::metrics::{FleetRollup, ReplicaSnapshot};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

/// Supervisor probe / cancel-propagation cadence.
const SUPERVISE_EVERY: Duration = Duration::from_millis(2);
/// How long a failover keeps retrying `Busy` survivors before giving up
/// and failing the session for real.
const FAILOVER_DEADLINE: Duration = Duration::from_secs(10);

/// A replica's lifecycle state. Transitions only move right
/// (`Healthy → Draining → Dead` or `Healthy → Dead`); a dead replica
/// never rejoins the fleet.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReplicaState {
    /// Accepting placements.
    Healthy,
    /// No new placements; existing sessions run to completion.
    Draining,
    /// Gone. Sessions it held have failed over or finished.
    Dead,
}

impl ReplicaState {
    fn name(self) -> &'static str {
        match self {
            ReplicaState::Healthy => "healthy",
            ReplicaState::Draining => "draining",
            ReplicaState::Dead => "dead",
        }
    }
}

/// What [`Fleet::drain`] proved at teardown.
#[derive(Clone, Copy, Debug)]
pub struct DrainReport {
    pub replica: usize,
    /// Sessions still live on the replica when the drain began.
    pub sessions_at_start: usize,
    /// K/V blocks still in use on the device tier at teardown (a clean
    /// drain leaves zero).
    pub device_blocks: usize,
    /// Same for the host (spill) tier.
    pub host_blocks: usize,
}

/// Last health-probe snapshot, kept so `stats` can describe a replica
/// even after its engine is gone.
#[derive(Clone, Copy, Default)]
struct Probe {
    ticks: u64,
    queued: usize,
    sessions: usize,
    pressure: bool,
}

struct ReplicaSlot {
    id: usize,
    /// `None` once killed/drained (the engine was consumed by shutdown).
    engine: Mutex<Option<Engine>>,
    state: Mutex<ReplicaState>,
    placed: AtomicU64,
    probe: Mutex<Probe>,
}

impl ReplicaSlot {
    fn state(&self) -> ReplicaState {
        *self.state.lock().unwrap()
    }
}

/// One live fleet session: the client-facing stream, the replica-facing
/// stream, and everything needed to replay it elsewhere.
struct SessionMeta {
    outer: GenRef,
    inner: GenRef,
    replica: usize,
    prompt: Vec<i32>,
    /// Tokens already pushed to the outer stream — the replay point.
    committed: Vec<i32>,
    max_new: usize,
    stop: Option<i32>,
    client: Option<u64>,
}

struct FleetShared {
    replicas: Vec<ReplicaSlot>,
    sessions: Mutex<HashMap<u64, SessionMeta>>,
    /// Client key → last replica that held its session (KV locality).
    affinity: Mutex<HashMap<u64, usize>>,
    /// Outer-GenRef cancel hook inbox, drained by the supervisor and
    /// propagated to the session's current inner stream.
    cancels: Arc<Mutex<Vec<u64>>>,
    next_id: AtomicU64,
    stopping: AtomicBool,
    placed: AtomicU64,
    failovers: AtomicU64,
    failover_us: Mutex<Vec<u64>>,
    kills: AtomicU64,
    drains: AtomicU64,
}

/// The router. All failure verbs and stats go through here; sessions
/// created by [`Fleet::generate_stream`] survive any single replica.
pub struct Fleet {
    shared: Arc<FleetShared>,
    supervisor: Option<JoinHandle<()>>,
    relays: Mutex<Vec<JoinHandle<()>>>,
    reapers: Mutex<Vec<JoinHandle<()>>>,
}

impl Fleet {
    /// Launch `n` replicas of `base`. Each replica gets its own engine
    /// (workers, batcher, collector, K/V tiers); a replica-scoped fault
    /// plan (`@r<id>`, see `coordinator::fault`) is partitioned so each
    /// engine only ever sees its own directives.
    pub fn launch(base: LaunchConfig, n: usize) -> anyhow::Result<Fleet> {
        anyhow::ensure!(n >= 1, "a fleet needs at least one replica");
        let plans = FaultPlan::split_for_replicas(&base.engine.fault_plan, n)?;
        let mut replicas = Vec::with_capacity(n);
        for (id, plan) in plans.into_iter().enumerate() {
            let mut launch = base.clone();
            launch.engine.fault_plan = plan;
            replicas.push(ReplicaSlot {
                id,
                engine: Mutex::new(Some(Engine::launch(launch)?)),
                state: Mutex::new(ReplicaState::Healthy),
                placed: AtomicU64::new(0),
                probe: Mutex::new(Probe::default()),
            });
        }
        let shared = Arc::new(FleetShared {
            replicas,
            sessions: Mutex::new(HashMap::new()),
            affinity: Mutex::new(HashMap::new()),
            cancels: Arc::new(Mutex::new(Vec::new())),
            next_id: AtomicU64::new(0),
            stopping: AtomicBool::new(false),
            placed: AtomicU64::new(0),
            failovers: AtomicU64::new(0),
            failover_us: Mutex::new(Vec::new()),
            kills: AtomicU64::new(0),
            drains: AtomicU64::new(0),
        });
        let supervisor = thread::spawn({
            let shared = shared.clone();
            move || supervise(&shared)
        });
        Ok(Fleet {
            shared,
            supervisor: Some(supervisor),
            relays: Mutex::new(Vec::new()),
            reapers: Mutex::new(Vec::new()),
        })
    }

    pub fn replicas(&self) -> usize {
        self.shared.replicas.len()
    }

    pub fn replica_state(&self, id: usize) -> Option<ReplicaState> {
        self.shared.replicas.get(id).map(|s| s.state())
    }

    /// Start a streaming session with no client affinity.
    pub fn generate_stream(&self, req: GenRequest) -> anyhow::Result<GenRef> {
        self.start_session(req, None)
    }

    /// Start a streaming session for `client`: placement prefers the
    /// replica that last held one of the client's sessions (its K/V
    /// context and any cached prefixes are local there).
    pub fn generate_stream_for(&self, client: u64, req: GenRequest) -> anyhow::Result<GenRef> {
        self.start_session(req, Some(client))
    }

    /// Blocking greedy generation through the fleet (mirrors
    /// `Engine::generate`).
    pub fn generate(&self, prompt: Vec<i32>, n_tokens: usize) -> anyhow::Result<Vec<i32>> {
        if n_tokens == 0 {
            anyhow::ensure!(!prompt.is_empty(), "empty prompt");
            return Ok(prompt);
        }
        self.generate_stream(GenRequest::new(prompt, n_tokens))?.to_here()
    }

    /// One-token submission (mirrors `Engine::submit`).
    pub fn submit(&self, tokens: Vec<i32>) -> anyhow::Result<TokenRef> {
        Ok(TokenRef::from_gen(self.generate_stream(GenRequest::new(tokens, 1))?))
    }

    fn start_session(&self, req: GenRequest, client: Option<u64>) -> anyhow::Result<GenRef> {
        anyhow::ensure!(!req.tokens.is_empty(), "empty prompt");
        anyhow::ensure!(req.max_new_tokens >= 1, "max_new_tokens must be >= 1");
        anyhow::ensure!(
            !self.shared.stopping.load(Ordering::SeqCst),
            "fleet is shutting down"
        );
        let sid = self.shared.next_id.fetch_add(1, Ordering::SeqCst);
        let outer = GenRef::new(req.tokens.clone());
        outer.set_cancel_hook(sid, Arc::downgrade(&self.shared.cancels));
        let (inner, rid) = place(&self.shared, &req, client, None)?;
        self.shared.sessions.lock().unwrap().insert(
            sid,
            SessionMeta {
                outer: outer.clone(),
                inner,
                replica: rid,
                prompt: req.tokens,
                committed: Vec::new(),
                max_new: req.max_new_tokens,
                stop: req.stop_token,
                client,
            },
        );
        let handle = thread::spawn({
            let shared = self.shared.clone();
            move || relay(&shared, sid)
        });
        self.relays.lock().unwrap().push(handle);
        Ok(outer)
    }

    /// Deliberately or chaos-driven: mark the replica dead and fail its
    /// in-flight work fast. Victim sessions' relays observe the error
    /// and replay on a survivor; the dead engine is drained and joined
    /// by a background reaper so the caller never blocks on teardown.
    pub fn kill(&self, id: usize) -> anyhow::Result<()> {
        let slot = self
            .shared
            .replicas
            .get(id)
            .ok_or_else(|| anyhow::anyhow!("no replica r{id}"))?;
        {
            let mut state = slot.state.lock().unwrap();
            anyhow::ensure!(*state != ReplicaState::Dead, "replica r{id} is already dead");
            *state = ReplicaState::Dead;
        }
        self.shared.kills.fetch_add(1, Ordering::Relaxed);
        // fail the victims fast: cancelling the *inner* stream unblocks
        // each relay with an error while the outer stream stays live, so
        // the relay's failover path takes over
        let victims: Vec<GenRef> = {
            let sessions = self.shared.sessions.lock().unwrap();
            sessions.values().filter(|m| m.replica == id).map(|m| m.inner.clone()).collect()
        };
        for inner in victims {
            inner.cancel();
        }
        if let Some(engine) = slot.engine.lock().unwrap().take() {
            let reaper = thread::spawn(move || engine.shutdown());
            self.reapers.lock().unwrap().push(reaper);
        }
        Ok(())
    }

    /// Stop placing on the replica, let its sessions finish, then tear
    /// the engine down — proving zero K/V blocks in use on either tier
    /// first. Returns the teardown gauges for the caller to assert on.
    pub fn drain(&self, id: usize) -> anyhow::Result<DrainReport> {
        let slot = self
            .shared
            .replicas
            .get(id)
            .ok_or_else(|| anyhow::anyhow!("no replica r{id}"))?;
        {
            let mut state = slot.state.lock().unwrap();
            anyhow::ensure!(
                *state == ReplicaState::Healthy,
                "replica r{id} is {} — only a healthy replica can drain",
                state.name()
            );
            *state = ReplicaState::Draining;
        }
        self.shared.drains.fetch_add(1, Ordering::Relaxed);
        let sessions_at_start = match slot.engine.lock().unwrap().as_ref() {
            Some(e) => e.session_count(),
            None => 0,
        };
        // relays consume inner streams unconditionally, so every session
        // finishes (budget, stop token, or context limit) without any
        // client cooperation; the engine watchdog bounds wedged batches
        loop {
            let fleet_side = self
                .shared
                .sessions
                .lock()
                .unwrap()
                .values()
                .filter(|m| m.replica == id)
                .count();
            let engine_side = match slot.engine.lock().unwrap().as_ref() {
                Some(e) => e.session_count() + e.pending_count(),
                None => 0,
            };
            if fleet_side == 0 && engine_side == 0 {
                break;
            }
            thread::sleep(Duration::from_millis(1));
        }
        let engine = slot.engine.lock().unwrap().take();
        let (device_blocks, host_blocks) = match &engine {
            Some(e) => e.tier_usage().unwrap_or((0, 0)),
            None => (0, 0),
        };
        if let Some(e) = engine {
            e.shutdown();
        }
        *slot.state.lock().unwrap() = ReplicaState::Dead;
        Ok(DrainReport { replica: id, sessions_at_start, device_blocks, host_blocks })
    }

    /// Per-replica health/load rollup plus the router's failure-verb
    /// counters.
    pub fn stats(&self) -> FleetRollup {
        let mut replicas = Vec::with_capacity(self.shared.replicas.len());
        for slot in &self.shared.replicas {
            let state = slot.state();
            let snap = match slot.engine.lock().unwrap().as_ref() {
                Some(e) => ReplicaSnapshot {
                    id: slot.id,
                    state: state.name(),
                    sessions: e.session_count(),
                    queued_prefills: e.queued_prefills(),
                    under_pressure: e.under_pressure(),
                    collector_ticks: e.collector_ticks(),
                    placed: slot.placed.load(Ordering::Relaxed),
                    device_blocks: e.tier_usage().map_or(0, |(d, _)| d),
                    host_blocks: e.tier_usage().map_or(0, |(_, h)| h),
                    summary: e.metrics_snapshot().summary(),
                },
                // engine gone (killed/drained): report the last health
                // probe taken while it was alive
                None => {
                    let probe = *slot.probe.lock().unwrap();
                    ReplicaSnapshot {
                        id: slot.id,
                        state: state.name(),
                        sessions: probe.sessions,
                        queued_prefills: probe.queued,
                        under_pressure: probe.pressure,
                        collector_ticks: probe.ticks,
                        placed: slot.placed.load(Ordering::Relaxed),
                        device_blocks: 0,
                        host_blocks: 0,
                        summary: String::new(),
                    }
                }
            };
            replicas.push(snap);
        }
        FleetRollup {
            replicas,
            placed: self.shared.placed.load(Ordering::Relaxed),
            failovers: self.shared.failovers.load(Ordering::Relaxed),
            failover_us: self.shared.failover_us.lock().unwrap().clone(),
            kills: self.shared.kills.load(Ordering::Relaxed),
            drains: self.shared.drains.load(Ordering::Relaxed),
        }
    }

    /// Orderly teardown: let every fleet session finish, then shut all
    /// surviving replicas down and join every service thread.
    pub fn shutdown(mut self) {
        self.shared.stopping.store(true, Ordering::SeqCst);
        // the supervisor exits on the stopping flag, so propagate any
        // late client cancels ourselves while sessions wind down
        loop {
            propagate_cancels(&self.shared);
            if self.shared.sessions.lock().unwrap().is_empty() {
                break;
            }
            thread::sleep(Duration::from_millis(1));
        }
        for handle in self.relays.lock().unwrap().drain(..) {
            let _ = handle.join();
        }
        for slot in &self.shared.replicas {
            *slot.state.lock().unwrap() = ReplicaState::Dead;
            if let Some(engine) = slot.engine.lock().unwrap().take() {
                engine.shutdown();
            }
        }
        for handle in self.reapers.lock().unwrap().drain(..) {
            let _ = handle.join();
        }
        if let Some(handle) = self.supervisor.take() {
            let _ = handle.join();
        }
    }
}

/// Admission headroom: lower scores first. Weighs live work (sessions,
/// queued prefills), the SLO pressure bit, and — spill-aware — how full
/// the device tier is plus how much has already been pushed to the host
/// tier (a spilled session must be prefetched back before it can run).
fn headroom(e: &Engine) -> u64 {
    let mut score = e.session_count() as u64 * 4 + e.queued_prefills() as u64 * 8;
    if e.under_pressure() {
        score += 64;
    }
    if let Some((device, host)) = e.tier_usage() {
        let cap = e.launch.engine.kv_device_blocks.max(1) as u64;
        score += device as u64 * 32 / cap + host as u64;
    }
    score
}

/// Choose a healthy replica and admit `req` there. Affinity wins while
/// its replica stays healthy; otherwise replicas are tried in headroom
/// order, falling through `Busy` rejections to the next-best one.
/// `exclude` bars the failing replica during a failover.
fn place(
    shared: &FleetShared,
    req: &GenRequest,
    client: Option<u64>,
    exclude: Option<usize>,
) -> anyhow::Result<(GenRef, usize)> {
    let mut order: Vec<(u64, usize)> = Vec::new();
    for slot in &shared.replicas {
        if Some(slot.id) == exclude || slot.state() != ReplicaState::Healthy {
            continue;
        }
        if let Some(e) = slot.engine.lock().unwrap().as_ref() {
            order.push((headroom(e), slot.id));
        }
    }
    order.sort_unstable();
    let mut order: Vec<usize> = order.into_iter().map(|(_, id)| id).collect();
    if let Some(c) = client {
        if let Some(&home) = shared.affinity.lock().unwrap().get(&c) {
            if let Some(pos) = order.iter().position(|&id| id == home) {
                order.remove(pos);
                order.insert(0, home);
            }
        }
    }
    let mut last_err = anyhow::anyhow!("no healthy replica");
    for rid in order {
        let slot = &shared.replicas[rid];
        if slot.state() != ReplicaState::Healthy {
            continue;
        }
        let guard = slot.engine.lock().unwrap();
        let Some(engine) = guard.as_ref() else { continue };
        match engine.generate_stream(req.clone()) {
            Ok(inner) => {
                slot.placed.fetch_add(1, Ordering::Relaxed);
                shared.placed.fetch_add(1, Ordering::Relaxed);
                if let Some(c) = client {
                    shared.affinity.lock().unwrap().insert(c, rid);
                }
                return Ok((inner, rid));
            }
            Err(e) => last_err = e,
        }
    }
    Err(last_err)
}

/// Per-session pump: copy tokens from the session's current inner stream
/// to the client's outer stream, failing over to a survivor whenever the
/// inner stream errors while its replica is dead or draining.
fn relay(shared: &Arc<FleetShared>, sid: u64) {
    loop {
        let inner = {
            let sessions = shared.sessions.lock().unwrap();
            match sessions.get(&sid) {
                Some(m) => {
                    if m.outer.is_cancelled() {
                        // client cancelled between iterations: tear the
                        // replica-side session down and stop
                        m.inner.cancel();
                        drop(sessions);
                        shared.sessions.lock().unwrap().remove(&sid);
                        return;
                    }
                    m.inner.clone()
                }
                None => return,
            }
        };
        match inner.next() {
            Ok(Some(tok)) => {
                let mut sessions = shared.sessions.lock().unwrap();
                if let Some(m) = sessions.get_mut(&sid) {
                    m.outer.push_token(tok);
                    m.committed.push(tok);
                }
            }
            Ok(None) => {
                let meta = shared.sessions.lock().unwrap().remove(&sid);
                if let Some(m) = meta {
                    m.outer.finish(Ok(()));
                }
                return;
            }
            Err(err) => {
                let (outer, home) = {
                    let sessions = shared.sessions.lock().unwrap();
                    match sessions.get(&sid) {
                        Some(m) => (m.outer.clone(), m.replica),
                        None => return,
                    }
                };
                if outer.is_cancelled() {
                    // the client's cancel propagated to the inner stream
                    // (or raced a fault) — the outer verdict is already
                    // terminal, nothing to replay
                    shared.sessions.lock().unwrap().remove(&sid);
                    return;
                }
                let healthy = shared.replicas[home].state() == ReplicaState::Healthy;
                if healthy || shared.stopping.load(Ordering::SeqCst) {
                    // a genuine engine failure (or teardown): surface it
                    shared.sessions.lock().unwrap().remove(&sid);
                    outer.finish(Err(err));
                    return;
                }
                if let Err(fail) = failover(shared, sid) {
                    shared.sessions.lock().unwrap().remove(&sid);
                    outer.finish(Err(fail));
                    return;
                }
                // failover swapped m.inner; loop picks the new stream up
            }
        }
    }
}

/// Replay a victim session on a survivor: re-prefill the prompt plus
/// every committed token with the remaining budget. Greedy decode makes
/// the survivor's continuation byte-identical to the one the victim's
/// replica owed. Retries `Busy` survivors until [`FAILOVER_DEADLINE`].
fn failover(shared: &Arc<FleetShared>, sid: u64) -> anyhow::Result<()> {
    let began = Instant::now();
    let (req, client, old_replica) = {
        let sessions = shared.sessions.lock().unwrap();
        let m = sessions
            .get(&sid)
            .ok_or_else(|| anyhow::anyhow!("session {sid} vanished mid-failover"))?;
        let remaining = m.max_new.saturating_sub(m.committed.len());
        anyhow::ensure!(remaining >= 1, "session {sid} has no budget left to replay");
        let mut tokens = m.prompt.clone();
        tokens.extend_from_slice(&m.committed);
        let mut req = GenRequest::new(tokens, remaining);
        req.stop_token = m.stop;
        (req, m.client, m.replica)
    };
    loop {
        match place(shared, &req, client, Some(old_replica)) {
            Ok((inner, rid)) => {
                let mut sessions = shared.sessions.lock().unwrap();
                let m = sessions
                    .get_mut(&sid)
                    .ok_or_else(|| anyhow::anyhow!("session {sid} vanished mid-failover"))?;
                m.inner = inner;
                m.replica = rid;
                shared.failovers.fetch_add(1, Ordering::Relaxed);
                shared
                    .failover_us
                    .lock()
                    .unwrap()
                    .push(began.elapsed().as_micros() as u64);
                return Ok(());
            }
            Err(e) => {
                let retriable = e.downcast_ref::<Busy>().is_some();
                if !retriable || began.elapsed() > FAILOVER_DEADLINE {
                    return Err(e.context(format!(
                        "failover of session {sid} off replica r{old_replica}"
                    )));
                }
                let hint = e.downcast_ref::<Busy>().map_or(5, |b| b.retry_after_ms.clamp(1, 50));
                thread::sleep(Duration::from_millis(hint));
            }
        }
    }
}

/// Forward outer-stream cancels to whichever inner stream currently
/// backs each session.
fn propagate_cancels(shared: &FleetShared) {
    let ids: Vec<u64> = std::mem::take(&mut *shared.cancels.lock().unwrap());
    for sid in ids {
        let inner = shared.sessions.lock().unwrap().get(&sid).map(|m| m.inner.clone());
        if let Some(inner) = inner {
            inner.cancel();
        }
    }
}

/// Supervisor loop: cancel propagation plus per-replica health probes.
fn supervise(shared: &FleetShared) {
    while !shared.stopping.load(Ordering::SeqCst) {
        propagate_cancels(shared);
        for slot in &shared.replicas {
            let snapshot = slot.engine.lock().unwrap().as_ref().map(|e| Probe {
                ticks: e.collector_ticks(),
                queued: e.queued_prefills(),
                sessions: e.session_count(),
                pressure: e.under_pressure(),
            });
            if let Some(probe) = snapshot {
                *slot.probe.lock().unwrap() = probe;
            }
        }
        thread::sleep(SUPERVISE_EVERY);
    }
}
