//! Worker: one simulated device. Runs the SPMD (multi-controller) half of
//! the hierarchy: executes its pipeline stage's layers as TP shards,
//! all-reduces with its TP group, hands activations to the next stage, and
//! — crucially — consumes engine commands through the distributed
//! consistency queue so every worker processes batch k as its k-th
//! execution (§4.2).

use super::consistency::ConsistencyQueue;
use super::fault::{FaultKind, FaultPlan};
use super::rpc::{BatchInput, BatchOutput, Command, Phase};
use crate::comm::channel::Endpoint;
use crate::comm::collective::{ring_allreduce, ChunkMsg};
use crate::config::{ModelConfig, ParallelConfig};
use crate::memory::kvcache::KvCache;
use crate::memory::LayerProvider;
use crate::runtime::{valid_len_arg, Device, Manifest};
use crate::tensor::drce::{self, DrceMaps};
use crate::tensor::{IntTensor, Tensor, Value};
use std::collections::HashMap;
use std::ops::Range;
use std::rc::Rc;
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;

/// Activation hand-off between consecutive pipeline stages. The tensor is
/// *moved* into the channel (and its storage is usually arena scratch or an
/// `Arc`-shared buffer), so a stage handoff never copies activation data.
pub type ActMsg = (u64, Tensor);

/// Worker reply to the engine collector.
pub type Reply = (u64, anyhow::Result<BatchOutput>);

/// Static description of one worker's role.
#[derive(Clone, Debug)]
pub struct WorkerCtx {
    pub preset: String,
    pub cfg: ModelConfig,
    pub par: ParallelConfig,
    pub stage: usize,
    pub tp_rank: usize,
    pub layers: Range<usize>,
    /// Attempt DRCE packed execution when a bucket fits (§4.3).
    pub drce: bool,
    /// Distributed consistency queue on/off (ablation).
    pub consistency: bool,
    /// Prefetch lookahead hint passed to the layer provider.
    pub lookahead: usize,
    /// Incremental decode via the paged K/V cache (requires the decode
    /// artifacts; the engine resolves availability at launch).
    pub kv_cache: bool,
    /// Chaos fault schedule (empty by default): perturbs this worker's
    /// handling of selected forward tickets at the reply boundary.
    pub faults: FaultPlan,
}

impl WorkerCtx {
    pub fn device_id(&self) -> usize {
        self.par.device_of(self.stage, self.tp_rank)
    }

    pub fn is_first_stage(&self) -> bool {
        self.stage == 0
    }

    pub fn is_last_stage(&self) -> bool {
        self.stage == self.par.pp - 1
    }

    pub fn tp_group(&self) -> Vec<usize> {
        (0..self.par.tp).map(|r| self.par.device_of(self.stage, r)).collect()
    }

    pub fn is_replier(&self) -> bool {
        self.is_last_stage() && self.tp_rank == 0
    }
}

/// Everything a worker thread owns.
pub struct Worker {
    pub ctx: WorkerCtx,
    pub manifest: Arc<Manifest>,
    pub device: Device,
    pub provider: Box<dyn LayerProvider>,
    /// wte/wpe (first stage) and lnf/wte (last stage) argument tails.
    pub embed_weights: Option<Vec<Value>>,
    pub logits_weights: Option<Vec<Value>>,
    pub cmd_rx: Receiver<Command>,
    pub coll_ep: Endpoint<ChunkMsg>,
    pub act_ep: Endpoint<ActMsg>,
    pub reply_tx: Sender<Reply>,
    /// Device-resident weight literals, keyed by (local layer, tail kind)
    /// and invalidated via the provider's epoch (§Perf: no per-batch
    /// weight re-upload).
    pub weight_lits: HashMap<(usize, WeightKind), (u64, Rc<Vec<xla::Literal>>)>,
    pub embed_lits: Option<Vec<xla::Literal>>,
    pub logits_lits: Option<Vec<xla::Literal>>,
    /// Paged per-session K/V storage for this worker's layers (`None`
    /// when incremental decode is off or the artifacts lack the decode
    /// variants). Sessions are freed by ticketed `Command::Release`;
    /// under the tiered cache, `Command::Spill`/`Command::Prefetch` move
    /// whole sessions between the device slab and the host tier.
    pub kv: Option<KvCache>,
}

/// Which argument tail of a layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum WeightKind {
    Attn,
    Mlp,
    All,
}

/// Activation as it flows through a stage: padded (B,S,H) or DRCE-packed
/// (T,H) with its maps.
enum Act {
    Padded(Tensor),
    Packed(Tensor, DrceMaps),
}

/// A ticketed unit of worker work: a forward pass, a cache release, or a
/// tier move. All flow through the consistency queue, so a release can
/// never overtake a still-queued decode step of the same session — and a
/// prefetch published before a decode bucket is always applied before
/// that bucket executes (the tiered cache's residency guarantee).
enum Work {
    Forward(Arc<BatchInput>),
    Release(Arc<Vec<u64>>),
    Cancel(Arc<Vec<u64>>),
    Spill(Arc<Vec<u64>>),
    Prefetch { ids: Arc<Vec<u64>>, hint: bool },
    Park(Arc<Vec<u64>>),
    Fetch(Arc<Vec<u64>>),
    EvictPrefix(Arc<Vec<u64>>),
}

impl Worker {
    /// Main loop: drain commands through the consistency queue, execute in
    /// ticket order, exit on Shutdown.
    pub fn run(mut self) {
        let mut queue: ConsistencyQueue<(u64, Work)> = ConsistencyQueue::new(self.ctx.consistency);
        let mut shutting_down = false;
        loop {
            if let Some((uid, work)) = queue.pop_ready() {
                // With the queue disabled (ablation), pop order is arrival
                // order, which can differ across workers — exactly the
                // mispairing hazard §4.2 describes.
                match work {
                    Work::Forward(input) => {
                        // staged tier copies (overlapped copier) must land
                        // before any forward reads or writes the cache
                        if let Some(kv) = &mut self.kv {
                            kv.settle_all();
                        }
                        let fault = if self.ctx.faults.is_empty() {
                            None
                        } else {
                            self.ctx.faults.action(self.ctx.device_id(), uid)
                        };
                        self.execute_faulted(uid, &input, fault);
                    }
                    // Cancel frees exactly like Release — the distinction
                    // is observability: one is a finished session, the
                    // other a disconnected client's
                    Work::Release(ids) | Work::Cancel(ids) => {
                        if let Some(kv) = &mut self.kv {
                            for &id in ids.iter() {
                                kv.free(id);
                            }
                        }
                    }
                    Work::Spill(ids) => {
                        if let Some(kv) = &mut self.kv {
                            for &id in ids.iter() {
                                kv.spill(id);
                            }
                        }
                    }
                    Work::Park(ids) => {
                        if let Some(kv) = &mut self.kv {
                            for &id in ids.iter() {
                                kv.park(id);
                            }
                            // drain any park arriving from the ring client
                            // while we're at a known-safe point
                            kv.pump_peer();
                        }
                    }
                    // no stall timing here: `fetch` self-measures its total
                    // elapsed (peer wait + landing copy) into the prefetch
                    // stall gauge, hint or not
                    Work::Fetch(ids) => {
                        if let Some(kv) = &mut self.kv {
                            for &id in ids.iter() {
                                kv.fetch(id);
                            }
                        }
                    }
                    Work::EvictPrefix(ids) => {
                        if let Some(kv) = &mut self.kv {
                            kv.evict_prefix(&ids);
                        }
                    }
                    Work::Prefetch { ids, hint } => {
                        if let Some(kv) = &mut self.kv {
                            let t0 = std::time::Instant::now();
                            let mut moved = 0u64;
                            for &id in ids.iter() {
                                moved += kv.prefetch(id);
                            }
                            // a non-hint prefetch was issued at bucket
                            // admission: its copy time sits on the decode
                            // critical path (the stall the lookahead
                            // hints exist to hide)
                            if !hint && moved > 0 {
                                crate::memory::kvcache::note_prefetch_stall_us(
                                    t0.elapsed().as_micros() as u64,
                                );
                            }
                        }
                    }
                }
                continue;
            }
            if shutting_down {
                break;
            }
            match self.cmd_rx.recv() {
                Ok(Command::Forward { uid, input }) => queue.push(uid, (uid, Work::Forward(input))),
                Ok(Command::Release { uid, ids }) => queue.push(uid, (uid, Work::Release(ids))),
                Ok(Command::Cancel { uid, ids }) => queue.push(uid, (uid, Work::Cancel(ids))),
                Ok(Command::Spill { uid, ids }) => queue.push(uid, (uid, Work::Spill(ids))),
                Ok(Command::Prefetch { uid, ids, hint }) => {
                    queue.push(uid, (uid, Work::Prefetch { ids, hint }))
                }
                Ok(Command::Park { uid, ids }) => queue.push(uid, (uid, Work::Park(ids))),
                Ok(Command::Fetch { uid, ids, hint: _ }) => {
                    queue.push(uid, (uid, Work::Fetch(ids)))
                }
                Ok(Command::EvictPrefix { uid, ids }) => {
                    queue.push(uid, (uid, Work::EvictPrefix(ids)))
                }
                Ok(Command::Shutdown) | Err(_) => shutting_down = true,
            }
        }
    }

    /// `execute_logged` with a chaos fault applied at the reply boundary.
    /// The batch is always *executed* — skipping execution on one rank
    /// would wedge the TP collectives and desynchronize every rank's K/V
    /// state — so faults perturb only what the engine observes:
    ///
    /// * `Delay` sleeps before executing (a stalled worker: the reply and
    ///   everything queued behind this ticket arrive late);
    /// * `Drop` suppresses the reply (observable on the replier rank: the
    ///   collector never hears back and the watchdog must poison the
    ///   batch — scope multi-rank plans with `@w<rank>`);
    /// * `Panic` replaces the reply with an injected error (the
    ///   crashed-worker case: on the replier the collector's error path
    ///   fails the batch; on other ranks it logs like any worker error).
    fn execute_faulted(&mut self, uid: u64, input: &BatchInput, fault: Option<FaultKind>) {
        if let Some(FaultKind::Delay(d)) = fault {
            std::thread::sleep(d);
        }
        match fault {
            Some(FaultKind::Drop) => {
                let r = self.execute(uid, input);
                eprintln!(
                    "worker {}: injected reply drop for batch {uid} (execute {})",
                    self.ctx.device_id(),
                    if r.is_ok() { "ok" } else { "failed" },
                );
            }
            Some(FaultKind::Panic) => {
                let _ = self.execute(uid, input);
                if self.ctx.is_replier() {
                    let _ = self
                        .reply_tx
                        .send((uid, Err(anyhow::anyhow!("injected worker fault on batch {uid}"))));
                } else {
                    eprintln!(
                        "worker {}: injected fault on batch {uid} (non-replier)",
                        self.ctx.device_id(),
                    );
                }
            }
            _ => self.execute_logged(uid, input),
        }
    }

    fn execute_logged(&mut self, uid: u64, input: &BatchInput) {
        match self.execute(uid, input) {
            Ok(Some(out)) => {
                let _ = self.reply_tx.send((out.uid, Ok(out)));
            }
            Ok(None) => {}
            Err(e) => {
                if self.ctx.is_replier() {
                    let _ = self.reply_tx.send((uid, Err(e)));
                } else {
                    // poison downstream by dropping; the engine watchdog
                    // will surface the stall. Log loudly, attributing the
                    // batch to its sessions via the per-row ids.
                    eprintln!(
                        "worker {} failed on batch {uid} (sessions {:?}): {e:#}",
                        self.ctx.device_id(),
                        input.req_ids,
                    );
                }
            }
        }
    }

    /// Execute one batch through this worker's stage. Returns the reply if
    /// this worker is the replier.
    fn execute(&mut self, uid: u64, input: &BatchInput) -> anyhow::Result<Option<BatchOutput>> {
        if input.phase == Phase::Decode {
            return self.execute_decode(uid, input);
        }
        if input.phase == Phase::Verify {
            return self.execute_verify(uid, input);
        }
        if input.phase == Phase::Chunk {
            return self.execute_chunk(uid, input);
        }
        let (b, s) = (input.batch, input.seq);
        let h = self.ctx.cfg.hidden;
        let valid = valid_len_arg(&input.valid_lens);
        // cache-seeding prefill runs the padded `*_kv` variants (they
        // can't emit K/V rows from the packed layout, so DRCE steps aside
        // for generation prefills)
        let store_kv = input.cache && self.kv.is_some();
        let drce_maps = if store_kv { None } else { self.plan_drce(input)? };

        // ---- acquire the stage input ------------------------------------
        let mut act = if self.ctx.is_first_stage() {
            let x = self.run_embed(input)?;
            match &drce_maps {
                Some(maps) => {
                    let flat = x.reshape(&[b * s, h]);
                    Act::Packed(drce::pack(&flat, maps), maps.clone())
                }
                None => Act::Padded(x),
            }
        } else {
            let prev = self.ctx.par.device_of(self.ctx.stage - 1, self.ctx.tp_rank);
            let (got_uid, t) = self.act_ep.recv(prev);
            if self.ctx.consistency {
                anyhow::ensure!(
                    got_uid == uid,
                    "stage {} received activation for batch {got_uid}, expected {uid}",
                    self.ctx.stage
                );
            }
            match &drce_maps {
                Some(maps) => Act::Packed(t, maps.clone()),
                None => Act::Padded(t),
            }
        };

        // ---- run my layers ----------------------------------------------
        let first = self.ctx.layers.start;
        self.provider.prefetch(0);
        for layer in self.ctx.layers.clone() {
            let local = layer - first;
            // issue the lookahead prefetch before computing (Fig. 8)
            for ahead in 1..=self.ctx.lookahead.max(1) {
                self.provider.prefetch(local + ahead);
            }
            act = self.run_layer(local, act, &valid, input, store_kv)?;
            self.provider.release(local);
        }
        if store_kv {
            self.kv_advance(input);
            self.kv_retain(input);
        }

        // ---- hand off or reply --------------------------------------------
        if !self.ctx.is_last_stage() {
            let next = self.ctx.par.device_of(self.ctx.stage + 1, self.ctx.tp_rank);
            let t = match act {
                Act::Padded(t) => t,
                Act::Packed(t, _) => t,
            };
            self.act_ep.send(next, (uid, t));
            return Ok(None);
        }

        // last stage: unpack, project to logits, reply (tp rank 0 only)
        let x = match act {
            Act::Padded(t) => t,
            Act::Packed(t, maps) => drce::unpack(&t, &maps).reshape(&[b, s, h]),
        };
        if !self.ctx.is_replier() {
            return Ok(None);
        }
        let logits = self.run_logits(x, input)?;
        let next_tokens = argmax_next_tokens(&logits, &input.valid_lens);
        Ok(Some(BatchOutput { uid, next_tokens, logits, accepted: Vec::new() }))
    }

    /// One decode engine step: embed the newest token per row at its
    /// position, run every local layer as a single-position attention over
    /// the session's cached K/V (appending the new row), and project the
    /// (b, 1, v) logits. The whole prefix never re-enters the linears —
    /// the O(N·(P+N)) → O(P+N) win of incremental decode.
    fn execute_decode(
        &mut self,
        uid: u64,
        input: &BatchInput,
    ) -> anyhow::Result<Option<BatchOutput>> {
        anyhow::ensure!(self.kv.is_some(), "decode batch {uid} but the KV cache is disabled");
        anyhow::ensure!(input.seq == 1, "decode batch {uid} has seq {}", input.seq);
        // shared-prefix hits arrive as decode steps whose session does not
        // exist yet: seed it from the registry before any layer gathers
        self.kv_adopt(input);
        let valid = valid_len_arg(&input.valid_lens);

        // ---- acquire the stage input ------------------------------------
        let mut x = if self.ctx.is_first_stage() {
            let v = self.variant("embed_decode", input, 0)?;
            if self.embed_lits.is_none() {
                let w = self.embed_weights.as_ref().expect("stage 0 has embed weights");
                self.embed_lits = Some(crate::runtime::pjrt::prepare(w)?);
            }
            let pos: Vec<i32> = input.valid_lens.iter().map(|&l| (l.max(1) - 1) as i32).collect();
            let acts = [
                Value::I32(input.ids.clone()),
                Value::I32(IntTensor::from_vec(pos)),
            ];
            self.device
                .execute_prepared(&self.manifest, &v, &acts, self.embed_lits.as_ref().unwrap())?
                .remove(0)
        } else {
            let prev = self.ctx.par.device_of(self.ctx.stage - 1, self.ctx.tp_rank);
            let (got_uid, t) = self.act_ep.recv(prev);
            if self.ctx.consistency {
                anyhow::ensure!(
                    got_uid == uid,
                    "stage {} received activation for batch {got_uid}, expected {uid}",
                    self.ctx.stage
                );
            }
            t
        };

        // ---- run my layers ----------------------------------------------
        let first = self.ctx.layers.start;
        self.provider.prefetch(0);
        for layer in self.ctx.layers.clone() {
            let local = layer - first;
            for ahead in 1..=self.ctx.lookahead.max(1) {
                self.provider.prefetch(local + ahead);
            }
            x = self.run_layer_cached(local, x, &valid, input, 1)?;
            self.provider.release(local);
        }
        self.kv_advance(input);
        // a chunked registrant whose suffix degenerated to stepping decode
        // retains on the step that crosses its retention boundary
        self.kv_retain(input);

        // ---- hand off or reply --------------------------------------------
        if !self.ctx.is_last_stage() {
            let next = self.ctx.par.device_of(self.ctx.stage + 1, self.ctx.tp_rank);
            self.act_ep.send(next, (uid, x));
            return Ok(None);
        }
        if !self.ctx.is_replier() {
            return Ok(None);
        }
        // (b, 1) logits: argmax reads position 0 of every row (the clamp
        // in argmax_next_tokens maps any valid_len to the only position)
        let logits = self.run_logits(x, input)?;
        let next_tokens = argmax_next_tokens(&logits, &input.valid_lens);
        Ok(Some(BatchOutput { uid, next_tokens, logits, accepted: Vec::new() }))
    }

    /// One speculative engine step: embed the k-token drafted window per
    /// row at its positions, run every local layer as a windowed attention
    /// over the session's cached K/V (appending all k new rows), score the
    /// whole window with the seq=k logits head, accept the longest drafted
    /// prefix that matches the true greedy tokens, and truncate the
    /// rejected speculative rows back out of the cache. One pass commits
    /// `accepted + 1` tokens — the tokens-per-pass > 1 win of speculative
    /// decoding, lossless because every committed token is the argmax the
    /// plain decode path would have produced. (Strictly: the verify and
    /// decode variants are *differently compiled* programs whose logits
    /// agree to float tolerance, not bitwise — a near-tie between the top
    /// two vocab entries could in principle argmax differently. The
    /// differential suite pins stream equality empirically; it is not a
    /// by-construction guarantee.)
    ///
    /// Verify batches only exist under pp == 1 (the engine gates them):
    /// acceptance is computed from the logits, which every last-stage
    /// worker evaluates locally so each can truncate its own cache —
    /// earlier pipeline stages would have no way to learn the accepted
    /// length without a backchannel. Under TP every rank sees bitwise-
    /// identical all-reduced activations, so their acceptance decisions
    /// agree (pinned by the tp=2 differential suite).
    fn execute_verify(
        &mut self,
        uid: u64,
        input: &BatchInput,
    ) -> anyhow::Result<Option<BatchOutput>> {
        anyhow::ensure!(self.kv.is_some(), "verify batch {uid} but the KV cache is disabled");
        anyhow::ensure!(
            self.ctx.par.pp == 1,
            "verify batch {uid} under pp={} (the engine must gate speculation off)",
            self.ctx.par.pp
        );
        let k = input.seq;
        anyhow::ensure!(k >= 2, "verify batch {uid} has window {k}");
        let valid = valid_len_arg(&input.valid_lens);

        // ---- embed the window -------------------------------------------
        let v = self.variant("embed_verify", input, 0)?;
        if self.embed_lits.is_none() {
            let w = self.embed_weights.as_ref().expect("stage 0 has embed weights");
            self.embed_lits = Some(crate::runtime::pjrt::prepare(w)?);
        }
        // base position of each row's window: valid_len - k
        let pos: Vec<i32> = input.valid_lens.iter().map(|&l| (l.max(k) - k) as i32).collect();
        let acts = [Value::I32(input.ids.clone()), Value::I32(IntTensor::from_vec(pos))];
        let mut x = self
            .device
            .execute_prepared(&self.manifest, &v, &acts, self.embed_lits.as_ref().unwrap())?
            .remove(0);

        // ---- run my layers ----------------------------------------------
        let first = self.ctx.layers.start;
        self.provider.prefetch(0);
        for layer in self.ctx.layers.clone() {
            let local = layer - first;
            for ahead in 1..=self.ctx.lookahead.max(1) {
                self.provider.prefetch(local + ahead);
            }
            x = self.run_layer_cached(local, x, &valid, input, k)?;
            self.provider.release(local);
        }
        // every window row is in the cache now; the acceptance pass below
        // truncates the rejected tail
        self.kv_advance(input);

        // ---- score the window + accept ----------------------------------
        // every last-stage worker computes the logits (the all-reduced
        // activation is identical on all tp ranks) so each can truncate
        // its own cache shard; only the replier also builds the reply
        let logits = self.run_logits(x, input)?;
        let (b, s, vsz) = (logits.shape[0], logits.shape[1], logits.shape[2]);
        debug_assert_eq!((b, s), (input.batch, k));
        let mut next_tokens = Vec::with_capacity(b);
        let mut accepted: Vec<Vec<i32>> = Vec::with_capacity(b);
        for (i, (&id, &len)) in input.req_ids.iter().zip(&input.valid_lens).enumerate() {
            if id == u64::MAX {
                next_tokens.push(0);
                accepted.push(Vec::new());
                continue;
            }
            // greedy token after each window prefix — selected by the
            // same argmax rule plain decode uses (argmax_next_tokens),
            // which is what keeps acceptance lossless
            let verified: Vec<i32> = (0..k)
                .map(|j| argmax_row(&logits.data[(i * k + j) * vsz..(i * k + j + 1) * vsz]))
                .collect();
            // longest drafted prefix matching the true greedy tokens:
            // drafted token j (ids slot j+1) must equal verified[j]
            let mut a = 0;
            while a < k - 1 && input.ids.data[i * k + a + 1] == verified[a] {
                a += 1;
            }
            // committed tokens: the accepted drafts are verified[0..a]
            // (each equals its draft), plus the bonus token verified[a]
            let committed: Vec<i32> = verified[..=a].to_vec();
            // cache keeps the rows of window positions 0..=a; rows for
            // the rejected tail come back out before the session's next
            // step reads (or re-appends over) those positions
            let keep = len - k + a + 1;
            self.kv.as_mut().expect("verify without a cache").truncate_tail(id, keep);
            next_tokens.push(committed[0]);
            accepted.push(committed);
        }
        if !self.ctx.is_replier() {
            return Ok(None);
        }
        Ok(Some(BatchOutput { uid, next_tokens, logits, accepted }))
    }

    /// One chunked-prefill engine step: embed a k-token window of the
    /// prompt at positions `chunk_start ..`, run every local layer as a
    /// windowed attention over the session's already-seeded prefix
    /// (appending the window's K/V rows), and advance the cache to the
    /// window end. The kernels are the verify family — a chunk window *is*
    /// a verify window whose "draft" happens to be real prompt tokens —
    /// so no new executables exist for this path; only the collector's
    /// interpretation differs (mid-prompt argmaxes are discarded, the
    /// final chunk's argmax is the first generated token, byte-identical
    /// to what a monolithic prefill's prompt-end logits produce).
    ///
    /// Unlike verify there is no acceptance pass and no cache truncation,
    /// so chunked prefill runs under any pp: stages just hand the
    /// activation down and the last stage replies.
    fn execute_chunk(
        &mut self,
        uid: u64,
        input: &BatchInput,
    ) -> anyhow::Result<Option<BatchOutput>> {
        anyhow::ensure!(self.kv.is_some(), "chunk batch {uid} but the KV cache is disabled");
        let k = input.seq;
        anyhow::ensure!(k >= 2, "chunk batch {uid} has window {k}");
        // a prefix hit's first chunk seeds its session from the registry
        self.kv_adopt(input);
        let valid = valid_len_arg(&input.valid_lens);

        // ---- acquire the stage input ------------------------------------
        let mut x = if self.ctx.is_first_stage() {
            let v = self.variant("embed_verify", input, 0)?;
            if self.embed_lits.is_none() {
                let w = self.embed_weights.as_ref().expect("stage 0 has embed weights");
                self.embed_lits = Some(crate::runtime::pjrt::prepare(w)?);
            }
            // base position of each row's window: valid_len - k, i.e. the
            // row's chunk_start (pads clamp to 0)
            let pos: Vec<i32> =
                input.valid_lens.iter().map(|&l| (l.max(k) - k) as i32).collect();
            let acts = [Value::I32(input.ids.clone()), Value::I32(IntTensor::from_vec(pos))];
            self.device
                .execute_prepared(&self.manifest, &v, &acts, self.embed_lits.as_ref().unwrap())?
                .remove(0)
        } else {
            let prev = self.ctx.par.device_of(self.ctx.stage - 1, self.ctx.tp_rank);
            let (got_uid, t) = self.act_ep.recv(prev);
            if self.ctx.consistency {
                anyhow::ensure!(
                    got_uid == uid,
                    "stage {} received activation for batch {got_uid}, expected {uid}",
                    self.ctx.stage
                );
            }
            t
        };

        // ---- run my layers ----------------------------------------------
        let first = self.ctx.layers.start;
        self.provider.prefetch(0);
        for layer in self.ctx.layers.clone() {
            let local = layer - first;
            for ahead in 1..=self.ctx.lookahead.max(1) {
                self.provider.prefetch(local + ahead);
            }
            x = self.run_layer_cached(local, x, &valid, input, k)?;
            self.provider.release(local);
        }
        self.kv_advance(input);
        // a registrant's retention lands on the chunk whose window crosses
        // the boundary (the batcher materializes retain only on that step)
        self.kv_retain(input);

        // ---- hand off or reply --------------------------------------------
        if !self.ctx.is_last_stage() {
            let next = self.ctx.par.device_of(self.ctx.stage + 1, self.ctx.tp_rank);
            self.act_ep.send(next, (uid, x));
            return Ok(None);
        }
        if !self.ctx.is_replier() {
            return Ok(None);
        }
        // (b, k, v) logits: valid (= chunk end, >= k) clamps to the last
        // window row — the model's prediction for the position after this
        // chunk. Mid-prompt the collector discards it; on the final chunk
        // it is the stream's first token.
        let logits = self.run_logits(x, input)?;
        let next_tokens = argmax_next_tokens(&logits, &input.valid_lens);
        Ok(Some(BatchOutput { uid, next_tokens, logits, accepted: Vec::new() }))
    }

    /// Append each real row's new K/V rows (shape (b, window, w)) at
    /// window positions `valid_len - window ..= valid_len - 1` (plain
    /// decode is the window == 1 case).
    fn kv_write_window(&mut self, local: usize, input: &BatchInput, k_new: &Tensor, v_new: &Tensor) {
        let k = input.seq;
        let w = self.ctx.cfg.hidden / self.ctx.par.tp;
        let kv = self.kv.as_mut().expect("kv_write_window without a cache");
        for (i, (&id, &len)) in input.req_ids.iter().zip(&input.valid_lens).enumerate() {
            if id == u64::MAX {
                continue;
            }
            let base = len - k;
            for j in 0..k {
                let row = (i * k + j) * w..(i * k + j + 1) * w;
                kv.write_row(id, local, base + j, &k_new.data[row.clone()], &v_new.data[row]);
            }
        }
    }

    /// Decide whether this batch runs packed, identically on all workers:
    /// DRCE is on, a (b, s, tp) bucket exists, and the valid tokens fit.
    fn plan_drce(&self, input: &BatchInput) -> anyhow::Result<Option<DrceMaps>> {
        if !self.ctx.drce {
            return Ok(None);
        }
        let total: usize = input.valid_lens.iter().sum();
        let mut buckets: Vec<usize> = self
            .manifest
            .by_kind(&self.ctx.preset, "drce_attn_shard")
            .filter(|v| v.batch == input.batch && v.seq == input.seq && v.tp == self.ctx.par.tp)
            .map(|v| v.t_bucket)
            .collect();
        buckets.sort();
        match drce::pick_bucket(total, &buckets) {
            Some(t) => Ok(Some(drce::make_maps(&input.valid_lens, input.seq, t)?)),
            None => Ok(None), // fall back to padded execution
        }
    }

    fn variant(&self, kind: &str, input: &BatchInput, t_bucket: usize) -> anyhow::Result<crate::runtime::VariantMeta> {
        let tp = if kind.starts_with("layer_full") || kind.starts_with("embed") || kind == "logits" {
            1
        } else {
            self.ctx.par.tp
        };
        let name = Manifest::name_of(&self.ctx.preset, kind, input.batch, input.seq, tp, t_bucket);
        self.manifest.get(&name).cloned()
    }

    /// Device-resident weight tail for a layer, rebuilt when the provider
    /// reports a new epoch (pool eviction + refetch).
    fn layer_lits(&mut self, local: usize, kind: WeightKind) -> anyhow::Result<Rc<Vec<xla::Literal>>> {
        let epoch = self.provider.epoch(local);
        if let Some((e, lits)) = self.weight_lits.get(&(local, kind)) {
            if *e == epoch {
                return Ok(lits.clone());
            }
        }
        let vals = match kind {
            WeightKind::Attn => self.provider.attn_args(local),
            WeightKind::Mlp => self.provider.mlp_args(local),
            WeightKind::All => self.provider.all_args(local),
        };
        let lits = Rc::new(crate::runtime::pjrt::prepare(&vals)?);
        self.weight_lits.insert((local, kind), (epoch, lits.clone()));
        Ok(lits)
    }

    fn run_embed(&mut self, input: &BatchInput) -> anyhow::Result<Tensor> {
        let v = self.variant("embed", input, 0)?;
        if self.embed_lits.is_none() {
            let w = self.embed_weights.as_ref().expect("stage 0 has embed weights");
            self.embed_lits = Some(crate::runtime::pjrt::prepare(w)?);
        }
        let acts = [Value::I32(input.ids.clone())];
        Ok(self
            .device
            .execute_prepared(&self.manifest, &v, &acts, self.embed_lits.as_ref().unwrap())?
            .remove(0))
    }

    fn run_logits(&mut self, x: Tensor, input: &BatchInput) -> anyhow::Result<Tensor> {
        let v = self.variant("logits", input, 0)?;
        if self.logits_lits.is_none() {
            let w = self.logits_weights.as_ref().expect("last stage has logits weights");
            self.logits_lits = Some(crate::runtime::pjrt::prepare(w)?);
        }
        // x is moved, not cloned — the last activation copy on this path
        let acts = [Value::F32(x)];
        Ok(self
            .device
            .execute_prepared(&self.manifest, &v, &acts, self.logits_lits.as_ref().unwrap())?
            .remove(0))
    }

    /// One transformer layer: fused single-device, TP-sharded, or DRCE.
    /// With `store_kv` the padded variants run their `*_kv` twins and the
    /// emitted K/V rows seed each real row's session cache.
    fn run_layer(
        &mut self,
        local: usize,
        act: Act,
        valid: &Value,
        input: &BatchInput,
        store_kv: bool,
    ) -> anyhow::Result<Act> {
        let (b, s) = (input.batch, input.seq);
        let h = self.ctx.cfg.hidden;
        let tp = self.ctx.par.tp;
        match act {
            Act::Padded(x) if tp == 1 => {
                let kind = if store_kv { "layer_full_kv" } else { "layer_full" };
                let v = self.variant(kind, input, 0)?;
                let lits = self.layer_lits(local, WeightKind::All)?;
                let acts = [Value::F32(x), valid.clone()];
                let mut out = self.device.execute_prepared(&self.manifest, &v, &acts, &lits)?;
                let y = out.remove(0);
                if store_kv {
                    let (k, vv) = (out.remove(0), out.remove(0));
                    self.kv_store_prefill(local, input, &k, &vv);
                }
                Ok(Act::Padded(y))
            }
            Act::Padded(mut x) => {
                // attention half (partial) -> all-reduce -> residual.
                // The activation fans out (executable arg + residual), so
                // share its storage once: the clone below is an Arc bump,
                // not a data copy (§Perf).
                x.make_shared();
                let kind = if store_kv { "attn_shard_kv" } else { "attn_shard" };
                let v = self.variant(kind, input, 0)?;
                let lits = self.layer_lits(local, WeightKind::Attn)?;
                let acts = [Value::F32(x.clone()), valid.clone()];
                let mut out = self.device.execute_prepared(&self.manifest, &v, &acts, &lits)?;
                let partial = out.remove(0);
                if store_kv {
                    let (k, vv) = (out.remove(0), out.remove(0));
                    self.kv_store_prefill(local, input, &k, &vv);
                }
                let attn_sum = self.allreduce(partial);
                let mut r = x.add(&attn_sum); // arena scratch
                r.make_shared();
                // mlp half over (b*s, h) rows — zero-copy reshape of a view
                let v = self.variant("mlp_shard", input, 0)?;
                let lits = self.layer_lits(local, WeightKind::Mlp)?;
                let r2 = r.clone().reshape(&[b * s, h]);
                let acts = [Value::F32(r2)];
                let partial = self.device.execute_prepared(&self.manifest, &v, &acts, &lits)?.remove(0);
                let mlp_sum = self.allreduce(partial).reshape(&[b, s, h]);
                Ok(Act::Padded(r.add(&mlp_sum)))
            }
            Act::Packed(mut xp, maps) => {
                xp.make_shared(); // Arc-cheap clone into the arg list below
                let v = self.variant("drce_attn_shard", input, maps.t_bucket)?;
                let lits = self.layer_lits(local, WeightKind::Attn)?;
                let acts = [
                    Value::F32(xp.clone()),
                    valid.clone(),
                    Value::I32(maps.unpad_map.clone()),
                    Value::I32(maps.pad_map.clone()),
                ];
                let partial = self.device.execute_prepared(&self.manifest, &v, &acts, &lits)?.remove(0);
                let attn_sum = self.allreduce(partial);
                let mut r = xp.add(&attn_sum); // arena scratch
                r.make_shared();
                let v = self.variant("mlp_shard", input, maps.t_bucket)?;
                let lits = self.layer_lits(local, WeightKind::Mlp)?;
                let acts = [Value::F32(r.clone())];
                let partial = self.device.execute_prepared(&self.manifest, &v, &acts, &lits)?.remove(0);
                let mlp_sum = self.allreduce(partial);
                Ok(Act::Packed(r.add(&mlp_sum), maps))
            }
        }
    }

    /// One transformer layer of a cached continuation step — the shared
    /// body of plain decode (`window == 1`) and speculative verify
    /// (`window == k`): windowed attention over the gathered cache
    /// (emitting the window's K/V rows, written back at positions
    /// `valid_len - window ..`), then — under TP — the usual all-reduce +
    /// residual + `mlp_shard` with rows = b·window. One body on purpose:
    /// decode and verify must stay numerically in lockstep for the
    /// acceptance parity the differential suite pins, so a fix to either
    /// path lands in both.
    fn run_layer_cached(
        &mut self,
        local: usize,
        x: Tensor,
        valid: &Value,
        input: &BatchInput,
        window: usize,
    ) -> anyhow::Result<Tensor> {
        let b = input.batch;
        debug_assert_eq!(input.seq, window);
        let h = self.ctx.cfg.hidden;
        let tp = self.ctx.par.tp;
        let (kc, vc) = self.kv_staging(local, input, window)?;
        let (full_kind, shard_kind) = if window == 1 {
            ("layer_full_decode", "attn_shard_decode")
        } else {
            ("layer_full_verify", "attn_shard_verify")
        };
        if tp == 1 {
            let v = self.variant(full_kind, input, 0)?;
            let lits = self.layer_lits(local, WeightKind::All)?;
            let acts = [Value::F32(x), valid.clone(), Value::F32(kc), Value::F32(vc)];
            let mut out = self.device.execute_prepared(&self.manifest, &v, &acts, &lits)?;
            let y = out.remove(0);
            let (k_new, v_new) = (out.remove(0), out.remove(0));
            self.kv_write_window(local, input, &k_new, &v_new);
            return Ok(y);
        }
        let mut x = x;
        x.make_shared();
        let v = self.variant(shard_kind, input, 0)?;
        let lits = self.layer_lits(local, WeightKind::Attn)?;
        let acts = [Value::F32(x.clone()), valid.clone(), Value::F32(kc), Value::F32(vc)];
        let mut out = self.device.execute_prepared(&self.manifest, &v, &acts, &lits)?;
        let partial = out.remove(0);
        let (k_new, v_new) = (out.remove(0), out.remove(0));
        self.kv_write_window(local, input, &k_new, &v_new);
        let attn_sum = self.allreduce(partial);
        let mut r = x.add(&attn_sum); // arena scratch
        r.make_shared();
        // rows = b·window (variant name mlp_shard_tp{tp}_r{b*window})
        let v = self.variant("mlp_shard", input, 0)?;
        let lits = self.layer_lits(local, WeightKind::Mlp)?;
        let r2 = r.clone().reshape(&[b * window, h]);
        let partial = self
            .device
            .execute_prepared(&self.manifest, &v, &[Value::F32(r2)], &lits)?
            .remove(0);
        let mlp_sum = self.allreduce(partial).reshape(&[b, window, h]);
        Ok(r.add(&mlp_sum))
    }

    /// Gather each real row's cached K/V for `local` into zeroed staging
    /// tensors of shape (b, max_seq, h/tp). Zeroing matters: masked score
    /// slots must hold finite small values, not recycled-arena garbage
    /// that could dominate the softmax max. `window` is how many of the
    /// row's `valid_len` positions this step itself computes (1 for plain
    /// decode, k for a verify window) — the cache must hold the rest.
    fn kv_staging(
        &mut self,
        local: usize,
        input: &BatchInput,
        window: usize,
    ) -> anyhow::Result<(Tensor, Tensor)> {
        let b = input.batch;
        let cap = self.ctx.cfg.max_seq;
        let w = self.ctx.cfg.hidden / self.ctx.par.tp;
        let mut kc = Tensor::pooled_zeros(&[b, cap, w]);
        let mut vc = Tensor::pooled_zeros(&[b, cap, w]);
        let kv = self.kv.as_ref().expect("kv_staging without a cache");
        for (i, (&id, &len)) in input.req_ids.iter().zip(&input.valid_lens).enumerate() {
            if id == u64::MAX {
                continue; // pad row: all-zero cache, fully masked anyway
            }
            let dst_k = &mut kc.data[i * cap * w..(i + 1) * cap * w];
            let dst_v = &mut vc.data[i * cap * w..(i + 1) * cap * w];
            let got = kv.gather(id, local, dst_k, dst_v);
            anyhow::ensure!(
                got + window == len,
                "session {id} layer {local}: cache holds {got} rows, step expects {}",
                len - window
            );
        }
        Ok((kc, vc))
    }

    /// Seed adopted rows' sessions from the prefix registry (shared-prefix
    /// reuse): a hit's first step carries `(donor, positions)` metadata,
    /// and `kv_staging` would find an empty cache without the adoption.
    /// A failed adoption (entry evicted despite the lease protocol) leaves
    /// the session absent, so the staging length check fails the batch
    /// loudly instead of decoding against garbage.
    fn kv_adopt(&mut self, input: &BatchInput) {
        if input.prefix_adopt.is_empty() {
            return;
        }
        let kv = self.kv.as_mut().expect("kv_adopt without a cache");
        for (i, &id) in input.req_ids.iter().enumerate() {
            if id == u64::MAX {
                continue;
            }
            if let Some(&Some((donor, positions))) = input.prefix_adopt.get(i) {
                if kv.len(id).is_none() {
                    kv.adopt_prefix(id, donor, positions);
                }
            }
        }
    }

    /// Retain prefill rows' prompt prefixes in the registry (shared-prefix
    /// reuse): the engine sets a non-zero count for rows whose prompt it
    /// registered in the admission trie. Runs after `kv_advance`, so the
    /// retained positions are published.
    fn kv_retain(&mut self, input: &BatchInput) {
        if input.prefix_retain.is_empty() {
            return;
        }
        let kv = self.kv.as_mut().expect("kv_retain without a cache");
        for (i, &id) in input.req_ids.iter().enumerate() {
            if id == u64::MAX {
                continue;
            }
            if input.prefix_retain.get(i).map_or(0, |&n| n) > 0 {
                kv.retain_prefix(id, input.prefix_retain[i]);
            }
        }
    }

    /// Seed the cache from a prefill `*_kv` output: rows 0..valid_len of
    /// each real batch row, for layer `local`. K/V are (b, s, w); a row's
    /// positions are contiguous, so the store is per-(block, layer)
    /// memcpys via [`KvCache::write_prefix`], mirroring `gather`.
    fn kv_store_prefill(&mut self, local: usize, input: &BatchInput, k: &Tensor, v: &Tensor) {
        let s = input.seq;
        let w = self.ctx.cfg.hidden / self.ctx.par.tp;
        let kv = self.kv.as_mut().expect("kv_store_prefill without a cache");
        for (i, (&id, &len)) in input.req_ids.iter().zip(&input.valid_lens).enumerate() {
            if id == u64::MAX {
                continue;
            }
            let row = i * s * w..(i * s + len) * w;
            kv.write_prefix(id, local, len, &k.data[row.clone()], &v.data[row]);
        }
    }

    /// Publish every real row's new cache length after all local layers
    /// ran (prefill: the prompt length; decode: one more position).
    fn kv_advance(&mut self, input: &BatchInput) {
        if let Some(kv) = self.kv.as_mut() {
            for (&id, &len) in input.req_ids.iter().zip(&input.valid_lens) {
                if id != u64::MAX {
                    kv.advance(id, len);
                }
            }
        }
    }

    fn allreduce(&self, t: Tensor) -> Tensor {
        if self.ctx.par.tp == 1 {
            return t;
        }
        ring_allreduce(&self.coll_ep, &self.ctx.tp_group(), t)
    }
}

/// Greedy token selection over one logits row — the single argmax rule
/// every sampling path shares (plain decode via [`argmax_next_tokens`],
/// verify acceptance in `execute_verify`), so speculation can never pick
/// a different token than plain decode would.
pub fn argmax_row(row: &[f32]) -> i32 {
    row.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(j, _)| j as i32)
        .unwrap()
}

/// Greedy next-token: argmax of the logits row at position valid-1.
pub fn argmax_next_tokens(logits: &Tensor, valid_lens: &[usize]) -> Vec<i32> {
    let (b, s, v) = (logits.shape[0], logits.shape[1], logits.shape[2]);
    assert_eq!(valid_lens.len(), b);
    let mut out = Vec::with_capacity(b);
    for (i, &vl) in valid_lens.iter().enumerate() {
        let pos = vl.clamp(1, s) - 1;
        out.push(argmax_row(&logits.data[(i * s + pos) * v..(i * s + pos + 1) * v]));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ctx_roles() {
        let par = ParallelConfig::new(2, 2);
        let cfg = ModelConfig::preset("tiny").unwrap();
        let ctx = WorkerCtx {
            preset: "tiny".into(),
            cfg: cfg.clone(),
            par,
            stage: 1,
            tp_rank: 0,
            layers: 2..4,
            drce: false,
            consistency: true,
            lookahead: 1,
            kv_cache: false,
            faults: FaultPlan::default(),
        };
        assert_eq!(ctx.device_id(), 2);
        assert!(ctx.is_last_stage());
        assert!(!ctx.is_first_stage());
        assert!(ctx.is_replier());
        assert_eq!(ctx.tp_group(), vec![2, 3]);
        let ctx2 = WorkerCtx { tp_rank: 1, ..ctx };
        assert!(!ctx2.is_replier());
    }

    #[test]
    fn argmax_uses_last_valid_position() {
        // b=1, s=3, v=4; valid=2 -> row at pos 1
        let logits = Tensor::new(
            &[1, 3, 4],
            vec![
                9., 0., 0., 0., // pos 0
                0., 0., 7., 0., // pos 1  <- selected
                0., 0., 0., 9., // pos 2
            ],
        );
        assert_eq!(argmax_next_tokens(&logits, &[2]), vec![2]);
        assert_eq!(argmax_next_tokens(&logits, &[1]), vec![0]);
        // valid beyond seq clamps
        assert_eq!(argmax_next_tokens(&logits, &[9]), vec![3]);
    }
}
