//! Worker: one simulated device. Runs the SPMD (multi-controller) half of
//! the hierarchy: executes its pipeline stage's layers as TP shards,
//! all-reduces with its TP group, hands activations to the next stage, and
//! — crucially — consumes engine commands through the distributed
//! consistency queue so every worker processes batch k as its k-th
//! execution (§4.2).

use super::consistency::ConsistencyQueue;
use super::rpc::{BatchInput, BatchOutput, Command};
use crate::comm::channel::Endpoint;
use crate::comm::collective::{ring_allreduce, ChunkMsg};
use crate::config::{ModelConfig, ParallelConfig};
use crate::memory::LayerProvider;
use crate::runtime::{valid_len_arg, Device, Manifest};
use crate::tensor::drce::{self, DrceMaps};
use crate::tensor::{Tensor, Value};
use std::collections::HashMap;
use std::ops::Range;
use std::rc::Rc;
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;

/// Activation hand-off between consecutive pipeline stages. The tensor is
/// *moved* into the channel (and its storage is usually arena scratch or an
/// `Arc`-shared buffer), so a stage handoff never copies activation data.
pub type ActMsg = (u64, Tensor);

/// Worker reply to the engine collector.
pub type Reply = (u64, anyhow::Result<BatchOutput>);

/// Static description of one worker's role.
#[derive(Clone, Debug)]
pub struct WorkerCtx {
    pub preset: String,
    pub cfg: ModelConfig,
    pub par: ParallelConfig,
    pub stage: usize,
    pub tp_rank: usize,
    pub layers: Range<usize>,
    /// Attempt DRCE packed execution when a bucket fits (§4.3).
    pub drce: bool,
    /// Distributed consistency queue on/off (ablation).
    pub consistency: bool,
    /// Prefetch lookahead hint passed to the layer provider.
    pub lookahead: usize,
}

impl WorkerCtx {
    pub fn device_id(&self) -> usize {
        self.par.device_of(self.stage, self.tp_rank)
    }

    pub fn is_first_stage(&self) -> bool {
        self.stage == 0
    }

    pub fn is_last_stage(&self) -> bool {
        self.stage == self.par.pp - 1
    }

    pub fn tp_group(&self) -> Vec<usize> {
        (0..self.par.tp).map(|r| self.par.device_of(self.stage, r)).collect()
    }

    pub fn is_replier(&self) -> bool {
        self.is_last_stage() && self.tp_rank == 0
    }
}

/// Everything a worker thread owns.
pub struct Worker {
    pub ctx: WorkerCtx,
    pub manifest: Arc<Manifest>,
    pub device: Device,
    pub provider: Box<dyn LayerProvider>,
    /// wte/wpe (first stage) and lnf/wte (last stage) argument tails.
    pub embed_weights: Option<Vec<Value>>,
    pub logits_weights: Option<Vec<Value>>,
    pub cmd_rx: Receiver<Command>,
    pub coll_ep: Endpoint<ChunkMsg>,
    pub act_ep: Endpoint<ActMsg>,
    pub reply_tx: Sender<Reply>,
    /// Device-resident weight literals, keyed by (local layer, tail kind)
    /// and invalidated via the provider's epoch (§Perf: no per-batch
    /// weight re-upload).
    pub weight_lits: HashMap<(usize, WeightKind), (u64, Rc<Vec<xla::Literal>>)>,
    pub embed_lits: Option<Vec<xla::Literal>>,
    pub logits_lits: Option<Vec<xla::Literal>>,
}

/// Which argument tail of a layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum WeightKind {
    Attn,
    Mlp,
    All,
}

/// Activation as it flows through a stage: padded (B,S,H) or DRCE-packed
/// (T,H) with its maps.
enum Act {
    Padded(Tensor),
    Packed(Tensor, DrceMaps),
}

impl Worker {
    /// Main loop: drain commands through the consistency queue, execute in
    /// ticket order, exit on Shutdown.
    pub fn run(mut self) {
        let mut queue: ConsistencyQueue<(u64, std::sync::Arc<BatchInput>)> =
            ConsistencyQueue::new(self.ctx.consistency);
        let mut shutting_down = false;
        loop {
            if let Some((uid, input)) = queue.pop_ready() {
                // With the queue disabled (ablation), pop order is arrival
                // order, which can differ across workers — exactly the
                // mispairing hazard §4.2 describes.
                self.execute_logged(uid, &input);
                continue;
            }
            if shutting_down {
                break;
            }
            match self.cmd_rx.recv() {
                Ok(Command::Forward { uid, input }) => queue.push(uid, (uid, input)),
                Ok(Command::Shutdown) | Err(_) => shutting_down = true,
            }
        }
    }

    fn execute_logged(&mut self, uid: u64, input: &BatchInput) {
        match self.execute(uid, input) {
            Ok(Some(out)) => {
                let _ = self.reply_tx.send((out.uid, Ok(out)));
            }
            Ok(None) => {}
            Err(e) => {
                if self.ctx.is_replier() {
                    let _ = self.reply_tx.send((uid, Err(e)));
                } else {
                    // poison downstream by dropping; the engine watchdog
                    // will surface the stall. Log loudly, attributing the
                    // batch to its sessions via the per-row ids.
                    eprintln!(
                        "worker {} failed on batch {uid} (sessions {:?}): {e:#}",
                        self.ctx.device_id(),
                        input.req_ids,
                    );
                }
            }
        }
    }

    /// Execute one batch through this worker's stage. Returns the reply if
    /// this worker is the replier.
    fn execute(&mut self, uid: u64, input: &BatchInput) -> anyhow::Result<Option<BatchOutput>> {
        let (b, s) = (input.batch, input.seq);
        let h = self.ctx.cfg.hidden;
        let valid = valid_len_arg(&input.valid_lens);
        let drce_maps = self.plan_drce(input)?;

        // ---- acquire the stage input ------------------------------------
        let mut act = if self.ctx.is_first_stage() {
            let x = self.run_embed(input)?;
            match &drce_maps {
                Some(maps) => {
                    let flat = x.reshape(&[b * s, h]);
                    Act::Packed(drce::pack(&flat, maps), maps.clone())
                }
                None => Act::Padded(x),
            }
        } else {
            let prev = self.ctx.par.device_of(self.ctx.stage - 1, self.ctx.tp_rank);
            let (got_uid, t) = self.act_ep.recv(prev);
            if self.ctx.consistency {
                anyhow::ensure!(
                    got_uid == uid,
                    "stage {} received activation for batch {got_uid}, expected {uid}",
                    self.ctx.stage
                );
            }
            match &drce_maps {
                Some(maps) => Act::Packed(t, maps.clone()),
                None => Act::Padded(t),
            }
        };

        // ---- run my layers ----------------------------------------------
        let first = self.ctx.layers.start;
        self.provider.prefetch(0);
        for layer in self.ctx.layers.clone() {
            let local = layer - first;
            // issue the lookahead prefetch before computing (Fig. 8)
            for ahead in 1..=self.ctx.lookahead.max(1) {
                self.provider.prefetch(local + ahead);
            }
            act = self.run_layer(local, act, &valid, input)?;
            self.provider.release(local);
        }

        // ---- hand off or reply --------------------------------------------
        if !self.ctx.is_last_stage() {
            let next = self.ctx.par.device_of(self.ctx.stage + 1, self.ctx.tp_rank);
            let t = match act {
                Act::Padded(t) => t,
                Act::Packed(t, _) => t,
            };
            self.act_ep.send(next, (uid, t));
            return Ok(None);
        }

        // last stage: unpack, project to logits, reply (tp rank 0 only)
        let x = match act {
            Act::Padded(t) => t,
            Act::Packed(t, maps) => drce::unpack(&t, &maps).reshape(&[b, s, h]),
        };
        if !self.ctx.is_replier() {
            return Ok(None);
        }
        let logits = self.run_logits(x, input)?;
        let next_tokens = argmax_next_tokens(&logits, &input.valid_lens);
        Ok(Some(BatchOutput { uid, next_tokens, logits }))
    }

    /// Decide whether this batch runs packed, identically on all workers:
    /// DRCE is on, a (b, s, tp) bucket exists, and the valid tokens fit.
    fn plan_drce(&self, input: &BatchInput) -> anyhow::Result<Option<DrceMaps>> {
        if !self.ctx.drce {
            return Ok(None);
        }
        let total: usize = input.valid_lens.iter().sum();
        let mut buckets: Vec<usize> = self
            .manifest
            .by_kind(&self.ctx.preset, "drce_attn_shard")
            .filter(|v| v.batch == input.batch && v.seq == input.seq && v.tp == self.ctx.par.tp)
            .map(|v| v.t_bucket)
            .collect();
        buckets.sort();
        match drce::pick_bucket(total, &buckets) {
            Some(t) => Ok(Some(drce::make_maps(&input.valid_lens, input.seq, t)?)),
            None => Ok(None), // fall back to padded execution
        }
    }

    fn variant(&self, kind: &str, input: &BatchInput, t_bucket: usize) -> anyhow::Result<crate::runtime::VariantMeta> {
        let tp = if kind == "layer_full" || kind == "embed" || kind == "logits" {
            1
        } else {
            self.ctx.par.tp
        };
        let name = Manifest::name_of(&self.ctx.preset, kind, input.batch, input.seq, tp, t_bucket);
        self.manifest.get(&name).cloned()
    }

    /// Device-resident weight tail for a layer, rebuilt when the provider
    /// reports a new epoch (pool eviction + refetch).
    fn layer_lits(&mut self, local: usize, kind: WeightKind) -> anyhow::Result<Rc<Vec<xla::Literal>>> {
        let epoch = self.provider.epoch(local);
        if let Some((e, lits)) = self.weight_lits.get(&(local, kind)) {
            if *e == epoch {
                return Ok(lits.clone());
            }
        }
        let vals = match kind {
            WeightKind::Attn => self.provider.attn_args(local),
            WeightKind::Mlp => self.provider.mlp_args(local),
            WeightKind::All => self.provider.all_args(local),
        };
        let lits = Rc::new(crate::runtime::pjrt::prepare(&vals)?);
        self.weight_lits.insert((local, kind), (epoch, lits.clone()));
        Ok(lits)
    }

    fn run_embed(&mut self, input: &BatchInput) -> anyhow::Result<Tensor> {
        let v = self.variant("embed", input, 0)?;
        if self.embed_lits.is_none() {
            let w = self.embed_weights.as_ref().expect("stage 0 has embed weights");
            self.embed_lits = Some(crate::runtime::pjrt::prepare(w)?);
        }
        let acts = [Value::I32(input.ids.clone())];
        Ok(self
            .device
            .execute_prepared(&self.manifest, &v, &acts, self.embed_lits.as_ref().unwrap())?
            .remove(0))
    }

    fn run_logits(&mut self, x: Tensor, input: &BatchInput) -> anyhow::Result<Tensor> {
        let v = self.variant("logits", input, 0)?;
        if self.logits_lits.is_none() {
            let w = self.logits_weights.as_ref().expect("last stage has logits weights");
            self.logits_lits = Some(crate::runtime::pjrt::prepare(w)?);
        }
        // x is moved, not cloned — the last activation copy on this path
        let acts = [Value::F32(x)];
        Ok(self
            .device
            .execute_prepared(&self.manifest, &v, &acts, self.logits_lits.as_ref().unwrap())?
            .remove(0))
    }

    /// One transformer layer: fused single-device, TP-sharded, or DRCE.
    fn run_layer(&mut self, local: usize, act: Act, valid: &Value, input: &BatchInput) -> anyhow::Result<Act> {
        let (b, s) = (input.batch, input.seq);
        let h = self.ctx.cfg.hidden;
        let tp = self.ctx.par.tp;
        match act {
            Act::Padded(x) if tp == 1 => {
                let v = self.variant("layer_full", input, 0)?;
                let lits = self.layer_lits(local, WeightKind::All)?;
                let acts = [Value::F32(x), valid.clone()];
                let y = self.device.execute_prepared(&self.manifest, &v, &acts, &lits)?.remove(0);
                Ok(Act::Padded(y))
            }
            Act::Padded(mut x) => {
                // attention half (partial) -> all-reduce -> residual.
                // The activation fans out (executable arg + residual), so
                // share its storage once: the clone below is an Arc bump,
                // not a data copy (§Perf).
                x.make_shared();
                let v = self.variant("attn_shard", input, 0)?;
                let lits = self.layer_lits(local, WeightKind::Attn)?;
                let acts = [Value::F32(x.clone()), valid.clone()];
                let partial = self.device.execute_prepared(&self.manifest, &v, &acts, &lits)?.remove(0);
                let attn_sum = self.allreduce(partial);
                let mut r = x.add(&attn_sum); // arena scratch
                r.make_shared();
                // mlp half over (b*s, h) rows — zero-copy reshape of a view
                let v = self.variant("mlp_shard", input, 0)?;
                let lits = self.layer_lits(local, WeightKind::Mlp)?;
                let r2 = r.clone().reshape(&[b * s, h]);
                let acts = [Value::F32(r2)];
                let partial = self.device.execute_prepared(&self.manifest, &v, &acts, &lits)?.remove(0);
                let mlp_sum = self.allreduce(partial).reshape(&[b, s, h]);
                Ok(Act::Padded(r.add(&mlp_sum)))
            }
            Act::Packed(mut xp, maps) => {
                xp.make_shared(); // Arc-cheap clone into the arg list below
                let v = self.variant("drce_attn_shard", input, maps.t_bucket)?;
                let lits = self.layer_lits(local, WeightKind::Attn)?;
                let acts = [
                    Value::F32(xp.clone()),
                    valid.clone(),
                    Value::I32(maps.unpad_map.clone()),
                    Value::I32(maps.pad_map.clone()),
                ];
                let partial = self.device.execute_prepared(&self.manifest, &v, &acts, &lits)?.remove(0);
                let attn_sum = self.allreduce(partial);
                let mut r = xp.add(&attn_sum); // arena scratch
                r.make_shared();
                let v = self.variant("mlp_shard", input, maps.t_bucket)?;
                let lits = self.layer_lits(local, WeightKind::Mlp)?;
                let acts = [Value::F32(r.clone())];
                let partial = self.device.execute_prepared(&self.manifest, &v, &acts, &lits)?.remove(0);
                let mlp_sum = self.allreduce(partial);
                Ok(Act::Packed(r.add(&mlp_sum), maps))
            }
        }
    }

    fn allreduce(&self, t: Tensor) -> Tensor {
        if self.ctx.par.tp == 1 {
            return t;
        }
        ring_allreduce(&self.coll_ep, &self.ctx.tp_group(), t)
    }
}

/// Greedy next-token: argmax of the logits row at position valid-1.
pub fn argmax_next_tokens(logits: &Tensor, valid_lens: &[usize]) -> Vec<i32> {
    let (b, s, v) = (logits.shape[0], logits.shape[1], logits.shape[2]);
    assert_eq!(valid_lens.len(), b);
    let mut out = Vec::with_capacity(b);
    for (i, &vl) in valid_lens.iter().enumerate() {
        let pos = vl.clamp(1, s) - 1;
        let row = &logits.data[(i * s + pos) * v..(i * s + pos + 1) * v];
        let argmax = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(j, _)| j as i32)
            .unwrap();
        out.push(argmax);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ctx_roles() {
        let par = ParallelConfig::new(2, 2);
        let cfg = ModelConfig::preset("tiny").unwrap();
        let ctx = WorkerCtx {
            preset: "tiny".into(),
            cfg: cfg.clone(),
            par,
            stage: 1,
            tp_rank: 0,
            layers: 2..4,
            drce: false,
            consistency: true,
            lookahead: 1,
        };
        assert_eq!(ctx.device_id(), 2);
        assert!(ctx.is_last_stage());
        assert!(!ctx.is_first_stage());
        assert!(ctx.is_replier());
        assert_eq!(ctx.tp_group(), vec![2, 3]);
        let ctx2 = WorkerCtx { tp_rank: 1, ..ctx };
        assert!(!ctx2.is_replier());
    }

    #[test]
    fn argmax_uses_last_valid_position() {
        // b=1, s=3, v=4; valid=2 -> row at pos 1
        let logits = Tensor::new(
            &[1, 3, 4],
            vec![
                9., 0., 0., 0., // pos 0
                0., 0., 7., 0., // pos 1  <- selected
                0., 0., 0., 9., // pos 2
            ],
        );
        assert_eq!(argmax_next_tokens(&logits, &[2]), vec![2]);
        assert_eq!(argmax_next_tokens(&logits, &[1]), vec![0]);
        // valid beyond seq clamps
        assert_eq!(argmax_next_tokens(&logits, &[9]), vec![3]);
    }
}
