//! Drafters for speculative decoding: cheap token proposers whose guesses
//! the verify pass scores in one batched forward (draft-and-verify).
//!
//! The contract is deliberately tiny — [`Drafter::draft`] maps a session's
//! committed token history to `n` proposed continuation tokens — so a
//! small-model drafter (a distilled LM running its own forward) can slot
//! in behind the same trait later. What ships today is the classic free
//! drafter: [`NGramDrafter`], longest-suffix n-gram matching over the
//! session's own history with a repeat-last-token fallback. It costs
//! microseconds, accepts well on repetitive continuations (code, lists,
//! loops — and small greedy models settle into cycles fast), and accepts
//! nothing on white-noise output, where speculation gracefully degenerates
//! to plain decode (the verify pass still commits one true greedy token).
//!
//! Speculation is **lossless** regardless of the drafter: the verify pass
//! computes the true greedy token at every window position, so a wrong
//! draft costs only wasted compute, never a changed stream. The harness
//! drafters at the bottom ([`ReplayDrafter`], [`MisdraftDrafter`]) pin the
//! two extremes — a perfect small-model stand-in (100% accept) and an
//! adversarial one (0% accept) — for the differential tests and the
//! accept-rate sweep in `benches/specdecode.rs`.

use std::fmt;
use std::sync::Arc;

/// A token proposer for draft-and-verify decoding. Implementations must
/// be cheap relative to one engine forward and side-effect free: `draft`
/// is called by the engine collector on the hot continuation path, once
/// per verify step, with the session's full committed history (prompt +
/// generated tokens, in order).
pub trait Drafter: Send + Sync {
    /// Propose `n` tokens continuing `history`. Must return exactly `n`
    /// tokens; out-of-vocabulary ids are clamped by the engine before
    /// they reach a verify batch, so a sloppy drafter degrades accept
    /// rate, never correctness.
    fn draft(&self, history: &[i32], n: usize) -> Vec<i32>;

    /// Short name for metrics / logs.
    fn name(&self) -> &'static str {
        "drafter"
    }
}

/// Cloneable, debuggable handle to a shared drafter (what
/// [`crate::coordinator::engine::LaunchConfig`] carries).
#[derive(Clone)]
pub struct DrafterHandle(pub Arc<dyn Drafter>);

impl DrafterHandle {
    pub fn new(d: impl Drafter + 'static) -> DrafterHandle {
        DrafterHandle(Arc::new(d))
    }
}

impl fmt::Debug for DrafterHandle {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "DrafterHandle({})", self.0.name())
    }
}

/// Longest-suffix n-gram drafter: to propose the next token, find the
/// most recent earlier occurrence of the longest (≤ `max_order`) suffix
/// of the history and replay the token that followed it; with no match,
/// repeat the last token. Drafting `n` tokens chains the rule on its own
/// proposals, so a detected cycle is replayed whole.
pub struct NGramDrafter {
    /// Longest suffix length to match (≥ 1).
    pub max_order: usize,
}

impl Default for NGramDrafter {
    fn default() -> Self {
        NGramDrafter { max_order: 3 }
    }
}

impl NGramDrafter {
    pub fn new(max_order: usize) -> NGramDrafter {
        assert!(max_order >= 1, "n-gram order must be >= 1");
        NGramDrafter { max_order }
    }

    /// One-token prediction over an explicit history.
    fn predict(&self, h: &[i32]) -> i32 {
        let len = h.len();
        if len == 0 {
            return 0;
        }
        // longest suffix first; its most recent earlier occurrence wins
        let max = self.max_order.min(len - 1);
        for order in (1..=max).rev() {
            let suffix = &h[len - order..];
            for start in (0..len - order).rev() {
                if &h[start..start + order] == suffix {
                    return h[start + order];
                }
            }
        }
        h[len - 1] // repetition fallback
    }
}

impl Drafter for NGramDrafter {
    fn draft(&self, history: &[i32], n: usize) -> Vec<i32> {
        let mut ctx: Vec<i32> = history.to_vec();
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let t = self.predict(&ctx);
            out.push(t);
            ctx.push(t);
        }
        out
    }

    fn name(&self) -> &'static str {
        "ngram"
    }
}

/// Harness drafter: replays a known continuation `script` indexed by
/// absolute position (prompt + generated so far), modelling a *perfect*
/// small-model drafter — every in-script draft is the true greedy token,
/// so the accept rate is 100% until the script runs out. Used by the
/// accept-rate sweep and the best-case differential tests.
pub struct ReplayDrafter {
    /// The full expected sequence (prompt included).
    pub script: Vec<i32>,
}

impl Drafter for ReplayDrafter {
    fn draft(&self, history: &[i32], n: usize) -> Vec<i32> {
        (0..n)
            .map(|j| {
                let pos = history.len() + j;
                self.script.get(pos).copied().unwrap_or_else(|| {
                    *self.script.last().unwrap_or(&0)
                })
            })
            .collect()
    }

    fn name(&self) -> &'static str {
        "replay"
    }
}

/// Harness drafter forced to a 0% accept rate: proposes `truth[pos] + 1`
/// (mod vocab) at every position, guaranteed unequal to the true greedy
/// token — the worst case, where every verify pass degenerates to one
/// committed token (plain-decode throughput) and every speculatively
/// appended K/V row must be truncated back. Pins the no-leak /
/// byte-identical-stream invariants in `rust/tests/spec_decode.rs`.
pub struct MisdraftDrafter {
    /// The true greedy sequence (prompt included).
    pub truth: Vec<i32>,
    pub vocab: i32,
}

impl Drafter for MisdraftDrafter {
    fn draft(&self, history: &[i32], n: usize) -> Vec<i32> {
        (0..n)
            .map(|j| {
                let pos = history.len() + j;
                let t = self.truth.get(pos).copied().unwrap_or(0);
                (t + 1).rem_euclid(self.vocab.max(1))
            })
            .collect()
    }

    fn name(&self) -> &'static str {
        "misdraft"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ngram_replays_a_cycle() {
        let d = NGramDrafter::default();
        // history ends in the cycle 7 8 9 7 8 9; suffix ..9 matched at the
        // earlier occurrence proposes 7, then 8, then 9 (chained)
        let h = vec![1, 7, 8, 9, 7, 8, 9];
        assert_eq!(d.draft(&h, 3), vec![7, 8, 9]);
    }

    #[test]
    fn ngram_prefers_longest_suffix() {
        let d = NGramDrafter::new(3);
        // suffix [5, 6] occurred earlier followed by 1; the shorter
        // suffix [6] also occurred followed by 9 — order-2 must win
        let h = vec![5, 6, 1, 6, 9, 5, 6];
        assert_eq!(d.draft(&h, 1), vec![1]);
    }

    #[test]
    fn ngram_falls_back_to_repeat() {
        let d = NGramDrafter::default();
        assert_eq!(d.draft(&[1, 2, 3], 2), vec![3, 3]);
        assert_eq!(d.draft(&[], 2), vec![0, 0]);
    }

    #[test]
    fn replay_follows_the_script() {
        let d = ReplayDrafter { script: vec![10, 11, 12, 13, 14] };
        assert_eq!(d.draft(&[10, 11], 2), vec![12, 13]);
        // past the end: repeats the last scripted token
        assert_eq!(d.draft(&[10, 11, 12, 13], 3), vec![14, 14, 14]);
    }

    #[test]
    fn misdraft_never_matches_truth() {
        let truth = vec![3, 1, 4, 1, 5, 9, 2, 6];
        let d = MisdraftDrafter { truth: truth.clone(), vocab: 10 };
        for hist_len in 1..truth.len() {
            let drafts = d.draft(&truth[..hist_len], 3);
            for (j, t) in drafts.iter().enumerate() {
                if let Some(&tr) = truth.get(hist_len + j) {
                    assert_ne!(*t, tr, "misdraft matched truth at {}", hist_len + j);
                }
                assert!((0..10).contains(t));
            }
        }
    }

    #[test]
    fn handle_is_cloneable_and_debuggable() {
        let h = DrafterHandle::new(NGramDrafter::default());
        let h2 = h.clone();
        assert_eq!(format!("{h2:?}"), "DrafterHandle(ngram)");
        assert_eq!(h.0.draft(&[4, 4], 1), vec![4]);
    }
}
