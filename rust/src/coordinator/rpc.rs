//! The RPC command bus: the single-controller half of the hierarchy
//! (§4.1.2). The engine publishes [`Command`]s to every worker; workers
//! never talk back except through the result path (last stage → engine
//! collector) — fine-grained SPMD communication stays worker-to-worker,
//! which is the multi-controller half.
//!
//! In the paper this is PyTorch RPC across processes; here it is an
//! in-process bus with the same semantics (per-worker FIFO delivery, but
//! no cross-worker ordering guarantee when multiple engine threads
//! publish concurrently — exactly the hazard the distributed consistency
//! queue exists to fix, §4.2).

use crate::tensor::{IntTensor, Tensor};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};

use std::time::Instant;

/// Which kind of engine step a batch row is (incremental decode): a
/// prefill runs the whole padded prompt through the layers; a decode runs
/// a single position against each session's paged K/V cache; a verify
/// runs a k-token drafted window against the cache in one pass
/// (speculative decode) and commits the longest accepted prefix; a chunk
/// runs a k-token *prompt window* against the cache (chunked prefill),
/// seeding the session's K/V incrementally so long prompts never occupy a
/// monolithic prefill bucket — each chunk row carries
/// `(session, chunk_start, chunk_len)` via the request metadata and the
/// window attends over the already-seeded prefix.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Phase {
    #[default]
    Prefill,
    Decode,
    Verify,
    Chunk,
}

/// A batched inference task, as published to workers.
#[derive(Clone, Debug)]
pub struct BatchInput {
    /// Token ids — (batch, seq) for prefill (consumed by stage 0 only);
    /// (batch, 1) holding each session's newest token for decode.
    pub ids: IntTensor,
    /// Per-sequence valid lengths (the DRCE metadata the engine binds to
    /// the command, §4.3). For decode rows this is the *total* session
    /// length including the token being decoded — it exceeds `seq` (1).
    pub valid_lens: Vec<usize>,
    /// Per-row session ids (iteration-level scheduling metadata): which
    /// generation session each batch row belongs to, `u64::MAX` for pad
    /// rows. The KV-cache path keys each worker's paged cache by these;
    /// the engine collector still routes tokens through its own
    /// pending-row table.
    pub req_ids: Vec<u64>,
    /// Padded shape point this batch was bucketed into. Decode buckets
    /// are width-only: `seq == 1`.
    pub batch: usize,
    pub seq: usize,
    /// Prefill or single-position decode.
    pub phase: Phase,
    /// Prefill only: seed each row's session K/V cache (the `*_kv`
    /// variants) so continuation steps can decode incrementally. Set by
    /// the engine for batcher sessions when the cache is enabled; direct
    /// `infer_batch` batches never touch the cache.
    pub cache: bool,
    /// Shared-prefix adoption metadata, one slot per batch row (empty =
    /// feature off, the byte-identical default). `Some((donor, positions))`
    /// on a row's *first* step makes every worker seed the row's session
    /// from its prefix registry (`KvCache::adopt_prefix`) before touching
    /// the cache — the adopted positions are never computed again.
    pub prefix_adopt: Vec<Option<(u64, usize)>>,
    /// Shared-prefix retention metadata, one count per batch row (empty =
    /// feature off). A non-zero count on a prefill row makes every worker
    /// retain the row's first `count` positions in its prefix registry
    /// (`KvCache::retain_prefix`) after seeding the cache.
    pub prefix_retain: Vec<usize>,
}

impl BatchInput {
    pub fn rows(&self) -> usize {
        self.batch * self.seq
    }
}

/// Result of one batch: last-token logits-argmax per sequence plus the
/// full logits tensor (small models only — callers that don't need it can
/// drop it).
#[derive(Clone, Debug)]
pub struct BatchOutput {
    pub uid: u64,
    pub next_tokens: Vec<i32>,
    pub logits: Tensor,
    /// Verify batches only: per row, the greedy tokens the pass committed
    /// in order — the accepted drafted prefix plus the one corrected /
    /// bonus token from the first rejected position (so its length is
    /// `accepted + 1`, between 1 and the window size). Empty for prefill
    /// and plain decode batches; `next_tokens[i] == accepted[i][0]` when
    /// present.
    pub accepted: Vec<Vec<i32>>,
}

/// Commands the engine publishes.
pub enum Command {
    /// Run one batch. `uid` is the consistency-queue ticket. The input is
    /// shared, not cloned per worker (§Perf: publish is O(world) sends,
    /// not O(world) tensor copies).
    Forward { uid: u64, input: Arc<BatchInput> },
    /// Free the listed sessions' K/V cache blocks. Ticketed like
    /// `Forward` and processed through the same consistency queue, so a
    /// release can never overtake a still-queued decode step of the same
    /// session on a lagging worker.
    Release { uid: u64, ids: Arc<Vec<u64>> },
    /// Write the listed sessions' K/V blocks out to the host tier
    /// (tiered cache). Ticketed: victims are chosen cold by the engine's
    /// tier policy, and ticket order guarantees the spill lands after
    /// any earlier forward that still reads those sessions.
    Spill { uid: u64, ids: Arc<Vec<u64>> },
    /// Stage the listed sessions' K/V blocks back into the device tier.
    /// Published *before* the decode bucket that needs them, so ticket
    /// order doubles as the residency guarantee; `hint` marks lookahead
    /// prefetches (a bucket ahead) vs sync fetches at bucket admission
    /// (whose copy time is the decode stall the lookahead exists to
    /// hide).
    Prefetch { uid: u64, ids: Arc<Vec<u64>>, hint: bool },
    /// Park the listed sessions' K/V blocks in the ring peer's spare
    /// device memory (§4.4 PMEP, third tier). Ticketed like `Spill`:
    /// every worker parks its own shard image at the same point in its
    /// execution order, so the peer exchange needs no extra handshake.
    Park { uid: u64, ids: Arc<Vec<u64>> },
    /// Bring the listed sessions' images home from the peer tier.
    /// Published before the decode bucket that needs them — ticket order
    /// alone guarantees residency, exactly like `Prefetch`; `hint` marks
    /// lookahead fetches vs sync fetches at bucket admission.
    Fetch { uid: u64, ids: Arc<Vec<u64>>, hint: bool },
    /// Cancellation propagation: free the listed sessions' K/V blocks on
    /// both tiers because their clients disconnected mid-generation.
    /// Worker-side this frees exactly like `Release`, but it is a
    /// distinct command so cancellation traffic is observable; ticket
    /// order guarantees the free lands after any in-flight forward that
    /// still writes those sessions.
    Cancel { uid: u64, ids: Arc<Vec<u64>> },
    /// Drop the listed shared-prefix registry entries (keyed by their
    /// registrant session ids) on every worker. Ticketed: eviction is
    /// decided by the engine-side trie only for lease-free entries, and
    /// ticket order guarantees the drop lands after every adoption formed
    /// against the entry.
    EvictPrefix { uid: u64, ids: Arc<Vec<u64>> },
    /// Drain and exit the worker loop.
    Shutdown,
}

/// Engine→worker command channels (one per worker, FIFO).
pub struct CommandBus {
    senders: Vec<Sender<Command>>,
}

impl CommandBus {
    pub fn new(world: usize) -> (CommandBus, Vec<Receiver<Command>>) {
        let mut senders = Vec::with_capacity(world);
        let mut receivers = Vec::with_capacity(world);
        for _ in 0..world {
            let (tx, rx) = std::sync::mpsc::channel();
            senders.push(tx);
            receivers.push(rx);
        }
        (CommandBus { senders }, receivers)
    }

    /// Publish a forward task to every worker (the engine's non-blocking
    /// launch: this returns as soon as the commands are enqueued).
    pub fn publish(&self, uid: u64, input: &Arc<BatchInput>) {
        for s in &self.senders {
            // ignore send errors during shutdown races; the engine joins
            // workers before dropping the bus in orderly teardown
            let _ = s.send(Command::Forward { uid, input: input.clone() });
        }
    }

    /// Publish a cache-release for finished sessions to every worker.
    /// Consumes a ticket from the same counter as `publish` — tickets must
    /// stay gap-free for the consistency queues to drain.
    pub fn publish_release(&self, uid: u64, ids: Vec<u64>) {
        let ids = Arc::new(ids);
        for s in &self.senders {
            let _ = s.send(Command::Release { uid, ids: ids.clone() });
        }
    }

    /// Publish a tier spill (device → host) for the listed sessions.
    pub fn publish_spill(&self, uid: u64, ids: Vec<u64>) {
        let ids = Arc::new(ids);
        for s in &self.senders {
            let _ = s.send(Command::Spill { uid, ids: ids.clone() });
        }
    }

    /// Publish a tier prefetch (host → device) for the listed sessions.
    pub fn publish_prefetch(&self, uid: u64, ids: Vec<u64>, hint: bool) {
        let ids = Arc::new(ids);
        for s in &self.senders {
            let _ = s.send(Command::Prefetch { uid, ids: ids.clone(), hint });
        }
    }

    /// Publish a peer-tier park (device → peer) for the listed sessions.
    pub fn publish_park(&self, uid: u64, ids: Vec<u64>) {
        let ids = Arc::new(ids);
        for s in &self.senders {
            let _ = s.send(Command::Park { uid, ids: ids.clone() });
        }
    }

    /// Publish a peer-tier fetch (peer → device) for the listed sessions.
    pub fn publish_fetch(&self, uid: u64, ids: Vec<u64>, hint: bool) {
        let ids = Arc::new(ids);
        for s in &self.senders {
            let _ = s.send(Command::Fetch { uid, ids: ids.clone(), hint });
        }
    }

    /// Publish a cancellation release for disconnected sessions.
    pub fn publish_cancel(&self, uid: u64, ids: Vec<u64>) {
        let ids = Arc::new(ids);
        for s in &self.senders {
            let _ = s.send(Command::Cancel { uid, ids: ids.clone() });
        }
    }

    /// Publish a shared-prefix registry eviction.
    pub fn publish_evict(&self, uid: u64, ids: Vec<u64>) {
        let ids = Arc::new(ids);
        for s in &self.senders {
            let _ = s.send(Command::EvictPrefix { uid, ids: ids.clone() });
        }
    }

    pub fn shutdown(&self) {
        for s in &self.senders {
            let _ = s.send(Command::Shutdown);
        }
    }

    pub fn world(&self) -> usize {
        self.senders.len()
    }
}

/// Remote reference to an in-flight result — the paper's usage model
/// (Fig. 9): `let rref = engine.submit(..); let out = rref.to_here();`.
#[derive(Clone)]
pub struct RRef {
    inner: Arc<(Mutex<Slot>, Condvar)>,
    pub uid: u64,
    pub submitted_at: Instant,
}

#[derive(Default)]
struct Slot {
    value: Option<anyhow::Result<BatchOutput>>,
}

impl RRef {
    pub fn new(uid: u64) -> RRef {
        RRef {
            inner: Arc::new((Mutex::new(Slot::default()), Condvar::new())),
            uid,
            submitted_at: Instant::now(),
        }
    }

    /// Fulfil the reference (engine collector thread).
    pub fn fulfil(&self, value: anyhow::Result<BatchOutput>) {
        let (lock, cv) = &*self.inner;
        let mut slot = lock.lock().unwrap();
        slot.value = Some(value);
        cv.notify_all();
    }

    /// Block until the result arrives (the paper's `to_here()`).
    pub fn to_here(&self) -> anyhow::Result<BatchOutput> {
        let (lock, cv) = &*self.inner;
        let mut slot = lock.lock().unwrap();
        loop {
            if let Some(v) = slot.value.take() {
                return v;
            }
            slot = cv.wait(slot).unwrap();
        }
    }

    /// Non-blocking poll.
    pub fn try_take(&self) -> Option<anyhow::Result<BatchOutput>> {
        let (lock, _) = &*self.inner;
        lock.lock().unwrap().value.take()
    }

    pub fn is_ready(&self) -> bool {
        let (lock, _) = &*self.inner;
        lock.lock().unwrap().value.is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;
    use std::time::Duration;

    fn input() -> BatchInput {
        BatchInput {
            ids: IntTensor::new(&[1, 4], vec![1, 2, 3, 0]),
            valid_lens: vec![3],
            req_ids: vec![0],
            batch: 1,
            seq: 4,
            phase: Phase::Prefill,
            cache: false,
            prefix_adopt: Vec::new(),
            prefix_retain: Vec::new(),
        }
    }

    #[test]
    fn publish_reaches_all_workers() {
        let (bus, rxs) = CommandBus::new(3);
        bus.publish(7, &Arc::new(input()));
        for rx in &rxs {
            match rx.recv().unwrap() {
                Command::Forward { uid, input } => {
                    assert_eq!(uid, 7);
                    assert_eq!(input.valid_lens, vec![3]);
                }
                _ => panic!("expected Forward"),
            }
        }
    }

    #[test]
    fn release_reaches_all_workers() {
        let (bus, rxs) = CommandBus::new(2);
        bus.publish_release(3, vec![7, 9]);
        for rx in &rxs {
            match rx.recv().unwrap() {
                Command::Release { uid, ids } => {
                    assert_eq!(uid, 3);
                    assert_eq!(*ids, vec![7, 9]);
                }
                _ => panic!("expected Release"),
            }
        }
    }

    #[test]
    fn tier_commands_reach_all_workers() {
        let (bus, rxs) = CommandBus::new(2);
        bus.publish_spill(4, vec![1]);
        bus.publish_prefetch(5, vec![1], true);
        for rx in &rxs {
            match rx.recv().unwrap() {
                Command::Spill { uid, ids } => {
                    assert_eq!(uid, 4);
                    assert_eq!(*ids, vec![1]);
                }
                _ => panic!("expected Spill"),
            }
            match rx.recv().unwrap() {
                Command::Prefetch { uid, ids, hint } => {
                    assert_eq!(uid, 5);
                    assert_eq!(*ids, vec![1]);
                    assert!(hint);
                }
                _ => panic!("expected Prefetch"),
            }
        }
    }

    #[test]
    fn peer_tier_commands_reach_all_workers() {
        let (bus, rxs) = CommandBus::new(2);
        bus.publish_park(6, vec![2]);
        bus.publish_fetch(7, vec![2], false);
        for rx in &rxs {
            match rx.recv().unwrap() {
                Command::Park { uid, ids } => {
                    assert_eq!(uid, 6);
                    assert_eq!(*ids, vec![2]);
                }
                _ => panic!("expected Park"),
            }
            match rx.recv().unwrap() {
                Command::Fetch { uid, ids, hint } => {
                    assert_eq!(uid, 7);
                    assert_eq!(*ids, vec![2]);
                    assert!(!hint);
                }
                _ => panic!("expected Fetch"),
            }
        }
    }

    #[test]
    fn evict_prefix_reaches_all_workers() {
        let (bus, rxs) = CommandBus::new(2);
        bus.publish_evict(8, vec![21]);
        for rx in &rxs {
            match rx.recv().unwrap() {
                Command::EvictPrefix { uid, ids } => {
                    assert_eq!(uid, 8);
                    assert_eq!(*ids, vec![21]);
                }
                _ => panic!("expected EvictPrefix"),
            }
        }
    }

    #[test]
    fn cancel_reaches_all_workers() {
        let (bus, rxs) = CommandBus::new(2);
        bus.publish_cancel(6, vec![11, 12]);
        for rx in &rxs {
            match rx.recv().unwrap() {
                Command::Cancel { uid, ids } => {
                    assert_eq!(uid, 6);
                    assert_eq!(*ids, vec![11, 12]);
                }
                _ => panic!("expected Cancel"),
            }
        }
    }

    #[test]
    fn shutdown_delivered() {
        let (bus, rxs) = CommandBus::new(2);
        bus.shutdown();
        for rx in &rxs {
            assert!(matches!(rx.recv().unwrap(), Command::Shutdown));
        }
    }

    #[test]
    fn rref_blocks_until_fulfilled() {
        let r = RRef::new(1);
        assert!(!r.is_ready());
        let r2 = r.clone();
        let h = thread::spawn(move || {
            thread::sleep(Duration::from_millis(30));
            r2.fulfil(Ok(BatchOutput {
                uid: 1,
                next_tokens: vec![5],
                logits: Tensor::zeros(&[1]),
                accepted: Vec::new(),
            }));
        });
        let out = r.to_here().unwrap();
        assert_eq!(out.next_tokens, vec![5]);
        h.join().unwrap();
    }

    #[test]
    fn rref_propagates_errors() {
        let r = RRef::new(2);
        r.fulfil(Err(anyhow::anyhow!("worker crashed")));
        assert!(r.to_here().is_err());
    }

    #[test]
    fn try_take_consumes_once() {
        let r = RRef::new(3);
        r.fulfil(Ok(BatchOutput {
            uid: 3,
            next_tokens: vec![],
            logits: Tensor::zeros(&[1]),
            accepted: Vec::new(),
        }));
        assert!(r.try_take().is_some());
        assert!(r.try_take().is_none());
    }
}
