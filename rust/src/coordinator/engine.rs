//! The centralized engine (§4.1.2, §4.2): runtime initialization, the
//! non-blocking task launch, the batch-list dispatcher pool, and the
//! result collector — extended with an **iteration-level generation
//! scheduler**: every submission is a session that re-enters the dynamic
//! batcher after each engine step until it finishes, so multi-token
//! generations from many clients coalesce into shared decode buckets
//! (Orca-style continuation batching).
//!
//! With the decode artifacts compiled, continuation steps are
//! **incremental**: the session's prefill seeds a paged per-worker K/V
//! cache (`memory::kvcache`), each continuation runs a single position
//! against it through the `*_decode` variants, and the collector releases
//! a session's blocks — by ticketed command through the consistency
//! queue — on completion, stop token, error, or watchdog poison. Without
//! them the engine falls back to the legacy re-prefill continuation path.
//!
//! With `engine.kv_spill` the cache is **tiered** (§4.4 applied to
//! generation state): every worker's device slab is capped, cold
//! sessions spill whole-session block images to a ledger-accounted host
//! tier, and the batch former — consulting the engine-side
//! `TierPolicy` — publishes ticketed `Spill`/`Prefetch` commands ahead
//! of each bucket so sessions are always resident when their decode step
//! executes (prefetch-on-reentry, one bucket of lookahead, prefill
//! admission control).
//!
//! Public usage mirrors the paper's Fig. 9, plus streaming generation:
//!
//! ```no_run
//! use energonai::coordinator::engine::{Engine, GenRequest, LaunchConfig};
//! use energonai::coordinator::batcher::Request;
//! let engine = Engine::launch(LaunchConfig::preset("tiny")).unwrap();
//! // direct pre-formed batch (benches): non-blocking RRef
//! let rref = engine.infer_batch(vec![Request::new(0, vec![1, 2, 3])]).unwrap();
//! let output = rref.to_here().unwrap();
//! // session lifecycle: stream tokens as engine steps complete
//! let gref = engine.generate_stream(GenRequest::new(vec![1, 2, 3], 8)).unwrap();
//! while let Some(tok) = gref.next().unwrap() {
//!     println!("token {tok}");
//! }
//! let full = gref.to_here().unwrap(); // prompt + generated
//! engine.shutdown();
//! ```

use super::batcher::{smallest_fitting_bucket, Batcher, FormedBatch, Request};
use super::consistency::TicketCounter;
use super::rpc::{CommandBus, Phase, RRef};
use super::worker::{ActMsg, Reply, Worker, WorkerCtx};
use crate::comm::channel::{CommWorld, Mode};
use crate::comm::collective::ChunkMsg;
use crate::config::{EngineConfig, ModelConfig, ParallelConfig};
use crate::memory::kvcache::tier::{TierCmd, TierConfig, TierPolicy};
use crate::memory::kvcache::{KvCache, KvCacheConfig};
use crate::memory::pool::{PoolConfig, PooledProvider};
use crate::memory::{LayerProvider, ResidentProvider};
use crate::metrics::Recorder;
use crate::model::{shard_layer, ModelWeights};
use crate::runtime::{Device, Manifest};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Where layer weights live on each worker (Fig. 13 scenarios).
#[derive(Clone, Debug)]
pub enum MemoryMode {
    /// Everything resident (the default).
    Resident,
    /// PMEP: keep `n_local` layers resident per worker, pool the rest in
    /// peer memory with prefetch (§4.4).
    Pmep { n_local: usize, pool: PoolConfig },
    /// BMInf-style synchronous CPU offload baseline (§5.6).
    Bminf { n_local: usize },
}

/// Everything `Engine::launch` needs.
#[derive(Clone, Debug)]
pub struct LaunchConfig {
    pub preset: String,
    pub parallel: ParallelConfig,
    pub engine: EngineConfig,
    pub memory: MemoryMode,
    pub seed: u64,
    /// Override layer count (the paper's customized 12/24/48-layer GPT-3s).
    pub n_layers: Option<usize>,
    /// Pre-compile all variants at launch (keeps latency measurements
    /// clean; off by default for fast test startup).
    pub warmup: bool,
}

impl LaunchConfig {
    pub fn preset(name: &str) -> LaunchConfig {
        LaunchConfig {
            preset: name.to_string(),
            parallel: ParallelConfig::serial(),
            engine: EngineConfig::default(),
            memory: MemoryMode::Resident,
            seed: 42,
            n_layers: None,
            warmup: false,
        }
    }

    pub fn with_parallel(mut self, tp: usize, pp: usize) -> Self {
        self.parallel = ParallelConfig::new(tp, pp);
        self
    }

    pub fn with_drce(mut self, on: bool) -> Self {
        self.engine.drce = on;
        self
    }

    pub fn with_blocking_comms(mut self, on: bool) -> Self {
        self.engine.blocking_comms = on;
        self
    }

    pub fn with_consistency(mut self, on: bool) -> Self {
        self.engine.consistency_queue = on;
        self
    }

    pub fn with_layers(mut self, n: usize) -> Self {
        self.n_layers = Some(n);
        self
    }

    pub fn with_memory(mut self, m: MemoryMode) -> Self {
        self.memory = m;
        self
    }

    pub fn with_warmup(mut self, on: bool) -> Self {
        self.warmup = on;
        self
    }

    /// Incremental decode via the paged K/V cache on/off (on by default;
    /// off is the re-prefill baseline the differential tests and the
    /// decode bench compare against).
    pub fn with_kv_cache(mut self, on: bool) -> Self {
        self.engine.kv_cache = on;
        self
    }

    /// Enable the tiered K/V cache: cap every worker's device slab at
    /// `device_blocks` and spill cold sessions to a host tier of
    /// `host_blocks` (0 = unlimited), with prefetch-on-reentry and
    /// admission control. Requires the decode artifacts (`kv_cache`);
    /// with spill off the resident-only fast path is byte-identical to
    /// before.
    pub fn with_kv_spill(mut self, device_blocks: usize, host_blocks: usize) -> Self {
        self.engine.kv_spill = true;
        self.engine.kv_device_blocks = device_blocks;
        self.engine.kv_host_blocks = host_blocks;
        self
    }
}

/// Paging granularity every worker's cache and the engine-side tier
/// policy must agree on (block counts per session are derived from it on
/// both sides).
pub const KV_BLOCK_POSITIONS: usize = 8;

/// A generation request entering the session lifecycle: the prompt, how
/// many continuation tokens to sample, and an optional stop token that
/// ends the session early (the stop token itself is emitted).
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub tokens: Vec<i32>,
    pub max_new_tokens: usize,
    pub stop_token: Option<i32>,
}

impl GenRequest {
    pub fn new(tokens: Vec<i32>, max_new_tokens: usize) -> GenRequest {
        GenRequest { tokens, max_new_tokens, stop_token: None }
    }

    pub fn with_stop(mut self, stop_token: i32) -> Self {
        self.stop_token = Some(stop_token);
        self
    }
}

#[derive(Default)]
struct GenState {
    /// Generated tokens so far (prompt excluded), in emission order.
    toks: Vec<i32>,
    /// `next()` read cursor into `toks`.
    read: usize,
    done: bool,
    /// Failure message, surfaced by `next()`/`to_here()` after any
    /// already-streamed tokens are drained.
    err: Option<String>,
}

/// Streaming future for one generation session. The collector appends
/// each sampled token as the session's batch completes an engine step;
/// clients consume incrementally with [`GenRef::next`] or wait for the
/// whole sequence with [`GenRef::to_here`].
#[derive(Clone)]
pub struct GenRef {
    prompt: Arc<Vec<i32>>,
    inner: Arc<(Mutex<GenState>, Condvar)>,
}

impl GenRef {
    fn new(prompt: Vec<i32>) -> GenRef {
        GenRef {
            prompt: Arc::new(prompt),
            inner: Arc::new((Mutex::new(GenState::default()), Condvar::new())),
        }
    }

    /// Collector side: one more sampled token is available.
    fn push_token(&self, t: i32) {
        let (m, cv) = &*self.inner;
        m.lock().unwrap().toks.push(t);
        cv.notify_all();
    }

    /// Collector side: the session ended (stop token, budget, context
    /// limit, or an error).
    fn finish(&self, res: anyhow::Result<()>) {
        let (m, cv) = &*self.inner;
        let mut g = m.lock().unwrap();
        g.done = true;
        g.err = res.err().map(|e| format!("{e:#}"));
        cv.notify_all();
    }

    /// Block for the next streamed token. `Ok(None)` means the session
    /// finished; buffered tokens are always drained before an error is
    /// reported.
    pub fn next(&self) -> anyhow::Result<Option<i32>> {
        let (m, cv) = &*self.inner;
        let mut g = m.lock().unwrap();
        loop {
            if g.read < g.toks.len() {
                let t = g.toks[g.read];
                g.read += 1;
                return Ok(Some(t));
            }
            if g.done {
                return match &g.err {
                    Some(e) => Err(anyhow::anyhow!("{e}")),
                    None => Ok(None),
                };
            }
            g = cv.wait(g).unwrap();
        }
    }

    /// Block until the session finishes and return the full sequence
    /// (prompt + generated tokens). Does not consume the `next()` cursor.
    pub fn to_here(&self) -> anyhow::Result<Vec<i32>> {
        let (m, cv) = &*self.inner;
        let mut g = m.lock().unwrap();
        while !g.done {
            g = cv.wait(g).unwrap();
        }
        if let Some(e) = &g.err {
            return Err(anyhow::anyhow!("{e}"));
        }
        let mut out = (*self.prompt).clone();
        out.extend_from_slice(&g.toks);
        Ok(out)
    }

    /// Tokens generated so far (non-blocking snapshot).
    pub fn n_generated(&self) -> usize {
        self.inner.0.lock().unwrap().toks.len()
    }

    pub fn is_done(&self) -> bool {
        self.inner.0.lock().unwrap().done
    }

    pub fn prompt(&self) -> &[i32] {
        &self.prompt
    }
}

/// Single-token future — `submit()`'s return type, kept as a thin wrapper
/// over a one-token session for API continuity.
#[derive(Clone)]
pub struct TokenRef {
    gref: GenRef,
}

impl TokenRef {
    pub fn to_here(&self) -> anyhow::Result<i32> {
        match self.gref.next()? {
            Some(t) => Ok(t),
            None => Err(anyhow::anyhow!("generation finished without a token")),
        }
    }
}

/// Engine-side state of one live generation session, keyed by request id.
/// The evolving token sequence itself travels through the batcher queue as
/// a plain [`Request`]; this holds everything the collector needs to
/// decide continue-vs-finish and to stream results back.
struct Session {
    prompt_len: usize,
    max_new: usize,
    stop: Option<i32>,
    /// Original submission time — preserved across every re-enqueue so
    /// batcher timeouts and TTFT measure client-observed waiting.
    arrived: Instant,
    /// Completion time of the session's previous engine step (for
    /// per-token decode latency).
    last_at: Instant,
    gref: GenRef,
}

/// Bookkeeping for one in-flight batch.
struct Pending {
    rref: RRef,
    /// The batch rows (real requests only; bucket pad rows excluded).
    rows: Vec<Request>,
    /// Batcher-path batches carry session rows the collector must route;
    /// direct `infer_batch` rows never touch the session table.
    from_batcher: bool,
}

struct Shared {
    bus: CommandBus,
    tickets: TicketCounter,
    pending: Mutex<HashMap<u64, Pending>>,
    /// Live generation sessions, keyed by request id.
    sessions: Mutex<HashMap<u64, Session>>,
    metrics: Mutex<Recorder>,
    stopping: AtomicBool,
    /// Incremental decode is live: sessions re-enter as decode steps and
    /// finished sessions' cache blocks are released by ticketed command.
    kv_on: bool,
}

impl Shared {
    /// The non-blocking launch (§4.2): take a ticket, register the rref,
    /// publish to every worker, return immediately. Takes the batch by
    /// value so the row token vectors move into `Pending` instead of being
    /// cloned per step (§Perf).
    fn publish(&self, fb: FormedBatch, from_batcher: bool) -> RRef {
        let mut input = fb.to_input();
        // only batcher sessions seed the cache; direct infer_batch rows
        // have no session lifecycle and must not leave blocks behind
        input.cache = self.kv_on && from_batcher && input.phase == Phase::Prefill;
        let input = std::sync::Arc::new(input);
        let uid = self.tickets.issue();
        let rref = RRef::new(uid);
        self.pending.lock().unwrap().insert(
            uid,
            Pending { rref: rref.clone(), rows: fb.requests, from_batcher },
        );
        self.bus.publish(uid, &input);
        rref
    }

    /// Free finished sessions' K/V blocks on every worker. Ticketed like a
    /// forward so the release drains through each worker's consistency
    /// queue *after* the session's final step (completion, stop token, or
    /// watchdog poison).
    fn release_sessions(&self, ids: Vec<u64>) {
        if self.kv_on && !ids.is_empty() {
            let uid = self.tickets.issue();
            self.bus.publish_release(uid, ids);
        }
    }

    /// Publish the tier policy's spill/prefetch decisions, one ticket
    /// each, in decision order. Called by the batch former *before* it
    /// hands the formed batch to a dispatcher, so every tier command's
    /// ticket precedes the forward that depends on it — the consistency
    /// queue then guarantees residency without any worker backchannel.
    fn publish_tier(&self, cmds: Vec<TierCmd>) {
        for cmd in cmds {
            let uid = self.tickets.issue();
            match cmd {
                TierCmd::Spill(ids) => self.bus.publish_spill(uid, ids),
                TierCmd::Prefetch { ids, hint } => self.bus.publish_prefetch(uid, ids, hint),
            }
        }
    }
}

/// The running system: workers + dispatcher pool + collector.
pub struct Engine {
    pub cfg: ModelConfig,
    pub launch: LaunchConfig,
    pub manifest: Arc<Manifest>,
    shared: Arc<Shared>,
    batcher: Arc<Mutex<Batcher>>,
    batch_signal: Sender<()>,
    next_req_id: std::sync::atomic::AtomicU64,
    workers: Vec<JoinHandle<()>>,
    service: Vec<JoinHandle<()>>,
}

impl Engine {
    /// Runtime initialization (§4.1.2): spawn one worker thread per device
    /// (each builds its own PJRT client, shards its layer range, compiles
    /// its variants), then start the dispatcher pool and collector.
    pub fn launch(launch: LaunchConfig) -> anyhow::Result<Engine> {
        // memoized parse: every engine (tests, benches, servers) shares
        // one parsed manifest per artifacts path (§Perf: manifest_parse_us)
        let manifest = Manifest::cached(crate::runtime::find_artifacts()?)?;
        let mut cfg = ModelConfig::preset(&launch.preset)
            .ok_or_else(|| anyhow::anyhow!("unknown preset {}", launch.preset))?;
        if let Some(n) = launch.n_layers {
            cfg.n_layers = n;
        }
        let par = launch.parallel;
        anyhow::ensure!(cfg.n_heads % par.tp == 0, "heads not divisible by tp");
        anyhow::ensure!(cfg.n_layers >= par.pp, "fewer layers than stages");
        anyhow::ensure!(
            !manifest.shape_points(&launch.preset).is_empty(),
            "no artifacts for preset {}; run `make artifacts`",
            launch.preset
        );
        // incremental decode goes live only when the whole decode family
        // is compiled for this (preset, tp); otherwise fall back to the
        // legacy re-prefill continuation path (old artifacts keep working)
        let decode_widths = if launch.engine.kv_cache && manifest.has_kv_prefill(&launch.preset, par.tp)
        {
            manifest.decode_widths(&launch.preset, par.tp)
        } else {
            Vec::new()
        };
        let kv_on = !decode_widths.is_empty();
        // tiered KV cache: spill cold sessions to pooled host memory.
        // Engine-side policy + per-worker host tiers only exist when the
        // knob is on *and* incremental decode is live; otherwise the
        // resident-only fast path is untouched. Builder-path configs get
        // the same validation the TOML loader enforces — a bad spill
        // config is an Err here, not a silent no-op or a thread panic.
        if launch.engine.kv_spill {
            anyhow::ensure!(
                launch.engine.kv_device_blocks > 0,
                "engine.kv_spill requires engine.kv_device_blocks > 0"
            );
            anyhow::ensure!(
                launch.engine.kv_spill_low_water <= launch.engine.kv_spill_high_water
                    && launch.engine.kv_spill_high_water <= 1.0
                    && launch.engine.kv_spill_low_water >= 0.0,
                "kv spill water marks must satisfy 0 <= low <= high <= 1"
            );
        }
        let spill_on = kv_on && launch.engine.kv_spill;

        let world = par.world_size();
        let (bus, cmd_rxs) = CommandBus::new(world);
        let act_mode = if launch.engine.blocking_comms { Mode::Blocking } else { Mode::NonBlocking };
        let coll_eps = CommWorld::new::<ChunkMsg>(world, Mode::NonBlocking);
        let act_eps = CommWorld::new::<ActMsg>(world, act_mode);
        let (reply_tx, reply_rx) = std::sync::mpsc::channel::<Reply>();

        // ---- workers -------------------------------------------------------
        let mut workers = Vec::with_capacity(world);
        let (ready_tx, ready_rx) = std::sync::mpsc::channel::<anyhow::Result<usize>>();
        let mut coll_it = coll_eps.into_iter();
        let mut act_it = act_eps.into_iter();
        let mut cmd_it = cmd_rxs.into_iter();
        for stage in 0..par.pp {
            for tp_rank in 0..par.tp {
                let ctx = WorkerCtx {
                    preset: launch.preset.clone(),
                    cfg: cfg.clone(),
                    par,
                    stage,
                    tp_rank,
                    layers: par.stage_layers(stage, cfg.n_layers),
                    drce: launch.engine.drce,
                    consistency: launch.engine.consistency_queue,
                    lookahead: match &launch.memory {
                        MemoryMode::Pmep { pool, .. } => pool.lookahead.max(1),
                        _ => 1,
                    },
                    kv_cache: kv_on,
                };
                // paged per-session K/V storage for this worker's layer
                // shard: width is hidden/tp (the shard's K or V row);
                // under spill the device slab is capped and a ledger-
                // accounted host tier sits behind it
                let kv_cfg = kv_on.then(|| {
                    let mut c = KvCacheConfig::new(
                        KV_BLOCK_POSITIONS,
                        ctx.layers.len(),
                        cfg.hidden / par.tp,
                    )
                    .with_device_id(ctx.device_id());
                    if spill_on {
                        // host_blocks == 0 means "unlimited" at the
                        // engine level; the worker tier encodes that as
                        // a saturating capacity
                        let host = match launch.engine.kv_host_blocks {
                            0 => usize::MAX,
                            n => n,
                        };
                        c = c
                            .with_device_capacity(launch.engine.kv_device_blocks)
                            .with_host_tier(host);
                    }
                    c
                });
                let args = (
                    ctx,
                    manifest.clone(),
                    cfg.clone(),
                    launch.memory.clone(),
                    launch.seed,
                    launch.warmup,
                    kv_cfg,
                    coll_it.next().unwrap(),
                    act_it.next().unwrap(),
                    cmd_it.next().unwrap(),
                    reply_tx.clone(),
                );
                let ready_tx = ready_tx.clone();
                workers.push(std::thread::spawn(move || {
                    let (ctx, man, cfg, mem, seed, warm, kv_cfg, coll, act, cmd, reply) = args;
                    let id = ctx.device_id();
                    match build_worker(ctx, man, cfg, mem, seed, warm, kv_cfg, coll, act, cmd, reply) {
                        Ok(w) => {
                            let _ = ready_tx.send(Ok(id));
                            w.run()
                        }
                        Err(e) => {
                            let _ = ready_tx.send(Err(anyhow::anyhow!("worker {id} init: {e:#}")));
                        }
                    }
                }));
            }
        }
        drop(reply_tx); // collector exits once all workers hang up
        drop(ready_tx);
        // runtime initialization barrier (§4.1.2): wait until every worker
        // has built its device, sharded its weights and compiled its
        // variants — so first-request latency is a serving number, not a
        // compile number
        for _ in 0..world {
            match ready_rx.recv() {
                Ok(Ok(_)) => {}
                Ok(Err(e)) => return Err(e),
                Err(_) => anyhow::bail!("a worker died during initialization"),
            }
        }

        let shared = Arc::new(Shared {
            bus,
            tickets: TicketCounter::new(),
            pending: Mutex::new(HashMap::new()),
            sessions: Mutex::new(HashMap::new()),
            metrics: Mutex::new(Recorder::new()),
            stopping: AtomicBool::new(false),
            kv_on,
        });

        // ---- batcher ---------------------------------------------------------
        let mut b = Batcher::new(
            manifest.shape_points(&launch.preset),
            launch.engine.max_batch,
            Duration::from_micros(launch.engine.batch_timeout_us),
        )
        .with_decode_widths(decode_widths);
        if spill_on {
            // the engine-side residency model: form() becomes the
            // admission gate and spill/prefetch decision point
            let mut tcfg =
                TierConfig::new(launch.engine.kv_device_blocks, launch.engine.kv_host_blocks);
            tcfg.high_water = launch.engine.kv_spill_high_water;
            tcfg.low_water = launch.engine.kv_spill_low_water;
            b = b.with_tier(TierPolicy::new(tcfg, KV_BLOCK_POSITIONS));
        }
        let batcher = Arc::new(Mutex::new(b));
        let max_seq = batcher.lock().unwrap().max_seq();
        let (batch_signal, batch_rx) = std::sync::mpsc::channel::<()>();

        // ---- collector -------------------------------------------------------
        // The collector is itself a producer now: after every completed
        // engine step it re-enqueues unfinished sessions at the front of
        // the batcher queue (continuation batching), so decode steps from
        // different clients coalesce into shared buckets.
        let mut service = Vec::new();
        {
            let shared = shared.clone();
            let batcher = batcher.clone();
            let signal = batch_signal.clone();
            service.push(std::thread::spawn(move || {
                collector_loop(reply_rx, shared, batcher, signal, max_seq)
            }));
        }

        // ---- watchdog --------------------------------------------------------
        // A non-replier worker error drops the activation, so the replier
        // never sends and the batch's RRef would hang forever. The watchdog
        // fails such poisoned batches (and their sessions) after the
        // configured deadline instead of letting shutdown spin.
        {
            let shared = shared.clone();
            let batcher = batcher.clone();
            let deadline = Duration::from_millis(launch.engine.batch_deadline_ms.max(1));
            service.push(std::thread::spawn(move || watchdog_loop(shared, batcher, deadline)));
        }

        // ---- former + dispatcher pool (Fig. 5) -------------------------------
        let (fb_tx, fb_rx) = std::sync::mpsc::channel::<FormedBatch>();
        let fb_rx = Arc::new(Mutex::new(fb_rx));

        // former thread: turns the request queue into the batch list
        {
            let batcher = batcher.clone();
            let shared = shared.clone();
            service.push(std::thread::spawn(move || {
                let tick = Duration::from_micros(500);
                loop {
                    if shared.stopping.load(Ordering::SeqCst) {
                        break;
                    }
                    let _ = batch_rx.recv_timeout(tick);
                    loop {
                        let (fb, tier_cmds) = {
                            let mut b = batcher.lock().unwrap();
                            let fb = b.form(Instant::now());
                            (fb, b.take_tier_cmds())
                        };
                        // tier commands are published here — before the
                        // batch reaches a dispatcher — so their tickets
                        // precede the forward's on every worker
                        if !tier_cmds.is_empty() {
                            shared.publish_tier(tier_cmds);
                        }
                        match fb {
                            Some(fb) => {
                                if fb_tx.send(fb).is_err() {
                                    return;
                                }
                            }
                            None => break,
                        }
                    }
                }
            }));
        }

        // dispatcher pool: N threads each take a formed batch, publish it
        // (non-blocking), then wait for completion — so the pool size is the
        // in-flight bound, exactly Fig. 5's thread-pool semantics.
        for _ in 0..launch.engine.pool_threads {
            let shared = shared.clone();
            let fb_rx = fb_rx.clone();
            service.push(std::thread::spawn(move || loop {
                let next = fb_rx.lock().unwrap().recv();
                match next {
                    Ok(fb) => {
                        let rref = shared.publish(fb, true);
                        let _ = rref.to_here(); // completion gates this slot
                    }
                    Err(_) => break,
                }
            }));
        }

        Ok(Engine {
            cfg,
            launch,
            manifest,
            shared,
            batcher,
            batch_signal,
            next_req_id: std::sync::atomic::AtomicU64::new(0),
            workers,
            service,
        })
    }

    /// Submit a pre-formed batch directly, bypassing the batcher (benches
    /// and examples that need exact shapes). Non-blocking.
    pub fn infer_batch(&self, requests: Vec<Request>) -> anyhow::Result<RRef> {
        anyhow::ensure!(!requests.is_empty(), "empty batch");
        let points = self.manifest.shape_points(&self.launch.preset);
        let n = requests.len();
        let max_len = requests.iter().map(Request::len).max().unwrap();
        let bucket = smallest_fitting_bucket(&points, n, max_len)
            .ok_or_else(|| anyhow::anyhow!("no compiled bucket fits ({n}, {max_len})"))?;
        let fb = FormedBatch { requests, bucket, phase: Phase::Prefill };
        Ok(self.shared.publish(fb, false))
    }

    /// Start a generation session through the dynamic batcher: the request
    /// enters the continuation queue, and after every completed engine step
    /// the collector streams the sampled token to the returned [`GenRef`]
    /// and re-enqueues the session until `max_new_tokens` are produced, the
    /// stop token appears, or the context reaches the longest compiled
    /// bucket. Non-blocking.
    pub fn generate_stream(&self, req: GenRequest) -> anyhow::Result<GenRef> {
        anyhow::ensure!(!req.tokens.is_empty(), "empty prompt");
        anyhow::ensure!(req.max_new_tokens >= 1, "max_new_tokens must be >= 1");
        let id = self.next_req_id.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
        let gref = GenRef::new(req.tokens.clone());
        let now = Instant::now();
        self.shared.sessions.lock().unwrap().insert(
            id,
            Session {
                prompt_len: req.tokens.len(),
                max_new: req.max_new_tokens,
                stop: req.stop_token,
                arrived: now,
                last_at: now,
                gref: gref.clone(),
            },
        );
        if let Err(e) = self.batcher.lock().unwrap().push_at(Request::new(id, req.tokens), now) {
            self.shared.sessions.lock().unwrap().remove(&id);
            return Err(e);
        }
        let _ = self.batch_signal.send(());
        Ok(gref)
    }

    /// Submit one request through the dynamic batcher. Returns a future
    /// for the request's next token (a one-token session).
    pub fn submit(&self, tokens: Vec<i32>) -> anyhow::Result<TokenRef> {
        Ok(TokenRef { gref: self.generate_stream(GenRequest::new(tokens, 1))? })
    }

    /// Greedy autoregressive generation: extend `prompt` by up to
    /// `n_tokens`, each step flowing through the shared continuation
    /// batcher. With the decode artifacts present, continuation steps are
    /// *incremental*: one position runs against the session's paged K/V
    /// cache instead of re-running the whole prefix (O(P+N) layer
    /// executions for N tokens over a P-token prompt, not O(N·(P+N))).
    /// Blocking wrapper over [`Engine::generate_stream`]; generation ends
    /// early once the context reaches the longest compiled bucket.
    pub fn generate(&self, prompt: Vec<i32>, n_tokens: usize) -> anyhow::Result<Vec<i32>> {
        if n_tokens == 0 {
            anyhow::ensure!(!prompt.is_empty(), "empty prompt");
            return Ok(prompt);
        }
        self.generate_stream(GenRequest::new(prompt, n_tokens))?.to_here()
    }

    /// Snapshot of serving metrics, with the process-wide activation-arena
    /// allocation counters (§Perf) and the paged-KV-cache pressure gauges
    /// (blocks in use / peak / recycled / slab bytes) folded in.
    pub fn metrics_snapshot(&self) -> Recorder {
        let mut r = self.shared.metrics.lock().unwrap().clone();
        r.record_arena(crate::memory::arena::ArenaPool::global_stats());
        r.record_kvcache(crate::memory::kvcache::global_stats());
        r
    }

    /// Is incremental decode live (decode artifacts present + enabled)?
    pub fn kv_cache_on(&self) -> bool {
        self.shared.kv_on
    }

    /// Is the tiered (spill-to-host) K/V cache live?
    pub fn kv_spill_on(&self) -> bool {
        self.shared.kv_on
            && self.launch.engine.kv_spill
            && self.launch.engine.kv_device_blocks > 0
    }

    pub fn pending_count(&self) -> usize {
        self.shared.pending.lock().unwrap().len()
    }

    /// Live generation sessions (queued or in flight).
    pub fn session_count(&self) -> usize {
        self.shared.sessions.lock().unwrap().len()
    }

    /// Orderly teardown: drain every live session and in-flight batch,
    /// stop services, shut workers down, join everything.
    pub fn shutdown(self) {
        // Drain: unfinished sessions re-enter the batcher after every
        // step, so keep the former ticking until the session table, the
        // queue, and the in-flight set are all empty. A poisoned batch
        // can't spin this forever — the watchdog fails it at the deadline.
        loop {
            let busy = self.session_count() > 0
                || self.pending_count() > 0
                || self.batcher.lock().unwrap().pending() > 0;
            if !busy {
                break;
            }
            let _ = self.batch_signal.send(());
            std::thread::sleep(Duration::from_millis(1));
        }
        self.shared.stopping.store(true, Ordering::SeqCst);
        self.shared.bus.shutdown();
        for w in self.workers {
            let _ = w.join();
        }
        // dropping Engine fields closes the batch list channel; dispatcher
        // and former threads exit, collector exits on worker hangup
        drop(self.batcher);
        drop(self.batch_signal);
        for s in self.service {
            let _ = s.join();
        }
    }
}

/// The collector: the completion half of the iteration-level scheduler.
/// For every finished batch it fulfils the batch `RRef`, streams each
/// row's sampled token to its session's `GenRef`, and re-enqueues
/// unfinished sessions at the front of the batcher queue — making the
/// collector a producer and closing the continuation loop.
fn collector_loop(
    reply_rx: Receiver<Reply>,
    shared: Arc<Shared>,
    batcher: Arc<Mutex<Batcher>>,
    signal: Sender<()>,
    max_seq: usize,
) {
    while let Ok((uid, result)) = reply_rx.recv() {
        let entry = shared.pending.lock().unwrap().remove(&uid);
        let Pending { rref, rows, from_batcher } = match entry {
            Some(p) => p,
            None => continue, // expired by the watchdog; drop the late reply
        };
        let latency = rref.submitted_at.elapsed();
        match &result {
            Ok(out) => {
                shared.metrics.lock().unwrap().record_batch(latency, rows.len());
                if from_batcher {
                    let now = Instant::now();
                    // (request, original arrival) pairs to re-enqueue
                    let mut continuations: Vec<(Request, Instant)> = Vec::new();
                    // finished sessions whose worker-side K/V blocks can go
                    let mut released: Vec<u64> = Vec::new();
                    // (is_first, latency) per emitted token, recorded after
                    // the sessions lock drops (one metrics lock per batch)
                    let mut token_lats: Vec<(bool, Duration)> = Vec::new();
                    {
                        let mut sessions = shared.sessions.lock().unwrap();
                        for (i, row) in rows.into_iter().enumerate() {
                            let sess = match sessions.get_mut(&row.id) {
                                Some(s) => s,
                                None => continue, // session already failed/expired
                            };
                            let tok = match out.next_tokens.get(i) {
                                Some(&t) => t,
                                None => {
                                    let sess = sessions.remove(&row.id).unwrap();
                                    sess.gref.finish(Err(anyhow::anyhow!(
                                        "batch {uid} returned no token for row {i}"
                                    )));
                                    released.push(row.id);
                                    continue;
                                }
                            };
                            let n_gen = row.tokens.len() - sess.prompt_len;
                            if n_gen == 0 {
                                token_lats.push((true, now.duration_since(sess.arrived)));
                            } else {
                                token_lats.push((false, now.duration_since(sess.last_at)));
                            }
                            sess.gref.push_token(tok);
                            sess.last_at = now;
                            let new_len = row.tokens.len() + 1;
                            let finished = n_gen + 1 >= sess.max_new
                                || sess.stop == Some(tok)
                                || new_len >= max_seq;
                            if finished {
                                let sess = sessions.remove(&row.id).unwrap();
                                sess.gref.finish(Ok(()));
                                released.push(row.id);
                            } else {
                                // the session's token vector moves on into
                                // its continuation row — no clone. With the
                                // cache live this is a *decode* step: only
                                // the newest token runs through the layers.
                                let mut toks = row.tokens;
                                toks.push(tok);
                                let req = if shared.kv_on {
                                    Request::decode(row.id, toks)
                                } else {
                                    Request::new(row.id, toks)
                                };
                                continuations.push((req, sess.arrived));
                            }
                        }
                        // publish while the sessions lock is held: shutdown's
                        // drain must not observe an empty table before the
                        // release command is on every worker's queue
                        shared.release_sessions(released.clone());
                    }
                    if !token_lats.is_empty() {
                        let mut m = shared.metrics.lock().unwrap();
                        for (is_first, lat) in token_lats {
                            if is_first {
                                m.record_first_token(lat);
                            } else {
                                m.record_decode_token(lat);
                            }
                        }
                    }
                    if !continuations.is_empty() || !released.is_empty() {
                        let mut b = batcher.lock().unwrap();
                        // tier model: freed sessions credit their blocks
                        // (freed capacity may admit a deferred prefill)
                        b.tier_free(&released);
                        // reversed so batch row order survives the
                        // front-pushes (decode priority); requeue_front
                        // also cold-marks each session in the tier model
                        for (r, arrived) in continuations.into_iter().rev() {
                            b.requeue_front(r, arrived);
                        }
                        drop(b);
                        let _ = signal.send(());
                    }
                }
            }
            Err(e) => {
                if from_batcher {
                    let mut released = Vec::new();
                    {
                        let mut sessions = shared.sessions.lock().unwrap();
                        for row in &rows {
                            if let Some(sess) = sessions.remove(&row.id) {
                                sess.gref.finish(Err(anyhow::anyhow!("{e}")));
                                released.push(row.id);
                            }
                        }
                        // under the lock — see the Ok branch
                        shared.release_sessions(released.clone());
                    }
                    if !released.is_empty() {
                        batcher.lock().unwrap().tier_free(&released);
                        let _ = signal.send(());
                    }
                }
            }
        }
        rref.fulfil(result);
    }
}

/// Watchdog: periodically fail in-flight batches older than `deadline`.
/// A non-replier worker error drops the activation, so the replier never
/// reports and the batch would otherwise hang its `RRef` (and `shutdown`
/// would busy-wait forever on `pending_count`).
fn watchdog_loop(shared: Arc<Shared>, batcher: Arc<Mutex<Batcher>>, deadline: Duration) {
    // short dozes keep shutdown responsive; the pending scan itself runs at
    // deadline/4 granularity (bounded to 1s) so the shared lock is touched
    // rarely relative to the hot path
    let doze = Duration::from_millis(5);
    let scan_every = (deadline / 4).clamp(Duration::from_millis(1), Duration::from_secs(1));
    let mut last_scan = Instant::now();
    while !shared.stopping.load(Ordering::SeqCst) {
        std::thread::sleep(doze);
        if last_scan.elapsed() >= scan_every {
            expire_stale(&shared, &batcher, deadline);
            last_scan = Instant::now();
        }
    }
}

/// Remove and fail every pending batch older than `deadline`. Returns how
/// many batches were expired.
fn expire_stale(shared: &Shared, batcher: &Mutex<Batcher>, deadline: Duration) -> usize {
    let stale: Vec<(u64, Pending)> = {
        let mut pending = shared.pending.lock().unwrap();
        let uids: Vec<u64> = pending
            .iter()
            .filter(|(_, p)| p.rref.submitted_at.elapsed() > deadline)
            .map(|(&u, _)| u)
            .collect();
        uids.into_iter().map(|u| (u, pending.remove(&u).unwrap())).collect()
    };
    let n = stale.len();
    for (uid, p) in stale {
        let msg = format!(
            "batch {uid} exceeded the {deadline:?} watchdog deadline \
             (a worker error likely dropped the activation)"
        );
        if p.from_batcher {
            let mut released = Vec::new();
            {
                let mut sessions = shared.sessions.lock().unwrap();
                for row in &p.rows {
                    if let Some(sess) = sessions.remove(&row.id) {
                        sess.gref.finish(Err(anyhow::anyhow!("{msg}")));
                        released.push(row.id);
                    }
                }
                // poisoned sessions must not leak their cache blocks: workers
                // that survive still hold them until this ticketed release,
                // published under the sessions lock so shutdown's drain can't
                // race past an un-published release
                shared.release_sessions(released.clone());
            }
            // tier model: poisoned sessions' blocks (either tier) are free
            if !released.is_empty() {
                batcher.lock().unwrap().tier_free(&released);
            }
        }
        p.rref.fulfil(Err(anyhow::anyhow!("{msg}")));
    }
    n
}

#[allow(clippy::too_many_arguments)]
fn build_worker(
    ctx: WorkerCtx,
    manifest: Arc<Manifest>,
    cfg: ModelConfig,
    memory: MemoryMode,
    seed: u64,
    warmup: bool,
    kv_cfg: Option<KvCacheConfig>,
    coll_ep: crate::comm::channel::Endpoint<ChunkMsg>,
    act_ep: crate::comm::channel::Endpoint<ActMsg>,
    cmd_rx: std::sync::mpsc::Receiver<super::rpc::Command>,
    reply_tx: Sender<Reply>,
) -> anyhow::Result<Worker> {
    let device = Device::new(ctx.device_id())?;
    // every worker regenerates the (seeded) full weights and keeps only its
    // shard — simple, reproducible, and mirrors the paper's per-worker init
    let full = ModelWeights::random(&cfg, seed);
    let my_layers: Vec<_> = ctx
        .layers
        .clone()
        .map(|l| shard_layer(&cfg, &full.layers[l], ctx.par.tp, ctx.tp_rank))
        .collect();
    let provider: Box<dyn LayerProvider> = match memory {
        MemoryMode::Resident => Box::new(ResidentProvider::new(my_layers)),
        MemoryMode::Pmep { n_local, pool } => {
            let off = crate::memory::ledger::even_offload_placement(
                my_layers.len(),
                n_local.min(my_layers.len()),
            );
            Box::new(PooledProvider::new(my_layers, off, pool))
        }
        MemoryMode::Bminf { n_local } => {
            let off = crate::memory::ledger::even_offload_placement(
                my_layers.len(),
                n_local.min(my_layers.len()),
            );
            Box::new(PooledProvider::new(my_layers, off, PoolConfig::bminf()))
        }
    };
    let embed_weights = ctx.is_first_stage().then(|| full.embed_args());
    let logits_weights = ctx.is_last_stage().then(|| full.logits_args());

    if warmup {
        let t_buckets: Vec<usize> = manifest
            .by_kind(&ctx.preset, "drce_attn_shard")
            .filter(|v| v.tp == ctx.par.tp)
            .map(|v| v.t_bucket)
            .collect();
        let prefill_kinds = [
            "embed",
            "layer_full",
            "layer_full_kv",
            "logits",
            "attn_shard",
            "attn_shard_kv",
            "mlp_shard",
        ];
        for (b, s) in manifest.shape_points(&ctx.preset) {
            for kind in prefill_kinds {
                let tp = if kind.starts_with("attn_shard") || kind == "mlp_shard" {
                    ctx.par.tp
                } else {
                    1
                };
                let name = Manifest::name_of(&ctx.preset, kind, b, s, tp, 0);
                if let Ok(v) = manifest.get(&name) {
                    let _ = device.load(&manifest, v);
                }
            }
            if ctx.drce {
                for &t in &t_buckets {
                    for kind in ["drce_attn_shard", "mlp_shard"] {
                        let name = Manifest::name_of(&ctx.preset, kind, b, s, ctx.par.tp, t);
                        if let Ok(v) = manifest.get(&name) {
                            let _ = device.load(&manifest, v);
                        }
                    }
                }
            }
        }
        if ctx.kv_cache {
            for w in manifest.decode_widths(&ctx.preset, ctx.par.tp) {
                for (kind, seq) in [
                    ("embed_decode", 0),
                    ("layer_full_decode", 0),
                    ("attn_shard_decode", 0),
                    ("mlp_shard", 1),
                    ("logits", 1),
                ] {
                    let tp = if kind.starts_with("attn_shard") || kind == "mlp_shard" {
                        ctx.par.tp
                    } else {
                        1
                    };
                    let name = Manifest::name_of(&ctx.preset, kind, w, seq, tp, 0);
                    if let Ok(v) = manifest.get(&name) {
                        let _ = device.load(&manifest, v);
                    }
                }
            }
        }
    }

    // paged (possibly two-tier) per-session K/V storage for this
    // worker's layer shard; the engine sized the config at launch
    let kv = kv_cfg.map(KvCache::new);

    Ok(Worker {
        ctx,
        manifest,
        device,
        provider,
        embed_weights,
        logits_weights,
        cmd_rx,
        coll_ep,
        act_ep,
        reply_tx,
        weight_lits: Default::default(),
        embed_lits: None,
        logits_lits: None,
        kv,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn genref_streams_in_order() {
        let g = GenRef::new(vec![1, 2]);
        let g2 = g.clone();
        let h = std::thread::spawn(move || {
            let mut got = Vec::new();
            while let Some(t) = g2.next().unwrap() {
                got.push(t);
            }
            got
        });
        for t in [10, 11, 12] {
            g.push_token(t);
            std::thread::sleep(Duration::from_millis(1));
        }
        g.finish(Ok(()));
        assert_eq!(h.join().unwrap(), vec![10, 11, 12]);
        assert_eq!(g.to_here().unwrap(), vec![1, 2, 10, 11, 12]);
        assert_eq!(g.n_generated(), 3);
        assert!(g.is_done());
        assert_eq!(g.prompt(), &[1, 2]);
    }

    #[test]
    fn genref_drains_buffered_tokens_before_error() {
        let g = GenRef::new(vec![1]);
        g.push_token(5);
        g.finish(Err(anyhow::anyhow!("poisoned")));
        assert_eq!(g.next().unwrap(), Some(5));
        assert!(g.next().is_err());
        assert!(g.to_here().is_err());
    }

    fn test_shared() -> Shared {
        Shared {
            bus: CommandBus::new(1).0,
            tickets: TicketCounter::new(),
            pending: Mutex::new(HashMap::new()),
            sessions: Mutex::new(HashMap::new()),
            metrics: Mutex::new(Recorder::new()),
            stopping: AtomicBool::new(false),
            kv_on: true,
        }
    }

    #[test]
    fn watchdog_expires_poisoned_batches_and_their_sessions() {
        let shared = test_shared();
        let gref = GenRef::new(vec![1, 2]);
        let now = Instant::now();
        shared.sessions.lock().unwrap().insert(
            9,
            Session {
                prompt_len: 2,
                max_new: 4,
                stop: None,
                arrived: now,
                last_at: now,
                gref: gref.clone(),
            },
        );
        let rref = RRef::new(0);
        shared.pending.lock().unwrap().insert(
            0,
            Pending {
                rref: rref.clone(),
                rows: vec![Request::new(9, vec![1, 2])],
                from_batcher: true,
            },
        );
        let batcher = Mutex::new(
            Batcher::new(vec![(1, 16)], 4, Duration::from_millis(10))
                .with_tier(TierPolicy::new(TierConfig::new(8, 8), 8)),
        );
        // the tier model learns of the session via its decode gate
        batcher.lock().unwrap().tier_mut().unwrap().gate_decode(&[(9, 2)]);
        assert_eq!(batcher.lock().unwrap().tier().unwrap().session_count(), 1);
        // under a generous deadline nothing expires
        assert_eq!(expire_stale(&shared, &batcher, Duration::from_secs(3600)), 0);
        assert!(!rref.is_ready());
        // at a zero deadline the batch is poisoned: the RRef errors instead
        // of hanging, and the session's stream fails
        std::thread::sleep(Duration::from_millis(2));
        assert_eq!(expire_stale(&shared, &batcher, Duration::ZERO), 1);
        // the poisoned session's blocks were credited in the tier model
        assert_eq!(batcher.lock().unwrap().tier().unwrap().session_count(), 0);
        assert_eq!(batcher.lock().unwrap().tier().unwrap().device_used(), 0);
        assert!(rref.to_here().is_err());
        assert!(gref.to_here().is_err());
        assert!(shared.sessions.lock().unwrap().is_empty());
        assert!(shared.pending.lock().unwrap().is_empty());
    }
}
