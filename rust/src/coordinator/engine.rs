//! The centralized engine (§4.1.2, §4.2): runtime initialization, the
//! non-blocking task launch, the batch-list dispatcher pool, and the
//! result collector. Public usage mirrors the paper's Fig. 9:
//!
//! ```no_run
//! use energonai::coordinator::engine::{Engine, LaunchConfig};
//! use energonai::coordinator::batcher::Request;
//! let engine = Engine::launch(LaunchConfig::preset("tiny")).unwrap();
//! let rref = engine.infer_batch(vec![Request::new(0, vec![1, 2, 3])]).unwrap(); // non-blocking
//! let output = rref.to_here().unwrap();
//! engine.shutdown();
//! ```

use super::batcher::{Batcher, FormedBatch, Request};
use super::consistency::TicketCounter;
use super::rpc::{CommandBus, RRef};
use super::worker::{ActMsg, Reply, Worker, WorkerCtx};
use crate::comm::channel::{CommWorld, Mode};
use crate::comm::collective::ChunkMsg;
use crate::config::{EngineConfig, ModelConfig, ParallelConfig};
use crate::memory::pool::{PoolConfig, PooledProvider};
use crate::memory::{LayerProvider, ResidentProvider};
use crate::metrics::Recorder;
use crate::model::{shard_layer, ModelWeights};
use crate::runtime::{Device, Manifest};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Where layer weights live on each worker (Fig. 13 scenarios).
#[derive(Clone, Debug)]
pub enum MemoryMode {
    /// Everything resident (the default).
    Resident,
    /// PMEP: keep `n_local` layers resident per worker, pool the rest in
    /// peer memory with prefetch (§4.4).
    Pmep { n_local: usize, pool: PoolConfig },
    /// BMInf-style synchronous CPU offload baseline (§5.6).
    Bminf { n_local: usize },
}

/// Everything `Engine::launch` needs.
#[derive(Clone, Debug)]
pub struct LaunchConfig {
    pub preset: String,
    pub parallel: ParallelConfig,
    pub engine: EngineConfig,
    pub memory: MemoryMode,
    pub seed: u64,
    /// Override layer count (the paper's customized 12/24/48-layer GPT-3s).
    pub n_layers: Option<usize>,
    /// Pre-compile all variants at launch (keeps latency measurements
    /// clean; off by default for fast test startup).
    pub warmup: bool,
}

impl LaunchConfig {
    pub fn preset(name: &str) -> LaunchConfig {
        LaunchConfig {
            preset: name.to_string(),
            parallel: ParallelConfig::serial(),
            engine: EngineConfig::default(),
            memory: MemoryMode::Resident,
            seed: 42,
            n_layers: None,
            warmup: false,
        }
    }

    pub fn with_parallel(mut self, tp: usize, pp: usize) -> Self {
        self.parallel = ParallelConfig::new(tp, pp);
        self
    }

    pub fn with_drce(mut self, on: bool) -> Self {
        self.engine.drce = on;
        self
    }

    pub fn with_blocking_comms(mut self, on: bool) -> Self {
        self.engine.blocking_comms = on;
        self
    }

    pub fn with_consistency(mut self, on: bool) -> Self {
        self.engine.consistency_queue = on;
        self
    }

    pub fn with_layers(mut self, n: usize) -> Self {
        self.n_layers = Some(n);
        self
    }

    pub fn with_memory(mut self, m: MemoryMode) -> Self {
        self.memory = m;
        self
    }

    pub fn with_warmup(mut self, on: bool) -> Self {
        self.warmup = on;
        self
    }
}

/// Per-request future (single-token greedy result), fulfilled when the
/// containing batch completes.
#[derive(Clone)]
pub struct TokenRef {
    inner: Arc<(Mutex<Option<anyhow::Result<i32>>>, Condvar)>,
}

impl TokenRef {
    fn new() -> TokenRef {
        TokenRef { inner: Arc::new((Mutex::new(None), Condvar::new())) }
    }

    fn fulfil(&self, v: anyhow::Result<i32>) {
        let (m, cv) = &*self.inner;
        *m.lock().unwrap() = Some(v);
        cv.notify_all();
    }

    pub fn to_here(&self) -> anyhow::Result<i32> {
        let (m, cv) = &*self.inner;
        let mut g = m.lock().unwrap();
        loop {
            if let Some(v) = g.take() {
                return v;
            }
            g = cv.wait(g).unwrap();
        }
    }
}

/// Bookkeeping for one in-flight batch.
struct Pending {
    rref: RRef,
    /// Real request count (bucket rows can exceed it due to padding).
    n_requests: usize,
    /// Per-request futures (batcher path only), in batch row order.
    token_refs: Vec<TokenRef>,
}

struct Shared {
    bus: CommandBus,
    tickets: TicketCounter,
    pending: Mutex<HashMap<u64, Pending>>,
    /// submit()'s per-request futures awaiting batch formation.
    req_futures: Mutex<HashMap<u64, TokenRef>>,
    metrics: Mutex<Recorder>,
    stopping: AtomicBool,
}

impl Shared {
    /// The non-blocking launch (§4.2): take a ticket, register the rref,
    /// publish to every worker, return immediately.
    fn publish(&self, fb: &FormedBatch, token_refs: Vec<TokenRef>) -> RRef {
        let input = std::sync::Arc::new(fb.to_input());
        let uid = self.tickets.issue();
        let rref = RRef::new(uid);
        self.pending.lock().unwrap().insert(
            uid,
            Pending { rref: rref.clone(), n_requests: fb.requests.len(), token_refs },
        );
        self.bus.publish(uid, &input);
        rref
    }
}

/// The running system: workers + dispatcher pool + collector.
pub struct Engine {
    pub cfg: ModelConfig,
    pub launch: LaunchConfig,
    pub manifest: Arc<Manifest>,
    shared: Arc<Shared>,
    batcher: Arc<Mutex<Batcher>>,
    batch_signal: Sender<()>,
    next_req_id: std::sync::atomic::AtomicU64,
    workers: Vec<JoinHandle<()>>,
    service: Vec<JoinHandle<()>>,
}

impl Engine {
    /// Runtime initialization (§4.1.2): spawn one worker thread per device
    /// (each builds its own PJRT client, shards its layer range, compiles
    /// its variants), then start the dispatcher pool and collector.
    pub fn launch(launch: LaunchConfig) -> anyhow::Result<Engine> {
        let manifest = Arc::new(Manifest::load(crate::runtime::find_artifacts()?)?);
        let mut cfg = ModelConfig::preset(&launch.preset)
            .ok_or_else(|| anyhow::anyhow!("unknown preset {}", launch.preset))?;
        if let Some(n) = launch.n_layers {
            cfg.n_layers = n;
        }
        let par = launch.parallel;
        anyhow::ensure!(cfg.n_heads % par.tp == 0, "heads not divisible by tp");
        anyhow::ensure!(cfg.n_layers >= par.pp, "fewer layers than stages");
        anyhow::ensure!(
            !manifest.shape_points(&launch.preset).is_empty(),
            "no artifacts for preset {}; run `make artifacts`",
            launch.preset
        );

        let world = par.world_size();
        let (bus, cmd_rxs) = CommandBus::new(world);
        let act_mode = if launch.engine.blocking_comms { Mode::Blocking } else { Mode::NonBlocking };
        let coll_eps = CommWorld::new::<ChunkMsg>(world, Mode::NonBlocking);
        let act_eps = CommWorld::new::<ActMsg>(world, act_mode);
        let (reply_tx, reply_rx) = std::sync::mpsc::channel::<Reply>();

        // ---- workers -------------------------------------------------------
        let mut workers = Vec::with_capacity(world);
        let (ready_tx, ready_rx) = std::sync::mpsc::channel::<anyhow::Result<usize>>();
        let mut coll_it = coll_eps.into_iter();
        let mut act_it = act_eps.into_iter();
        let mut cmd_it = cmd_rxs.into_iter();
        for stage in 0..par.pp {
            for tp_rank in 0..par.tp {
                let ctx = WorkerCtx {
                    preset: launch.preset.clone(),
                    cfg: cfg.clone(),
                    par,
                    stage,
                    tp_rank,
                    layers: par.stage_layers(stage, cfg.n_layers),
                    drce: launch.engine.drce,
                    consistency: launch.engine.consistency_queue,
                    lookahead: match &launch.memory {
                        MemoryMode::Pmep { pool, .. } => pool.lookahead.max(1),
                        _ => 1,
                    },
                };
                let args = (
                    ctx,
                    manifest.clone(),
                    cfg.clone(),
                    launch.memory.clone(),
                    launch.seed,
                    launch.warmup,
                    coll_it.next().unwrap(),
                    act_it.next().unwrap(),
                    cmd_it.next().unwrap(),
                    reply_tx.clone(),
                );
                let ready_tx = ready_tx.clone();
                workers.push(std::thread::spawn(move || {
                    let (ctx, man, cfg, mem, seed, warm, coll, act, cmd, reply) = args;
                    let id = ctx.device_id();
                    match build_worker(ctx, man, cfg, mem, seed, warm, coll, act, cmd, reply) {
                        Ok(w) => {
                            let _ = ready_tx.send(Ok(id));
                            w.run()
                        }
                        Err(e) => {
                            let _ = ready_tx.send(Err(anyhow::anyhow!("worker {id} init: {e:#}")));
                        }
                    }
                }));
            }
        }
        drop(reply_tx); // collector exits once all workers hang up
        drop(ready_tx);
        // runtime initialization barrier (§4.1.2): wait until every worker
        // has built its device, sharded its weights and compiled its
        // variants — so first-request latency is a serving number, not a
        // compile number
        for _ in 0..world {
            match ready_rx.recv() {
                Ok(Ok(_)) => {}
                Ok(Err(e)) => return Err(e),
                Err(_) => anyhow::bail!("a worker died during initialization"),
            }
        }

        let shared = Arc::new(Shared {
            bus,
            tickets: TicketCounter::new(),
            pending: Mutex::new(HashMap::new()),
            req_futures: Mutex::new(HashMap::new()),
            metrics: Mutex::new(Recorder::new()),
            stopping: AtomicBool::new(false),
        });

        // ---- collector -------------------------------------------------------
        let mut service = Vec::new();
        {
            let shared = shared.clone();
            service.push(std::thread::spawn(move || collector_loop(reply_rx, shared)));
        }

        // ---- batcher + dispatcher pool (Fig. 5) ------------------------------
        let batcher = Arc::new(Mutex::new(Batcher::new(
            manifest.shape_points(&launch.preset),
            launch.engine.max_batch,
            Duration::from_micros(launch.engine.batch_timeout_us),
        )));
        let (batch_signal, batch_rx) = std::sync::mpsc::channel::<()>();
        let (fb_tx, fb_rx) = std::sync::mpsc::channel::<(FormedBatch, Vec<TokenRef>)>();
        let fb_rx = Arc::new(Mutex::new(fb_rx));

        // former thread: turns the request queue into the batch list
        {
            let batcher = batcher.clone();
            let shared = shared.clone();
            service.push(std::thread::spawn(move || {
                let tick = Duration::from_micros(500);
                loop {
                    if shared.stopping.load(Ordering::SeqCst) {
                        break;
                    }
                    let _ = batch_rx.recv_timeout(tick);
                    loop {
                        let fb = batcher.lock().unwrap().form(std::time::Instant::now());
                        match fb {
                            Some(fb) => {
                                // bind each request's future (created by
                                // submit()) to its batch row
                                let refs: Vec<TokenRef> = {
                                    let mut reg = shared.req_futures.lock().unwrap();
                                    fb.requests
                                        .iter()
                                        .map(|r| reg.remove(&r.id).unwrap_or_else(TokenRef::new))
                                        .collect()
                                };
                                if fb_tx.send((fb, refs)).is_err() {
                                    return;
                                }
                            }
                            None => break,
                        }
                    }
                }
            }));
        }

        // dispatcher pool: N threads each take a formed batch, publish it
        // (non-blocking), then wait for completion — so the pool size is the
        // in-flight bound, exactly Fig. 5's thread-pool semantics.
        for _ in 0..launch.engine.pool_threads {
            let shared = shared.clone();
            let fb_rx = fb_rx.clone();
            service.push(std::thread::spawn(move || loop {
                let next = fb_rx.lock().unwrap().recv();
                match next {
                    Ok((fb, refs)) => {
                        let rref = shared.publish(&fb, refs);
                        let _ = rref.to_here(); // completion gates this slot
                    }
                    Err(_) => break,
                }
            }));
        }

        Ok(Engine {
            cfg,
            launch,
            manifest,
            shared,
            batcher,
            batch_signal,
            next_req_id: std::sync::atomic::AtomicU64::new(0),
            workers,
            service,
        })
    }

    /// Submit a pre-formed batch directly, bypassing the batcher (benches
    /// and examples that need exact shapes). Non-blocking.
    pub fn infer_batch(&self, requests: Vec<Request>) -> anyhow::Result<RRef> {
        anyhow::ensure!(!requests.is_empty(), "empty batch");
        let points = self.manifest.shape_points(&self.launch.preset);
        let n = requests.len();
        let max_len = requests.iter().map(Request::len).max().unwrap();
        let bucket = points
            .iter()
            .copied()
            .filter(|&(b, s)| b >= n && s >= max_len)
            .min_by_key(|&(b, s)| b * s)
            .ok_or_else(|| anyhow::anyhow!("no compiled bucket fits ({n}, {max_len})"))?;
        let fb = FormedBatch { requests, bucket };
        Ok(self.shared.publish(&fb, vec![]))
    }

    /// Submit one request through the dynamic batcher. Returns a future
    /// for the request's next token.
    pub fn submit(&self, tokens: Vec<i32>) -> anyhow::Result<TokenRef> {
        let id = self.next_req_id.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
        let tref = TokenRef::new();
        self.shared.req_futures.lock().unwrap().insert(id, tref.clone());
        if let Err(e) = self.batcher.lock().unwrap().push(Request::new(id, tokens)) {
            self.shared.req_futures.lock().unwrap().remove(&id);
            return Err(e);
        }
        let _ = self.batch_signal.send(());
        Ok(tref)
    }

    /// Greedy autoregressive generation: extend `prompt` by `n_tokens`,
    /// re-running prefill each step (no KV cache — each step flows through
    /// the full batch path, exercising progressively longer buckets).
    /// Stops early if the context exceeds the longest compiled bucket.
    pub fn generate(&self, prompt: Vec<i32>, n_tokens: usize) -> anyhow::Result<Vec<i32>> {
        anyhow::ensure!(!prompt.is_empty(), "empty prompt");
        let max_seq = self
            .manifest
            .shape_points(&self.launch.preset)
            .iter()
            .map(|&(_, s)| s)
            .max()
            .unwrap_or(0);
        let mut tokens = prompt;
        for _ in 0..n_tokens {
            if tokens.len() >= max_seq {
                break;
            }
            let rref = self.infer_batch(vec![Request::new(0, tokens.clone())])?;
            let out = rref.to_here()?;
            let next = *out
                .next_tokens
                .first()
                .ok_or_else(|| anyhow::anyhow!("no token returned"))?;
            tokens.push(next);
        }
        Ok(tokens)
    }

    /// Snapshot of serving metrics, with the process-wide activation-arena
    /// allocation counters folded in (fresh allocs vs bytes recycled on the
    /// host hot path — §Perf).
    pub fn metrics_snapshot(&self) -> Recorder {
        let mut r = self.shared.metrics.lock().unwrap().clone();
        r.record_arena(crate::memory::arena::ArenaPool::global_stats());
        r
    }

    pub fn pending_count(&self) -> usize {
        self.shared.pending.lock().unwrap().len()
    }

    /// Orderly teardown: flush the batcher, stop services, shut workers
    /// down, join everything.
    pub fn shutdown(self) {
        // flush remaining queued requests
        let leftovers = self.batcher.lock().unwrap().flush();
        for fb in leftovers {
            let refs: Vec<TokenRef> = {
                let mut reg = self.shared.req_futures.lock().unwrap();
                fb.requests
                    .iter()
                    .map(|r| reg.remove(&r.id).unwrap_or_else(TokenRef::new))
                    .collect()
            };
            let rref = self.shared.publish(&fb, refs);
            let _ = rref.to_here();
        }
        // wait for in-flight work to drain
        while self.pending_count() > 0 {
            std::thread::sleep(Duration::from_millis(1));
        }
        self.shared.stopping.store(true, Ordering::SeqCst);
        self.shared.bus.shutdown();
        for w in self.workers {
            let _ = w.join();
        }
        // dropping Engine fields closes the batch list channel; dispatcher
        // and former threads exit, collector exits on worker hangup
        drop(self.batcher);
        drop(self.batch_signal);
        for s in self.service {
            let _ = s.join();
        }
    }
}

fn collector_loop(reply_rx: Receiver<Reply>, shared: Arc<Shared>) {
    while let Ok((uid, result)) = reply_rx.recv() {
        let entry = shared.pending.lock().unwrap().remove(&uid);
        if let Some(Pending { rref, n_requests, token_refs }) = entry {
            let latency = rref.submitted_at.elapsed();
            match &result {
                Ok(out) => {
                    shared.metrics.lock().unwrap().record_batch(latency, n_requests);
                    for (i, t) in token_refs.iter().enumerate() {
                        t.fulfil(
                            out.next_tokens
                                .get(i)
                                .copied()
                                .ok_or_else(|| anyhow::anyhow!("missing token {i}")),
                        );
                    }
                }
                Err(e) => {
                    for t in &token_refs {
                        t.fulfil(Err(anyhow::anyhow!("{e}")));
                    }
                }
            }
            rref.fulfil(result);
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn build_worker(
    ctx: WorkerCtx,
    manifest: Arc<Manifest>,
    cfg: ModelConfig,
    memory: MemoryMode,
    seed: u64,
    warmup: bool,
    coll_ep: crate::comm::channel::Endpoint<ChunkMsg>,
    act_ep: crate::comm::channel::Endpoint<ActMsg>,
    cmd_rx: std::sync::mpsc::Receiver<super::rpc::Command>,
    reply_tx: Sender<Reply>,
) -> anyhow::Result<Worker> {
    let device = Device::new(ctx.device_id())?;
    // every worker regenerates the (seeded) full weights and keeps only its
    // shard — simple, reproducible, and mirrors the paper's per-worker init
    let full = ModelWeights::random(&cfg, seed);
    let my_layers: Vec<_> = ctx
        .layers
        .clone()
        .map(|l| shard_layer(&cfg, &full.layers[l], ctx.par.tp, ctx.tp_rank))
        .collect();
    let provider: Box<dyn LayerProvider> = match memory {
        MemoryMode::Resident => Box::new(ResidentProvider::new(my_layers)),
        MemoryMode::Pmep { n_local, pool } => {
            let off = crate::memory::ledger::even_offload_placement(
                my_layers.len(),
                n_local.min(my_layers.len()),
            );
            Box::new(PooledProvider::new(my_layers, off, pool))
        }
        MemoryMode::Bminf { n_local } => {
            let off = crate::memory::ledger::even_offload_placement(
                my_layers.len(),
                n_local.min(my_layers.len()),
            );
            Box::new(PooledProvider::new(my_layers, off, PoolConfig::bminf()))
        }
    };
    let embed_weights = ctx.is_first_stage().then(|| full.embed_args());
    let logits_weights = ctx.is_last_stage().then(|| full.logits_args());

    if warmup {
        let t_buckets: Vec<usize> = manifest
            .by_kind(&ctx.preset, "drce_attn_shard")
            .filter(|v| v.tp == ctx.par.tp)
            .map(|v| v.t_bucket)
            .collect();
        for (b, s) in manifest.shape_points(&ctx.preset) {
            for kind in ["embed", "layer_full", "logits", "attn_shard", "mlp_shard"] {
                let name = Manifest::name_of(&ctx.preset, kind, b, s, if kind == "attn_shard" || kind == "mlp_shard" { ctx.par.tp } else { 1 }, 0);
                if let Ok(v) = manifest.get(&name) {
                    let _ = device.load(&manifest, v);
                }
            }
            if ctx.drce {
                for &t in &t_buckets {
                    for kind in ["drce_attn_shard", "mlp_shard"] {
                        let name = Manifest::name_of(&ctx.preset, kind, b, s, ctx.par.tp, t);
                        if let Ok(v) = manifest.get(&name) {
                            let _ = device.load(&manifest, v);
                        }
                    }
                }
            }
        }
    }

    Ok(Worker {
        ctx,
        manifest,
        device,
        provider,
        embed_weights,
        logits_weights,
        cmd_rx,
        coll_ep,
        act_ep,
        reply_tx,
        weight_lits: Default::default(),
        embed_lits: None,
        logits_lits: None,
    })
}
