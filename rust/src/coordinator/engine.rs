//! The centralized engine (§4.1.2, §4.2): runtime initialization, the
//! non-blocking task launch, the batch-list dispatcher pool, and the
//! result collector — extended with an **iteration-level generation
//! scheduler**: every submission is a session that re-enters the dynamic
//! batcher after each engine step until it finishes, so multi-token
//! generations from many clients coalesce into shared decode buckets
//! (Orca-style continuation batching).
//!
//! With the decode artifacts compiled, continuation steps are
//! **incremental**: the session's prefill seeds a paged per-worker K/V
//! cache (`memory::kvcache`), each continuation runs a single position
//! against it through the `*_decode` variants, and the collector releases
//! a session's blocks — by ticketed command through the consistency
//! queue — on completion, stop token, error, or watchdog poison. Without
//! them the engine falls back to the legacy re-prefill continuation path.
//!
//! With `engine.kv_spill` the cache is **tiered** (§4.4 applied to
//! generation state): every worker's device slab is capped, cold
//! sessions spill whole-session block images to a ledger-accounted host
//! tier, and the batch former — consulting the engine-side
//! `TierPolicy` — publishes ticketed `Spill`/`Prefetch` commands ahead
//! of each bucket so sessions are always resident when their decode step
//! executes (prefetch-on-reentry, one bucket of lookahead, prefill
//! admission control).
//!
//! Public usage mirrors the paper's Fig. 9, plus streaming generation:
//!
//! ```no_run
//! use energonai::coordinator::engine::{Engine, GenRequest, LaunchConfig};
//! use energonai::coordinator::batcher::Request;
//! let engine = Engine::launch(LaunchConfig::preset("tiny")).unwrap();
//! // direct pre-formed batch (benches): non-blocking RRef
//! let rref = engine.infer_batch(vec![Request::new(0, vec![1, 2, 3])]).unwrap();
//! let output = rref.to_here().unwrap();
//! // session lifecycle: stream tokens as engine steps complete
//! let gref = engine.generate_stream(GenRequest::new(vec![1, 2, 3], 8)).unwrap();
//! while let Some(tok) = gref.next().unwrap() {
//!     println!("token {tok}");
//! }
//! let full = gref.to_here().unwrap(); // prompt + generated
//! engine.shutdown();
//! ```

use super::batcher::{smallest_fitting_bucket, Batcher, Busy, FormedBatch, Request};
use super::consistency::TicketCounter;
use super::drafter::{Drafter, DrafterHandle, NGramDrafter};
use super::fault::FaultPlan;
use super::rpc::{CommandBus, Phase, RRef};
use super::worker::{ActMsg, Reply, Worker, WorkerCtx};
use crate::comm::channel::{CommWorld, Mode};
use crate::comm::collective::ChunkMsg;
use crate::config::{EngineConfig, ModelConfig, ParallelConfig};
use crate::memory::kvcache::tier::{TierCmd, TierConfig, TierPolicy};
use crate::memory::kvcache::{KvCache, KvCacheConfig};
use crate::memory::pool::{PoolConfig, PooledProvider};
use crate::memory::{LayerProvider, ResidentProvider};
use crate::metrics::Recorder;
use crate::model::{shard_layer, ModelWeights};
use crate::runtime::{Device, Manifest};
use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Where layer weights live on each worker (Fig. 13 scenarios).
#[derive(Clone, Debug)]
pub enum MemoryMode {
    /// Everything resident (the default).
    Resident,
    /// PMEP: keep `n_local` layers resident per worker, pool the rest in
    /// peer memory with prefetch (§4.4).
    Pmep { n_local: usize, pool: PoolConfig },
    /// BMInf-style synchronous CPU offload baseline (§5.6).
    Bminf { n_local: usize },
}

/// Everything `Engine::launch` needs.
#[derive(Clone, Debug)]
pub struct LaunchConfig {
    pub preset: String,
    pub parallel: ParallelConfig,
    pub engine: EngineConfig,
    pub memory: MemoryMode,
    pub seed: u64,
    /// Override layer count (the paper's customized 12/24/48-layer GPT-3s).
    pub n_layers: Option<usize>,
    /// Pre-compile all variants at launch (keeps latency measurements
    /// clean; off by default for fast test startup).
    pub warmup: bool,
    /// Speculative-decode drafter (`engine.speculative`). `None` uses the
    /// built-in n-gram drafter; tests and benches slot in harness
    /// drafters ([`super::drafter::ReplayDrafter`] /
    /// [`super::drafter::MisdraftDrafter`]) to pin the accept-rate
    /// extremes, and a small-model drafter can ride the same trait.
    pub drafter: Option<DrafterHandle>,
}

impl LaunchConfig {
    pub fn preset(name: &str) -> LaunchConfig {
        LaunchConfig {
            preset: name.to_string(),
            parallel: ParallelConfig::serial(),
            engine: EngineConfig::default(),
            memory: MemoryMode::Resident,
            seed: 42,
            n_layers: None,
            warmup: false,
            drafter: None,
        }
    }

    pub fn with_parallel(mut self, tp: usize, pp: usize) -> Self {
        self.parallel = ParallelConfig::new(tp, pp);
        self
    }

    pub fn with_drce(mut self, on: bool) -> Self {
        self.engine.drce = on;
        self
    }

    pub fn with_blocking_comms(mut self, on: bool) -> Self {
        self.engine.blocking_comms = on;
        self
    }

    pub fn with_consistency(mut self, on: bool) -> Self {
        self.engine.consistency_queue = on;
        self
    }

    pub fn with_layers(mut self, n: usize) -> Self {
        self.n_layers = Some(n);
        self
    }

    pub fn with_memory(mut self, m: MemoryMode) -> Self {
        self.memory = m;
        self
    }

    pub fn with_warmup(mut self, on: bool) -> Self {
        self.warmup = on;
        self
    }

    /// Incremental decode via the paged K/V cache on/off (on by default;
    /// off is the re-prefill baseline the differential tests and the
    /// decode bench compare against).
    pub fn with_kv_cache(mut self, on: bool) -> Self {
        self.engine.kv_cache = on;
        self
    }

    /// Speculative decode (draft-and-verify) on/off. Requires the verify
    /// artifact family, the KV cache, and pp == 1; the engine falls back
    /// to plain decode otherwise. Off = the verify path is never entered,
    /// so streams are byte-identical to the non-speculative engine.
    pub fn with_speculative(mut self, on: bool) -> Self {
        self.engine.speculative = on;
        self
    }

    /// Cap the verify window (1 committed token + up to `k - 1` drafts).
    pub fn with_spec_k(mut self, k: usize) -> Self {
        self.engine.spec_k = k;
        self
    }

    /// Use a custom [`Drafter`] for speculative decode (default: n-gram).
    pub fn with_drafter(mut self, d: impl Drafter + 'static) -> Self {
        self.drafter = Some(DrafterHandle::new(d));
        self
    }

    /// Chunked prefill: split prompts longer than `chunk` tokens into
    /// fixed windows that seed the paged cache incrementally, yielding to
    /// waiting decode buckets every `decode_ratio` windows. 0 = off (the
    /// default), which keeps the monolithic prefill path byte-identical.
    /// Requires the KV cache and the verify artifact family (chunk
    /// windows run the verify kernels); the engine silently falls back to
    /// monolithic prefill when either is missing.
    pub fn with_prefill_chunk(mut self, chunk: usize, decode_ratio: usize) -> Self {
        self.engine.prefill_chunk = chunk;
        self.engine.chunk_decode_ratio = decode_ratio;
        self
    }

    /// Enable the tiered K/V cache: cap every worker's device slab at
    /// `device_blocks` and spill cold sessions to a host tier of
    /// `host_blocks` (0 = unlimited), with prefetch-on-reentry and
    /// admission control. Requires the decode artifacts (`kv_cache`);
    /// with spill off the resident-only fast path is byte-identical to
    /// before.
    pub fn with_kv_spill(mut self, device_blocks: usize, host_blocks: usize) -> Self {
        self.engine.kv_spill = true;
        self.engine.kv_device_blocks = device_blocks;
        self.engine.kv_host_blocks = host_blocks;
        self
    }

    /// Enable the peer tier (§4.4 PMEP): let every worker park up to
    /// `blocks` cold session blocks in its ring peer's spare device
    /// memory, demoting the coldest parked sessions to host under peer
    /// pressure. Requires `with_kv_spill`; 0 keeps the two-tier path
    /// byte-identical.
    pub fn with_kv_peer(mut self, blocks: usize) -> Self {
        self.engine.kv_peer_blocks = blocks;
        self
    }

    /// Overlapped tier copier: staging memcpys (host prefetch and peer
    /// fetch landings) run on a per-worker copier thread behind the
    /// current forward instead of inline, so `prefetch_stall_us` shrinks
    /// to the residual settle wait. Off by default (inline copies).
    pub fn with_kv_copier(mut self, on: bool) -> Self {
        self.engine.kv_copier = on;
        self
    }

    /// Shared-prefix K/V reuse on/off (off by default — off is
    /// byte-identical to builds that predate the feature). Requires the
    /// decode artifacts (`kv_cache`); with them live, admission matches
    /// each new prompt against a trie of retained prefixes and hits adopt
    /// the cached blocks instead of re-running the shared prefill.
    pub fn with_prefix_cache(mut self, on: bool) -> Self {
        self.engine.prefix_cache = on;
        self
    }

    /// Load shedding: cap the queued-prefill depth (`max_queue_depth`,
    /// 0 = unbounded) and bound admitted-but-unfinished KV positions
    /// (`token_budget`, 0 = unlimited). Past the depth cap `submit` /
    /// `generate_stream` return a structured [`Busy`] error instead of
    /// queueing; past the budget new prefills defer inside the former.
    pub fn with_admission(mut self, max_queue_depth: usize, token_budget: usize) -> Self {
        self.engine.max_queue_depth = max_queue_depth;
        self.engine.admission_token_budget = token_budget;
        self
    }

    /// SLO targets for TTFT / TPOT in milliseconds (0 disables either).
    /// Violations feed a rolling window; sustained pressure tightens the
    /// admission cap so the engine sheds before latency collapses.
    pub fn with_slo(mut self, ttft_ms: u64, tpot_ms: u64) -> Self {
        self.engine.slo_ttft_ms = ttft_ms;
        self.engine.slo_tpot_ms = tpot_ms;
        self
    }

    /// Chaos fault injection: a seeded [`FaultPlan`] spec (see
    /// `coordinator::fault`) applied at every worker's reply boundary.
    /// Empty spec = no faults. The plan is validated at launch.
    pub fn with_faults(mut self, plan: &str, seed: u64) -> Self {
        self.engine.fault_plan = plan.to_string();
        self.engine.fault_seed = seed;
        self
    }

    /// Graceful degradation: while the SLO window votes "shedding",
    /// clamp admitted sessions' `max_new_tokens` to this floor instead
    /// of rejecting them outright (0 = off, shed as before).
    pub fn with_pressure_floor(mut self, max_new_tokens: usize) -> Self {
        self.engine.pressure_max_new_tokens = max_new_tokens;
        self
    }
}

/// Paging granularity every worker's cache and the engine-side tier
/// policy must agree on (block counts per session are derived from it on
/// both sides).
pub const KV_BLOCK_POSITIONS: usize = 8;

/// Capacity cap on the shared-prefix trie: ready, unleased entries past
/// this count are evicted FIFO (worker registries free the cached blocks
/// via a ticketed `EvictPrefix`). Generous relative to realistic template
/// counts — eviction is a backstop against unbounded registry growth, not
/// a working-set policy.
pub const PREFIX_CACHE_MAX_ENTRIES: usize = 256;

/// A generation request entering the session lifecycle: the prompt, how
/// many continuation tokens to sample, and an optional stop token that
/// ends the session early (the stop token itself is emitted).
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub tokens: Vec<i32>,
    pub max_new_tokens: usize,
    pub stop_token: Option<i32>,
}

impl GenRequest {
    pub fn new(tokens: Vec<i32>, max_new_tokens: usize) -> GenRequest {
        GenRequest { tokens, max_new_tokens, stop_token: None }
    }

    pub fn with_stop(mut self, stop_token: i32) -> Self {
        self.stop_token = Some(stop_token);
        self
    }
}

#[derive(Default)]
struct GenState {
    /// Generated tokens so far (prompt excluded), in emission order.
    toks: Vec<i32>,
    /// `next()` read cursor into `toks`.
    read: usize,
    done: bool,
    /// The client abandoned the session ([`GenRef::cancel`], or a TCP
    /// disconnect detected by the server). Terminal like `done`, but
    /// distinguishable so callers can tell "cancelled" from "failed".
    cancelled: bool,
    /// Failure message, surfaced by `next()`/`to_here()` after any
    /// already-streamed tokens are drained.
    err: Option<String>,
}

/// How a [`GenRef::cancel`] reaches the engine: the session id plus a
/// weak handle on the engine's cancellation inbox (weak so a `GenRef`
/// held past `shutdown` never keeps engine state alive, and a cancel
/// after teardown is a silent no-op).
#[derive(Clone)]
struct CancelHook {
    id: u64,
    inbox: std::sync::Weak<Mutex<Vec<u64>>>,
}

/// Streaming future for one generation session. The collector appends
/// each sampled token as the session's batch completes an engine step;
/// clients consume incrementally with [`GenRef::next`] or wait for the
/// whole sequence with [`GenRef::to_here`].
#[derive(Clone)]
pub struct GenRef {
    prompt: Arc<Vec<i32>>,
    inner: Arc<(Mutex<GenState>, Condvar)>,
    /// Engine-side cancellation route, attached by `generate_stream`
    /// (absent on bare test `GenRef`s — cancel then just ends the stream).
    hook: Arc<Mutex<Option<CancelHook>>>,
}

impl GenRef {
    // The constructor and producer-side hooks are crate-visible: the
    // replica fleet builds its own outer GenRef per session and relays
    // tokens into it from whichever replica currently runs the session.
    pub(crate) fn new(prompt: Vec<i32>) -> GenRef {
        GenRef {
            prompt: Arc::new(prompt),
            inner: Arc::new((Mutex::new(GenState::default()), Condvar::new())),
            hook: Arc::new(Mutex::new(None)),
        }
    }

    pub(crate) fn set_cancel_hook(&self, id: u64, inbox: std::sync::Weak<Mutex<Vec<u64>>>) {
        *self.hook.lock().unwrap() = Some(CancelHook { id, inbox });
    }

    /// Collector side: one more sampled token is available. Tokens sampled
    /// by a step already in flight when the session was cancelled are
    /// dropped — the stream is terminal from the client's point of view.
    pub(crate) fn push_token(&self, t: i32) {
        let (m, cv) = &*self.inner;
        let mut g = m.lock().unwrap();
        if g.done {
            return;
        }
        g.toks.push(t);
        cv.notify_all();
    }

    /// Collector side: the session ended (stop token, budget, context
    /// limit, or an error). The first terminal state wins: a finish that
    /// races a cancel keeps the cancel's verdict.
    pub(crate) fn finish(&self, res: anyhow::Result<()>) {
        let (m, cv) = &*self.inner;
        let mut g = m.lock().unwrap();
        if g.done {
            return;
        }
        g.done = true;
        g.err = res.err().map(|e| format!("{e:#}"));
        cv.notify_all();
    }

    /// Client side: abandon the session. The stream ends immediately with
    /// a "cancelled" error; the engine purges the session from the batch
    /// queue (or evicts it at the next collector step if a batch is in
    /// flight) and frees its K/V blocks on every worker by ticketed
    /// command — no leak, no further decode work. Idempotent; a cancel
    /// after natural completion is a no-op.
    pub fn cancel(&self) {
        {
            let (m, cv) = &*self.inner;
            let mut g = m.lock().unwrap();
            if g.done {
                return;
            }
            g.done = true;
            g.cancelled = true;
            g.err = Some("cancelled".to_string());
            cv.notify_all();
        }
        let hook = self.hook.lock().unwrap().clone();
        if let Some(h) = hook {
            if let Some(inbox) = h.inbox.upgrade() {
                inbox.lock().unwrap().push(h.id);
            }
        }
    }

    /// Did the session end by cancellation (vs. completing or failing)?
    pub fn is_cancelled(&self) -> bool {
        self.inner.0.lock().unwrap().cancelled
    }

    /// Block for the next streamed token. `Ok(None)` means the session
    /// finished; buffered tokens are always drained before an error is
    /// reported.
    pub fn next(&self) -> anyhow::Result<Option<i32>> {
        let (m, cv) = &*self.inner;
        let mut g = m.lock().unwrap();
        loop {
            if g.read < g.toks.len() {
                let t = g.toks[g.read];
                g.read += 1;
                return Ok(Some(t));
            }
            if g.done {
                return match &g.err {
                    Some(e) => Err(anyhow::anyhow!("{e}")),
                    None => Ok(None),
                };
            }
            g = cv.wait(g).unwrap();
        }
    }

    /// Block until the session finishes and return the full sequence
    /// (prompt + generated tokens). Does not consume the `next()` cursor.
    pub fn to_here(&self) -> anyhow::Result<Vec<i32>> {
        let (m, cv) = &*self.inner;
        let mut g = m.lock().unwrap();
        while !g.done {
            g = cv.wait(g).unwrap();
        }
        if let Some(e) = &g.err {
            return Err(anyhow::anyhow!("{e}"));
        }
        let mut out = (*self.prompt).clone();
        out.extend_from_slice(&g.toks);
        Ok(out)
    }

    /// Tokens generated so far (non-blocking snapshot).
    pub fn n_generated(&self) -> usize {
        self.inner.0.lock().unwrap().toks.len()
    }

    pub fn is_done(&self) -> bool {
        self.inner.0.lock().unwrap().done
    }

    pub fn prompt(&self) -> &[i32] {
        &self.prompt
    }
}

/// Single-token future — `submit()`'s return type, kept as a thin wrapper
/// over a one-token session for API continuity.
#[derive(Clone)]
pub struct TokenRef {
    gref: GenRef,
}

impl TokenRef {
    /// Wrap a one-token stream (the fleet router's `submit` path).
    pub(crate) fn from_gen(gref: GenRef) -> TokenRef {
        TokenRef { gref }
    }

    pub fn to_here(&self) -> anyhow::Result<i32> {
        match self.gref.next()? {
            Some(t) => Ok(t),
            None => Err(anyhow::anyhow!("generation finished without a token")),
        }
    }
}

/// Engine-side state of one live generation session, keyed by request id.
/// The evolving token sequence itself travels through the batcher queue as
/// a plain [`Request`]; this holds everything the collector needs to
/// decide continue-vs-finish and to stream results back.
struct Session {
    prompt_len: usize,
    max_new: usize,
    stop: Option<i32>,
    /// Original submission time — preserved across every re-enqueue so
    /// batcher timeouts and TTFT measure client-observed waiting.
    arrived: Instant,
    /// Completion time of the session's previous engine step (for
    /// per-token decode latency).
    last_at: Instant,
    gref: GenRef,
}

/// Bookkeeping for one in-flight batch.
struct Pending {
    rref: RRef,
    /// The batch rows (real requests only; bucket pad rows excluded).
    rows: Vec<Request>,
    /// Batcher-path batches carry session rows the collector must route;
    /// direct `infer_batch` rows never touch the session table.
    from_batcher: bool,
}

/// Collector-side context for speculative decode: present only when the
/// verify artifact family is live (so `Some` == "speculation on").
struct SpecShared {
    drafter: Arc<dyn Drafter>,
    /// Compiled verify window sizes (ascending, every k >= 2; lone
    /// sessions pad into the smallest compiled width for their k).
    ks: Vec<usize>,
    /// Draft sanitation: proposed ids are folded into [0, vocab).
    vocab: i32,
}

struct Shared {
    bus: CommandBus,
    tickets: TicketCounter,
    pending: Mutex<HashMap<u64, Pending>>,
    /// Live generation sessions, keyed by request id.
    sessions: Mutex<HashMap<u64, Session>>,
    metrics: Mutex<Recorder>,
    stopping: AtomicBool,
    /// Collector liveness: bumped once per worker reply processed. A
    /// fleet health probe reads this — a counter that stalls while
    /// batches are pending marks a wedged pipeline.
    ticks: AtomicU64,
    /// Incremental decode is live: sessions re-enter as decode steps and
    /// finished sessions' cache blocks are released by ticketed command.
    kv_on: bool,
    /// Speculative decode is live: continuations re-enter as drafted
    /// verify windows whenever a compiled k fits the session's remaining
    /// budget and context (plain decode otherwise).
    spec: Option<SpecShared>,
    /// Chunked prefill is live: compiled chunk window sizes (ascending,
    /// every k >= 2, capped by `engine.prefill_chunk`). Empty = off —
    /// prompts run the monolithic prefill path.
    chunk_ks: Vec<usize>,
    /// Prefill / chunk batches currently occupying workers, maintained by
    /// the dispatcher pool around each publish-and-wait.
    prefill_inflight: AtomicUsize,
    /// Total µs that formed decode/verify buckets spent waiting for a
    /// dispatcher slot while prompt work occupied the workers — the
    /// decode-starvation number chunked prefill exists to bound.
    decode_stall_us: AtomicU64,
    /// Cancellation inbox: ids pushed by [`GenRef::cancel`] (client side
    /// or server disconnect), drained by the former on every tick.
    cancels: Arc<Mutex<Vec<u64>>>,
    /// Cancelled sessions whose current step is in flight: evicted at the
    /// next collector boundary, so the ticketed K/V free always lands
    /// *after* that step's cache writes on every worker.
    doomed: Mutex<HashSet<u64>>,
}

impl Shared {
    /// The non-blocking launch (§4.2): take a ticket, register the rref,
    /// publish to every worker, return immediately. Takes the batch by
    /// value so the row token vectors move into `Pending` instead of being
    /// cloned per step (§Perf).
    fn publish(&self, fb: FormedBatch, from_batcher: bool) -> RRef {
        let mut input = fb.to_input();
        // only batcher sessions seed the cache; direct infer_batch rows
        // have no session lifecycle and must not leave blocks behind
        input.cache = self.kv_on && from_batcher && input.phase == Phase::Prefill;
        let input = std::sync::Arc::new(input);
        let uid = self.tickets.issue();
        let rref = RRef::new(uid);
        self.pending.lock().unwrap().insert(
            uid,
            Pending { rref: rref.clone(), rows: fb.requests, from_batcher },
        );
        self.bus.publish(uid, &input);
        rref
    }

    /// Free finished sessions' K/V blocks on every worker. Ticketed like a
    /// forward so the release drains through each worker's consistency
    /// queue *after* the session's final step (completion, stop token, or
    /// watchdog poison).
    fn release_sessions(&self, ids: Vec<u64>) {
        if self.kv_on && !ids.is_empty() {
            let uid = self.tickets.issue();
            self.bus.publish_release(uid, ids);
        }
    }

    /// Free *cancelled* sessions' K/V blocks on every worker. Same
    /// ticketed-after-the-last-step contract as [`Shared::release_sessions`],
    /// but published as a distinct `Cancel` command so workers (and fault
    /// plans / logs) can tell an abandonment from a natural completion.
    fn cancel_sessions(&self, ids: Vec<u64>) {
        if self.kv_on && !ids.is_empty() {
            let uid = self.tickets.issue();
            self.bus.publish_cancel(uid, ids);
        }
    }

    /// Publish the tier policy's spill/prefetch decisions, one ticket
    /// each, in decision order. Called by the batch former *before* it
    /// hands the formed batch to a dispatcher, so every tier command's
    /// ticket precedes the forward that depends on it — the consistency
    /// queue then guarantees residency without any worker backchannel.
    fn publish_tier(&self, cmds: Vec<TierCmd>) {
        for cmd in cmds {
            let uid = self.tickets.issue();
            match cmd {
                TierCmd::Spill(ids) => self.bus.publish_spill(uid, ids),
                TierCmd::Prefetch { ids, hint } => self.bus.publish_prefetch(uid, ids, hint),
                TierCmd::Park(ids) => self.bus.publish_park(uid, ids),
                TierCmd::Fetch { ids, hint } => self.bus.publish_fetch(uid, ids, hint),
            }
        }
    }

    /// Publish prefix-registry evictions decided by the trie's capacity
    /// cap (or a failure-path `prefix_drop`). Ticketed after any tier
    /// commands from the same `form()` pass, so on every worker the
    /// eviction lands after the last adoption that leased the entry.
    fn publish_prefix_evictions(&self, ids: Vec<u64>) {
        if self.kv_on && !ids.is_empty() {
            let uid = self.tickets.issue();
            self.bus.publish_evict(uid, ids);
        }
    }
}

/// The running system: workers + dispatcher pool + collector.
pub struct Engine {
    pub cfg: ModelConfig,
    pub launch: LaunchConfig,
    pub manifest: Arc<Manifest>,
    shared: Arc<Shared>,
    batcher: Arc<Mutex<Batcher>>,
    batch_signal: Sender<()>,
    next_req_id: std::sync::atomic::AtomicU64,
    workers: Vec<JoinHandle<()>>,
    service: Vec<JoinHandle<()>>,
}

impl Engine {
    /// Runtime initialization (§4.1.2): spawn one worker thread per device
    /// (each builds its own PJRT client, shards its layer range, compiles
    /// its variants), then start the dispatcher pool and collector.
    pub fn launch(launch: LaunchConfig) -> anyhow::Result<Engine> {
        // memoized parse: every engine (tests, benches, servers) shares
        // one parsed manifest per artifacts path (§Perf: manifest_parse_us)
        let manifest = Manifest::cached(crate::runtime::find_artifacts()?)?;
        let mut cfg = ModelConfig::preset(&launch.preset)
            .ok_or_else(|| anyhow::anyhow!("unknown preset {}", launch.preset))?;
        if let Some(n) = launch.n_layers {
            cfg.n_layers = n;
        }
        let par = launch.parallel;
        anyhow::ensure!(cfg.n_heads % par.tp == 0, "heads not divisible by tp");
        anyhow::ensure!(cfg.n_layers >= par.pp, "fewer layers than stages");
        anyhow::ensure!(
            !manifest.shape_points(&launch.preset).is_empty(),
            "no artifacts for preset {}; run `make artifacts`",
            launch.preset
        );
        // incremental decode goes live only when the whole decode family
        // is compiled for this (preset, tp); otherwise fall back to the
        // legacy re-prefill continuation path (old artifacts keep working)
        let decode_widths = if launch.engine.kv_cache && manifest.has_kv_prefill(&launch.preset, par.tp)
        {
            manifest.decode_widths(&launch.preset, par.tp)
        } else {
            Vec::new()
        };
        let kv_on = !decode_widths.is_empty();
        // speculative decode goes live only when incremental decode is,
        // the verify family is compiled, and pp == 1 (acceptance is
        // computed from the logits on the last stage, which under pp > 1
        // could not truncate earlier stages' caches without a worker
        // backchannel). Otherwise continuations stay plain decode steps.
        if launch.engine.speculative {
            anyhow::ensure!(
                launch.engine.spec_k >= 2,
                "engine.speculative requires engine.spec_k >= 2"
            );
        }
        let verify_points = if kv_on && launch.engine.speculative && par.pp == 1 {
            manifest.verify_points(&launch.preset, par.tp)
        } else {
            Vec::new()
        };
        // usable window sizes: capped by spec_k. Any compiled width can
        // host a lone session — the batcher pads a short run into the
        // smallest fitting width (verify pad rows clamp to one window),
        // exactly like decode buckets on presets with no width-1 point.
        let mut spec_ks: Vec<usize> = verify_points
            .iter()
            .filter(|&&(_, k)| k >= 2 && k <= launch.engine.spec_k)
            .map(|&(_, k)| k)
            .collect();
        spec_ks.sort_unstable();
        spec_ks.dedup();
        let verify_points: Vec<(usize, usize)> = verify_points
            .into_iter()
            .filter(|(_, k)| spec_ks.contains(k))
            .collect();
        let spec_on = !spec_ks.is_empty();
        // chunked prefill: prompt windows run the verify-family kernels
        // (a chunk window is a verify window whose "draft" is real prompt
        // tokens), so chunk points are the compiled verify points with k
        // capped by the knob. Unlike speculation this needs no acceptance
        // pass, so it is live under any pp. Empty — knob off or family
        // missing — silently keeps the monolithic prefill path.
        let chunk_points: Vec<(usize, usize)> = if kv_on && launch.engine.prefill_chunk >= 2 {
            manifest
                .verify_points(&launch.preset, par.tp)
                .into_iter()
                .filter(|&(_, k)| k >= 2 && k <= launch.engine.prefill_chunk)
                .collect()
        } else {
            Vec::new()
        };
        let mut chunk_ks: Vec<usize> = chunk_points.iter().map(|&(_, k)| k).collect();
        chunk_ks.sort_unstable();
        chunk_ks.dedup();
        // tiered KV cache: spill cold sessions to pooled host memory.
        // Engine-side policy + per-worker host tiers only exist when the
        // knob is on *and* incremental decode is live; otherwise the
        // resident-only fast path is untouched. Builder-path configs get
        // the same validation the TOML loader enforces — a bad spill
        // config is an Err here, not a silent no-op or a thread panic.
        if launch.engine.kv_spill {
            anyhow::ensure!(
                launch.engine.kv_device_blocks > 0,
                "engine.kv_spill requires engine.kv_device_blocks > 0"
            );
            anyhow::ensure!(
                launch.engine.kv_spill_low_water <= launch.engine.kv_spill_high_water
                    && launch.engine.kv_spill_high_water <= 1.0
                    && launch.engine.kv_spill_low_water >= 0.0,
                "kv spill water marks must satisfy 0 <= low <= high <= 1"
            );
        }
        anyhow::ensure!(
            launch.engine.kv_peer_blocks == 0 || launch.engine.kv_spill,
            "engine.kv_peer_blocks requires engine.kv_spill (the peer tier sits between device and host)"
        );
        let spill_on = kv_on && launch.engine.kv_spill;
        let peer_on = spill_on && launch.engine.kv_peer_blocks > 0;
        // chaos fault plan (empty spec parses to the no-fault default):
        // validated here so a bad spec is a launch error, not a worker
        // panic mid-traffic
        let faults = FaultPlan::parse(&launch.engine.fault_plan, launch.engine.fault_seed)?;

        let world = par.world_size();
        let (bus, cmd_rxs) = CommandBus::new(world);
        let act_mode = if launch.engine.blocking_comms { Mode::Blocking } else { Mode::NonBlocking };
        let coll_eps = CommWorld::new::<ChunkMsg>(world, Mode::NonBlocking);
        let act_eps = CommWorld::new::<ActMsg>(world, act_mode);
        // peer-tier parking ring (§4.4 PMEP): worker i parks into (i+1) %
        // world and holds images for (i-1) % world. Looped so the world=1
        // degenerate ring (self-parking over a buffered self-channel)
        // works; buffered so a park send never blocks the parker.
        let peer_eps: Vec<Option<crate::comm::channel::Endpoint<crate::memory::kvcache::PeerMsg>>> =
            if peer_on {
                CommWorld::new_looped(world, Mode::NonBlocking).into_iter().map(Some).collect()
            } else {
                (0..world).map(|_| None).collect()
            };
        let (reply_tx, reply_rx) = std::sync::mpsc::channel::<Reply>();

        // ---- workers -------------------------------------------------------
        let mut workers = Vec::with_capacity(world);
        let (ready_tx, ready_rx) = std::sync::mpsc::channel::<anyhow::Result<usize>>();
        let mut coll_it = coll_eps.into_iter();
        let mut act_it = act_eps.into_iter();
        let mut peer_it = peer_eps.into_iter();
        let mut cmd_it = cmd_rxs.into_iter();
        for stage in 0..par.pp {
            for tp_rank in 0..par.tp {
                let ctx = WorkerCtx {
                    preset: launch.preset.clone(),
                    cfg: cfg.clone(),
                    par,
                    stage,
                    tp_rank,
                    layers: par.stage_layers(stage, cfg.n_layers),
                    drce: launch.engine.drce,
                    consistency: launch.engine.consistency_queue,
                    lookahead: match &launch.memory {
                        MemoryMode::Pmep { pool, .. } => pool.lookahead.max(1),
                        _ => 1,
                    },
                    kv_cache: kv_on,
                    faults: faults.clone(),
                };
                // paged per-session K/V storage for this worker's layer
                // shard: width is hidden/tp (the shard's K or V row);
                // under spill the device slab is capped and a ledger-
                // accounted host tier sits behind it
                let kv_cfg = kv_on.then(|| {
                    let mut c = KvCacheConfig::new(
                        KV_BLOCK_POSITIONS,
                        ctx.layers.len(),
                        cfg.hidden / par.tp,
                    )
                    .with_device_id(ctx.device_id());
                    if spill_on {
                        // host_blocks == 0 means "unlimited" at the
                        // engine level; the worker tier encodes that as
                        // a saturating capacity
                        let host = match launch.engine.kv_host_blocks {
                            0 => usize::MAX,
                            n => n,
                        };
                        c = c
                            .with_device_capacity(launch.engine.kv_device_blocks)
                            .with_host_tier(host)
                            .with_peer_tier(launch.engine.kv_peer_blocks)
                            .with_copier(launch.engine.kv_copier);
                    }
                    c
                });
                let args = (
                    ctx,
                    manifest.clone(),
                    cfg.clone(),
                    launch.memory.clone(),
                    launch.seed,
                    launch.warmup,
                    kv_cfg,
                    coll_it.next().unwrap(),
                    act_it.next().unwrap(),
                    peer_it.next().unwrap(),
                    cmd_it.next().unwrap(),
                    reply_tx.clone(),
                );
                let ready_tx = ready_tx.clone();
                workers.push(std::thread::spawn(move || {
                    let (ctx, man, cfg, mem, seed, warm, kv_cfg, coll, act, peer, cmd, reply) = args;
                    let id = ctx.device_id();
                    match build_worker(ctx, man, cfg, mem, seed, warm, kv_cfg, coll, act, peer, cmd, reply) {
                        Ok(w) => {
                            let _ = ready_tx.send(Ok(id));
                            w.run()
                        }
                        Err(e) => {
                            let _ = ready_tx.send(Err(anyhow::anyhow!("worker {id} init: {e:#}")));
                        }
                    }
                }));
            }
        }
        drop(reply_tx); // collector exits once all workers hang up
        drop(ready_tx);
        // runtime initialization barrier (§4.1.2): wait until every worker
        // has built its device, sharded its weights and compiled its
        // variants — so first-request latency is a serving number, not a
        // compile number
        for _ in 0..world {
            match ready_rx.recv() {
                Ok(Ok(_)) => {}
                Ok(Err(e)) => return Err(e),
                Err(_) => anyhow::bail!("a worker died during initialization"),
            }
        }

        let mut recorder = Recorder::new();
        recorder.set_slo(
            Duration::from_millis(launch.engine.slo_ttft_ms),
            Duration::from_millis(launch.engine.slo_tpot_ms),
        );
        let shared = Arc::new(Shared {
            bus,
            tickets: TicketCounter::new(),
            pending: Mutex::new(HashMap::new()),
            sessions: Mutex::new(HashMap::new()),
            metrics: Mutex::new(recorder),
            stopping: AtomicBool::new(false),
            ticks: AtomicU64::new(0),
            kv_on,
            spec: spec_on.then(|| SpecShared {
                drafter: launch
                    .drafter
                    .clone()
                    .map(|d| d.0)
                    .unwrap_or_else(|| Arc::new(NGramDrafter::default())),
                ks: spec_ks,
                vocab: cfg.vocab as i32,
            }),
            chunk_ks,
            prefill_inflight: AtomicUsize::new(0),
            decode_stall_us: AtomicU64::new(0),
            cancels: Arc::new(Mutex::new(Vec::new())),
            doomed: Mutex::new(HashSet::new()),
        });

        // ---- batcher ---------------------------------------------------------
        let mut b = Batcher::new(
            manifest.shape_points(&launch.preset),
            launch.engine.max_batch,
            Duration::from_micros(launch.engine.batch_timeout_us),
        )
        .with_decode_widths(decode_widths)
        .with_verify_points(verify_points)
        .with_admission(launch.engine.max_queue_depth, launch.engine.admission_token_budget);
        if !chunk_points.is_empty() {
            b = b.with_chunked_prefill(chunk_points, launch.engine.chunk_decode_ratio);
        }
        if spill_on {
            // the engine-side residency model: form() becomes the
            // admission gate and spill/prefetch decision point
            let mut tcfg =
                TierConfig::new(launch.engine.kv_device_blocks, launch.engine.kv_host_blocks);
            tcfg.high_water = launch.engine.kv_spill_high_water;
            tcfg.low_water = launch.engine.kv_spill_low_water;
            if peer_on {
                // 0 stays two-tier byte-identical; the gate only ever
                // emits Park/Fetch when the peer budget is nonzero
                tcfg = tcfg.with_peer(launch.engine.kv_peer_blocks);
            }
            b = b.with_tier(TierPolicy::new(tcfg, KV_BLOCK_POSITIONS));
        }
        // shared-prefix reuse: admission-time trie matching only exists
        // when incremental decode is live (adoption replays through the
        // decode family). Off — the default — leaves every queue and
        // batch byte-identical to a build without the feature.
        let prefix_on = kv_on && launch.engine.prefix_cache;
        if prefix_on {
            b = b.with_prefix_cache(KV_BLOCK_POSITIONS, PREFIX_CACHE_MAX_ENTRIES);
        }
        let batcher = Arc::new(Mutex::new(b));
        let max_seq = batcher.lock().unwrap().max_seq();
        let (batch_signal, batch_rx) = std::sync::mpsc::channel::<()>();

        // ---- collector -------------------------------------------------------
        // The collector is itself a producer now: after every completed
        // engine step it re-enqueues unfinished sessions at the front of
        // the batcher queue (continuation batching), so decode steps from
        // different clients coalesce into shared buckets.
        let mut service = Vec::new();
        {
            let shared = shared.clone();
            let batcher = batcher.clone();
            let signal = batch_signal.clone();
            service.push(std::thread::spawn(move || {
                collector_loop(reply_rx, shared, batcher, signal, max_seq)
            }));
        }

        // ---- watchdog --------------------------------------------------------
        // A non-replier worker error drops the activation, so the replier
        // never sends and the batch's RRef would hang forever. The watchdog
        // fails such poisoned batches (and their sessions) after the
        // configured deadline instead of letting shutdown spin.
        {
            let shared = shared.clone();
            let batcher = batcher.clone();
            let deadline = Duration::from_millis(launch.engine.batch_deadline_ms.max(1));
            service.push(std::thread::spawn(move || watchdog_loop(shared, batcher, deadline)));
        }

        // ---- former + dispatcher pool (Fig. 5) -------------------------------
        // each formed batch carries its formation instant so dispatchers
        // can attribute decode queue-wait to concurrent prompt work
        let (fb_tx, fb_rx) = std::sync::mpsc::channel::<(FormedBatch, Instant)>();
        let fb_rx = Arc::new(Mutex::new(fb_rx));

        // former thread: turns the request queue into the batch list
        {
            let batcher = batcher.clone();
            let shared = shared.clone();
            service.push(std::thread::spawn(move || {
                let tick = Duration::from_micros(500);
                loop {
                    if shared.stopping.load(Ordering::SeqCst) {
                        break;
                    }
                    let _ = batch_rx.recv_timeout(tick);
                    // cancellations first: purging a dead client's queued
                    // step before forming means the batch it would have
                    // ridden in is never built, so no decode work is wasted
                    process_cancels(&shared, &batcher);
                    loop {
                        let (fb, tier_cmds, prefix_evicts) = {
                            let mut b = batcher.lock().unwrap();
                            let fb = b.form(Instant::now());
                            // tier cmds drained first: a spill of a stale
                            // registrant removes its trie entry, and that
                            // eviction must ride this same drain
                            (fb, b.take_tier_cmds(), b.take_prefix_evictions())
                        };
                        // tier commands are published here — before the
                        // batch reaches a dispatcher — so their tickets
                        // precede the forward's on every worker
                        if !tier_cmds.is_empty() {
                            shared.publish_tier(tier_cmds);
                        }
                        // prefix evictions after tier cmds: every adoption
                        // that leased the evicted entry has already been
                        // formed and its lease released, so its forward's
                        // ticket precedes this one
                        shared.publish_prefix_evictions(prefix_evicts);
                        match fb {
                            Some(fb) => {
                                if fb_tx.send((fb, Instant::now())).is_err() {
                                    return;
                                }
                            }
                            None => break,
                        }
                    }
                }
            }));
        }

        // dispatcher pool: N threads each take a formed batch, publish it
        // (non-blocking), then wait for completion — so the pool size is the
        // in-flight bound, exactly Fig. 5's thread-pool semantics.
        for _ in 0..launch.engine.pool_threads {
            let shared = shared.clone();
            let fb_rx = fb_rx.clone();
            service.push(std::thread::spawn(move || loop {
                let next = fb_rx.lock().unwrap().recv();
                match next {
                    Ok((fb, formed_at)) => {
                        // decode-stall attribution: a decode/verify bucket
                        // that waited for this slot while prompt work
                        // (prefill or chunk waves) occupied the workers
                        // charges its wait to `decode_stall_us` — the TPOT
                        // spike source chunked prefill exists to bound
                        let prompt_work = matches!(fb.phase, Phase::Prefill | Phase::Chunk);
                        if prompt_work {
                            shared.prefill_inflight.fetch_add(1, Ordering::SeqCst);
                        } else if shared.prefill_inflight.load(Ordering::SeqCst) > 0 {
                            let waited = formed_at.elapsed().as_micros() as u64;
                            shared.decode_stall_us.fetch_add(waited, Ordering::Relaxed);
                        }
                        let rref = shared.publish(fb, true);
                        let _ = rref.to_here(); // completion gates this slot
                        if prompt_work {
                            shared.prefill_inflight.fetch_sub(1, Ordering::SeqCst);
                        }
                    }
                    Err(_) => break,
                }
            }));
        }

        Ok(Engine {
            cfg,
            launch,
            manifest,
            shared,
            batcher,
            batch_signal,
            next_req_id: std::sync::atomic::AtomicU64::new(0),
            workers,
            service,
        })
    }

    /// Submit a pre-formed batch directly, bypassing the batcher (benches
    /// and examples that need exact shapes). Non-blocking.
    pub fn infer_batch(&self, requests: Vec<Request>) -> anyhow::Result<RRef> {
        anyhow::ensure!(!requests.is_empty(), "empty batch");
        let points = self.manifest.shape_points(&self.launch.preset);
        let n = requests.len();
        let max_len = requests.iter().map(Request::len).max().unwrap();
        let bucket = smallest_fitting_bucket(&points, n, max_len)
            .ok_or_else(|| anyhow::anyhow!("no compiled bucket fits ({n}, {max_len})"))?;
        let fb = FormedBatch { requests, bucket, phase: Phase::Prefill };
        Ok(self.shared.publish(fb, false))
    }

    /// Start a generation session through the dynamic batcher: the request
    /// enters the continuation queue, and after every completed engine step
    /// the collector streams the sampled token to the returned [`GenRef`]
    /// and re-enqueues the session until `max_new_tokens` are produced, the
    /// stop token appears, or the context reaches the longest compiled
    /// bucket. Non-blocking.
    pub fn generate_stream(&self, req: GenRequest) -> anyhow::Result<GenRef> {
        anyhow::ensure!(!req.tokens.is_empty(), "empty prompt");
        anyhow::ensure!(req.max_new_tokens >= 1, "max_new_tokens must be >= 1");
        let id = self.next_req_id.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
        let gref = GenRef::new(req.tokens.clone());
        gref.set_cancel_hook(id, Arc::downgrade(&self.shared.cancels));
        let now = Instant::now();
        // sustained SLO violations tighten the admission cap (shed early
        // rather than queue into latency collapse); the retry hint rides
        // any Busy reply so clients back off by observed service time
        // instead of guessing
        let (pressure, retry_hint) = {
            let m = self.shared.metrics.lock().unwrap();
            (m.under_pressure(), m.retry_after_hint_ms())
        };
        // graceful degradation before shedding: while under pressure,
        // clamp the token budget to the configured floor — a short
        // answer drains the queue faster than a busy error retries it
        let floor = self.launch.engine.pressure_max_new_tokens;
        let max_new = if pressure && floor > 0 && req.max_new_tokens > floor {
            self.shared.metrics.lock().unwrap().record_degraded();
            floor
        } else {
            req.max_new_tokens
        };
        self.shared.sessions.lock().unwrap().insert(
            id,
            Session {
                prompt_len: req.tokens.len(),
                max_new,
                stop: req.stop_token,
                arrived: now,
                last_at: now,
                gref: gref.clone(),
            },
        );
        if let Err(e) =
            self.batcher.lock().unwrap().admit(Request::new(id, req.tokens), now, pressure, retry_hint)
        {
            self.shared.sessions.lock().unwrap().remove(&id);
            if e.downcast_ref::<Busy>().is_some() {
                self.shared.metrics.lock().unwrap().record_shed();
            }
            return Err(e);
        }
        let _ = self.batch_signal.send(());
        Ok(gref)
    }

    /// Submit one request through the dynamic batcher. Returns a future
    /// for the request's next token (a one-token session).
    pub fn submit(&self, tokens: Vec<i32>) -> anyhow::Result<TokenRef> {
        Ok(TokenRef { gref: self.generate_stream(GenRequest::new(tokens, 1))? })
    }

    /// Greedy autoregressive generation: extend `prompt` by up to
    /// `n_tokens`, each step flowing through the shared continuation
    /// batcher. With the decode artifacts present, continuation steps are
    /// *incremental*: one position runs against the session's paged K/V
    /// cache instead of re-running the whole prefix (O(P+N) layer
    /// executions for N tokens over a P-token prompt, not O(N·(P+N))).
    /// Blocking wrapper over [`Engine::generate_stream`]; generation ends
    /// early once the context reaches the longest compiled bucket.
    pub fn generate(&self, prompt: Vec<i32>, n_tokens: usize) -> anyhow::Result<Vec<i32>> {
        if n_tokens == 0 {
            anyhow::ensure!(!prompt.is_empty(), "empty prompt");
            return Ok(prompt);
        }
        self.generate_stream(GenRequest::new(prompt, n_tokens))?.to_here()
    }

    /// Snapshot of serving metrics, with the process-wide activation-arena
    /// allocation counters (§Perf) and the paged-KV-cache pressure gauges
    /// (blocks in use / peak / recycled / slab bytes) folded in.
    pub fn metrics_snapshot(&self) -> Recorder {
        let mut r = self.shared.metrics.lock().unwrap().clone();
        r.record_arena(crate::memory::arena::ArenaPool::global_stats());
        r.record_kvcache(crate::memory::kvcache::global_stats());
        {
            let b = self.batcher.lock().unwrap();
            if b.prefix_enabled() {
                let (hits, misses) = b.prefix_hit_counts();
                r.record_prefix_index(hits, misses, b.cached_prefix_entries());
            }
        }
        r.record_decode_stall(self.shared.decode_stall_us.load(Ordering::Relaxed));
        r
    }

    /// Is incremental decode live (decode artifacts present + enabled)?
    pub fn kv_cache_on(&self) -> bool {
        self.shared.kv_on
    }

    /// Is speculative (draft-and-verify) decode live — knob on, verify
    /// artifacts present, KV cache live, pp == 1?
    pub fn speculative_on(&self) -> bool {
        self.shared.spec.is_some()
    }

    /// Compiled verify window sizes the engine may use (empty when
    /// speculation is off).
    pub fn spec_ks(&self) -> Vec<usize> {
        self.shared.spec.as_ref().map(|s| s.ks.clone()).unwrap_or_default()
    }

    /// Is the tiered (spill-to-host) K/V cache live?
    pub fn kv_spill_on(&self) -> bool {
        self.shared.kv_on
            && self.launch.engine.kv_spill
            && self.launch.engine.kv_device_blocks > 0
    }

    /// Is shared-prefix K/V reuse live — knob on + incremental decode live?
    pub fn prefix_cache_on(&self) -> bool {
        self.shared.kv_on && self.launch.engine.prefix_cache
    }

    /// Is chunked prefill live — `engine.prefill_chunk` admits at least
    /// one compiled window and the KV cache is live?
    pub fn chunked_prefill_on(&self) -> bool {
        !self.shared.chunk_ks.is_empty()
    }

    /// Compiled chunk window sizes (ascending; empty when chunking is off).
    pub fn chunk_ks(&self) -> Vec<usize> {
        self.shared.chunk_ks.clone()
    }

    pub fn pending_count(&self) -> usize {
        self.shared.pending.lock().unwrap().len()
    }

    /// Live generation sessions (queued or in flight).
    pub fn session_count(&self) -> usize {
        self.shared.sessions.lock().unwrap().len()
    }

    /// Collector liveness ticks: worker replies processed so far. A
    /// fleet health probe watches the delta — a counter that stalls
    /// while batches are pending marks a wedged pipeline.
    pub fn collector_ticks(&self) -> u64 {
        self.shared.ticks.load(Ordering::Relaxed)
    }

    /// Prefill requests waiting in the admission queue (placement
    /// pressure for the fleet router).
    pub fn queued_prefills(&self) -> usize {
        self.batcher.lock().unwrap().queued_prefills()
    }

    /// The rolling SLO window currently votes "shedding".
    pub fn under_pressure(&self) -> bool {
        self.shared.metrics.lock().unwrap().under_pressure()
    }

    /// `(device, host)` K/V blocks in use in the engine-side tier model
    /// (`None` without the spill tier) — the spill-aware half of fleet
    /// headroom scoring, and the drain verb's leak gauge.
    pub fn tier_usage(&self) -> Option<(usize, usize)> {
        self.batcher.lock().unwrap().tier().map(|t| (t.device_used(), t.host_used()))
    }

    /// Is the peer (park) tier live — spill on + a nonzero peer budget?
    pub fn kv_peer_on(&self) -> bool {
        self.kv_spill_on() && self.launch.engine.kv_peer_blocks > 0
    }

    /// K/V blocks parked in peer memory per the engine-side tier model
    /// (`None` without the spill tier) — the third leg of the drain
    /// verb's leak gauge.
    pub fn peer_usage(&self) -> Option<usize> {
        self.batcher.lock().unwrap().tier().map(|t| t.peer_used())
    }

    /// Orderly teardown: drain every live session and in-flight batch,
    /// stop services, shut workers down, join everything.
    pub fn shutdown(self) {
        // Drain: unfinished sessions re-enter the batcher after every
        // step, so keep the former ticking until the session table, the
        // queue, and the in-flight set are all empty. A poisoned batch
        // can't spin this forever — the watchdog fails it at the deadline.
        loop {
            let busy = self.session_count() > 0
                || self.pending_count() > 0
                || self.batcher.lock().unwrap().pending() > 0;
            if !busy {
                break;
            }
            let _ = self.batch_signal.send(());
            std::thread::sleep(Duration::from_millis(1));
        }
        self.shared.stopping.store(true, Ordering::SeqCst);
        self.shared.bus.shutdown();
        for w in self.workers {
            let _ = w.join();
        }
        // dropping Engine fields closes the batch list channel; dispatcher
        // and former threads exit, collector exits on worker hangup
        drop(self.batcher);
        drop(self.batch_signal);
        for s in self.service {
            let _ = s.join();
        }
    }
}

/// The collector: the completion half of the iteration-level scheduler.
/// For every finished batch it fulfils the batch `RRef`, streams each
/// row's sampled token to its session's `GenRef`, and re-enqueues
/// unfinished sessions at the front of the batcher queue — making the
/// collector a producer and closing the continuation loop.
fn collector_loop(
    reply_rx: Receiver<Reply>,
    shared: Arc<Shared>,
    batcher: Arc<Mutex<Batcher>>,
    signal: Sender<()>,
    max_seq: usize,
) {
    while let Ok((uid, result)) = reply_rx.recv() {
        // liveness tick for fleet health probes: every processed reply
        // advances this, whatever its verdict
        shared.ticks.fetch_add(1, Ordering::Relaxed);
        let entry = shared.pending.lock().unwrap().remove(&uid);
        let Pending { rref, rows, from_batcher } = match entry {
            Some(p) => p,
            None => continue, // expired by the watchdog; drop the late reply
        };
        let latency = rref.submitted_at.elapsed();
        match &result {
            Ok(out) => {
                shared.metrics.lock().unwrap().record_batch(latency, rows.len());
                if from_batcher {
                    let now = Instant::now();
                    // unfinished sessions staged for re-enqueue as
                    // (id, tokens, remaining budget, original arrival,
                    // adopted positions, continuation kind) — the
                    // continuation requests themselves (and with
                    // speculation on, the *drafting*, which may one day
                    // be a small-model forward) are built only after the
                    // sessions lock drops, so drafter cost never blocks
                    // submissions or other collector iterations
                    let mut staged: Vec<(u64, Vec<i32>, usize, Instant, usize, ContKind)> =
                        Vec::new();
                    // mid-chunk registrants cancelled before their
                    // retention boundary: their trie entries can never
                    // become ready and must be dropped, not marked ready
                    let mut dropped_prefixes: Vec<u64> = Vec::new();
                    // finished sessions whose worker-side K/V blocks can go
                    let mut released: Vec<u64> = Vec::new();
                    // cancelled mid-generation: evicted here, freed by a
                    // distinct ticketed Cancel command
                    let mut cancelled: Vec<u64> = Vec::new();
                    // (is_first, prefix_hit, latency) per emitted token,
                    // recorded after the sessions lock drops (one metrics
                    // lock per batch)
                    let mut token_lats: Vec<(bool, bool, Duration)> = Vec::new();
                    // prompt positions this batch computed (whole prompts
                    // for fresh prefills, one position per prompt-stepping
                    // decode row) — the work prefix reuse exists to avoid
                    let mut prefill_toks: u64 = 0;
                    // per verify row: (drafted, accepted, emitted)
                    let mut spec_rows: Vec<(u64, u64, u64)> = Vec::new();
                    {
                        let mut sessions = shared.sessions.lock().unwrap();
                        let mut doomed = shared.doomed.lock().unwrap();
                        for (i, row) in rows.into_iter().enumerate() {
                            // a session cancelled while this step was in
                            // flight is evicted at this boundary: its K/V
                            // free (ticketed below) lands after the step's
                            // cache writes on every worker, and the row's
                            // token is dropped (push_token is a no-op once
                            // the stream is terminal)
                            if doomed.remove(&row.id) {
                                if sessions.remove(&row.id).is_some() {
                                    cancelled.push(row.id);
                                    // a chunked registrant killed before
                                    // its crossing chunk leaves a forever-
                                    // unready trie entry behind
                                    if row.phase == Phase::Chunk && row.chunk_start < row.retain {
                                        dropped_prefixes.push(row.id);
                                    }
                                }
                                continue;
                            }
                            let sess = match sessions.get_mut(&row.id) {
                                Some(s) => s,
                                None => continue, // session already failed/expired
                            };
                            // the greedy tokens this engine step committed
                            // for the row: one for prefill / plain decode,
                            // `accepted + 1` for a verify pass
                            let committed: Vec<i32> = match row.phase {
                                Phase::Verify => match out.accepted.get(i) {
                                    Some(c) if !c.is_empty() => c.clone(),
                                    _ => {
                                        let sess = sessions.remove(&row.id).unwrap();
                                        sess.gref.finish(Err(anyhow::anyhow!(
                                            "verify batch {uid} returned no acceptance for row {i}"
                                        )));
                                        released.push(row.id);
                                        continue;
                                    }
                                },
                                _ => match out.next_tokens.get(i) {
                                    Some(&t) => vec![t],
                                    None => {
                                        let sess = sessions.remove(&row.id).unwrap();
                                        sess.gref.finish(Err(anyhow::anyhow!(
                                            "batch {uid} returned no token for row {i}"
                                        )));
                                        released.push(row.id);
                                        continue;
                                    }
                                },
                            };
                            // stream the committed tokens in order under
                            // exactly the per-token finish rules plain
                            // decode applies — budget, stop token and
                            // context limit truncate a verify window
                            // mid-flight the same way they would have
                            // ended a plain decode session, so speculation
                            // never changes a stream
                            let mut toks = row.tokens;
                            // prefill-equivalent work: a fresh prefill
                            // computes every prompt position; a decode row
                            // still at or below the prompt boundary (only
                            // possible for a prefix-cache hit) computes
                            // exactly one
                            if row.phase == Phase::Chunk {
                                prefill_toks += row.chunk_len as u64;
                            } else if row.phase == Phase::Prefill {
                                prefill_toks += toks.len() as u64;
                            } else if toks.len() <= sess.prompt_len {
                                prefill_toks += 1;
                            }
                            // mid-prompt chunk of a chunked prefill: the
                            // window seeded its K/V rows; the argmax is
                            // only meaningful once the final chunk covers
                            // the last prompt position, so earlier chunks
                            // discard it and emit nothing — TTFT keeps
                            // running until the last chunk's token
                            if row.phase == Phase::Chunk {
                                let end = row.chunk_start + row.chunk_len;
                                if end < sess.prompt_len {
                                    sess.last_at = now;
                                    staged.push((
                                        row.id,
                                        toks,
                                        sess.max_new,
                                        sess.arrived,
                                        row.adopted,
                                        ContKind::Chunk {
                                            start: end,
                                            retain: row.retain,
                                        },
                                    ));
                                    continue;
                                }
                                // final chunk: fall through — its argmax at
                                // the prompt boundary is the first token
                            }
                            // prompt-stepping row of a prefix-cache hit:
                            // every position before the last prompt token
                            // has a known successor, so the argmax computed
                            // here is discarded and the actual next prompt
                            // token is fed instead. Nothing is emitted;
                            // TTFT keeps running until the step at the
                            // prompt boundary samples the first real token.
                            if toks.len() < sess.prompt_len {
                                let next = sess.gref.prompt()[toks.len()];
                                toks.push(next);
                                sess.last_at = now;
                                staged.push((
                                    row.id,
                                    toks,
                                    sess.max_new,
                                    sess.arrived,
                                    row.adopted,
                                    ContKind::Stepping,
                                ));
                                continue;
                            }
                            let gap = now.duration_since(sess.last_at);
                            let m = committed.len() as u32;
                            let mut consumed = 0u64;
                            let mut finished = false;
                            for &tok in &committed {
                                let n_gen = toks.len() - sess.prompt_len;
                                if n_gen == 0 {
                                    token_lats.push((
                                        true,
                                        row.adopted > 0,
                                        now.duration_since(sess.arrived),
                                    ));
                                } else {
                                    // one engine step emitted m tokens:
                                    // attribute an equal share of the gap
                                    // to each so per-token percentiles
                                    // reflect the speculative speedup
                                    token_lats.push((false, false, gap / m));
                                }
                                sess.gref.push_token(tok);
                                toks.push(tok);
                                consumed += 1;
                                finished = n_gen + 1 >= sess.max_new
                                    || sess.stop == Some(tok)
                                    || toks.len() >= max_seq;
                                if finished {
                                    break;
                                }
                            }
                            sess.last_at = now;
                            if row.phase == Phase::Verify {
                                spec_rows.push((
                                    row.draft.len() as u64,
                                    (committed.len() - 1) as u64,
                                    consumed,
                                ));
                            }
                            if finished {
                                let sess = sessions.remove(&row.id).unwrap();
                                sess.gref.finish(Ok(()));
                                released.push(row.id);
                            } else {
                                // the session's token vector moves on into
                                // its continuation row — no clone
                                let remaining = sess.max_new - (toks.len() - sess.prompt_len);
                                staged.push((
                                    row.id,
                                    toks,
                                    remaining,
                                    sess.arrived,
                                    row.adopted,
                                    ContKind::Generate,
                                ));
                            }
                        }
                        // publish while the sessions lock is held: shutdown's
                        // drain must not observe an empty table before the
                        // release command is on every worker's queue
                        shared.release_sessions(released.clone());
                        shared.cancel_sessions(cancelled.clone());
                    }
                    if !token_lats.is_empty() || !spec_rows.is_empty() || prefill_toks > 0 {
                        let mut m = shared.metrics.lock().unwrap();
                        m.record_prefill_tokens(prefill_toks);
                        for (is_first, hit, lat) in token_lats {
                            if is_first {
                                m.record_first_token_prefix(lat, hit);
                            } else {
                                m.record_decode_token(lat);
                            }
                        }
                        for (drafted, accepted, emitted) in spec_rows {
                            m.record_spec(drafted, accepted, emitted);
                        }
                    }
                    // build the continuation steps (decode, or a drafted
                    // verify window when a compiled k fits the budget and
                    // context) outside every lock
                    let continuations: Vec<(Request, Instant)> = staged
                        .into_iter()
                        .map(|(id, toks, remaining, arrived, adopted, kind)| {
                            let req = match kind {
                                // mid-prompt step of a prefix hit: always a
                                // plain decode — a verify window would treat
                                // committed prompt tokens as sampled output
                                ContKind::Stepping => Request::decode(id, toks),
                                ContKind::Generate => continuation_request(
                                    shared.spec.as_ref(),
                                    shared.kv_on,
                                    id,
                                    toks,
                                    remaining,
                                    max_seq,
                                ),
                                ContKind::Chunk { start, retain } => chunk_continuation_request(
                                    &shared.chunk_ks,
                                    id,
                                    toks,
                                    start,
                                    retain,
                                ),
                            }
                            .with_adopted(adopted);
                            (req, arrived)
                        })
                        .collect();
                    if !continuations.is_empty() || !released.is_empty() || !cancelled.is_empty() {
                        let mut b = batcher.lock().unwrap();
                        // drop unfinished chunked registrations before
                        // tier_free could mark their partial entries ready
                        b.prefix_drop(&dropped_prefixes);
                        // tier model: freed sessions credit their blocks
                        // (freed capacity may admit a deferred prefill)
                        b.tier_free(&released);
                        b.tier_free(&cancelled);
                        // reversed so batch row order survives the
                        // front-pushes (decode priority); requeue_front
                        // also cold-marks each session in the tier model
                        for (r, arrived) in continuations.into_iter().rev() {
                            b.requeue_front(r, arrived);
                        }
                        drop(b);
                        let _ = signal.send(());
                    }
                }
            }
            Err(e) => {
                if from_batcher {
                    let mut released = Vec::new();
                    {
                        let mut sessions = shared.sessions.lock().unwrap();
                        let mut doomed = shared.doomed.lock().unwrap();
                        for row in &rows {
                            // a failed batch retires its doomed rows too
                            doomed.remove(&row.id);
                            if let Some(sess) = sessions.remove(&row.id) {
                                sess.gref.finish(Err(anyhow::anyhow!("{e}")));
                                released.push(row.id);
                            }
                        }
                        // under the lock — see the Ok branch
                        shared.release_sessions(released.clone());
                    }
                    if !released.is_empty() {
                        let mut b = batcher.lock().unwrap();
                        // a failed batch may be a registrant's prefill —
                        // its retention never ran, so the trie entry must
                        // go before tier_free could mark it ready
                        b.prefix_drop(&released);
                        b.tier_free(&released);
                        drop(b);
                        let _ = signal.send(());
                    }
                }
            }
        }
        rref.fulfil(result);
    }
}

/// Build the next continuation step for an unfinished session holding
/// `toks` committed tokens: a drafted verify window when speculation is
/// live and a compiled k fits both the remaining token budget and the
/// context (`valid = len + k - 1 <= max_seq`), otherwise a plain decode
/// step (or a legacy re-prefill without the cache). Drafts are sanitized
/// — folded into the vocabulary and padded/truncated to exactly k-1 — so
/// a sloppy [`Drafter`] can only lower the accept rate, never break a
/// batch.
fn continuation_request(
    spec: Option<&SpecShared>,
    kv_on: bool,
    id: u64,
    toks: Vec<i32>,
    remaining: usize,
    max_seq: usize,
) -> Request {
    if !kv_on {
        return Request::new(id, toks);
    }
    if let Some(sp) = spec {
        let n = toks.len();
        // the verify window occupies cache positions up to n + k - 2
        let room = (max_seq + 1).saturating_sub(n);
        if let Some(k) = sp.ks.iter().rev().copied().find(|&k| k <= remaining && k <= room) {
            let mut draft = sp.drafter.draft(&toks, k - 1);
            draft.truncate(k - 1);
            let fill = *draft.last().or(toks.last()).unwrap_or(&0);
            while draft.len() < k - 1 {
                draft.push(fill);
            }
            for t in draft.iter_mut() {
                *t = t.rem_euclid(sp.vocab.max(1));
            }
            return Request::verify(id, toks, draft);
        }
    }
    Request::decode(id, toks)
}

/// How an unfinished session re-enters the queue.
enum ContKind {
    /// mid-prompt step of a prefix-cache hit — stays a plain decode
    Stepping,
    /// normal generation continuation (decode, or a drafted verify
    /// window when one fits)
    Generate,
    /// next window of a chunked prefill, starting at `start` with the
    /// session's registered retention boundary threaded through
    Chunk { start: usize, retain: usize },
}

/// Build the continuation step for a chunked prefill whose next window
/// starts at `start`. Picks the largest compiled chunk window that fits
/// the remaining prompt; when none fits (tail shorter than the smallest
/// compiled k, only possible at `remaining == 1`) the session falls back
/// to prompt-stepping decode over the seeded prefix — the argmax at the
/// prompt's last position is the first real token either way.
fn chunk_continuation_request(
    chunk_ks: &[usize],
    id: u64,
    toks: Vec<i32>,
    start: usize,
    retain: usize,
) -> Request {
    let remaining = toks.len() - start;
    match chunk_ks.iter().rev().copied().find(|&k| k <= remaining) {
        Some(k) => {
            let mut r = Request::chunk(id, toks, start, k);
            r.retain = retain;
            r
        }
        None => {
            let mut r = Request::decode(id, toks[..start + 1].to_vec());
            // materialize the retention only if this very step crosses
            // the boundary (provably dead — retain never exceeds the
            // last full block below the prompt end — but kept so the
            // invariant is local, not an action at a distance)
            if retain > 0 && start + 1 == retain {
                r.retain = retain;
            }
            r
        }
    }
}

/// Drain the cancellation inbox (former tick). For every cancelled id:
/// if its next step is still *queued*, purge it from the batcher, drop
/// the session, and free its K/V blocks right away by ticketed `Cancel`
/// command (the ticket is issued after the session's last completed
/// step, so the consistency queue guarantees the free lands after its
/// writes). If its step is *in flight*, mark it doomed — the collector
/// evicts it at the batch boundary instead, because a free published now
/// could race the in-flight forward's cache writes on a worker that has
/// not executed the batch yet. Ids that match no live session (already
/// finished, failed, or expired) are dropped silently — cancel is a
/// no-op after the fact.
fn process_cancels(shared: &Shared, batcher: &Mutex<Batcher>) {
    let fresh: Vec<u64> = {
        let mut inbox = shared.cancels.lock().unwrap();
        if inbox.is_empty() {
            return;
        }
        std::mem::take(&mut *inbox)
    };
    let mut b = batcher.lock().unwrap();
    let mut purged: Vec<u64> = Vec::new();
    let mut n_cancelled = 0u64;
    {
        let mut sessions = shared.sessions.lock().unwrap();
        let mut doomed = shared.doomed.lock().unwrap();
        for id in fresh {
            if b.purge(id) {
                if sessions.remove(&id).is_some() {
                    n_cancelled += 1;
                }
                purged.push(id);
            } else if sessions.contains_key(&id) && doomed.insert(id) {
                n_cancelled += 1;
            }
        }
        // under the sessions lock, like every other release publication:
        // shutdown's drain must not observe an empty table before the
        // free is on every worker's queue
        shared.cancel_sessions(purged.clone());
    }
    // tier model: purged sessions' blocks (either tier) are free, and
    // their admission-ledger tokens retire
    b.tier_free(&purged);
    drop(b);
    if n_cancelled > 0 {
        shared.metrics.lock().unwrap().record_cancelled(n_cancelled);
    }
}

/// Watchdog: periodically fail in-flight batches older than `deadline`.
/// A non-replier worker error drops the activation, so the replier never
/// reports and the batch would otherwise hang its `RRef` (and `shutdown`
/// would busy-wait forever on `pending_count`).
fn watchdog_loop(shared: Arc<Shared>, batcher: Arc<Mutex<Batcher>>, deadline: Duration) {
    // short dozes keep shutdown responsive; the pending scan itself runs at
    // deadline/4 granularity (bounded to 1s) so the shared lock is touched
    // rarely relative to the hot path
    let doze = Duration::from_millis(5);
    let scan_every = (deadline / 4).clamp(Duration::from_millis(1), Duration::from_secs(1));
    let mut last_scan = Instant::now();
    let mut head: Option<(u64, Instant)> = None;
    while !shared.stopping.load(Ordering::SeqCst) {
        std::thread::sleep(doze);
        if last_scan.elapsed() >= scan_every {
            expire_stale(&shared, &batcher, deadline, &mut head);
            last_scan = Instant::now();
        }
    }
}

/// Fail the *head* pending batch (minimum ticket) once it has been head
/// for longer than `deadline`, and remove it — along with every other
/// pending batch whose publish age also exceeds the deadline, since a
/// timed-out head proves the pipeline is wedged (workers reply in ticket
/// order, so nothing queued behind a dead head can ever complete) and
/// those batches have already served their full wait behind it. Returns
/// how many batches were expired.
///
/// Only the head can *trigger* expiry: a batch queued behind an
/// in-flight one is merely waiting its turn, so its age since publish is
/// not by itself evidence of poisoning. The seed watchdog compared every
/// pending batch's publish-time age against the deadline, so a long
/// generation's re-enqueued continuations (or any dispatch backlog under
/// a short deadline) could be expired spuriously while the engine was
/// making perfectly healthy progress; `head` tracks (uid, promoted-at)
/// so the trigger clock only starts when a batch reaches the front of
/// the worker queues. The cascade keeps a genuinely poisoned backlog
/// draining in one scan (as before the fix) rather than one promotion
/// per deadline.
/// `gen_scheduler.rs::short_deadline_does_not_poison_long_generations`
/// is the regression test.
fn expire_stale(
    shared: &Shared,
    batcher: &Mutex<Batcher>,
    deadline: Duration,
    head: &mut Option<(u64, Instant)>,
) -> usize {
    let stale: Vec<(u64, Pending)> = {
        let mut pending = shared.pending.lock().unwrap();
        let oldest = pending.keys().copied().min();
        let uid = match oldest {
            None => {
                *head = None;
                return 0;
            }
            Some(uid) => uid,
        };
        if head.map(|(u, _)| u) != Some(uid) {
            // a new batch reached the front: its deadline starts now
            *head = Some((uid, Instant::now()));
        }
        let (_, since) = head.unwrap();
        if since.elapsed() > deadline {
            *head = None;
            // the head is wedged: take it plus the backlog that has
            // already waited a full deadline behind it
            let mut uids: Vec<u64> = pending
                .iter()
                .filter(|(&u, p)| u == uid || p.rref.submitted_at.elapsed() > deadline)
                .map(|(&u, _)| u)
                .collect();
            uids.sort_unstable();
            uids.into_iter().map(|u| (u, pending.remove(&u).unwrap())).collect()
        } else {
            Vec::new()
        }
    };
    let n = stale.len();
    for (uid, p) in stale {
        let msg = format!(
            "batch {uid} exceeded the {deadline:?} watchdog deadline \
             (a worker error likely dropped the activation)"
        );
        if p.from_batcher {
            let mut released = Vec::new();
            {
                let mut sessions = shared.sessions.lock().unwrap();
                let mut doomed = shared.doomed.lock().unwrap();
                for row in &p.rows {
                    // watchdog-killed sessions retire their doomed marks
                    doomed.remove(&row.id);
                    if let Some(sess) = sessions.remove(&row.id) {
                        sess.gref.finish(Err(anyhow::anyhow!("{msg}")));
                        released.push(row.id);
                    }
                }
                // poisoned sessions must not leak their cache blocks: workers
                // that survive still hold them until this ticketed release,
                // published under the sessions lock so shutdown's drain can't
                // race past an un-published release
                shared.release_sessions(released.clone());
            }
            // tier model: poisoned sessions' blocks (either tier) are free.
            // A poisoned registrant's prefill never completed, so its trie
            // entry is dropped rather than marked ready (a ready entry
            // with no worker-side retention would fail every adopter).
            if !released.is_empty() {
                let mut b = batcher.lock().unwrap();
                b.prefix_drop(&released);
                b.tier_free(&released);
            }
        }
        p.rref.fulfil(Err(anyhow::anyhow!("{msg}")));
    }
    n
}

#[allow(clippy::too_many_arguments)]
fn build_worker(
    ctx: WorkerCtx,
    manifest: Arc<Manifest>,
    cfg: ModelConfig,
    memory: MemoryMode,
    seed: u64,
    warmup: bool,
    kv_cfg: Option<KvCacheConfig>,
    coll_ep: crate::comm::channel::Endpoint<ChunkMsg>,
    act_ep: crate::comm::channel::Endpoint<ActMsg>,
    peer_ep: Option<crate::comm::channel::Endpoint<crate::memory::kvcache::PeerMsg>>,
    cmd_rx: std::sync::mpsc::Receiver<super::rpc::Command>,
    reply_tx: Sender<Reply>,
) -> anyhow::Result<Worker> {
    let device = Device::new(ctx.device_id())?;
    // every worker regenerates the (seeded) full weights and keeps only its
    // shard — simple, reproducible, and mirrors the paper's per-worker init
    let full = ModelWeights::random(&cfg, seed);
    let my_layers: Vec<_> = ctx
        .layers
        .clone()
        .map(|l| shard_layer(&cfg, &full.layers[l], ctx.par.tp, ctx.tp_rank))
        .collect();
    let provider: Box<dyn LayerProvider> = match memory {
        MemoryMode::Resident => Box::new(ResidentProvider::new(my_layers)),
        MemoryMode::Pmep { n_local, pool } => {
            let off = crate::memory::ledger::even_offload_placement(
                my_layers.len(),
                n_local.min(my_layers.len()),
            );
            Box::new(PooledProvider::new(my_layers, off, pool))
        }
        MemoryMode::Bminf { n_local } => {
            let off = crate::memory::ledger::even_offload_placement(
                my_layers.len(),
                n_local.min(my_layers.len()),
            );
            Box::new(PooledProvider::new(my_layers, off, PoolConfig::bminf()))
        }
    };
    let embed_weights = ctx.is_first_stage().then(|| full.embed_args());
    let logits_weights = ctx.is_last_stage().then(|| full.logits_args());

    if warmup {
        let t_buckets: Vec<usize> = manifest
            .by_kind(&ctx.preset, "drce_attn_shard")
            .filter(|v| v.tp == ctx.par.tp)
            .map(|v| v.t_bucket)
            .collect();
        let prefill_kinds = [
            "embed",
            "layer_full",
            "layer_full_kv",
            "logits",
            "attn_shard",
            "attn_shard_kv",
            "mlp_shard",
        ];
        for (b, s) in manifest.shape_points(&ctx.preset) {
            for kind in prefill_kinds {
                let tp = if kind.starts_with("attn_shard") || kind == "mlp_shard" {
                    ctx.par.tp
                } else {
                    1
                };
                let name = Manifest::name_of(&ctx.preset, kind, b, s, tp, 0);
                if let Ok(v) = manifest.get(&name) {
                    let _ = device.load(&manifest, v);
                }
            }
            if ctx.drce {
                for &t in &t_buckets {
                    for kind in ["drce_attn_shard", "mlp_shard"] {
                        let name = Manifest::name_of(&ctx.preset, kind, b, s, ctx.par.tp, t);
                        if let Ok(v) = manifest.get(&name) {
                            let _ = device.load(&manifest, v);
                        }
                    }
                }
            }
        }
        if ctx.kv_cache {
            for w in manifest.decode_widths(&ctx.preset, ctx.par.tp) {
                for (kind, seq) in [
                    ("embed_decode", 0),
                    ("layer_full_decode", 0),
                    ("attn_shard_decode", 0),
                    ("mlp_shard", 1),
                    ("logits", 1),
                ] {
                    let tp = if kind.starts_with("attn_shard") || kind == "mlp_shard" {
                        ctx.par.tp
                    } else {
                        1
                    };
                    let name = Manifest::name_of(&ctx.preset, kind, w, seq, tp, 0);
                    if let Ok(v) = manifest.get(&name) {
                        let _ = device.load(&manifest, v);
                    }
                }
            }
            for (w, k) in manifest.verify_points(&ctx.preset, ctx.par.tp) {
                for kind in [
                    "embed_verify",
                    "layer_full_verify",
                    "attn_shard_verify",
                    "mlp_shard",
                    "logits",
                ] {
                    let tp = if kind.starts_with("attn_shard") || kind == "mlp_shard" {
                        ctx.par.tp
                    } else {
                        1
                    };
                    let name = Manifest::name_of(&ctx.preset, kind, w, k, tp, 0);
                    if let Ok(v) = manifest.get(&name) {
                        let _ = device.load(&manifest, v);
                    }
                }
            }
        }
    }

    // paged (possibly three-tier) per-session K/V storage for this
    // worker's layer shard; the engine sized the config at launch
    let mut kv = kv_cfg.map(KvCache::new);
    if let (Some(kv), Some(ep)) = (kv.as_mut(), peer_ep) {
        // ring topology: park into the next rank, hold images for the
        // previous one; world == 1 degenerates to a buffered self-loop
        let (r, w) = (ep.rank, ep.world);
        kv.attach_peer_mesh(ep, (r + 1) % w, (r + w - 1) % w);
    }

    Ok(Worker {
        ctx,
        manifest,
        device,
        provider,
        embed_weights,
        logits_weights,
        cmd_rx,
        coll_ep,
        act_ep,
        reply_tx,
        weight_lits: Default::default(),
        embed_lits: None,
        logits_lits: None,
        kv,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn genref_streams_in_order() {
        let g = GenRef::new(vec![1, 2]);
        let g2 = g.clone();
        let h = std::thread::spawn(move || {
            let mut got = Vec::new();
            while let Some(t) = g2.next().unwrap() {
                got.push(t);
            }
            got
        });
        for t in [10, 11, 12] {
            g.push_token(t);
            std::thread::sleep(Duration::from_millis(1));
        }
        g.finish(Ok(()));
        assert_eq!(h.join().unwrap(), vec![10, 11, 12]);
        assert_eq!(g.to_here().unwrap(), vec![1, 2, 10, 11, 12]);
        assert_eq!(g.n_generated(), 3);
        assert!(g.is_done());
        assert_eq!(g.prompt(), &[1, 2]);
    }

    #[test]
    fn genref_drains_buffered_tokens_before_error() {
        let g = GenRef::new(vec![1]);
        g.push_token(5);
        g.finish(Err(anyhow::anyhow!("poisoned")));
        assert_eq!(g.next().unwrap(), Some(5));
        assert!(g.next().is_err());
        assert!(g.to_here().is_err());
    }

    fn test_shared() -> Shared {
        Shared {
            bus: CommandBus::new(1).0,
            tickets: TicketCounter::new(),
            pending: Mutex::new(HashMap::new()),
            sessions: Mutex::new(HashMap::new()),
            metrics: Mutex::new(Recorder::new()),
            stopping: AtomicBool::new(false),
            ticks: AtomicU64::new(0),
            kv_on: true,
            spec: None,
            chunk_ks: Vec::new(),
            prefill_inflight: AtomicUsize::new(0),
            decode_stall_us: AtomicU64::new(0),
            cancels: Arc::new(Mutex::new(Vec::new())),
            doomed: Mutex::new(HashSet::new()),
        }
    }

    fn test_session(gref: &GenRef, prompt_len: usize, max_new: usize) -> Session {
        let now = Instant::now();
        Session {
            prompt_len,
            max_new,
            stop: None,
            arrived: now,
            last_at: now,
            gref: gref.clone(),
        }
    }

    #[test]
    fn cancel_is_terminal_and_idempotent() {
        let g = GenRef::new(vec![1]);
        g.push_token(7);
        g.cancel();
        assert!(g.is_done());
        assert!(g.is_cancelled());
        // buffered tokens drain, then the cancelled error surfaces
        assert_eq!(g.next().unwrap(), Some(7));
        assert!(g.next().unwrap_err().to_string().contains("cancelled"));
        // late collector traffic is dropped, a second cancel is a no-op
        g.push_token(8);
        g.finish(Ok(()));
        g.cancel();
        assert_eq!(g.n_generated(), 1);
        assert!(g.is_cancelled());
        // cancel after natural completion does not rewrite the verdict
        let done = GenRef::new(vec![1]);
        done.finish(Ok(()));
        done.cancel();
        assert!(!done.is_cancelled());
        assert!(done.to_here().is_ok());
    }

    #[test]
    fn cancel_routes_through_the_hook_once() {
        let inbox = Arc::new(Mutex::new(Vec::new()));
        let g = GenRef::new(vec![1]);
        g.set_cancel_hook(42, Arc::downgrade(&inbox));
        g.cancel();
        g.cancel();
        assert_eq!(*inbox.lock().unwrap(), vec![42]);
        // a hook outliving its engine is a silent no-op
        let g2 = GenRef::new(vec![1]);
        g2.set_cancel_hook(43, Arc::downgrade(&inbox));
        drop(inbox);
        g2.cancel();
        assert!(g2.is_cancelled());
    }

    /// A cancelled session whose step is queued is purged immediately
    /// (session dropped, ledger retired); one whose step is in flight is
    /// doomed and evicted at the next collector boundary instead.
    #[test]
    fn process_cancels_purges_queued_and_dooms_in_flight() {
        let shared = test_shared();
        let batcher = Mutex::new(Batcher::new(vec![(4, 16)], 4, Duration::from_millis(10)));
        let queued = GenRef::new(vec![1, 2]);
        let inflight = GenRef::new(vec![3, 4]);
        {
            let mut sessions = shared.sessions.lock().unwrap();
            sessions.insert(1, test_session(&queued, 2, 4));
            sessions.insert(2, test_session(&inflight, 2, 4));
        }
        // session 1 queued; session 2's step rides an in-flight batch
        batcher.lock().unwrap().push_at(Request::new(1, vec![1, 2]), Instant::now()).unwrap();
        queued.cancel();
        inflight.cancel();
        {
            let mut inbox = shared.cancels.lock().unwrap();
            inbox.push(1);
            inbox.push(2);
        }
        process_cancels(&shared, &batcher);
        assert_eq!(batcher.lock().unwrap().pending(), 0, "queued step purged");
        let sessions = shared.sessions.lock().unwrap();
        assert!(!sessions.contains_key(&1), "purged session dropped");
        assert!(sessions.contains_key(&2), "in-flight session waits for the boundary");
        drop(sessions);
        assert!(shared.doomed.lock().unwrap().contains(&2));
        assert_eq!(shared.metrics.lock().unwrap().cancelled(), 2);
        // an id matching no live session is dropped silently
        shared.cancels.lock().unwrap().push(99);
        process_cancels(&shared, &batcher);
        assert_eq!(shared.metrics.lock().unwrap().cancelled(), 2);
        assert!(shared.doomed.lock().unwrap().contains(&2));
    }

    /// The watchdog head-cascade crossed with the spill tier (satellite):
    /// a poisoned batch whose sessions live on *different tiers* — one
    /// device-resident, one spilled to host — must credit `tier_free`
    /// exactly once per session: both tiers drain to zero, and a repeat
    /// scan (or a late reply, which gates on the now-empty sessions map)
    /// cannot double-credit.
    #[test]
    fn watchdog_cascade_credits_spilled_sessions_exactly_once() {
        let shared = test_shared();
        let g9 = GenRef::new(vec![1, 2]);
        let g10 = GenRef::new(vec![3, 4]);
        {
            let mut sessions = shared.sessions.lock().unwrap();
            sessions.insert(9, test_session(&g9, 2, 4));
            sessions.insert(10, test_session(&g10, 2, 4));
        }
        let rref = RRef::new(0);
        shared.pending.lock().unwrap().insert(
            0,
            Pending {
                rref: rref.clone(),
                rows: vec![Request::decode(9, vec![1, 2]), Request::decode(10, vec![3, 4])],
                from_batcher: true,
            },
        );
        // a one-block device tier: admitting 10 spills cold 9 to host
        let batcher = Mutex::new(
            Batcher::new(vec![(1, 16)], 4, Duration::from_millis(10))
                .with_decode_widths(vec![1])
                .with_tier(TierPolicy::new(TierConfig::new(1, 8), 8)),
        );
        {
            let mut b = batcher.lock().unwrap();
            let t = b.tier_mut().unwrap();
            t.gate_decode(&[(9, 2)]);
            t.on_requeue(9);
            t.gate_decode(&[(10, 4)]);
            assert_eq!(t.is_resident(9), Some(false), "9 spilled to host");
            assert_eq!(t.is_resident(10), Some(true), "10 on device");
            assert_eq!(t.session_count(), 2);
            assert!(t.host_used() > 0);
        }
        let mut head = None;
        assert_eq!(expire_stale(&shared, &batcher, Duration::from_secs(3600), &mut head), 0);
        std::thread::sleep(Duration::from_millis(2));
        assert_eq!(expire_stale(&shared, &batcher, Duration::ZERO, &mut head), 1);
        {
            let b = batcher.lock().unwrap();
            let t = b.tier().unwrap();
            assert_eq!(t.session_count(), 0, "both sessions credited");
            assert_eq!(t.device_used(), 0, "device tier drained");
            assert_eq!(t.host_used(), 0, "host tier drained");
        }
        assert!(rref.to_here().is_err());
        assert!(g9.to_here().is_err());
        assert!(g10.to_here().is_err());
        assert!(shared.sessions.lock().unwrap().is_empty());
        // exactly once: a repeat scan finds nothing to credit and the
        // tier gauges stay at zero (no double free, no underflow)
        assert_eq!(expire_stale(&shared, &batcher, Duration::ZERO, &mut head), 0);
        let b = batcher.lock().unwrap();
        assert_eq!(b.tier().unwrap().session_count(), 0);
        assert_eq!(b.tier().unwrap().host_used(), 0);
    }

    #[test]
    fn watchdog_expires_poisoned_batches_and_their_sessions() {
        let shared = test_shared();
        let gref = GenRef::new(vec![1, 2]);
        let now = Instant::now();
        shared.sessions.lock().unwrap().insert(
            9,
            Session {
                prompt_len: 2,
                max_new: 4,
                stop: None,
                arrived: now,
                last_at: now,
                gref: gref.clone(),
            },
        );
        let rref = RRef::new(0);
        shared.pending.lock().unwrap().insert(
            0,
            Pending {
                rref: rref.clone(),
                rows: vec![Request::new(9, vec![1, 2])],
                from_batcher: true,
            },
        );
        let batcher = Mutex::new(
            Batcher::new(vec![(1, 16)], 4, Duration::from_millis(10))
                .with_tier(TierPolicy::new(TierConfig::new(8, 8), 8)),
        );
        // the tier model learns of the session via its decode gate
        batcher.lock().unwrap().tier_mut().unwrap().gate_decode(&[(9, 2)]);
        assert_eq!(batcher.lock().unwrap().tier().unwrap().session_count(), 1);
        // under a generous deadline nothing expires (this scan also
        // promotes the batch to watchdog head, starting its clock)
        let mut head = None;
        assert_eq!(expire_stale(&shared, &batcher, Duration::from_secs(3600), &mut head), 0);
        assert!(!rref.is_ready());
        // at a zero deadline the head batch is poisoned: the RRef errors
        // instead of hanging, and the session's stream fails
        std::thread::sleep(Duration::from_millis(2));
        assert_eq!(expire_stale(&shared, &batcher, Duration::ZERO, &mut head), 1);
        // the poisoned session's blocks were credited in the tier model
        assert_eq!(batcher.lock().unwrap().tier().unwrap().session_count(), 0);
        assert_eq!(batcher.lock().unwrap().tier().unwrap().device_used(), 0);
        assert!(rref.to_here().is_err());
        assert!(gref.to_here().is_err());
        assert!(shared.sessions.lock().unwrap().is_empty());
        assert!(shared.pending.lock().unwrap().is_empty());
    }

    /// The satellite fix: only the *head* batch (minimum ticket) can
    /// trigger expiry, and its clock starts at promotion — a batch queued
    /// behind an in-flight one is waiting its turn, not poisoned, no
    /// matter how long ago it was published. The seed compared every
    /// pending batch's publish age to the deadline, so a dispatch backlog
    /// under a short deadline (e.g. a long generation's continuation
    /// steps) died spuriously. Once a head *does* time out, though, the
    /// pipeline is provably wedged and the backlog that already waited a
    /// full deadline behind it cascades in the same scan.
    #[test]
    fn watchdog_expiry_is_head_triggered_with_cascade() {
        let shared = test_shared();
        let batcher = Mutex::new(Batcher::new(vec![(1, 16)], 4, Duration::from_millis(10)));
        let insert = |uid: u64| {
            let rref = RRef::new(uid);
            shared.pending.lock().unwrap().insert(
                uid,
                Pending {
                    rref: rref.clone(),
                    rows: vec![Request::new(100 + uid, vec![1, 2])],
                    from_batcher: false,
                },
            );
            rref
        };
        let refs: Vec<RRef> = (0..3u64).map(insert).collect();
        std::thread::sleep(Duration::from_millis(3));
        // every batch's *publish* age now exceeds a 1ms deadline, but the
        // first scan only promotes batch 0 to head (clock starts fresh):
        // nothing expires — this is the spurious-kill fix
        let mut head = None;
        let deadline = Duration::from_millis(1);
        assert_eq!(expire_stale(&shared, &batcher, deadline, &mut head), 0);
        assert_eq!(shared.pending.lock().unwrap().len(), 3);
        // once the head has been head for > deadline the pipeline is
        // wedged: it expires together with the old backlog in one scan,
        // but a batch published *after* the head wedged must not cascade
        std::thread::sleep(Duration::from_millis(3));
        let fresh = insert(3);
        let expired = expire_stale(&shared, &batcher, deadline, &mut head);
        assert!(refs.iter().all(RRef::is_ready), "wedged backlog must fail in one scan");
        if expired == 3 {
            assert!(!fresh.is_ready(), "a batch younger than the deadline must survive");
            // the survivor is promoted with a fresh clock and only dies
            // after its own grace period
            assert_eq!(expire_stale(&shared, &batcher, deadline, &mut head), 0);
            std::thread::sleep(Duration::from_millis(3));
            assert_eq!(expire_stale(&shared, &batcher, deadline, &mut head), 1);
            assert!(fresh.is_ready());
        } else {
            // timing slop: the 'fresh' batch aged past the 1ms deadline
            // before the scan evaluated it, so it cascaded too
            assert_eq!(expired, 4);
            assert!(fresh.is_ready());
        }
        assert!(shared.pending.lock().unwrap().is_empty());
        // an empty pending set clears the head tracker
        assert_eq!(expire_stale(&shared, &batcher, deadline, &mut head), 0);
        assert!(head.is_none());
    }

    #[test]
    fn continuation_request_picks_fitting_windows() {
        let spec = SpecShared {
            drafter: Arc::new(NGramDrafter::default()),
            ks: vec![2, 4],
            vocab: 100,
        };
        // plenty of budget and room: the largest k (4) wins, k-1 drafts
        let r = continuation_request(Some(&spec), true, 7, vec![5, 6, 5, 6], 10, 32);
        assert_eq!(r.phase, Phase::Verify);
        assert_eq!(r.window(), 4);
        assert_eq!(r.draft.len(), 3);
        assert!(r.draft.iter().all(|t| (0..100).contains(t)));
        // remaining budget 3: k=4 would overshoot, k=2 fits
        let r = continuation_request(Some(&spec), true, 7, vec![5, 6, 5], 3, 32);
        assert_eq!(r.window(), 2);
        // remaining budget 1: no k >= 2 fits -> plain decode
        let r = continuation_request(Some(&spec), true, 7, vec![5, 6], 1, 32);
        assert_eq!(r.phase, Phase::Decode);
        // context nearly full (n = max_seq - 1 => room = 2): k=2 only
        let toks: Vec<i32> = (0..31).collect();
        let r = continuation_request(Some(&spec), true, 7, toks, 10, 32);
        assert_eq!(r.window(), 2);
        // context full to the brim (n = max_seq => room = 1): decode
        let toks: Vec<i32> = (0..32).collect();
        let r = continuation_request(Some(&spec), true, 7, toks, 10, 32);
        assert_eq!(r.phase, Phase::Decode);
        // speculation off / cache off
        let r = continuation_request(None, true, 7, vec![1], 10, 32);
        assert_eq!(r.phase, Phase::Decode);
        let r = continuation_request(None, false, 7, vec![1], 10, 32);
        assert_eq!(r.phase, Phase::Prefill);
    }

    #[test]
    fn chunk_continuation_picks_windows_and_falls_back_to_stepping() {
        let ks = vec![2usize, 4];
        let toks: Vec<i32> = (0..11).collect();
        // 4 seeded of 11: remaining 7 -> largest window 4, retain rides along
        let r = chunk_continuation_request(&ks, 9, toks.clone(), 4, 8);
        assert_eq!(r.phase, Phase::Chunk);
        assert_eq!((r.chunk_start, r.chunk_len, r.retain), (4, 4, 8));
        assert_eq!(r.window(), 4);
        assert_eq!(r.cache_len(), 11);
        // 8 seeded: remaining 3 -> window 2 fits
        let r = chunk_continuation_request(&ks, 9, toks.clone(), 8, 8);
        assert_eq!((r.chunk_start, r.chunk_len), (8, 2));
        // 10 seeded: remaining 1, no k fits -> prompt-stepping decode over
        // the seeded prefix; tokens truncate to the next unseeded position
        let r = chunk_continuation_request(&ks, 9, toks, 10, 8);
        assert_eq!(r.phase, Phase::Decode);
        assert_eq!(r.tokens.len(), 11);
        assert_eq!(r.retain, 0, "boundary already crossed by an earlier chunk");
    }
}
