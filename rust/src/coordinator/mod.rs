//! The hierarchy-controller coordinator — the paper's system contribution.
//!
//! * Single-controller half: [`engine::Engine`] owns initialization and
//!   task launch, publishing commands over the [`rpc::CommandBus`].
//! * Multi-controller half: [`worker::Worker`]s execute SPMD, moving
//!   tensors among themselves (TP all-reduce, pipeline hand-offs) without
//!   engine involvement.
//! * NBPP (§4.2) is the combination of: the dispatcher pool's non-blocking
//!   launches, buffered (non-rendezvous) activation channels, and the
//!   [`consistency`] queue that makes out-of-order arrival safe. The
//!   FasterTransformer-style baseline flips the channels to blocking
//!   rendezvous (`EngineConfig::blocking_comms`).
//! * DRCE (§4.3) rides on the commands: the engine binds per-sequence
//!   valid lengths; workers deterministically pick the packed bucket.

pub mod batcher;
pub mod consistency;
pub mod drafter;
pub mod engine;
pub mod fault;
pub mod fleet;
pub mod rpc;
pub mod worker;

pub use batcher::{smallest_fitting_bucket, Batcher, Busy, Request};
pub use fault::{FaultKind, FaultPlan};
pub use consistency::{ConsistencyQueue, TicketCounter};
pub use drafter::{Drafter, DrafterHandle, MisdraftDrafter, NGramDrafter, ReplayDrafter};
pub use engine::{Engine, GenRef, GenRequest, LaunchConfig, MemoryMode, TokenRef};
pub use fleet::{DrainReport, Fleet, ReplicaState};
pub use rpc::{BatchInput, BatchOutput, Phase, RRef};
