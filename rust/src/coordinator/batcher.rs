//! Dynamic batcher: the engine-side queue that turns a stream of
//! variable-length requests into padded batches at the AOT shape points.
//!
//! The paper's engine keeps a "batch list" a thread pool fetches from
//! (§4.2, Fig. 5); this module produces that list. Requests are packed
//! greedily up to `max_batch` or until `batch_timeout` expires, then padded
//! into the smallest compiled (batch, seq) bucket that fits — AOT shapes
//! are static, so bucketing is the standard trick (DESIGN.md).
//!
//! With iteration-level scheduling the queue has two producers: new
//! arrivals enter at the back (`push`), while unfinished generation
//! sessions re-enter at the *front* (`requeue_front`) after every engine
//! step, carrying their original arrival timestamp. Decode steps therefore
//! take priority over fresh prefills and coalesce with each other into
//! shared buckets, Orca-style.
//!
//! Prefill and decode are **distinct bucket kinds**: a prefill batch pads
//! whole prompts into a compiled (batch, seq) point, while a decode batch
//! is a width-only bucket of single-position steps — one newest token per
//! row, executed against each session's paged K/V cache. `form` never
//! mixes the two; it batches the longest same-phase run at the queue
//! front (continuations re-enter front-first together, so concurrent
//! decodes still coalesce).

use super::rpc::{BatchInput, Phase};
use crate::memory::kvcache::prefix::PrefixIndex;
use crate::memory::kvcache::tier::{TierCmd, TierPolicy};
use crate::tensor::IntTensor;
use std::collections::{HashMap, VecDeque};
use std::time::{Duration, Instant};

/// Structured load-shed rejection: the admission gate refused a new
/// request instead of queueing it unboundedly. Carried through
/// `anyhow::Error` so callers (the engine, then the server) can downcast
/// and answer the client with a `busy` line rather than a hard error.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Busy {
    /// Which gate fired: `"queue-full"` (depth cap) or `"slo-pressure"`
    /// (depth cap tightened by SLO violations).
    pub reason: &'static str,
    /// Prefill requests queued at the moment of rejection.
    pub queued: usize,
    /// Client back-off hint in milliseconds, derived from the Recorder's
    /// rolling SLO window at rejection time (0 = no estimate, retry at
    /// will). Carried to the server's `busy` reply so well-behaved
    /// clients pace their retries instead of hammering a hot gate.
    pub retry_after_ms: u64,
}

impl std::fmt::Display for Busy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "busy ({}): {} prefills queued, retry after {} ms",
            self.reason, self.queued, self.retry_after_ms
        )
    }
}

impl std::error::Error for Busy {}

/// One inference request: a token sequence, tagged with the engine step
/// kind it needs next (a fresh prompt prefills; a cached continuation
/// decodes its newest token only; a speculative continuation verifies its
/// newest token plus a drafted window in one pass).
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub phase: Phase,
    /// Verify steps only: the drafted candidate tokens following the
    /// newest committed token — the verify window is `[last committed,
    /// draft...]`, so its size is `draft.len() + 1`. Empty otherwise.
    pub draft: Vec<i32>,
    /// First step of a shared-prefix hit: adopt `(registrant id,
    /// positions)` from every worker's prefix registry before this row
    /// executes. Set only on the stepping decode `form` converts a
    /// matched prefill into; continuations never carry it.
    pub adopt: Option<(u64, usize)>,
    /// Positions this prefill retains into the worker prefix registries
    /// right after it executes (0 = none; always block-aligned). Set by
    /// the admission pass when the prompt registers as a future donor.
    pub retain: usize,
    /// Cached positions this session adopted at admission — persisted
    /// through every continuation so the token budget meters only the
    /// computed suffix and metrics can attribute TTFT to the hit path.
    pub adopted: usize,
    /// Chunked-prefill steps only: prompt positions already seeded into
    /// the session's K/V cache before this step (adopted prefix included).
    /// This step computes `tokens[chunk_start .. chunk_start + chunk_len]`.
    pub chunk_start: usize,
    /// Chunked-prefill steps only: the window size of this step. Zero for
    /// every other phase.
    pub chunk_len: usize,
}

impl Request {
    pub fn new(id: u64, tokens: Vec<i32>) -> Request {
        Request {
            id,
            tokens,
            phase: Phase::Prefill,
            draft: Vec::new(),
            adopt: None,
            retain: 0,
            adopted: 0,
            chunk_start: 0,
            chunk_len: 0,
        }
    }

    /// A continuation step of a cached session: `tokens` is the full
    /// evolving sequence (the collector and length bookkeeping need it),
    /// but only the last token enters the decode batch.
    pub fn decode(id: u64, tokens: Vec<i32>) -> Request {
        Request {
            id,
            tokens,
            phase: Phase::Decode,
            draft: Vec::new(),
            adopt: None,
            retain: 0,
            adopted: 0,
            chunk_start: 0,
            chunk_len: 0,
        }
    }

    /// A speculative continuation step: the last committed token plus
    /// `draft` enter the verify batch as a `draft.len() + 1`-token window.
    pub fn verify(id: u64, tokens: Vec<i32>, draft: Vec<i32>) -> Request {
        debug_assert!(!draft.is_empty(), "a verify step needs at least one drafted token");
        Request {
            id,
            tokens,
            phase: Phase::Verify,
            draft,
            adopt: None,
            retain: 0,
            adopted: 0,
            chunk_start: 0,
            chunk_len: 0,
        }
    }

    /// One chunked-prefill step: `tokens` is the *full* prompt (so tier
    /// and ledger accounting charge the final cache length from the first
    /// chunk), and this step seeds positions `start .. start + len` of it
    /// into the session's K/V cache.
    pub fn chunk(id: u64, tokens: Vec<i32>, start: usize, len: usize) -> Request {
        debug_assert!(len >= 2, "a chunk window needs at least two positions");
        debug_assert!(start + len <= tokens.len(), "chunk window past the prompt end");
        Request {
            id,
            tokens,
            phase: Phase::Chunk,
            draft: Vec::new(),
            adopt: None,
            retain: 0,
            adopted: 0,
            chunk_start: start,
            chunk_len: len,
        }
    }

    /// Tag a continuation with the positions its session adopted at
    /// admission (see [`Request::adopted`]).
    pub fn with_adopted(mut self, n: usize) -> Request {
        self.adopted = n;
        self
    }

    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    /// Window size this request's engine step scores: the drafted tokens
    /// plus the newest committed one (1 for plain decode / prefill), or
    /// the chunk length for a chunked-prefill step.
    pub fn window(&self) -> usize {
        if self.phase == Phase::Chunk {
            self.chunk_len
        } else {
            self.draft.len() + 1
        }
    }

    /// Chunked-prefill steps: whether this is the session's *first* chunk
    /// (nothing beyond an adopted prefix is cached yet). First chunks
    /// admit like prefills — token budget and tier gate both meter them —
    /// while continuations are exempt, like decode steps.
    pub fn is_first_chunk(&self) -> bool {
        self.chunk_start == self.adopted
    }

    /// Positions the session's K/V cache will hold right after this step
    /// (speculative rows included) — what tier-capacity checks must use.
    pub fn cache_len(&self) -> usize {
        self.tokens.len() + self.draft.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }
}

/// Smallest compiled (batch, seq) bucket fitting `n` rows of up to
/// `max_len` tokens — the one selection rule shared by the dynamic batcher
/// and the engine's direct `infer_batch` path.
pub fn smallest_fitting_bucket(
    points: &[(usize, usize)],
    n: usize,
    max_len: usize,
) -> Option<(usize, usize)> {
    points
        .iter()
        .copied()
        .filter(|&(b, s)| b >= n && s >= max_len)
        .min_by_key(|&(b, s)| b * s)
}

/// A formed batch: requests + the bucket it was padded into. Decode
/// batches use a width-only bucket `(w, 1)`.
#[derive(Clone, Debug)]
pub struct FormedBatch {
    pub requests: Vec<Request>,
    pub bucket: (usize, usize), // (batch, seq)
    pub phase: Phase,
}

impl FormedBatch {
    /// Materialize the padded id tensor + valid-length metadata.
    ///
    /// Prefill: the usual (batch, seq) padded prompt tensor. Decode: a
    /// (batch, 1) tensor of each session's newest token, with
    /// `valid_lens` carrying the *total* session length (the decode
    /// variants attend over `valid_len` cache positions and place the new
    /// K/V row at `valid_len - 1`). Verify: a (batch, k) tensor holding
    /// each session's newest token followed by its k-1 drafted tokens,
    /// with `valid_lens` counting the whole window (`len + k - 1`) — the
    /// verify variants place the window's K/V rows at positions
    /// `valid_len - k ..= valid_len - 1` with causal masking inside it.
    pub fn to_input(&self) -> BatchInput {
        let (b, s) = self.bucket;
        let mut ids = vec![0i32; b * s];
        let mut valid = Vec::with_capacity(b);
        for (i, r) in self.requests.iter().enumerate() {
            match self.phase {
                Phase::Prefill => {
                    ids[i * s..i * s + r.len()].copy_from_slice(&r.tokens);
                    valid.push(r.len());
                }
                Phase::Decode => {
                    debug_assert_eq!(s, 1, "decode buckets are width-only");
                    ids[i] = *r.tokens.last().expect("empty decode request");
                    valid.push(r.len());
                }
                Phase::Verify => {
                    debug_assert_eq!(r.window(), s, "verify bucket k mismatch");
                    ids[i * s] = *r.tokens.last().expect("empty verify request");
                    ids[i * s + 1..i * s + s].copy_from_slice(&r.draft);
                    // the whole drafted window counts as valid tokens
                    valid.push(r.len() + r.draft.len());
                }
                Phase::Chunk => {
                    debug_assert_eq!(r.window(), s, "chunk bucket k mismatch");
                    let start = r.chunk_start;
                    ids[i * s..i * s + s].copy_from_slice(&r.tokens[start..start + s]);
                    // valid through the end of this window: the chunk
                    // kernels place its K/V rows at `valid - k ..= valid-1`
                    // and attend over the already-seeded prefix below them
                    valid.push(start + s);
                }
            }
        }
        // bucket rows beyond the real requests are zero-length pads
        valid.resize(b, 0);
        // executables mask keys at valid_len, but a 0-length row would
        // produce a fully-masked softmax; clamp pads to one window over
        // the zero token (verify windows need valid >= k so the window
        // base position stays non-negative)
        let pad_min = match self.phase {
            Phase::Verify | Phase::Chunk => s,
            _ => 1,
        };
        for v in valid.iter_mut() {
            if *v == 0 {
                *v = pad_min;
            }
        }
        // per-row session ids: pad rows carry the sentinel so the
        // collector never mistakes them for a live session
        let mut req_ids: Vec<u64> = self.requests.iter().map(|r| r.id).collect();
        req_ids.resize(b, u64::MAX);
        // shared-prefix metadata: only materialized when some row carries
        // it, so batches formed with the feature off stay byte-identical
        // to builds that predate it
        let prefix_adopt = if self.requests.iter().any(|r| r.adopt.is_some()) {
            let mut v: Vec<Option<(u64, usize)>> =
                self.requests.iter().map(|r| r.adopt).collect();
            v.resize(b, None);
            v
        } else {
            Vec::new()
        };
        // A chunked registrant carries its total `retain` on every chunk,
        // but the workers must only retain on the step whose window
        // *crosses* the retention boundary — earlier chunks haven't cached
        // the positions yet, later ones would retain twice.
        let eff_retain = |r: &Request| -> usize {
            if r.phase != Phase::Chunk {
                return r.retain;
            }
            let end = r.chunk_start + r.chunk_len;
            if r.retain > 0 && r.chunk_start < r.retain && end >= r.retain {
                r.retain
            } else {
                0
            }
        };
        let prefix_retain = if self.requests.iter().any(|r| eff_retain(r) > 0) {
            let mut v: Vec<usize> = self.requests.iter().map(eff_retain).collect();
            v.resize(b, 0);
            v
        } else {
            Vec::new()
        };
        BatchInput {
            ids: IntTensor::new(&[b, s], ids),
            valid_lens: valid,
            req_ids,
            batch: b,
            seq: s,
            phase: self.phase,
            cache: false,
            prefix_adopt,
            prefix_retain,
        }
    }
}

/// Greedy dynamic batcher over a fixed set of compiled shape points.
pub struct Batcher {
    /// Available (batch, seq) buckets, sorted.
    buckets: Vec<(usize, usize)>,
    /// Compiled decode widths as width-only points `(w, 1)`, sorted.
    /// Empty when the engine runs without a KV cache — decode requests
    /// then never reach the queue.
    decode_points: Vec<(usize, usize)>,
    /// Compiled speculative-verify points `(width, k)`, sorted. Empty
    /// when speculation is off — verify requests then never reach the
    /// queue. A verify bucket never mixes windows of different k (the
    /// variants are shape-specialized per k).
    verify_points: Vec<(usize, usize)>,
    max_batch: usize,
    timeout: Duration,
    queue: VecDeque<(Request, Instant)>,
    /// Engine-side tiered-KV residency model (`None` = resident-only,
    /// the byte-identical fast path). When present, `form` becomes the
    /// **admission gate**: decode buckets are only formed from
    /// resident-or-prefetched sessions (spilled rows get a sync prefetch
    /// command first), prefill batches defer when the device tier cannot
    /// hold them, and prefetch hints are issued one bucket ahead.
    tier: Option<TierPolicy>,
    /// Spill/prefetch commands the policy decided on during `form`,
    /// drained by the caller via [`Batcher::take_tier_cmds`] and
    /// published *before* the formed batch so ticket order makes every
    /// gated session resident by the time its forward executes.
    tier_cmds: Vec<TierCmd>,
    /// Load-shed depth cap: max queued *prefill* requests before `admit`
    /// rejects with [`Busy`] (0 = unlimited). Decode continuations are
    /// never shed — a session already holding KV must run to completion
    /// or be cancelled, not abandoned mid-stream.
    max_queue_depth: usize,
    /// Token-budget admission gate: when the KV positions held by
    /// admitted-but-unfinished sessions reach this, `form` defers new
    /// prefill buckets until releases drain the ledger (0 = unlimited).
    token_budget: usize,
    /// KV positions per admitted session, updated as batches form and
    /// continuations re-enter; retired by `tier_free` / `purge`. This is
    /// the batcher-local view of decode working-set load that the token
    /// budget meters — in-flight sessions are *not* in `queue`, so queue
    /// length alone cannot see them. Sessions that adopted a cached
    /// prefix are charged their computed suffix only.
    active_tokens: HashMap<u64, usize>,
    /// Prefill buckets deferred by the token budget (observability).
    budget_deferrals: u64,
    /// Shared-prefix trie (`None` = feature off, the byte-identical fast
    /// path). When present, `form` runs an admission pass over the
    /// prefill run at the queue front: prompts whose leading blocks are
    /// retained in the worker registries convert into stepping decodes
    /// that adopt those blocks, and fresh prompts register as donors.
    prefix: Option<PrefixIndex>,
    /// K/V block size in positions — match/retain granularity.
    prefix_chunk: usize,
    /// Device blocks each live registry entry holds (for crediting the
    /// tier model when the entry is evicted).
    retained_blocks: HashMap<u64, usize>,
    /// In-flight adoptions: adopter session id -> leased registrant id.
    /// The lease is released on the adopter's first completed step (or
    /// its purge), never twice.
    adopt_leases: HashMap<u64, u64>,
    /// Compiled chunked-prefill points `(width, k)`, sorted. Empty when
    /// chunking is off — prefills then always run monolithically, the
    /// byte-identical default. A chunk bucket never mixes windows of
    /// different k (like verify, the variants are shape-specialized).
    chunk_points: Vec<(usize, usize)>,
    /// Decode-interleave ratio: after this many consecutive chunk waves,
    /// `form` rotates a queued chunk run behind any waiting decode /
    /// verify continuations so prefill work can never starve in-flight
    /// token generation (the TPOT-spike fix).
    chunk_decode_ratio: usize,
    /// Consecutive chunk waves formed since the last decode/verify bucket.
    chunk_streak: usize,
}

impl Batcher {
    pub fn new(mut buckets: Vec<(usize, usize)>, max_batch: usize, timeout: Duration) -> Batcher {
        assert!(!buckets.is_empty(), "no AOT shape points available");
        buckets.sort();
        Batcher {
            buckets,
            decode_points: Vec::new(),
            verify_points: Vec::new(),
            max_batch,
            timeout,
            queue: VecDeque::new(),
            tier: None,
            tier_cmds: Vec::new(),
            max_queue_depth: 0,
            token_budget: 0,
            active_tokens: HashMap::new(),
            budget_deferrals: 0,
            prefix: None,
            prefix_chunk: 0,
            retained_blocks: HashMap::new(),
            adopt_leases: HashMap::new(),
            chunk_points: Vec::new(),
            chunk_decode_ratio: 1,
            chunk_streak: 0,
        }
    }

    /// Enable load-shed admission control: a queued-prefill depth cap and
    /// a token budget over the active working set (0 = unlimited each).
    pub fn with_admission(mut self, max_queue_depth: usize, token_budget: usize) -> Batcher {
        self.max_queue_depth = max_queue_depth;
        self.token_budget = token_budget;
        self
    }

    /// Enable decode buckets for the given compiled widths.
    pub fn with_decode_widths(mut self, mut widths: Vec<usize>) -> Batcher {
        widths.sort_unstable();
        widths.dedup();
        self.decode_points = widths.into_iter().map(|w| (w, 1)).collect();
        self
    }

    /// Enable speculative-verify buckets for the given compiled
    /// `(width, k)` points.
    pub fn with_verify_points(mut self, mut points: Vec<(usize, usize)>) -> Batcher {
        points.sort_unstable();
        points.dedup();
        self.verify_points = points;
        self
    }

    /// Attach the tiered-KV policy (spill-to-host mode).
    pub fn with_tier(mut self, tier: TierPolicy) -> Batcher {
        self.tier = Some(tier);
        self
    }

    /// Enable chunked prefill over the given compiled `(width, k)` window
    /// points: prompts longer than the largest k split into fixed-size
    /// chunk steps that seed the K/V cache incrementally, and `form`
    /// admits at most `decode_ratio` consecutive chunk waves before a
    /// queued decode/verify bucket goes first. Requires the KV cache
    /// (chunk steps execute against it).
    pub fn with_chunked_prefill(
        mut self,
        mut points: Vec<(usize, usize)>,
        decode_ratio: usize,
    ) -> Batcher {
        points.sort_unstable();
        points.dedup();
        points.retain(|&(_, k)| k >= 2);
        self.chunk_points = points;
        self.chunk_decode_ratio = decode_ratio.max(1);
        self
    }

    pub fn chunk_points(&self) -> &[(usize, usize)] {
        &self.chunk_points
    }

    /// Largest compiled chunk window — the effective chunk size. Prompts
    /// no longer than this run as one monolithic prefill.
    fn max_chunk_k(&self) -> usize {
        self.chunk_points.iter().map(|&(_, k)| k).max().unwrap_or(0)
    }

    /// Largest compiled chunk window that fits `remaining` prompt
    /// positions (capped at the effective chunk size), if any.
    pub fn chunk_window_for(points: &[(usize, usize)], remaining: usize) -> Option<usize> {
        points.iter().map(|&(_, k)| k).filter(|&k| k <= remaining).max()
    }

    /// Enable shared-prefix reuse at admission: a token-id-keyed trie at
    /// K/V block granularity (`chunk` positions per level) holding at
    /// most `max_entries` retained prefixes (0 = unbounded). Requires
    /// decode widths — a matched prompt is served through the decode
    /// path.
    pub fn with_prefix_cache(mut self, chunk: usize, max_entries: usize) -> Batcher {
        assert!(chunk >= 1, "prefix chunk must be at least one position");
        self.prefix_chunk = chunk;
        self.prefix = Some(PrefixIndex::new(chunk, max_entries));
        self
    }

    pub fn prefix_enabled(&self) -> bool {
        self.prefix.is_some()
    }

    /// (hits, misses) the admission matcher has observed so far.
    pub fn prefix_hit_counts(&self) -> (u64, u64) {
        self.prefix.as_ref().map_or((0, 0), |p| p.hit_counts())
    }

    /// Live trie entries (registered donor prefixes).
    pub fn cached_prefix_entries(&self) -> usize {
        self.prefix.as_ref().map_or(0, |p| p.len())
    }

    /// Drain registrant ids whose registry entries must be dropped on the
    /// workers (cap eviction, registrant spill, purge). The caller
    /// publishes them as ticketed `EvictPrefix` commands — ticket order
    /// lands each eviction after the retention and after every adoption
    /// formed against the entry. Device blocks held by the evicted
    /// entries are credited back to the tier model here.
    pub fn take_prefix_evictions(&mut self) -> Vec<u64> {
        let evicted = match self.prefix.as_mut() {
            Some(p) => p.take_evictions(),
            None => return Vec::new(),
        };
        for id in &evicted {
            if let Some(blocks) = self.retained_blocks.remove(id) {
                if let Some(t) = self.tier.as_mut() {
                    t.note_released(blocks);
                }
            }
        }
        evicted
    }

    /// Failure-path removal: the registrant's prefill batch errored, so
    /// its retention may never have landed on the workers. Drop the trie
    /// entries now; the published eviction is a tolerated no-op on any
    /// worker that never retained.
    pub fn prefix_drop(&mut self, ids: &[u64]) {
        if let Some(p) = self.prefix.as_mut() {
            p.remove(ids);
        }
    }

    pub fn tier(&self) -> Option<&TierPolicy> {
        self.tier.as_ref()
    }

    pub fn tier_mut(&mut self) -> Option<&mut TierPolicy> {
        self.tier.as_mut()
    }

    /// Drain the tier commands the last `form` calls produced. The caller
    /// must publish these (ticketed) before publishing the formed batch.
    /// A spill decision also removes the victims' trie entries (shared
    /// registrants are excluded from spill victims, so this is a
    /// defensive backstop): eviction rides the spill, published through
    /// the same ticketed stream via [`Batcher::take_prefix_evictions`].
    pub fn take_tier_cmds(&mut self) -> Vec<TierCmd> {
        let cmds = std::mem::take(&mut self.tier_cmds);
        if let Some(p) = self.prefix.as_mut() {
            for c in &cmds {
                // parks leave the device tier just like spills, so the
                // same backstop applies (shared registrants are already
                // excluded from park victims too)
                let (TierCmd::Spill(ids) | TierCmd::Park(ids)) = c else { continue };
                let present: Vec<u64> =
                    ids.iter().copied().filter(|id| p.contains(*id)).collect();
                if !present.is_empty() {
                    p.remove(&present);
                }
            }
        }
        cmds
    }

    pub fn decode_widths(&self) -> Vec<usize> {
        self.decode_points.iter().map(|&(w, _)| w).collect()
    }

    pub fn verify_points(&self) -> &[(usize, usize)] {
        &self.verify_points
    }

    pub fn max_seq(&self) -> usize {
        self.buckets.iter().map(|&(_, s)| s).max().unwrap()
    }

    pub fn push(&mut self, r: Request) -> anyhow::Result<()> {
        self.push_at(r, Instant::now())
    }

    /// Enqueue with an explicit arrival time (continuations keep the time
    /// the client originally submitted, so timeouts measure client wait).
    pub fn push_at(&mut self, r: Request, arrived: Instant) -> anyhow::Result<()> {
        anyhow::ensure!(
            r.len() <= self.max_seq(),
            "request {} length {} exceeds longest compiled bucket {}",
            r.id,
            r.len(),
            self.max_seq()
        );
        anyhow::ensure!(!r.is_empty(), "empty request {}", r.id);
        self.queue.push_back((r, arrived));
        Ok(())
    }

    /// Admission-gated enqueue for *new* requests (the server path).
    /// Rejects with a downcastable [`Busy`] when the queued-prefill depth
    /// cap is hit, instead of queueing unboundedly. Under SLO `pressure`
    /// (the Recorder's rolling violation window is hot) the cap tightens
    /// to half — and a cap of 0 (unlimited) degrades to `2 * max_batch`
    /// so a saturated engine still sheds rather than building an
    /// ever-growing backlog it can never serve within SLO.
    /// `retry_after_ms` is the caller's current back-off estimate (the
    /// Recorder's SLO-window hint), stamped into the [`Busy`] rejection.
    pub fn admit(
        &mut self,
        r: Request,
        arrived: Instant,
        pressure: bool,
        retry_after_ms: u64,
    ) -> anyhow::Result<()> {
        let mut cap = self.max_queue_depth;
        if pressure {
            cap = if cap == 0 { 2 * self.max_batch } else { (cap / 2).max(1) };
        }
        if cap > 0 {
            let queued = self.queued_prefills();
            if queued >= cap {
                let reason = if pressure { "slo-pressure" } else { "queue-full" };
                return Err(anyhow::Error::new(Busy { reason, queued, retry_after_ms }));
            }
        }
        self.push_at(r, arrived)
    }

    /// Drop a cancelled session's queued step, if any. Returns whether a
    /// queued request was actually removed — `false` means the session is
    /// in flight (or already finished) and must instead be evicted at the
    /// next collector step. Either way the session leaves the token
    /// ledger: its KV release is the caller's next move.
    pub fn purge(&mut self, id: u64) -> bool {
        let before = self.queue.len();
        let mut dropped_prefill = false;
        self.queue.retain(|(r, _)| {
            if r.id == id {
                // a queued chunk step whose retention boundary hasn't been
                // crossed yet is still an unexecuted prefill as far as the
                // trie is concerned: its entry can never become ready
                dropped_prefill |= r.phase == Phase::Prefill
                    || (r.phase == Phase::Chunk && r.chunk_start < r.retain);
                false
            } else {
                true
            }
        });
        self.active_tokens.remove(&id);
        if let Some(p) = self.prefix.as_mut() {
            // a *queued* prefill never executed, so a trie entry it
            // registered must go (its retention will never land); an
            // in-flight or finished registrant keeps its entry — the
            // cached prefix outliving its donor is the whole point
            if dropped_prefill && p.contains(id) {
                p.remove(&[id]);
            }
            if let Some(donor) = self.adopt_leases.remove(&id) {
                p.unlease(donor);
            }
        }
        self.queue.len() != before
    }

    /// Queued prefill requests (the depth the admission cap meters). A
    /// first chunk still waiting to form is an unstarted prompt, so it
    /// counts; chunk continuations are in-flight sessions and don't.
    pub fn queued_prefills(&self) -> usize {
        self.queue
            .iter()
            .filter(|(r, _)| {
                r.phase == Phase::Prefill || (r.phase == Phase::Chunk && r.is_first_chunk())
            })
            .count()
    }

    /// KV positions currently held by admitted-but-unfinished sessions.
    pub fn active_token_load(&self) -> usize {
        self.active_tokens.values().sum()
    }

    /// Prefill buckets the token budget has deferred so far.
    pub fn budget_deferrals(&self) -> u64 {
        self.budget_deferrals
    }

    /// Re-enqueue an unfinished generation session at the *front* of the
    /// queue (decode priority): its next step dispatches before any fresh
    /// prefill, so concurrent decodes coalesce into shared buckets. The
    /// original arrival time is preserved. With the tier policy attached
    /// this is also the **cold mark**: the session just left a batch, so
    /// it becomes spillable (LRU by last decode step) until its next
    /// bucket forms.
    pub fn requeue_front(&mut self, r: Request, arrived: Instant) {
        // a verify window's speculative rows must also fit the cache
        debug_assert!(r.cache_len() <= self.max_seq() && !r.is_empty());
        if let Some(t) = self.tier.as_mut() {
            t.on_requeue(r.id);
        }
        if r.phase == Phase::Chunk {
            // mid-prompt: the session's registered prefix only becomes
            // matchable once the crossing chunk has retained it into the
            // worker registries — `chunk_start` counts what's cached, so
            // `>= retain` means the retention landed. The adoption lease,
            // if any, released after the first chunk (which adopted).
            if let Some(p) = self.prefix.as_mut() {
                if r.retain > 0 && r.chunk_start >= r.retain {
                    p.mark_ready(r.id);
                }
                if let Some(donor) = self.adopt_leases.remove(&r.id) {
                    p.unlease(donor);
                }
            }
        } else {
            self.prefix_step_done(r.id);
        }
        // keep the token ledger tracking the session's grown context;
        // adopted positions were never computed here, so they don't count
        self.active_tokens.insert(r.id, r.cache_len().saturating_sub(r.adopted));
        self.queue.push_front((r, arrived));
    }

    /// A session's forward completed (it re-entered the queue or
    /// finished): its trie entry, if any, becomes matchable — the
    /// retained rows are durably in every worker's registry — and any
    /// adoption lease it held is released.
    fn prefix_step_done(&mut self, id: u64) {
        let p = match self.prefix.as_mut() {
            Some(p) => p,
            None => return,
        };
        p.mark_ready(id);
        if let Some(donor) = self.adopt_leases.remove(&id) {
            p.unlease(donor);
        }
    }

    /// Finished sessions: credit their blocks in the tier model (no-op
    /// without a tier policy) and retire them from the admission ledger.
    pub fn tier_free(&mut self, ids: &[u64]) {
        for id in ids {
            self.active_tokens.remove(id);
            self.prefix_step_done(*id);
        }
        if let Some(t) = self.tier.as_mut() {
            t.on_free(ids);
        }
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Largest request count any bucket supports.
    fn max_bucket_batch(&self) -> usize {
        self.buckets.iter().map(|&(b, _)| b).max().unwrap()
    }

    /// Form the next batch if the policy says go: either a full batch is
    /// available or the oldest request has waited past the timeout.
    ///
    /// Only the contiguous same-phase run at the queue front is batched —
    /// prefill and decode run different executables, so a batch never
    /// mixes them. Decode continuations carry their original (long-
    /// expired) arrival time, so a decode run at the front dispatches
    /// immediately and as one shared bucket.
    pub fn form(&mut self, now: Instant) -> Option<FormedBatch> {
        if self.queue.is_empty() {
            return None;
        }
        self.apply_prefix_matches();
        self.apply_chunking();
        self.interleave_chunks();
        let phase = self.queue[0].0.phase;
        // verify / chunk buckets are shape-specialized per window size k:
        // only a same-k run can share one (runs are homogeneous anyway —
        // the collector picks one k per wave of coalescing continuations).
        // A chunk run additionally never mixes first chunks (which admit
        // like prefills) with continuations (which are admission-exempt).
        let window = self.queue[0].0.window();
        let first_chunk = self.queue[0].0.is_first_chunk();
        let run = self
            .queue
            .iter()
            .take_while(|(r, _)| {
                r.phase == phase
                    && (!matches!(phase, Phase::Verify | Phase::Chunk)
                        || r.window() == window)
                    && (phase != Phase::Chunk || r.is_first_chunk() == first_chunk)
            })
            .count();
        let cap = match phase {
            Phase::Prefill => self.max_batch.min(self.max_bucket_batch()),
            Phase::Decode => {
                let max_w = self.decode_points.iter().map(|&(w, _)| w).max().unwrap_or(0);
                assert!(max_w > 0, "decode request queued but no decode widths compiled");
                self.max_batch.min(max_w)
            }
            Phase::Verify => {
                let max_w = self
                    .verify_points
                    .iter()
                    .filter(|&&(_, k)| k == window)
                    .map(|&(w, _)| w)
                    .max()
                    .unwrap_or(0);
                assert!(max_w > 0, "verify request queued but no k={window} buckets compiled");
                self.max_batch.min(max_w)
            }
            Phase::Chunk => {
                let max_w = self
                    .chunk_points
                    .iter()
                    .filter(|&&(_, k)| k == window)
                    .map(|&(w, _)| w)
                    .max()
                    .unwrap_or(0);
                assert!(max_w > 0, "chunk request queued but no k={window} buckets compiled");
                self.max_batch.min(max_w)
            }
        };
        let oldest_expired = now.duration_since(self.queue[0].1) >= self.timeout;
        if run < cap && !oldest_expired {
            return None;
        }
        // take up to cap same-phase requests, but never exceed what some
        // bucket fits
        let mut take = run.min(cap);
        // token-budget admission: new prefill buckets defer while the KV
        // positions held by unfinished sessions saturate the budget, and
        // otherwise shrink to what still fits beside that working set.
        // Decode/verify continuations are exempt — they only ever *drain*
        // the ledger, and deferring them would deadlock the very sessions
        // the budget is waiting on. A lone oversized prompt against an
        // empty ledger still admits: the budget meters concurrency, not
        // single-request size (max_seq already bounds that on push).
        // First chunks of a chunked prefill meter like prefills (charging
        // the whole prompt minus any adopted prefix — the full cache
        // length their session will hold); chunk continuations are exempt
        // like decodes, for the same no-deadlock reason.
        let budget_metered =
            phase == Phase::Prefill || (phase == Phase::Chunk && first_chunk);
        if budget_metered && self.token_budget > 0 {
            let active = self.active_token_load();
            if active >= self.token_budget {
                self.budget_deferrals += 1;
                return None;
            }
            let mut fit = 0;
            let mut cum = 0usize;
            for (r, _) in self.queue.iter().take(take) {
                cum += r.cache_len().saturating_sub(r.adopted);
                if active + cum > self.token_budget && !(fit == 0 && active == 0) {
                    break;
                }
                fit += 1;
            }
            if fit == 0 {
                self.budget_deferrals += 1;
                return None;
            }
            take = fit;
        }
        // tier capacity caps the bucket width: a decode bucket must fit
        // beside the already-pinned in-flight working set (cold resident
        // sessions don't count — the gate can spill them), and a prefill
        // wave splits into buckets that fit the device tier alone
        if let Some(t) = self.tier.as_ref() {
            // verify rows speculatively append draft-window K/V rows, so
            // capacity checks use the post-step cache length
            let rows: Vec<(u64, usize)> =
                self.queue.iter().take(take).map(|(r, _)| (r.id, r.cache_len())).collect();
            take = match phase {
                // first chunks admit like prefills (their rows are new to
                // the tier model and charge the final cache length)...
                Phase::Prefill => t.max_prefill_rows(&rows).min(take),
                Phase::Chunk if first_chunk => t.max_prefill_rows(&rows).min(take),
                // ...continuations gate like decodes: already charged,
                // just kept / staged resident
                Phase::Decode | Phase::Verify | Phase::Chunk => {
                    let m = t.max_decode_rows(&rows).min(take);
                    if m == 0 {
                        // everything is pinned by in-flight buckets:
                        // defer until one completes and unpins
                        return None;
                    }
                    m
                }
            };
        }
        let mut reqs: Vec<(Request, Instant)> = Vec::with_capacity(take);
        let mut max_len = 0;
        for _ in 0..take {
            let pair = self.queue.pop_front().unwrap();
            max_len = max_len.max(pair.0.len());
            reqs.push(pair);
        }
        // If no bucket covers (take, max_len), shed the longest requests
        // back to the queue until one does. max_seq is checked on push, so
        // shrinking the count always converges to a feasible bucket.
        loop {
            let bucket = match phase {
                Phase::Prefill => smallest_fitting_bucket(&self.buckets, reqs.len(), max_len),
                // decode row "length" is always the single newest token
                Phase::Decode => smallest_fitting_bucket(&self.decode_points, reqs.len(), 1),
                // verify / chunk buckets: exact-k points only, widths
                // compared as width-only (the k column is the fixed
                // window, not a pad target)
                Phase::Verify => {
                    let pts: Vec<(usize, usize)> = self
                        .verify_points
                        .iter()
                        .filter(|&&(_, k)| k == window)
                        .map(|&(w, _)| (w, 1))
                        .collect();
                    smallest_fitting_bucket(&pts, reqs.len(), 1).map(|(w, _)| (w, window))
                }
                Phase::Chunk => {
                    let pts: Vec<(usize, usize)> = self
                        .chunk_points
                        .iter()
                        .filter(|&&(_, k)| k == window)
                        .map(|&(w, _)| (w, 1))
                        .collect();
                    smallest_fitting_bucket(&pts, reqs.len(), 1).map(|(w, _)| (w, window))
                }
            };
            if let Some(bucket) = bucket {
                if !self.tier_gate(phase, &mut reqs) {
                    return None; // admission control deferred the batch
                }
                // the batch is committed: its sessions join (or update)
                // the admission token ledger at their post-step length,
                // minus any positions adopted from a cached prefix (the
                // budget meters computed work, and the adopted blocks are
                // already charged to the registry)
                for (r, _) in reqs.iter() {
                    self.active_tokens.insert(r.id, r.cache_len().saturating_sub(r.adopted));
                }
                if self.prefix.is_some() {
                    self.commit_prefix_rows(&reqs);
                }
                // decode-interleave accounting: consecutive chunk waves
                // count up; any decode/verify bucket resets the streak
                match phase {
                    Phase::Chunk => self.chunk_streak += 1,
                    Phase::Decode | Phase::Verify => self.chunk_streak = 0,
                    Phase::Prefill => {}
                }
                return Some(FormedBatch {
                    requests: reqs.into_iter().map(|(r, _)| r).collect(),
                    bucket,
                    phase,
                });
            }
            // shed the last request back, keeping its *original* arrival
            // time — resetting it would silently extend the timeout of a
            // request that already waited a full batching window
            let pair = reqs.pop().expect("bucket must fit a single request");
            self.queue.push_front(pair);
            if phase == Phase::Prefill {
                max_len = reqs.iter().map(|(r, _)| r.len()).max().unwrap_or(0);
            }
        }
    }

    /// The tiered-KV admission gate, run once a bucket has been chosen.
    /// Returns `false` when admission control defers the batch (the
    /// requests are pushed back to the queue front in order). Any spill /
    /// prefetch commands the policy decides on are buffered in
    /// `tier_cmds` — even on deferral, since pressure relief was already
    /// applied to the model.
    fn tier_gate(&mut self, phase: Phase, reqs: &mut Vec<(Request, Instant)>) -> bool {
        let tier = match self.tier.as_mut() {
            Some(t) => t,
            None => return true,
        };
        let rows: Vec<(u64, usize)> = reqs.iter().map(|(r, _)| (r.id, r.cache_len())).collect();
        // first chunks of a chunked prefill admit atomically like
        // prefills — charging the *final* cache length so spill water
        // marks stay correct for the whole chunked lifetime — while chunk
        // continuations gate like decodes (already charged and pinned by
        // their first chunk; the gate only keeps / stages them resident)
        let chunk_admits = phase == Phase::Chunk
            && reqs.first().is_some_and(|(r, _)| r.is_first_chunk());
        match phase {
            _ if chunk_admits => {
                let (cmds, admitted) = tier.admit_prefill(&rows);
                self.tier_cmds.extend(cmds);
                if !admitted {
                    for pair in reqs.drain(..).rev() {
                        self.queue.push_front(pair);
                    }
                    return false;
                }
            }
            Phase::Chunk => {
                self.tier_cmds.extend(tier.gate_decode(&rows));
            }
            Phase::Prefill => {
                let (cmds, admitted) = tier.admit_prefill(&rows);
                self.tier_cmds.extend(cmds);
                if !admitted {
                    // device tier is full of busy sessions: leave the
                    // prompts queued (original order + arrival times) and
                    // retry once running sessions finish. Decode
                    // continuations re-enter at the queue front, so they
                    // are never starved by a parked prefill.
                    for pair in reqs.drain(..).rev() {
                        self.queue.push_front(pair);
                    }
                    return false;
                }
            }
            Phase::Decode | Phase::Verify => {
                self.tier_cmds.extend(tier.gate_decode(&rows));
                // prefetch hints one decode bucket ahead (the
                // `PoolConfig.lookahead` idea applied to sessions): the
                // next bucket's worth of queued continuations gets staged
                // back now, so their admission needs no sync fetch
                let max_w = self.decode_points.iter().map(|&(w, _)| w).max().unwrap_or(0);
                let ahead = tier.config().lookahead * max_w.min(self.max_batch);
                if ahead > 0 {
                    let upcoming: Vec<(u64, usize)> = self
                        .queue
                        .iter()
                        .take_while(|(r, _)| r.phase != Phase::Prefill)
                        .take(ahead)
                        .map(|(r, _)| (r.id, r.cache_len()))
                        .collect();
                    if !upcoming.is_empty() {
                        let cmds = tier.prefetch_hint(&upcoming);
                        self.tier_cmds.extend(cmds);
                    }
                }
            }
        }
        true
    }

    /// Shared-prefix admission pass over the contiguous prefill run at
    /// the queue front. Prompts whose leading blocks are already retained
    /// in the worker registries convert into **stepping decodes**: adopt
    /// the cached blocks, then walk the remaining prompt through the
    /// decode path one token per step — byte-identical to a fresh prefill
    /// because decode applies the same pinned greedy rule over the same
    /// cached K/V rows. Prompts that miss register as future donors
    /// (block-aligned, whole blocks only). Converted rows move ahead of
    /// the remaining prefills: they are decode steps now, and decode
    /// priority is the queue's standing rule.
    fn apply_prefix_matches(&mut self) {
        if self.prefix.is_none() {
            return;
        }
        let run = self.queue.iter().take_while(|(r, _)| r.phase == Phase::Prefill).count();
        if run == 0 {
            return;
        }
        let chunk = self.prefix_chunk;
        let mut stepped: Vec<(Request, Instant)> = Vec::new();
        let mut kept: Vec<(Request, Instant)> = Vec::new();
        for _ in 0..run {
            let (mut r, at) = self.queue.pop_front().unwrap();
            if r.retain > 0 || r.adopt.is_some() {
                // already resolved on an earlier (deferred) pass
                kept.push((r, at));
                continue;
            }
            let p = self.prefix.as_mut().unwrap();
            // the final prompt position is always computed fresh — its
            // logits are this row's first sampled token — so the match
            // caps one position short of the prompt end
            let cap = ((r.len() - 1) / chunk) * chunk;
            let hit = if cap > 0 { p.match_longest(&r.tokens[..cap]) } else { None };
            match hit {
                Some((donor, m)) => {
                    p.lease(donor);
                    self.adopt_leases.insert(r.id, donor);
                    // with chunked prefill on, the unmatched suffix walks
                    // in chunk windows instead of one-token decode steps
                    // whenever a compiled window fits it — same adopted
                    // blocks, fewer engine steps
                    let suffix_window =
                        Self::chunk_window_for(&self.chunk_points, r.len() - m);
                    let step = match suffix_window {
                        Some(k) => Request {
                            id: r.id,
                            tokens: r.tokens,
                            phase: Phase::Chunk,
                            draft: Vec::new(),
                            adopt: Some((donor, m)),
                            retain: 0,
                            adopted: m,
                            chunk_start: m,
                            chunk_len: k,
                        },
                        None => Request {
                            id: r.id,
                            tokens: r.tokens[..m + 1].to_vec(),
                            phase: Phase::Decode,
                            draft: Vec::new(),
                            adopt: Some((donor, m)),
                            retain: 0,
                            adopted: m,
                            chunk_start: 0,
                            chunk_len: 0,
                        },
                    };
                    stepped.push((step, at));
                }
                None => {
                    if p.register(r.id, &r.tokens) {
                        r.retain = (r.len() / chunk) * chunk;
                    }
                    kept.push((r, at));
                }
            }
        }
        for pair in kept.into_iter().rev() {
            self.queue.push_front(pair);
        }
        for pair in stepped.into_iter().rev() {
            self.queue.push_front(pair);
        }
    }

    /// Chunked-prefill admission pass over the contiguous prefill run at
    /// the queue front (the run the prefix pass just resolved): prompts
    /// longer than the effective chunk size convert in place into their
    /// *first* chunk step. Later chunks are threaded back to the queue
    /// front by the collector, so conversion happens exactly once per
    /// prompt. Prompts that fit one window keep the monolithic path — a
    /// single prefill bucket is strictly cheaper than a lone chunk.
    fn apply_chunking(&mut self) {
        if self.chunk_points.is_empty() {
            return;
        }
        let c = self.max_chunk_k();
        let block = self.prefix_chunk;
        for (r, _) in self.queue.iter_mut() {
            if r.phase != Phase::Prefill {
                break;
            }
            if r.len() <= c {
                continue;
            }
            r.phase = Phase::Chunk;
            r.chunk_start = 0;
            r.chunk_len = c;
            // cap a registrant's retention one position short of the
            // prompt end: the crossing chunk then always lands while the
            // prompt is still being chunk-walked, which is the invariant
            // `requeue_front` relies on before marking the entry ready
            if block > 0 && r.retain >= r.len() {
                r.retain = ((r.len() - 1) / block) * block;
            }
        }
    }

    /// Decode-interleave rotation: once `chunk_decode_ratio` consecutive
    /// chunk waves have formed and decode/verify continuations are
    /// waiting, the chunk run at the queue front moves behind them (but
    /// stays ahead of fresh prefills). This bounds decode starvation by
    /// construction — a long prompt can occupy the workers for at most
    /// `ratio` chunk windows before every in-flight generation gets a
    /// token step.
    fn interleave_chunks(&mut self) {
        if self.chunk_points.is_empty() || self.chunk_streak < self.chunk_decode_ratio {
            return;
        }
        if self.queue.front().map_or(true, |(r, _)| r.phase != Phase::Chunk) {
            return;
        }
        if !self
            .queue
            .iter()
            .any(|(r, _)| matches!(r.phase, Phase::Decode | Phase::Verify))
        {
            return;
        }
        let mut rotated = Vec::new();
        while self.queue.front().map_or(false, |(r, _)| r.phase == Phase::Chunk) {
            rotated.push(self.queue.pop_front().unwrap());
        }
        // re-insert after the last waiting decode/verify continuation
        let at = self
            .queue
            .iter()
            .rposition(|(r, _)| matches!(r.phase, Phase::Decode | Phase::Verify))
            .map_or(0, |i| i + 1);
        for pair in rotated.into_iter().rev() {
            self.queue.insert(at, pair);
        }
    }

    /// Post-commit bookkeeping for prefix-cache rows in a formed batch:
    /// registrants charge their registry blocks to the tier model (the
    /// registry is its own holder, outliving the session) and both
    /// registrants and adopters become spill-exempt — their device blocks
    /// are (or are about to be) shared, and shared blocks never move.
    fn commit_prefix_rows(&mut self, reqs: &[(Request, Instant)]) {
        for (r, _) in reqs {
            if r.retain > 0 && !self.retained_blocks.contains_key(&r.id) {
                let blocks = r.retain / self.prefix_chunk;
                self.retained_blocks.insert(r.id, blocks);
                if let Some(t) = self.tier.as_mut() {
                    t.note_retained(blocks);
                    t.mark_shared(r.id);
                }
            }
            if r.adopt.is_some() {
                if let Some(t) = self.tier.as_mut() {
                    t.mark_shared(r.id);
                }
            }
        }
    }

    /// Drain everything regardless of timeout (shutdown path). With a
    /// tier policy attached this is best-effort: prefill batches parked
    /// by admission control stay queued (`pending() > 0`) until running
    /// sessions free device blocks — the engine's shutdown drain keeps
    /// ticking `form` for exactly that reason, rather than calling this.
    pub fn flush(&mut self) -> Vec<FormedBatch> {
        let mut out = Vec::new();
        while !self.queue.is_empty() {
            // force timeout semantics
            let long_ago = Instant::now() + self.timeout + Duration::from_secs(1);
            if let Some(b) = self.form(long_ago) {
                out.push(b);
            } else {
                break;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn batcher() -> Batcher {
        Batcher::new(
            vec![(1, 16), (2, 16), (4, 32)],
            4,
            Duration::from_millis(10),
        )
    }

    fn req(id: u64, len: usize) -> Request {
        Request::new(id, vec![1; len])
    }

    #[test]
    fn full_batch_dispatches_immediately() {
        let mut b = batcher();
        for i in 0..4 {
            b.push(req(i, 8)).unwrap();
        }
        let fb = b.form(Instant::now()).expect("full batch should form");
        assert_eq!(fb.requests.len(), 4);
        assert_eq!(fb.bucket, (4, 32));
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn partial_batch_waits_for_timeout() {
        let mut b = batcher();
        b.push(req(0, 8)).unwrap();
        assert!(b.form(Instant::now()).is_none());
        let later = Instant::now() + Duration::from_millis(20);
        let fb = b.form(later).expect("timeout should dispatch");
        assert_eq!(fb.requests.len(), 1);
        assert_eq!(fb.bucket, (1, 16));
    }

    #[test]
    fn bucket_is_smallest_fitting() {
        let mut b = batcher();
        b.push(req(0, 4)).unwrap();
        b.push(req(1, 12)).unwrap();
        let later = Instant::now() + Duration::from_millis(20);
        let fb = b.form(later).unwrap();
        assert_eq!(fb.bucket, (2, 16));
    }

    #[test]
    fn long_requests_force_big_bucket() {
        let mut b = batcher();
        b.push(req(0, 30)).unwrap();
        let later = Instant::now() + Duration::from_millis(20);
        let fb = b.form(later).unwrap();
        assert_eq!(fb.bucket, (4, 32));
    }

    #[test]
    fn oversized_request_rejected() {
        let mut b = batcher();
        assert!(b.push(req(0, 100)).is_err());
        assert!(b.push(Request::new(1, vec![])).is_err());
    }

    #[test]
    fn infeasible_combo_sheds_to_queue() {
        // 2 requests, one long: (2,16) doesn't fit len 30, (4,32) does
        let mut b = batcher();
        b.push(req(0, 30)).unwrap();
        b.push(req(1, 30)).unwrap();
        b.push(req(2, 30)).unwrap();
        b.push(req(3, 30)).unwrap();
        b.push(req(4, 30)).unwrap();
        let fb = b.form(Instant::now()).unwrap();
        assert_eq!(fb.bucket, (4, 32));
        assert_eq!(fb.requests.len(), 4);
        assert_eq!(b.pending(), 1);
    }

    #[test]
    fn to_input_pads_and_clamps() {
        let fb = FormedBatch { requests: vec![req(7, 3)], bucket: (2, 16), phase: Phase::Prefill };
        let input = fb.to_input();
        assert_eq!(input.ids.shape, vec![2, 16]);
        assert_eq!(input.valid_lens, vec![3, 1]); // empty row clamped to 1
        assert_eq!(&input.ids.data[0..3], &[1, 1, 1]);
        assert_eq!(input.ids.data[3], 0);
        // per-row session ids: real rows carry the request id, pad rows
        // the sentinel
        assert_eq!(input.req_ids, vec![7, u64::MAX]);
    }

    #[test]
    fn shed_preserves_arrival_time() {
        // long sequences only fit the narrow bucket: 4 requests of len 20
        // can't use (4,16), so two are shed back to the queue
        let mut b = Batcher::new(vec![(2, 32), (4, 16)], 4, Duration::from_millis(10));
        let old = Instant::now() - Duration::from_millis(20); // past timeout
        for i in 0..4 {
            b.push_at(req(i, 20), old).unwrap();
        }
        let fb = b.form(Instant::now()).unwrap();
        assert_eq!(fb.bucket, (2, 32));
        assert_eq!(fb.requests.len(), 2);
        assert_eq!(b.pending(), 2);
        // the shed requests kept their original (already expired) arrival,
        // so a lone form() dispatches them immediately instead of
        // silently re-waiting a full timeout window
        let fb2 = b.form(Instant::now()).expect("shed requests must stay timed out");
        assert_eq!(fb2.requests.len(), 2);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn requeue_front_takes_decode_priority() {
        let mut b = batcher();
        b.push(req(10, 8)).unwrap(); // fresh prefill
        // a continuation re-enters at the front with its old arrival
        let old = Instant::now() - Duration::from_millis(20);
        b.requeue_front(req(3, 9), old);
        // old arrival => timeout already expired => forms immediately, and
        // the decode row leads the batch
        let fb = b.form(Instant::now()).expect("expired continuation must dispatch");
        assert_eq!(fb.requests[0].id, 3);
        assert_eq!(fb.requests.len(), 2);
    }

    #[test]
    fn shared_bucket_helper_matches_batcher() {
        let points = vec![(1, 16), (2, 16), (4, 32)];
        assert_eq!(smallest_fitting_bucket(&points, 1, 8), Some((1, 16)));
        assert_eq!(smallest_fitting_bucket(&points, 2, 8), Some((2, 16)));
        assert_eq!(smallest_fitting_bucket(&points, 2, 20), Some((4, 32)));
        assert_eq!(smallest_fitting_bucket(&points, 5, 8), None);
        assert_eq!(smallest_fitting_bucket(&points, 1, 64), None);
    }

    fn decode_batcher() -> Batcher {
        batcher().with_decode_widths(vec![1, 2, 4])
    }

    #[test]
    fn decode_run_forms_width_bucket_immediately() {
        let mut b = decode_batcher();
        let old = Instant::now() - Duration::from_millis(20);
        // three continuations re-enter front-first (reverse push order)
        for id in [3u64, 2, 1] {
            b.requeue_front(Request::decode(id, vec![7; 10 + id as usize]), old);
        }
        let fb = b.form(Instant::now()).expect("expired decode run must dispatch");
        assert_eq!(fb.phase, Phase::Decode);
        assert_eq!(fb.bucket, (4, 1), "3 rows need the width-4 bucket");
        assert_eq!(fb.requests.len(), 3);
        assert_eq!(fb.requests[0].id, 1);
    }

    #[test]
    fn decode_and_prefill_never_mix() {
        let mut b = decode_batcher();
        let old = Instant::now() - Duration::from_millis(20);
        b.push_at(req(9, 8), old).unwrap(); // expired prefill at the back
        b.requeue_front(Request::decode(1, vec![5; 6]), old);
        b.requeue_front(Request::decode(0, vec![5; 4]), old);
        let fb = b.form(Instant::now()).unwrap();
        assert_eq!(fb.phase, Phase::Decode);
        assert_eq!(fb.requests.len(), 2, "prefill must not ride in a decode bucket");
        let fb2 = b.form(Instant::now()).unwrap();
        assert_eq!(fb2.phase, Phase::Prefill);
        assert_eq!(fb2.requests[0].id, 9);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn decode_input_carries_last_token_and_total_len() {
        let fb = FormedBatch {
            requests: vec![Request::decode(7, vec![4, 5, 6])],
            bucket: (2, 1),
            phase: Phase::Decode,
        };
        let input = fb.to_input();
        assert_eq!(input.phase, Phase::Decode);
        assert_eq!(input.ids.shape, vec![2, 1]);
        assert_eq!(input.ids.data, vec![6, 0]); // newest token + pad
        assert_eq!(input.valid_lens, vec![3, 1]); // total len; pad clamped
        assert_eq!(input.req_ids, vec![7, u64::MAX]);
    }

    #[test]
    fn decode_widths_are_sorted_and_deduped() {
        let b = batcher().with_decode_widths(vec![4, 1, 4, 2]);
        assert_eq!(b.decode_widths(), vec![1, 2, 4]);
    }

    fn verify_batcher() -> Batcher {
        batcher()
            .with_decode_widths(vec![1, 2, 4])
            .with_verify_points(vec![(1, 2), (2, 2), (4, 2), (1, 4), (2, 4), (4, 4)])
    }

    #[test]
    fn verify_run_forms_exact_k_bucket() {
        let mut b = verify_batcher();
        let old = Instant::now() - Duration::from_millis(20);
        for id in [3u64, 2, 1] {
            b.requeue_front(Request::verify(id, vec![7; 6], vec![9, 9, 9]), old);
        }
        let fb = b.form(Instant::now()).expect("expired verify run must dispatch");
        assert_eq!(fb.phase, Phase::Verify);
        assert_eq!(fb.bucket, (4, 4), "3 rows of k=4 need the (4, 4) bucket");
        assert_eq!(fb.requests.len(), 3);
        assert_eq!(fb.requests[0].id, 1);
    }

    #[test]
    fn verify_buckets_never_mix_ks_or_phases() {
        let mut b = verify_batcher();
        let old = Instant::now() - Duration::from_millis(20);
        b.push_at(req(9, 8), old).unwrap(); // expired prefill at the back
        b.requeue_front(Request::decode(5, vec![5; 6]), old);
        b.requeue_front(Request::verify(2, vec![5; 6], vec![8, 8, 8]), old); // k=4
        b.requeue_front(Request::verify(1, vec![5; 4], vec![8]), old); // k=2
        let fb = b.form(Instant::now()).unwrap();
        assert_eq!((fb.phase, fb.bucket), (Phase::Verify, (1, 2)));
        assert_eq!(fb.requests[0].id, 1);
        let fb = b.form(Instant::now()).unwrap();
        assert_eq!((fb.phase, fb.bucket), (Phase::Verify, (1, 4)));
        assert_eq!(fb.requests[0].id, 2);
        let fb = b.form(Instant::now()).unwrap();
        assert_eq!(fb.phase, Phase::Decode);
        assert_eq!(fb.requests[0].id, 5);
        let fb = b.form(Instant::now()).unwrap();
        assert_eq!(fb.phase, Phase::Prefill);
        assert_eq!(fb.requests[0].id, 9);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn verify_input_carries_window_and_total_len() {
        let fb = FormedBatch {
            requests: vec![Request::verify(7, vec![4, 5, 6], vec![11, 12, 13])],
            bucket: (2, 4),
            phase: Phase::Verify,
        };
        let input = fb.to_input();
        assert_eq!(input.phase, Phase::Verify);
        assert_eq!(input.ids.shape, vec![2, 4]);
        // newest committed token + the drafted window, then a pad row
        assert_eq!(input.ids.data, vec![6, 11, 12, 13, 0, 0, 0, 0]);
        // total tokens incl the draft; pad rows clamp to one window
        assert_eq!(input.valid_lens, vec![6, 4]);
        assert_eq!(input.req_ids, vec![7, u64::MAX]);
    }

    #[test]
    fn verify_tier_gate_accounts_speculative_rows() {
        // bp=8: a verify step over 7 committed + 3 drafted rows needs
        // ceil(10/8)=2 blocks — with only 1 device block the bucket must
        // not pass the gate without spilling someone else first
        let mut b = batcher()
            .with_decode_widths(vec![1, 2, 4])
            .with_verify_points(vec![(1, 4), (2, 4), (4, 4)])
            .with_tier(TierPolicy::new(TierConfig::new(8, 64), 8));
        let old = Instant::now() - Duration::from_millis(20);
        b.requeue_front(Request::verify(1, vec![7; 7], vec![9, 9, 9]), old);
        b.form(Instant::now()).expect("verify bucket forms");
        assert!(b.take_tier_cmds().is_empty());
        // the tier model charged 2 blocks, not 1
        assert_eq!(b.tier().unwrap().device_used(), 2);
    }

    use crate::memory::kvcache::tier::TierConfig;

    #[test]
    fn no_tier_means_no_commands() {
        let mut b = decode_batcher();
        let old = Instant::now() - Duration::from_millis(20);
        b.requeue_front(Request::decode(1, vec![5; 4]), old);
        b.form(Instant::now()).expect("decode forms");
        assert!(b.tier().is_none());
        assert!(b.take_tier_cmds().is_empty());
    }

    #[test]
    fn prefill_admission_defers_until_capacity_frees() {
        // one-block device tier (bp=8: a len-8 prompt is one block)
        let mut b = batcher()
            .with_decode_widths(vec![1, 2, 4])
            .with_tier(TierPolicy::new(TierConfig::new(1, 4), 8));
        let old = Instant::now() - Duration::from_millis(20);
        b.push_at(req(1, 8), old).unwrap();
        let fb = b.form(Instant::now()).expect("first prompt admits");
        assert_eq!(fb.requests[0].id, 1);
        assert!(b.take_tier_cmds().is_empty());
        // 1 is pinned (in flight): 2 cannot fit and cannot evict -> defer
        b.push_at(req(2, 8), old).unwrap();
        assert!(b.form(Instant::now()).is_none(), "must defer while 1 is pinned");
        assert_eq!(b.pending(), 1, "deferred request stays queued");
        // 1 finishes and frees its blocks; 2 admits now
        b.tier_free(&[1]);
        let fb2 = b.form(Instant::now()).expect("admits after free");
        assert_eq!(fb2.requests[0].id, 2);
    }

    #[test]
    fn decode_gate_prefetches_and_hints_spilled_sessions() {
        // max_batch 1 => width-1 decode buckets, so the lookahead peeks a
        // *queued* session instead of batching it
        let mut b = Batcher::new(vec![(1, 16), (2, 16), (4, 32)], 1, Duration::from_millis(10))
            .with_decode_widths(vec![1, 2, 4])
            .with_tier(TierPolicy::new(TierConfig::new(8, 64), 8));
        let old = Instant::now() - Duration::from_millis(20);
        // fill the 8-block device tier with 8 one-block sessions
        for id in 1..=8u64 {
            b.push_at(req(id, 8), old).unwrap();
            let fb = b.form(Instant::now()).expect("prefill admits");
            assert_eq!(fb.requests[0].id, id);
            assert!(b.take_tier_cmds().is_empty());
            b.tier_mut().unwrap().on_requeue(id);
        }
        // a 9th prompt forces LRU spills (1 is coldest)
        b.push_at(req(9, 8), old).unwrap();
        b.form(Instant::now()).expect("prefill admits by spilling");
        let cmds = b.take_tier_cmds();
        assert!(
            matches!(&cmds[0], TierCmd::Spill(ids) if ids.contains(&1) && ids.contains(&2)),
            "{cmds:?}"
        );
        b.tier_mut().unwrap().on_requeue(9);
        assert_eq!(b.tier().unwrap().is_resident(1), Some(false));
        assert_eq!(b.tier().unwrap().is_resident(2), Some(false));
        // session 1's decode bucket forms; spilled session 2 queues behind
        b.requeue_front(Request::decode(2, vec![7; 9]), old);
        b.requeue_front(Request::decode(1, vec![7; 9]), old);
        let fb = b.form(Instant::now()).expect("decode bucket forms");
        assert_eq!(fb.phase, Phase::Decode);
        assert_eq!(fb.requests.len(), 1);
        assert_eq!(fb.requests[0].id, 1);
        let cmds = b.take_tier_cmds();
        // 1 staged back synchronously for its own bucket...
        assert!(
            cmds.iter()
                .any(|c| matches!(c, TierCmd::Prefetch { ids, hint: false } if ids == &vec![1])),
            "{cmds:?}"
        );
        // ...and 2 hinted back one bucket ahead
        assert!(
            cmds.iter()
                .any(|c| matches!(c, TierCmd::Prefetch { ids, hint: true } if ids.contains(&2))),
            "{cmds:?}"
        );
        assert_eq!(b.tier().unwrap().is_resident(1), Some(true));
        assert_eq!(b.tier().unwrap().is_resident(2), Some(true));
    }

    #[test]
    fn flush_drains_everything() {
        let mut b = batcher();
        for i in 0..6 {
            b.push(req(i, 8)).unwrap();
        }
        let batches = b.flush();
        let total: usize = batches.iter().map(|fb| fb.requests.len()).sum();
        assert_eq!(total, 6);
        assert_eq!(b.pending(), 0);
    }

    fn busy_of(e: &anyhow::Error) -> &Busy {
        e.downcast_ref::<Busy>().expect("admission rejection must downcast to Busy")
    }

    #[test]
    fn admit_sheds_past_depth_cap() {
        let mut b = batcher().with_admission(2, 0);
        let now = Instant::now();
        b.admit(req(0, 8), now, false, 0).unwrap();
        b.admit(req(1, 8), now, false, 0).unwrap();
        let err = b.admit(req(2, 8), now, false, 40).unwrap_err();
        let busy = busy_of(&err);
        assert_eq!((busy.reason, busy.queued), ("queue-full", 2));
        assert_eq!(busy.retry_after_ms, 40, "rejection carries the back-off hint");
        assert_eq!(b.pending(), 2, "shed request must not enter the queue");
        // the cap meters prefills only: a decode continuation still
        // requeues (front) and the prefills behind it still count as 2
        b.requeue_front(Request::decode(9, vec![5; 4]), now);
        let err = b.admit(req(3, 8), now, false, 0).unwrap_err();
        assert_eq!(busy_of(&err).queued, 2);
    }

    #[test]
    fn admit_pressure_tightens_cap() {
        // explicit cap 4 halves to 2 under pressure
        let mut b = batcher().with_admission(4, 0);
        let now = Instant::now();
        b.admit(req(0, 8), now, true, 0).unwrap();
        b.admit(req(1, 8), now, true, 0).unwrap();
        let err = b.admit(req(2, 8), now, true, 0).unwrap_err();
        assert_eq!(busy_of(&err).reason, "slo-pressure");
        // ...but without pressure the full cap still admits
        b.admit(req(2, 8), now, false, 0).unwrap();
        // unlimited cap degrades to 2 * max_batch (= 8) under pressure
        let mut b = batcher();
        for i in 0..8 {
            b.admit(req(i, 8), now, true, 0).unwrap();
            // consume nothing: form won't fire below, queue just grows
        }
        assert!(b.admit(req(8, 8), now, true, 0).is_err());
        assert!(b.admit(req(8, 8), now, false, 0).is_ok(), "no cap without pressure");
    }

    #[test]
    fn purge_removes_queued_request_only() {
        let mut b = batcher();
        let now = Instant::now();
        b.push_at(req(1, 8), now).unwrap();
        b.push_at(req(2, 8), now).unwrap();
        assert!(b.purge(1), "queued request purges");
        assert!(!b.purge(1), "second purge finds nothing");
        assert!(!b.purge(77), "unknown id purges nothing");
        assert_eq!(b.pending(), 1);
        let later = now + Duration::from_millis(20);
        let fb = b.form(later).expect("survivor still forms");
        assert_eq!(fb.requests.len(), 1);
        assert_eq!(fb.requests[0].id, 2);
    }

    #[test]
    fn token_budget_defers_prefill_until_sessions_retire() {
        // budget 20: one len-16 prompt fills most of it
        let mut b = batcher().with_admission(0, 20);
        let old = Instant::now() - Duration::from_millis(20);
        b.push_at(req(1, 16), old).unwrap();
        let fb = b.form(Instant::now()).expect("first prompt admits");
        assert_eq!(fb.requests[0].id, 1);
        assert_eq!(b.active_token_load(), 16);
        // 16 + 8 > 20: the second prompt defers, stays queued
        b.push_at(req(2, 8), old).unwrap();
        assert!(b.form(Instant::now()).is_none(), "must defer over budget");
        assert_eq!(b.pending(), 1);
        assert_eq!(b.budget_deferrals(), 1);
        // session 1 finishes -> ledger drains -> 2 admits
        b.tier_free(&[1]);
        assert_eq!(b.active_token_load(), 0);
        let fb2 = b.form(Instant::now()).expect("admits after release");
        assert_eq!(fb2.requests[0].id, 2);
    }

    #[test]
    fn token_budget_splits_wave_and_tracks_growth() {
        let mut b = decode_batcher().with_admission(0, 20);
        let old = Instant::now() - Duration::from_millis(20);
        for id in 1..=4u64 {
            b.push_at(req(id, 8), old).unwrap();
        }
        // 8 + 8 fits the budget of 20; the third row would overflow
        let fb = b.form(Instant::now()).expect("partial wave admits");
        assert_eq!(fb.requests.len(), 2);
        assert_eq!(b.pending(), 2);
        assert!(b.form(Instant::now()).is_none(), "rest defers");
        // continuations grow the ledger entry in place (no double count)
        b.requeue_front(Request::decode(1, vec![7; 9]), old);
        assert_eq!(b.active_token_load(), 9 + 8);
        let fb = b.form(Instant::now()).expect("decode is budget-exempt");
        assert_eq!(fb.phase, Phase::Decode);
        // cancellation purges the ledger even for in-flight sessions
        assert!(!b.purge(2), "in-flight session is not queued");
        assert_eq!(b.active_token_load(), 9);
    }

    #[test]
    fn oversized_lone_prompt_still_admits_against_empty_ledger() {
        // budget 4 < prompt 8: concurrency metering must not wedge a
        // single request the compiled buckets can serve
        let mut b = batcher().with_admission(0, 4);
        let old = Instant::now() - Duration::from_millis(20);
        b.push_at(req(1, 8), old).unwrap();
        let fb = b.form(Instant::now()).expect("lone oversized prompt admits");
        assert_eq!(fb.requests.len(), 1);
        // but a second one defers until the first retires
        b.push_at(req(2, 8), old).unwrap();
        assert!(b.form(Instant::now()).is_none());
        b.tier_free(&[1]);
        assert!(b.form(Instant::now()).is_some());
    }

    #[test]
    fn busy_formats_and_downcasts_through_anyhow() {
        let e = anyhow::Error::new(Busy { reason: "queue-full", queued: 3, retry_after_ms: 25 });
        assert_eq!(e.to_string(), "busy (queue-full): 3 prefills queued, retry after 25 ms");
        assert_eq!(e.downcast_ref::<Busy>().unwrap().queued, 3);
        assert_eq!(e.downcast_ref::<Busy>().unwrap().retry_after_ms, 25);
    }

    fn prefix_batcher() -> Batcher {
        batcher().with_decode_widths(vec![1, 2, 4]).with_prefix_cache(4, 0)
    }

    /// Drive prompt `toks` for session `id` through prefill + one
    /// continuation so its registered prefix becomes matchable.
    fn seed_donor(b: &mut Batcher, id: u64, toks: Vec<i32>) {
        let old = Instant::now() - Duration::from_millis(20);
        let len = toks.len();
        b.push_at(Request::new(id, toks.clone()), old).unwrap();
        let fb = b.form(Instant::now()).expect("donor prefill forms");
        assert_eq!((fb.phase, fb.requests[0].id), (Phase::Prefill, id));
        let mut cont = toks;
        cont.push(777);
        b.requeue_front(Request::decode(id, cont), old);
        let fb = b.form(Instant::now()).expect("donor continuation forms");
        assert_eq!(fb.phase, Phase::Decode);
        assert_eq!(fb.requests[0].len(), len + 1);
    }

    #[test]
    fn prefix_miss_registers_whole_blocks_for_retention() {
        let mut b = prefix_batcher();
        let old = Instant::now() - Duration::from_millis(20);
        // 10 tokens, chunk 4: two whole blocks (8 positions) register
        b.push_at(Request::new(1, (0..10).collect()), old).unwrap();
        let fb = b.form(Instant::now()).expect("miss still prefills");
        assert_eq!(fb.phase, Phase::Prefill);
        assert_eq!(fb.requests[0].retain, 8);
        let input = fb.to_input();
        assert_eq!(input.prefix_retain[0], 8);
        assert!(input.prefix_adopt.is_empty(), "no adoptions in this batch");
        assert_eq!(b.cached_prefix_entries(), 1);
        assert_eq!(b.prefix_hit_counts(), (0, 1));
        // a sub-block prompt neither matches nor registers
        b.push_at(Request::new(2, vec![9, 9, 9]), old).unwrap();
        let fb = b.form(Instant::now()).unwrap();
        assert_eq!(fb.requests[0].retain, 0);
        assert!(fb.to_input().prefix_retain.is_empty());
        assert_eq!(b.cached_prefix_entries(), 1);
    }

    #[test]
    fn prefix_hit_converts_prefill_into_stepping_decode() {
        let mut b = prefix_batcher();
        let old = Instant::now() - Duration::from_millis(20);
        seed_donor(&mut b, 1, (0..10).collect());
        // same first 8 tokens, different tail: adopt 8, step from there
        let prompt: Vec<i32> = (0..8).chain([50, 51, 52, 53]).collect();
        b.push_at(Request::new(2, prompt), old).unwrap();
        let fb = b.form(Instant::now()).expect("hit forms as a decode step");
        assert_eq!(fb.phase, Phase::Decode);
        let r = &fb.requests[0];
        assert_eq!(r.adopt, Some((1, 8)));
        assert_eq!(r.adopted, 8);
        assert_eq!(r.len(), 9, "adopted prefix + the first stepped position");
        assert_eq!(*r.tokens.last().unwrap(), 50);
        let input = fb.to_input();
        assert_eq!(input.prefix_adopt[0], Some((1, 8)));
        assert_eq!(b.prefix_hit_counts().0, 1);
        // the token budget meters the computed suffix only
        assert_eq!(b.active_tokens[&2], 1);
        // continuations keep the discount
        b.requeue_front(Request::decode(2, vec![0; 10]).with_adopted(8), old);
        assert_eq!(b.active_tokens[&2], 2);
    }

    #[test]
    fn prefix_match_never_covers_the_final_prompt_position() {
        let mut b = prefix_batcher();
        let old = Instant::now() - Duration::from_millis(20);
        seed_donor(&mut b, 1, (0..8).collect());
        // identical 8-token prompt: the last position must be computed
        // fresh (its logits are the first sampled token), so only the
        // first block can be adopted
        b.push_at(Request::new(2, (0..8).collect()), old).unwrap();
        let fb = b.form(Instant::now()).unwrap();
        assert_eq!(fb.phase, Phase::Decode);
        assert_eq!(fb.requests[0].adopt, Some((1, 4)));
    }

    #[test]
    fn purge_of_queued_registrant_drops_its_trie_entry() {
        let mut b = prefix_batcher();
        // fresh arrival: form() registers the prompt but waits for the
        // batching timeout, leaving the registrant queued
        b.push_at(Request::new(1, (0..8).collect()), Instant::now()).unwrap();
        assert!(b.form(Instant::now()).is_none());
        assert_eq!(b.cached_prefix_entries(), 1);
        assert!(b.purge(1));
        assert_eq!(b.cached_prefix_entries(), 0);
        // the eviction publishes (a no-op on workers that never retained)
        assert_eq!(b.take_prefix_evictions(), vec![1]);
    }

    #[test]
    fn adoption_lease_pins_entry_until_first_step_completes() {
        let mut b = batcher().with_decode_widths(vec![1, 2, 4]).with_prefix_cache(4, 1);
        let old = Instant::now() - Duration::from_millis(20);
        seed_donor(&mut b, 1, (0..8).collect());
        // an adopter forms against entry 1 and holds a lease on it
        b.push_at(Request::new(2, (0..6).chain([60, 61]).collect()), old).unwrap();
        let fb = b.form(Instant::now()).unwrap();
        assert_eq!(fb.requests[0].adopt, Some((1, 4)));
        // a second donor overflows the 1-entry cap, but the leased entry
        // cannot be evicted yet
        seed_donor(&mut b, 3, vec![9; 8]);
        assert!(b.take_prefix_evictions().is_empty());
        assert_eq!(b.cached_prefix_entries(), 2);
        // the adopter's first step completes: the lease releases and the
        // FIFO eviction resumes (oldest entry goes)
        b.requeue_front(Request::decode(2, vec![0; 9]).with_adopted(4), old);
        assert_eq!(b.take_prefix_evictions(), vec![1]);
        assert_eq!(b.cached_prefix_entries(), 1);
    }

    #[test]
    fn prefix_registry_blocks_charge_and_credit_the_tier_model() {
        let mut b = batcher()
            .with_decode_widths(vec![1, 2, 4])
            .with_prefix_cache(8, 0)
            .with_tier(TierPolicy::new(TierConfig::new(64, 64), 8));
        let old = Instant::now() - Duration::from_millis(20);
        b.push_at(req(1, 16), old).unwrap();
        b.form(Instant::now()).expect("prefill forms");
        // session blocks (2) + registry's own hold (2)
        assert_eq!(b.tier().unwrap().device_used(), 4);
        b.requeue_front(Request::decode(1, vec![1; 17]), old);
        b.form(Instant::now()).expect("continuation forms");
        b.tier_free(&[1]);
        // the session's blocks are credited; the registry entry remains
        assert_eq!(b.tier().unwrap().device_used(), 2);
        b.prefix_drop(&[1]);
        assert_eq!(b.take_prefix_evictions(), vec![1]);
        assert_eq!(b.tier().unwrap().device_used(), 0);
        // shared registrants are spill-exempt while alive
        b.push_at(req(2, 16), old).unwrap();
        b.form(Instant::now()).expect("second prefill forms");
        b.requeue_front(Request::decode(2, vec![1; 17]), old);
        assert!(
            b.tier().unwrap().is_resident(2) == Some(true),
            "registrant stays resident (shared sessions are never victims)"
        );
    }

    fn chunk_batcher() -> Batcher {
        batcher()
            .with_decode_widths(vec![1, 2, 4])
            .with_chunked_prefill(vec![(1, 2), (2, 2), (4, 2), (1, 4), (2, 4), (4, 4)], 1)
    }

    #[test]
    fn chunking_off_or_short_prompts_stay_monolithic() {
        // no chunk points: byte-identical to the pre-chunking batcher
        let old = Instant::now() - Duration::from_millis(20);
        let mut b = batcher();
        b.push_at(req(1, 12), old).unwrap();
        assert_eq!(b.form(Instant::now()).unwrap().phase, Phase::Prefill);
        // chunking on, prompt fits one window: still monolithic
        let mut b = chunk_batcher();
        b.push_at(req(2, 4), old).unwrap();
        let fb = b.form(Instant::now()).unwrap();
        assert_eq!(fb.phase, Phase::Prefill);
        assert_eq!(fb.requests[0].chunk_len, 0);
    }

    #[test]
    fn long_prompt_converts_to_first_chunk_wave() {
        let mut b = chunk_batcher();
        let old = Instant::now() - Duration::from_millis(20);
        b.push_at(req(1, 12), old).unwrap();
        let fb = b.form(Instant::now()).expect("first chunk wave forms");
        assert_eq!(fb.phase, Phase::Chunk);
        assert_eq!(fb.bucket, (1, 4), "largest compiled window is the chunk size");
        let r = &fb.requests[0];
        assert_eq!((r.chunk_start, r.chunk_len), (0, 4));
        assert_eq!(r.len(), 12, "chunk requests carry the full prompt");
        assert!(r.is_first_chunk());
        // the ledger charges the final cache length from the first chunk
        assert_eq!(b.active_token_load(), 12);
    }

    #[test]
    fn chunk_input_carries_window_tokens_and_valid() {
        let fb = FormedBatch {
            requests: vec![Request::chunk(7, (0..12).collect(), 4, 4)],
            bucket: (2, 4),
            phase: Phase::Chunk,
        };
        let input = fb.to_input();
        assert_eq!(input.phase, Phase::Chunk);
        assert_eq!(input.ids.shape, vec![2, 4]);
        // the window's own tokens, then a zeroed pad row
        assert_eq!(input.ids.data, vec![4, 5, 6, 7, 0, 0, 0, 0]);
        // valid through the window end; pad rows clamp to one window
        assert_eq!(input.valid_lens, vec![8, 4]);
        assert_eq!(input.req_ids, vec![7, u64::MAX]);
    }

    #[test]
    fn chunk_runs_never_mix_first_and_continuation() {
        let mut b = chunk_batcher();
        let old = Instant::now() - Duration::from_millis(20);
        // a fresh first chunk queued behind a mid-prompt continuation
        b.requeue_front(Request::chunk(2, vec![3; 12], 0, 4), old);
        b.requeue_front(Request::chunk(1, vec![2; 12], 4, 4), old);
        let fb = b.form(Instant::now()).unwrap();
        assert_eq!(fb.requests.len(), 1, "continuation must not share an admission bucket");
        assert_eq!(fb.requests[0].id, 1);
        let fb = b.form(Instant::now()).unwrap();
        assert_eq!(fb.requests[0].id, 2);
        assert!(fb.requests[0].is_first_chunk());
    }

    #[test]
    fn chunk_streak_rotates_behind_waiting_decodes() {
        let mut b = chunk_batcher(); // decode-interleave ratio 1
        let old = Instant::now() - Duration::from_millis(20);
        b.push_at(req(1, 12), old).unwrap();
        assert_eq!(b.form(Instant::now()).unwrap().phase, Phase::Chunk); // streak 1
        // the continuation re-enters the front while a decode waits
        b.requeue_front(Request::decode(9, vec![5; 6]), old);
        b.requeue_front(Request::chunk(1, vec![1; 12], 4, 4), old);
        let fb = b.form(Instant::now()).expect("decode must go first");
        assert_eq!(fb.phase, Phase::Decode);
        assert_eq!(fb.requests[0].id, 9);
        // the streak reset: the chunk wave follows immediately
        let fb = b.form(Instant::now()).unwrap();
        assert_eq!(fb.phase, Phase::Chunk);
        assert_eq!(fb.requests[0].chunk_start, 4);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn chunk_streak_ratio_admits_consecutive_waves() {
        let mut b = batcher()
            .with_decode_widths(vec![1, 2, 4])
            .with_chunked_prefill(vec![(1, 4), (2, 4), (4, 4)], 2);
        let old = Instant::now() - Duration::from_millis(20);
        b.push_at(req(1, 12), old).unwrap();
        assert_eq!(b.form(Instant::now()).unwrap().phase, Phase::Chunk); // streak 1
        b.requeue_front(Request::decode(9, vec![5; 6]), old);
        b.requeue_front(Request::chunk(1, vec![1; 12], 4, 4), old);
        // ratio 2: one wave so far, the chunk still leads the decode
        let fb = b.form(Instant::now()).unwrap();
        assert_eq!(fb.phase, Phase::Chunk); // streak 2
        b.requeue_front(Request::chunk(1, vec![1; 12], 8, 4), old);
        let fb = b.form(Instant::now()).expect("streak hit the ratio: decode first");
        assert_eq!(fb.phase, Phase::Decode);
    }

    #[test]
    fn first_chunk_meters_token_budget_at_full_prompt() {
        let mut b = chunk_batcher().with_admission(0, 16);
        let old = Instant::now() - Duration::from_millis(20);
        b.push_at(req(1, 12), old).unwrap();
        assert_eq!(b.form(Instant::now()).unwrap().phase, Phase::Chunk);
        assert_eq!(b.active_token_load(), 12);
        // a second long prompt would overflow the budget: its first chunk
        // defers even though the window itself is only 4 tokens
        b.push_at(req(2, 12), old).unwrap();
        assert!(b.form(Instant::now()).is_none(), "first chunk must defer over budget");
        assert_eq!(b.budget_deferrals(), 1);
        // continuations of admitted sessions stay exempt
        b.requeue_front(Request::chunk(1, vec![1; 12], 4, 4), old);
        assert_eq!(b.form(Instant::now()).unwrap().phase, Phase::Chunk);
        // session 1 retires -> the deferred prompt's first chunk admits
        b.tier_free(&[1]);
        let fb = b.form(Instant::now()).expect("admits after release");
        assert_eq!(fb.requests[0].id, 2);
        assert!(fb.requests[0].is_first_chunk());
    }

    #[test]
    fn first_chunk_charges_tier_for_final_cache_length() {
        // bp=8: a len-12 prompt needs 2 blocks; device holds exactly 2
        let mut b = chunk_batcher().with_tier(TierPolicy::new(TierConfig::new(2, 64), 8));
        let old = Instant::now() - Duration::from_millis(20);
        b.push_at(req(1, 12), old).unwrap();
        let fb = b.form(Instant::now()).expect("first chunk admits");
        assert_eq!(fb.phase, Phase::Chunk);
        assert_eq!(
            b.tier().unwrap().device_used(),
            2,
            "admission charges the final cache length, not the window"
        );
        // a second long prompt cannot fit while session 1 is pinned
        b.push_at(req(2, 12), old).unwrap();
        assert!(b.form(Instant::now()).is_none(), "must defer while 1 is pinned");
        // continuations pass the decode-style gate without re-charging
        b.requeue_front(Request::chunk(1, vec![1; 12], 4, 4), old);
        let fb = b.form(Instant::now()).expect("continuation forms");
        assert_eq!(fb.phase, Phase::Chunk);
        assert_eq!(b.tier().unwrap().device_used(), 2);
        b.tier_free(&[1]);
        assert!(b.form(Instant::now()).is_some(), "deferred prompt admits after free");
    }

    #[test]
    fn chunked_registrant_matchable_only_after_crossing_chunk() {
        let mut b = prefix_batcher()
            .with_chunked_prefill(vec![(1, 4), (2, 4), (4, 4)], 1);
        let old = Instant::now() - Duration::from_millis(20);
        // 12 tokens, block 4: retention would be 12 but caps one position
        // short of the prompt end -> 8, crossed by the second chunk
        b.push_at(Request::new(1, (0..12).collect()), old).unwrap();
        let fb = b.form(Instant::now()).expect("first chunk forms");
        assert_eq!(fb.phase, Phase::Chunk);
        assert_eq!(fb.requests[0].retain, 8);
        let input = fb.to_input();
        assert!(
            input.prefix_retain.is_empty(),
            "retention must not materialize before the crossing chunk"
        );
        // chunk 2 (positions 4..8) crosses the boundary, but at requeue
        // time it hasn't run: the entry stays unmatchable
        let mut c2 = Request::chunk(1, (0..12).collect(), 4, 4);
        c2.retain = 8;
        b.requeue_front(c2, old);
        b.push_at(Request::new(2, (0..8).chain([50, 51, 52, 53]).collect()), old).unwrap();
        let fb = b.form(Instant::now()).expect("continuation forms");
        assert_eq!(fb.to_input().prefix_retain, vec![8], "crossing chunk retains");
        let fb = b.form(Instant::now()).expect("prompt 2 forms");
        assert_eq!(fb.phase, Phase::Chunk);
        assert!(fb.requests[0].adopt.is_none(), "entry not ready: no match yet");
        assert_eq!(b.prefix_hit_counts().0, 0);
        // chunk 3 requeues with the boundary behind it: entry goes ready
        let mut c3 = Request::chunk(1, (0..12).collect(), 8, 4);
        c3.retain = 8;
        b.requeue_front(c3, old);
        let fb = b.form(Instant::now()).expect("chunk 3 forms");
        assert_eq!(fb.requests[0].id, 1);
        // a third templated prompt now adopts and chunk-walks the suffix
        b.push_at(Request::new(3, (0..8).chain([60, 61, 62, 63]).collect()), old).unwrap();
        let fb = b.form(Instant::now()).expect("hit forms");
        assert_eq!(fb.phase, Phase::Chunk);
        let r = &fb.requests[0];
        assert_eq!(r.adopt, Some((1, 8)));
        assert_eq!((r.chunk_start, r.chunk_len, r.adopted), (8, 4, 8));
        assert!(r.is_first_chunk());
        assert_eq!(b.prefix_hit_counts().0, 1);
        // the budget meters only the computed suffix
        assert_eq!(b.active_tokens[&3], 4);
    }

    #[test]
    fn purge_of_mid_chunk_registrant_drops_its_trie_entry() {
        let mut b = prefix_batcher()
            .with_chunked_prefill(vec![(1, 4), (2, 4), (4, 4)], 1);
        let old = Instant::now() - Duration::from_millis(20);
        b.push_at(Request::new(1, (0..12).collect()), old).unwrap();
        let fb = b.form(Instant::now()).expect("first chunk forms");
        assert_eq!(fb.phase, Phase::Chunk);
        assert_eq!(b.cached_prefix_entries(), 1);
        // the continuation is queued but the retention boundary (8) is
        // still ahead: cancelling now must drop the unready entry
        let mut c2 = Request::chunk(1, (0..12).collect(), 4, 4);
        c2.retain = 8;
        b.requeue_front(c2, old);
        assert!(b.purge(1));
        assert_eq!(b.cached_prefix_entries(), 0);
        assert_eq!(b.take_prefix_evictions(), vec![1]);
    }
}
