//! Chaos fault injection: a seeded, config-driven plan that perturbs
//! worker replies for chosen tickets, so the failure machinery the
//! coordinator carries around — the collector watchdog's poison/cascade
//! path, the spill-tier anomaly counters, shutdown drain under wedged
//! batches — is deterministically testable instead of dead code.
//!
//! A [`FaultPlan`] is parsed from a compact spec string
//! (`engine.fault_plan` in the config file / `LaunchConfig::with_faults`)
//! and consulted by every worker at the reply boundary of each `Forward`
//! ticket. Grammar: comma-separated directives, each
//!
//! ```text
//! <kind>@<selector>[@w<rank>][@r<id>]
//!
//! kind:      delay<N>ms | delay<N>us   sleep before replying (a stalled
//!                                      worker; the batch completes late)
//!            drop                      execute but never reply (a wedged
//!                                      worker; the watchdog must poison)
//!            panic                     reply with an injected error (a
//!                                      crashed worker; the collector's
//!                                      error path fails the batch)
//! selector:  t<N>                      exactly ticket N
//!            t<A>..<B>                 tickets A..=B
//!            every<M>+<K>              tickets where ticket % M == K
//!            p<F>                      probability F per ticket, decided
//!                                      by a hash of (plan seed, ticket) —
//!                                      reproducible across runs and
//!                                      identical on every worker
//! ```
//!
//! Examples: `delay5ms@t3`, `drop@t7@w0`, `panic@every16+5`,
//! `delay250us@p0.1`. Faults are keyed by the consistency-queue ticket, so
//! the same plan hits the same logical batch on every run of a seeded
//! workload — and because every worker evaluates the same pure function,
//! an unscoped directive perturbs all ranks coherently while `@w<rank>`
//! confines it to one (the asymmetric case the watchdog exists for).
//!
//! `@r<id>` confines a directive to one *replica* of a fleet (scopes
//! combine in either order, each at most once, e.g. `drop@t7@w0@r2`).
//! Replica identity lives in the fleet router, not the engine: the fleet
//! splits a plan with [`FaultPlan::split_for_replicas`] and hands each
//! engine its own scope-stripped spec, so on a standalone engine (which
//! has no replica identity) a replica-scoped directive never fires.

use std::time::Duration;

/// What to do to one worker's handling of one ticket.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Sleep this long before replying.
    Delay(Duration),
    /// Execute but suppress the reply entirely (watchdog path).
    Drop,
    /// Replace the reply with an injected error (crash path).
    Panic,
}

/// Which tickets a directive selects.
#[derive(Clone, Copy, Debug, PartialEq)]
enum Select {
    Exact(u64),
    Range(u64, u64),
    Every { modulo: u64, phase: u64 },
    Prob(f64),
}

impl Select {
    fn hits(&self, seed: u64, ticket: u64) -> bool {
        match *self {
            Select::Exact(n) => ticket == n,
            Select::Range(a, b) => (a..=b).contains(&ticket),
            Select::Every { modulo, phase } => ticket % modulo == phase,
            Select::Prob(p) => hash01(seed, ticket) < p,
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq)]
struct Directive {
    kind: FaultKind,
    sel: Select,
    /// Restrict to one worker's world rank (`stage * tp + tp_rank`);
    /// `None` hits every rank.
    worker: Option<usize>,
    /// Restrict to one fleet replica. Engines never carry a replica
    /// identity, so a scoped directive is inert until the fleet strips
    /// the scope via [`FaultPlan::split_for_replicas`].
    replica: Option<usize>,
}

/// A parsed, immutable fault schedule. The empty plan (default) is free:
/// workers skip the lookup entirely.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    seed: u64,
    directives: Vec<Directive>,
}

/// splitmix64-style hash of (seed, ticket) folded into [0, 1) — the
/// probabilistic selector's coin, identical on every worker.
fn hash01(seed: u64, ticket: u64) -> f64 {
    let mut z = seed ^ ticket.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^= z >> 31;
    (z >> 11) as f64 / (1u64 << 53) as f64
}

impl FaultPlan {
    /// Parse a spec string (see module docs). The empty string is the
    /// empty plan. `seed` drives only the `p<F>` selectors.
    pub fn parse(spec: &str, seed: u64) -> anyhow::Result<FaultPlan> {
        let mut directives = Vec::new();
        for entry in spec.split(',').map(str::trim).filter(|e| !e.is_empty()) {
            directives.push(parse_directive(entry)?);
        }
        Ok(FaultPlan { seed, directives })
    }

    pub fn is_empty(&self) -> bool {
        self.directives.is_empty()
    }

    /// The fault (if any) this worker must apply to this ticket. First
    /// matching directive wins. Replica-scoped directives never fire
    /// here: an engine has no replica identity — the fleet router strips
    /// the scope before the plan reaches an engine.
    pub fn action(&self, worker_rank: usize, ticket: u64) -> Option<FaultKind> {
        self.directives
            .iter()
            .find(|d| {
                d.replica.is_none()
                    && d.worker.map_or(true, |w| w == worker_rank)
                    && d.sel.hits(self.seed, ticket)
            })
            .map(|d| d.kind)
    }

    /// Partition a replica-scoped spec into one engine-ready spec per
    /// replica: an `@r<id>` directive lands only in replica `id`'s spec
    /// (with the scope stripped — engines stay replica-unaware), an
    /// unscoped directive lands in every spec. The whole spec is
    /// validated up front, including that every referenced replica
    /// exists in a fleet of `replicas`.
    pub fn split_for_replicas(spec: &str, replicas: usize) -> anyhow::Result<Vec<String>> {
        FaultPlan::parse(spec, 0)?;
        let mut out = vec![Vec::<String>::new(); replicas];
        for entry in spec.split(',').map(str::trim).filter(|e| !e.is_empty()) {
            let mut replica = None;
            let mut kept = Vec::new();
            for (i, seg) in entry.split('@').enumerate() {
                // only scope positions (after kind@selector) can carry @r
                if i >= 2 {
                    if let Some(id) = seg.strip_prefix('r').and_then(|r| r.parse::<usize>().ok()) {
                        replica = Some(id);
                        continue;
                    }
                }
                kept.push(seg);
            }
            let stripped = kept.join("@");
            match replica {
                Some(id) => {
                    anyhow::ensure!(
                        id < replicas,
                        "fault directive {entry:?}: replica r{id} out of range (fleet has {replicas})"
                    );
                    out[id].push(stripped);
                }
                None => {
                    for per_replica in &mut out {
                        per_replica.push(stripped.clone());
                    }
                }
            }
        }
        Ok(out.into_iter().map(|v| v.join(",")).collect())
    }
}

fn parse_directive(entry: &str) -> anyhow::Result<Directive> {
    let mut parts = entry.split('@');
    let kind_s = parts.next().unwrap_or("");
    let sel_s = parts.next();
    let scope_a = parts.next();
    let scope_b = parts.next();
    anyhow::ensure!(
        parts.next().is_none(),
        "fault directive {entry:?}: too many '@' segments (kind@selector[@w<rank>][@r<id>])"
    );

    let kind = if kind_s == "drop" {
        FaultKind::Drop
    } else if kind_s == "panic" {
        FaultKind::Panic
    } else if let Some(d) = kind_s.strip_prefix("delay") {
        let (num, unit): (&str, fn(u64) -> Duration) = if let Some(n) = d.strip_suffix("ms") {
            (n, Duration::from_millis)
        } else if let Some(n) = d.strip_suffix("us") {
            (n, Duration::from_micros)
        } else {
            anyhow::bail!("fault directive {entry:?}: delay needs a ms/us suffix (e.g. delay5ms)");
        };
        let n: u64 = num
            .parse()
            .map_err(|_| anyhow::anyhow!("fault directive {entry:?}: bad delay amount {num:?}"))?;
        FaultKind::Delay(unit(n))
    } else {
        anyhow::bail!("fault directive {entry:?}: kind must be delay<N>ms|delay<N>us|drop|panic");
    };

    let sel_s = sel_s
        .ok_or_else(|| anyhow::anyhow!("fault directive {entry:?}: missing @<selector>"))?;
    let sel = parse_select(entry, sel_s)?;

    let mut worker = None;
    let mut replica = None;
    for scope in [scope_a, scope_b].into_iter().flatten() {
        if let Some(rank) = scope.strip_prefix('w').and_then(|r| r.parse::<usize>().ok()) {
            anyhow::ensure!(
                worker.is_none(),
                "fault directive {entry:?}: duplicate w<rank> scope"
            );
            worker = Some(rank);
        } else if let Some(id) = scope.strip_prefix('r').and_then(|r| r.parse::<usize>().ok()) {
            anyhow::ensure!(
                replica.is_none(),
                "fault directive {entry:?}: duplicate r<id> scope"
            );
            replica = Some(id);
        } else {
            anyhow::bail!("fault directive {entry:?}: scope must be w<rank> or r<id>");
        }
    }
    Ok(Directive { kind, sel, worker, replica })
}

fn parse_select(entry: &str, sel: &str) -> anyhow::Result<Select> {
    if let Some(t) = sel.strip_prefix('t') {
        if let Some((a, b)) = t.split_once("..") {
            let a: u64 = a
                .parse()
                .map_err(|_| anyhow::anyhow!("fault directive {entry:?}: bad range start"))?;
            let b: u64 = b
                .parse()
                .map_err(|_| anyhow::anyhow!("fault directive {entry:?}: bad range end"))?;
            anyhow::ensure!(a <= b, "fault directive {entry:?}: range start > end");
            return Ok(Select::Range(a, b));
        }
        let n: u64 = t
            .parse()
            .map_err(|_| anyhow::anyhow!("fault directive {entry:?}: bad ticket number"))?;
        return Ok(Select::Exact(n));
    }
    if let Some(e) = sel.strip_prefix("every") {
        let (m, k) = e.split_once('+').ok_or_else(|| {
            anyhow::anyhow!("fault directive {entry:?}: every selector is every<M>+<K>")
        })?;
        let m: u64 =
            m.parse().map_err(|_| anyhow::anyhow!("fault directive {entry:?}: bad modulo"))?;
        let k: u64 =
            k.parse().map_err(|_| anyhow::anyhow!("fault directive {entry:?}: bad phase"))?;
        anyhow::ensure!(m >= 1 && k < m, "fault directive {entry:?}: need M >= 1 and K < M");
        return Ok(Select::Every { modulo: m, phase: k });
    }
    if let Some(p) = sel.strip_prefix('p') {
        let p: f64 = p
            .parse()
            .map_err(|_| anyhow::anyhow!("fault directive {entry:?}: bad probability"))?;
        anyhow::ensure!((0.0..=1.0).contains(&p), "fault directive {entry:?}: p out of [0,1]");
        return Ok(Select::Prob(p));
    }
    anyhow::bail!("fault directive {entry:?}: selector must be t<N>|t<A>..<B>|every<M>+<K>|p<F>")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_plan_never_fires() {
        let p = FaultPlan::parse("", 1).unwrap();
        assert!(p.is_empty());
        for t in 0..100 {
            assert_eq!(p.action(0, t), None);
        }
        assert_eq!(FaultPlan::default(), p);
    }

    #[test]
    fn exact_range_and_modular_selectors() {
        let p = FaultPlan::parse("drop@t7, panic@t10..12, delay5ms@every8+3", 0).unwrap();
        assert_eq!(p.action(0, 7), Some(FaultKind::Drop));
        assert_eq!(p.action(3, 7), Some(FaultKind::Drop), "unscoped hits every rank");
        assert_eq!(p.action(0, 8), None);
        for t in 10..=12 {
            assert_eq!(p.action(1, t), Some(FaultKind::Panic));
        }
        assert_eq!(p.action(1, 13), None);
        assert_eq!(p.action(0, 3), Some(FaultKind::Delay(Duration::from_millis(5))));
        assert_eq!(p.action(0, 11 + 8), Some(FaultKind::Delay(Duration::from_millis(5))));
        assert_eq!(p.action(0, 4), None);
    }

    #[test]
    fn worker_scope_confines_the_fault() {
        let p = FaultPlan::parse("drop@t5@w1", 0).unwrap();
        assert_eq!(p.action(1, 5), Some(FaultKind::Drop));
        assert_eq!(p.action(0, 5), None);
        assert_eq!(p.action(2, 5), None);
    }

    #[test]
    fn replica_scope_parses_but_is_inert_on_a_bare_engine() {
        // scopes combine in either order, each at most once
        for spec in ["drop@t5@r1", "drop@t5@w0@r1", "drop@t5@r1@w0"] {
            let p = FaultPlan::parse(spec, 0).unwrap();
            assert!(!p.is_empty());
            // an engine has no replica identity: the directive never fires
            for rank in 0..4 {
                assert_eq!(p.action(rank, 5), None, "{spec}");
            }
        }
    }

    #[test]
    fn split_for_replicas_partitions_and_strips_the_scope() {
        let spec = "delay5ms@t3, drop@t7@r1, panic@t9@r0@w2, drop@every4+1@w0@r1";
        let per = FaultPlan::split_for_replicas(spec, 2).unwrap();
        assert_eq!(per[0], "delay5ms@t3,panic@t9@w2");
        assert_eq!(per[1], "delay5ms@t3,drop@t7,drop@every4+1@w0");
        // the stripped specs parse, and now fire on their engine
        let p0 = FaultPlan::parse(&per[0], 0).unwrap();
        assert_eq!(p0.action(2, 9), Some(FaultKind::Panic));
        assert_eq!(p0.action(0, 7), None, "r1's directive must not leak into r0");
        let p1 = FaultPlan::parse(&per[1], 0).unwrap();
        assert_eq!(p1.action(0, 7), Some(FaultKind::Drop));
        // unscoped spec fans out to every replica; empty spec stays empty
        assert_eq!(FaultPlan::split_for_replicas("drop@t1", 3).unwrap(), vec![
            "drop@t1".to_string(),
            "drop@t1".to_string(),
            "drop@t1".to_string()
        ]);
        assert_eq!(FaultPlan::split_for_replicas("", 2).unwrap(), vec!["", ""]);
        // a directive naming a replica outside the fleet is an error
        assert!(FaultPlan::split_for_replicas("drop@t1@r5", 2).is_err());
        // and a malformed spec fails validation before partitioning
        assert!(FaultPlan::split_for_replicas("drop@t1@q2", 2).is_err());
    }

    #[test]
    fn first_match_wins() {
        let p = FaultPlan::parse("panic@t4, drop@every2+0", 0).unwrap();
        assert_eq!(p.action(0, 4), Some(FaultKind::Panic));
        assert_eq!(p.action(0, 6), Some(FaultKind::Drop));
    }

    #[test]
    fn probabilistic_selector_is_seeded_and_rank_coherent() {
        let p = FaultPlan::parse("drop@p0.25", 42).unwrap();
        let hits: Vec<u64> = (0..400).filter(|&t| p.action(0, t).is_some()).collect();
        // ~25% fire, and the same set fires again (same seed, any rank)
        assert!((50..150).contains(&hits.len()), "{} hits", hits.len());
        let again: Vec<u64> = (0..400).filter(|&t| p.action(3, t).is_some()).collect();
        assert_eq!(hits, again, "plan must be deterministic and rank-coherent");
        // a different seed selects a different set
        let q = FaultPlan::parse("drop@p0.25", 43).unwrap();
        let other: Vec<u64> = (0..400).filter(|&t| q.action(0, t).is_some()).collect();
        assert_ne!(hits, other);
        // p0 never fires, p1 always fires
        let never = FaultPlan::parse("drop@p0.0", 1).unwrap();
        assert!((0..100).all(|t| never.action(0, t).is_none()));
        let always = FaultPlan::parse("drop@p1.0", 1).unwrap();
        assert!((0..100).all(|t| always.action(0, t).is_some()));
    }

    #[test]
    fn delay_units_parse() {
        let p = FaultPlan::parse("delay250us@t1", 0).unwrap();
        assert_eq!(p.action(0, 1), Some(FaultKind::Delay(Duration::from_micros(250))));
        let p = FaultPlan::parse("delay2ms@t1", 0).unwrap();
        assert_eq!(p.action(0, 1), Some(FaultKind::Delay(Duration::from_millis(2))));
    }

    #[test]
    fn malformed_specs_are_errors() {
        for bad in [
            "explode@t1",
            "delay@t1",
            "delay5@t1",
            "delayxms@t1",
            "drop",
            "drop@x3",
            "drop@t1..0",
            "drop@every0+0",
            "drop@every4+4",
            "drop@p1.5",
            "drop@pabc",
            "drop@t1@q2",
            "drop@t1@w2@extra",
            "drop@t1@r",
            "drop@t1@rx",
            "drop@t1@r1@r2",
            "drop@t1@w0@w1",
            "drop@t1@w0@r1@r2",
        ] {
            assert!(FaultPlan::parse(bad, 0).is_err(), "{bad:?} should not parse");
        }
    }
}
