//! EnergonAI launcher — the CLI the paper's "launch tool" corresponds to
//! (§5.2: "we provide a launch tool for initializing the global
//! communication context and the RPC context. User can specify the size
//! of tensor parallelism and pipeline parallelism in the launch tool").
//!
//! Subcommands:
//!   serve      run the TCP serving front-end over a live engine
//!   demo       submit a few requests and print tokens + metrics
//!   bench      regenerate the paper's figures (fig2|fig10|fig11|fig12|
//!              fig13|crossover|all) from the calibrated simulators
//!   info       list model presets and the GPT family table
//!
//! Common flags: --preset tiny|small|base  --tp N  --pp N  --drce
//!               --blocking  --layers N  --seed N

use energonai::baselines;
use energonai::config::ModelConfig;
use energonai::coordinator::engine::{Engine, LaunchConfig, MemoryMode};
use energonai::memory::pool::PoolConfig;
use energonai::server::Server;
use energonai::sim::report;
use energonai::util::cli::Args;
use energonai::workload::{Generator, LengthDist};
use std::sync::Arc;

fn main() {
    let args = Args::from_env();
    let code = match args.subcommand.as_deref() {
        Some("serve") => cmd_serve(&args),
        Some("demo") => cmd_demo(&args),
        Some("generate") => cmd_generate(&args),
        Some("bench") => cmd_bench(&args),
        Some("info") => cmd_info(),
        _ => {
            eprint!("{}", USAGE);
            2
        }
    };
    std::process::exit(code);
}

const USAGE: &str = "\
energonai — hierarchy-controller inference system (EnergonAI reproduction)

USAGE:
  energonai serve  [--preset tiny] [--tp 1] [--pp 1] [--drce] [--addr 127.0.0.1:7070]
  energonai demo   [--preset tiny] [--tp 1] [--pp 1] [--drce] [--requests 8]
  energonai generate [--prompt 1,2,3] [--tokens 8] [--preset tiny]
  energonai bench  <fig2|fig10|fig11|fig12|fig13|crossover|all>
  energonai info

Any engine subcommand also accepts --config <file.toml> (CLI flags override).
";

fn cmd_generate(args: &Args) -> i32 {
    let engine = match launch_from_args(args) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("launch failed: {e:#}");
            return 1;
        }
    };
    let prompt: Vec<i32> = args
        .get_or("prompt", "1,2,3")
        .split(',')
        .filter_map(|t| t.trim().parse().ok())
        .collect();
    let n = args.usize("tokens", 8);
    if n == 0 {
        // engine.generate owns the n==0 semantics (validates the prompt,
        // returns it unchanged)
        match engine.generate(prompt.clone(), 0) {
            Ok(tokens) => {
                println!("prompt {:?}", prompt);
                println!("output {:?}", tokens);
            }
            Err(e) => {
                eprintln!("generate failed: {e:#}");
                return 1;
            }
        }
        engine.shutdown();
        return 0;
    }
    // stream tokens as the scheduler produces them, then print the result
    let gref = match engine
        .generate_stream(energonai::coordinator::GenRequest::new(prompt.clone(), n))
    {
        Ok(g) => g,
        Err(e) => {
            eprintln!("generate failed: {e:#}");
            return 1;
        }
    };
    println!("prompt {:?}", prompt);
    print!("tokens ");
    loop {
        match gref.next() {
            Ok(Some(t)) => {
                print!("{t} ");
                use std::io::Write;
                let _ = std::io::stdout().flush();
            }
            Ok(None) => break,
            Err(e) => {
                eprintln!("\ngenerate failed: {e:#}");
                return 1;
            }
        }
    }
    println!();
    match gref.to_here() {
        Ok(tokens) => println!("output {:?}", tokens),
        Err(e) => {
            eprintln!("generate failed: {e:#}");
            return 1;
        }
    }
    engine.shutdown();
    0
}

fn launch_from_args(args: &Args) -> anyhow::Result<Engine> {
    // config file first; CLI flags override
    if let Some(path) = args.get("config") {
        let mut launch = energonai::config::file::launch_from_file(path)?;
        if let Some(tp) = args.get("tp") {
            let pp = launch.parallel.pp;
            launch = launch.with_parallel(tp.parse()?, pp);
        }
        if let Some(pp) = args.get("pp") {
            let tp = launch.parallel.tp;
            launch = launch.with_parallel(tp, pp.parse()?);
        }
        if args.flag("drce") {
            launch = launch.with_drce(true);
        }
        println!(
            "launching from {path}: {} (tp={}, pp={}, drce={})...",
            launch.preset, launch.parallel.tp, launch.parallel.pp, launch.engine.drce
        );
        return Engine::launch(launch);
    }
    let preset = args.get_or("preset", "tiny");
    let tp = args.usize("tp", 1);
    let pp = args.usize("pp", 1);
    let mut launch = if args.flag("blocking") || args.get("baseline") == Some("ft") {
        baselines::fastertransformer(preset, tp, pp)
    } else {
        LaunchConfig::preset(preset).with_parallel(tp, pp)
    };
    launch = launch
        .with_drce(args.flag("drce"))
        .with_warmup(!args.flag("no-warmup"));
    if let Some(n) = args.get("layers") {
        launch = launch.with_layers(n.parse()?);
    }
    launch.seed = args.usize("seed", 42) as u64;
    if let Some(n_local) = args.get("pmep-local") {
        launch = launch.with_memory(MemoryMode::Pmep {
            n_local: n_local.parse()?,
            pool: PoolConfig::pmep(),
        });
    } else if let Some(n_local) = args.get("bminf-local") {
        launch = launch.with_memory(MemoryMode::Bminf { n_local: n_local.parse()? });
    }
    println!(
        "launching {} (tp={tp}, pp={pp}, drce={}, blocking={})...",
        preset, launch.engine.drce, launch.engine.blocking_comms
    );
    Engine::launch(launch)
}

fn cmd_serve(args: &Args) -> i32 {
    let engine = match launch_from_args(args) {
        Ok(e) => Arc::new(e),
        Err(e) => {
            eprintln!("launch failed: {e:#}");
            return 1;
        }
    };
    let addr = args.get_or("addr", "127.0.0.1:7070");
    match Server::start(engine, addr) {
        Ok(server) => {
            println!(
                "serving on {} — protocol: `infer 1,2,3` | `gen 8 1,2,3` | `stats` | `quit`",
                server.addr
            );
            // serve until killed
            loop {
                std::thread::sleep(std::time::Duration::from_secs(3600));
            }
        }
        Err(e) => {
            eprintln!("bind failed: {e:#}");
            1
        }
    }
}

fn cmd_demo(args: &Args) -> i32 {
    let engine = match launch_from_args(args) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("launch failed: {e:#}");
            return 1;
        }
    };
    let n = args.usize("requests", 8);
    let mut gen = Generator::new(7, LengthDist::Uniform(3, 12), engine.cfg.vocab);
    println!("submitting {n} requests through the dynamic batcher...");
    let futs: Vec<_> = (0..n).map(|_| engine.submit(gen.request().tokens).unwrap()).collect();
    for (i, f) in futs.iter().enumerate() {
        match f.to_here() {
            Ok(tok) => println!("  request {i}: next token {tok}"),
            Err(e) => println!("  request {i}: error {e}"),
        }
    }
    println!("{}", engine.metrics_snapshot().summary());
    engine.shutdown();
    0
}

fn cmd_bench(args: &Args) -> i32 {
    let which = args.positional.first().map(String::as_str).unwrap_or("all");
    let tables: Vec<(&str, fn() -> String)> = vec![
        ("fig2", report::fig2),
        ("fig10", report::fig10),
        ("fig11", report::fig11),
        ("fig12", report::fig12),
        ("fig13", report::fig13),
        ("crossover", report::crossover),
    ];
    let mut found = false;
    for (name, f) in tables {
        if which == "all" || which == name {
            println!("{}", f());
            found = true;
        }
    }
    if !found {
        eprintln!("unknown figure {which:?}; expected fig2|fig10|fig11|fig12|fig13|crossover|all");
        return 2;
    }
    0
}

fn cmd_info() -> i32 {
    println!("presets (real PJRT execution):");
    for p in ["tiny", "small", "base", "gpt3"] {
        println!("  {}", ModelConfig::preset(p).unwrap());
    }
    println!("\nGPT family (Fig. 2 / paper-scale simulation):");
    for c in ModelConfig::gpt_family() {
        println!("  {c}");
    }
    0
}
