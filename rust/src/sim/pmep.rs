//! Peer-memory-pooling timeline simulation — regenerates Fig. 13
//! (throughput in TFLOPS for 20/24/30/40-layer GPT-3 on one computing
//! GPU, offloading to a peer GPU via PMEP vs to host memory via
//! BMInf-style synchronous offload).
//!
//! The schedule mirrors `memory::pool::PooledProvider`:
//! * PMEP: a copy stream prefetches the next off-device layer while the
//!   compute stream runs; compute for layer k stalls only if its copy
//!   hasn't landed (§4.4, Fig. 8). Layer placement comes from the *same*
//!   `even_offload_placement` the live provider uses.
//! * BMInf: each off-device layer's copy sits on the compute path (the
//!   host link is too slow to hide, §5.6).

use crate::comm::topology::Link;
use crate::config::ModelConfig;
use crate::memory::ledger::even_offload_placement;
use crate::perf::{self, DeviceModel, LayerShape};

/// One Fig. 13 scenario.
#[derive(Clone, Debug)]
pub struct PmepQuery {
    pub cfg: ModelConfig,
    pub n_local: usize,
    pub batch: usize,
    pub seq: usize,
    /// Copy link: NVLINK for PMEP, HOST for BMInf.
    pub link: Link,
    /// Prefetch lookahead in layers (0 = synchronous copies, BMInf).
    pub lookahead: usize,
    /// Peer-GPU concurrent workload shaves a little link bandwidth; the
    /// paper measures <5% interference (§4.4 prerequisite 1).
    pub peer_busy_penalty: f64,
}

impl PmepQuery {
    pub fn pmep(cfg: ModelConfig, n_local: usize, batch: usize, seq: usize) -> PmepQuery {
        PmepQuery {
            cfg,
            n_local,
            batch,
            seq,
            link: Link::NVLINK,
            lookahead: 1,
            peer_busy_penalty: 0.05,
        }
    }

    pub fn bminf(cfg: ModelConfig, n_local: usize, batch: usize, seq: usize) -> PmepQuery {
        PmepQuery {
            cfg,
            n_local,
            batch,
            seq,
            link: Link::HOST,
            lookahead: 0,
            peer_busy_penalty: 0.0,
        }
    }

    fn effective_link(&self) -> Link {
        Link {
            bandwidth_gbps: self.link.bandwidth_gbps * (1.0 - self.peer_busy_penalty),
            latency_us: self.link.latency_us,
        }
    }
}

/// Timeline result.
#[derive(Clone, Copy, Debug)]
pub struct PmepResult {
    pub total_seconds: f64,
    /// Seconds the compute stream stalled waiting on copies.
    pub stall_seconds: f64,
    pub tflops: f64,
}

/// Simulate one forward pass (all layers) and report throughput.
pub fn run(q: &PmepQuery, dev: &DeviceModel) -> PmepResult {
    let n = q.cfg.n_layers;
    let off = even_offload_placement(n, q.n_local.min(n));
    let layer_t = perf::layer_time(dev, &q.cfg, LayerShape::padded(q.batch, q.seq, 1), false);
    let copy_t = q.effective_link().transfer_time(q.cfg.layer_bytes(2));

    // Incoming copies contend with local HBM traffic: layers whose compute
    // overlaps an in-flight copy run slightly slower — this is the 2.3-3.9%
    // local-GPU loss Fig. 13 reports for PMEP.
    const COPY_INTERFERENCE: f64 = 0.05;

    // copy stream: one copy at a time, issued `lookahead` off-device layers
    // ahead (lookahead 0 = issued at need time)
    let mut compute_clock = 0.0f64;
    let mut copy_clock = 0.0f64;
    let mut stall = 0.0f64;
    // landed[i] = time copy of off layer i completes
    let mut landed: std::collections::HashMap<usize, f64> = Default::default();
    let mut next_to_issue = 0usize; // index into `off`

    let issue = |copy_clock: &mut f64, landed: &mut std::collections::HashMap<usize, f64>, layer: usize, at: f64| {
        let start = copy_clock.max(at);
        let done = start + copy_t;
        *copy_clock = done;
        landed.insert(layer, done);
    };

    for layer in 0..n {
        // prefetch policy: keep `lookahead` off-device copies in flight
        // ahead of the compute frontier (the live provider's behaviour)
        if q.lookahead > 0 {
            while next_to_issue < off.len()
                && off[next_to_issue] <= layer + find_horizon(&off, layer, q.lookahead)
            {
                issue(&mut copy_clock, &mut landed, off[next_to_issue], compute_clock);
                next_to_issue += 1;
            }
        }
        if off.contains(&layer) {
            if q.lookahead == 0 {
                // synchronous: the copy occupies the compute path
                issue(&mut copy_clock, &mut landed, layer, compute_clock);
            }
            let ready = landed.get(&layer).copied().unwrap_or(compute_clock);
            if ready > compute_clock {
                stall += ready - compute_clock;
                compute_clock = ready;
            }
        }
        // HBM interference while a copy is streaming in under this layer
        let copy_in_flight = copy_clock > compute_clock;
        compute_clock += if copy_in_flight { layer_t * (1.0 + COPY_INTERFERENCE) } else { layer_t };
    }

    let flops = perf::layer_flops(&q.cfg, q.batch, q.seq) * n as f64;
    PmepResult {
        total_seconds: compute_clock,
        stall_seconds: stall,
        tflops: flops / compute_clock / 1e12,
    }
}

/// How many layers ahead the next `lookahead` off-device layers span.
fn find_horizon(off: &[usize], layer: usize, lookahead: usize) -> usize {
    let upcoming: Vec<usize> = off.iter().copied().filter(|&o| o >= layer).take(lookahead).collect();
    match upcoming.last() {
        Some(&l) => l - layer + 1,
        None => 0,
    }
}

/// Differential oracle for the live K/V peer tier: seconds to ship one
/// parked session image (`bytes` of K/V blocks) over the park link.
/// Park and fetch are symmetric whole-image transfers, so the same
/// figure bounds both directions. `tests/peer_pool.rs` and
/// `benches/peer_pool.rs` compare the live engine's measured
/// `prefetch_stall_us` per fetch against these bounds: a peer fetch
/// must beat a host prefetch of the same image, and the overlapped
/// copier should push the visible stall well under the synchronous
/// transfer time.
pub fn kv_image_seconds(bytes: u64, link: Link) -> f64 {
    link.transfer_time(bytes)
}

/// The sim's verdict on the three-tier hierarchy: the peer:host stall
/// ratio for one session image. < 1.0 means parking beats spilling for
/// images of this size — the admission-time reason the tier policy
/// prefers the peer tier while its ledger has room.
pub fn kv_peer_over_host_ratio(bytes: u64) -> f64 {
    kv_image_seconds(bytes, Link::NVLINK) / kv_image_seconds(bytes, Link::HOST)
}

/// Throughput of the all-resident model (the "theoretical" bars Fig. 13
/// extrapolates from the 20-layer run).
pub fn resident_tflops(cfg: &ModelConfig, dev: &DeviceModel, batch: usize, seq: usize) -> f64 {
    let layer_t = perf::layer_time(dev, cfg, LayerShape::padded(batch, seq, 1), false);
    let flops = perf::layer_flops(cfg, batch, seq) * cfg.n_layers as f64;
    flops / (layer_t * cfg.n_layers as f64) / 1e12
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gpt3(n: usize) -> ModelConfig {
        ModelConfig::preset("gpt3").unwrap().with_layers(n)
    }

    #[test]
    fn fig13_pmep_loss_is_small() {
        // paper: local-GPU throughput drops only 2.3-3.9% for 24/30/40
        // layers at bs=32 pad=64
        let dev = DeviceModel::default();
        let base = resident_tflops(&gpt3(24), &dev, 32, 64);
        for n in [24usize, 30, 40] {
            let r = run(&PmepQuery::pmep(gpt3(n), 20, 32, 64), &dev);
            let loss = (1.0 - r.tflops / base) * 100.0;
            assert!((0.0..10.0).contains(&loss), "{n}-layer PMEP loss {loss}%");
        }
    }

    #[test]
    fn fig13_bminf_collapses() {
        // paper: CPU offload loses 55%/73%/81% for 24/30/40 layers
        let dev = DeviceModel::default();
        let base = resident_tflops(&gpt3(24), &dev, 32, 64);
        let mut losses = Vec::new();
        for n in [24usize, 30, 40] {
            let r = run(&PmepQuery::bminf(gpt3(n), 20, 32, 64), &dev);
            losses.push((1.0 - r.tflops / base) * 100.0);
        }
        assert!(losses[0] > 30.0, "24-layer BMInf loss {losses:?}");
        assert!(losses[2] > losses[1] && losses[1] > losses[0], "{losses:?}");
        assert!(losses[2] > 60.0, "{losses:?}");
    }

    #[test]
    fn pmep_stall_is_negligible_bminf_stall_is_not() {
        let dev = DeviceModel::default();
        let p = run(&PmepQuery::pmep(gpt3(24), 20, 32, 64), &dev);
        let b = run(&PmepQuery::bminf(gpt3(24), 20, 32, 64), &dev);
        assert!(p.stall_seconds < 0.1 * p.total_seconds, "pmep stall {p:?}");
        assert!(b.stall_seconds > 0.3 * b.total_seconds, "bminf stall {b:?}");
    }

    #[test]
    fn small_batch_amplifies_bminf_pain() {
        // §5.6: PMEP keeps throughput at small batch; CPU offload cannot
        // overlap because compute shrinks but copies don't
        let dev = DeviceModel::default();
        let base_small = resident_tflops(&gpt3(24), &dev, 8, 64);
        let p = run(&PmepQuery::pmep(gpt3(24), 20, 8, 64), &dev);
        let b = run(&PmepQuery::bminf(gpt3(24), 20, 8, 64), &dev);
        let p_keep = p.tflops / base_small;
        let b_keep = b.tflops / base_small;
        assert!(p_keep > 0.85, "pmep keeps {p_keep}");
        assert!(b_keep < 0.5, "bminf keeps {b_keep}");
    }

    #[test]
    fn no_offload_no_overhead() {
        let dev = DeviceModel::default();
        let r = run(&PmepQuery::pmep(gpt3(20), 20, 32, 64), &dev);
        assert_eq!(r.stall_seconds, 0.0);
        let base = resident_tflops(&gpt3(20), &dev, 32, 64);
        assert!((r.tflops / base - 1.0).abs() < 1e-9);
    }

    #[test]
    fn kv_peer_link_beats_host_link_for_session_images() {
        // a typical parked image: 8 blocks × 16 KiB — small enough that
        // latency matters, large enough that bandwidth does too
        for bytes in [16u64 * 1024, 128 * 1024, 4 * 1024 * 1024] {
            let r = kv_peer_over_host_ratio(bytes);
            assert!(r < 1.0, "peer/host ratio {r} at {bytes} bytes");
        }
        // and the absolute figure is sane: a 128 KiB image over NVLink
        // lands in microseconds, not milliseconds
        assert!(kv_image_seconds(128 * 1024, Link::NVLINK) < 1e-4);
    }

    #[test]
    fn lookahead_two_no_worse_than_one() {
        let dev = DeviceModel::default();
        let mut q = PmepQuery::pmep(gpt3(40), 20, 32, 64);
        let one = run(&q, &dev);
        q.lookahead = 2;
        let two = run(&q, &dev);
        assert!(two.total_seconds <= one.total_seconds + 1e-9);
    }
}
