//! Table renderers for every figure in the paper's evaluation — shared by
//! the bench binaries (`cargo bench`) and the CLI (`energonai bench ...`).
//! Each function regenerates one figure's rows and annotates the paper's
//! reported values where it states them.

use super::{pipeline, pmep, tp, System};
use crate::comm::topology::Topology;
use crate::config::ModelConfig;
use crate::perf::{breakdown, DeviceModel};

fn gpt3(layers: usize) -> ModelConfig {
    ModelConfig::preset("gpt3").unwrap().with_layers(layers)
}

/// Fig. 2: normalized kernel time distribution across the GPT family.
pub fn fig2() -> String {
    let mut out = String::from(
        "Fig 2 — kernel execution time distribution (bs=32, seq=64, FP16)\n\
         paper: GEMM share grows ~62% (125M) -> ~96% (175B)\n\n",
    );
    out += &breakdown::render(&breakdown::fig2(&DeviceModel::default()));
    out
}

/// Fig. 10: TP scalability on the fully NVLink-connected server.
pub fn fig10() -> String {
    let cfg = gpt3(12);
    let topo = Topology::full_nvlink(8);
    let mut out = String::from(
        "Fig 10 — tensor parallelism, 12-layer GPT-3, full-NVLink server\n\
         paper anchors: bs2/pad64 55.8% reduction @8; bs32/pad128 82.0% @8;\n\
         speedups 1.87x @2 ... 5.56x @8 (bs32/pad128)\n\n",
    );
    out += &format!("{:<6}{:<6}{:>10}{:>12}{:>12}\n", "batch", "pad", "gpus", "latency_ms", "reduction%");
    for &(b, s) in &[(2usize, 64usize), (8, 64), (16, 128), (32, 128)] {
        let base = tp::latency(&tp::TpQuery::new(cfg.clone(), topo.clone(), 1, b, s, System::EnergonAi));
        for &g in &[1usize, 2, 4, 8] {
            let l = tp::latency(&tp::TpQuery::new(cfg.clone(), topo.clone(), g, b, s, System::EnergonAi));
            out += &format!(
                "{:<6}{:<6}{:>10}{:>12.2}{:>12.1}\n",
                b,
                s,
                g,
                l * 1e3,
                (1.0 - l / base) * 100.0
            );
        }
    }
    out
}

/// Fig. 11: pipeline scalability vs FasterTransformer.
pub fn fig11() -> String {
    let cfg = gpt3(12);
    let topo = Topology::paired_nvlink(4);
    let mut out = String::from(
        "Fig 11 — pipeline parallelism, 12-layer GPT-3, paired-NVLink server\n\
         paper anchors: @4GPU bs1 EnergonAI 3.49x vs FT 3.29x; bs32 3.82x vs 3.45x\n\n",
    );
    out += &format!("{:<6}{:<6}{:>14}{:>10}{:>12}\n", "batch", "gpus", "energonai_x", "ft_x", "advantage%");
    for &b in &[1usize, 4, 16, 32] {
        for &pp in &[2usize, 3, 4] {
            let q = |system| pipeline::PipelineQuery {
                cfg: cfg.clone(),
                topo: topo.clone(),
                pp,
                batch: b,
                seq: 64,
                n_batches: 32,
                system,
                blocking_override: None,
            };
            let ours = pipeline::speedup(&q(System::EnergonAi));
            let ft = pipeline::speedup(&q(System::FasterTransformer));
            out += &format!(
                "{:<6}{:<6}{:>14.2}{:>10.2}{:>12.1}\n",
                b,
                pp,
                ours,
                ft,
                (ours / ft - 1.0) * 100.0
            );
        }
    }
    out
}

/// Fig. 12: DRCE vs pure EnergonAI vs FasterTransformer under TP.
pub fn fig12() -> String {
    let topo = Topology::paired_nvlink(8);
    let mut out = String::from(
        "Fig 12 — DRCE (valid = pad/2), paired-NVLink server\n\
         paper anchors: pure EnergonAI ~12% behind FT; +DRCE up to 46.8% over pure,\n\
         39% over FT; FT wins at bs=1; TP2->TP4 (2x layers) costs ~1.4x latency\n\n",
    );
    out += &format!(
        "{:<5}{:<8}{:<6}{:<6}{:>12}{:>10}{:>12}{:>14}\n",
        "tp", "layers", "batch", "pad", "energonai", "ft", "e+drce", "drce_vs_ft%"
    );
    for &(tpn, layers) in &[(2usize, 24usize), (4, 48)] {
        let cfg = gpt3(layers);
        for &(b, s) in &[(1usize, 64usize), (8, 64), (16, 64), (32, 64), (16, 128)] {
            let ours = tp::latency(&tp::TpQuery::new(cfg.clone(), topo.clone(), tpn, b, s, System::EnergonAi));
            let ft = tp::latency(&tp::TpQuery::new(cfg.clone(), topo.clone(), tpn, b, s, System::FasterTransformer));
            let drce = tp::latency(
                &tp::TpQuery::new(cfg.clone(), topo.clone(), tpn, b, s, System::EnergonAiDrce).with_valid(s / 2),
            );
            out += &format!(
                "{:<5}{:<8}{:<6}{:<6}{:>10.1}ms{:>8.1}ms{:>10.1}ms{:>14.1}\n",
                tpn,
                layers,
                b,
                s,
                ours * 1e3,
                ft * 1e3,
                drce * 1e3,
                (1.0 - drce / ft) * 100.0
            );
        }
    }
    out
}

/// Fig. 13: PMEP vs BMInf-style CPU offload, throughput in TFLOPS.
pub fn fig13() -> String {
    let dev = DeviceModel::default();
    let mut out = String::from(
        "Fig 13 — PMEP vs CPU offload; 20 layers resident on the local GPU\n\
         paper anchors (bs32/pad64): PMEP loses 2.3/3.9/3.9%; BMInf 55/73/81%\n\n",
    );
    out += &format!(
        "{:<8}{:<6}{:<6}{:>12}{:>10}{:>10}{:>12}{:>12}\n",
        "layers", "batch", "pad", "theoretical", "pmep", "bminf", "pmep_loss%", "bminf_loss%"
    );
    for &(b, s) in &[(32usize, 64usize), (32, 128), (64, 64), (64, 128)] {
        let base = pmep::resident_tflops(&gpt3(20), &dev, b, s);
        for &n in &[20usize, 24, 30, 40] {
            let p = pmep::run(&pmep::PmepQuery::pmep(gpt3(n), 20, b, s), &dev);
            let c = pmep::run(&pmep::PmepQuery::bminf(gpt3(n), 20, b, s), &dev);
            out += &format!(
                "{:<8}{:<6}{:<6}{:>12.1}{:>10.1}{:>10.1}{:>12.1}{:>12.1}\n",
                n,
                b,
                s,
                base,
                p.tflops,
                c.tflops,
                (1.0 - p.tflops / base) * 100.0,
                (1.0 - c.tflops / base) * 100.0
            );
        }
    }
    out
}

/// §5.3's guidance as a table: TP vs PP crossover — "use the fewest TP
/// devices that meet the latency constraint, then PP for memory".
pub fn crossover() -> String {
    let cfg = gpt3(12);
    let topo = Topology::full_nvlink(8);
    let mut out = String::from(
        "Crossover — TP latency gain vs PP throughput gain on 4 GPUs\n\n",
    );
    out += &format!("{:<6}{:>14}{:>14}{:>16}\n", "batch", "tp4_latency", "pp4_latency", "pp4_throughput_x");
    for &b in &[1usize, 4, 16, 32] {
        let tp4 = tp::latency(&tp::TpQuery::new(cfg.clone(), topo.clone(), 4, b, 64, System::EnergonAi));
        let serial = tp::latency(&tp::TpQuery::new(cfg.clone(), topo.clone(), 1, b, 64, System::EnergonAi));
        let ppq = pipeline::PipelineQuery {
            cfg: cfg.clone(),
            topo: topo.clone(),
            pp: 4,
            batch: b,
            seq: 64,
            n_batches: 32,
            system: System::EnergonAi,
            blocking_override: None,
        };
        out += &format!(
            "{:<6}{:>12.1}ms{:>12.1}ms{:>16.2}\n",
            b,
            tp4 * 1e3,
            serial * 1e3, // PP doesn't reduce per-batch latency
            pipeline::speedup(&ppq)
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_reports_render() {
        for (name, table) in [
            ("fig2", fig2()),
            ("fig10", fig10()),
            ("fig11", fig11()),
            ("fig12", fig12()),
            ("fig13", fig13()),
            ("crossover", crossover()),
        ] {
            assert!(table.lines().count() > 5, "{name} too short:\n{table}");
            let bad = table
                .split_whitespace()
                .any(|w| w == "NaN" || w == "inf" || w == "-inf");
            assert!(!bad, "{name} has NaN/inf:\n{table}");
        }
    }

    #[test]
    fn fig12_drce_wins_at_large_batch() {
        let t = fig12();
        // data rows: last column is drce_vs_ft%; DRCE must beat FT by a
        // wide margin on most rows (paper: up to 39%) while FT stays
        // competitive on the bs=1 rows
        let margins: Vec<f64> = t
            .lines()
            .filter(|l| l.trim_start().starts_with(['2', '4']))
            .filter_map(|l| l.split_whitespace().last()?.parse().ok())
            .collect();
        assert!(margins.len() >= 8, "{t}");
        let big_wins = margins.iter().filter(|&&m| m > 30.0).count();
        assert!(big_wins >= 6, "margins {margins:?}");
        assert!(margins.iter().any(|&m| m < 10.0), "FT never competitive: {margins:?}");
    }
}
