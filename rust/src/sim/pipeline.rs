//! Pipeline-parallel timeline simulation — regenerates Fig. 11 (pipeline
//! scalability, EnergonAI NBPP vs FasterTransformer blocking comms).
//!
//! The schedule mirrors the real worker loop: stage s processes batches in
//! ticket order; batch k enters stage s when (a) the stage is free and (b)
//! the activation has arrived from stage s-1. The two systems differ in
//! hand-off semantics, exactly like `comm::channel::Mode`:
//!
//! * **Non-blocking (NBPP)**: the sender enqueues and immediately starts
//!   its next batch (buffered channel; asynchronous comm overlaps).
//! * **Blocking (FT)**: `nccl_send` is a rendezvous — the sender stays
//!   busy until the receiver reaches the matching `recv`, so a slow
//!   downstream stage bubbles the upstream one (§5.4).

use super::System;
use crate::comm::topology::Topology;
use crate::config::{ModelConfig, ParallelConfig};
use crate::perf::{self, LayerShape};

/// One pipeline throughput query.
#[derive(Clone, Debug)]
pub struct PipelineQuery {
    pub cfg: ModelConfig,
    pub topo: Topology,
    pub pp: usize,
    pub batch: usize,
    pub seq: usize,
    pub n_batches: usize,
    pub system: System,
    /// Override the hand-off semantics independently of `system` — used by
    /// the ablation that isolates NBPP from FT's kernel-speed edge.
    pub blocking_override: Option<bool>,
}

impl PipelineQuery {
    fn blocking(&self) -> bool {
        self.blocking_override.unwrap_or_else(|| self.system.blocking_pipeline())
    }
}

/// Per-stage compute time (embed on stage 0, logits on the last — the
/// imbalance the paper mentions in §5.4).
/// Exposed for debugging/benches: per-stage compute time.
pub fn dbg_stage_time(q: &PipelineQuery, stage: usize) -> f64 {
    stage_time(q, stage)
}

fn stage_time(q: &PipelineQuery, stage: usize) -> f64 {
    let dev = q.system.device();
    let par = ParallelConfig::new(1, q.pp);
    let layers = par.stage_layers(stage, q.cfg.n_layers).len() as f64;
    let shape = LayerShape::padded(q.batch, q.seq, 1);
    let mut t = layers * perf::layer_time(&dev, &q.cfg, shape, q.system.fused_attention());
    if stage == 0 {
        // the paper's §5.4 workload measures the transformer stack: the
        // only per-stage extra it mentions is "one embedding module in the
        // top", whose slight imbalance grows with device count — no
        // vocab-projection head is benchmarked
        t += perf::embed_time(&dev, &q.cfg, q.batch, q.seq);
    }
    t
}

/// Activation transfer time between consecutive stages.
fn xfer_time(q: &PipelineQuery, stage: usize) -> f64 {
    if q.pp <= 1 {
        return 0.0;
    }
    let bytes = (q.batch * q.seq * q.cfg.hidden * 2) as u64;
    q.topo.p2p_time(stage, stage + 1, bytes)
}

/// Per-boundary stream-synchronize cost of blocking comms, as a fraction
/// of the stage's compute time (kernel-drain + relaunch lost overlap).
/// Calibrated once against Fig. 11's reported EnergonAI-vs-FT gap.
pub const BLOCKING_SYNC_FRACTION: f64 = 0.06;

/// Simulate the pipeline timeline; returns the makespan in seconds.
pub fn makespan(q: &PipelineQuery) -> f64 {
    let stages = q.pp;
    let compute: Vec<f64> = (0..stages).map(|s| stage_time(q, s)).collect();
    // stage_free[s]: when stage s can start its next batch
    let mut stage_free = vec![0.0f64; stages];
    // arrival of batch k at stage s
    let mut finish_last = 0.0;
    for k in 0..q.n_batches {
        // engine publishes command k (non-blocking in both systems; the
        // paper's engine is EnergonAI's — FT uses a driver loop, costed
        // the same)
        let launch = super::ENGINE_OVERHEAD_US * 1e-6 * (k as f64 + 1.0);
        let mut arrive = launch;
        for s in 0..stages {
            let start = arrive.max(stage_free[s]);
            let done = start + compute[s];
            if s + 1 < stages {
                let xfer = xfer_time(q, s);
                if q.blocking() {
                    // rendezvous nccl_send/recv: the transfer can only run
                    // once BOTH sides arrive and it occupies both; after
                    // the blocking call returns, the host must re-launch
                    // the next batch's kernel stream — a serial cost that
                    // cannot overlap anything (§5.4's bubbles; the
                    // fraction is calibrated once to Fig. 11's reported
                    // ~10% EnergonAI-vs-FT scalability gap)
                    let rendezvous = done.max(stage_free[s + 1]);
                    let xfer_end = rendezvous + xfer;
                    stage_free[s] = xfer_end + BLOCKING_SYNC_FRACTION * compute[s];
                    arrive = xfer_end;
                } else {
                    // NBPP: async send — the copy streams out while the
                    // sender starts its next batch and the receiver
                    // finishes its previous one
                    stage_free[s] = done;
                    arrive = done + xfer;
                }
            } else {
                // last stage: the reply send back to the engine is also a
                // blocking boundary in FT mode (stream sync before the
                // synchronous send); NBPP replies through a buffered
                // channel while the next batch's kernels launch. A 1-GPU
                // run has no comm boundaries at all — it is the unpenalized
                // baseline both systems normalize against.
                stage_free[s] = if q.blocking() && stages > 1 {
                    done + BLOCKING_SYNC_FRACTION * compute[s]
                } else {
                    done
                };
                finish_last = done;
            }
        }
    }
    finish_last
}

/// Throughput speedup vs the 1-GPU run of the same system (Fig. 11's y-axis).
pub fn speedup(q: &PipelineQuery) -> f64 {
    let base = PipelineQuery { pp: 1, ..q.clone() };
    makespan(&base) / makespan(q)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn query(pp: usize, batch: usize, system: System) -> PipelineQuery {
        PipelineQuery {
            cfg: ModelConfig::preset("gpt3").unwrap().with_layers(12),
            topo: Topology::paired_nvlink(4),
            pp,
            batch,
            seq: 64,
            n_batches: 32,
            system,
            blocking_override: None,
        }
    }

    #[test]
    fn fig11_scaling_improves_with_batch_size() {
        // paper: bs=1 → 3.49×@4GPU (EnergonAI); bs=32 → 3.82×
        let s1 = speedup(&query(4, 1, System::EnergonAi));
        let s32 = speedup(&query(4, 32, System::EnergonAi));
        assert!(s32 > s1, "bs32 {s32} should beat bs1 {s1}");
        assert!((3.0..4.0).contains(&s1), "bs1 speedup {s1}");
        assert!((3.4..4.0).contains(&s32), "bs32 speedup {s32}");
    }

    #[test]
    fn fig11_energonai_beats_ft() {
        // paper: EnergonAI ~10% better scalability than FT
        for bs in [1usize, 4, 16, 32] {
            let ours = speedup(&query(4, bs, System::EnergonAi));
            let ft = speedup(&query(4, bs, System::FasterTransformer));
            assert!(ours > ft, "bs={bs}: ours {ours} vs ft {ft}");
        }
        let ours = speedup(&query(4, 32, System::EnergonAi));
        let ft = speedup(&query(4, 32, System::FasterTransformer));
        let adv = (ours / ft - 1.0) * 100.0;
        assert!((3.0..25.0).contains(&adv), "advantage {adv}%");
    }

    #[test]
    fn fig11_efficiency_drops_with_more_stages() {
        // paper: ratios 0.99@2, 0.96@3, 0.93@4 for bs=32
        let e2 = speedup(&query(2, 32, System::EnergonAi)) / 2.0;
        let e3 = speedup(&query(3, 32, System::EnergonAi)) / 3.0;
        let e4 = speedup(&query(4, 32, System::EnergonAi)) / 4.0;
        assert!(e2 > e3 && e3 > e4, "{e2} {e3} {e4}");
        assert!(e2 > 0.93 && e4 > 0.80, "{e2} {e4}");
    }

    #[test]
    fn single_stage_speedup_is_one() {
        let s = speedup(&query(1, 8, System::EnergonAi));
        assert!((s - 1.0).abs() < 1e-9);
    }

    #[test]
    fn blocking_creates_bubbles_on_imbalanced_stages() {
        // 13 layers on 4 stages: 4,3,3,3 — imbalance makes rendezvous
        // stall the fat stage's successor chain
        let mut q = query(4, 16, System::EnergonAi);
        q.cfg = ModelConfig::preset("gpt3").unwrap().with_layers(13);
        let nb = makespan(&q);
        q.system = System::FasterTransformer;
        let ft_cfg_span = makespan(&q);
        // FT's fused kernels make each stage faster, yet blocking still
        // keeps it from beating NBPP proportionally; compare bubbles via
        // normalized efficiency instead of absolute time
        let nb_eff = {
            let base = PipelineQuery { pp: 1, system: System::EnergonAi, ..q.clone() };
            makespan(&base) / nb / 4.0
        };
        let ft_eff = {
            let base = PipelineQuery { pp: 1, system: System::FasterTransformer, ..q.clone() };
            makespan(&base) / ft_cfg_span / 4.0
        };
        assert!(nb_eff > ft_eff, "nb {nb_eff} vs ft {ft_eff}");
    }
}
