//! Tensor-parallel latency simulation — regenerates Fig. 10 (TP scaling on
//! the fully NVLink-connected server) and Fig. 12 (EnergonAI vs
//! EnergonAI(DRCE) vs FasterTransformer on the partially connected one).
//!
//! The schedule is the real worker's (`coordinator::worker::run_layer`):
//! per layer, each rank computes its attention shard, the group
//! all-reduces a (b·s, h) tensor, computes its MLP shard, all-reduces
//! again — "a single synchronization point every two linear operations"
//! (§4.1.3). DRCE shrinks both the linear rows and the all-reduce payload
//! to the valid-token count (§4.3).

use super::System;
use crate::comm::topology::Topology;
use crate::config::ModelConfig;
use crate::perf::{self, LayerShape};

/// One TP latency query.
#[derive(Clone, Debug)]
pub struct TpQuery {
    pub cfg: ModelConfig,
    pub topo: Topology,
    pub tp: usize,
    pub batch: usize,
    pub seq: usize,
    /// Valid tokens per sequence (None = fully padded input).
    pub valid: Option<usize>,
    pub system: System,
}

impl TpQuery {
    pub fn new(cfg: ModelConfig, topo: Topology, tp: usize, batch: usize, seq: usize, system: System) -> TpQuery {
        TpQuery { cfg, topo, tp, batch, seq, valid: None, system }
    }

    pub fn with_valid(mut self, v: usize) -> Self {
        self.valid = Some(v);
        self
    }
}

/// End-to-end single-batch latency (seconds).
pub fn latency(q: &TpQuery) -> f64 {
    let dev = q.system.device();
    let ranks: Vec<usize> = (0..q.tp).collect();
    let drce_active = q.system.drce() && q.valid.is_some();
    let linear_rows = if drce_active {
        q.batch * q.valid.unwrap()
    } else {
        q.batch * q.seq
    };
    let shape = LayerShape { batch: q.batch, seq: q.seq, linear_rows, tp: q.tp };
    let layer_compute = perf::layer_time(&dev, &q.cfg, shape, q.system.fused_attention());

    // two all-reduces per layer over the activation (fp16)
    let ar_bytes = (linear_rows * q.cfg.hidden * 2) as u64;
    let ar = q.topo.allreduce_time(&ranks, ar_bytes);

    // DRCE adds the pad-remove/rebuild kernels around attention (§4.3):
    // two gather kernels over the qkv/context activations
    let drce_overhead = if drce_active {
        2.0 * dev.mem_time((q.batch * q.seq * q.cfg.hidden * 2) as u64)
    } else {
        0.0
    };

    let per_layer = layer_compute + 2.0 * ar + drce_overhead;
    let embed = perf::embed_time(&dev, &q.cfg, q.batch, q.seq);
    let logits = perf::logits_time(&dev, &q.cfg, q.batch, q.seq);
    super::ENGINE_OVERHEAD_US * 1e-6 + embed + q.cfg.n_layers as f64 * per_layer + logits
}

/// Latency-reduction percentage vs the 1-GPU run (Fig. 10's metric).
pub fn latency_reduction(q1: &TpQuery, qn: &TpQuery) -> f64 {
    let l1 = latency(q1);
    let ln = latency(qn);
    (1.0 - ln / l1) * 100.0
}

/// Speedup of n-GPU TP vs serial.
pub fn speedup(cfg: &ModelConfig, topo: &Topology, tp: usize, batch: usize, seq: usize, system: System) -> f64 {
    let q1 = TpQuery::new(cfg.clone(), topo.clone(), 1, batch, seq, system);
    let qn = TpQuery::new(cfg.clone(), topo.clone(), tp, batch, seq, system);
    latency(&q1) / latency(&qn)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gpt3_12l() -> ModelConfig {
        ModelConfig::preset("gpt3").unwrap().with_layers(12)
    }

    #[test]
    fn fig10_large_batch_scales_better() {
        // paper: bs2/pad64 → 55.8% reduction at 8 GPUs; bs32/pad128 → 82.0%
        let cfg = gpt3_12l();
        let topo = Topology::full_nvlink(8);
        let small = latency_reduction(
            &TpQuery::new(cfg.clone(), topo.clone(), 1, 2, 64, System::EnergonAi),
            &TpQuery::new(cfg.clone(), topo.clone(), 8, 2, 64, System::EnergonAi),
        );
        let large = latency_reduction(
            &TpQuery::new(cfg.clone(), topo.clone(), 1, 32, 128, System::EnergonAi),
            &TpQuery::new(cfg.clone(), topo.clone(), 8, 32, 128, System::EnergonAi),
        );
        assert!(large > small, "large {large} <= small {small}");
        assert!((45.0..70.0).contains(&small), "small-batch reduction {small}");
        assert!((75.0..88.0).contains(&large), "large-batch reduction {large}");
    }

    #[test]
    fn fig10_2gpu_speedup_near_paper() {
        // paper: 1.87x at 2 GPUs for bs32/pad128
        let cfg = gpt3_12l();
        let topo = Topology::full_nvlink(8);
        let s2 = speedup(&cfg, &topo, 2, 32, 128, System::EnergonAi);
        assert!((1.6..2.0).contains(&s2), "2-gpu speedup {s2}");
        let s8 = speedup(&cfg, &topo, 8, 32, 128, System::EnergonAi);
        assert!((4.3..6.8).contains(&s8), "8-gpu speedup {s8}");
        assert!(s8 > s2);
    }

    #[test]
    fn drce_reduces_latency_at_half_padding() {
        // Fig. 12: DRCE up to ~46.8% faster than pure EnergonAI
        let cfg = ModelConfig::preset("gpt3").unwrap().with_layers(24);
        let topo = Topology::paired_nvlink(8);
        let pure = latency(&TpQuery::new(cfg.clone(), topo.clone(), 2, 16, 64, System::EnergonAi));
        let drce = latency(
            &TpQuery::new(cfg.clone(), topo.clone(), 2, 16, 64, System::EnergonAiDrce).with_valid(32),
        );
        let reduction = (1.0 - drce / pure) * 100.0;
        assert!((30.0..50.0).contains(&reduction), "drce reduction {reduction}");
    }

    #[test]
    fn ft_beats_pure_energonai_on_fixed_length() {
        // Fig. 12: pure EnergonAI ~12% slower than FT
        let cfg = ModelConfig::preset("gpt3").unwrap().with_layers(24);
        let topo = Topology::paired_nvlink(8);
        let ours = latency(&TpQuery::new(cfg.clone(), topo.clone(), 2, 16, 64, System::EnergonAi));
        let ft = latency(&TpQuery::new(cfg.clone(), topo.clone(), 2, 16, 64, System::FasterTransformer));
        let gap = (ours / ft - 1.0) * 100.0;
        assert!((4.0..20.0).contains(&gap), "FT advantage {gap}%");
    }

    #[test]
    fn drce_beats_ft_except_tiny_batch() {
        // Fig. 12: DRCE up to 39% over FT, but FT wins at batch 1
        let cfg = ModelConfig::preset("gpt3").unwrap().with_layers(24);
        let topo = Topology::paired_nvlink(8);
        let at = |bs: usize| {
            let d = latency(
                &TpQuery::new(cfg.clone(), topo.clone(), 2, bs, 64, System::EnergonAiDrce).with_valid(32),
            );
            let f = latency(&TpQuery::new(cfg.clone(), topo.clone(), 2, bs, 64, System::FasterTransformer));
            (d, f)
        };
        let (d32, f32_) = at(32);
        assert!(d32 < f32_, "DRCE should win at bs=32: {d32} vs {f32_}");
        let (d1, f1) = at(1);
        assert!(d1 > f1 * 0.95, "FT should be competitive at bs=1: {d1} vs {f1}");
    }

    #[test]
    fn pcie_crossing_hurts_tp4() {
        // Fig. 12's observation: TP=2→TP=4 with doubled layers costs ~1.4×
        // because TP=4 crosses PCIe on the paired server
        let topo = Topology::paired_nvlink(8);
        let l2 = latency(&TpQuery::new(
            ModelConfig::preset("gpt3").unwrap().with_layers(24),
            topo.clone(),
            2,
            16,
            64,
            System::EnergonAi,
        ));
        let l4 = latency(&TpQuery::new(
            ModelConfig::preset("gpt3").unwrap().with_layers(48),
            topo.clone(),
            4,
            16,
            64,
            System::EnergonAi,
        ));
        let ratio = l4 / l2;
        assert!((1.15..2.2).contains(&ratio), "tp2->tp4 ratio {ratio}");
    }
}
