//! Paper-scale simulation (the DESIGN.md hardware substitution).
//!
//! The coordinator's *policies* run for real in this repo (tests exercise
//! them through PJRT on scaled-down models); what a 1-core CPU testbed
//! cannot do is time GPT-3-sized kernels on 8×A100. These simulators
//! re-cost the same schedules with the [`perf`](crate::perf) roofline and
//! the [`comm::topology`](crate::comm::topology) link model, sharing the
//! policy code (layer partitioning, offload placement, bucket picking)
//! with the real engine so the *shape* of every figure comes from the
//! same decisions the live system makes.
//!
//! * [`tp`] — tensor-parallel latency (Fig. 10, Fig. 12 incl. DRCE)
//! * [`pipeline`] — microbatch pipeline timeline, non-blocking vs
//!   blocking rendezvous (Fig. 11)
//! * [`pmep`] — compute/copy overlap timeline for peer-memory pooling vs
//!   CPU offload (Fig. 13)

pub mod pipeline;
pub mod report;
pub mod pmep;
pub mod tp;

use crate::perf::DeviceModel;

/// Engine-side fixed cost per batch command (RPC publish + thread hop).
/// Measured on the real engine (EXPERIMENTS.md §Perf) and scaled to the
/// paper's PyTorch-RPC setup.
pub const ENGINE_OVERHEAD_US: f64 = 80.0;

/// System under simulation: EnergonAI or the FasterTransformer baseline.
///
/// FT's two advantages the paper concedes (§5.5): warm-up GEMM algorithm
/// selection (a slightly higher effective GEMM efficiency) and the fused
/// MHA kernel (no separate softmax/transpose/bias launches). Its
/// disadvantage: blocking `nccl_send/recv` pipeline hand-offs (§5.4).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum System {
    EnergonAi,
    EnergonAiDrce,
    FasterTransformer,
}

impl System {
    /// Device model as seen by this system's kernels.
    pub fn device(&self) -> DeviceModel {
        let mut d = DeviceModel::default();
        if *self == System::FasterTransformer {
            // cublas algo selection in the warm-up phase (§5.5)
            d.gemm_peak_eff *= 1.08;
        }
        d
    }

    /// Whether attention-side memory kernels are fused away.
    pub fn fused_attention(&self) -> bool {
        matches!(self, System::FasterTransformer)
    }

    pub fn blocking_pipeline(&self) -> bool {
        matches!(self, System::FasterTransformer)
    }

    pub fn drce(&self) -> bool {
        matches!(self, System::EnergonAiDrce)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ft_has_fused_and_blocking() {
        assert!(System::FasterTransformer.fused_attention());
        assert!(System::FasterTransformer.blocking_pipeline());
        assert!(!System::EnergonAi.fused_attention());
        assert!(!System::EnergonAi.blocking_pipeline());
        assert!(System::EnergonAiDrce.drce());
    }

    #[test]
    fn ft_device_is_faster_on_gemm() {
        let e = System::EnergonAi.device();
        let f = System::FasterTransformer.device();
        assert!(f.gemm_peak_eff > e.gemm_peak_eff);
    }
}
