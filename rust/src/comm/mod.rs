//! Communication substrate: the "NCCL + NVLink" of this reproduction.
//!
//! Two halves:
//! * [`channel`] — real in-process message passing between worker threads,
//!   with both **blocking** (rendezvous, FasterTransformer's
//!   `nccl_send`/`nccl_recv` style, §5.4) and **non-blocking** (buffered,
//!   EnergonAI NBPP style) semantics. Correctness-bearing: actual tensors
//!   move through these channels.
//! * [`topology`] — the analytic link model (NVLink 600 GB/s, PCIe, host)
//!   used by the perf model and the discrete-event simulator to cost
//!   paper-scale transfers.
//! * [`collective`] — ring all-reduce / broadcast built on [`channel`],
//!   used by the TP orchestrator (two all-reduces per layer, §4.1.3).
//!   Chunk payloads are recyclable arena buffers ([`collective::ChunkMsg`])
//!   so steady-state collectives are allocation-free (§Perf).

pub mod channel;
pub mod collective;
pub mod topology;

pub use channel::{CommWorld, Endpoint};
pub use collective::{broadcast, ring_allreduce, ChunkMsg, WireBuf};
pub use topology::{Interconnect, Link, Topology};
